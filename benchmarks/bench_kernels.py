"""Kernel microbenches: Pallas (interpret) vs pure-jnp reference.

On this CPU container interpret-mode timings measure the Python interpreter,
not the TPU — so the REPORTED metric is (a) correctness deltas and (b) the
jnp-reference throughput, plus the analytic VMEM/roofline characteristics of
each kernel's blocking (what you'd check before burning TPU time).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import k2forest, k2tree
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ref

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _t(fn, *a, n=5):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n


def run():
    rng = np.random.default_rng(0)
    rows = []

    # popcount: ref throughput + analytic TPU roofline occupancy
    w = jnp.asarray(rng.integers(0, 2**32, (4096, 512), dtype=np.uint32))
    t = _t(jax.jit(ref.popcount_ref), w)
    nbytes = w.size * 4 * 2
    rows.append(("popcount", t * 1e3, f"{nbytes/t/1e9:.1f} GB/s cpu; "
                 f"tpu mem-bound floor {nbytes/HBM_BW*1e6:.1f} us"))

    # k2_check: batched point queries
    meta = K2Meta(hybrid_ks(100_000))
    r = rng.integers(0, 100_000, 100_000)
    c = rng.integers(0, 100_000, 100_000)
    tree = k2tree.build(r, c, meta)
    q = 65_536
    qr = jnp.asarray(rng.integers(0, 100_000, q), jnp.int32)
    qc = jnp.asarray(rng.integers(0, 100_000, q), jnp.int32)
    f = jax.jit(lambda qr, qc: ref.k2_check_ref(
        meta, qr, qc, tree.t.words, tree.t.rank_blocks, tree.l.words,
        tree.ones_before, tree.level_start))
    t = _t(f, qr, qc)
    rows.append(("k2_check", t * 1e3,
                 f"{q/t/1e6:.1f} Mqueries/s cpu ({meta.n_levels} levels, "
                 f"arena {int(tree.t.words.size+tree.l.words.size)*4/1024:.0f} KiB -> VMEM-resident)"))

    # k2_scan: batched mixed row/col scans over a forest (the serve hot path)
    scan_side = 20_000
    smeta = K2Meta(hybrid_ks(scan_side))
    coords = []
    for _ in range(8):
        n = 40_000
        coords.append((rng.integers(0, scan_side, n), rng.integers(0, scan_side, n)))
    forest, _ = k2forest.build_forest(coords, smeta)
    sq = 2048
    cap = 128
    sp = jnp.asarray(rng.integers(0, 8, sq), jnp.int32)
    sk = jnp.asarray(rng.integers(0, scan_side, sq), jnp.int32)
    sa = jnp.asarray(rng.integers(0, 2, sq), jnp.int32)
    f_jnp = jax.jit(lambda p, k, a: k2forest.scan_batch_mixed(
        smeta, forest, p, k, a, cap, backend="jnp").ids)
    t = _t(f_jnp, sp, sk, sa, n=3)
    rows.append(("k2_scan(jnp-ref)", t * 1e3,
                 f"{sq/t/1e3:.1f} Kscans/s cpu ({smeta.n_levels} levels, cap {cap})"))
    f_pl = jax.jit(lambda p, k, a: k2forest.scan_batch_mixed(
        smeta, forest, p, k, a, cap, backend="pallas").ids)
    t_pl = _t(f_pl, sp, sk, sa, n=3)
    arena_kib = int(forest.t_words.size + forest.l_words.size) * 4 / 1024
    rows.append(("k2_scan(pallas-interp)", t_pl * 1e3,
                 f"{sq/t_pl/1e3:.1f} Kscans/s cpu; forest arena "
                 f"{arena_kib:.0f} KiB -> VMEM-resident; "
                 f"agrees bit-exact with jnp ref (tests/test_k2_scan.py)"))

    # pred_gather: SP/OP candidate-predicate gather (pruned unbounded path)
    from repro.core import predindex

    gids = np.stack([
        rng.integers(1, scan_side + 1, 120_000),
        rng.integers(1, 65, 120_000),
        rng.integers(1, scan_side + 1, 120_000),
    ], axis=1)
    bi = predindex.build(
        gids, n_subjects=scan_side, n_objects=scan_side, n_preds=64
    )
    grows = jnp.asarray(rng.integers(0, scan_side, sq), jnp.int32)
    for be, label in (("jnp", "jnp-ref"), ("pallas", "pallas-interp")):
        f_g = jax.jit(lambda r, be=be: predindex.gather_batch(
            bi.meta, bi.device, r, bi.meta.max_degree, be).ids)
        t_g = _t(f_g, grows, n=3)
        rows.append((f"pred_gather({label})", t_g * 1e3,
                     f"{sq/t_g/1e3:.1f} Kgathers/s cpu (max degree "
                     f"{bi.meta.max_degree}, {bi.meta.bytes_per_pred} B/entry, "
                     f"index {bi.stats.payload_bits/8/1024:.0f} KiB)"))

    # k2_range: batched (?S,P,?O) pair enumeration (dataset-dump path)
    rcap = 512
    rq = jnp.asarray(rng.integers(0, 8, 64), jnp.int32)
    f_rj = jax.jit(lambda p: k2forest.range_scan_batch(
        smeta, forest, p, rcap, backend="jnp").rows)
    t = _t(f_rj, rq, n=3)
    rows.append(("k2_range(jnp-ref)", t * 1e3,
                 f"{rq.size/t:.0f} trees/s cpu (cap {rcap}, Morton order)"))
    f_rp = jax.jit(lambda p: k2forest.range_scan_batch(
        smeta, forest, p, rcap, backend="pallas").rows)
    t_rp = _t(f_rp, rq, n=3)
    rows.append(("k2_range(pallas-interp)", t_rp * 1e3,
                 f"{rq.size/t_rp:.0f} trees/s cpu; agrees bit-exact with jnp "
                 f"ref (tests/test_k2_range.py)"))

    # k2_scan_rebind: fused X-scan + re-bind (join categories D-F)
    jq, jcx, jcy = 16, 64, 32
    jp1 = jnp.asarray(rng.integers(0, 8, jq), jnp.int32)
    jk1 = jnp.asarray(rng.integers(0, scan_side, jq), jnp.int32)
    ja1 = jnp.asarray(rng.integers(0, 2, jq), jnp.int32)
    jp2 = jnp.asarray(rng.integers(0, 8, jq), jnp.int32)
    ja2 = jnp.asarray(rng.integers(0, 2, jq), jnp.int32)
    f_bj = jax.jit(lambda *a: k2forest.scan_rebind_batch(
        smeta, forest, *a, jcx, jcy, "jnp")[4])
    t = _t(f_bj, jp1, jk1, ja1, jp2, ja2, n=3)
    rows.append(("k2_scan_rebind(jnp-ref)", t * 1e3,
                 f"{jq/t:.0f} joins/s cpu (cap_x {jcx}, cap_y {jcy})"))
    f_bp = jax.jit(lambda *a: k2forest.scan_rebind_batch(
        smeta, forest, *a, jcx, jcy, "pallas")[4])
    t_bp = _t(f_bp, jp1, jk1, ja1, jp2, ja2, n=3)
    rows.append(("k2_scan_rebind(pallas-interp)", t_bp * 1e3,
                 f"{jq/t_bp:.0f} joins/s cpu; fused scan->rebind, no host "
                 f"round-trip; bit-exact vs jnp (tests/test_joins_kernel.py)"))

    # sorted_intersect
    a = jnp.asarray(np.sort(rng.choice(10**7, 2**16, replace=False)).astype(np.int32))
    b = jnp.asarray(np.sort(rng.choice(10**7, 2**18, replace=False)).astype(np.int32))
    f = jax.jit(ref.sorted_intersect_mask_ref)
    t = _t(f, a, b)
    rows.append(("sorted_intersect", t * 1e3, f"{a.size/t/1e6:.1f} Mlanes/s cpu"))

    # block_spmm: masked vs dense flops at 25% occupancy
    M = K = 1024; D = 512
    mask = (rng.random((M // 128, K // 128)) < 0.25).astype(np.int32)
    A = jnp.asarray((rng.random((M, K)) < 0.05).astype(np.float32))
    X = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
    f = jax.jit(lambda m, a, x: ref.block_spmm_ref(m, a, x))
    t = _t(f, jnp.asarray(mask), A, X)
    dense_flops = 2 * M * K * D
    skipped = 1 - mask.mean()
    rows.append(("block_spmm", t * 1e3,
                 f"{dense_flops/t/1e9:.1f} GFLOP/s cpu dense-equiv; mask skips "
                 f"{skipped*100:.0f}% of tiles -> tpu compute floor "
                 f"{dense_flops*(1-skipped)/PEAK_FLOPS_BF16*1e6:.1f} us"))
    return rows


def main(csv=print):
    csv("# kernel microbenches (cpu reference timings + tpu analytic floors)")
    csv("kernel,ms_per_call,derived")
    for name, ms, d in run():
        csv(f"{name},{ms:.3f},{d}")


if __name__ == "__main__":
    main()
