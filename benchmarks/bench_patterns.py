"""Paper Table 3 — query times per simple triple pattern (ms/pattern).

Mirrors the paper's methodology: (S,P,O) patterns drawn from the dataset
itself, others sampled like the USEWOD'2011 mix; times averaged per pattern.
Two engines are compared, matching the paper's vertical-partitioning story:

    k2         this paper's engine (jit'd batched k²-tree primitives)
    vertical   a faithful MonetDB-style baseline: per-predicate sorted [S,O]
               (+ [O,S]) numpy tables with binary search — the strongest
               reasonable table implementation (the paper's Table 3 MonetDB
               numbers include SQL overhead; ours is a floor, so observed
               speedups are conservative)

The paper's headline — bounded-predicate patterns are fast everywhere, and
unbounded-predicate patterns catastrophically slow on vertical tables but
fine on k²-triples — is asserted as ratios in EXPERIMENTS.md.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng, k2forest, k2triples, patterns
from repro.core.query import ExecConfig
from repro.data import rdf

# benchmarks select the traversal substrate through explicit ExecConfigs —
# never by mutating REPRO_SCAN_BACKEND (both columns of a backend sweep
# must come from the same process state)
BACKEND_CFGS = {be: ExecConfig(backend=be) for be in ("pallas", "jnp")}


class VerticalTables:
    """MonetDB-style baseline: per-predicate [S,O] tables, SO + OS sorted."""

    def __init__(self, ids: np.ndarray, n_preds: int):
        self.so = {}
        self.os = {}
        for p in range(1, n_preds + 1):
            rowsp = ids[ids[:, 1] == p][:, [0, 2]]
            self.so[p] = rowsp[np.lexsort((rowsp[:, 1], rowsp[:, 0]))]
            self.os[p] = rowsp[np.lexsort((rowsp[:, 0], rowsp[:, 1]))]
        self.n_preds = n_preds

    def spo(self, s, p, o):
        t = self.so[p]
        i = np.searchsorted(t[:, 0], s)
        j = np.searchsorted(t[:, 0], s, side="right")
        return o in t[i:j, 1]

    def sp_any(self, s, p):
        t = self.so[p]
        i = np.searchsorted(t[:, 0], s)
        j = np.searchsorted(t[:, 0], s, side="right")
        return t[i:j, 1]

    def any_po(self, p, o):
        t = self.os[p]
        i = np.searchsorted(t[:, 1], o)
        j = np.searchsorted(t[:, 1], o, side="right")
        return t[i:j, 0]

    # unbounded predicate: must touch EVERY table (the paper's weakness)
    def s_any_o(self, s, o):
        return [p for p in range(1, self.n_preds + 1) if self.spo(s, p, o)]

    def s_any_any(self, s):
        return {p: self.sp_any(s, p) for p in range(1, self.n_preds + 1)}

    def any_any_o(self, o):
        return {p: self.any_po(p, o) for p in range(1, self.n_preds + 1)}


def _timeit(fn, n, *args):
    fn(*args[0] if args else ())  # warm
    t0 = time.perf_counter()
    for i in range(n):
        a = args[i % len(args)] if args else ()
        r = fn(*a)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    return (time.perf_counter() - t0) / n * 1e3  # ms


def run(n_triples: int = 120_000, n_preds: int = 64, n_queries: int = 50, seed=0):
    ds = rdf.generate(
        n_triples, n_subjects=n_triples // 12, n_preds=n_preds,
        n_objects=n_triples // 8, seed=seed,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    vt = VerticalTables(ds.ids, ds.n_preds)
    meta, f = store.meta, store.forest
    cap = 2048
    rng = np.random.default_rng(seed + 1)
    qs = ds.ids[rng.integers(0, ds.n_triples, n_queries)]
    args_spo = [(int(s), int(p), int(o)) for s, p, o in qs]

    # jit'd single-pattern entry points (compile once each)
    j_spo = jax.jit(lambda s, p, o: patterns.spo(meta, f, s, p, o))
    j_sp = jax.jit(lambda s, p: patterns.sp_any(meta, f, s, p, cap).ids)
    j_po = jax.jit(lambda p, o: patterns.any_po(meta, f, p, o, cap).ids)
    j_s_o = jax.jit(lambda s, o: patterns.s_any_o(meta, f, s, o))
    j_s = jax.jit(lambda s: patterns.s_any_any(meta, f, s, cap).ids)
    j_o = jax.jit(lambda o: patterns.any_any_o(meta, f, o, cap).ids)

    out = {}
    out["(S,P,O)"] = (
        _timeit(lambda s, p, o: j_spo(s, p, o).block_until_ready(), 30, *args_spo),
        _timeit(vt.spo, 30, *args_spo),
    )
    args_sp = [(s, p) for s, p, o in args_spo]
    out["(S,P,?O)"] = (
        _timeit(lambda s, p: j_sp(s, p).block_until_ready(), 30, *args_sp),
        _timeit(vt.sp_any, 30, *args_sp),
    )
    args_po = [(p, o) for s, p, o in args_spo]
    out["(?S,P,O)"] = (
        _timeit(lambda p, o: j_po(p, o).block_until_ready(), 30, *args_po),
        _timeit(vt.any_po, 30, *args_po),
    )
    args_so = [(s, o) for s, p, o in args_spo]
    out["(S,?P,O)"] = (
        _timeit(lambda s, o: j_s_o(s, o).block_until_ready(), 20, *args_so),
        _timeit(vt.s_any_o, 20, *args_so),
    )
    args_s = [(s,) for s, p, o in args_spo]
    out["(S,?P,?O)"] = (
        _timeit(lambda s: j_s(s).block_until_ready(), 10, *args_s),
        _timeit(vt.s_any_any, 10, *args_s),
    )
    args_o = [(o,) for s, p, o in args_spo]
    out["(?S,?P,O)"] = (
        _timeit(lambda o: j_o(o).block_until_ready(), 10, *args_o),
        _timeit(vt.any_any_o, 10, *args_o),
    )
    args_p = [(p,) for s, p, o in args_spo]
    # range scan is backend-routed like the row/col scans: time both paths
    for backend, be_cfg in BACKEND_CFGS.items():
        j_p_be = jax.jit(
            lambda p, be=be_cfg: patterns.any_p_any(meta, f, p, cap, be).rows
        )
        out[f"(?S,P,?O)[{backend}]"] = (
            _timeit(lambda p, jf=j_p_be: jf(p).block_until_ready(), 10, *args_p),
            float("nan"),
        )
    # batched serving throughput (the production path, amortized) — once per
    # scan backend: the Pallas k2_scan kernel vs the vmapped jnp traversal
    B = 4096
    ids = ds.ids[rng.integers(0, ds.n_triples, B)]
    q = eng.ServeBatch(
        op=jnp.asarray(rng.integers(0, 3, B), jnp.int32),
        s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(ids[:, 1], jnp.int32),
        o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    for backend, be_cfg in BACKEND_CFGS.items():
        serve = eng.make_serve_step(meta, cap=512, backend=be_cfg)
        serve(store.forest, q)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(serve(store.forest, q))
        batch_ms = (time.perf_counter() - t0) / 3 / B * 1e3
        out[f"batched(all,{backend})"] = (batch_ms, float("nan"))
    return out


def run_pruned(
    n_triples: int = 60_000, n_preds: int = 64, preds_per_subject: int = 4,
    n_queries: int = 128, cap: int = 128, seed: int = 0,
):
    """Index-pruned unbounded-?P serving vs the all-preds sweep.

    Skewed-predicate dataset (the 1310.4954 premise): |P| = ``n_preds`` but
    the median subject touches ≤ ``preds_per_subject`` predicates, so the
    SP/OP index prunes each (S,?P,?O) / (?S,?P,O) query from P scans down
    to a handful.  Both paths run through the SAME unified serve program
    (``engine.make_serve_step``), differing only in ``u_width`` + index.

    Returns (rows, info): timing rows per pattern × backend, and the
    dataset/index shape summary (incl. index overhead in bits/triple).
    """
    ds = rdf.generate(
        n_triples, n_subjects=n_triples // 12, n_preds=n_preds,
        n_objects=n_triples // 8, preds_per_subject=preds_per_subject,
        seed=seed,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    bi = store.pred_index
    sp_deg = np.diff(bi.host_offsets[: store.n_subjects + 1])
    info = dict(
        triples=store.n_triples, preds=store.n_preds,
        max_degree=bi.meta.max_degree,
        median_subject_degree=float(np.median(sp_deg[sp_deg > 0])),
        index_bits_per_triple=k2triples.size_pred_index_bits(store)
        / store.n_triples,
        k2_bits_per_triple=k2triples.size_k2triples_bits(store)
        / store.n_triples,
    )
    rng = np.random.default_rng(seed + 1)
    picks = ds.ids[rng.integers(0, ds.n_triples, n_queries)]
    batches = {
        "(S,?P,?O)": eng.ServeBatch(
            op=jnp.full((n_queries,), eng.OP_S_ANY_ANY, jnp.int32),
            s=jnp.asarray(picks[:, 0], jnp.int32),
            p=jnp.zeros((n_queries,), jnp.int32),
            o=jnp.zeros((n_queries,), jnp.int32),
        ),
        "(?S,?P,O)": eng.ServeBatch(
            op=jnp.full((n_queries,), eng.OP_ANY_ANY_O, jnp.int32),
            s=jnp.zeros((n_queries,), jnp.int32),
            p=jnp.zeros((n_queries,), jnp.int32),
            o=jnp.asarray(picks[:, 2], jnp.int32),
        ),
    }
    rows = []
    for backend, be_cfg in BACKEND_CFGS.items():
        pruned = eng.make_serve_step(
            store.meta, cap, backend=be_cfg, pmeta=bi.meta
        )
        sweep = eng.make_serve_step(
            store.meta, cap, backend=be_cfg, u_width=store.n_preds
        )
        for pat, q in batches.items():
            tp = _timeit(
                lambda: jax.block_until_ready(pruned(store.forest, q, bi.device)),
                3,
            ) / n_queries
            ts = _timeit(
                lambda: jax.block_until_ready(sweep(store.forest, q)), 3
            ) / n_queries
            rows.append(dict(
                pattern=pat, backend=backend, pruned_ms=tp, sweep_ms=ts,
                speedup=ts / tp,
            ))
    return rows, info


CSV_HEADER = "pattern,k2_ms,vertical_ms,speedup"


def format_row(pattern: str, k2_ms: float, vertical_ms: float) -> str:
    if vertical_ms != vertical_ms:  # NaN: no vertical-tables counterpart
        return f"{pattern},{k2_ms:.4f},n/a,n/a"
    return f"{pattern},{k2_ms:.3f},{vertical_ms:.3f},{vertical_ms/k2_ms:.1f}"


PRUNED_CSV_HEADER = "pattern,backend,pruned_ms,sweep_ms,speedup"


def format_pruned_info(info: dict) -> str:
    return (
        f"# P={info['preds']}, median subject degree "
        f"{info['median_subject_degree']:.1f}, index overhead "
        f"{info['index_bits_per_triple']:.2f} bits/triple "
        f"(k2 {info['k2_bits_per_triple']:.2f})"
    )


def format_pruned_row(r: dict) -> str:
    return (
        f"{r['pattern']},{r['backend']},{r['pruned_ms']:.3f},"
        f"{r['sweep_ms']:.3f},{r['speedup']:.1f}"
    )


def main(csv=print):
    csv("# Table 3 analogue: ms/pattern (k2 vs vertical tables)")
    csv(CSV_HEADER)
    for k, (a, b) in run().items():
        csv(format_row(k, a, b))
    csv("# Pruned unbounded-?P (k2-triples+ SP/OP index) vs all-preds sweep")
    rows, info = run_pruned()
    csv(format_pruned_info(info))
    csv(PRUNED_CSV_HEADER)
    for r in rows:
        csv(format_pruned_row(r))


if __name__ == "__main__":
    main()
