"""Benchmark harness: one module per paper table + kernel microbenches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json [OUT.json]]

Emits CSV blocks per table (the EXPERIMENTS.md §Paper-validation source;
see EXPERIMENTS.md at the repo root for how to read each block, including
the SP/OP index-overhead columns).  ``--json`` additionally writes every
table as machine-readable JSON — with no path it lands at
``BENCH_results.json`` in the repo root, the committed perf-trajectory
file (``BENCH_*.json``) that CI also uploads as an artifact.

Every backend comparison is driven by explicit ``ExecConfig`` objects
(see ``bench_patterns.BACKEND_CFGS`` / ``bench_joins.run``); the harness
never mutates ``REPRO_SCAN_BACKEND``.

Each JSON lands with a ``provenance`` header (git SHA, UTC timestamp,
jax version, backend, device kind/count — ``repro.obs.provenance``) so
the committed perf trajectory is self-describing.  ``--trace`` /
``--metrics`` switch the observability layer on around the sweep and
write its Chrome-trace / metrics exports next to the results.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

DEFAULT_JSON = str(pathlib.Path(__file__).resolve().parent.parent / "BENCH_results.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller datasets")
    ap.add_argument(
        "--json", metavar="PATH", nargs="?", const=DEFAULT_JSON, default=None,
        help="also write all tables as JSON (default path: BENCH_results.json "
        "at the repo root)",
    )
    ap.add_argument(
        "--trace", nargs="?", const="bench_trace.json", default=None,
        metavar="PATH",
        help="trace the sweep; write Chrome trace_event JSON",
    )
    ap.add_argument(
        "--metrics", nargs="?", const="bench_metrics.json", default=None,
        metavar="PATH",
        help="write the obs metrics snapshot + Prometheus text as JSON",
    )
    args = ap.parse_args()

    from repro import obs

    from benchmarks import (
        bench_compression, bench_dynamic, bench_joins, bench_kernels,
        bench_patterns, bench_queries, bench_serve,
    )

    tracer = metrics = None
    if args.trace is not None or args.metrics is not None:
        from repro.core.query import ObsConfig

        tracer, metrics = obs.enable(ObsConfig())

    results: dict = {"fast": bool(args.fast), "provenance": obs.provenance()}
    t0 = time.time()
    print("=" * 72)
    print("# Table 2 analogue: compression (bits/triple, ID space)")
    print(bench_compression.CSV_HEADER)
    comp = (
        bench_compression.run(n_triples=30_000, datasets=("geonames", "dbtune"))
        if args.fast
        else bench_compression.run()
    )
    for r in comp:
        print(bench_compression.format_row(r))
    results["compression"] = comp

    print("=" * 72)
    print("# Table 3 analogue: ms/pattern (k2 vs vertical tables)")
    print(bench_patterns.CSV_HEADER)
    pats = (
        bench_patterns.run(n_triples=30_000, n_preds=16, n_queries=20)
        if args.fast
        else bench_patterns.run()
    )
    for k, (a, b) in pats.items():
        print(bench_patterns.format_row(k, a, b))
    results["patterns"] = {
        k: {"k2_ms": a, "vertical_ms": (None if b != b else b)}
        for k, (a, b) in pats.items()
    }

    print("# Pruned unbounded-?P (k2-triples+ SP/OP index) vs all-preds sweep")
    prows, pinfo = (
        bench_patterns.run_pruned(n_triples=20_000, n_queries=32)
        if args.fast
        else bench_patterns.run_pruned()
    )
    print(bench_patterns.format_pruned_info(pinfo))
    print(bench_patterns.PRUNED_CSV_HEADER)
    for r in prows:
        print(bench_patterns.format_pruned_row(r))
    results["patterns_pruned"] = {"info": pinfo, "rows": prows}

    print("=" * 72)
    print("# Table 4 analogue: ms/query by join category x scan backend")
    print("category,ms_per_query")
    joins = (
        bench_joins.run(n_triples=20_000, n_preds=12, n_each=5)
        if args.fast
        else bench_joins.run()
    )
    for k, v in joins.items():
        print(f"{k},{v:.2f}")
    results["joins"] = joins

    print("=" * 72)
    print("# Serving: streaming multi-tenant broker (Zipf trace, mixed ops)")
    print(bench_serve.CSV_HEADER)
    srows = bench_serve.run(fast=args.fast)
    for r in srows:
        print(bench_serve.format_row(r))
    results["serving"] = srows

    print("=" * 72)
    print("# Dynamic store: churn (insert qps, read tails vs delta "
          "fraction, compaction pause)")
    print(bench_dynamic.CSV_HEADER)
    dyn_res = bench_dynamic.run(fast=args.fast)
    for line in bench_dynamic.format_rows(dyn_res):
        print(line)
    results["dynamic"] = dyn_res

    print("=" * 72)
    print("# Query planner: cost-ordered vs greedy vs worst join orders")
    print(bench_queries.CSV_HEADER)
    qrows = bench_queries.run(fast=args.fast)
    for r in qrows:
        print(bench_queries.format_row(r))
    results["queries"] = qrows

    print("=" * 72)
    print("# kernel microbenches (cpu ref timings + TPU roofline analytics)")
    print("kernel,ms,notes")
    kern = bench_kernels.run()
    for name, ms, note in kern:
        print(f"{name},{ms:.3f},{note}")
    results["kernels"] = [
        {"kernel": n, "ms": ms, "notes": note} for n, ms, note in kern
    ]

    print("=" * 72)
    results["total_s"] = time.time() - t0
    print(f"# total {results['total_s']:.0f}s")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(results, fh, indent=2, default=float)
        print(f"# wrote {args.json}")
    if tracer is not None and args.trace is not None:
        with open(args.trace, "w") as fh:
            json.dump(tracer.to_chrome(metadata=results["provenance"]), fh)
        print(f"# wrote {args.trace} ({tracer.dropped} spans dropped)")
    if metrics is not None and args.metrics is not None:
        with open(args.metrics, "w") as fh:
            json.dump(
                {
                    "provenance": results["provenance"],
                    "metrics": metrics.snapshot(),
                    "prometheus": metrics.to_prometheus(),
                },
                fh, indent=2, default=float,
            )
        print(f"# wrote {args.metrics}")
    if tracer is not None or metrics is not None:
        obs.disable()


if __name__ == "__main__":
    main()
