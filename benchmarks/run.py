"""Benchmark harness: one module per paper table + kernel microbenches.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits CSV blocks per table (the EXPERIMENTS.md §Paper-validation source).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller datasets")
    args = ap.parse_args()

    from benchmarks import bench_compression, bench_joins, bench_kernels, bench_patterns

    t0 = time.time()
    print("=" * 72)
    if args.fast:
        print("# Table 2 analogue: compression (bits/triple, ID space)")
        print("dataset,triples,preds,k2,raw,vertical,sextuple,x_vs_vertical,x_vs_sextuple")
        for r in bench_compression.run(n_triples=30_000, datasets=("geonames", "dbtune")):
            print(
                f"{r['dataset']},{r['triples']},{r['preds']},"
                f"{r['k2_bits_per_triple']:.2f},{r['raw_bits_per_triple']:.0f},"
                f"{r['vertical_bits_per_triple']:.0f},{r['sextuple_bits_per_triple']:.2f},"
                f"{r['vs_vertical']:.1f},{r['vs_sextuple']:.1f}"
            )
    else:
        bench_compression.main()
    print("=" * 72)
    bench_patterns.main() if not args.fast else _patterns_fast()
    print("=" * 72)
    bench_joins.main() if not args.fast else _joins_fast()
    print("=" * 72)
    bench_kernels.main()
    print("=" * 72)
    print(f"# total {time.time()-t0:.0f}s")


def _patterns_fast():
    from benchmarks import bench_patterns

    print("# Table 3 analogue: ms/pattern (k2 vs vertical tables)")
    print("pattern,k2_ms,vertical_ms,speedup")
    for k, (a, b) in bench_patterns.run(n_triples=30_000, n_preds=16, n_queries=20).items():
        print(f"{k},{a:.3f},{b:.3f},{b/a:.1f}" if b == b else f"{k},{a:.4f},n/a,n/a")


def _joins_fast():
    from benchmarks import bench_joins

    print("# Table 4 analogue: ms/query by join category x scan backend")
    print("category,ms_per_query")
    for k, v in bench_joins.run(n_triples=20_000, n_preds=12, n_each=5).items():
        print(f"{k},{v:.2f}")


if __name__ == "__main__":
    main()
