"""Serving benchmark rows: the streaming multi-tenant broker under a
Zipf-skewed mixed-op trace (sustained queries/sec + per-query tails).

Single-device always; a predicate-sharded row rides along whenever more
than one device is visible (CI fakes 8 hosts via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  A requested-but-
impossible sharded run is reported as a skip, never silently downgraded
to single-device numbers — that is the bug class this PR removes.
"""

from __future__ import annotations

from repro.launch import serve

CSV_HEADER = (
    "mode,backend,devices,donate,queries,qps,p50_ms,p99_ms,coalesce,shed,"
    "cap_growths"
)

_FAST = dict(
    n_triples=20_000, n_preds=16, n_tenants=4, n_queries=256,
    cap=256, max_batch=64, warmup=32,
)
_FULL = dict(
    n_triples=100_000, n_preds=64, n_tenants=8, n_queries=4096,
    cap=1024, max_batch=256, warmup=64,
)


def run(*, fast: bool = False, backend: str | None = None) -> list[dict]:
    """Single-device rows with buffer donation on AND off (the before/after
    pair for the per-batch donation optimisation), plus one sharded row
    when devices allow."""
    import jax

    kw = dict(_FAST if fast else _FULL, backend=backend, quiet=True)
    rows = [serve.run_bench(**kw), serve.run_bench(**kw, donate=False)]
    if len(jax.devices()) > 1:
        rows.append(serve.run_bench(**kw, sharded=True))
    else:
        print("# sharded serving row skipped: one device visible "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return rows


def format_row(row: dict) -> str:
    def pct(v):
        return f"{v:.2f}" if v is not None else "n/a"

    return (
        f"{row['mode']},{row['backend']},{row['devices']},"
        f"{int(row.get('donate', False))},{row['queries']},"
        f"{row['qps']:.0f},{pct(row['p50_ms'])},{pct(row['p99_ms'])},"
        f"{row['coalesce_factor']:.1f},{row['shed']},{row['cap_growth_events']}"
    )
