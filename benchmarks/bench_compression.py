"""Paper Table 2 — compression of the Triples component.

The 2011 corpora are not redistributable offline; we reproduce the paper's
COMPARISON on synthetic datasets scaled to each corpus's published shape
statistics (Table 1 ratios), in ID space exactly as the paper measures:

    raw         3×32-bit ID triples (what an uncompressed table costs)
    vertical    MonetDB-style per-predicate [S,O] tables (2×32 bits/triple)
    sextuple    RDF-3X-style 6 sort orders with byte-level gap compression
    k²-triples  |T|+|L| bits summed over predicate trees (this paper)

Reported: bits/triple and the ratios the paper claims — k²-triples beats
vertical tables by >2× and multi-index stores by >4× (Table 2 shows 4-20×).

The ``spop`` column is the SP/OP predicate-index overhead in bits/triple
(k²-triples+, arXiv:1310.4954's Table analogue): the price of predicate
pruning, charged at the **measured device layout** — since the DAC arena
landed this is the multi-level DAC(b=8) chunk words + flag bitmaps + rank
blocks + SWAR-packed row pointers actually uploaded; ``spop_dac`` stays
the analytic DAC figure (9 bits per chunk, no padding) so the measured
column can be gated against it (``benchmarks/check_compression.py``).

``dict`` is the measured front-coded dictionary (bucketed PFC pools + the
Elias–Fano bucket-offset indexes, ``core.dictionary``) over the corpus's
URI terms, and ``e2e`` is the honest end-to-end figure the paper's
in-memory claim needs: (k² + SP/OP index + dictionary) bits per triple —
everything a serving replica must hold.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import k2triples
from repro.core.dictionary import CompressedTripleDictionary, FrontCodedStrings
from repro.data import rdf


def _dictionary_for(ds: rdf.RdfDataset) -> CompressedTripleDictionary:
    """Front-code the corpus's term strings (``rdf.to_strings`` URI scheme)
    without materializing string triples: the four sorted term classes come
    straight from the distinct ids (fixed-width ids => lexicographic order
    == numeric order)."""
    s_ids = np.unique(ds.ids[:, 0])
    o_ids = np.unique(ds.ids[:, 2])
    p_ids = np.unique(ds.ids[:, 1])
    so = np.union1d(s_ids[s_ids <= ds.n_so], o_ids[o_ids <= ds.n_so])
    return CompressedTripleDictionary(
        so=FrontCodedStrings([f"http://ex.org/so/{i:08d}" for i in so]),
        s=FrontCodedStrings([f"http://ex.org/s/{i:08d}" for i in s_ids[s_ids > ds.n_so]]),
        o=FrontCodedStrings([f"http://ex.org/o/{i:08d}" for i in o_ids[o_ids > ds.n_so]]),
        p=FrontCodedStrings([f"http://ex.org/p/{i:04d}" for i in p_ids]),
    )


def run(n_triples: int = 200_000, datasets=("geonames", "wikipedia", "dbtune", "uniprot")):
    rows = []
    for name in datasets:
        ds = rdf.generate_like(name, n_triples, seed=1)
        t0 = time.time()
        store = k2triples.from_id_triples(
            ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
            n_objects=ds.n_objects, n_preds=ds.n_preds,
        )
        build_s = time.time() - t0
        n = store.n_triples
        k2_bits = k2triples.size_k2triples_bits(store)
        raw = k2triples.size_raw_triples_bits(n)
        vert = k2triples.size_vertical_tables_bits(n)
        sext = k2triples.size_sextuple_gap_bits(ds.ids)
        spop = k2triples.size_pred_index_bits(store)
        d = _dictionary_for(ds)
        dict_bits = d.size_bits()
        rows.append(
            dict(
                dataset=name, triples=n, preds=ds.n_preds,
                k2_bits_per_triple=k2_bits / n,
                spop_bits_per_triple=spop / n,
                spop_dac_bits_per_triple=(
                    store.pred_index.stats.dac_bits / n if store.pred_index else 0.0
                ),
                dict_bits_per_triple=dict_bits / n,
                dict_raw_bits_per_triple=d.raw_bits() / n,
                e2e_bits_per_triple=(k2_bits + spop + dict_bits) / n,
                raw_bits_per_triple=raw / n,
                vertical_bits_per_triple=vert / n,
                sextuple_bits_per_triple=sext / n,
                vs_vertical=vert / k2_bits,
                vs_sextuple=sext / k2_bits,
                build_s=build_s,
            )
        )
    return rows


CSV_HEADER = (
    "dataset,triples,preds,k2,spop,spop_dac,dict,dict_raw,e2e,raw,vertical,"
    "sextuple,x_vs_vertical,x_vs_sextuple"
)


def format_row(r: dict) -> str:
    return (
        f"{r['dataset']},{r['triples']},{r['preds']},"
        f"{r['k2_bits_per_triple']:.2f},{r['spop_bits_per_triple']:.2f},"
        f"{r['spop_dac_bits_per_triple']:.2f},{r['dict_bits_per_triple']:.2f},"
        f"{r['dict_raw_bits_per_triple']:.2f},{r['e2e_bits_per_triple']:.2f},"
        f"{r['raw_bits_per_triple']:.0f},"
        f"{r['vertical_bits_per_triple']:.0f},{r['sextuple_bits_per_triple']:.2f},"
        f"{r['vs_vertical']:.1f},{r['vs_sextuple']:.1f}"
    )


def main(csv=print):
    csv("# Table 2 analogue: compression (bits/triple, ID space)")
    csv(CSV_HEADER)
    for r in run():
        csv(format_row(r))


if __name__ == "__main__":
    main()
