"""Paper Table 2 — compression of the Triples component.

The 2011 corpora are not redistributable offline; we reproduce the paper's
COMPARISON on synthetic datasets scaled to each corpus's published shape
statistics (Table 1 ratios), in ID space exactly as the paper measures:

    raw         3×32-bit ID triples (what an uncompressed table costs)
    vertical    MonetDB-style per-predicate [S,O] tables (2×32 bits/triple)
    sextuple    RDF-3X-style 6 sort orders with byte-level gap compression
    k²-triples  |T|+|L| bits summed over predicate trees (this paper)

Reported: bits/triple and the ratios the paper claims — k²-triples beats
vertical tables by >2× and multi-index stores by >4× (Table 2 shows 4-20×).

The ``spop`` column is the SP/OP predicate-index overhead in bits/triple
(k²-triples+, arXiv:1310.4954's Table analogue): the price of predicate
pruning, charged at the byte-packed CSR layout we actually materialize;
``spop_dac`` is the analytic multi-level DAC(b=8) size of the same lists —
what a host-side DAC implementation would report.  Honest comparisons add
``spop`` to ``k2`` when pruning is enabled.
"""

from __future__ import annotations

import time

from repro.core import k2triples
from repro.data import rdf


def run(n_triples: int = 200_000, datasets=("geonames", "wikipedia", "dbtune", "uniprot")):
    rows = []
    for name in datasets:
        ds = rdf.generate_like(name, n_triples, seed=1)
        t0 = time.time()
        store = k2triples.from_id_triples(
            ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
            n_objects=ds.n_objects, n_preds=ds.n_preds,
        )
        build_s = time.time() - t0
        n = store.n_triples
        k2_bits = k2triples.size_k2triples_bits(store)
        raw = k2triples.size_raw_triples_bits(n)
        vert = k2triples.size_vertical_tables_bits(n)
        sext = k2triples.size_sextuple_gap_bits(ds.ids)
        spop = k2triples.size_pred_index_bits(store)
        rows.append(
            dict(
                dataset=name, triples=n, preds=ds.n_preds,
                k2_bits_per_triple=k2_bits / n,
                spop_bits_per_triple=spop / n,
                spop_dac_bits_per_triple=(
                    store.pred_index.stats.dac_bits / n if store.pred_index else 0.0
                ),
                raw_bits_per_triple=raw / n,
                vertical_bits_per_triple=vert / n,
                sextuple_bits_per_triple=sext / n,
                vs_vertical=vert / k2_bits,
                vs_sextuple=sext / k2_bits,
                build_s=build_s,
            )
        )
    return rows


CSV_HEADER = (
    "dataset,triples,preds,k2,spop,spop_dac,raw,vertical,sextuple,"
    "x_vs_vertical,x_vs_sextuple"
)


def format_row(r: dict) -> str:
    return (
        f"{r['dataset']},{r['triples']},{r['preds']},"
        f"{r['k2_bits_per_triple']:.2f},{r['spop_bits_per_triple']:.2f},"
        f"{r['spop_dac_bits_per_triple']:.2f},{r['raw_bits_per_triple']:.0f},"
        f"{r['vertical_bits_per_triple']:.0f},{r['sextuple_bits_per_triple']:.2f},"
        f"{r['vs_vertical']:.1f},{r['vs_sextuple']:.1f}"
    )


def main(csv=print):
    csv("# Table 2 analogue: compression (bits/triple, ID space)")
    csv(CSV_HEADER)
    for r in run():
        csv(format_row(r))


if __name__ == "__main__":
    main()
