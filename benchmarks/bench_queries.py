"""Query-planner benchmark rows: cost-ordered vs greedy vs worst-case
join orders on a skewed P=64 corpus.

The corpus is planner-hostile by construction: 64 subjects against a
few-thousand-object extent, an ``anchor`` predicate (one triple per
subject, objects on a sparse lattice), a ``bad`` predicate fanning every
subject out, and a ``good`` predicate whose objects rarely hit the
anchor lattice.  The trap query joins all three: greedy's flat
connected-bonus (stand-alone estimate ÷ 10) picks the smaller-looking
``bad`` branch and rides the fanout, while the DP's per-variable extent
pricing (``planner.step_estimate``) sees that the ``good`` join prunes
through the big object extent and runs it first.

Methodology: every DISTINCT join order is timed once (best of
``repeats`` runs on identical machinery via ``order_override`` — min, so
a one-off stall or stray recompile can't skew a row) and each strategy
reports the timing of ITS order — strategies that choose the same order
report byte-identical numbers, so "cost never slower than greedy" is a
property of the orders, not of timer noise.  The planner's own search
cost is reported separately as ``plan_ms``.  ``worst`` is the costliest
CONNECTED order (cartesian-producing permutations excluded: the executor
turns those into one bulk enumerate-and-check launch, which this
substrate batch-vectorizes so well it stops being a join-order
comparison at all).
"""

from __future__ import annotations

import itertools
import time

import numpy as np

from repro.core import algebra, k2triples, planner
from repro.core.algebra import TriplePattern
from repro.core.query import CapOverflow

CSV_HEADER = (
    "query,patterns,cost_ms,greedy_ms,worst_ms,plan_ms,"
    "cost_order,greedy_order,worst_order"
)

N_SUBJECTS = 64
N_PREDS = 64
P_ANCHOR, P_BAD, P_GOOD = 1, 2, 3

_FAST = dict(n_obj=4000, bad=400, good=600, overlap=48, extra_nnz=16,
             cap=1024, repeats=3)
_FULL = dict(n_obj=8000, bad=600, good=900, overlap=64, extra_nnz=32,
             cap=2048, repeats=4)


def build_corpus(*, n_obj, bad, good, overlap, extra_nnz, seed=11, **_):
    """Skewed ID-triple corpus over ``N_PREDS`` predicates (see module
    docstring).  Subject extent is tiny (64), object extent is ``n_obj``
    — the asymmetry the DP prices and greedy cannot."""
    step = n_obj // N_SUBJECTS  # the anchor's object lattice
    rng = np.random.default_rng(seed)
    ids = []
    ids += [(s, P_ANCHOR, step * s) for s in range(1, N_SUBJECTS + 1)]
    ids += [
        (int(rng.integers(1, N_SUBJECTS + 1)), P_BAD,
         int(rng.integers(1, n_obj + 1)))
        for _ in range(bad)
    ]
    # good: objects rarely on the anchor lattice, plus explicit overlap
    # rows so the trap query is non-empty
    ids += [
        (int(rng.integers(1, N_SUBJECTS + 1)), P_GOOD,
         int(rng.integers(1, n_obj + 1)))
        for _ in range(good - overlap)
    ]
    ids += [
        (int(rng.integers(1, N_SUBJECTS + 1)), P_GOOD,
         step * int(rng.integers(1, N_SUBJECTS + 1)))
        for _ in range(overlap)
    ]
    # background: sparse fill across the remaining predicates
    ids += [
        (int(rng.integers(1, N_SUBJECTS + 1)), p,
         int(rng.integers(1, n_obj + 1)))
        for p in range(P_GOOD + 1, N_PREDS + 1)
        for _ in range(extra_nnz)
    ]
    ids = np.unique(np.asarray(ids, np.int64), axis=0)
    return k2triples.from_id_triples(
        ids, n_so=N_SUBJECTS, n_subjects=N_SUBJECTS, n_objects=n_obj,
        n_preds=N_PREDS,
    )


QUERIES = [
    ("star2", [
        TriplePattern("?s", P_ANCHOR, "?x"),
        TriplePattern("?s", P_BAD, "?z"),
    ]),
    ("objjoin2", [
        TriplePattern("?s", P_ANCHOR, "?x"),
        TriplePattern("?w", P_GOOD, "?x"),
    ]),
    # the greedy trap: expand through ?s (extent 64, barely prunes) or
    # join through ?x (extent n_obj, prunes hard) — greedy picks by
    # stand-alone estimate, the DP by extent-priced steps
    ("trap3", [
        TriplePattern("?s", P_ANCHOR, "?x"),
        TriplePattern("?s", P_BAD, "?z"),
        TriplePattern("?w", P_GOOD, "?x"),
    ]),
    ("star3", [
        TriplePattern("?s", P_ANCHOR, "?x"),
        TriplePattern("?s", P_BAD, "?z"),
        TriplePattern("?z", P_GOOD, "?y"),
    ]),
]


def _connected(pats, order):
    bound = set(pats[order[0]].variables)
    for i in order[1:]:
        if not (pats[i].variables & bound):
            return False
        bound |= pats[i].variables
    return True


def _worst_order(store, pats):
    perms = [
        o for o in itertools.permutations(range(len(pats)))
        if _connected(pats, o)
    ] or list(itertools.permutations(range(len(pats))))
    return max(perms, key=lambda o: planner.order_cost(store, pats, o))


def _time_order(store, pats, order, *, cap, repeats, backend="jnp"):
    tree = algebra.bgp(pats)
    try:
        planner.execute(store, tree, cap=cap, exec_=backend,
                        order_override=list(order))  # warm the jit caches
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            planner.execute(store, tree, cap=cap, exec_=backend,
                            order_override=list(order))
            times.append((time.perf_counter() - t0) * 1e3)
        return float(np.min(times))
    except CapOverflow:
        return None  # the order blew past the lane cap: reported, not hidden


def run(*, fast: bool = False, backend: str = "jnp") -> list[dict]:
    kw = _FAST if fast else _FULL
    store = build_corpus(**kw)
    rows = []
    for name, pats in QUERIES:
        t0 = time.perf_counter()
        orders = {
            "cost": tuple(planner.cost_order(store, pats)),
            "greedy": tuple(planner.greedy_order(store, pats)),
        }
        plan_ms = (time.perf_counter() - t0) * 1e3
        orders["worst"] = _worst_order(store, pats)
        timed = {
            o: _time_order(store, pats, o, cap=kw["cap"],
                           repeats=kw["repeats"], backend=backend)
            for o in set(orders.values())
        }
        rows.append({
            "query": name,
            "patterns": len(pats),
            "plan_ms": plan_ms,
            **{f"{s}_ms": timed[o] for s, o in orders.items()},
            **{f"{s}_order": list(o) for s, o in orders.items()},
            **{f"{s}_cost": planner.order_cost(store, pats, o)
               for s, o in orders.items()},
        })
    return rows


def format_row(r: dict) -> str:
    def ms(v):
        return f"{v:.2f}" if v is not None else "overflow"

    def order(o):
        return "".join(map(str, o))

    return (
        f"{r['query']},{r['patterns']},{ms(r['cost_ms'])},"
        f"{ms(r['greedy_ms'])},{ms(r['worst_ms'])},{r['plan_ms']:.2f},"
        f"{order(r['cost_order'])},{order(r['greedy_order'])},"
        f"{order(r['worst_order'])}"
    )
