"""Paper Table 4 — join queries by category A–F (ms/query, 10 queries each).

Query constants are sampled from the store so joins are non-empty, mirroring
the paper's USEWOD-derived 2-pattern join workload.  The paper's qualitative
claims validated in EXPERIMENTS.md:

  * A and D (bounded predicates) are fast;
  * B and E (one unbounded predicate) scale with |P|;
  * C and F (two unbounded predicates) are the expensive tail.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core import joins, k2triples
from repro.core.query import ExecConfig
from repro.data import rdf


def run(n_triples: int = 60_000, n_preds: int = 32, n_each: int = 10, seed=0,
        backends=("pallas", "jnp")):
    """Times every category on each backend; the substrate is selected per
    call through an explicit ``ExecConfig`` (never env mutation)."""
    ds = rdf.generate(
        n_triples, n_subjects=n_triples // 12, n_preds=n_preds,
        n_objects=n_triples // 8, seed=seed,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    meta, f = store.meta, store.forest
    cap, cap_y = 1024, 128
    rng = np.random.default_rng(seed + 1)
    qs = ds.ids[rng.integers(0, ds.n_triples, 2 * n_each)]

    out = {}
    for name in backends:
        be = ExecConfig(backend=name)
        jit = jax.jit
        fns = {
            "A": jit(lambda p1, c1, p2, c2: joins.join_a(meta, f, p1, c1, "s", p2, c2, "s", cap, be).ids),
            "B": jit(lambda p1, c1, c2: joins.join_b(meta, f, p1, c1, "s", c2, "s", cap, be).ids),
            "C": jit(lambda c1, c2: joins.join_c(meta, f, c1, "s", c2, "s", cap, be).ids),
            "D": jit(lambda p1, c1, p2: joins.join_d(meta, f, p1, c1, "s", p2, "o", cap, cap_y, be).y_ids),
            "E": jit(lambda p1, c1: joins.join_e(meta, f, p1, c1, "s", "o", cap, cap_y, be).y_ids),
            "F": jit(lambda c1: joins.join_f(meta, f, c1, "s", "o", cap, cap_y, be).y_ids),
        }
        for cat, fn in fns.items():
            times = []
            for i in range(n_each):
                s1, p1, o1 = map(int, qs[2 * i])
                s2, p2, o2 = map(int, qs[2 * i + 1])
                args = {
                    "A": (p1, o1, p2, o2), "B": (p1, o1, o2), "C": (o1, o2),
                    "D": (p1, o1, p2), "E": (p1, o1), "F": (o1,),
                }[cat]
                if i == 0:
                    jax.block_until_ready(fn(*args))  # compile
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                times.append(time.perf_counter() - t0)
            out[f"{cat}[{name}]"] = float(np.mean(times) * 1e3)
    return out


def main(csv=print):
    csv("# Table 4 analogue: ms/query by join category x scan backend")
    csv("category,ms_per_query")
    for k, v in run().items():
        csv(f"{k},{v:.2f}")


if __name__ == "__main__":
    main()
