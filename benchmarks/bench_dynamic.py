"""Dynamic-store churn benchmark: what the LSM delta layer costs.

Three numbers the EXPERIMENTS.md "Dynamic store" section reads off:

  * **sustained insert qps** — single-writer ``DynamicStore.insert``
    throughput (a host-side set op; no device work on the write path);
  * **read p50/p99 at 0 / 5 / 20 % delta fraction** — mixed CHECK/ROW/COL
    serve batches through a compiled plan while that fraction of the
    static triple count sits in the delta (half fresh inserts, half
    tombstones).  The 0 % row doubles as the read-path overhead probe:
    an empty delta must serve at static-store latency (the acceptance
    bound is <= 1.15x the pure-static p50, reported alongside);
  * **compaction pause** — wall-clock to fold the 20 % delta down
    (device dump -> rebuild -> epoch swap) plus the base-plan recompile
    at the new epoch.  The broker runs both off the serve path; the
    pause is what a single-threaded caller would block.

    PYTHONPATH=src python -m benchmarks.bench_dynamic [--fast]
        [--backend pallas|jnp] [--json OUT.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import compaction, delta, k2triples
from repro.core import engine as eng
from repro.core.query import ExecConfig, ServeQ
from repro.data import rdf

CSV_HEADER = "backend,delta_frac,delta_triples,tombstones,p50_ms,p99_ms"

_FAST = dict(n_triples=10_000, n_preds=16, cap=256, batch=64,
             reps=40, warmup=5, n_writes=2_000)
_FULL = dict(n_triples=60_000, n_preds=32, cap=1024, batch=256,
             reps=80, warmup=10, n_writes=10_000)

_FRACS = (0.0, 0.05, 0.20)


def _mixed_batch(ds, n, seed=3):
    rng = np.random.default_rng(seed)
    ops = rng.integers(0, 3, n).astype(np.int32)  # CHECK / ROW / COL
    rows = ds.ids[rng.integers(0, ds.n_triples, n)]
    s = np.where(ops != eng.OP_COL, rows[:, 0], 0).astype(np.int32)
    p = rows[:, 1].astype(np.int32)
    o = np.where(ops != eng.OP_ROW, rows[:, 2], 0).astype(np.int32)
    return eng.ServeBatch(op=ops, s=s, p=p, o=o)


def _churn(store, ds, n, seed):
    """Half tombstones of static triples, half fresh inserts (including
    appended-range entity ids the static store never saw)."""
    rng = np.random.default_rng(seed)
    kill = ds.ids[rng.choice(ds.n_triples, n // 2, replace=False)]
    for s, p, o in kill:
        store.delete(int(s), int(p), int(o))
    E = max(ds.n_subjects, ds.n_objects)
    for _ in range(n - n // 2):
        store.insert(
            int(rng.integers(1, E + 3)),
            int(rng.integers(1, ds.n_preds + 1)),
            int(rng.integers(1, E + 3)),
        )


def _read_tails(engine, cfg, qb, reps, warmup):
    plan = engine.compile(ServeQ(unbounded=False), cfg)
    for _ in range(warmup):
        plan(qb)
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        plan(qb)
        lat.append((time.perf_counter() - t0) * 1e3)
    a = np.asarray(lat)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def run(*, fast: bool = False, backend: str | None = None) -> dict:
    kw = _FAST if fast else _FULL
    cfg = ExecConfig(cap=kw["cap"], **(
        {"backend": backend} if backend else {}
    ))
    ds = rdf.generate_like("dbtune", kw["n_triples"], seed=5)
    static = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    qb = _mixed_batch(ds, kw["batch"])

    # pure-static reference p50 (the <= 1.15x acceptance denominator)
    static_p50, _ = _read_tails(
        eng.Engine(store=static), cfg, qb, kw["reps"], kw["warmup"]
    )

    # sustained single-writer insert qps (host-side set ops)
    store = delta.DynamicStore(static)
    rng = np.random.default_rng(7)
    E = max(ds.n_subjects, ds.n_objects)
    trips = rng.integers(
        1, [E + 1, ds.n_preds + 1, E + 1], size=(kw["n_writes"], 3)
    )
    t0 = time.perf_counter()
    for s, p, o in trips:
        store.insert(int(s), int(p), int(o))
    insert_qps = kw["n_writes"] / (time.perf_counter() - t0)

    reads = []
    for frac in _FRACS:
        store = delta.DynamicStore(static)
        n_delta = int(frac * ds.n_triples)
        if n_delta:
            _churn(store, ds, n_delta, seed=int(frac * 100))
        engine = eng.Engine(store=store)
        p50, p99 = _read_tails(engine, cfg, qb, kw["reps"], kw["warmup"])
        reads.append({
            "delta_frac": frac,
            "delta_triples": store.delta.n_inserts,
            "tombstones": store.delta.n_tombstones,
            "p50_ms": p50,
            "p99_ms": p99,
        })

    # compaction pause on the 20% store: rebuild + base-plan recompile
    engine = eng.Engine(store=store)
    engine.compile(ServeQ(unbounded=False), cfg)(qb)  # warm epoch-0 plan
    t0 = time.perf_counter()
    rep = compaction.compact(store, backend=cfg.backend)
    t1 = time.perf_counter()
    engine.compile(ServeQ(unbounded=False), cfg)(qb)  # epoch-1 recompile
    t2 = time.perf_counter()

    return {
        "backend": cfg.backend,
        "n_triples": int(ds.n_triples),
        "insert_qps": insert_qps,
        "static_p50_ms": static_p50,
        "overhead_x": reads[0]["p50_ms"] / static_p50 if static_p50 else None,
        "read": reads,
        "compaction": {
            "rebuild_ms": (t1 - t0) * 1e3,
            "recompile_ms": (t2 - t1) * 1e3,
            "pause_ms": (t2 - t0) * 1e3,
            "n_triples": rep.n_triples,
            "delta_merged": rep.delta_merged,
            "tombstones_applied": rep.tombstones_applied,
        },
    }


def format_rows(res: dict) -> list[str]:
    out = [
        f"{res['backend']},{r['delta_frac']:.2f},{r['delta_triples']},"
        f"{r['tombstones']},{r['p50_ms']:.2f},{r['p99_ms']:.2f}"
        for r in res["read"]
    ]
    c = res["compaction"]
    out.append(
        f"# insert_qps={res['insert_qps']:.0f} "
        f"static_p50_ms={res['static_p50_ms']:.2f} "
        f"overhead_x={res['overhead_x']:.3f}"
    )
    out.append(
        f"# compaction pause_ms={c['pause_ms']:.0f} "
        f"(rebuild={c['rebuild_ms']:.0f} recompile={c['recompile_ms']:.0f}) "
        f"triples={c['n_triples']} merged={c['delta_merged']} "
        f"tombstoned={c['tombstones_applied']}"
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default=None, choices=("pallas", "jnp"))
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args()
    res = run(fast=args.fast, backend=args.backend)
    print(CSV_HEADER)
    for line in format_rows(res):
        print(line)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(res, fh, indent=2, default=float)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
