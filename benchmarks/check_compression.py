"""CI compression-regression gate over a fresh ``benchmarks.run`` JSON.

Two checks per compression row, against the baseline ``BENCH_results.json``
committed in the repo (copied aside BEFORE the bench refreshes it):

  1. **measured-vs-analytic band** — the device SP/OP index must stay
     real: ``spop_bits_per_triple <= RATIO * spop_dac_bits_per_triple``.
     A layout regression (padding creep, a fallback silently re-becoming
     the default) shows up here even though every functional test passes.
  2. **end-to-end no-regress** — ``e2e_bits_per_triple`` (k² + index +
     dictionary) must not exceed the committed baseline row by more than
     ``SLACK`` (small float/shape jitter allowance).  Datasets missing
     from the baseline (first run after adding a corpus) are skipped with
     a note.

Usage:  python -m benchmarks.check_compression NEW.json BASELINE.json
Exit status 1 on any violation; prints one verdict line per row.
"""

from __future__ import annotations

import json
import sys

RATIO = 1.25   # measured DAC arena within 25% of the analytic figure
SLACK = 1.02   # <=2% end-to-end drift vs the committed baseline


def check(new: dict, baseline: dict) -> list[str]:
    """-> list of violation messages (empty == gate passes)."""
    problems: list[str] = []
    base_rows = {
        r["dataset"]: r for r in baseline.get("compression", [])
    }
    rows = new.get("compression", [])
    if not rows:
        return ["no compression rows in the new results JSON"]
    for r in rows:
        name = r["dataset"]
        spop = float(r["spop_bits_per_triple"])
        dac = float(r["spop_dac_bits_per_triple"])
        if dac > 0 and spop > RATIO * dac:
            problems.append(
                f"{name}: measured spop {spop:.2f} > {RATIO}x analytic "
                f"DAC {dac:.2f} ({RATIO * dac:.2f}) — device layout "
                "regressed"
            )
        else:
            print(
                f"ok {name}: spop {spop:.2f} <= {RATIO}x dac {dac:.2f}"
            )
        e2e = r.get("e2e_bits_per_triple")
        base = base_rows.get(name)
        if e2e is None:
            problems.append(f"{name}: new results lack e2e_bits_per_triple")
        elif base is None or "e2e_bits_per_triple" not in base:
            print(f"note {name}: no baseline e2e row; skipping no-regress")
        else:
            b = float(base["e2e_bits_per_triple"])
            if float(e2e) > SLACK * b:
                problems.append(
                    f"{name}: e2e {float(e2e):.2f} bits/triple regressed "
                    f"vs baseline {b:.2f} (allowed {SLACK * b:.2f})"
                )
            else:
                print(
                    f"ok {name}: e2e {float(e2e):.2f} <= {SLACK}x "
                    f"baseline {b:.2f}"
                )
    return problems


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print(__doc__)
        return 2
    with open(argv[0]) as fh:
        new = json.load(fh)
    with open(argv[1]) as fh:
        baseline = json.load(fh)
    problems = check(new, baseline)
    for p in problems:
        print(f"FAIL {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
