"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun JSONs.

    PYTHONPATH=src python -m benchmarks.render_roofline [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json


def load(d):
    rows = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        r = json.load(open(f))
        if r.get("ok"):
            rows.append(r)
    return rows


def md_table(rows, mesh):
    out = [
        "| arch:shape | bottleneck | t_compute | t_memory | t_collective "
        "| useful FLOPs | roofline | HBM/dev | CPU-artifact |",
        "|---|---|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for r in sorted(rows, key=lambda r: r["name"]):
        if r["mesh"] != mesh:
            continue
        art = r.get("cpu_bf16_upcast_artifact_bytes", 0)
        out.append(
            f"| {r['name']} | **{r['bottleneck']}** "
            f"| {r['t_compute']*1e3:.2f} ms | {r['t_memory']*1e3:.2f} ms "
            f"| {r['t_collective']*1e3:.2f} ms "
            f"| {r['useful_flops_frac']*100:.1f}% | {r['roofline_frac']*100:.1f}% "
            f"| {r['peak_mem_bytes']/2**30:.2f} G | {art/2**30:.1f} G |"
        )
    return "\n".join(out)


def md_multipod(rows):
    out = [
        "| arch:shape | 16x16 ok | 2x16x16 ok | x-pod wire/step (2x16x16) | HBM/dev 512c |",
        "|---|---|---|---:|---:|",
    ]
    by = {}
    for r in rows:
        by.setdefault(r["name"], {})[r["mesh"]] = r
    for name, d in sorted(by.items()):
        a, b = d.get("16x16"), d.get("2x16x16")
        wire = f"{b['wire_bytes_per_dev']/2**20:.1f} MiB" if b else "—"
        hbm = f"{b['peak_mem_bytes']/2**30:.2f} G" if b else "—"
        out.append(
            f"| {name} | {'✓' if a else '✗'} | {'✓' if b else '✗'} | {wire} | {hbm} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    n16 = sum(1 for r in rows if r["mesh"] == "16x16")
    n512 = sum(1 for r in rows if r["mesh"] == "2x16x16")
    print(f"## cells: {n16} single-pod + {n512} multi-pod compiled OK\n")
    print("### single-pod (16x16 = 256 chips) roofline\n")
    print(md_table(rows, "16x16"))
    print("\n### multi-pod summary\n")
    print(md_multipod(rows))


if __name__ == "__main__":
    main()
