"""Synthetic RDF corpora + a minimal N3-ish parser.

The paper's 2011 corpora (geonames, wikipedia, dbtune, uniprot, dbpedia-en)
are not redistributable offline, so the compression/query benchmarks run on
synthetic datasets that mirror the paper's PUBLISHED shape statistics
(Table 1): #triples and the |S| / |P| / |O| ratios, with power-law predicate
frequencies and the SO-overlap that makes cross-joins meaningful.

``generate`` returns 1-based ID triples directly (the paper benchmarks on
ID-space; the Dictionary is shared across engines).  ``generate_strings``
additionally wraps IDs in URI-ish strings for the dictionary/end-to-end path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Table 1 of the paper (counts); used to scale synthetic corpora.
PAPER_DATASETS = {
    "geonames": dict(triples=9_415_253, subjects=2_203_561, preds=20, objects=3_031_664),
    "wikipedia": dict(triples=47_054_407, subjects=2_162_189, preds=9, objects=8_268_864),
    "dbtune": dict(triples=58_920_361, subjects=12_401_228, preds=394, objects=14_264_221),
    "uniprot": dict(triples=72_460_981, subjects=12_188_927, preds=126, objects=9_084_674),
    "dbpedia-en": dict(triples=232_542_405, subjects=18_425_128, preds=39_672, objects=65_200_769),
}


@dataclasses.dataclass(frozen=True)
class RdfDataset:
    """ID triples + the dictionary partition sizes they were drawn from."""

    ids: np.ndarray  # int64[N, 3] 1-based (s, p, o), unique
    n_so: int
    n_subjects: int
    n_objects: int
    n_preds: int

    @property
    def n_triples(self) -> int:
        return int(self.ids.shape[0])


def generate(
    n_triples: int,
    *,
    n_subjects: int,
    n_preds: int,
    n_objects: int,
    so_frac: float = 0.3,
    pred_alpha: float = 1.2,
    obj_alpha: float = 1.05,
    preds_per_subject: int | None = None,
    seed: int = 0,
) -> RdfDataset:
    """Power-law synthetic RDF in the paper's 4-range ID space.

    so_frac: fraction of the smaller of (|S|,|O|) that plays both roles —
    real datasets have few but nonzero SO terms (Fernández et al. 2010).

    preds_per_subject: skewed predicate usage — every subject draws its
    predicates from an own small pool of at most this many (pool sizes
    1..preds_per_subject, uniform).  Real corpora behave this way (a
    resource's class fixes its predicate vocabulary; arXiv:1310.4954's
    SP-index premise): |P| is large but each subject touches a handful.
    """
    rng = np.random.default_rng(seed)
    n_so = int(so_frac * min(n_subjects, n_objects))
    # zipf-ish ranks without scipy: inverse-CDF on a truncated power law
    def powerlaw_ids(n, lo, hi, alpha):
        u = rng.random(n)
        span = hi - lo + 1
        ranks = np.floor(span * u ** alpha).astype(np.int64)
        return lo + np.clip(ranks, 0, span - 1)

    s = powerlaw_ids(n_triples, 1, n_subjects, 1.0)  # subjects ~uniform-ish
    if preds_per_subject is None:
        p = powerlaw_ids(n_triples, 1, n_preds, pred_alpha)
    else:
        # per-subject predicate pool: pool of subject i is a contiguous slice
        # of a global random permutation, offset by a per-subject start
        perm = rng.permutation(n_preds).astype(np.int64)
        pool_size = rng.integers(1, preds_per_subject + 1, n_subjects + 1)
        pool_start = rng.integers(0, n_preds, n_subjects + 1)
        slot = rng.integers(0, 1 << 30, n_triples) % pool_size[s]
        p = 1 + perm[(pool_start[s] + slot) % n_preds]
    o = powerlaw_ids(n_triples, 1, n_objects, obj_alpha)
    # real RDF clusters: a subject's objects are nearby in dictionary order
    # (Fernández et al. 2010) — k²-trees exploit exactly this.  Mix 60%
    # subject-correlated objects with 40% global power-law draws.
    local = rng.random(n_triples) < 0.6
    spread = max(4, n_objects // 64)
    o_local = 1 + (
        (s - 1) * n_objects // max(n_subjects, 1)
        + rng.integers(0, spread, n_triples)
    ) % n_objects
    o = np.where(local, o_local, o)
    ids = np.stack([s, p, o], axis=1)
    ids = np.unique(ids, axis=0)  # paper: duplicates removed
    return RdfDataset(
        ids=ids, n_so=n_so, n_subjects=n_subjects, n_objects=n_objects, n_preds=n_preds
    )


def generate_like(name: str, n_triples: int, seed: int = 0) -> RdfDataset:
    """Scale a paper dataset's ratios down to ``n_triples``."""
    d = PAPER_DATASETS[name]
    f = n_triples / d["triples"]
    return generate(
        n_triples,
        n_subjects=max(4, int(d["subjects"] * f)),
        n_preds=max(2, min(d["preds"], int(np.ceil(d["preds"] * f)) + 2)),
        n_objects=max(4, int(d["objects"] * f)),
        seed=seed,
    )


def to_strings(ds: RdfDataset) -> list[tuple[str, str, str]]:
    """URI-ish string triples honoring the SO overlap (for dictionary tests)."""
    out = []
    for s, p, o in ds.ids:
        s_term = (
            f"http://ex.org/so/{s:08d}" if s <= ds.n_so else f"http://ex.org/s/{s:08d}"
        )
        o_term = (
            f"http://ex.org/so/{o:08d}" if o <= ds.n_so else f"http://ex.org/o/{o:08d}"
        )
        out.append((s_term, f"http://ex.org/p/{p:04d}", o_term))
    return out


def generate_strings(
    n_triples: int, *, like: str | None = None, seed: int = 0, **kw
) -> list[tuple[str, str, str]]:
    """Synthetic *string* triples for the dictionary/end-to-end path.

    ``like`` scales a paper dataset's ratios (as ``generate_like``);
    otherwise ``kw`` is forwarded to ``generate``.  URIs honor the SO
    overlap so the shared [1,|SO|] range is exercised.
    """
    if like is not None:
        ds = generate_like(like, n_triples, seed)
    else:
        ds = generate(n_triples, seed=seed, **kw)
    return to_strings(ds)


def parse_n3(text: str) -> list[tuple[str, str, str]]:
    """Minimal N3/N-Triples subset: ``<s> <p> <o> .`` / quoted literals."""
    triples = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if line.endswith("."):
            line = line[:-1].strip()
        parts = _split_terms(line)
        if len(parts) != 3:
            raise ValueError(f"bad N3 line: {line!r}")
        triples.append((parts[0], parts[1], parts[2]))
    return triples


def _split_terms(line: str) -> list[str]:
    terms, i, n = [], 0, len(line)
    while i < n:
        while i < n and line[i].isspace():
            i += 1
        if i >= n:
            break
        if line[i] == "<":
            j = line.index(">", i)
            terms.append(line[i + 1 : j])
            i = j + 1
        elif line[i] == '"':
            j = i + 1
            while j < n and (line[j] != '"' or line[j - 1] == "\\"):
                j += 1
            terms.append(line[i : j + 1])
            i = j + 1
        else:
            j = i
            while j < n and not line[j].isspace():
                j += 1
            terms.append(line[i:j])
            i = j
    return terms
