"""LM token pipeline: deterministic synthetic shards + next-token batching.

Stands in for the usual sharded-tfrecord reader: documents are generated
per-host from a seeded Markov-ish mixture (so perplexity actually decreases
during the example training runs), packed into fixed-length sequences, and
served as {tokens, labels} with labels = tokens shifted left.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, *, seed: int = 0, n_modes: int = 32):
        self.vocab = vocab
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        # low-entropy structure: per-mode bigram preferences
        self.mode_shift = self.rng.integers(1, vocab - 1, n_modes)
        self.n_modes = n_modes

    def batch(self, batch_size: int) -> dict[str, np.ndarray]:
        B, S, V = batch_size, self.seq_len, self.vocab
        mode = self.rng.integers(0, self.n_modes, B)
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = self.rng.integers(0, V, B)
        noise = self.rng.random((B, S))
        rand = self.rng.integers(0, V, (B, S))
        shift = self.mode_shift[mode][:, None]
        for t in range(S):
            nxt = (toks[:, t] + shift[:, 0]) % V
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, nxt, rand[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
