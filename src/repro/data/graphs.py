"""Graph generation + the fanout neighbor sampler (host-side, numpy).

The assigned GNN shapes name public datasets (cora / reddit / ogbn-products
scale); offline we generate graphs with the same (n_nodes, n_edges, d_feat)
and degree skew, and implement the REAL sampled-training machinery:
``NeighborSampler`` does layered fanout sampling (15-10) over a CSR adjacency
— the part of the system GNN papers assume away.  Sampled blocks are padded
to static shapes (JAX contract) with masks.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.gnn.common import GraphBatch


@dataclasses.dataclass
class HostGraph:
    """CSR adjacency + features on host."""

    indptr: np.ndarray  # int64[N+1]
    indices: np.ndarray  # int64[E]
    feat: np.ndarray  # f32[N, F]
    labels: np.ndarray  # int64[N]
    positions: np.ndarray  # f32[N, 3]
    species: np.ndarray  # int64[N]

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]


def random_graph(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 16,
    *, seed: int = 0, skew: float = 0.8,
) -> HostGraph:
    """Power-law-ish random digraph in CSR (degree skew like real datasets)."""
    rng = np.random.default_rng(seed)
    src = (n_nodes * rng.random(n_edges) ** (1.0 + skew)).astype(np.int64) % n_nodes
    dst = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    feat = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes)
    pos = rng.standard_normal((n_nodes, 3)).astype(np.float32)
    species = rng.integers(0, 16, n_nodes)
    return HostGraph(indptr, dst, feat, labels, pos, species)


def to_batch(g: HostGraph, n_classes: int) -> GraphBatch:
    """Full-batch GraphBatch (edge list from CSR)."""
    src = np.repeat(np.arange(g.n_nodes), np.diff(g.indptr))
    e = g.n_edges
    edge_feat = np.stack(
        [
            g.positions[g.indices][:, 0] - g.positions[src][:, 0],
            g.positions[g.indices][:, 1] - g.positions[src][:, 1],
            g.positions[g.indices][:, 2] - g.positions[src][:, 2],
            np.linalg.norm(g.positions[g.indices] - g.positions[src], axis=1),
        ],
        axis=1,
    ).astype(np.float32)
    return GraphBatch(
        node_feat=g.feat,
        positions=g.positions,
        species=g.species.astype(np.int32),
        edge_src=src.astype(np.int32),
        edge_dst=g.indices.astype(np.int32),
        edge_feat=edge_feat,
        node_mask=np.ones(g.n_nodes, bool),
        edge_mask=np.ones(e, bool),
        labels=g.labels.astype(np.int32),
        graph_ids=np.zeros(g.n_nodes, np.int32),
        graph_y=np.zeros((1,), np.float32),
       
    )


class NeighborSampler:
    """Layered fanout sampling (GraphSAGE-style) with static padded output."""

    def __init__(self, g: HostGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.g = g
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> GraphBatch:
        g = self.g
        nodes = [seeds.astype(np.int64)]
        src_all, dst_all = [], []
        frontier = seeds.astype(np.int64)
        for f in self.fanouts:
            deg = np.diff(g.indptr)[frontier]
            # sample up to f neighbors per frontier node (with replacement
            # when deg > 0; isolated nodes contribute nothing)
            has = deg > 0
            idx = np.repeat(np.arange(frontier.shape[0]), np.where(has, f, 0))
            base = g.indptr[frontier[idx]]
            d = deg[idx]
            off = (self.rng.random(idx.shape[0]) * d).astype(np.int64)
            nbrs = g.indices[base + off]
            src_all.append(nbrs)  # message flows neighbor -> frontier node
            dst_all.append(frontier[idx])
            frontier = np.unique(nbrs)
            nodes.append(frontier)

        node_ids = np.unique(np.concatenate(nodes))
        remap = {int(v): i for i, v in enumerate(node_ids)}
        lut = np.zeros(g.n_nodes, np.int64)
        lut[node_ids] = np.arange(node_ids.shape[0])
        src = lut[np.concatenate(src_all)]
        dst = lut[np.concatenate(dst_all)]

        # pad to static shapes: nodes -> seeds·Π(1+f), edges -> seeds·Σ(Πf)
        max_nodes = int(seeds.shape[0] * np.prod([f + 1 for f in self.fanouts]))
        max_edges = 0
        m = seeds.shape[0]
        for f in self.fanouts:
            m *= f
            max_edges += m
        n, e = node_ids.shape[0], src.shape[0]
        n_pad, e_pad = min(n, max_nodes), min(e, max_edges)

        feat = np.zeros((max_nodes, g.feat.shape[1]), np.float32)
        feat[:n_pad] = g.feat[node_ids[:n_pad]]
        pos = np.zeros((max_nodes, 3), np.float32)
        pos[:n_pad] = g.positions[node_ids[:n_pad]]
        spec = np.zeros(max_nodes, np.int32)
        spec[:n_pad] = g.species[node_ids[:n_pad]]
        labels = np.full(max_nodes, -1, np.int32)
        seed_local = lut[seeds]
        labels[seed_local] = g.labels[seeds]  # loss only on seed nodes

        es = np.zeros(max_edges, np.int32)
        ed = np.zeros(max_edges, np.int32)
        es[:e_pad] = src[:e_pad]
        ed[:e_pad] = dst[:e_pad]
        edge_feat = np.zeros((max_edges, 4), np.float32)
        rel = pos[ed[:e_pad]] - pos[es[:e_pad]]
        edge_feat[:e_pad, :3] = rel
        edge_feat[:e_pad, 3] = np.linalg.norm(rel, axis=1)

        node_mask = np.zeros(max_nodes, bool)
        node_mask[:n_pad] = True
        edge_mask = np.zeros(max_edges, bool)
        edge_mask[:e_pad] = True
        return GraphBatch(
            node_feat=feat, positions=pos, species=spec,
            edge_src=es, edge_dst=ed, edge_feat=edge_feat,
            node_mask=node_mask, edge_mask=edge_mask, labels=labels,
            graph_ids=np.zeros(max_nodes, np.int32),
            graph_y=np.zeros((1,), np.float32),
        )


def molecule_batch(
    batch: int, n_nodes: int, n_edges: int, *, seed: int = 0
) -> GraphBatch:
    """Batched small molecules: kNN point clouds flattened with graph_ids."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    pos = rng.standard_normal((batch, n_nodes, 3)).astype(np.float32) * 2.0
    # kNN edges per molecule
    d = np.linalg.norm(pos[:, :, None] - pos[:, None, :], axis=-1)
    np.einsum("bii->bi", d)[:] = np.inf
    k = max(1, n_edges // n_nodes)
    nn = np.argsort(d, axis=-1)[:, :, :k]  # [B, n, k]
    src = np.tile(np.arange(n_nodes)[None, :, None], (batch, 1, k))
    offs = (np.arange(batch) * n_nodes)[:, None, None]
    es = (src + offs).reshape(-1)[:E]
    ed = (nn + offs).reshape(-1)[:E]
    species = rng.integers(0, 8, N).astype(np.int32)
    feat = np.eye(8, dtype=np.float32)[species]
    rel = pos.reshape(N, 3)[ed] - pos.reshape(N, 3)[es]
    edge_feat = np.concatenate(
        [rel, np.linalg.norm(rel, axis=1, keepdims=True)], axis=1
    ).astype(np.float32)
    y = rng.standard_normal(batch).astype(np.float32)
    return GraphBatch(
        node_feat=feat, positions=pos.reshape(N, 3).astype(np.float32),
        species=species, edge_src=es.astype(np.int32), edge_dst=ed.astype(np.int32),
        edge_feat=edge_feat, node_mask=np.ones(N, bool), edge_mask=np.ones(es.shape[0], bool),
        labels=np.full(N, -1, np.int32),
        graph_ids=np.repeat(np.arange(batch), n_nodes).astype(np.int32),
        graph_y=y,
    )
