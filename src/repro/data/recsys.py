"""Synthetic CTR batches (Criteo-shaped) for xDeepFM."""

from __future__ import annotations

import numpy as np


def ctr_batch(batch: int, n_fields: int, rows_per_field: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    # zipf-ish id popularity like real CTR logs
    ids = (rows_per_field * rng.random((batch, n_fields)) ** 2.5).astype(np.int64)
    ids = np.clip(ids, 0, rows_per_field - 1).astype(np.int32)
    labels = (rng.random(batch) < 0.25).astype(np.int32)
    return {"ids": ids, "labels": labels}


def multi_hot_bags(batch: int, rows: int, max_per_bag: int = 6, *, seed: int = 0):
    """Ragged multi-hot field flattened to (ids, bag_ids) for EmbeddingBag."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, max_per_bag + 1, batch)
    bag_ids = np.repeat(np.arange(batch), counts).astype(np.int32)
    ids = rng.integers(0, rows, counts.sum()).astype(np.int32)
    return ids, bag_ids, counts
