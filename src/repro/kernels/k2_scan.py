"""Pallas TPU kernel: batched k²-tree row/col scans (the (S,P,?O)/(?S,P,O) path).

This is the scan counterpart of ``k2_check``: one grid step processes a
(BQ,)-block of queries against the **forest** arenas (``(P, W)`` padded word
matrices — vertical partitioning's whole-arena VMEM residency; a
dbpedia-scale forest is a few MB, within the ~16 MB/core budget).  Each query
lane carries its own (pred, key, axis): ``axis == 0`` scans a row (direct
neighbors, (S,P,?O)), ``axis == 1`` a column (reverse neighbors, (?S,P,O)) —
the mixed-batch contract of ``core/k2forest.scan_batch_mixed``.

The traversal is the level-synchronous frontier BFS from ``core/k2tree``,
statically unrolled over the (tiny) tree height.  Per level, each of the
``cap`` frontier lanes does

    word   = words[pred, pos >> 5]          (2-D dynamic gather, minor dim)
    rank   = t_rank[pred, pos >> 5] + popcount(word & mask)
    children expand along the free axis     (frontier (cap,) -> (cap·k,))
    compact valid children to the front     (stable: keeps ID-sorted order)

Compaction is phrased as a **stable argsort of the invalid flag** followed by
a gather — a fixed-shape, sort-network-friendly formulation (XLA lowers it to
``lax.sort``; on TPU this is the standard bitonic path) that exactly
reproduces the scatter-based ``_compact`` of the jnp reference, including
which candidates survive when the frontier exceeds ``cap`` (the first ``cap``
in free-axis order) and the zeroing of dead lanes.

Outputs per query: ``ids[cap]`` (free-axis coordinates, ascending),
``valid[cap]``, ``count`` = min(#results, cap), ``overflow`` latched if any
level's frontier was truncated.  Bit-exact against ``ref.k2_scan_ref`` and
``k2forest.scan_batch_mixed`` (jnp backend); validated with
``interpret=True`` against the numpy dense oracle in ``tests/test_k2_scan.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.k2tree import K2Meta


def _bit_at(words2d: jax.Array, pred2d: jax.Array, pos: jax.Array) -> jax.Array:
    """Gather bit ``pos`` of tree ``pred`` from a (P, W) word arena."""
    widx = jnp.clip(pos >> 5, 0, words2d.shape[-1] - 1)
    word = words2d[pred2d, widx]
    return ((word >> (pos & 31).astype(jnp.uint32)) & jnp.uint32(1)).astype(jnp.int32)


def _rank_at(
    words2d: jax.Array, rank2d: jax.Array, pred2d: jax.Array, pos: jax.Array
) -> jax.Array:
    widx = jnp.clip(pos >> 5, 0, words2d.shape[-1] - 1)
    word = words2d[pred2d, widx]
    base = rank2d[pred2d, widx]
    mask = (jnp.uint32(1) << (pos & 31).astype(jnp.uint32)) - jnp.uint32(1)
    return base + jax.lax.population_count(word & mask).astype(jnp.int32)


def _compact_rows(valid: jax.Array, cap: int, *arrays: jax.Array):
    """Stable per-row compaction (BQ, N) -> (BQ, cap), valid lanes first.

    Matches ``core.k2tree._compact`` exactly: survivors are the first
    min(#valid, cap) valid candidates in lane order; dropped/dead slots are
    zeroed.  Phrased as stable argsort + gather instead of scatter-drop.
    """
    order = jnp.argsort(~valid, axis=-1, stable=True)[:, :cap]
    n = jnp.minimum(valid.sum(axis=-1), cap).astype(jnp.int32)
    new_valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < n[:, None]
    outs = tuple(
        jnp.where(new_valid, jnp.take_along_axis(a, order, axis=-1), 0)
        for a in arrays
    )
    overflow = valid.sum(axis=-1) > cap
    return new_valid, n, overflow, outs


def _traverse(meta: K2Meta, cap: int, preds, keys, is_row,
              t_words, t_rank, l_words, ones_before, level_start):
    """Level-synchronous frontier BFS over (N,) mixed row/col queries.

    The shared kernel body: returns ``(ids, valid, count, overflow)`` with
    shapes ``(N, cap) / (N, cap) / (N,) / (N,)``.  Used by both the plain
    scan kernel and the fused scan→rebind kernel (which runs it twice).
    """
    H = meta.n_levels
    ks = meta.ks
    radices = meta.radices
    subsides = meta.subsides
    bq = preds.shape[0]
    p2 = jnp.broadcast_to(preds[:, None], (bq, cap))

    # per-level digit of the bound coordinate (static unroll)
    fdig = []
    rem = keys
    for sub in subsides:
        fdig.append(rem // sub)
        rem = rem % sub

    # level-0 frontier: the k0 children of the root along the free axis
    k0, sub0 = ks[0], subsides[0]
    init_n = min(k0, cap)
    lane = jnp.arange(cap, dtype=jnp.int32)
    on = lane < init_n
    j0 = jnp.minimum(lane, init_n - 1)[None, :]
    p0 = jnp.where(is_row[:, None], fdig[0][:, None] * k0 + j0,
                   j0 * k0 + fdig[0][:, None])
    pos = jnp.where(on[None, :], p0, 0).astype(jnp.int32)
    base = jnp.broadcast_to(
        jnp.where(on[None, :], j0 * sub0, 0), (bq, cap)
    ).astype(jnp.int32)
    valid = jnp.broadcast_to(on[None, :], (bq, cap))
    overflow = jnp.full((bq,), k0 > cap, jnp.bool_)

    words0 = l_words if H == 1 else t_words
    valid = valid & (_bit_at(words0, p2, pos) == 1)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = ks[lvl + 1]
        r = radices[lvl + 1]
        sub = subsides[lvl + 1]
        j = _rank_at(t_words, t_rank, p2, pos) - ones_before[preds, lvl][:, None]
        child_base0 = level_start[preds, lvl + 1][:, None] + j * r
        ch = jnp.arange(k, dtype=jnp.int32)[None, None, :]
        cpos = child_base0[:, :, None] + jnp.where(
            is_row[:, None, None],
            fdig[lvl + 1][:, None, None] * k + ch,
            ch * k + fdig[lvl + 1][:, None, None],
        )
        cbase = base[:, :, None] + ch * sub
        wordsc = l_words if last_child else t_words
        cpos_safe = jnp.where(valid[:, :, None], cpos, 0).reshape(bq, cap * k)
        cbit = _bit_at(wordsc, jnp.broadcast_to(preds[:, None], (bq, cap * k)),
                       cpos_safe)
        cvalid = valid[:, :, None].repeat(k, axis=2).reshape(bq, cap * k) & (cbit == 1)
        valid, _, ovf, (pos, base) = _compact_rows(
            cvalid, cap, cpos_safe, cbase.reshape(bq, cap * k)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (ids,) = _compact_rows(valid, cap, base)
    return ids, valid, count, overflow | ovf


def _make_scan_kernel(meta: K2Meta, cap: int):
    def kernel(preds_ref, keys_ref, axes_ref, t_words_ref, t_rank_ref,
               l_words_ref, ones_before_ref, level_start_ref,
               ids_ref, valid_ref, count_ref, ovf_ref):
        ids, valid, count, ovf = _traverse(
            meta, cap, preds_ref[...], keys_ref[...], axes_ref[...] == 0,
            t_words_ref[...], t_rank_ref[...], l_words_ref[...],
            ones_before_ref[...], level_start_ref[...],
        )
        ids_ref[...] = ids
        valid_ref[...] = valid
        count_ref[...] = count
        ovf_ref[...] = ovf

    return kernel


@functools.partial(
    jax.jit, static_argnames=("meta", "cap", "block_q", "interpret")
)
def k2_scan(
    meta: K2Meta,
    preds: jax.Array,
    keys: jax.Array,
    axes: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    cap: int,
    block_q: int = 256,
    interpret: bool = False,
):
    """Batched mixed row/col scans over a K2Forest arena.

    Returns ``(ids, valid, count, overflow)`` with shapes
    ``(Q, cap) / (Q, cap) / (Q,) / (Q,)``.  Q must divide by block_q.
    """
    (q,) = preds.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    qvec = pl.BlockSpec((block_q,), lambda i: (i,))
    qmat = pl.BlockSpec((block_q, cap), lambda i: (i, 0))
    return pl.pallas_call(
        _make_scan_kernel(meta, cap),
        grid=grid,
        in_specs=[
            qvec, qvec, qvec,
            whole(t_words), whole(t_rank), whole(l_words),
            whole(ones_before), whole(level_start),
        ],
        out_specs=(qmat, qmat, qvec, qvec),
        out_shape=(
            jax.ShapeDtypeStruct((q, cap), jnp.int32),
            jax.ShapeDtypeStruct((q, cap), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.bool_),
        ),
        interpret=interpret,
    )(preds.astype(jnp.int32), keys.astype(jnp.int32), axes.astype(jnp.int32),
      t_words, t_rank, l_words, ones_before, level_start)


# ---------------------------------------------------------------------------
# fused scan → rebind (join categories D–F: resolve ?X, re-bind into pattern 2)
# ---------------------------------------------------------------------------


def _make_scan_rebind_kernel(meta: K2Meta, cap_x: int, cap_y: int):
    def kernel(preds1_ref, keys1_ref, axes1_ref, preds2_ref, axes2_ref,
               t_words_ref, t_rank_ref, l_words_ref, ones_before_ref,
               level_start_ref,
               x_ids_ref, x_valid_ref, x_count_ref, x_ovf_ref,
               y_ids_ref, y_valid_ref, y_count_ref, y_ovf_ref):
        t_words = t_words_ref[...]
        t_rank = t_rank_ref[...]
        l_words = l_words_ref[...]
        ones_before = ones_before_ref[...]
        level_start = level_start_ref[...]

        preds1 = preds1_ref[...]                      # (BQ,)
        bq = preds1.shape[0]
        x_ids, x_valid, x_count, x_ovf = _traverse(
            meta, cap_x, preds1, keys1_ref[...], axes1_ref[...] == 0,
            t_words, t_rank, l_words, ones_before, level_start,
        )

        # re-bind: every X lane becomes a pattern-2 query.  Dead lanes scan
        # key 0 (the caller masks y_valid with x_valid) — this matches the
        # jnp composition's clamp-to-a-safe-id exactly, bit for bit.
        keys2 = jnp.where(x_valid, x_ids, 0).reshape(bq * cap_x)
        preds2 = jnp.broadcast_to(
            preds2_ref[...][:, None], (bq, cap_x)
        ).reshape(bq * cap_x)
        is_row2 = jnp.broadcast_to(
            (axes2_ref[...] == 0)[:, None], (bq, cap_x)
        ).reshape(bq * cap_x)
        y_ids, y_valid, y_count, y_ovf = _traverse(
            meta, cap_y, preds2, keys2, is_row2,
            t_words, t_rank, l_words, ones_before, level_start,
        )

        x_ids_ref[...] = x_ids
        x_valid_ref[...] = x_valid
        x_count_ref[...] = x_count
        x_ovf_ref[...] = x_ovf
        y_ids_ref[...] = y_ids.reshape(bq, cap_x, cap_y)
        y_valid_ref[...] = y_valid.reshape(bq, cap_x, cap_y)
        y_count_ref[...] = y_count.reshape(bq, cap_x)
        y_ovf_ref[...] = y_ovf.reshape(bq, cap_x)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("meta", "cap_x", "cap_y", "block_q", "interpret")
)
def k2_scan_rebind(
    meta: K2Meta,
    preds1: jax.Array,
    keys1: jax.Array,
    axes1: jax.Array,
    preds2: jax.Array,
    axes2: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    cap_x: int,
    cap_y: int,
    block_q: int = 1,
    interpret: bool = False,
):
    """Fused X-resolution + re-bind: two chained traversals, one kernel.

    Per query lane: scan (preds1, keys1, axes1) into a ``cap_x`` side-list of
    ?X candidates, then — without leaving VMEM — run ``cap_x`` pattern-2
    scans (preds2, X, axes2) at ``cap_y`` each.  Returns
    ``(x_ids, x_valid, x_count, x_overflow, y_ids, y_valid, y_count,
    y_overflow)`` shaped ``(Q,cap_x) ×2, (Q,) ×2, (Q,cap_x,cap_y) ×2,
    (Q,cap_x) ×2``.  Q must divide by block_q.
    """
    (q,) = preds1.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    qvec = pl.BlockSpec((block_q,), lambda i: (i,))
    qx = pl.BlockSpec((block_q, cap_x), lambda i: (i, 0))
    qxy = pl.BlockSpec((block_q, cap_x, cap_y), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _make_scan_rebind_kernel(meta, cap_x, cap_y),
        grid=grid,
        in_specs=[
            qvec, qvec, qvec, qvec, qvec,
            whole(t_words), whole(t_rank), whole(l_words),
            whole(ones_before), whole(level_start),
        ],
        out_specs=(qx, qx, qvec, qvec, qxy, qxy, qx, qx),
        out_shape=(
            jax.ShapeDtypeStruct((q, cap_x), jnp.int32),
            jax.ShapeDtypeStruct((q, cap_x), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.bool_),
            jax.ShapeDtypeStruct((q, cap_x, cap_y), jnp.int32),
            jax.ShapeDtypeStruct((q, cap_x, cap_y), jnp.bool_),
            jax.ShapeDtypeStruct((q, cap_x), jnp.int32),
            jax.ShapeDtypeStruct((q, cap_x), jnp.bool_),
        ),
        interpret=interpret,
    )(preds1.astype(jnp.int32), keys1.astype(jnp.int32),
      axes1.astype(jnp.int32), preds2.astype(jnp.int32),
      axes2.astype(jnp.int32),
      t_words, t_rank, l_words, ones_before, level_start)
