"""Pure-jnp oracles for every Pallas kernel (the ``interpret=True`` ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitvec
from repro.core.k2tree import K2Meta


def popcount_ref(words: jax.Array) -> jax.Array:
    return jax.lax.population_count(words).astype(jnp.int32)


def k2_check_ref(
    meta: K2Meta,
    rows: jax.Array,
    cols: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
) -> jax.Array:
    """Identical math to core/k2tree.check, phrased on raw arrays."""
    H = meta.n_levels
    rrem, crem = rows.astype(jnp.int32), cols.astype(jnp.int32)
    rdig, cdig = [], []
    for sub in meta.subsides:
        rdig.append(rrem // sub)
        cdig.append(crem // sub)
        rrem, crem = rrem % sub, crem % sub
    alive = jnp.ones(rows.shape, jnp.bool_)
    pos = (rdig[0] * meta.ks[0] + cdig[0]).astype(jnp.int32)
    for lvl in range(H):
        last = lvl == H - 1
        words = l_words if last else t_words
        bit = bitvec.get_bit(words, pos)
        alive = alive & (bit == 1)
        if not last:
            j = bitvec.rank1(t_words, t_rank, pos) - ones_before[lvl]
            nxt = rdig[lvl + 1] * meta.ks[lvl + 1] + cdig[lvl + 1]
            pos = level_start[lvl + 1] + j * meta.radices[lvl + 1] + nxt
            pos = jnp.where(alive, pos, 0).astype(jnp.int32)
    return alive


def k2_scan_ref(
    meta: K2Meta,
    preds: jax.Array,
    keys: jax.Array,
    axes: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    cap: int,
):
    """Identical semantics to kernels.k2_scan, phrased on raw forest arrays.

    Deliberately uses the scatter-based ``_compact`` (vs the kernel's stable
    argsort) so kernel-vs-ref agreement checks two independent compaction
    algorithms.  Returns (ids, valid, count, overflow).
    """
    from repro.core.k2tree import _compact, _row_digits

    H = meta.n_levels

    def one(pred, key, axis):
        pred = pred.astype(jnp.int32)
        is_row = axis.astype(jnp.int32) == 0
        fdig = _row_digits(meta, key.astype(jnp.int32))
        k0, sub0 = meta.ks[0], meta.subsides[0]
        init_n = min(k0, cap)
        j0 = jnp.arange(init_n, dtype=jnp.int32)
        p0 = jnp.where(is_row, fdig[0] * k0 + j0, j0 * k0 + fdig[0])
        pos = jnp.zeros((cap,), jnp.int32).at[:init_n].set(p0)
        base = jnp.zeros((cap,), jnp.int32).at[:init_n].set(j0 * sub0)
        valid = jnp.zeros((cap,), jnp.bool_).at[:init_n].set(True)
        overflow = jnp.asarray(k0 > cap)

        words0 = l_words if H == 1 else t_words
        valid = valid & (bitvec.get_bit_2d(words0, pred, pos) == 1)

        for lvl in range(H - 1):
            last_child = lvl + 1 == H - 1
            k, r, sub = meta.ks[lvl + 1], meta.radices[lvl + 1], meta.subsides[lvl + 1]
            j = bitvec.rank1_2d(t_words, t_rank, pred, pos) - ones_before[pred, lvl]
            child_base0 = level_start[pred, lvl + 1] + j * r
            ch = jnp.arange(k, dtype=jnp.int32)
            cpos = child_base0[:, None] + jnp.where(
                is_row, fdig[lvl + 1] * k + ch[None, :], ch[None, :] * k + fdig[lvl + 1]
            )
            cbase = base[:, None] + ch[None, :] * sub
            wordsc = l_words if last_child else t_words
            cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
            cvalid = valid[:, None] & (cbit == 1)
            valid, _, ovf, (pos, base) = _compact(
                cvalid.reshape(-1), cap, cpos.reshape(-1), cbase.reshape(-1)
            )
            overflow = overflow | ovf
            pos = jnp.where(valid, pos, 0)

        valid, count, ovf, (ids,) = _compact(valid, cap, base)
        return ids, valid, count, overflow | ovf

    return jax.vmap(one)(
        jnp.asarray(preds, jnp.int32), jnp.asarray(keys, jnp.int32),
        jnp.asarray(axes, jnp.int32),
    )


def k2_range_ref(
    meta: K2Meta,
    preds: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    cap: int,
):
    """Identical semantics to kernels.k2_range, phrased on raw forest arrays.

    Like ``k2_scan_ref`` this deliberately uses the scatter-based
    ``_compact`` (vs the kernel's stable argsort) so agreement checks two
    independent compaction algorithms.  Level 0 bit-tests every root child
    and only then compacts — the fixed overflow semantics.  Returns
    ``(rows, cols, valid, count, overflow)``.
    """
    from repro.core.k2tree import _compact

    H = meta.n_levels

    def one(pred):
        pred = pred.astype(jnp.int32)
        k0, r0, sub0 = meta.ks[0], meta.radices[0], meta.subsides[0]
        d0 = jnp.arange(r0, dtype=jnp.int32)
        words0 = l_words if H == 1 else t_words
        bit0 = bitvec.get_bit_2d(words0, pred, d0)
        valid, _, ovf, (pos, rbase, cbase) = _compact(
            bit0 == 1, cap, d0, (d0 // k0) * sub0, (d0 % k0) * sub0
        )
        overflow = ovf
        pos = jnp.where(valid, pos, 0)

        for lvl in range(H - 1):
            last_child = lvl + 1 == H - 1
            k, r, sub = meta.ks[lvl + 1], meta.radices[lvl + 1], meta.subsides[lvl + 1]
            j = bitvec.rank1_2d(t_words, t_rank, pred, pos) - ones_before[pred, lvl]
            child_base0 = level_start[pred, lvl + 1] + j * r
            d = jnp.arange(r, dtype=jnp.int32)
            cpos = child_base0[:, None] + d[None, :]
            crb = rbase[:, None] + (d[None, :] // k) * sub
            ccb = cbase[:, None] + (d[None, :] % k) * sub
            wordsc = l_words if last_child else t_words
            cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
            cvalid = valid[:, None] & (cbit == 1)
            valid, _, ovf, (pos, rbase, cbase) = _compact(
                cvalid.reshape(-1), cap, cpos.reshape(-1), crb.reshape(-1),
                ccb.reshape(-1)
            )
            overflow = overflow | ovf
            pos = jnp.where(valid, pos, 0)

        valid, count, ovf, (rows, cols) = _compact(valid, cap, rbase, cbase)
        return rows, cols, valid, count, overflow | ovf

    return jax.vmap(one)(jnp.asarray(preds, jnp.int32))


def k2_scan_rebind_ref(
    meta: K2Meta,
    preds1: jax.Array,
    keys1: jax.Array,
    axes1: jax.Array,
    preds2: jax.Array,
    axes2: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    cap_x: int,
    cap_y: int,
):
    """Fused scan→rebind reference: ``k2_scan_ref`` composed with itself.

    Dead X lanes are clamped to key 0 exactly as the kernel does (their
    ``y_valid`` rows are masked by the caller).  Returns the kernel's 8-tuple.
    """
    q = jnp.shape(preds1)[0]
    x_ids, x_valid, x_count, x_ovf = k2_scan_ref(
        meta, preds1, keys1, axes1, t_words, t_rank, l_words,
        ones_before, level_start, cap=cap_x,
    )
    keys2 = jnp.where(x_valid, x_ids, 0).reshape(q * cap_x)
    p2 = jnp.broadcast_to(
        jnp.asarray(preds2, jnp.int32)[:, None], (q, cap_x)
    ).reshape(q * cap_x)
    a2 = jnp.broadcast_to(
        jnp.asarray(axes2, jnp.int32)[:, None], (q, cap_x)
    ).reshape(q * cap_x)
    y_ids, y_valid, y_count, y_ovf = k2_scan_ref(
        meta, p2, keys2, a2, t_words, t_rank, l_words,
        ones_before, level_start, cap=cap_y,
    )
    return (
        x_ids, x_valid, x_count, x_ovf,
        y_ids.reshape(q, cap_x, cap_y), y_valid.reshape(q, cap_x, cap_y),
        y_count.reshape(q, cap_x), y_ovf.reshape(q, cap_x),
    )


def pred_gather_ref(
    rows: jax.Array,
    offsets: jax.Array,
    words: jax.Array,
    *,
    bytes_per_pred: int,
    cap: int,
):
    """Identical semantics to kernels.pred_gather, phrased on raw CSR arrays.

    Lane (q, j) holds the j-th packed entry of row ``rows[q]``; prefix
    ``valid``, dead lanes zeroed, ``overflow`` = row longer than ``cap``.
    The byte unpacking is ``predindex.payload_at`` — one source of truth
    for the packing scheme; the Pallas kernel is the independent
    implementation the differential harness checks against.
    Returns (ids, valid, count, overflow).
    """
    from repro.core.predindex import payload_at

    rows = jnp.asarray(rows, jnp.int32)
    start = offsets[rows]
    deg = offsets[rows + 1] - start
    lane = jnp.arange(cap, dtype=jnp.int32)[None, :]
    n = jnp.minimum(deg, cap)
    valid = lane < n[:, None]
    elem = jnp.where(valid, start[:, None] + lane, 0)
    ids = jnp.where(valid, payload_at(words, elem, bytes_per_pred), 0)
    return ids, valid, n.astype(jnp.int32), deg > cap


def pred_gather_dac_ref(
    rows: jax.Array,
    anchors: jax.Array,
    words: jax.Array,
    degs: jax.Array,
    flags: jax.Array,
    frank: jax.Array,
    *,
    levels: int,
    level_byte_start: tuple,
    flag_word_start: tuple,
    deg_width: int,
    rows_per_block: int,
    cap: int,
):
    """Identical semantics to kernels.pred_gather_dac, on raw DAC arrays.

    Decodes the multi-level DAC(b=8) payload of ``core/predindex``
    (``layout="dac"``): row pointers are reconstructed from one int32
    anchor per ``rows_per_block`` rows plus ``deg_width``-bit packed
    degrees; per lane, the level-0 chunk is read at ``start + lane``, and
    each continuation flag's in-level rank re-addresses the lane into the
    next level's byte stream; the recovered gaps prefix-sum back to
    0-based predicate ids.  This reference is vectorized jnp with
    ``jnp.cumsum``; the Pallas kernel uses a log-doubling prefix sum and a
    masked SWAR loop — two independent implementations for the
    differential harness.  Returns (ids, valid, count, overflow).
    """
    rows = jnp.asarray(rows, jnp.int32)
    per_word = 32 // deg_width
    dmask = jnp.uint32((1 << deg_width) - 1 if deg_width < 32 else 0xFFFFFFFF)
    block = rows // rows_per_block
    within = rows % rows_per_block

    kidx = jnp.arange(rows_per_block, dtype=jnp.int32)
    widx = block[:, None] * 4 + kidx[None, :] // per_word
    dword = degs[jnp.clip(widx, 0, degs.shape[0] - 1)]
    shift = ((kidx % per_word) * deg_width).astype(jnp.uint32)
    dvals = ((dword >> shift[None, :]) & dmask).astype(jnp.int32)  # (B, rb)
    start = anchors[jnp.clip(block, 0, anchors.shape[0] - 1)] + jnp.sum(
        dvals * (kidx[None, :] < within[:, None]), axis=1
    )
    deg = jnp.take_along_axis(dvals, within[:, None], axis=1)[:, 0]

    def byte_at(bidx):
        w = words[jnp.clip(bidx >> 2, 0, words.shape[0] - 1)]
        return ((w >> ((bidx & 3) * 8).astype(jnp.uint32)) & 0xFF).astype(
            jnp.int32
        )

    lane = jnp.arange(cap, dtype=jnp.int32)[None, :]
    n = jnp.minimum(deg, cap)
    valid = lane < n[:, None]
    pos = jnp.where(valid, start[:, None] + lane, 0)
    gap = byte_at(level_byte_start[0] + pos)
    alive = valid
    for lvl in range(levels - 1):
        fidx = jnp.clip(flag_word_start[lvl] + (pos >> 5), 0, flags.shape[0] - 1)
        fword = flags[fidx]
        sh = (pos & 31).astype(jnp.uint32)
        bit = ((fword >> sh) & 1) == 1
        low = fword & ((jnp.uint32(1) << sh) - jnp.uint32(1))
        rank = frank[fidx] + popcount_ref(low)
        alive = alive & bit
        pos = jnp.where(alive, rank, 0)
        chunk = byte_at(level_byte_start[lvl + 1] + pos)
        gap = gap + jnp.where(alive, chunk << (8 * (lvl + 1)), 0)
    preds = jnp.cumsum(jnp.where(valid, gap, 0), axis=1) - 1
    ids = jnp.where(valid, preds, 0).astype(jnp.int32)
    return ids, valid, n.astype(jnp.int32), deg > cap


def sorted_intersect_mask_ref(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    pos = jnp.searchsorted(b_ids, a_ids)
    got = jnp.take(b_ids, jnp.clip(pos, 0, b_ids.shape[0] - 1), mode="clip")
    return (got == a_ids) & (a_ids != jnp.int32(2**31 - 1))


def block_spmm_ref(mask: jax.Array, a: jax.Array, x: jax.Array,
                   block_m: int = 128, block_k: int = 128) -> jax.Array:
    """Masked matmul: zero out masked-off tiles of A, then dense matmul."""
    m, k = a.shape
    mm = jnp.repeat(jnp.repeat(mask, block_m, 0), block_k, 1).astype(a.dtype)
    return jnp.dot(a * mm, x, preferred_element_type=jnp.float32)
