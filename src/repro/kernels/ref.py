"""Pure-jnp oracles for every Pallas kernel (the ``interpret=True`` ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitvec
from repro.core.k2tree import K2Meta


def popcount_ref(words: jax.Array) -> jax.Array:
    return jax.lax.population_count(words).astype(jnp.int32)


def k2_check_ref(
    meta: K2Meta,
    rows: jax.Array,
    cols: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
) -> jax.Array:
    """Identical math to core/k2tree.check, phrased on raw arrays."""
    H = meta.n_levels
    rrem, crem = rows.astype(jnp.int32), cols.astype(jnp.int32)
    rdig, cdig = [], []
    for sub in meta.subsides:
        rdig.append(rrem // sub)
        cdig.append(crem // sub)
        rrem, crem = rrem % sub, crem % sub
    alive = jnp.ones(rows.shape, jnp.bool_)
    pos = (rdig[0] * meta.ks[0] + cdig[0]).astype(jnp.int32)
    for lvl in range(H):
        last = lvl == H - 1
        words = l_words if last else t_words
        bit = bitvec.get_bit(words, pos)
        alive = alive & (bit == 1)
        if not last:
            j = bitvec.rank1(t_words, t_rank, pos) - ones_before[lvl]
            nxt = rdig[lvl + 1] * meta.ks[lvl + 1] + cdig[lvl + 1]
            pos = level_start[lvl + 1] + j * meta.radices[lvl + 1] + nxt
            pos = jnp.where(alive, pos, 0).astype(jnp.int32)
    return alive


def sorted_intersect_mask_ref(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    pos = jnp.searchsorted(b_ids, a_ids)
    got = jnp.take(b_ids, jnp.clip(pos, 0, b_ids.shape[0] - 1), mode="clip")
    return (got == a_ids) & (a_ids != jnp.int32(2**31 - 1))


def block_spmm_ref(mask: jax.Array, a: jax.Array, x: jax.Array,
                   block_m: int = 128, block_k: int = 128) -> jax.Array:
    """Masked matmul: zero out masked-off tiles of A, then dense matmul."""
    m, k = a.shape
    mm = jnp.repeat(jnp.repeat(mask, block_m, 0), block_k, 1).astype(a.dtype)
    return jnp.dot(a * mm, x, preferred_element_type=jnp.float32)
