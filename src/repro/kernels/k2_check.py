"""Pallas TPU kernel: batched k²-tree point queries (the (S,P,O) hot path).

One grid step processes a (BQ,)-block of queries against a single tree whose
T / L word arenas and rank directory are resident in VMEM (k²-trees are tiny
— that is the paper's point — so whole-arena VMEM residency is the natural
TPU mapping; a dbpedia-scale predicate tree is a few MB).

The traversal is the level-synchronous reformulation from ``core/k2tree``:
a STATIC unrolled loop over the tree height; each level does, per query lane,

    word   = T_words[pos >> 5]            (dynamic gather, minor dim)
    bit    = (word >> (pos & 31)) & 1
    rank   = rank_blocks[pos >> 5] + popcount(word & mask)
    pos'   = level_start[l+1] + (rank - ones_before[l]) * k² + digit

i.e. two dynamic gathers + VPU integer ALU per level.  Mosaic lowers 1-D
``jnp.take`` to ``tpu.dynamic_gather`` on the minor dimension; positions are
int32 and the arenas are <= a few MB, within VMEM.  Query blocks of 1024
lanes keep the gathers dense enough to hide latency.

Validated with ``interpret=True`` against ``ref.check_ref`` (pure jnp) and
against the numpy oracle in tests.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.k2tree import K2Meta


def _make_kernel(meta: K2Meta):
    H = meta.n_levels
    ks = meta.ks
    radices = meta.radices
    subsides = meta.subsides

    def kernel(rows_ref, cols_ref, t_words_ref, t_rank_ref, l_words_ref,
               ones_before_ref, level_start_ref, out_ref):
        rows = rows_ref[...]
        cols = cols_ref[...]
        t_words = t_words_ref[...]
        t_rank = t_rank_ref[...]
        l_words = l_words_ref[...]

        # per-level digits (static unroll — H is tiny)
        rrem, crem = rows, cols
        rdig, cdig = [], []
        for sub in subsides:
            rdig.append(rrem // sub)
            cdig.append(crem // sub)
            rrem = rrem % sub
            crem = crem % sub

        alive = jnp.ones(rows.shape, dtype=jnp.bool_)
        pos = (rdig[0] * ks[0] + cdig[0]).astype(jnp.int32)
        for lvl in range(H):
            last = lvl == H - 1
            words = l_words if last else t_words
            widx = pos >> 5
            word = jnp.take(words, widx, mode="clip")
            bit = (word >> (pos & 31).astype(jnp.uint32)) & jnp.uint32(1)
            alive = alive & (bit == 1)
            if not last:
                base = jnp.take(t_rank, widx, mode="clip")
                mask = (jnp.uint32(1) << (pos & 31).astype(jnp.uint32)) - jnp.uint32(1)
                rank = base + jax.lax.population_count(word & mask).astype(jnp.int32)
                j = rank - ones_before_ref[lvl]
                nxt = rdig[lvl + 1] * ks[lvl + 1] + cdig[lvl + 1]
                pos = level_start_ref[lvl + 1] + j * radices[lvl + 1] + nxt
                pos = jnp.where(alive, pos, 0).astype(jnp.int32)
        out_ref[...] = alive

    return kernel


@functools.partial(
    jax.jit, static_argnames=("meta", "block_q", "interpret")
)
def k2_check(
    meta: K2Meta,
    rows: jax.Array,
    cols: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    block_q: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Batched point queries -> bool[Q].  Q must divide by block_q."""
    (q,) = rows.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    return pl.pallas_call(
        _make_kernel(meta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            whole(t_words),
            whole(t_rank),
            whole(l_words),
            whole(ones_before),
            whole(level_start),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.bool_),
        interpret=interpret,
    )(rows.astype(jnp.int32), cols.astype(jnp.int32), t_words, t_rank,
      l_words, ones_before, level_start)
