"""Public jit'd entry points for the kernel layer.

Environment flags (read once at import):

``REPRO_PALLAS_INTERPRET``
    "1" (default off-TPU) flips every Pallas kernel into interpret mode —
    the CPU correctness path used by this container (TPU is the compile
    target).  On a real TPU backend set ``REPRO_PALLAS_INTERPRET=0`` (the
    default there: interpret only engages when the backend is not TPU).

``REPRO_SCAN_BACKEND``
    Selects the implementation behind ``core.k2forest.scan_batch_mixed``
    (the (S,P,?O)/(?S,P,O) serve hot path):

      * ``"pallas"`` (default) — the batched ``k2_scan`` kernel
        (``kernels/k2_scan.py``): whole-arena VMEM residency, one grid step
        per query block.
      * ``"jnp"`` — the vmapped pure-jnp level-synchronous traversal
        (the pre-kernel path; also the differential reference).

    Callers can override per-call via the ``backend=`` keyword.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.k2tree import K2Meta, K2Tree
from repro.kernels import block_spmm as _bs
from repro.kernels import k2_check as _kc
from repro.kernels import k2_scan as _ks
from repro.kernels import popcount as _pc
from repro.kernels import sorted_intersect as _si

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0" and (
    jax.default_backend() != "tpu"
)

SCAN_BACKEND = os.environ.get("REPRO_SCAN_BACKEND", "pallas")


def scan_backend(override: str | None = None) -> str:
    """Resolve the scan backend ("pallas" | "jnp")."""
    b = override or SCAN_BACKEND
    if b not in ("pallas", "jnp"):
        raise ValueError(f"unknown scan backend {b!r} (want 'pallas' or 'jnp')")
    return b


def popcount(words: jax.Array, *, block_m: int = 8) -> jax.Array:
    return _pc.popcount_2d(words, block_m=block_m, interpret=INTERPRET)


def k2_check_tree(
    meta: K2Meta, tree: K2Tree, rows: jax.Array, cols: jax.Array, *, block_q: int = 1024
) -> jax.Array:
    """Kernel-backed version of core.k2tree.check (single tree)."""
    q = rows.shape[0]
    pad = (-q) % block_q
    if pad:
        rows = jnp.pad(rows, (0, pad))
        cols = jnp.pad(cols, (0, pad))
    out = _kc.k2_check(
        meta, rows, cols, tree.t.words, tree.t.rank_blocks, tree.l.words,
        tree.ones_before, tree.level_start, block_q=block_q, interpret=INTERPRET,
    )
    return out[:q]


def k2_scan_forest(
    meta: K2Meta,
    forest,
    preds: jax.Array,
    keys: jax.Array,
    axes: jax.Array,
    *,
    cap: int,
    block_q: int = 256,
):
    """Kernel-backed batched mixed row/col scan over a K2Forest.

    Drop-in compute for ``core.k2forest.scan_batch_mixed`` (which routes
    here when the scan backend is "pallas").  Queries are padded up to a
    ``block_q`` multiple; padded lanes traverse tree 0 at key 0 and are
    sliced off before returning.  Returns (ids, valid, count, overflow).
    """
    (q,) = jnp.shape(preds)
    bq = min(block_q, max(1, q))
    pad = (-q) % bq
    preds = jnp.asarray(preds, jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    axes = jnp.asarray(axes, jnp.int32)
    if pad:
        preds = jnp.pad(preds, (0, pad))
        keys = jnp.pad(keys, (0, pad))
        axes = jnp.pad(axes, (0, pad))
    ids, valid, count, overflow = _ks.k2_scan(
        meta, preds, keys, axes,
        forest.t_words, forest.t_rank, forest.l_words,
        forest.ones_before, forest.level_start,
        cap=cap, block_q=bq, interpret=INTERPRET,
    )
    return ids[:q], valid[:q], count[:q], overflow[:q]


def sorted_intersect_mask(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    return _si.sorted_intersect_mask(a_ids, b_ids, interpret=INTERPRET)


def block_spmm(mask: jax.Array, a: jax.Array, x: jax.Array, **kw) -> jax.Array:
    return _bs.block_spmm(mask, a, x, interpret=INTERPRET, **kw)
