"""Public jit'd entry points for the kernel layer.

Execution knobs reach this layer one of two ways:

  * **the compiled-plan path** (``core.query.ExecConfig`` threaded through
    ``Engine.compile`` → patterns/joins/optimizer → ``core.k2forest``):
    the config object carries explicit ``backend`` + ``interpret`` values
    and ``resolve_exec`` honors them with ZERO environment reads — nothing
    inside a compiled ``Plan.__call__`` consults ``os.environ``
    (tests/test_backend_flag.py);
  * **the legacy path** (``backend=None`` or a bare string from the
    deprecation shims / ad-hoc calls): ``scan_backend()`` /
    ``pallas_interpret()`` resolve the environment flags below per call.

Environment flags (legacy defaults — fold them into an explicit config
once via ``ExecConfig.from_env()``):

``REPRO_PALLAS_INTERPRET``
    (re-read on every entry-point call — same semantics as the scan-backend
    flag below; a function already jit-compiled keeps the mode baked in at
    trace time) "1" (default off-TPU) flips every Pallas kernel into
    interpret mode — the CPU correctness path used by this container (TPU
    is the compile target).  On a real TPU backend set
    ``REPRO_PALLAS_INTERPRET=0`` (the default there: interpret only engages
    when the backend is not TPU).

``REPRO_SCAN_BACKEND``
    (re-read on every resolve — flipping the var mid-session takes effect
    on the next *trace*: eager calls and fresh jit traces see the new
    value, but a function already jit-compiled keeps the backend baked in
    at trace time) Selects the traversal substrate behind
    ``core.k2forest`` batch scans — ``scan_batch_mixed`` (the
    (S,P,?O)/(?S,P,O) serve hot path + all-preds sweeps),
    ``range_scan_batch`` ((?S,P,?O) pair enumeration),
    ``scan_rebind_batch`` (join categories D–F), and
    ``core.predindex.gather_batch`` (the SP/OP candidate gather feeding
    the index-pruned unbounded-?P lanes):

      * ``"pallas"`` (default) — the batched kernels (``kernels/k2_scan.py``
        / ``kernels/k2_range.py``): whole-arena VMEM residency, one grid
        step per query block.
      * ``"jnp"`` — the vmapped pure-jnp level-synchronous traversal
        (the pre-kernel path; also the differential reference).

    Callers can override per-call via the ``backend=`` keyword.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.k2tree import K2Meta, K2Tree
from repro.kernels import block_spmm as _bs
from repro.kernels import k2_check as _kc
from repro.kernels import k2_range as _kr
from repro.kernels import k2_scan as _ks
from repro.kernels import popcount as _pc
from repro.kernels import pred_gather as _pg
from repro.kernels import sorted_intersect as _si

DEFAULT_SCAN_BACKEND = "pallas"


def pallas_interpret(override: bool | None = None) -> bool:
    """Resolve interpret mode for every Pallas launch.

    Re-reads ``REPRO_PALLAS_INTERPRET`` from the environment on every call —
    the same no-latching contract as ``scan_backend()`` (the original code
    captured it once into a module constant, so flipping the var after
    import was silently ignored; tests/test_backend_flag.py).
    """
    if override is not None:
        return override
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0" and (
        jax.default_backend() != "tpu"
    )


def scan_backend(override: str | None = None) -> str:
    """Resolve the scan backend ("pallas" | "jnp").

    Re-reads ``REPRO_SCAN_BACKEND`` from the environment on every call, so
    flipping the flag after import (as a test or notebook naturally does)
    is honored — the value is NOT latched at import time.
    """
    b = override or os.environ.get("REPRO_SCAN_BACKEND", DEFAULT_SCAN_BACKEND)
    if b not in ("pallas", "jnp"):
        raise ValueError(f"unknown scan backend {b!r} (want 'pallas' or 'jnp')")
    return b


def resolve_exec(backend=None) -> tuple[str, bool]:
    """Resolve ``(backend, interpret)`` for one traversal dispatch.

    ``backend`` may be an ``ExecConfig``-shaped object (anything with
    ``.backend`` / ``.interpret`` attributes — duck-typed so core modules
    need no import of ``core.query``), a bare backend string, or ``None``.
    A config resolves WITHOUT touching the environment: its values are
    explicit (``interpret=None`` means the deterministic off-TPU default).
    A string or ``None`` falls back to the legacy per-call env resolution.
    """
    cfg_backend = getattr(backend, "backend", None)
    if cfg_backend is not None:
        if cfg_backend not in ("pallas", "jnp"):
            raise ValueError(
                f"unknown scan backend {cfg_backend!r} (want 'pallas' or 'jnp')"
            )
        interp = backend.interpret
        if interp is None:
            from repro.core.query import default_interpret

            interp = default_interpret()
        return cfg_backend, bool(interp)
    return scan_backend(backend), pallas_interpret()


def popcount(words: jax.Array, *, block_m: int = 8) -> jax.Array:
    return _pc.popcount_2d(words, block_m=block_m, interpret=pallas_interpret())


def k2_check_tree(
    meta: K2Meta, tree: K2Tree, rows: jax.Array, cols: jax.Array, *, block_q: int = 1024
) -> jax.Array:
    """Kernel-backed version of core.k2tree.check (single tree)."""
    q = rows.shape[0]
    pad = (-q) % block_q
    if pad:
        rows = jnp.pad(rows, (0, pad))
        cols = jnp.pad(cols, (0, pad))
    out = _kc.k2_check(
        meta, rows, cols, tree.t.words, tree.t.rank_blocks, tree.l.words,
        tree.ones_before, tree.level_start, block_q=block_q, interpret=pallas_interpret(),
    )
    return out[:q]


def k2_scan_forest(
    meta: K2Meta,
    forest,
    preds: jax.Array,
    keys: jax.Array,
    axes: jax.Array,
    *,
    cap: int,
    block_q: int = 256,
    interpret: bool | None = None,
):
    """Kernel-backed batched mixed row/col scan over a K2Forest.

    Drop-in compute for ``core.k2forest.scan_batch_mixed`` (which routes
    here when the scan backend is "pallas").  Queries are padded up to a
    ``block_q`` multiple; padded lanes traverse tree 0 at key 0 and are
    sliced off before returning.  Returns (ids, valid, count, overflow).
    ``interpret=None`` defers to the legacy env flag; the compiled-plan
    path always passes an explicit bool.
    """
    (q,) = jnp.shape(preds)
    bq = min(block_q, max(1, q))
    pad = (-q) % bq
    preds = jnp.asarray(preds, jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    axes = jnp.asarray(axes, jnp.int32)
    if pad:
        preds = jnp.pad(preds, (0, pad))
        keys = jnp.pad(keys, (0, pad))
        axes = jnp.pad(axes, (0, pad))
    ids, valid, count, overflow = _ks.k2_scan(
        meta, preds, keys, axes,
        forest.t_words, forest.t_rank, forest.l_words,
        forest.ones_before, forest.level_start,
        cap=cap, block_q=bq, interpret=pallas_interpret(interpret),
    )
    return ids[:q], valid[:q], count[:q], overflow[:q]


def k2_range_forest(
    meta: K2Meta,
    forest,
    preds: jax.Array,
    *,
    cap: int,
    block_q: int = 8,
    interpret: bool | None = None,
):
    """Kernel-backed batched (?S,P,?O) pair enumeration over a K2Forest.

    Drop-in compute for ``core.k2forest.range_scan_batch`` (which routes
    here when the scan backend is "pallas").  Queries are padded up to a
    ``block_q`` multiple; padded lanes enumerate tree 0 and are sliced off.
    Returns (rows, cols, valid, count, overflow).
    """
    (q,) = jnp.shape(preds)
    bq = min(block_q, max(1, q))
    pad = (-q) % bq
    preds = jnp.asarray(preds, jnp.int32)
    if pad:
        preds = jnp.pad(preds, (0, pad))
    rows, cols, valid, count, overflow = _kr.k2_range(
        meta, preds,
        forest.t_words, forest.t_rank, forest.l_words,
        forest.ones_before, forest.level_start,
        cap=cap, block_q=bq, interpret=pallas_interpret(interpret),
    )
    return rows[:q], cols[:q], valid[:q], count[:q], overflow[:q]


def k2_scan_rebind_forest(
    meta: K2Meta,
    forest,
    preds1: jax.Array,
    keys1: jax.Array,
    axes1: jax.Array,
    preds2: jax.Array,
    axes2: jax.Array,
    *,
    cap_x: int,
    cap_y: int,
    block_q: int = 1,
    interpret: bool | None = None,
):
    """Kernel-backed fused X-scan + re-bind (join categories D–F).

    Drop-in compute for ``core.k2forest.scan_rebind_batch`` (which routes
    here when the scan backend is "pallas").  The default ``block_q=1``
    bounds the rebind frontier VMEM at cap_x·cap_y·k lanes per grid step.
    Returns the kernel's 8-tuple (x_ids, x_valid, x_count, x_overflow,
    y_ids, y_valid, y_count, y_overflow).
    """
    (q,) = jnp.shape(preds1)
    bq = min(block_q, max(1, q))
    pad = (-q) % bq
    arrs = [jnp.asarray(a, jnp.int32) for a in (preds1, keys1, axes1, preds2, axes2)]
    if pad:
        arrs = [jnp.pad(a, (0, pad)) for a in arrs]
    out = _ks.k2_scan_rebind(
        meta, *arrs,
        forest.t_words, forest.t_rank, forest.l_words,
        forest.ones_before, forest.level_start,
        cap_x=cap_x, cap_y=cap_y, block_q=bq,
        interpret=pallas_interpret(interpret),
    )
    return tuple(a[:q] for a in out)


def pred_gather_index(
    pmeta,
    index,
    rows: jax.Array,
    *,
    cap: int,
    block_q: int = 256,
    interpret: bool | None = None,
):
    """Kernel-backed candidate-predicate gather over a PredIndex.

    Drop-in compute for ``core.predindex.gather_batch`` (which routes here
    when the scan backend is "pallas").  The decode layout follows
    ``pmeta.layout``: "dac" launches the on-device DAC(b=8) decode kernel,
    "fixed" the byte-packed direct-access kernel.  Rows are clipped to the
    index range and padded up to a ``block_q`` multiple; padded lanes read
    row 0 and are sliced off.  Returns (ids, valid, count, overflow).
    """
    (q,) = jnp.shape(rows)
    bq = min(block_q, max(1, q))
    pad = (-q) % bq
    rows = jnp.clip(
        jnp.asarray(rows, jnp.int32), 0,
        max(pmeta.n_subjects + pmeta.n_objects - 1, 0),
    )
    if pad:
        rows = jnp.pad(rows, (0, pad))
    if getattr(pmeta, "layout", "fixed") == "dac":
        ids, valid, count, overflow = _pg.pred_gather_dac(
            rows, index.offsets, index.words, index.degs, index.flags,
            index.frank, levels=pmeta.levels,
            level_byte_start=pmeta.level_byte_start,
            flag_word_start=pmeta.flag_word_start,
            deg_width=pmeta.deg_width, rows_per_block=pmeta.rows_per_block,
            cap=cap, block_q=bq, interpret=pallas_interpret(interpret),
        )
    else:
        ids, valid, count, overflow = _pg.pred_gather(
            rows, index.offsets, index.words,
            bytes_per_pred=pmeta.bytes_per_pred, cap=cap, block_q=bq,
            interpret=pallas_interpret(interpret),
        )
    return ids[:q], valid[:q], count[:q], overflow[:q]


def sorted_intersect_mask(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    return _si.sorted_intersect_mask(a_ids, b_ids, interpret=pallas_interpret())


def block_spmm(mask: jax.Array, a: jax.Array, x: jax.Array, **kw) -> jax.Array:
    return _bs.block_spmm(mask, a, x, interpret=pallas_interpret(), **kw)
