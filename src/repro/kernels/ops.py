"""Public jit'd entry points for the kernel layer.

``INTERPRET`` flips every kernel into Pallas interpret mode — the CPU
correctness path used by this container (TPU is the compile target).  On a
real TPU backend set ``REPRO_PALLAS_INTERPRET=0`` (the default there).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.k2tree import K2Meta, K2Tree
from repro.kernels import block_spmm as _bs
from repro.kernels import k2_check as _kc
from repro.kernels import popcount as _pc
from repro.kernels import sorted_intersect as _si

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0" and (
    jax.default_backend() != "tpu"
)


def popcount(words: jax.Array, *, block_m: int = 8) -> jax.Array:
    return _pc.popcount_2d(words, block_m=block_m, interpret=INTERPRET)


def k2_check_tree(
    meta: K2Meta, tree: K2Tree, rows: jax.Array, cols: jax.Array, *, block_q: int = 1024
) -> jax.Array:
    """Kernel-backed version of core.k2tree.check (single tree)."""
    q = rows.shape[0]
    pad = (-q) % block_q
    if pad:
        rows = jnp.pad(rows, (0, pad))
        cols = jnp.pad(cols, (0, pad))
    out = _kc.k2_check(
        meta, rows, cols, tree.t.words, tree.t.rank_blocks, tree.l.words,
        tree.ones_before, tree.level_start, block_q=block_q, interpret=INTERPRET,
    )
    return out[:q]


def sorted_intersect_mask(a_ids: jax.Array, b_ids: jax.Array) -> jax.Array:
    return _si.sorted_intersect_mask(a_ids, b_ids, interpret=INTERPRET)


def block_spmm(mask: jax.Array, a: jax.Array, x: jax.Array, **kw) -> jax.Array:
    return _bs.block_spmm(mask, a, x, interpret=INTERPRET, **kw)
