"""Pallas TPU kernel: k²-masked block-sparse matmul (the k² → MXU bridge).

Beyond-paper feature.  The upper levels of a k²-tree are a hierarchical
block-occupancy bitmap of the adjacency matrix: a 0 at level ℓ certifies an
empty (side/k^ℓ)² region.  This kernel consumes one such level, re-tiled to
the MXU blocking, and computes

    Y[M, D] = A[M, K] @ X[K, D]      skipping tiles where mask[mi, ki] == 0

so the paper's "elide empty regions" idea moves from *space* into *compute*:
dense-block aggregation for GNN message passing (GraphCast mesh hops, EGNN /
MACE neighborhoods) never feeds the MXU an all-zero tile.

Blocking: (BM, BK) × (BK, BD) MXU tiles, grid (M/BM, D/BD, K/BK) with the K
dimension innermost ("arbitrary") accumulating into the output block, which
Pallas keeps VMEM-resident across the K sweep.  ``@pl.when`` guards both the
zero-init (k==0) and the matmul (mask≠0) — a masked-off tile costs one VMEM
mask read, no HBM traffic for A's tile (its BlockSpec index still walks, but
Mosaic elides loads of unused refs inside the false branch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mask_ref, a_ref, x_ref, y_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(mask_ref[0, 0] != 0)
    def _mm():
        y_ref[...] += jnp.dot(
            a_ref[...], x_ref[...], preferred_element_type=jnp.float32
        )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "block_d", "interpret")
)
def block_spmm(
    mask: jax.Array,  # int32[M/BM, K/BK] tile-occupancy (k²-level derived)
    a: jax.Array,  # [M, K] adjacency (bf16/f32 0-1) or weighted adjacency
    x: jax.Array,  # [K, D] features
    *,
    block_m: int = 128,
    block_k: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, kk = a.shape
    k2, d = x.shape
    assert kk == k2
    assert m % block_m == 0 and kk % block_k == 0 and d % block_d == 0
    assert mask.shape == (m // block_m, kk // block_k), mask.shape
    grid = (m // block_m, d // block_d, kk // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(mask, a, x)


def mask_from_k2_level(
    level_bits_dense: jax.Array, side: int, block: int
) -> jax.Array:
    """Re-tile a k²-tree level's dense occupancy square to MXU blocking.

    ``level_bits_dense`` is the (side_l, side_l) 0/1 occupancy at some tree
    level (each cell certifies a (side/side_l)² region).  Returns an
    int32[side/block, side/block] tile mask: tile ON iff any covering k²
    region is ON.  Exact when block divides the region size (128-aligned
    levels); conservative (never false-empty) otherwise.
    """
    side_l = level_bits_dense.shape[0]
    region = side // side_l
    nb = side // block
    if region >= block:
        rep = region // block
        m = jnp.repeat(jnp.repeat(level_bits_dense, rep, 0), rep, 1)
        return m.astype(jnp.int32)
    # region < block: OR-reduce regions into tiles
    g = block // region
    m = level_bits_dense.reshape(nb, g, nb, g).max(axis=(1, 3))
    return m.astype(jnp.int32)
