"""Pallas TPU kernel: ragged candidate-predicate gather (the SP/OP index read).

The pruned unbounded-``?P`` path (``core/predindex.scan_pruned_batch``) first
expands every query into its candidate predicate list — a ragged CSR gather
that this kernel phrases as a fixed-shape ``(BQ, L)`` launch layout: lane
``(q, j)`` holds the j-th predicate of query q's entity row, ready to feed
the flat ``(query, pred)`` grid of the batched ``k2_scan`` kernel.

Per grid step (one ``(BQ,)`` block of entity rows) with the whole index
arena (``offsets`` + byte-packed ``words``) VMEM-resident — the index is a
few bytes per distinct (s,p)/(o,p) pair, far smaller than the forest:

    start  = offsets[row]            deg = offsets[row + 1] - start
    elem   = start + j                              (j = 0 .. L-1)
    word   = words[(elem * bpp) >> 2]               (1-D dynamic gather)
    pred   = (word >> (8 * ((elem * bpp) & 3))) & ((1 << 8*bpp) - 1)

``bytes_per_pred`` ∈ {1, 2, 4} divides the word size, so an entry never
straddles a word.  Outputs follow the ``QueryResult`` contract: ``ids``
(0-based predicate ids, ascending — the lists are stored sorted), prefix
``valid`` mask, ``count`` = min(deg, L), ``overflow`` = deg > L.  Bit-exact
against ``ref.pred_gather_ref`` and ``predindex._gather_traced``
(tests/test_pred_gather.py).

``pred_gather_dac`` is the same launch layout over the DAC(b=8) layout
(``predindex`` ``layout="dac"``), decoding the compressed index entirely
on device:

    1. row pointers: ``start = anchors[row / RB] + Σ_{k < row mod RB}
       deg[k]`` — the packed ``deg_width``-bit degrees of one block span
       exactly 4 uint32 words, so the sum is a statically unrolled masked
       SWAR loop; ``deg`` itself is one more gather + shift + mask.
    2. chunk decode: lane j reads level-0 byte ``start + j``; while the
       level's continuation flag is set, the flag's in-level rank
       (``frank[word] + popcount(word & below)``) is the lane's position
       in the next level's byte stream, whose chunk ors in at bits 8·l.
    3. gaps → ids: an in-kernel log-doubling prefix sum over the lane
       axis turns the recovered gaps back into ascending 0-based
       predicate ids (first gap is id+1, so the running sum minus 1).

Bit-exact against ``ref.pred_gather_dac_ref`` (vectorized jnp with
``jnp.cumsum`` — an independent implementation) and the fixed-width
baseline on the same store (tests/test_pred_gather.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(bytes_per_pred: int, cap: int):
    mask_val = (1 << (8 * bytes_per_pred)) - 1 if bytes_per_pred < 4 else 0xFFFFFFFF

    def kernel(rows_ref, offsets_ref, words_ref,
               ids_ref, valid_ref, count_ref, ovf_ref):
        mask = jnp.uint32(mask_val)
        rows = rows_ref[...]
        offsets = offsets_ref[...]
        words = words_ref[...]
        start = offsets[rows]
        deg = offsets[rows + 1] - start
        lane = jnp.arange(cap, dtype=jnp.int32)[None, :]
        n = jnp.minimum(deg, cap)
        valid = lane < n[:, None]
        elem = jnp.where(valid, start[:, None] + lane, 0)
        bidx = elem * bytes_per_pred
        word = words[jnp.clip(bidx >> 2, 0, words.shape[0] - 1)]
        shift = ((bidx & 3) * 8).astype(jnp.uint32)
        pred = ((word >> shift) & mask).astype(jnp.int32)
        ids_ref[...] = jnp.where(valid, pred, 0)
        valid_ref[...] = valid
        count_ref[...] = n.astype(jnp.int32)
        ovf_ref[...] = deg > cap

    return kernel


@functools.partial(
    jax.jit, static_argnames=("bytes_per_pred", "cap", "block_q", "interpret")
)
def pred_gather(
    rows: jax.Array,
    offsets: jax.Array,
    words: jax.Array,
    *,
    bytes_per_pred: int,
    cap: int,
    block_q: int = 256,
    interpret: bool = False,
):
    """Batched CSR predicate-list gather.

    Returns ``(ids, valid, count, overflow)`` with shapes
    ``(Q, cap) / (Q, cap) / (Q,) / (Q,)``.  Q must divide by block_q;
    ``rows`` must be pre-clipped to ``[0, len(offsets) - 2]``.
    """
    (q,) = rows.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    qvec = pl.BlockSpec((block_q,), lambda i: (i,))
    qmat = pl.BlockSpec((block_q, cap), lambda i: (i, 0))
    return pl.pallas_call(
        _make_kernel(bytes_per_pred, cap),
        grid=grid,
        in_specs=[qvec, whole(offsets), whole(words)],
        out_specs=(qmat, qmat, qvec, qvec),
        out_shape=(
            jax.ShapeDtypeStruct((q, cap), jnp.int32),
            jax.ShapeDtypeStruct((q, cap), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.bool_),
        ),
        interpret=interpret,
    )(rows.astype(jnp.int32), offsets, words)


def _popcount32(w: jax.Array) -> jax.Array:
    """SWAR popcount of uint32 lanes -> int32 (no population_count dep)."""
    w = w - ((w >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    w = (w + (w >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((w * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _make_dac_kernel(
    cap: int,
    levels: int,
    level_byte_start: tuple,
    flag_word_start: tuple,
    deg_width: int,
    rows_per_block: int,
):
    per_word = 32 // deg_width
    dmask_val = (1 << deg_width) - 1 if deg_width < 32 else 0xFFFFFFFF

    def kernel(rows_ref, anchors_ref, words_ref, degs_ref, flags_ref,
               frank_ref, ids_ref, valid_ref, count_ref, ovf_ref):
        dmask = jnp.uint32(dmask_val)
        rows = rows_ref[...]
        anchors = anchors_ref[...]
        words = words_ref[...]
        degs = degs_ref[...]
        flags = flags_ref[...]
        frank = frank_ref[...]

        block = rows // rows_per_block
        within = rows % rows_per_block
        w0 = block * 4
        start = anchors[jnp.clip(block, 0, anchors.shape[0] - 1)]
        # masked SWAR sum of the degrees before `within` inside the block:
        # static unroll over the block's 4 packed words x per_word lanes
        for k in range(4):
            dword = degs[jnp.clip(w0 + k, 0, degs.shape[0] - 1)]
            for j in range(per_word):
                idx = k * per_word + j
                dv = ((dword >> jnp.uint32(j * deg_width)) & dmask).astype(
                    jnp.int32
                )
                start = start + dv * (idx < within).astype(jnp.int32)
        dword = degs[jnp.clip(w0 + within // per_word, 0, degs.shape[0] - 1)]
        dsh = ((within % per_word) * deg_width).astype(jnp.uint32)
        deg = ((dword >> dsh) & dmask).astype(jnp.int32)

        def byte_at(bidx):
            w = words[jnp.clip(bidx >> 2, 0, words.shape[0] - 1)]
            return ((w >> ((bidx & 3) * 8).astype(jnp.uint32))
                    & jnp.uint32(0xFF)).astype(jnp.int32)

        lane = jnp.arange(cap, dtype=jnp.int32)[None, :]
        n = jnp.minimum(deg, cap)
        valid = lane < n[:, None]
        pos = jnp.where(valid, start[:, None] + lane, 0)
        gap = byte_at(level_byte_start[0] + pos)
        alive = valid
        for lvl in range(levels - 1):
            fidx = jnp.clip(
                flag_word_start[lvl] + (pos >> 5), 0, flags.shape[0] - 1
            )
            fword = flags[fidx]
            sh = (pos & 31).astype(jnp.uint32)
            bit = ((fword >> sh) & jnp.uint32(1)) == 1
            low = fword & ((jnp.uint32(1) << sh) - jnp.uint32(1))
            rank = frank[fidx] + _popcount32(low)
            alive = alive & bit
            pos = jnp.where(alive, rank, 0)
            chunk = byte_at(level_byte_start[lvl + 1] + pos)
            gap = gap + jnp.where(alive, chunk << (8 * (lvl + 1)), 0)

        # log-doubling inclusive prefix sum along the lane axis (the
        # Pallas-side independent implementation vs the ref's jnp.cumsum)
        acc = jnp.where(valid, gap, 0)
        d = 1
        while d < cap:
            shifted = jnp.where(lane >= d, jnp.roll(acc, d, axis=1), 0)
            acc = acc + shifted
            d *= 2
        preds = acc - 1
        ids_ref[...] = jnp.where(valid, preds, 0)
        valid_ref[...] = valid
        count_ref[...] = n.astype(jnp.int32)
        ovf_ref[...] = deg > cap

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "levels", "level_byte_start", "flag_word_start", "deg_width",
        "rows_per_block", "cap", "block_q", "interpret",
    ),
)
def pred_gather_dac(
    rows: jax.Array,
    anchors: jax.Array,
    words: jax.Array,
    degs: jax.Array,
    flags: jax.Array,
    frank: jax.Array,
    *,
    levels: int,
    level_byte_start: tuple,
    flag_word_start: tuple,
    deg_width: int,
    rows_per_block: int,
    cap: int,
    block_q: int = 256,
    interpret: bool = False,
):
    """Batched DAC(b=8) predicate-list gather + on-device decode.

    Returns ``(ids, valid, count, overflow)`` with shapes
    ``(Q, cap) / (Q, cap) / (Q,) / (Q,)``.  Q must divide by block_q;
    ``rows`` must be pre-clipped to ``[0, n_rows - 1]``.
    """
    (q,) = rows.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    qvec = pl.BlockSpec((block_q,), lambda i: (i,))
    qmat = pl.BlockSpec((block_q, cap), lambda i: (i, 0))
    return pl.pallas_call(
        _make_dac_kernel(
            cap, levels, level_byte_start, flag_word_start, deg_width,
            rows_per_block,
        ),
        grid=grid,
        in_specs=[qvec, whole(anchors), whole(words), whole(degs),
                  whole(flags), whole(frank)],
        out_specs=(qmat, qmat, qvec, qvec),
        out_shape=(
            jax.ShapeDtypeStruct((q, cap), jnp.int32),
            jax.ShapeDtypeStruct((q, cap), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.bool_),
        ),
        interpret=interpret,
    )(rows.astype(jnp.int32), anchors, words, degs, flags, frank)
