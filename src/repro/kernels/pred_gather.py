"""Pallas TPU kernel: ragged candidate-predicate gather (the SP/OP index read).

The pruned unbounded-``?P`` path (``core/predindex.scan_pruned_batch``) first
expands every query into its candidate predicate list — a ragged CSR gather
that this kernel phrases as a fixed-shape ``(BQ, L)`` launch layout: lane
``(q, j)`` holds the j-th predicate of query q's entity row, ready to feed
the flat ``(query, pred)`` grid of the batched ``k2_scan`` kernel.

Per grid step (one ``(BQ,)`` block of entity rows) with the whole index
arena (``offsets`` + byte-packed ``words``) VMEM-resident — the index is a
few bytes per distinct (s,p)/(o,p) pair, far smaller than the forest:

    start  = offsets[row]            deg = offsets[row + 1] - start
    elem   = start + j                              (j = 0 .. L-1)
    word   = words[(elem * bpp) >> 2]               (1-D dynamic gather)
    pred   = (word >> (8 * ((elem * bpp) & 3))) & ((1 << 8*bpp) - 1)

``bytes_per_pred`` ∈ {1, 2, 4} divides the word size, so an entry never
straddles a word.  Outputs follow the ``QueryResult`` contract: ``ids``
(0-based predicate ids, ascending — the lists are stored sorted), prefix
``valid`` mask, ``count`` = min(deg, L), ``overflow`` = deg > L.  Bit-exact
against ``ref.pred_gather_ref`` and ``predindex._gather_traced``
(tests/test_pred_gather.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(bytes_per_pred: int, cap: int):
    mask_val = (1 << (8 * bytes_per_pred)) - 1 if bytes_per_pred < 4 else 0xFFFFFFFF

    def kernel(rows_ref, offsets_ref, words_ref,
               ids_ref, valid_ref, count_ref, ovf_ref):
        mask = jnp.uint32(mask_val)
        rows = rows_ref[...]
        offsets = offsets_ref[...]
        words = words_ref[...]
        start = offsets[rows]
        deg = offsets[rows + 1] - start
        lane = jnp.arange(cap, dtype=jnp.int32)[None, :]
        n = jnp.minimum(deg, cap)
        valid = lane < n[:, None]
        elem = jnp.where(valid, start[:, None] + lane, 0)
        bidx = elem * bytes_per_pred
        word = words[jnp.clip(bidx >> 2, 0, words.shape[0] - 1)]
        shift = ((bidx & 3) * 8).astype(jnp.uint32)
        pred = ((word >> shift) & mask).astype(jnp.int32)
        ids_ref[...] = jnp.where(valid, pred, 0)
        valid_ref[...] = valid
        count_ref[...] = n.astype(jnp.int32)
        ovf_ref[...] = deg > cap

    return kernel


@functools.partial(
    jax.jit, static_argnames=("bytes_per_pred", "cap", "block_q", "interpret")
)
def pred_gather(
    rows: jax.Array,
    offsets: jax.Array,
    words: jax.Array,
    *,
    bytes_per_pred: int,
    cap: int,
    block_q: int = 256,
    interpret: bool = False,
):
    """Batched CSR predicate-list gather.

    Returns ``(ids, valid, count, overflow)`` with shapes
    ``(Q, cap) / (Q, cap) / (Q,) / (Q,)``.  Q must divide by block_q;
    ``rows`` must be pre-clipped to ``[0, len(offsets) - 2]``.
    """
    (q,) = rows.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    qvec = pl.BlockSpec((block_q,), lambda i: (i,))
    qmat = pl.BlockSpec((block_q, cap), lambda i: (i, 0))
    return pl.pallas_call(
        _make_kernel(bytes_per_pred, cap),
        grid=grid,
        in_specs=[qvec, whole(offsets), whole(words)],
        out_specs=(qmat, qmat, qvec, qvec),
        out_shape=(
            jax.ShapeDtypeStruct((q, cap), jnp.int32),
            jax.ShapeDtypeStruct((q, cap), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.bool_),
        ),
        interpret=interpret,
    )(rows.astype(jnp.int32), offsets, words)
