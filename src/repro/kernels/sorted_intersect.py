"""Pallas TPU kernel: sorted-list intersection (joins A–C hot loop).

The paper's merge-join intersects two ID-sorted result lists.  A sequential
two-pointer merge is hostile to the VPU, so the TPU formulation is a
**vectorized binary search**: every lane of A searches B (log₂|B| static
steps of gather + compare), then membership = (B[lo] == a).  Sentinel-padded
invalid lanes (int32 max) never match.

Grid: blocks of A lanes; B is whole-array VMEM resident (result lists are
capacity-bounded, cap ≤ 64k -> 256 KB — fits easily).  Output is the match
mask; compaction (cumsum scatter) stays in XLA where it fuses with the
downstream join logic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(cap_b: int):
    steps = max(1, math.ceil(math.log2(cap_b)))

    def kernel(a_ref, b_ref, out_ref):
        a = a_ref[...]
        b = b_ref[...]
        lo = jnp.zeros(a.shape, jnp.int32)
        hi = jnp.full(a.shape, cap_b, jnp.int32)  # search [lo, hi)
        for _ in range(steps):
            mid = (lo + hi) >> 1
            bm = jnp.take(b, mid, mode="clip")
            go_right = bm < a
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(go_right, hi, mid)
        hit = jnp.take(b, jnp.clip(lo, 0, cap_b - 1), mode="clip") == a
        out_ref[...] = hit & (a != jnp.int32(2**31 - 1))  # sentinel never matches

    return kernel


@functools.partial(jax.jit, static_argnames=("block_a", "interpret"))
def sorted_intersect_mask(
    a_ids: jax.Array,
    b_ids: jax.Array,
    *,
    block_a: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """mask[i] = a_ids[i] ∈ b_ids.  Both sentinel-padded ascending int32."""
    (ca,) = a_ids.shape
    (cb,) = b_ids.shape
    block_a = min(block_a, ca)
    assert ca % block_a == 0, (ca, block_a)
    return pl.pallas_call(
        _make_kernel(cb),
        grid=(ca // block_a,),
        in_specs=[
            pl.BlockSpec((block_a,), lambda i: (i,)),
            pl.BlockSpec((cb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_a,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ca,), jnp.bool_),
        interpret=interpret,
    )(a_ids, b_ids)
