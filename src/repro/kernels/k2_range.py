"""Pallas TPU kernel: batched k²-tree range scans (the (?S,P,?O) path).

Pair enumeration over whole matrices: one query lane = one predicate's tree,
and the traversal walks EVERY 1-node instead of a single row/column slab.
Each lane carries a frontier of up to ``cap`` nodes as ``(pos, rbase,
cbase)`` — tree bit position plus the node's row/column submatrix origin —
and per level expands by the full radix ``k²_{l+1}`` (vs the scan kernel's
``k`` free-axis children), so results come out in Morton (level-order)
sequence: the order the paper's DFS would emit.

Level 0 materializes ALL ``k0²`` root children, tests their bits, and only
then compacts into the ``cap`` frontier — overflow latches only when more
than ``cap`` root children are actually occupied.  (The original jnp
traversal truncated the root radix to ``cap`` *before* the bit test, so a
sparse matrix under a large root radix both falsely reported overflow and
silently dropped candidates; ``core/k2forest.range_scan`` is fixed to the
same compact-after-test semantics and is the differential reference.)

Outputs per lane: ``rows[cap] / cols[cap]`` (Morton-ordered pair
coordinates), ``valid[cap]``, ``count``, ``overflow``.  Bit-exact against
``ref.k2_range_ref`` and ``k2forest.range_scan_batch(backend="jnp")``;
validated with ``interpret=True`` against the numpy Morton-order oracle in
``tests/test_k2_range.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.k2tree import K2Meta

from repro.kernels.k2_scan import _bit_at, _compact_rows, _rank_at


def _pad_cols(width: int, cap: int, valid, *arrays):
    """Right-pad candidate columns with dead lanes so ``_compact_rows`` can
    always gather ``cap`` survivors (level-0 radix may be below cap)."""
    if width >= cap:
        return valid, arrays
    pad = [(0, 0), (0, cap - width)]
    return (
        jnp.pad(valid, pad),
        tuple(jnp.pad(a, pad) for a in arrays),
    )


def _traverse_range(meta: K2Meta, cap: int, preds,
                    t_words, t_rank, l_words, ones_before, level_start):
    """Level-synchronous full-matrix enumeration over (N,) predicate lanes.

    Returns ``(rows, cols, valid, count, overflow)`` with shapes
    ``(N, cap) ×3, (N,) ×2``.
    """
    H = meta.n_levels
    ks = meta.ks
    radices = meta.radices
    subsides = meta.subsides
    bq = preds.shape[0]

    # level 0: every root child, bit-tested BEFORE the frontier is capped
    k0, r0, sub0 = ks[0], radices[0], subsides[0]
    d0 = jnp.arange(r0, dtype=jnp.int32)[None, :]
    pos0 = jnp.broadcast_to(d0, (bq, r0)).astype(jnp.int32)
    rb0 = jnp.broadcast_to((d0 // k0) * sub0, (bq, r0)).astype(jnp.int32)
    cb0 = jnp.broadcast_to((d0 % k0) * sub0, (bq, r0)).astype(jnp.int32)
    words0 = l_words if H == 1 else t_words
    bit0 = _bit_at(words0, jnp.broadcast_to(preds[:, None], (bq, r0)), pos0)
    valid0, (pos0, rb0, cb0) = _pad_cols(r0, cap, bit0 == 1, pos0, rb0, cb0)
    valid, _, ovf, (pos, rbase, cbase) = _compact_rows(
        valid0, cap, pos0, rb0, cb0
    )
    overflow = ovf
    pos = jnp.where(valid, pos, 0)

    p2 = jnp.broadcast_to(preds[:, None], (bq, cap))
    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = ks[lvl + 1]
        r = radices[lvl + 1]
        sub = subsides[lvl + 1]
        j = _rank_at(t_words, t_rank, p2, pos) - ones_before[preds, lvl][:, None]
        child_base0 = level_start[preds, lvl + 1][:, None] + j * r
        d = jnp.arange(r, dtype=jnp.int32)[None, None, :]
        cpos = child_base0[:, :, None] + d
        crb = rbase[:, :, None] + (d // k) * sub
        ccb = cbase[:, :, None] + (d % k) * sub
        wordsc = l_words if last_child else t_words
        cpos_safe = jnp.where(valid[:, :, None], cpos, 0).reshape(bq, cap * r)
        cbit = _bit_at(wordsc, jnp.broadcast_to(preds[:, None], (bq, cap * r)),
                       cpos_safe)
        cvalid = valid[:, :, None].repeat(r, axis=2).reshape(bq, cap * r) & (cbit == 1)
        valid, _, ovf, (pos, rbase, cbase) = _compact_rows(
            cvalid, cap, cpos_safe,
            crb.reshape(bq, cap * r), ccb.reshape(bq, cap * r),
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (rows, cols) = _compact_rows(valid, cap, rbase, cbase)
    return rows, cols, valid, count, overflow | ovf


def _make_range_kernel(meta: K2Meta, cap: int):
    def kernel(preds_ref, t_words_ref, t_rank_ref, l_words_ref,
               ones_before_ref, level_start_ref,
               rows_ref, cols_ref, valid_ref, count_ref, ovf_ref):
        rows, cols, valid, count, ovf = _traverse_range(
            meta, cap, preds_ref[...],
            t_words_ref[...], t_rank_ref[...], l_words_ref[...],
            ones_before_ref[...], level_start_ref[...],
        )
        rows_ref[...] = rows
        cols_ref[...] = cols
        valid_ref[...] = valid
        count_ref[...] = count
        ovf_ref[...] = ovf

    return kernel


@functools.partial(
    jax.jit, static_argnames=("meta", "cap", "block_q", "interpret")
)
def k2_range(
    meta: K2Meta,
    preds: jax.Array,
    t_words: jax.Array,
    t_rank: jax.Array,
    l_words: jax.Array,
    ones_before: jax.Array,
    level_start: jax.Array,
    *,
    cap: int,
    block_q: int = 8,
    interpret: bool = False,
):
    """Batched full-matrix pair enumeration over a K2Forest arena.

    Returns ``(rows, cols, valid, count, overflow)`` with shapes
    ``(Q, cap) ×3, (Q,) ×2``.  Q must divide by block_q.
    """
    (q,) = preds.shape
    assert q % block_q == 0, (q, block_q)
    grid = (q // block_q,)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    qvec = pl.BlockSpec((block_q,), lambda i: (i,))
    qmat = pl.BlockSpec((block_q, cap), lambda i: (i, 0))
    return pl.pallas_call(
        _make_range_kernel(meta, cap),
        grid=grid,
        in_specs=[
            qvec,
            whole(t_words), whole(t_rank), whole(l_words),
            whole(ones_before), whole(level_start),
        ],
        out_specs=(qmat, qmat, qmat, qvec, qvec),
        out_shape=(
            jax.ShapeDtypeStruct((q, cap), jnp.int32),
            jax.ShapeDtypeStruct((q, cap), jnp.int32),
            jax.ShapeDtypeStruct((q, cap), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.bool_),
        ),
        interpret=interpret,
    )(preds.astype(jnp.int32),
      t_words, t_rank, l_words, ones_before, level_start)
