"""Pallas TPU kernel: packed-word popcount (the rank primitive's hot loop).

The k²-tree's ``rank1`` decomposes into a gather + ``popcount(word & mask)``;
bulk rank-directory (re)builds and bit-density stats reduce to popcount over
the whole word arena.  This kernel tiles the uint32 arena into (BM, 128)
VMEM blocks (lane dim = 128, the VPU width) and evaluates the classic
SWAR popcount entirely in registers.

TPU notes: integer SWAR ops (shift/and/mul) are native VPU int32 ops; one
(8,128) vreg tile per step.  No MXU use; this kernel is memory-bound by
design — it exists to keep rank rebuilds at HBM bandwidth instead of
scalar-core speed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BM = 8  # sublane dim of one vreg tile


def _popcount_swar(w: jax.Array) -> jax.Array:
    """Branch-free SWAR popcount on uint32 lanes."""
    w = w - ((w >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    w = (w + (w >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((w * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _popcount_kernel(words_ref, out_ref):
    out_ref[...] = _popcount_swar(words_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def popcount_2d(
    words: jax.Array, *, block_m: int = DEFAULT_BM, interpret: bool = False
) -> jax.Array:
    """Popcount of a (M, 128·k) uint32 arena -> int32 of the same shape."""
    m, n = words.shape
    assert n % LANES == 0, f"lane dim must be a multiple of {LANES}, got {n}"
    assert m % block_m == 0, f"rows {m} not divisible by block {block_m}"
    return pl.pallas_call(
        _popcount_kernel,
        grid=(m // block_m,),
        in_specs=[pl.BlockSpec((block_m, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(words)
