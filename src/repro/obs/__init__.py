"""repro.obs — tracing, metrics, and cost profiling for the serve stack.

One process-global observability state (``STATE``) holds an optional
:class:`~repro.obs.trace.Tracer` and an optional
:class:`~repro.obs.metrics.MetricsRegistry`.  Both default to ``None`` —
observability OFF — and every instrumentation site in the engine and the
broker guards on that ``None`` before doing anything: the disabled cost
of a site is one attribute read and one branch (tripwire-tested in
``tests/test_obs.py``, the same discipline PR 5 applied to env reads
inside compiled plan calls).

Enable with :func:`enable` (optionally with an
:class:`~repro.core.query.ObsConfig`), tear down with :func:`disable`::

    tracer, metrics = obs.enable()
    ...serve...
    json.dump(tracer.to_chrome(), fh)
    print(metrics.to_prometheus())
    obs.disable()

:func:`span` is the one-liner for instrumentation sites that just want a
context manager: it returns the shared no-op span when tracing is off.
"""

from __future__ import annotations

from repro.obs.metrics import (  # noqa: F401  (re-exports)
    Counter,
    DEFAULT_BUCKETS,
    Gauge,
    Histogram,
    LATENCY_MS_BUCKETS,
    MetricsRegistry,
    log_buckets,
)
from repro.obs.trace import NOOP_SPAN, Tracer  # noqa: F401

__all__ = [
    "STATE", "enable", "disable", "enabled", "span", "provenance",
    "Tracer", "NOOP_SPAN",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "log_buckets",
    "DEFAULT_BUCKETS", "LATENCY_MS_BUCKETS",
]


class _State:
    """Global observability switches.  ``None`` means OFF."""

    __slots__ = ("tracer", "metrics")

    def __init__(self):
        self.tracer: Tracer | None = None
        self.metrics: MetricsRegistry | None = None


STATE = _State()


def enabled() -> bool:
    return STATE.tracer is not None or STATE.metrics is not None


def enable(config=None):
    """Turn observability on; returns ``(tracer, metrics)``.

    ``config`` is an :class:`repro.core.query.ObsConfig` (imported lazily
    here — ``repro.core`` imports this package, not the other way round);
    ``None`` enables both tracing and metrics with defaults.  Either
    component can be ``None`` in the result if the config disabled it.
    """
    if config is None:
        from repro.core.query import ObsConfig

        config = ObsConfig()
    STATE.tracer = (
        Tracer(config.trace_capacity, annotate=config.device_annotations)
        if config.trace
        else None
    )
    STATE.metrics = MetricsRegistry() if config.metrics else None
    return STATE.tracer, STATE.metrics


def disable() -> None:
    """Turn observability off (instrumentation reverts to the no-op path)."""
    STATE.tracer = None
    STATE.metrics = None


def span(name: str, **attrs):
    """Context manager for one span; the shared no-op when tracing is off."""
    t = STATE.tracer
    return NOOP_SPAN if t is None else t.span(name, **attrs)


def provenance() -> dict:
    """Self-describing run header: git SHA, UTC timestamp, jax version,
    backend, device kind/count.  Embedded in benchmark JSON and trace
    exports so a committed number can always be tied back to the code and
    hardware that produced it.  Every field is best-effort."""
    import datetime

    out = {
        "utc": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
    }
    try:
        import os
        import subprocess

        # anchor git to the package's own checkout, not the process cwd
        here = os.path.dirname(os.path.abspath(__file__))
        out["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, check=True, cwd=here,
        ).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=5, check=True, cwd=here,
        ).stdout.strip()
        out["git_dirty"] = bool(dirty)
    except Exception:
        out["git_sha"] = None
    try:
        import jax

        devs = jax.devices()
        out["jax_version"] = jax.__version__
        out["jax_backend"] = jax.default_backend()
        out["device_kind"] = devs[0].device_kind if devs else None
        out["device_count"] = len(devs)
    except Exception as e:  # pragma: no cover - env-specific
        out["jax_error"] = f"{type(e).__name__}: {e}"
    return out
