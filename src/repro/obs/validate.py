"""Minimal Chrome ``trace_event`` schema validator.

CI's trace-smoke step runs a ``launch/serve.py --fast --trace`` pass and
then validates the emitted JSON here: non-empty, every event carries the
required keys, complete (``X``) spans have non-negative durations and
are well-nested per ``(pid, tid)`` track, and async ``b``/``e`` events
balance per ``(cat, id)``.  Usable as a library
(:func:`validate_chrome_trace` returns a list of problem strings) or as
a CLI::

    PYTHONPATH=src python -m repro.obs.validate serve_trace.json --require-queries

exiting non-zero when the trace is malformed (or, with
``--require-queries``, when it contains no per-query async spans).
"""

from __future__ import annotations

import json
import sys

_REQUIRED = ("name", "ph")


def validate_chrome_trace(obj, *, require_queries: bool = False) -> list[str]:
    """Return a list of problems (empty == valid)."""
    problems: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' array"]
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' must be a non-empty array"]

    tracks: dict = {}   # (pid, tid) -> [(ts, dur, i, name)] complete spans
    asyncs: dict = {}   # (cat, id) -> open-begin depth
    n_query_asyncs = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        for k in _REQUIRED:
            if k not in e:
                problems.append(f"event {i}: missing required key {k!r}")
        ph = e.get("ph")
        if ph == "M":
            continue  # metadata events carry no timestamp
        if "ts" not in e:
            problems.append(f"event {i}: missing required key 'ts'")
            continue
        ts = e["ts"]
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: 'ts' must be a number")
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: 'X' event needs numeric dur >= 0")
                continue
            key = (e.get("pid"), e.get("tid"))
            tracks.setdefault(key, []).append((ts, dur, i, e.get("name")))
        elif ph in ("b", "e"):
            key = (e.get("cat"), e.get("id"))
            if key[1] is None:
                problems.append(f"event {i}: async event missing 'id'")
                continue
            d = asyncs.get(key, 0)
            if ph == "b":
                asyncs[key] = d + 1
                if e.get("cat") == "query":
                    n_query_asyncs += 1
            else:
                if d <= 0:
                    problems.append(
                        f"event {i}: async 'e' for {key} with no open 'b'"
                    )
                else:
                    asyncs[key] = d - 1
        elif ph not in ("i", "I", "C", "s", "t", "f"):
            problems.append(f"event {i}: unsupported phase {ph!r}")
    # X events on one track must nest once sorted by start time (events
    # are recorded at span END, so file order is not timeline order —
    # Perfetto sorts by ts, and so do we; longer spans first on ties so
    # a parent precedes children that start at the same instant).
    # Tolerance: ts/dur are ns-resolution clocks exported in float µs, so
    # adjacent distinct instants differ by >= 1e-3 while double rounding
    # of ts + dur is ~ULP(ts) (4e-9 at µs-timestamps in the 1e8 range);
    # 1e-4 sits safely between the two.
    tol = 1e-4
    for key, spans in tracks.items():
        stack: list[float] = []  # end timestamps of enclosing spans
        for ts, dur, i, name in sorted(spans, key=lambda s: (s[0], -s[1])):
            while stack and ts >= stack[-1] - tol:
                stack.pop()
            if stack and ts + dur > stack[-1] + tol:
                problems.append(
                    f"event {i}: span {name!r} overlaps the enclosing "
                    f"span on track {key} without nesting"
                )
            stack.append(ts + dur)
    for key, depth in asyncs.items():
        if depth:
            problems.append(f"async {key}: {depth} unmatched 'b' event(s)")
    if require_queries and n_query_asyncs == 0:
        problems.append("no 'query'-category async spans found")
    return problems


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    require_queries = "--require-queries" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 1:
        print("usage: python -m repro.obs.validate PATH [--require-queries]",
              file=sys.stderr)
        return 2
    with open(paths[0]) as fh:
        obj = json.load(fh)
    problems = validate_chrome_trace(obj, require_queries=require_queries)
    if problems:
        for p in problems:
            print(f"TRACE INVALID: {p}", file=sys.stderr)
        return 1
    n = len(obj["traceEvents"])
    print(f"trace OK: {paths[0]} ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
