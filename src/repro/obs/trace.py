"""Low-overhead host-side span tracer with a Chrome ``trace_event`` export.

The serve stack's timeline instrument: a fixed-capacity ring buffer of
spans stamped with the monotonic clock (``time.perf_counter_ns`` — the
same clock base the broker's latency samples use), recorded either live
(``begin``/``end`` or the ``span`` context manager) or retroactively
(``add``/``add_async`` with explicit timestamps — how the broker emits
per-query phase spans at delivery time, when every timestamp of the
batch is known).

Design rules:

* **Disabled is free.**  The tracer only exists while observability is
  enabled (``repro.obs.enable``); every instrumentation site guards on
  ``obs.STATE.tracer is None`` — one attribute read and one branch, no
  tracer method calls, no allocation (``tests/test_obs.py`` tripwires
  this the same way ``test_no_env_read_inside_plan_call`` bans env reads
  in compiled plan calls).
* **Recording never blocks the serve path.**  A record is a dict append
  into a pre-sized ring under a (practically uncontended) lock; when the
  ring wraps, the OLDEST spans are dropped and counted (``dropped``) —
  tracing a long run degrades to a suffix window, never to back-pressure.
* **Hierarchy is time containment.**  Spans carry a track id (``tid`` —
  the thread id by default, or an explicit string track like
  ``"batch-slot-0"``); within a track, nesting is by interval
  containment, exactly the Chrome/Perfetto model, so no parent pointers
  are threaded through async hops.  Overlapping per-query lifetimes ride
  Chrome *async* events (``ph: "b"/"e"`` with an ``id``) instead, which
  Perfetto renders as per-id nested tracks.

The optional ``jax.profiler`` bridge (``annotate=True``) wraps every live
span in a ``jax.profiler.TraceAnnotation`` so a device profile captured
around the same run carries the same span names.
"""

from __future__ import annotations

import threading
import time

__all__ = ["Tracer", "NOOP_SPAN"]


class _NoopSpan:
    """The shared disabled-path context manager: no state, no effect."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _LiveSpan:
    """Handle for an open ``begin``/``end`` span."""

    __slots__ = ("name", "cat", "t0", "tid", "args", "ann")

    def __init__(self, name, cat, t0, tid, args, ann):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.tid = tid
        self.args = args
        self.ann = ann


class _SpanCM:
    __slots__ = ("tracer", "live", "name", "attrs")

    def __init__(self, tracer, name, attrs):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.live = None

    def __enter__(self):
        self.live = self.tracer.begin(self.name, **self.attrs)
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is not None:
            self.live.args = dict(self.live.args, error=exc_type.__name__)
        self.tracer.end(self.live)
        return False


class Tracer:
    """Ring-buffered hierarchical span recorder.

    All timestamps are ``time.perf_counter_ns`` integers (``Tracer.now``);
    retroactive ``add*`` callers holding ``time.perf_counter`` float
    seconds convert with ``int(t * 1e9)`` — same clock, same epoch.
    """

    def __init__(self, capacity: int = 1 << 16, *, annotate: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.annotate = annotate
        self._ring: list = [None] * capacity
        self._n = 0  # total records ever (ring cursor = _n % capacity)
        self._lock = threading.Lock()
        self.t_epoch = time.perf_counter_ns()
        self._profiler = None
        if annotate:
            import jax.profiler  # deferred: only the bridge needs it

            self._profiler = jax.profiler

    # -- recording ------------------------------------------------------

    @staticmethod
    def now() -> int:
        return time.perf_counter_ns()

    def _record(self, rec: dict) -> None:
        with self._lock:
            self._ring[self._n % self.capacity] = rec
            self._n += 1

    def begin(self, name: str, **attrs) -> _LiveSpan:
        """Open a live span on the current thread's track."""
        ann = None
        if self._profiler is not None:
            ann = self._profiler.TraceAnnotation(name)
            ann.__enter__()
        return _LiveSpan(
            name, attrs.pop("cat", ""), time.perf_counter_ns(),
            attrs.pop("tid", None), attrs, ann,
        )

    def end(self, live: _LiveSpan, **extra) -> None:
        t1 = time.perf_counter_ns()
        if live.ann is not None:
            live.ann.__exit__(None, None, None)
        args = dict(live.args, **extra) if extra else live.args
        self._record({
            "kind": "X", "name": live.name, "cat": live.cat,
            "t0": live.t0, "t1": t1,
            "tid": live.tid if live.tid is not None else threading.get_ident(),
            "args": args,
        })

    def span(self, name: str, **attrs) -> _SpanCM:
        """``with tracer.span("engine.compile", shape=...):`` — live span."""
        return _SpanCM(self, name, attrs)

    def add(self, name: str, t0: int, t1: int, *, tid=None, cat: str = "",
            **attrs) -> None:
        """Retroactive complete span with explicit ns timestamps."""
        self._record({
            "kind": "X", "name": name, "cat": cat, "t0": int(t0), "t1": int(t1),
            "tid": tid if tid is not None else threading.get_ident(),
            "args": attrs,
        })

    def add_async(self, name: str, aid, t0: int, t1: int, *,
                  cat: str = "query", **attrs) -> None:
        """Retroactive async (overlappable) span — one ``b``/``e`` pair
        under ``id=aid`` in the Chrome export.  Same-id slices nest by
        time, so per-query phase breakdowns share the query's id."""
        self._record({
            "kind": "async", "name": name, "cat": cat or "async",
            "id": aid, "t0": int(t0), "t1": int(t1), "tid": 0, "args": attrs,
        })

    def instant(self, name: str, *, tid=None, **attrs) -> None:
        t = time.perf_counter_ns()
        self._record({
            "kind": "I", "name": name, "cat": "", "t0": t, "t1": t,
            "tid": tid if tid is not None else threading.get_ident(),
            "args": attrs,
        })

    # -- inspection -----------------------------------------------------

    @property
    def dropped(self) -> int:
        """Spans overwritten by ring wrap (oldest-first)."""
        return max(0, self._n - self.capacity)

    def events(self) -> list[dict]:
        """Retained records, oldest first."""
        with self._lock:
            n = self._n
            if n <= self.capacity:
                out = self._ring[:n]
            else:
                cur = n % self.capacity
                out = self._ring[cur:] + self._ring[:cur]
            return list(out)

    def clear(self) -> None:
        """Drop everything recorded so far (the warmup boundary)."""
        with self._lock:
            self._ring = [None] * self.capacity
            self._n = 0
            self.t_epoch = time.perf_counter_ns()

    # -- Chrome trace_event export --------------------------------------

    def to_chrome(self, *, metadata: dict | None = None) -> dict:
        """The Perfetto-loadable ``{"traceEvents": [...]}`` object.

        Complete spans become ``ph: "X"`` events nested by time per
        track; async records become ``ph: "b"``/``"e"`` pairs; string
        track ids are mapped to integer tids with ``thread_name``
        metadata so Perfetto shows readable track names.
        """
        events = self.events()
        t_base = min((e["t0"] for e in events), default=self.t_epoch)
        tids: dict = {}

        def tid_of(raw):
            if raw not in tids:
                tids[raw] = len(tids) + 1
            return tids[raw]

        out = []
        for e in events:
            ts = (e["t0"] - t_base) / 1e3  # us
            args = {k: _jsonable(v) for k, v in e["args"].items()}
            if e["kind"] == "X":
                out.append({
                    "ph": "X", "name": e["name"], "cat": e["cat"] or "span",
                    "ts": ts, "dur": max(0.0, (e["t1"] - e["t0"]) / 1e3),
                    "pid": 1, "tid": tid_of(e["tid"]), "args": args,
                })
            elif e["kind"] == "async":
                common = {
                    "name": e["name"], "cat": e["cat"], "id": str(e["id"]),
                    "pid": 1, "tid": 0,
                }
                out.append({"ph": "b", "ts": ts, "args": args, **common})
                out.append({
                    "ph": "e", "ts": (e["t1"] - t_base) / 1e3, **common,
                })
            else:  # instant
                out.append({
                    "ph": "i", "name": e["name"], "cat": e["cat"] or "span",
                    "ts": ts, "s": "t", "pid": 1, "tid": tid_of(e["tid"]),
                    "args": args,
                })
        for raw, tid in tids.items():
            out.append({
                "ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                "args": {"name": raw if isinstance(raw, str) else f"thread-{raw}"},
            })
        trace = {"traceEvents": out, "displayTimeUnit": "ms"}
        if self.dropped:
            trace["droppedSpans"] = self.dropped
        if metadata:
            trace["otherData"] = {k: _jsonable(v) for k, v in metadata.items()}
        return trace


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
