"""Static per-plan cost profiles: what a compiled serve program *should*
cost, captured once from the XLA compiler's own accounting.

A trace tells you where a query's milliseconds went; a cost profile tells
you what the program underneath was built to do — compiled-program FLOPs
and bytes (``Compiled.cost_analysis``), live-buffer/device-memory stats
(``Compiled.memory_analysis``), and the lanes × cap geometry the engine
chose.  Joining the two answers the questions the paper's evaluation asks
quantitatively: is a slow batch arithmetic-bound, transfer-bound, or just
padded to a wasteful geometry?

Profiles are plain JSON-ready dicts.  Every field that depends on a
backend-specific analysis is best-effort: a backend that cannot produce
it yields an ``*_error`` string instead of crashing the serve path —
profiling must never be the thing that takes serving down.
"""

from __future__ import annotations

__all__ = ["profile_compiled", "profile_jit"]


def profile_jit(fn, args, geometry: dict | None = None) -> dict:
    """AOT-lower ``fn`` on ``args`` and profile the compiled program.

    ``fn`` is a ``jax.jit`` wrapper; this compiles through the jit cache's
    AOT path (``fn.lower(*args).compile()``), so the profile reflects
    exactly the program geometry the given arguments select.
    """
    return profile_compiled(fn.lower(*args).compile(), geometry)


def profile_compiled(compiled, geometry: dict | None = None) -> dict:
    """Extract the static cost profile of one ``jax`` ``Compiled``."""
    out: dict = {"geometry": dict(geometry or {})}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        ca = dict(ca or {})
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        if "transcendentals" in ca:
            out["transcendentals"] = float(ca["transcendentals"])
        if out["bytes_accessed"] > 0:
            out["arithmetic_intensity"] = out["flops"] / out["bytes_accessed"]
    except Exception as e:  # pragma: no cover - backend-specific
        out["cost_analysis_error"] = f"{type(e).__name__}: {e}"
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            out["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "generated_code_bytes": int(ma.generated_code_size_in_bytes),
            }
            out["memory"]["live_bytes"] = (
                out["memory"]["argument_bytes"]
                + out["memory"]["output_bytes"]
                + out["memory"]["temp_bytes"]
            )
    except Exception as e:  # pragma: no cover - backend-specific
        out["memory_analysis_error"] = f"{type(e).__name__}: {e}"
    return out
