"""Typed metrics registry: counters, gauges, histograms with fixed
log-spaced buckets, a JSON snapshot, and a Prometheus-style exposition.

This replaces ad-hoc ``collections.Counter`` stat dicts across the serve
stack: metrics are declared once by name, typed (re-registering a name as
a different kind raises), thread-safe (one registry lock — serve decode
runs off-loop in a worker thread), and resettable as a unit
(``registry.reset()`` — the broker's warmup boundary).

Histogram buckets are FIXED and log-spaced (``log_buckets``): bucket
geometry never adapts to data, so two snapshots — or two processes — are
always mergeable bucket-by-bucket, the property Prometheus histograms are
built on.  ``Histogram.percentile`` gives the standard
interpolated-within-bucket estimate for quick reads; exact tails stay
with the broker's sample lists (``tail_percentile``).
"""

from __future__ import annotations

import bisect
import math
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "log_buckets",
    "DEFAULT_BUCKETS", "LATENCY_MS_BUCKETS",
]


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds covering ``[lo, hi]``.

    ``per_decade`` bounds per factor-of-10, snapped to exact decade
    multiples so bucket edges are stable, human-readable values
    (1, 2.15, 4.64, 10, ... for ``per_decade=3``).
    """
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    if per_decade < 1:
        raise ValueError("per_decade must be >= 1")
    d0 = math.floor(math.log10(lo) * per_decade)
    d1 = math.ceil(math.log10(hi) * per_decade)
    return tuple(round(10.0 ** (i / per_decade), 12) for i in range(d0, d1 + 1))


# general-purpose default: 1e-6 .. 1e3 (covers ns..ks in seconds, B..GB, ...)
DEFAULT_BUCKETS = log_buckets(1e-6, 1e3, per_decade=3)
# per-query serve latency in milliseconds: 1 us .. 100 s
LATENCY_MS_BUCKETS = log_buckets(1e-3, 1e5, per_decade=3)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._v = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v

    def _reset(self) -> None:
        self._v = 0

    def _snapshot(self) -> dict:
        return {"type": "counter", "value": self._v}


class Gauge:
    """Last-written value."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._v = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    @property
    def value(self) -> float:
        return self._v

    def _reset(self) -> None:
        self._v = 0.0

    def _snapshot(self) -> dict:
        return {"type": "gauge", "value": self._v}


class Histogram:
    """Fixed-bucket histogram: counts per log-spaced bucket + sum/min/max.

    ``bounds[i]`` is the INCLUSIVE upper edge of bucket ``i``; one
    overflow bucket catches everything above the last bound.
    """

    __slots__ = ("name", "bounds", "_counts", "_sum", "_count", "_min",
                 "_max", "_lock")

    def __init__(self, name: str, bounds: tuple[float, ...],
                 lock: threading.Lock):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError("bounds must be a non-empty ascending sequence")
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf
        self._lock = lock

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float | None:
        """Bucket-interpolated quantile estimate (``None`` when empty)."""
        if not 0 <= q <= 100:
            raise ValueError(f"q must be in [0, 100], got {q}")
        if self._count == 0:
            return None
        target = self._count * q / 100.0
        acc = 0
        for i, c in enumerate(self._counts):
            if acc + c >= target and c:
                lo = self.bounds[i - 1] if i > 0 else min(self._min, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self._max
                frac = (target - acc) / c
                return min(max(lo + (hi - lo) * frac, self._min), self._max)
            acc += c
        return self._max

    def _reset(self) -> None:
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf

    def _snapshot(self) -> dict:
        return {
            "type": "histogram",
            "count": self._count,
            "sum": self._sum,
            "min": None if self._count == 0 else self._min,
            "max": None if self._count == 0 else self._max,
            "buckets": {
                ("+Inf" if i == len(self.bounds) else repr(self.bounds[i])): c
                for i, c in enumerate(self._counts)
                if c
            },
        }


class MetricsRegistry:
    """Named, typed metric store.

    ``counter``/``gauge``/``histogram`` create-or-return by name — a name
    registered as one kind can never silently come back as another.
    ``snapshot()`` is the JSON-ready view; ``to_prometheus()`` the text
    exposition; ``reset()`` zeroes every metric in place (registered
    metric objects stay valid — callers may hold them)."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind, factory):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = factory()
        if not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, "
                f"not a {kind.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name, self._lock))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name, self._lock))

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        """First registration fixes the buckets; later calls return the
        existing histogram (their ``buckets`` argument is ignored)."""
        return self._get(
            name, Histogram, lambda: Histogram(name, buckets, self._lock)
        )

    def reset(self) -> None:
        with self._lock:
            for m in self._metrics.values():
                m._reset()

    def snapshot(self) -> dict:
        """``{name: {"type": ..., ...}}`` sorted by name."""
        with self._lock:
            return {
                name: self._metrics[name]._snapshot()
                for name in sorted(self._metrics)
            }

    def to_prometheus(self) -> str:
        """Prometheus text exposition (names sanitized ``.`` -> ``_``)."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pn = _prom_name(name)
            if isinstance(m, Counter):
                lines += [f"# TYPE {pn} counter", f"{pn} {m.value}"]
            elif isinstance(m, Gauge):
                lines += [f"# TYPE {pn} gauge", f"{pn} {_prom_num(m.value)}"]
            else:
                lines.append(f"# TYPE {pn} histogram")
                acc = 0
                for i, b in enumerate(m.bounds):
                    acc += m._counts[i]
                    lines.append(f'{pn}_bucket{{le="{_prom_num(b)}"}} {acc}')
                acc += m._counts[-1]
                lines.append(f'{pn}_bucket{{le="+Inf"}} {acc}')
                lines.append(f"{pn}_sum {_prom_num(m.sum)}")
                lines.append(f"{pn}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_num(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))
