"""Version-compat shims for the supported jax range.

``shard_map`` moved between namespaces across jax releases: 0.4.x exposes
only ``jax.experimental.shard_map.shard_map`` (with a ``check_rep`` flag);
newer releases promote it to ``jax.shard_map`` (flag renamed ``check_vma``)
and deprecate the experimental alias.  Import it from here so every caller
works on both — either flag spelling is accepted and translated:

    from repro.compat import shard_map
"""

from __future__ import annotations

import inspect

import jax

try:  # newer jax: promoted to the top-level namespace
    _impl = jax.shard_map
except AttributeError:  # jax 0.4.x: experimental namespace only
    from jax.experimental.shard_map import shard_map as _impl

_IMPL_PARAMS = inspect.signature(_impl).parameters


def shard_map(f, *, mesh, in_specs, out_specs,
              check_rep=None, check_vma=None, **kw):
    """Drop-in ``shard_map`` accepting both the old and new replication flag."""
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None:
        key = "check_vma" if "check_vma" in _IMPL_PARAMS else "check_rep"
        kw[key] = flag
    return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


__all__ = ["shard_map"]
