"""MACE — higher-order equivariant message passing (arXiv:2206.07697).

The ACE construction, per layer:

  A-basis  (one-particle):  A_i^{(c, l3 m3)} = Σ_j Σ_{l1 l2} R^{l1l2l3}_c(r_ij)
                             · CG^{l1 l2 l3}_{m1 m2 m3} Y_{l1 m1}(r̂_ij) X_j^{(c, l2 m2)}
  B-basis  (correlation ν): symmetric CG products of A with itself up to
                             correlation_order (assigned: 3)
  message:  m_i = Σ_paths W_path · B_path;   X' = Lin(m) + Lin_species(X)
  readout:  site energies from the l=0 channels, summed per graph.

CG coefficients, SH and all coupling paths come from ``so3`` (exact,
host-precomputed); device work is dense einsums + one segment_sum per layer
— the "irrep tensor-product" kernel regime of the taxonomy.  Assigned
config: n_layers=2, d_hidden=128 channels, l_max=2, ν=3, n_rbf=8.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import common as C
from repro.models.gnn import so3


@dataclasses.dataclass(frozen=True)
class MACECfg:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128  # channels
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 32
    out_dim: int = 1  # site-energy readout
    # remat trades memory for re-gathered halo exchanges in the backward —
    # a LOSS for full-batch giant graphs (collective-bound); builder-controlled
    remat: bool = True


@lru_cache(maxsize=None)
def a_paths(l_max: int) -> tuple[tuple[int, int, int], ...]:
    """(l_sh, l_node, l_out) triples for the A-basis."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l_max, l1 + l2) + 1):
                out.append((l1, l2, l3))
    return tuple(out)


@lru_cache(maxsize=None)
def b2_paths(l_max: int) -> tuple[tuple[int, int, int], ...]:
    """(la, lb, lout) with la <= lb (symmetric) for correlation-2 products."""
    out = []
    for la in range(l_max + 1):
        for lb in range(la, l_max + 1):
            for lo in range(abs(la - lb), min(l_max, la + lb) + 1):
                out.append((la, lb, lo))
    return tuple(out)


@lru_cache(maxsize=None)
def b3_paths(l_max: int) -> tuple[tuple[int, int, int, int, int], ...]:
    """((la, lb)->lab, lc)->lout chains for correlation-3 products."""
    out = []
    for (la, lb, lab) in b2_paths(l_max):
        for lc in range(l_max + 1):
            for lo in range(abs(lab - lc), min(l_max, lab + lc) + 1):
                out.append((la, lb, lab, lc, lo))
    return tuple(out)


def param_specs(cfg: MACECfg):
    Cn, dim = cfg.d_hidden, so3.irrep_dim(cfg.l_max)
    nA, nB2, nB3 = len(a_paths(cfg.l_max)), len(b2_paths(cfg.l_max)), len(b3_paths(cfg.l_max))
    lay = []
    for _ in range(cfg.n_layers):
        lay.append({
            "radial": C.mlp_specs([cfg.n_rbf, 64, nA * Cn]),
            "w_b1": jax.ShapeDtypeStruct((Cn, Cn), jnp.float32),
            "w_b2": jax.ShapeDtypeStruct((nB2, Cn, Cn), jnp.float32),
            "w_b3": jax.ShapeDtypeStruct((nB3, Cn, Cn), jnp.float32),
            "w_res": jax.ShapeDtypeStruct((cfg.n_species, Cn, Cn), jnp.float32),
            "readout": C.mlp_specs([Cn, 16, cfg.out_dim]),
        })
    return {
        "species_embed": jax.ShapeDtypeStruct((cfg.n_species, Cn), jnp.float32),
        "layers": lay,
    }


def init(cfg: MACECfg, key: jax.Array):
    specs = param_specs(cfg)
    flat, td = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    out = []
    for k, s in zip(keys, flat):
        fan = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        out.append(jax.random.normal(k, s.shape, s.dtype) / np.sqrt(max(fan, 1)))
    return jax.tree.unflatten(td, out)


def _sl(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


def _ckpt(cfg):
    if cfg.remat:
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable
        )
    return lambda f: f


def forward(cfg: MACECfg, params, g: C.GraphBatch) -> jax.Array:
    N = g.node_feat.shape[0]
    Cn, L = cfg.d_hidden, cfg.l_max
    dim = so3.irrep_dim(L)

    rel = jnp.take(g.positions, g.edge_dst, 0) - jnp.take(g.positions, g.edge_src, 0)
    r = jnp.sqrt(jnp.sum(rel**2, -1) + 1e-9)
    Y = so3.real_sph_harm(rel, L)  # [E, dim]
    rbf = C.bessel_rbf(r, cfg.n_rbf, cfg.r_cut)  # [E, n_rbf]
    emask = g.edge_mask.astype(jnp.float32)

    # node irreps: X[N, C, dim], l=0 slot from species embedding
    h0 = jnp.take(params["species_embed"], g.species, axis=0)  # [N, C]
    X = jnp.zeros((N, Cn, dim), jnp.float32).at[:, :, 0].set(h0)

    site_e = jnp.zeros((N, cfg.out_dim), jnp.float32)
    pathsA = a_paths(L)

    def one_layer(lp, X, site_e):
        Rw = C.mlp_apply(lp["radial"], rbf).reshape(-1, len(pathsA), Cn)  # [E, nA, C]
        Xs = jnp.take(X, g.edge_src, axis=0)  # [E, C, dim]
        A = jnp.zeros((N, Cn, dim), jnp.float32)
        for pi, (l1, l2, l3) in enumerate(pathsA):
            cg = jnp.asarray(so3.cg_real(l1, l2, l3), jnp.float32)
            contrib = jnp.einsum(
                "abc,ea,ecb->ecb" if False else "abc,ea,exb->exc",
                cg, Y[:, _sl(l1)], Xs[:, :, _sl(l2)],
            )  # [E, C, 2l3+1]
            contrib = contrib * (Rw[:, pi, :] * emask[:, None])[:, :, None]
            A = A.at[:, :, _sl(l3)].add(
                jax.ops.segment_sum(contrib, g.edge_dst, N)
            )

        # B-basis: correlation 1..3 with per-path channel mixing
        msg = jnp.einsum("xcv,cd->xdv", A, lp["w_b1"])
        for pi, (la, lb, lo) in enumerate(b2_paths(L)):
            cg = jnp.asarray(so3.cg_real(la, lb, lo), jnp.float32)
            prod = jnp.einsum("abc,xna,xnb->xnc", cg, A[:, :, _sl(la)], A[:, :, _sl(lb)])
            msg = msg.at[:, :, _sl(lo)].add(
                jnp.einsum("xnc,nd->xdc", prod, lp["w_b2"][pi])
            )
        if cfg.correlation >= 3:
            for pi, (la, lb, lab, lc, lo) in enumerate(b3_paths(L)):
                cg1 = jnp.asarray(so3.cg_real(la, lb, lab), jnp.float32)
                cg2 = jnp.asarray(so3.cg_real(lab, lc, lo), jnp.float32)
                p2 = jnp.einsum("abc,xna,xnb->xnc", cg1, A[:, :, _sl(la)], A[:, :, _sl(lb)])
                p3 = jnp.einsum("abc,xna,xnb->xnc", cg2, p2, A[:, :, _sl(lc)])
                msg = msg.at[:, :, _sl(lo)].add(
                    jnp.einsum("xnc,nd->xdc", p3, lp["w_b3"][pi])
                )

        res = jnp.einsum(
            "xcv,xcd->xdv", X, jnp.take(lp["w_res"], g.species, axis=0)
        )
        X = msg + res
        site_e = site_e + C.mlp_apply(lp["readout"], X[:, :, 0])
        return X, site_e

    for lp in params["layers"]:
        X, site_e = _ckpt(cfg)(one_layer)(lp, X, site_e)

    return site_e


def loss_fn(cfg: MACECfg, params, g: C.GraphBatch) -> jax.Array:
    out = forward(cfg, params, g)
    if cfg.out_dim == 1:
        return C.graph_regression_loss(out, g)
    return C.node_class_loss(out, g.labels, g.node_mask)
