"""EGNN — E(n)-equivariant GNN (Satorras et al., arXiv:2102.09844).

Faithful to the paper's equations (Eq. 3-6):

    m_ij  = φ_e(h_i, h_j, ‖x_i − x_j‖², a_ij)
    x_i'  = x_i + (1/C) Σ_j (x_i − x_j) · φ_x(m_ij)
    h_i'  = φ_h(h_i, Σ_j m_ij)

Equivariance comes free: only squared distances enter φ_e and coordinate
updates are radial.  Assigned config: n_layers=4, d_hidden=64.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class EGNNCfg:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    in_dim: int = 16
    edge_dim: int = 0
    out_dim: int = 1
    update_coords: bool = True
    # remat trades memory for re-gathered halo exchanges in the backward —
    # a LOSS for full-batch giant graphs (collective-bound); builder-controlled
    remat: bool = True


def param_specs(cfg: EGNNCfg):
    d, e = cfg.d_hidden, cfg.edge_dim
    lay = []
    for _ in range(cfg.n_layers):
        lay.append({
            "phi_e": C.mlp_specs([2 * d + 1 + e, d, d]),
            "phi_x": C.mlp_specs([d, d, 1]),
            "phi_h": C.mlp_specs([2 * d, d, d]),
        })
    return {
        "embed": C.mlp_specs([cfg.in_dim, d]),
        "layers": lay,
        "readout": C.mlp_specs([d, d, cfg.out_dim]),
    }


def init(cfg: EGNNCfg, key: jax.Array):
    specs = param_specs(cfg)
    flat, td = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    return jax.tree.unflatten(
        td,
        [
            jax.random.normal(k, s.shape, s.dtype) / jnp.sqrt(s.shape[0])
            if len(s.shape) == 2
            else jnp.zeros(s.shape, s.dtype)
            for k, s in zip(keys, flat)
        ],
    )


def _ckpt(cfg):
    if cfg.remat:
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable
        )
    return lambda f: f


def forward(cfg: EGNNCfg, params, g: C.GraphBatch) -> jax.Array:
    n = g.node_feat.shape[0]
    h = C.mlp_apply(params["embed"], g.node_feat)
    x = g.positions

    def one_layer(lp, h, x):
        hs = jnp.take(h, g.edge_src, axis=0)
        hd = jnp.take(h, g.edge_dst, axis=0)
        xs = jnp.take(x, g.edge_src, axis=0)
        xd = jnp.take(x, g.edge_dst, axis=0)
        d2 = jnp.sum((xd - xs) ** 2, axis=-1, keepdims=True)
        feats = [hd, hs, d2]
        if cfg.edge_dim:
            feats.append(g.edge_feat)
        m = C.mlp_apply(lp["phi_e"], jnp.concatenate(feats, axis=-1), final_act=True)
        m = m * g.edge_mask[:, None].astype(m.dtype)
        if cfg.update_coords:
            w = C.mlp_apply(lp["phi_x"], m)  # [E, 1]
            dx = C.scatter_edges((xd - xs) * w, g.edge_dst, n, g.edge_mask)
            deg = C.scatter_edges(
                jnp.ones((m.shape[0], 1), x.dtype), g.edge_dst, n, g.edge_mask
            )
            x = x + dx / jnp.maximum(deg, 1.0)
        agg = C.scatter_edges(m, g.edge_dst, n, g.edge_mask)
        h = h + C.mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], axis=-1))
        return h, x

    for lp in params["layers"]:
        h, x = _ckpt(cfg)(one_layer)(lp, h, x)
    return C.mlp_apply(params["readout"], h)


def loss_fn(cfg: EGNNCfg, params, g: C.GraphBatch) -> jax.Array:
    out = forward(cfg, params, g)
    if cfg.out_dim == 1:  # graph-level energy regression
        return C.graph_regression_loss(out, g)
    return C.node_class_loss(out, g.labels, g.node_mask)
