"""SO(3) substrate for the equivariant GNNs (MACE, EquiformerV2/eSCN).

Everything heavy is precomputed HOST-side in numpy (exact factorial
arithmetic) and baked into constant tensors; the per-edge device work is
pure dense algebra:

  * real spherical harmonics Y_lm (associated-Legendre recursion, generic l);
  * Clebsch-Gordan coefficients in the REAL basis (Racah formula + complex→
    real change of basis) for MACE's tensor-product contractions;
  * exact real-basis Wigner rotations as POLYNOMIAL COEFFICIENT tensors:
    d^l(β) entries are polynomials in cos(β/2), sin(β/2) (Wigner's formula),
    so the full real-basis rotation for "align edge to ẑ" evaluates per edge
    as two closed-form Rz mixes + one polynomial einsum — no expm, no
    per-edge matrix factorization.  This is the TPU-native reformulation of
    eSCN's rotation trick.

Irrep layout convention: channels-last flat vector over (l, m): index
``l² + (m + l)`` — size (L+1)² for l = 0..L.
"""

from __future__ import annotations

from functools import lru_cache
from math import factorial, sqrt

import jax
import jax.numpy as jnp
import numpy as np


def irrep_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def irrep_slices(l_max: int) -> list[slice]:
    return [slice(l * l, (l + 1) * (l + 1)) for l in range(l_max + 1)]


# ---------------------------------------------------------------------------
# real spherical harmonics (device, generic l, Condon-Shortley-free real form)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _sh_norms(l_max: int) -> np.ndarray:
    """N_lm = sqrt((2l+1)/(4π) · (l-m)!/(l+m)!) for m ≥ 0, flattened."""
    out = np.zeros(irrep_dim(l_max))
    for l in range(l_max + 1):
        for m in range(0, l + 1):
            n = sqrt((2 * l + 1) / (4 * np.pi) * factorial(l - m) / factorial(l + m))
            out[l * l + l + m] = n
            out[l * l + l - m] = n
    return out


def real_sph_harm(vec: jax.Array, l_max: int, eps: float = 1e-9) -> jax.Array:
    """Y_lm(v̂) for unit(ish) vectors.  vec: [..., 3] -> [..., (L+1)²].

    Associated Legendre by stable recursion; azimuth via cos/sin(mφ)
    recurrences.  Fully vectorized (VPU-friendly), no trig of arccos.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    ct = z / r  # cosθ
    rxy = jnp.sqrt(x * x + y * y + eps)
    st = rxy / r  # sinθ
    cphi = x / rxy
    sphi = y / rxy

    # P_l^m(ct) for 0 ≤ m ≤ l
    P: dict[tuple[int, int], jax.Array] = {(0, 0): jnp.ones_like(ct)}
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * st * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * ct * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = (
                (2 * l - 1) * ct * P[(l - 1, m)] - (l + m - 1) * P[(l - 2, m)]
            ) / (l - m)

    # cos(mφ), sin(mφ) recurrences
    cm = [jnp.ones_like(cphi), cphi]
    sm = [jnp.zeros_like(sphi), sphi]
    for m in range(2, l_max + 1):
        cm.append(2 * cphi * cm[-1] - cm[-2])
        sm.append(2 * cphi * sm[-1] - sm[-2])

    norms = _sh_norms(l_max)
    comps = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            n = norms[l * l + l + m]
            if m == 0:
                comps.append(n * P[(l, 0)])
            elif m > 0:
                comps.append(sqrt(2.0) * n * P[(l, m)] * cm[m])
            else:
                comps.append(sqrt(2.0) * n * P[(l, am)] * sm[am])
    return jnp.stack(comps, axis=-1)


# ---------------------------------------------------------------------------
# Clebsch-Gordan (host, exact) — complex CG via Racah, then real basis
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _cg_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """⟨l1 m1 l2 m2 | l3 m3⟩ -> [2l1+1, 2l2+1, 2l3+1] (Racah's formula)."""
    out = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return out
    f = factorial
    pref_l = sqrt(
        (2 * l3 + 1)
        * f(l3 + l1 - l2) * f(l3 - l1 + l2) * f(l1 + l2 - l3)
        / f(l1 + l2 + l3 + 1)
    )
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) > l3:
                continue
            pref_m = sqrt(
                f(l3 + m3) * f(l3 - m3) * f(l1 - m1) * f(l1 + m1) * f(l2 - m2) * f(l2 + m2)
            )
            s = 0.0
            for k in range(0, l1 + l2 - l3 + 1):
                denoms = [
                    k, l1 + l2 - l3 - k, l1 - m1 - k, l2 + m2 - k,
                    l3 - l2 + m1 + k, l3 - l1 - m2 + k,
                ]
                if any(d < 0 for d in denoms):
                    continue
                s += (-1) ** k / np.prod([float(f(d)) for d in denoms])
            out[m1 + l1, m2 + l2, m3 + l3] = pref_l * pref_m * s
    return out


@lru_cache(maxsize=None)
def _real_to_complex(l: int) -> np.ndarray:
    """U with Y^C_{lm} = Σ_m' U[m, m'] Y^R_{lm'} (rows complex m, cols real)."""
    U = np.zeros((2 * l + 1, 2 * l + 1), dtype=np.complex128)
    for m in range(-l, l + 1):
        if m == 0:
            U[l, l] = 1.0
        elif m > 0:
            # convention matching ``real_sph_harm`` (CS phase inside P_l^m):
            #   Y^C_m = (Y^R_m + i·Y^R_{-m})/√2,  Y^C_{-m} = (-1)^m (Y^R_m - i·Y^R_{-m})/√2
            U[m + l, m + l] = 1 / sqrt(2)  # cos part
            U[m + l, -m + l] = 1j / sqrt(2)  # sin part
            U[-m + l, m + l] = (-1) ** m / sqrt(2)
            U[-m + l, -m + l] = -1j * (-1) ** m / sqrt(2)
    return U


@lru_cache(maxsize=None)
def cg_real(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG: C[m1, m2, m3] with real Y.  Guaranteed real (up to fp)."""
    C = _cg_complex(l1, l2, l3).astype(np.complex128)
    U1, U2, U3 = _real_to_complex(l1), _real_to_complex(l2), _real_to_complex(l3)
    # C_real = U1† U2† C U3 (contract complex indices onto real ones)
    out = np.einsum("abc,ax,by,cz->xyz", C, U1.conj(), U2.conj(), U3)
    assert np.abs(out.imag).max() < 1e-10 or np.abs(out.real).max() < 1e-12, (
        l1, l2, l3, np.abs(out.imag).max(),
    )
    # real CG can land purely imaginary for some parities; fold the phase in
    if np.abs(out.imag).max() > np.abs(out.real).max():
        out = out.imag
    else:
        out = out.real
    return np.ascontiguousarray(out)


# ---------------------------------------------------------------------------
# Wigner rotations in the real basis, as polynomial coefficient tensors
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _wigner_d_poly(l: int) -> np.ndarray:
    """Coefficients W[m', m, i, j]: d^l_{m'm}(β) = Σ_ij W c^i s^j with
    c = cos(β/2), s = sin(β/2).  Exact from Wigner's formula."""
    dim = 2 * l + 1
    deg = 2 * l + 1
    W = np.zeros((dim, dim, deg, deg))
    f = factorial
    for mp in range(-l, l + 1):
        for m in range(-l, l + 1):
            pref = sqrt(f(l + mp) * f(l - mp) * f(l + m) * f(l - m))
            for k in range(0, 2 * l + 1):
                denoms = [l + m - k, k, mp - m + k, l - mp - k]
                if any(d < 0 for d in denoms):
                    continue
                coef = (-1) ** (mp - m + k) * pref / np.prod(
                    [float(f(d)) for d in denoms]
                )
                ci = 2 * l + m - mp - 2 * k  # power of cos(β/2)
                si = mp - m + 2 * k  # power of sin(β/2)
                W[mp + l, m + l, ci, si] += coef
    return W


@lru_cache(maxsize=None)
def wigner_dy_real_poly(l: int) -> np.ndarray:
    """Real-basis Ry(β) rotation as polynomial tensor P[a, b, i, j]:
    D^l_real(β)_{ab} = Σ_ij P c^i s^j.  (U† d U, U the real↔complex map.)"""
    U = _real_to_complex(l)
    W = _wigner_d_poly(l).astype(np.complex128)
    # D^R = U† d U  (U maps real -> complex coefficients)
    P = np.einsum("xa,abij,by->xyij", U.conj().T, W, U)
    assert np.abs(P.imag).max() < 1e-9, (l, np.abs(P.imag).max())
    return np.ascontiguousarray(P.real)


def rz_real(l_max: int, phi: jax.Array) -> jax.Array:
    """Block-diagonal real-basis Rz(φ): closed-form cos/sin(mφ) mixing.

    Returns [..., dim, dim] with dim = (l_max+1)².  Cheap: O(L²) nonzeros.
    """
    dim = irrep_dim(l_max)
    out = jnp.zeros((*phi.shape, dim, dim))
    for l in range(l_max + 1):
        base = l * l + l
        out = out.at[..., base, base].set(1.0)
        for m in range(1, l + 1):
            c, s = jnp.cos(m * phi), jnp.sin(m * phi)
            ip, im = base + m, base - m
            out = out.at[..., ip, ip].set(c)
            out = out.at[..., im, im].set(c)
            out = out.at[..., ip, im].set(-s)
            out = out.at[..., im, ip].set(s)
    return out


def ry_real(l_max: int, beta: jax.Array) -> jax.Array:
    """Real-basis Ry(β) via the precomputed polynomial tensors."""
    dim = irrep_dim(l_max)
    c = jnp.cos(beta / 2)
    s = jnp.sin(beta / 2)
    out = jnp.zeros((*beta.shape, dim, dim))
    for l in range(l_max + 1):
        P = jnp.asarray(wigner_dy_real_poly(l))  # [d, d, deg, deg]
        deg = 2 * l + 1
        cp = jnp.stack([c**i for i in range(deg)], axis=-1)  # [..., deg]
        sp = jnp.stack([s**j for j in range(deg)], axis=-1)
        blk = jnp.einsum("abij,...i,...j->...ab", P, cp, sp)
        sl = slice(l * l, (l + 1) * (l + 1))
        out = out.at[..., sl, sl].set(blk)
    return out


def _rz_block(l: int, phi: jax.Array) -> jax.Array:
    """One l-block of the real-basis Rz(φ): [..., 2l+1, 2l+1]."""
    d = 2 * l + 1
    out = jnp.zeros((*phi.shape, d, d))
    out = out.at[..., l, l].set(1.0)
    for m in range(1, l + 1):
        c, s = jnp.cos(m * phi), jnp.sin(m * phi)
        ip, im = l + m, l - m
        out = out.at[..., ip, ip].set(c)
        out = out.at[..., im, im].set(c)
        out = out.at[..., ip, im].set(-s)
        out = out.at[..., im, ip].set(s)
    return out


def _ry_block(l: int, beta: jax.Array) -> jax.Array:
    """One l-block of the real-basis Ry(β) via the polynomial tensor."""
    P = jnp.asarray(wigner_dy_real_poly(l))
    deg = 2 * l + 1
    c = jnp.cos(beta / 2)
    s = jnp.sin(beta / 2)
    cp = jnp.stack([c**i for i in range(deg)], axis=-1)
    sp = jnp.stack([s**j for j in range(deg)], axis=-1)
    return jnp.einsum("abij,...i,...j->...ab", P, cp, sp)


def align_blocks(vec: jax.Array, l_max: int, eps: float = 1e-9):
    """Per-l rotation blocks aligning ``vec`` to +z (memory-lean form).

    Returns list of [..., 2l+1, 2l+1] for l = 0..l_max.  Storage Σ(2l+1)²
    per element instead of the full (L+1)⁴ dense matrix.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    beta = jnp.arccos(jnp.clip(z / r, -1 + 1e-7, 1 - 1e-7))
    phi = jnp.arctan2(y, x)
    return [
        jnp.einsum("...ab,...bc->...ac", _ry_block(l, -beta), _rz_block(l, -phi))
        for l in range(l_max + 1)
    ]


def align_to_z(vec: jax.Array, l_max: int, eps: float = 1e-9):
    """Rotation R (real irrep basis) with R·irreps expressed in the frame
    where ``vec`` points along +z.  Returns (R, R_inv) of shape
    [..., dim, dim].  R = Ry(-β)·Rz(-φ);   R_inv = Rᵀ (orthogonal)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    beta = jnp.arccos(jnp.clip(z / r, -1 + 1e-7, 1 - 1e-7))
    phi = jnp.arctan2(y, x)
    R = jnp.einsum(
        "...ab,...bc->...ac", ry_real(l_max, -beta), rz_real(l_max, -phi)
    )
    return R, jnp.swapaxes(R, -1, -2)
