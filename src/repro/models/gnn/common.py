"""Shared GNN plumbing: graph batches, segment message passing, losses.

JAX has no sparse message-passing primitive (BCOO only), so aggregation is
built from first principles: gather node states along ``edge_src``, compute
edge messages densely, ``jax.ops.segment_sum`` (or max) into ``edge_dst``.
Edges are padded to static shapes with ``edge_mask``; padded edges point at
node 0 with zero weight — semantically inert.

Distribution: the edge axis is the data-parallel axis (edges sharded over
('pod','data'); node states replicated per shard, psum-combined after
segment_sum).  This matches the dominant cost: |E| ≫ |N| for every assigned
shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class GraphBatch(NamedTuple):
    """One (possibly multi-graph) padded graph batch."""

    node_feat: jax.Array  # f32[N, F]
    positions: jax.Array  # f32[N, 3] (synthesized for non-geometric datasets)
    species: jax.Array  # int32[N]  (atomic number / node type bucket)
    edge_src: jax.Array  # int32[E]
    edge_dst: jax.Array  # int32[E]
    edge_feat: jax.Array  # f32[E, Fe]
    node_mask: jax.Array  # bool[N]
    edge_mask: jax.Array  # bool[E]
    labels: jax.Array  # int32[N] node classes (or -1); regression via graph_y
    graph_ids: jax.Array  # int32[N] graph id per node (0 for single graph)
    graph_y: jax.Array  # f32[B] per-graph regression target

    @property
    def n_graphs(self) -> int:  # static (from shape, jit-safe)
        return self.graph_y.shape[0]


def segment_mean(data, segment_ids, num_segments, mask=None):
    if mask is not None:
        data = data * mask[:, None].astype(data.dtype)
        cnt = jax.ops.segment_sum(mask.astype(data.dtype), segment_ids, num_segments)
    else:
        cnt = jax.ops.segment_sum(jnp.ones(data.shape[0], data.dtype), segment_ids, num_segments)
    s = jax.ops.segment_sum(data, segment_ids, num_segments)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_edges(edge_vals: jax.Array, dst: jax.Array, n_nodes: int,
                  mask: jax.Array | None = None) -> jax.Array:
    """Masked segment-sum of per-edge vectors into destination nodes."""
    if mask is not None:
        edge_vals = edge_vals * mask[..., None].astype(edge_vals.dtype)
    return jax.ops.segment_sum(edge_vals, dst, n_nodes)


def mlp_params(key, dims: list[int], scale: float = 1.0):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [
            jax.random.normal(k, (a, b), jnp.float32) * scale / np.sqrt(a)
            for k, a, b in zip(ks, dims[:-1], dims[1:])
        ],
        "b": [jnp.zeros((b,), jnp.float32) for b in dims[1:]],
    }


def mlp_specs(dims: list[int]):
    return {
        "w": [jax.ShapeDtypeStruct((a, b), jnp.float32) for a, b in zip(dims[:-1], dims[1:])],
        "b": [jax.ShapeDtypeStruct((b,), jnp.float32) for b in dims[1:]],
    }


def mlp_apply(p, x, act=jax.nn.silu, final_act=False):
    n = len(p["w"])
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def node_class_loss(node_out: jax.Array, labels: jax.Array, node_mask: jax.Array):
    """Masked softmax CE over nodes with labels >= 0."""
    mask = node_mask & (labels >= 0)
    logits = node_out.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
    nll = jnp.where(mask, lse - lab, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def graph_regression_loss(node_out: jax.Array, g: GraphBatch):
    """Per-graph energy: sum node scalars, MSE against graph_y."""
    e_node = node_out[..., 0] * g.node_mask
    e_graph = jax.ops.segment_sum(e_node, g.graph_ids, g.n_graphs)
    return jnp.mean((e_graph - g.graph_y) ** 2)


def bessel_rbf(r: jax.Array, n_rbf: int, r_cut: float) -> jax.Array:
    """Sinc-like Bessel radial basis with smooth polynomial cutoff."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    x = jnp.clip(r[..., None] / r_cut, 1e-5, 1.0)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * x) / (x * r_cut)
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    fcut = 1 - 10 * u**3 + 15 * u**4 - 6 * u**5
    return rb * fcut[..., None]
