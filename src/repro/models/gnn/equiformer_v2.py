"""EquiformerV2 — equivariant graph attention via eSCN convolutions
(arXiv:2306.12059).

The eSCN trick: rotate each edge's irreps into the frame where the edge
points along +z; there the SO(3) tensor-product convolution becomes an SO(2)
linear layer coupling only (+m, −m) pairs, and restricting to m ≤ m_max
(assigned: 2) drops the O(L⁶) CG contraction to O(L³)-ish dense matmuls —
exactly MXU-shaped.  Per block:

  1. per-edge Wigner rotation to the edge frame (``so3.align_blocks``,
     computed once per graph, reused by all layers);
  2. SO(2) convolution over concatenated (src ‖ dst) features for
     m = 0..m_max, with a radial gate on the output;
  3. multi-head attention: logits from the m=0 (invariant) channel,
     softmax over each destination's incoming edges (segment max/sum);
  4. rotate messages back, aggregate, residual; then an S2-style gated FFN.

Assigned config: n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import common as C
from repro.models.gnn import so3


@dataclasses.dataclass(frozen=True)
class EquiformerV2Cfg:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128  # channels per irrep degree
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 8
    r_cut: float = 6.0
    n_species: int = 32
    out_dim: int = 1
    # process edges in chunks (lax.scan) with ONLINE-softmax attention: the
    # per-layer [E, C, (L+1)²] edge working set shrinks by edge_chunks× — at
    # ogb_products scale the difference between ~100 GB/dev and ~3 GB/dev.
    edge_chunks: int = 1
    # remat trades memory for re-gathered halo exchanges in the backward —
    # a LOSS for full-batch giant graphs (collective-bound); builder-controlled
    remat: bool = True


def _n_l(cfg, m):  # number of degrees carrying an |m| component
    return cfg.l_max + 1 - m


def param_specs(cfg: EquiformerV2Cfg):
    Cn = cfg.d_hidden
    lay = []
    for _ in range(cfg.n_layers):
        d: dict = {
            "radial": C.mlp_specs([cfg.n_rbf, 64, Cn]),
            "attn": C.mlp_specs([Cn + cfg.n_rbf, 64, cfg.n_heads]),
            "out_proj": jax.ShapeDtypeStruct((Cn, Cn), jnp.float32),
            "ffn_gate": C.mlp_specs([Cn, Cn, (cfg.l_max + 1) * Cn]),
            "ffn_l0": C.mlp_specs([Cn, Cn, Cn]),
        }
        # SO(2) conv weights: m=0 real; m>0 a (W1, W2) pair
        two = 2 * Cn  # src ‖ dst concat
        d["w0"] = jax.ShapeDtypeStruct((_n_l(cfg, 0) * two, _n_l(cfg, 0) * Cn), jnp.float32)
        for m in range(1, cfg.m_max + 1):
            nl = _n_l(cfg, m)
            d[f"w{m}_r"] = jax.ShapeDtypeStruct((nl * two, nl * Cn), jnp.float32)
            d[f"w{m}_i"] = jax.ShapeDtypeStruct((nl * two, nl * Cn), jnp.float32)
        lay.append(d)
    return {
        "species_embed": jax.ShapeDtypeStruct((cfg.n_species, Cn), jnp.float32),
        "feat_embed": C.mlp_specs([cfg.n_rbf, Cn]),  # placeholder edge-degree feat
        "layers": lay,
        "readout": C.mlp_specs([Cn, Cn, cfg.out_dim]),
    }


def init(cfg: EquiformerV2Cfg, key: jax.Array):
    specs = param_specs(cfg)
    flat, td = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    return jax.tree.unflatten(
        td,
        [
            jax.random.normal(k, s.shape, s.dtype) / np.sqrt(max(s.shape[0], 1))
            if len(s.shape) >= 2
            else jnp.zeros(s.shape, s.dtype)
            for k, s in zip(keys, flat)
        ],
    )


def _sl(l):
    return slice(l * l, (l + 1) * (l + 1))


def _rotate(blocks, X, inverse=False):
    """Apply per-l rotation blocks to X[E, C, dim]."""
    outs = []
    for l, D in enumerate(blocks):
        xb = X[:, :, _sl(l)]
        if inverse:
            outs.append(jnp.einsum("eba,ecb->eca", D, xb))
        else:
            outs.append(jnp.einsum("eab,ecb->eca", D, xb))
    return jnp.concatenate(outs, axis=-1)


def _m_index(cfg, m):
    """Flat irrep indices of the +m and -m components across degrees."""
    plus = [l * l + l + m for l in range(m, cfg.l_max + 1)]
    minus = [l * l + l - m for l in range(m, cfg.l_max + 1)]
    return np.array(plus), np.array(minus)


NEG = -1e30


def _edge_messages(cfg, lp, X, src, dst, rel_c, rbf_c, em_c):
    """Per-edge eSCN conv + attention logits for one edge block.

    Returns (msg [Ec, C, dim] rotated back to the global frame,
    logits [Ec] — head-averaged invariant attention logits, masked to NEG).
    """
    Cn, L = cfg.d_hidden, cfg.l_max
    Ec = src.shape[0]
    blocks_c = so3.align_blocks(rel_c, L)
    Xs = _rotate(blocks_c, jnp.take(X, src, 0))  # edge frame
    Xd = _rotate(blocks_c, jnp.take(X, dst, 0))
    cat = jnp.concatenate([Xs, Xd], axis=1)  # [Ec, 2C, dim]

    y = jnp.zeros((Ec, Cn, so3.irrep_dim(L)), jnp.float32)
    p0, _ = _m_index(cfg, 0)
    x0 = cat[:, :, p0].reshape(Ec, -1)
    y = y.at[:, :, p0].set((x0 @ lp["w0"]).reshape(Ec, Cn, len(p0)))
    for m in range(1, cfg.m_max + 1):  # SO(2) complex-pair mixing
        pp, pm = _m_index(cfg, m)
        xp = cat[:, :, pp].reshape(Ec, -1)
        xm = cat[:, :, pm].reshape(Ec, -1)
        yr = (xp @ lp[f"w{m}_r"] - xm @ lp[f"w{m}_i"]).reshape(Ec, Cn, len(pp))
        yi = (xp @ lp[f"w{m}_i"] + xm @ lp[f"w{m}_r"]).reshape(Ec, Cn, len(pp))
        y = y.at[:, :, pp].set(yr).at[:, :, pm].set(yi)

    y = y * C.mlp_apply(lp["radial"], rbf_c)[:, :, None]
    inv = y[:, :, 0]  # invariant channel after conv
    logits = C.mlp_apply(lp["attn"], jnp.concatenate([inv, rbf_c], -1)).mean(-1)
    logits = jnp.where(em_c > 0, logits, NEG)
    msg = _rotate(blocks_c, y, inverse=True)
    return msg, logits


def _agg_fwd_scan(cfg, lp, X, chunks, N):
    """Forward chunk sweep with online softmax. Returns (agg, m, l)."""
    Cn, dim = cfg.d_hidden, so3.irrep_dim(cfg.l_max)

    def body(carry, inp):
        m, l, acc = carry
        src_c, dst_c, rel_c, rbf_c, em_c = inp
        msg, logits = _edge_messages(cfg, lp, X, src_c, dst_c, rel_c, rbf_c, em_c)
        m_c = jax.ops.segment_max(logits, dst_c, N)
        m_new = jnp.maximum(m, m_c)
        corr = jnp.exp(m - m_new)
        a = jnp.exp(logits - jnp.take(m_new, dst_c, 0)) * em_c
        l = l * corr + jax.ops.segment_sum(a, dst_c, N)
        acc = acc * corr[:, None, None] + jax.ops.segment_sum(
            msg * a[:, None, None], dst_c, N
        )
        return (m_new, l, acc), None

    m0 = jnp.full((N,), NEG, jnp.float32)
    l0 = jnp.zeros((N,), jnp.float32)
    a0 = jnp.zeros((N, Cn, dim), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), chunks)
    agg = acc / jnp.maximum(l, 1e-9)[:, None, None]
    return agg, m, l


@partial(jax.custom_vjp, nondiff_argnums=(0, 4))
def _chunked_agg(cfg, lp, X, chunks, N):
    return _agg_fwd_scan(cfg, lp, X, chunks, N)[0]


def _chunked_agg_fwd(cfg, lp, X, chunks, N):
    agg, m, l = _agg_fwd_scan(cfg, lp, X, chunks, N)
    # residuals are NODE-sized + the inputs — no [E, C, dim] edge tensor is
    # ever saved (plain AD through the scan would stash one per chunk).
    return agg, (lp, X, chunks, m, l, agg)


def _chunked_agg_bwd(cfg, N, res, dagg):
    """Flash-style backward: recompute each chunk's messages, pull cotangents
    through with the saved (m, l, agg) statistics.

      agg = acc / l,  acc = Σ_chunks accpart(logits, msg),  l = Σ_chunks lpart
      dacc = dagg / l
      dl   = -(dagg · agg) / l        (per node)
      m is a constant of the softmax (standard max-subtraction backward).
    """
    lp, X, chunks, m, l, agg = res
    linv = 1.0 / jnp.maximum(l, 1e-9)
    dacc = dagg * linv[:, None, None]
    dl = -jnp.sum(dagg * agg, axis=(1, 2)) * linv

    def body(carry, inp):
        dlp, dX = carry
        src_c, dst_c, rel_c, rbf_c, em_c = inp

        def chunk_part(lp_, X_):
            msg, logits = _edge_messages(cfg, lp_, X_, src_c, dst_c, rel_c, rbf_c, em_c)
            a = jnp.exp(logits - jnp.take(m, dst_c, 0)) * em_c
            accpart = jax.ops.segment_sum(msg * a[:, None, None], dst_c, N)
            lpart = jax.ops.segment_sum(a, dst_c, N)
            return accpart, lpart

        _, pull = jax.vjp(chunk_part, lp, X)
        dlp_c, dX_c = pull((dacc, dl))
        return (jax.tree.map(jnp.add, dlp, dlp_c), dX + dX_c), None

    dlp0 = jax.tree.map(jnp.zeros_like, lp)
    (dlp, dX), _ = jax.lax.scan(body, (dlp0, jnp.zeros_like(X)), chunks)
    return dlp, dX, jax.tree.map(jnp.zeros_like, chunks)


_chunked_agg.defvjp(_chunked_agg_fwd, _chunked_agg_bwd)


def _ckpt(cfg):
    if cfg.remat:
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable
        )
    return lambda f: f


def forward(cfg: EquiformerV2Cfg, params, g: C.GraphBatch) -> jax.Array:
    N = g.node_feat.shape[0]
    Cn, L = cfg.d_hidden, cfg.l_max
    dim = so3.irrep_dim(L)
    E = g.edge_src.shape[0]

    rel = jnp.take(g.positions, g.edge_dst, 0) - jnp.take(g.positions, g.edge_src, 0)
    r = jnp.sqrt(jnp.sum(rel**2, -1) + 1e-9)
    rbf = C.bessel_rbf(r, cfg.n_rbf, cfg.r_cut)
    emask = g.edge_mask.astype(jnp.float32)

    h0 = jnp.take(params["species_embed"], g.species, axis=0)
    X = jnp.zeros((N, Cn, dim), jnp.float32).at[:, :, 0].set(h0)

    nc = max(1, cfg.edge_chunks)
    if nc > 1:  # pad + block the edge arrays once
        pad = (-E) % nc
        srcs = jnp.pad(g.edge_src, (0, pad)).reshape(nc, -1)
        dsts = jnp.pad(g.edge_dst, (0, pad)).reshape(nc, -1)
        rels = jnp.pad(rel, ((0, pad), (0, 0))).reshape(nc, -1, 3)
        rbfs = jnp.pad(rbf, ((0, pad), (0, 0))).reshape(nc, -1, cfg.n_rbf)
        ems = jnp.pad(emask, (0, pad)).reshape(nc, -1)

    # per-layer remat + (optionally) edge-chunked ONLINE-softmax attention:
    # the unrolled loop would otherwise keep [E, C, dim] edge tensors alive
    # (terabytes at ogb_products scale; confirmed in the dry-run HLO).
    def one_layer(lp, X):
        if nc == 1:
            msg, logits = _edge_messages(
                cfg, lp, X, g.edge_src, g.edge_dst, rel, rbf, emask
            )
            lmax = jax.ops.segment_max(logits, g.edge_dst, N)
            a = jnp.exp(logits - jnp.take(lmax, g.edge_dst, 0)) * emask
            den = jax.ops.segment_sum(a, g.edge_dst, N)
            agg = jax.ops.segment_sum(
                msg * a[:, None, None], g.edge_dst, N
            ) / jnp.maximum(den, 1e-9)[:, None, None]
        else:
            # flash-over-graph: chunked online softmax with a custom VJP
            # (node-sized residuals; chunks recomputed in the backward)
            agg = _chunked_agg(cfg, lp, X, (srcs, dsts, rels, rbfs, ems), N)

        X = X + jnp.einsum("ncv,cd->ndv", agg, lp["out_proj"])

        # S2-gated FFN: per-degree scalar gates from the invariant channel
        inv_n = X[:, :, 0]
        gates = jax.nn.sigmoid(
            C.mlp_apply(lp["ffn_gate"], inv_n).reshape(N, Cn, L + 1)
        )
        scale = jnp.concatenate(
            [jnp.repeat(gates[:, :, l : l + 1], 2 * l + 1, axis=2) for l in range(L + 1)],
            axis=2,
        )
        X = X * scale
        X = X.at[:, :, 0].add(C.mlp_apply(lp["ffn_l0"], inv_n))
        return X

    for lp in params["layers"]:
        X = _ckpt(cfg)(one_layer)(lp, X)

    return C.mlp_apply(params["readout"], X[:, :, 0])


def loss_fn(cfg: EquiformerV2Cfg, params, g: C.GraphBatch) -> jax.Array:
    out = forward(cfg, params, g)
    if cfg.out_dim == 1:
        return C.graph_regression_loss(out, g)
    return C.node_class_loss(out, g.labels, g.node_mask)
