"""GraphCast-style encoder-processor-decoder GNN (arXiv:2212.12794).

Interaction-network blocks with residuals and sum aggregation, exactly the
processor structure of GraphCast (n_layers=16, d_hidden=512, sum aggregator):

    e'  = e + MLP_e([e, h_src, h_dst])
    h'  = h + MLP_n([h, Σ_{e into i} e'])

GraphCast's native deployment encodes a lat-lon grid onto a refined
icosahedral mesh (mesh_refinement=6) and decodes back; here the
encoder/decoder are feature MLPs over the supplied graph (the assigned
benchmark shapes supply generic graphs), with the native config recorded in
the arch file (n_vars=227 output channels on its own shape).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class GraphCastCfg:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    in_dim: int = 227
    edge_dim: int = 4
    out_dim: int = 227
    mesh_refinement: int = 6  # native config (recorded; generic graphs supplied)
    # remat trades memory for re-gathered halo exchanges in the backward —
    # a LOSS for full-batch giant graphs (collective-bound); builder-controlled
    remat: bool = True


def param_specs(cfg: GraphCastCfg):
    d = cfg.d_hidden
    lay = [
        {
            "edge_mlp": C.mlp_specs([3 * d, d, d]),
            "node_mlp": C.mlp_specs([2 * d, d, d]),
        }
        for _ in range(cfg.n_layers)
    ]
    return {
        "node_enc": C.mlp_specs([cfg.in_dim, d, d]),
        "edge_enc": C.mlp_specs([max(cfg.edge_dim, 1), d, d]),
        "layers": lay,
        "node_dec": C.mlp_specs([d, d, cfg.out_dim]),
    }


def init(cfg: GraphCastCfg, key: jax.Array):
    specs = param_specs(cfg)
    flat, td = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    return jax.tree.unflatten(
        td,
        [
            jax.random.normal(k, s.shape, s.dtype) / jnp.sqrt(s.shape[0])
            if len(s.shape) == 2
            else jnp.zeros(s.shape, s.dtype)
            for k, s in zip(keys, flat)
        ],
    )


def _ckpt(cfg):
    if cfg.remat:
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable
        )
    return lambda f: f


def forward(cfg: GraphCastCfg, params, g: C.GraphBatch) -> jax.Array:
    n = g.node_feat.shape[0]
    # bf16 node/edge states: halves the cross-shard gather (halo-exchange)
    # bytes — the dominant collective at ogb_products scale.  Accumulation
    # inside the MLP matmuls stays f32 via preferred_element_type defaults.
    h = C.mlp_apply(params["node_enc"], g.node_feat).astype(jnp.bfloat16)
    ef = g.edge_feat if cfg.edge_dim else jnp.ones((g.edge_src.shape[0], 1), h.dtype)
    e = C.mlp_apply(params["edge_enc"], ef).astype(jnp.bfloat16)

    def one_layer(lp, h, e):
        hs = jnp.take(h, g.edge_src, axis=0)
        hd = jnp.take(h, g.edge_dst, axis=0)
        e = e + C.mlp_apply(lp["edge_mlp"], jnp.concatenate([e, hs, hd], axis=-1))
        agg = C.scatter_edges(e, g.edge_dst, n, g.edge_mask)
        h = h + C.mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], axis=-1))
        return h, e

    for lp in params["layers"]:
        h, e = _ckpt(cfg)(one_layer)(lp, h, e)
    return C.mlp_apply(params["node_dec"], h.astype(jnp.float32))


def loss_fn(cfg: GraphCastCfg, params, g: C.GraphBatch) -> jax.Array:
    out = forward(cfg, params, g)
    if g.labels is not None and cfg.out_dim > 1:
        return C.node_class_loss(out, g.labels, g.node_mask)
    return C.graph_regression_loss(out, g)
