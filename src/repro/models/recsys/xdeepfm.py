"""xDeepFM — CIN + DNN + linear CTR model (arXiv:1803.05170).

Assigned config: 39 sparse fields, embed_dim=10, CIN 200-200-200, DNN
400-400.  The hot path is the embedding LOOKUP over huge tables — JAX has no
EmbeddingBag, so it is built here from ``jnp.take`` + ``jax.ops.segment_sum``
(``embedding_bag``), with the single-valued fast path a pure gather.

CIN (Compressed Interaction Network), layer k with H_k feature maps:

    x^{k+1}_h,d = Σ_{i,j} W^k_{h,i,j} · x^k_{i,d} · x^0_{j,d}

i.e. an outer product along the field axis, compressed by a learned W —
einsum-shaped, MXU-friendly.  Sum-pooling over d of every layer feeds the
final logit.  ``retrieval_score`` scores one user against N candidates as a
single [N, D] × [D] matvec (the retrieval_cand shape).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import common as C  # mlp helpers


@dataclasses.dataclass(frozen=True)
class XDeepFMCfg:
    name: str = "xdeepfm"
    n_fields: int = 39
    embed_dim: int = 10
    rows_per_field: int = 1_000_000  # 10⁶–10⁹ regime; 39 tables stacked
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_dims: tuple[int, ...] = (400, 400)

    @property
    def n_params(self) -> int:
        flat = sum(x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: param_specs(self))))
        return flat


def param_specs(cfg: XDeepFMCfg):
    F, D, R = cfg.n_fields, cfg.embed_dim, cfg.rows_per_field
    cin = []
    h_prev = F
    for h in cfg.cin_layers:
        cin.append(jax.ShapeDtypeStruct((h, h_prev, F), jnp.float32))
        h_prev = h
    return {
        "tables": jax.ShapeDtypeStruct((F, R, D), jnp.float32),
        "linear": jax.ShapeDtypeStruct((F, R), jnp.float32),
        "cin": cin,
        "cin_out": jax.ShapeDtypeStruct((sum(cfg.cin_layers), 1), jnp.float32),
        "dnn": C.mlp_specs([F * D, *cfg.mlp_dims, 1]),
        "bias": jax.ShapeDtypeStruct((), jnp.float32),
    }


def init(cfg: XDeepFMCfg, key: jax.Array):
    specs = param_specs(cfg)
    flat, td = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    out = []
    for k, s in zip(keys, flat):
        scale = 0.01 if len(s.shape) >= 2 else 0.0
        out.append(jax.random.normal(k, s.shape, s.dtype) * scale)
    return jax.tree.unflatten(td, out)


# ---------------------------------------------------------------------------
# EmbeddingBag — the JAX-native substrate (take + segment_sum)
# ---------------------------------------------------------------------------


def embedding_bag(
    table: jax.Array,  # [R, D]
    ids: jax.Array,  # int32[NNZ] flat multi-hot ids
    bag_ids: jax.Array,  # int32[NNZ] which bag each id belongs to
    n_bags: int,
    weights: jax.Array | None = None,
    mode: str = "sum",
) -> jax.Array:
    """PyTorch-EmbeddingBag semantics on TPU-friendly primitives."""
    emb = jnp.take(table, ids, axis=0)
    if weights is not None:
        emb = emb * weights[:, None]
    out = jax.ops.segment_sum(emb, bag_ids, n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bag_ids, n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def field_embed(params, ids: jax.Array) -> jax.Array:
    """Single-valued fields fast path: ids int32[B, F] -> [B, F, D]."""
    # tables: [F, R, D]; one gather per field along the stacked axis
    return jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, 1), out_axes=1)(
        params["tables"], ids
    )


def forward(cfg: XDeepFMCfg, params, ids: jax.Array) -> jax.Array:
    """CTR logit for ids int32[B, F]."""
    B, F = ids.shape
    x0 = field_embed(params, ids)  # [B, F, D]

    # linear term
    lin = jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, 1), out_axes=1)(
        params["linear"], ids
    ).sum(axis=1)

    # CIN
    xk = x0
    pooled = []
    for W in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        xk = jnp.einsum("bhmd,nhm->bnd", z, W)
        pooled.append(xk.sum(axis=-1))  # [B, H_k]
    cin_feat = jnp.concatenate(pooled, axis=-1)
    cin_out = (cin_feat @ params["cin_out"])[:, 0]

    # DNN
    dnn_out = C.mlp_apply(params["dnn"], x0.reshape(B, -1), act=jax.nn.relu)[:, 0]

    return lin + cin_out + dnn_out + params["bias"]


def loss_fn(cfg: XDeepFMCfg, params, batch) -> jax.Array:
    logit = forward(cfg, params, batch["ids"])
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


def retrieval_score(cfg: XDeepFMCfg, params, user_ids: jax.Array, cand_ids: jax.Array):
    """Score 1 user (ids[F]) against N candidates — one matvec, no loop.

    Candidates live in field 0's table (item id field, the standard layout).
    """
    u = field_embed(params, user_ids[None]).reshape(-1, cfg.embed_dim).mean(0)  # [D]
    cand = jnp.take(params["tables"][0], cand_ids, axis=0)  # [N, D]
    return cand @ u
