"""Decoder-only transformer family covering the five assigned LM archs.

One config dataclass expresses: dense GQA (tinyllama, command-r-plus),
local/global alternating attention + logit softcaps (gemma2), and top-k MoE
(kimi-k2, olmoe).  Params are stacked over layers ([L, ...] leaves) and the
forward pass is a ``lax.scan`` with per-layer remat — compile time and HLO
size stay O(1) in depth, which matters at 61 layers × 512 devices.

Sharding is expressed as LOGICAL axis names on every param leaf
(``logical_axes``); ``repro.dist.sharding`` maps them onto the production
mesh (TP over 'model', FSDP over 'data', DP over 'pod'×'data', sequence-
parallel residual stream over 'model').
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = Any


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    moe: MoECfg | None = None
    window: int | None = None  # sliding window for local layers
    local_every: int = 2  # gemma2: alternate local/global when window set
    attn_softcap: float | None = None
    final_softcap: float | None = None
    parallel_residual: bool = False  # command-r style
    tie_embeddings: bool = False
    remat: bool = True
    # attention chunking (flash-style); tuned per shape by the launcher
    chunk_q: int = 512
    chunk_kv: int = 1024

    @property
    def n_params(self) -> int:
        """Total parameter count (dense equivalent; MoE counts all experts)."""
        D, H, Kv, dh, F, V, Lz = (
            self.d_model, self.n_heads, self.n_kv_heads, self.d_head,
            self.d_ff, self.vocab, self.n_layers,
        )
        attn = D * H * dh + 2 * D * Kv * dh + H * dh * D
        if self.moe:
            ffn = D * self.moe.n_experts + 3 * self.moe.n_experts * D * self.moe.d_ff_expert
        else:
            ffn = 3 * D * F
        emb = V * D * (1 if self.tie_embeddings else 2)
        return Lz * (attn + ffn + 2 * D) + emb + D

    @property
    def n_active_params(self) -> int:
        """Per-token active params (MoE: top-k experts only)."""
        if not self.moe:
            return self.n_params
        D, Lz = self.d_model, self.n_layers
        full_ffn = 3 * self.moe.n_experts * D * self.moe.d_ff_expert
        act_ffn = 3 * self.moe.top_k * D * self.moe.d_ff_expert
        return self.n_params - Lz * (full_ffn - act_ffn)


# ---------------------------------------------------------------------------
# params: shapes, logical axes, init
# ---------------------------------------------------------------------------


def _layer_shapes(cfg: TransformerCfg) -> dict[str, tuple[tuple[int, ...], tuple[str | None, ...]]]:
    D, H, Kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s: dict[str, tuple[tuple[int, ...], tuple[str | None, ...]]] = {
        "attn_norm": ((D,), ("embed",)),
        "wq": ((D, H, dh), ("embed", "heads", "head_dim")),
        "wk": ((D, Kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ((D, Kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ((H, dh, D), ("heads", "head_dim", "embed_out")),
        "ffn_norm": ((D,), ("embed",)),
    }
    if cfg.moe:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        s |= {
            "router": ((D, E), ("embed", None)),
            "we1": ((E, D, Fe), ("experts", "embed", "ffn")),
            "we3": ((E, D, Fe), ("experts", "embed", "ffn")),
            "we2": ((E, Fe, D), ("experts", "ffn", "embed_out")),
        }
    else:
        F = cfg.d_ff
        s |= {
            "w1": ((D, F), ("embed", "ffn")),
            "w3": ((D, F), ("embed", "ffn")),
            "w2": ((F, D), ("ffn", "embed_out")),
        }
    return s


def param_specs(cfg: TransformerCfg, dtype=jnp.float32):
    """ShapeDtypeStructs for every param (no allocation — dry-run path)."""
    Lz = cfg.n_layers
    lay = {
        k: jax.ShapeDtypeStruct((Lz, *shape), dtype)
        for k, (shape, _) in _layer_shapes(cfg).items()
    }
    p = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dtype),
        "layers": lay,
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dtype)
    return p


def logical_axes(cfg: TransformerCfg):
    """Same pytree as params, leaves = logical axis-name tuples."""
    lay = {k: ("layers", *ax) for k, (_, ax) in _layer_shapes(cfg).items()}
    p = {
        "embed": ("vocab", "embed"),
        "layers": lay,
        "final_norm": ("embed",),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed", "vocab")
    return p


def init(cfg: TransformerCfg, key: jax.Array, dtype=jnp.float32) -> Params:
    specs = param_specs(cfg, dtype)
    flat, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    out = []
    for k, s in zip(keys, flat):
        if len(s.shape) <= 1 or s.shape[-1] == 1:
            out.append(jnp.zeros(s.shape, dtype))
        else:
            fan_in = int(s.shape[-2]) if len(s.shape) >= 2 else int(s.shape[-1])
            out.append(
                (jax.random.normal(k, s.shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)
            )
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# MoE FFN: top-k, capacity-based sort dispatch (dense shapes, shardable)
# ---------------------------------------------------------------------------


def _moe_dispatch_indices(gates: jax.Array, E: int, K: int, C: int, e0=0, e_count=None):
    """Sort-based capacity routing -> gather/scatter INDEX tensors only.

    Returns (idx [E_loc·C] token index per slot, wgt [E_loc·C] combine weight,
    valid [E_loc·C]).  No [T·K, D] activation temp is ever built — dispatch
    is a [E_loc·C, D] gather, combine a scatter-add of the same size.
    ``e0/e_count`` restrict to a local expert range (shard_map path).
    """
    T = gates.shape[0]
    e_count = e_count or E
    topv, topi = jax.lax.top_k(gates, K)  # [T, K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    exp = topi.reshape(-1).astype(jnp.int32) - e0
    wgt = topv.reshape(-1)
    local = (exp >= 0) & (exp < e_count)
    exp = jnp.where(local, exp, e_count)  # foreign experts sort to the tail
    order = jnp.argsort(exp)  # stable: groups by expert, arrival order kept
    exp_s, tok_s, w_s = exp[order], tok[order], wgt[order]
    start = jnp.searchsorted(exp_s, jnp.arange(e_count, dtype=jnp.int32))
    rank = jnp.arange(T * K, dtype=jnp.int32) - start[exp_s]
    keep = (rank < C) & (exp_s < e_count)
    slot = jnp.where(keep, exp_s * C + rank, e_count * C)  # overflow -> dropped

    z = e_count * C + 1
    idx = jnp.zeros((z,), jnp.int32).at[slot].set(tok_s, mode="drop")[:-1]
    wslot = jnp.zeros((z,), jnp.float32).at[slot].set(w_s, mode="drop")[:-1]
    valid = jnp.zeros((z,), jnp.bool_).at[slot].set(keep, mode="drop")[:-1]
    return idx, wslot, valid


def _moe_expert_compute(lp, x2, idx, wslot, valid, E_loc: int, C: int):
    """Gather -> per-expert gated MLP -> weighted scatter-add."""
    T, D = x2.shape
    xe = jnp.take(x2, idx, axis=0) * valid[:, None].astype(x2.dtype)
    xe = xe.reshape(E_loc, C, D)
    h = jnp.einsum("ecd,edf->ecf", xe, lp["we1"].astype(x2.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, lp["we3"].astype(x2.dtype))
    y = jnp.einsum(
        "ecf,efd->ecd", jax.nn.silu(h) * g, lp["we2"].astype(x2.dtype)
    ).reshape(E_loc * C, D)
    contrib = y * (wslot * valid).astype(x2.dtype)[:, None]
    return jnp.zeros((T, D), x2.dtype).at[idx].add(contrib, mode="drop")


def moe_capacity(cfg: TransformerCfg, T: int) -> int:
    m = cfg.moe
    C = max(8, int(math.ceil(m.capacity_factor * T * m.top_k / m.n_experts)))
    return min(C, T)


def moe_ffn(cfg: TransformerCfg, lp: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: [T, D] -> [T, D].  Single-shard reference path (smoke/CPU)."""
    m = cfg.moe
    T, D = x.shape
    C = moe_capacity(cfg, T)
    gates = jax.nn.softmax(
        (x @ lp["router"].astype(x.dtype)).astype(jnp.float32), axis=-1
    )
    idx, wslot, valid = _moe_dispatch_indices(gates, m.n_experts, m.top_k, C)
    return _moe_expert_compute(lp, x, idx, wslot, valid, m.n_experts, C)


def moe_ffn_shmap(cfg: TransformerCfg, lp, x3, *, mesh, dp_axes, model_axis="model"):
    """Expert-parallel MoE under shard_map: per-shard routing, no global sort.

    Tokens are data-parallel (replicated across the model axis after the
    sequence-parallel all-gather); experts shard over 'model'.  Every model
    shard routes its LOCAL tokens to its LOCAL experts and a psum combines —
    the only cross-shard traffic is the [T_loc, D] partial-output reduce,
    identical to a Megatron TP-FFN all-reduce.  Token order never leaves the
    shard, so the argsort is shard-local (GSPMD would emit a global sort).
    """
    m = cfg.moe
    B, S, D = x3.shape
    mp = mesh.shape[model_axis]
    E_loc = m.n_experts // mp
    from jax.sharding import PartitionSpec as P  # local import: keep models jax-pure

    dp = tuple(a for a in dp_axes if a in mesh.shape)
    x_spec = P(dp if len(dp) > 1 else (dp[0] if dp else None), None, None)
    lp_specs = {
        "router": P(), "we1": P(model_axis), "we3": P(model_axis),
        "we2": P(model_axis),
    }

    # remat INSIDE the body: shard_map residuals are opaque to an outer
    # checkpoint policy — without this the [E_loc, C, F] expert activations
    # get saved per layer (gigabytes; confirmed in the dry-run HLO).
    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def inner(xl, lpl):
        Bl = xl.shape[0]
        T = Bl * S
        C = moe_capacity(cfg, T)
        x2 = xl.reshape(T, D)
        gates = jax.nn.softmax(
            (x2 @ lpl["router"].astype(x2.dtype)).astype(jnp.float32), axis=-1
        )
        e0 = jax.lax.axis_index(model_axis) * E_loc
        idx, wslot, valid = _moe_dispatch_indices(
            gates, m.n_experts, m.top_k, C, e0=e0, e_count=E_loc
        )
        out = _moe_expert_compute(lpl, x2, idx, wslot, valid, E_loc, C)
        out = jax.lax.psum(out, model_axis)
        return out.reshape(Bl, S, D)

    from repro.compat import shard_map  # local import: keep models jax-pure

    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, {k: lp_specs[k] for k in ("router", "we1", "we3", "we2")}),
        out_specs=x_spec,
    )
    lp_used = {k: lp[k] for k in ("router", "we1", "we3", "we2")}
    return fn(x3, lp_used)


# ---------------------------------------------------------------------------
# layer + full forward (scan over stacked layers)
# ---------------------------------------------------------------------------


def _attention(cfg, lp, x, positions, *, is_local, kv=None, lengths=None):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"].astype(x.dtype))
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    win_on = cfg.window is not None
    out_g = L.chunked_attention(
        q, k, v, causal=True, window=None, attn_softcap=cfg.attn_softcap,
        chunk_q=cfg.chunk_q, chunk_kv=cfg.chunk_kv,
    )
    if win_on:
        out_l = L.chunked_attention(
            q, k, v, causal=True, window=cfg.window, attn_softcap=cfg.attn_softcap,
            chunk_q=cfg.chunk_q, chunk_kv=cfg.chunk_kv,
        )
        out = jnp.where(is_local, out_l, out_g)
    else:
        out = out_g
    out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(x.dtype))
    return out, (k, v)


def _ffn(cfg, lp, x, moe_ctx=None):
    B, S, D = x.shape
    if cfg.moe:
        if moe_ctx is not None:
            return moe_ffn_shmap(cfg, lp, x, **moe_ctx)
        return moe_ffn(cfg, lp, x.reshape(B * S, D)).reshape(B, S, D)
    return L.swiglu(x, lp["w1"], lp["w3"], lp["w2"])


def _layer(cfg, lp, x, positions, is_local, constrain, moe_ctx=None):
    h = L.rms_norm(x, lp["attn_norm"])
    attn, _ = _attention(cfg, lp, h, positions, is_local=is_local)
    if cfg.parallel_residual:
        f = _ffn(cfg, lp, h, moe_ctx)
        x = constrain(x + attn + f)
    else:
        x = constrain(x + attn)
        h2 = L.rms_norm(x, lp["ffn_norm"])
        x = constrain(x + _ffn(cfg, lp, h2, moe_ctx))
    return x


def forward(
    cfg: TransformerCfg,
    params: Params,
    tokens: jax.Array,  # int32[B, S]
    *,
    constrain=lambda x: x,  # sharding-constraint hook from the launcher
    moe_ctx: dict | None = None,  # mesh/axes for the shard_map MoE path
) -> jax.Array:
    """Token ids -> final hidden states [B, S, D] (bf16)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    x = constrain(x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    # gemma2-style: odd layers local when a window is configured
    local_flags = (
        (jnp.arange(cfg.n_layers) % cfg.local_every) != (cfg.local_every - 1)
        if cfg.window is not None
        else jnp.zeros((cfg.n_layers,), jnp.bool_)
    )

    def body(x, inp):
        lp, is_local = inp
        fn = partial(_layer, cfg, constrain=constrain, moe_ctx=moe_ctx)
        if cfg.remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn(lp, x, positions, is_local), None

    x, _ = jax.lax.scan(body, x, (params["layers"], local_flags))
    return L.rms_norm(x, params["final_norm"])


def unembed_logits(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum(
        "bsd,dv->bsv", h, w.astype(h.dtype), preferred_element_type=jnp.float32
    )
    return L.softcap(logits, cfg.final_softcap)


def loss_fn(
    cfg: TransformerCfg, params: Params, batch: dict, *, constrain=lambda x: x,
    constrain_logits=lambda x: x, moe_ctx: dict | None = None,
) -> jax.Array:
    """Next-token cross-entropy, computed without a [B,S,V] f32 dump.

    The vocab dim shards over 'model'; log-sum-exp and the label gather are
    vocab-local + an all-reduce that GSPMD emits from the sharding.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    h = forward(cfg, params, tokens, constrain=constrain, moe_ctx=moe_ctx)
    logits = constrain_logits(unembed_logits(cfg, params, h))  # [B,S,V] f32, V-sharded
    lmax = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - lmax), axis=-1)) + lmax[..., 0]
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = labels >= 0
    nll = jnp.where(mask, lse - lab, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------


class KVCache:
    """Layout helper: k/v stacked over layers, [L, B, S, Kv, dh] bf16."""

    @staticmethod
    def specs(cfg: TransformerCfg, batch: int, max_seq: int):
        sh = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        return {
            "k": jax.ShapeDtypeStruct(sh, jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(sh, jnp.bfloat16),
        }

    @staticmethod
    def zeros(cfg: TransformerCfg, batch: int, max_seq: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), KVCache.specs(cfg, batch, max_seq)
        )


def prefill(cfg: TransformerCfg, params: Params, tokens, *, constrain=lambda x: x,
            moe_ctx: dict | None = None):
    """Process a prompt; returns (last-position logits, kv cache [L,B,S,Kv,dh])."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    x = constrain(x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    local_flags = (
        (jnp.arange(cfg.n_layers) % cfg.local_every) != (cfg.local_every - 1)
        if cfg.window is not None
        else jnp.zeros((cfg.n_layers,), jnp.bool_)
    )

    def body(x, inp):
        lp, is_local = inp

        def step(lp, x):
            h = L.rms_norm(x, lp["attn_norm"])
            attn, (k, v) = _attention(cfg, lp, h, positions, is_local=is_local)
            if cfg.parallel_residual:
                x = constrain(x + attn + _ffn(cfg, lp, h, moe_ctx))
            else:
                x = constrain(x + attn)
                x = constrain(x + _ffn(cfg, lp, L.rms_norm(x, lp["ffn_norm"]), moe_ctx))
            return x, (k, v)

        fn = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable) if cfg.remat else step
        x, kv = fn(lp, x)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], local_flags))
    h = L.rms_norm(x, params["final_norm"])
    logits = unembed_logits(cfg, params, h[:, -1:, :])[:, 0]
    return logits, {"k": ks, "v": vs}


def decode_step(
    cfg: TransformerCfg,
    params: Params,
    cache: dict,
    tokens_new: jax.Array,  # int32[B] — one token per sequence
    lengths: jax.Array,  # int32[B] current cache fill (new token position)
    *,
    constrain=lambda x: x,
):
    """One autoregressive step against a [L,B,S,Kv,dh] cache. Linear in S."""
    B = tokens_new.shape[0]
    x = jnp.take(params["embed"], tokens_new, axis=0).astype(jnp.bfloat16)  # [B, D]
    pos = lengths.astype(jnp.int32)  # [B]
    local_flags = (
        (jnp.arange(cfg.n_layers) % cfg.local_every) != (cfg.local_every - 1)
        if cfg.window is not None
        else jnp.zeros((cfg.n_layers,), jnp.bool_)
    )

    def body(x, inp):
        lp, is_local, kc, vc = inp
        h = L.rms_norm(x, lp["attn_norm"])  # [B, D]
        q = jnp.einsum("bd,dhk->bhk", h, lp["wq"].astype(h.dtype))
        k = jnp.einsum("bd,dhk->bhk", h, lp["wk"].astype(h.dtype))
        v = jnp.einsum("bd,dhk->bhk", h, lp["wv"].astype(h.dtype))
        q = L.rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = L.rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        # write new k/v at pos (one-hot masked update keeps S shardable)
        S = kc.shape[1]
        onehot = (jnp.arange(S)[None, :] == pos[:, None]).astype(kc.dtype)
        kc = kc * (1 - onehot[..., None, None]) + onehot[..., None, None] * k[:, None]
        vc = vc * (1 - onehot[..., None, None]) + onehot[..., None, None] * v[:, None]
        attn = L.decode_attention(
            q, kc, vc, length=pos + 1, window=cfg.window,
            is_local=is_local if cfg.window is not None else None,
            attn_softcap=cfg.attn_softcap,
        )
        attn = jnp.einsum("bhk,hkd->bd", attn, lp["wo"].astype(h.dtype))
        if cfg.parallel_residual:
            x = x + attn + _ffn(cfg, lp, h[:, None, :])[:, 0]
        else:
            x = x + attn
            x = x + _ffn(cfg, lp, L.rms_norm(x, lp["ffn_norm"])[:, None, :])[:, 0]
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], local_flags, cache["k"], cache["v"])
    )
    h = L.rms_norm(x, params["final_norm"])
    logits = unembed_logits(cfg, params, h[:, None, :])[:, 0]
    return logits, {"k": ks, "v": vs}
