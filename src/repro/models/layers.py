"""Shared neural-net layers (pure JAX, no framework deps).

Everything is functional: params are pytrees of jnp arrays, layers are
functions.  Conventions:

  * activations bf16, params f32 master + bf16 compute cast at use;
  * attention is **chunked online-softmax** (flash-style lax.scan over KV
    blocks) so the S×S score matrix is never materialized — required for the
    32k/500k assigned shapes to fit HBM;
  * GQA: q heads H grouped over Kv kv-heads (H % Kv == 0);
  * optional logit soft-capping (gemma2) and sliding-window masks.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: [..., S, n, dh] (dh even), positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — pure JAX, shape-bounded memory
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_mask(qp, kp, kv_len, causal, window):
    """[cq, ckv] validity mask from absolute positions."""
    m = kp[None, :] < kv_len
    if causal:
        m = m & (kp[None, :] <= qp[:, None])
    if window is not None:
        m = m & (kp[None, :] > qp[:, None] - window)
    return m


def _flash_fwd_impl(q, k, v, causal, window, attn_softcap, skv, scale):
    """q: [B,nq,cq,Kv,G,dh] blocked; k/v: [B,nkv,ckv,Kv,dh] blocked.
    Returns (out [B,nq,cq,Kv,G,dh] f32, lse [B,nq,cq,Kv,G] f32)."""
    B, nq, cq, Kv, G, dh = q.shape
    nkv, ckv = k.shape[1], k.shape[2]
    q_pos = jnp.arange(nq * cq).reshape(nq, cq)
    kv_pos = jnp.arange(nkv * ckv).reshape(nkv, ckv)

    def per_qchunk(args):
        qc, qp = args  # [B,cq,Kv,G,dh], [cq]
        m0 = jnp.full((B, cq, Kv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, Kv, G), jnp.float32)
        a0 = jnp.zeros((B, cq, Kv, G, dh), jnp.float32)

        def body(carry, inp):
            m, l, acc = carry
            kc, vc, kp = inp
            s = jnp.einsum(
                "bqkgd,bckd->bqkgc", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            s = softcap(s, attn_softcap)
            mask = _attn_mask(qp, kp, skv, causal, window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    out, lse = jax.lax.map(per_qchunk, (q.swapaxes(0, 1), q_pos))
    return out.swapaxes(0, 1), lse.swapaxes(0, 1)


def _flash_scores(qc, kc, qp, kp, skv, causal, window, attn_softcap, scale):
    """Recompute one (q-chunk, kv-chunk) score block + d(softcap) factor."""
    raw = jnp.einsum(
        "bqkgd,bckd->bqkgc", qc, kc, preferred_element_type=jnp.float32
    ) * scale
    s = softcap(raw, attn_softcap)
    dcap = (
        1.0 - (s / attn_softcap) ** 2 if attn_softcap is not None
        else jnp.ones_like(s)
    )
    mask = _attn_mask(qp, kp, skv, causal, window)[None, :, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    return s, jnp.where(mask, dcap, 0.0)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, attn_softcap, skv, scale, cq, ckv):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, attn_softcap, skv, scale)
    return out


def _flash_vjp_fwd(q, k, v, causal, window, attn_softcap, skv, scale, cq, ckv):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, attn_softcap, skv, scale)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, attn_softcap, skv, scale, cq, ckv, res, do):
    """Flash backward: two block sweeps (dq; then dk/dv), p recomputed from
    the saved log-sum-exp — memory stays O(B·S·H), no stored probabilities."""
    q, k, v, out, lse = res
    B, nq, _cq, Kv, G, dh = q.shape
    nkv, _ckv = k.shape[1], k.shape[2]
    q_pos = jnp.arange(nq * _cq).reshape(nq, _cq)
    kv_pos = jnp.arange(nkv * _ckv).reshape(nkv, _ckv)
    delta = jnp.sum(do * out, axis=-1)  # [B,nq,cq,Kv,G]

    def dq_chunk(args):
        qc, lsec, doc, dlt, qp = args

        def body(dq_acc, inp):
            kc, vc, kp = inp
            s, dcap = _flash_scores(qc, kc, qp, kp, skv, causal, window, attn_softcap, scale)
            p = jnp.exp(s - lsec[..., None])
            dp = jnp.einsum("bqkgd,bckd->bqkgc", doc, vc.astype(jnp.float32))
            ds = p * (dp - dlt[..., None]) * dcap * scale
            dq_acc = dq_acc + jnp.einsum(
                "bqkgc,bckd->bqkgd", ds, kc.astype(jnp.float32)
            )
            return dq_acc, None

        dq0 = jnp.zeros((B, _cq, Kv, G, dh), jnp.float32)
        dq, _ = jax.lax.scan(body, dq0, (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos))
        return dq

    dq = jax.lax.map(
        dq_chunk,
        (q.swapaxes(0, 1), lse.swapaxes(0, 1), do.swapaxes(0, 1),
         delta.swapaxes(0, 1), q_pos),
    ).swapaxes(0, 1)

    def dkv_chunk(args):
        kc, vc, kp = args

        def body(carry, inp):
            dk_acc, dv_acc = carry
            qc, lsec, doc, dlt, qp = inp
            s, dcap = _flash_scores(qc, kc, qp, kp, skv, causal, window, attn_softcap, scale)
            p = jnp.exp(s - lsec[..., None])
            dv_acc = dv_acc + jnp.einsum("bqkgc,bqkgd->bckd", p, doc)
            dp = jnp.einsum("bqkgd,bckd->bqkgc", doc, vc.astype(jnp.float32))
            ds = p * (dp - dlt[..., None]) * dcap * scale
            dk_acc = dk_acc + jnp.einsum(
                "bqkgc,bqkgd->bckd", ds, qc.astype(jnp.float32)
            )
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, _ckv, Kv, dh), jnp.float32)
        (dk, dv), _ = jax.lax.scan(
            body, (z, z),
            (q.swapaxes(0, 1), lse.swapaxes(0, 1), do.swapaxes(0, 1),
             delta.swapaxes(0, 1), q_pos),
        )
        return dk, dv

    dk, dv = jax.lax.map(dkv_chunk, (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos))
    dk, dv = dk.swapaxes(0, 1), dv.swapaxes(0, 1)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(
    q: jax.Array,  # [B, Sq, H, dh]
    k: jax.Array,  # [B, Skv, Kv, dh]
    v: jax.Array,  # [B, Skv, Kv, dh]
    *,
    causal: bool,
    q_offset: int = 0,  # static; full-sequence paths use 0
    window: int | None = None,  # sliding-window size (None = global)
    attn_softcap: float | None = None,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
) -> jax.Array:
    """Flash attention in pure JAX: online-softmax forward + custom-VJP
    backward that RECOMPUTES score blocks from the saved log-sum-exp.

    Plain AD through the online-softmax scan would stash every probability
    block ([nq·nkv·B·cq·H·ckv] — gigabytes per layer); the custom VJP keeps
    attention memory at O(B·S·H) statistics.  GQA folds q heads into groups
    of the Kv kv-heads.
    """
    B, Sq, H, dh = q.shape
    _, Skv, Kv, _ = k.shape
    G = H // Kv
    scale = 1.0 / math.sqrt(dh)
    orig_sq = Sq
    assert q_offset == 0, "full-sequence path expects q_offset 0 (decode is separate)"

    chunk_q = min(chunk_q, max(Sq, 1))
    chunk_kv = min(chunk_kv, max(Skv, 1))
    pad_q = (-Sq) % chunk_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        Sq = q.shape[1]
    pad_kv = (-Skv) % chunk_kv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    Skv_p = k.shape[1]

    qb = q.reshape(B, Sq // chunk_q, chunk_q, Kv, G, dh)
    kb = k.reshape(B, Skv_p // chunk_kv, chunk_kv, Kv, dh)
    vb = v.reshape(B, Skv_p // chunk_kv, chunk_kv, Kv, dh)
    out = _flash(
        qb, kb, vb, causal, window, attn_softcap, Skv, scale, chunk_q, chunk_kv
    )
    out = out.reshape(B, Sq, H, dh)
    return out[:, :orig_sq].astype(jnp.bfloat16)


def decode_attention(
    q: jax.Array,  # [B, H, dh] — one new token per sequence
    k_cache: jax.Array,  # [B, S, Kv, dh]
    v_cache: jax.Array,  # [B, S, Kv, dh]
    *,
    length: jax.Array,  # [B] or scalar: number of valid cache positions
    window: int | None = None,
    is_local: jax.Array | None = None,  # traced flag: apply window or not
    attn_softcap: float | None = None,
) -> jax.Array:
    """Single-token attention, linear in S.  S may be mesh-sharded; the
    softmax max/sum reductions over S become XLA all-reduces.

    The cache stays bf16 end-to-end (einsum accumulates in f32 via
    preferred_element_type — no f32 copy of a multi-GB cache).  Local
    windows select via the MASK under a traced ``is_local`` flag, so
    local/global layers share one attention computation.
    """
    B, H, dh = q.shape
    _, S, Kv, _ = k_cache.shape
    G = H // Kv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Kv, G, dh)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = softcap(s, attn_softcap)
    pos = jnp.arange(S)[None, None, None, :]
    ln = jnp.asarray(length)
    ln = ln[:, None, None, None] if ln.ndim else ln
    mask = pos < ln
    if window is not None:
        win_mask = pos > ln - 1 - window
        if is_local is not None:
            win_mask = win_mask | ~jnp.asarray(is_local)
        mask = mask & win_mask
    s = jnp.where(mask, s, NEG_INF)
    # p stays f32: it is ~dh·G× smaller than the cache stream (no bandwidth
    # win from bf16) and quantizing it costs visible decode accuracy
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, H, dh).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """Gated-SiLU MLP: (silu(x·w1) ⊙ (x·w3)) · w2."""
    h = jax.nn.silu(x @ w1.astype(x.dtype)) * (x @ w3.astype(x.dtype))
    return h @ w2.astype(x.dtype)


def gelu_mlp(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w1.astype(x.dtype)) @ w2.astype(x.dtype)


def mlp_stack(x: jax.Array, ws: list[jax.Array], bs: list[jax.Array]) -> jax.Array:
    """Plain relu MLP (recsys / GNN blocks)."""
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < len(ws) - 1:
            x = jax.nn.relu(x)
    return x
