"""Distribution utilities: logical-axis sharding rules + gradient compression.

``sharding``  maps model-declared logical axis names ("embed", "ffn",
              "batch", ...) onto the production mesh ("pod", "data",
              "model") — the single place the paper's vertical partitioning
              and the LM/GNN/recsys programs agree on placement.
``compress``  int8 error-feedback gradient all-reduce for the slow
              cross-pod links.
"""
