"""Logical-axis -> mesh-axis sharding rules.

Models annotate parameters/activations with *logical* axis names
(``transformer.logical_axes``, GNN batch fields, the k²-forest predicate
axis); this module turns a tuple of those names into a ``PartitionSpec``
for a concrete mesh.  Rules are overridable per shape cell
(``ShapeSpec.rules_override``) so one arch can flip e.g. vocab-TP on and
off without touching model code.

Resolution per dimension:
  * ``None`` or an unknown name  -> replicated;
  * a rule value may be one mesh axis or a tuple (e.g. ("pod", "data"));
    axes absent from the mesh are dropped (the same rules serve the
    single-pod and multi-pod meshes);
  * a mesh axis is used at most once per spec (first dimension wins);
  * if the dimension size does not divide the mapped axis product, the
    dimension falls back to replicated rather than failing to lower.
"""

from __future__ import annotations

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import jax

# Default placement on the production mesh (see launch/mesh.py):
# 'model' carries TP / EP / the predicate arena; 'data' (+ 'pod') carry DP.
DEFAULT_RULES: dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq_sp": "model",  # sequence-parallel residual stream
    "kv_seq": None,
    # LM params
    "vocab": "model",
    "embed": None,
    "embed_out": None,
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",
    "layers": None,
    # recsys params
    "fields": None,
    "rows": "model",
    # GNN batches
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    # engine
    "preds": "model",
}


def spec_for(mesh: Mesh, names, shape=None, rules=None) -> P:
    """PartitionSpec for logical axis ``names`` of a ``shape`` on ``mesh``."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    used: set[str] = set()
    parts = []
    for i, nm in enumerate(names):
        rule = merged.get(nm) if nm is not None else None
        if rule is None:
            parts.append(None)
            continue
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if not axes or (shape is not None and shape[i] % size != 0):
            parts.append(None)
            continue
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def constrain_fn(mesh: Mesh, names, rules=None):
    """A ``with_sharding_constraint`` closure for activations of ``names``."""

    def constrain(x):
        sh = NamedSharding(mesh, spec_for(mesh, names, x.shape, rules))
        return jax.lax.with_sharding_constraint(x, sh)

    return constrain
