"""int8 error-feedback gradient all-reduce (the cross-pod DP link saver).

The 'pod' mesh axis rides the slow inter-pod links (ICI_BW per link, see
``launch/mesh.py``), so the gradient all-reduce there is the one collective
worth compressing.  Scheme (inside ``shard_map`` over the DP axis):

  1. **error feedback**: x = g + e, where e is the residual carried from the
     previous step — quantization bias turns into dither instead of drift;
  2. **shared scale**: scale = pmax(|x|) / 127 over the axis, so every shard
     quantizes against the SAME grid and the decompressed psum is the exact
     sum of the decompressed values (no per-shard scale mixing);
  3. q = round(x / scale) in int8 — 4x fewer bytes on the wire than f32;
  4. new residual e' = x - scale·q stays local.

With a fixed gradient the time-average of the output converges to the exact
mean at O(scale/N): sum_t deq_t telescopes to N·g + e_0 - e_N.  Verified in
``tests/sharded_driver.py::case_compress``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_decompress_psum(
    g: jax.Array, err: jax.Array, axis: str
) -> tuple[jax.Array, jax.Array]:
    """Compressed DP mean over ``axis`` with error feedback.

    Args:
      g:    local gradient shard (float32, any shape).
      err:  residual from the previous call (same shape; zeros at step 0).
      axis: mapped mesh axis name (must run inside shard_map).

    Returns (mean_gradient, new_residual); the mean is what an exact
    ``psum(g)/n`` would give, up to one int8 quantization step.
    """
    x = g.astype(jnp.float32) + err.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
    scale = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = x - deq
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return jax.lax.psum(deq, axis) / n, new_err
