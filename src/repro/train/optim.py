"""Optimizers (functional, optax-shaped: init / update).

AdamW for the small/medium archs; Adafactor (factored second moment, no
momentum) for the 100B+ archs where AdamW's 8 bytes/param of f32 state can't
fit the per-chip HBM budget — the launcher picks per arch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


def _layerwise(upd):
    """Apply a per-leaf update over axis 0 for layer-stacked leaves.

    Optimizer math is elementwise (or reduces only over trailing dims), so
    mapping over the [L, ...] leading axis is semantics-preserving while
    cutting the f32 temp working set by L× — the difference between 5 GiB
    and 88 MiB scratch per MoE weight at kimi-k2 scale.
    """

    def wrapped(*args):
        p = args[-1]
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd(*a), args)
        return upd(*args)

    return wrapped


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], tuple[Params, Any]]  # (g, state, p) -> (new_p, new_state)
    state_logical_axes: Callable[[Any], Any] | None = None


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"mu": z, "nu": jax.tree.map(jnp.copy, z), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p - lr * upd.astype(p.dtype)).astype(p.dtype), mu, nu

        out = jax.tree.map(
            lambda g, mu, nu, p: _layerwise(upd)(g, mu, nu, p),
            grads, state["mu"], state["nu"], params,
        )
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"mu": new_mu, "nu": new_nu, "step": step}

    def state_axes(param_axes):
        return {
            "mu": param_axes,
            "nu": jax.tree.map(lambda a: a, param_axes),
            "step": (),
        }

    return Optimizer(init, update, state_axes)


def adafactor(
    lr: float = 1e-3, eps: float = 1e-30, decay: float = 0.8, clip_threshold: float = 1.0
) -> Optimizer:
    """Factored second moment: state is O(rows + cols) per matrix."""

    def init(params):
        def per(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"f": jax.tree.map(per, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def per(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
                u = g / jnp.sqrt(jnp.maximum(r[..., None] * vc[..., None, :], 1e-30))
                news = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, 1e-30))
                news = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p - lr * u.astype(p.dtype)).astype(p.dtype), news

        def per_leaf(g, s, p):  # layer-sliced for stacked leaves (memory)
            if p.ndim >= 3 and p.shape[0] > 1 and "vr" in s:
                return jax.lax.map(lambda a: per(*a), (g, s, p))
            return per(g, s, p)

        out = jax.tree.map(
            per_leaf, grads, state["f"], params,
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
        )
        is_pair = lambda t: isinstance(t, tuple)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_f = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return new_p, {"f": new_f, "step": step}

    def state_axes(param_axes):
        def per(ax):
            if ax is None:
                return None
            if len(ax) >= 2:
                return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
            return {"v": tuple(ax)}

        return {
            "f": jax.tree.map(per, param_axes, is_leaf=lambda x: isinstance(x, tuple)),
            "step": (),
        }

    return Optimizer(init, update, state_axes)


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        new_p = jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads)
        return new_p, {"step": state["step"] + 1}

    return Optimizer(init, update, lambda ax: {"step": ()})
