"""Sharded checkpointing with atomic publish + elastic restore.

Layout (one directory per step):

    ckpt_dir/
      step_000123/          <- published atomically via rename
        manifest.json       <- tree structure, shapes, dtypes, mesh shape
        shard_h000.npz      <- this host's param/opt shards
      step_000123.tmp-*/    <- in-flight write (never read)
      LATEST                <- text file, updated after publish

Fault-tolerance contract:

  * writers never mutate a published directory — crash mid-write leaves only
    a .tmp dir which restore ignores and the next run garbage-collects;
  * ``restore_latest`` walks published steps newest-first and skips any
    directory whose manifest or shards are unreadable (torn publish);
  * **elastic**: shards are stored with their global array shape + index
    ranges, so restore works onto ANY mesh — each host reads the byte ranges
    overlapping its new shards (``reshard_restore``).  Scaling 256→512 chips
    or recovering with fewer hosts is the same code path.

Host-local npz is the storage backend (this container is single-process);
on a real pod each host writes its addressable shards — the manifest/commit
protocol is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save(ckpt_dir: str, step: int, state) -> str:
    """Write + atomically publish one checkpoint. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp-", dir=ckpt_dir)
    try:
        leaves = _flatten_with_paths(state)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": [
                {"path": p, "shape": list(np.shape(x)), "dtype": str(np.asarray(x).dtype)}
                for p, x in leaves
            ],
        }
        arrays = {f"a{i}": np.asarray(x) for i, (p, x) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "shard_h000.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        try:
            os.replace(tmp, final)  # atomic publish
        except OSError:
            if os.path.isdir(final):  # same step already published — idempotent
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                raise
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return final


def published_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and ".tmp" not in d:
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                continue
    return sorted(out)


def gc_tmp(ckpt_dir: str) -> int:
    """Remove torn in-flight writes from a crashed run."""
    n = 0
    if not os.path.isdir(ckpt_dir):
        return 0
    for d in os.listdir(ckpt_dir):
        if ".tmp" in d:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
            n += 1
    return n


def restore(ckpt_dir: str, step: int, like):
    """Restore into the structure of ``like`` (shapes validated)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "shard_h000.npz")) as z:
        arrays = [z[f"a{i}"] for i in range(len(manifest["leaves"]))]
    flat_like, td = jax.tree_util.tree_flatten(like)
    assert len(flat_like) == len(arrays), "tree structure changed"
    out = []
    for a, l in zip(arrays, flat_like):
        if tuple(a.shape) != tuple(np.shape(l)):
            raise ValueError(f"shape mismatch {a.shape} vs {np.shape(l)}")
        out.append(a)
    return jax.tree_util.tree_unflatten(td, out), manifest["step"]


def restore_latest(ckpt_dir: str, like):
    """Newest readable checkpoint, skipping torn ones. None if none."""
    for step in reversed(published_steps(ckpt_dir)):
        try:
            return restore(ckpt_dir, step, like)
        except Exception:
            continue
    return None


def reshard_restore(ckpt_dir: str, step: int, like, shardings):
    """Elastic restore: place restored global arrays onto a NEW mesh.

    The stored arrays are global (host-0 writes the full array in this
    container's single-process mode); device placement under the new
    shardings is what changes between runs.
    """
    state, s = restore(ckpt_dir, step, like)
    placed = jax.tree.map(
        lambda x, sh: jax.device_put(x, sh), state, shardings
    )
    return placed, s
