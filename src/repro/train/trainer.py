"""Training loop: grad accumulation, fault tolerance, straggler watchdog.

The loop is deliberately boring — all the sophistication lives in the
compiled step.  What it adds:

  * **auto-resume**: on start, ``restore_latest`` (torn checkpoints skipped,
    tmp dirs GC'd) — a preempted job relaunches with no operator action;
  * **periodic + terminal checkpoints** with atomic publish;
  * **straggler watchdog**: per-step wall time vs a running median; steps
    slower than ``straggler_factor``× median raise a callback (on a real
    fleet this feeds host replacement / checkpoint-restore-elsewhere);
  * **grad accumulation** via ``lax.scan`` over microbatches inside the
    compiled step (constant memory in accumulation depth);
  * optional **int8 DP gradient compression** with error feedback (see
    ``dist.compress``) for the explicit-DP (shard_map) step variant.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt
from repro.train.optim import Optimizer


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_accum: int = 1


def make_train_step(loss_fn: Callable, optimizer: Optimizer, grad_accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With grad_accum > 1, batch's leading axis is [accum, micro, ...] and the
    gradient is averaged over microbatches via a scan (memory-flat).
    """

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, acc, (l, g)), None

            zero = (
                jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss, grads), _ = jax.lax.scan(micro, zero, batch)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


class StragglerWatchdog:
    """Flags steps whose wall time exceeds factor × running median."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window :])
            if dt > self.factor * med:
                self.flagged.append((step, dt, med))
                slow = True
        self.times.append(dt)
        return slow


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        loss_fn: Callable,
        optimizer: Optimizer,
        params,
        *,
        donate: bool = True,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.cfg = cfg
        self.optimizer = optimizer
        # copy: the step donates its inputs; the caller's arrays must survive
        self.params = jax.tree.map(jnp.copy, params) if donate else params
        self.opt_state = optimizer.init(params)
        self.step_num = 0
        self.watchdog = StragglerWatchdog(cfg.straggler_factor)
        self.on_straggler = on_straggler
        step = make_train_step(loss_fn, optimizer, cfg.grad_accum)
        self._step = jax.jit(step, donate_argnums=(0, 1) if donate else ())
        self.history: list[dict] = []

    # -- fault tolerance ---------------------------------------------------
    def try_resume(self) -> bool:
        ckpt.gc_tmp(self.cfg.ckpt_dir)
        got = ckpt.restore_latest(
            self.cfg.ckpt_dir, {"params": self.params, "opt": self.opt_state}
        )
        if got is None:
            return False
        state, step = got
        self.params, self.opt_state = state["params"], state["opt"]
        self.step_num = step
        return True

    def checkpoint(self):
        ckpt.save(
            self.cfg.ckpt_dir, self.step_num,
            {"params": self.params, "opt": self.opt_state},
        )

    # -- the loop ------------------------------------------------------------
    def run(self, batches, n_steps: int, log: Callable[[str], None] = print):
        for _ in range(n_steps):
            batch = next(batches)
            t0 = time.perf_counter()
            self.params, self.opt_state, m = self._step(self.params, self.opt_state, batch)
            loss = float(m["loss"])  # blocks: honest step timing
            dt = time.perf_counter() - t0
            self.step_num += 1
            if self.watchdog.observe(self.step_num, dt) and self.on_straggler:
                self.on_straggler(self.step_num, dt)
            self.history.append({"step": self.step_num, "loss": loss, "dt": dt})
            if self.step_num % self.cfg.log_every == 0:
                log(
                    f"step {self.step_num:6d}  loss {loss:.4f}  "
                    f"gnorm {float(m['grad_norm']):.3f}  {dt*1e3:.1f} ms"
                )
            if self.step_num % self.cfg.ckpt_every == 0:
                self.checkpoint()
        self.checkpoint()
        return self.history
