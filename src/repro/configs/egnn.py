"""egnn — E(n)-equivariant GNN. [arXiv:2102.09844; paper]"""

from repro.configs import base
from repro.models.gnn.egnn import EGNNCfg

CFG = EGNNCfg(name="egnn", n_layers=4, d_hidden=64)
SMOKE = EGNNCfg(name="egnn-smoke", n_layers=2, d_hidden=16)

base.register(
    base.ArchSpec(
        arch_id="egnn", family="gnn", cfg=CFG, smoke_cfg=SMOKE,
        shapes=base.gnn_shapes(), source="arXiv:2102.09844; paper",
    )
)
