"""equiformer-v2 — SO(2)/eSCN equivariant graph attention.
[arXiv:2306.12059; unverified]"""

from repro.configs import base
from repro.models.gnn.equiformer_v2 import EquiformerV2Cfg

CFG = EquiformerV2Cfg(
    name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8
)
SMOKE = EquiformerV2Cfg(
    name="equiformer-v2-smoke", n_layers=2, d_hidden=8, l_max=3, m_max=2, n_heads=2, n_rbf=4
)

base.register(
    base.ArchSpec(
        arch_id="equiformer-v2", family="gnn", cfg=CFG, smoke_cfg=SMOKE,
        shapes=base.gnn_shapes(), source="arXiv:2306.12059; unverified",
    )
)
