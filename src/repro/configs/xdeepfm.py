"""xdeepfm — CIN + DNN CTR model. [arXiv:1803.05170; paper]"""

from repro.configs import base
from repro.models.recsys.xdeepfm import XDeepFMCfg

CFG = XDeepFMCfg(
    name="xdeepfm", n_fields=39, embed_dim=10, rows_per_field=1_000_000,
    cin_layers=(200, 200, 200), mlp_dims=(400, 400),
)
SMOKE = XDeepFMCfg(
    name="xdeepfm-smoke", n_fields=8, embed_dim=6, rows_per_field=1000,
    cin_layers=(16, 16), mlp_dims=(32, 32),
)

base.register(
    base.ArchSpec(
        arch_id="xdeepfm", family="recsys", cfg=CFG, smoke_cfg=SMOKE,
        shapes=base.recsys_shapes(), source="arXiv:1803.05170; paper",
    )
)
