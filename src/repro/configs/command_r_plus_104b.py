"""command-r-plus-104b — dense GQA, no-bias, parallel residual.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from repro.configs import base
from repro.models.transformer import TransformerCfg

CFG = TransformerCfg(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=33792, vocab=256_000,
    parallel_residual=True,  # Cohere parallel attn/ffn block
    rope_theta=75_000_000.0,
)

SMOKE = TransformerCfg(
    name="command-r-plus-104b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab=128, parallel_residual=True, chunk_q=8, chunk_kv=16,
)

base.register(
    base.ArchSpec(
        arch_id="command-r-plus-104b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        shapes=base.lm_shapes(),
        optimizer="adafactor",  # AdamW f32 state (12B/param) busts 16G HBM at 104B
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
)
