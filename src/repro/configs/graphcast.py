"""graphcast — encoder-processor-decoder mesh GNN. [arXiv:2212.12794; unverified]"""

from repro.configs import base
from repro.models.gnn.graphcast import GraphCastCfg

CFG = GraphCastCfg(
    name="graphcast", n_layers=16, d_hidden=512, mesh_refinement=6,
    in_dim=227, out_dim=227, edge_dim=4,
)
SMOKE = GraphCastCfg(
    name="graphcast-smoke", n_layers=2, d_hidden=32, in_dim=16, out_dim=7, edge_dim=4
)

base.register(
    base.ArchSpec(
        arch_id="graphcast", family="gnn", cfg=CFG, smoke_cfg=SMOKE,
        shapes=base.gnn_shapes(), source="arXiv:2212.12794; unverified",
    )
)
