"""tinyllama-1.1b — llama2-arch small, GQA kv=4. [arXiv:2401.02385; hf]"""

from repro.configs import base
from repro.models.transformer import TransformerCfg

CFG = TransformerCfg(
    name="tinyllama-1.1b",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_head=64,
    d_ff=5632, vocab=32_000,
)

SMOKE = TransformerCfg(
    name="tinyllama-1.1b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=176, vocab=128, chunk_q=8, chunk_kv=16,
)

base.register(
    base.ArchSpec(
        arch_id="tinyllama-1.1b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        shapes=base.lm_shapes(),
        optimizer="adamw",
        source="arXiv:2401.02385; hf",
    )
)
