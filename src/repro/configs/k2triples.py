"""k2triples — the PAPER's engine as a first-class arch (extra, beyond the
assigned 10): predicate-sharded k²-tree forest serving SPARQL pattern
batches on the production mesh."""

import dataclasses

from repro.configs import base


@dataclasses.dataclass(frozen=True)
class K2TriplesEngineCfg:
    name: str = "k2triples"
    # dbpedia-scale synthetic store (Table 1 ratios; preds padded to mesh)
    n_triples: int = 1_000_000
    n_subjects: int = 80_000
    n_preds: int = 512
    n_objects: int = 280_000
    cap: int = 1024  # per-scan result capacity


CFG = K2TriplesEngineCfg()
SMOKE = K2TriplesEngineCfg(
    name="k2triples-smoke", n_triples=3000, n_subjects=120, n_preds=16,
    n_objects=150, cap=256,
)

base.register(
    base.ArchSpec(
        arch_id="k2triples",
        family="engine",
        cfg=CFG,
        smoke_cfg=SMOKE,
        shapes=(
            base.ShapeSpec("serve_64k", "serve", dict(batch=65_536)),
            base.ShapeSpec("unbounded_4k", "serve", dict(batch=4096, unbounded=1)),
        ),
        source="this paper",
    )
)
