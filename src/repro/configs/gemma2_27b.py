"""gemma2-27b — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""

from repro.configs import base
from repro.models.transformer import TransformerCfg

CFG = TransformerCfg(
    name="gemma2-27b",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=36864, vocab=256_000,
    window=4096, local_every=2,  # alternating local(4096)/global
    attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True,  # gemma ties in/out embeddings
)

SMOKE = TransformerCfg(
    name="gemma2-27b-smoke",
    n_layers=4, d_model=64, n_heads=8, n_kv_heads=4, d_head=8,
    d_ff=192, vocab=128, window=16, local_every=2,
    attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True,
    chunk_q=8, chunk_kv=16,
)

base.register(
    base.ArchSpec(
        arch_id="gemma2-27b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        shapes=base.lm_shapes(),
        optimizer="adamw",
        source="arXiv:2408.00118; hf",
    )
)
