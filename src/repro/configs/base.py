"""Arch/shape registry shared by the launcher, dry-run and smoke tests."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

ARCHS: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (a dry-run cell is arch × shape × mesh)."""

    shape_id: str
    kind: str  # train | prefill | decode | forward | retrieval | serve
    dims: dict[str, int]  # family-specific sizes
    rules_override: dict[str, Any] = dataclasses.field(default_factory=dict)
    skip: str | None = None  # reason if inapplicable (recorded, not silently)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | engine
    cfg: Any  # full (paper-table) config
    smoke_cfg: Any  # reduced same-family config for CPU smoke tests
    shapes: tuple[ShapeSpec, ...]
    optimizer: str = "adamw"  # adamw | adafactor (大-model memory)
    param_dtype: str = "float32"  # float32 | bfloat16 (1T-class)
    source: str = ""

    def shape(self, shape_id: str) -> ShapeSpec:
        for s in self.shapes:
            if s.shape_id == shape_id:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {shape_id!r}")


def register(spec: ArchSpec) -> ArchSpec:
    ARCHS[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    return ARCHS[arch_id]


# ---------------------------------------------------------------------------
# family-level shape tables (each arch file instantiates these)
# ---------------------------------------------------------------------------


def lm_shapes(*, sub_quadratic: bool = False) -> tuple[ShapeSpec, ...]:
    """The 4 assigned LM shapes.  ``long_500k`` lowers serve_step (decode
    against a 512k KV cache — linear in S), which every arch supports; the
    sub-quadratic caveat applies to 500k PREFILL, which is not an assigned
    shape (see DESIGN.md §6)."""
    return (
        ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
        ShapeSpec(
            "decode_32k", "decode", dict(seq_len=32768, global_batch=128),
            rules_override={"kv_seq": "model"},
        ),
        ShapeSpec(
            "long_500k", "decode", dict(seq_len=524288, global_batch=1),
            rules_override={"batch": None, "kv_seq": ("pod", "data", "model")},
        ),
    )


def gnn_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec(
            "full_graph_sm", "train",
            dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
        ),
        ShapeSpec(
            "minibatch_lg", "train",
            dict(
                n_nodes=232_965, n_edges=114_615_892, d_feat=602,
                batch_nodes=1024, fanouts=(15, 10), n_classes=41,
            ),
        ),
        ShapeSpec(
            "ogb_products", "train",
            dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47),
        ),
        ShapeSpec(
            "molecule", "train",
            dict(n_nodes=30, n_edges=64, batch=128),
        ),
    )


def recsys_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_batch", "train", dict(batch=65_536)),
        ShapeSpec("serve_p99", "forward", dict(batch=512)),
        ShapeSpec("serve_bulk", "forward", dict(batch=262_144)),
        ShapeSpec("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
    )
