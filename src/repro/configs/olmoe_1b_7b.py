"""olmoe-1b-7b — 64 experts top-8 MoE. [arXiv:2409.02060; hf]"""

from repro.configs import base
from repro.models.transformer import MoECfg, TransformerCfg

CFG = TransformerCfg(
    name="olmoe-1b-7b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1024,  # per-expert ff
    vocab=50_304,
    moe=MoECfg(n_experts=64, top_k=8, d_ff_expert=1024, capacity_factor=1.25),
)

SMOKE = TransformerCfg(
    name="olmoe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=32, vocab=128, chunk_q=8, chunk_kv=16,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32),
)

base.register(
    base.ArchSpec(
        arch_id="olmoe-1b-7b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        shapes=base.lm_shapes(),
        optimizer="adamw",
        source="arXiv:2409.02060; hf",
    )
)
