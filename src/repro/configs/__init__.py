"""Arch registry: ``get(arch_id)`` -> ArchSpec; ``ARCHS`` lists all ids."""

from repro.configs.base import ARCHS, ArchSpec, ShapeSpec, get, register

# importing the arch modules populates the registry
from repro.configs import (  # noqa: F401
    command_r_plus_104b,
    tinyllama_1_1b,
    gemma2_27b,
    kimi_k2_1t_a32b,
    olmoe_1b_7b,
    mace,
    graphcast,
    egnn,
    equiformer_v2,
    xdeepfm,
    k2triples,
)
