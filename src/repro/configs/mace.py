"""mace — higher-order equivariant message passing. [arXiv:2206.07697; paper]"""

from repro.configs import base
from repro.models.gnn.mace import MACECfg

CFG = MACECfg(
    name="mace", n_layers=2, d_hidden=128, l_max=2, correlation=3, n_rbf=8
)
SMOKE = MACECfg(
    name="mace-smoke", n_layers=2, d_hidden=8, l_max=2, correlation=3, n_rbf=4
)

base.register(
    base.ArchSpec(
        arch_id="mace", family="gnn", cfg=CFG, smoke_cfg=SMOKE,
        shapes=base.gnn_shapes(), source="arXiv:2206.07697; paper",
    )
)
