"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8.
[arXiv:2501.kimi2; unverified]

Memory arithmetic that drives the optimizer/dtype choices (v5e, 16G HBM,
512 chips): AdamW f32 state = 12 B/param -> 12 TB (23 G/chip, impossible);
bf16 params + Adafactor factored state ≈ 2 TB + ~0 -> 4 G/chip params,
grads bf16 transient 4 G/chip.  See EXPERIMENTS.md §Dry-run for the
measured memory_analysis.
"""

from repro.configs import base
from repro.models.transformer import MoECfg, TransformerCfg

CFG = TransformerCfg(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=112,
    d_ff=2048,  # per-expert ff
    vocab=163_840,
    moe=MoECfg(n_experts=384, top_k=8, d_ff_expert=2048, capacity_factor=1.25),
)

SMOKE = TransformerCfg(
    name="kimi-k2-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=32, vocab=128, chunk_q=8, chunk_kv=16,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32),
)

base.register(
    base.ArchSpec(
        arch_id="kimi-k2-1t-a32b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        shapes=base.lm_shapes(),
        optimizer="adafactor",
        param_dtype="bfloat16",
        source="arXiv:2501.kimi2; unverified",
    )
)
