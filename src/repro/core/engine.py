"""Query engine: host dispatch + jit'd batched ``serve_step`` (single & sharded).

Two layers:

  * ``Engine`` — host-side convenience: takes a triple pattern with ``None``
    for variables, encodes it into the serve IR below, and decodes numpy
    results.  This is the paper's per-query interface (Tables 3/4 are
    measured on it); every keyed pattern rides ONE compiled program.

  * ``make_serve_step`` / ``make_sharded_serve_step`` — the production path:
    one compiled program serving a BATCH of queries spanning all keyed
    patterns — checks, mixed row/col scans, AND the unbounded-predicate
    lanes (the serve IR ops below).

Serve IR: a ``ServeBatch`` lane is ``(op, s, p, o)`` with

    OP_CHECK      (S, P, O)     -> hit flag
    OP_ROW        (S, P, ?O)    -> object list            (ids/valid/count)
    OP_COL        (?S, P, O)    -> subject list           (ids/valid/count)
    OP_S_ANY_O    (S, ?P, O)    -> matching predicates    (ids/valid/count)
    OP_S_ANY_ANY  (S, ?P, ?O)   -> per-pred object lists  (u_* block)
    OP_ANY_ANY_O  (?S, ?P, O)   -> per-pred subject lists (u_* block)

The two full-enumeration patterns ((?S,P,?O) pairs and the (?S,?P,?O) dump)
return pair sets and stay on ``k2forest.range_scan[_all_preds]``.

Unbounded-``?P`` lanes are the paper's conceded worst case.  With a
``predindex.PredIndex`` (k²-triples+, arXiv:1310.4954) they gather their
candidate predicate list from the SP/OP index and launch a PRUNED
``scan_batch_mixed`` of ``u_width`` lanes per query; without one
(``index=None``) they fall back to the all-preds broadcast sweep
(``u_width`` must then cover ``n_preds``) — the differential reference.

Distribution (the paper's vertical partitioning lifted to the mesh):
the forest arena is sharded by predicate over the ``model`` axis; the query
batch is sharded over ``data`` (× ``pod``); the (tiny) predicate index is
replicated.  Inside ``shard_map`` each model shard resolves the queries —
and the candidate predicates — it owns (others masked out) and a ``psum``
over the model axis combines.  The pruned unbounded path reduces
``[B, u_width, cap]`` instead of all-gathering ``[B, P, cap]``: predicate
pruning shrinks the wire bytes by the same factor as the compute.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import joins, k2forest, patterns, predindex
from repro.core.k2forest import K2Forest
from repro.core.k2tree import _compact
from repro.core.k2triples import K2TriplesStore
from repro.core.k2tree import K2Meta
from repro.core.predindex import PredIndex, PredIndexMeta

# serve IR ops
OP_CHECK = 0  # (S, P, O)    -> hit flag
OP_ROW = 1  # (S, P, ?O)   -> object list
OP_COL = 2  # (?S, P, O)   -> subject list
OP_S_ANY_ANY = 3  # (S, ?P, ?O)  -> per-candidate-predicate object lists
OP_ANY_ANY_O = 4  # (?S, ?P, O)  -> per-candidate-predicate subject lists
OP_S_ANY_O = 5  # (S, ?P, O)   -> matching predicate list


class ServeBatch(NamedTuple):
    """Encoded queries (1-based ids; 0 for positions an op leaves free)."""

    op: jax.Array  # int32[B] in the serve IR ops above
    s: jax.Array  # int32[B] subject id (or 0)
    p: jax.Array  # int32[B] predicate id (0 for unbounded-?P ops)
    o: jax.Array  # int32[B] object id (or 0)


class ServeResult(NamedTuple):
    hit: jax.Array  # bool[B]      — checks
    ids: jax.Array  # int32[B,cap] — scans + S?PO predicate lists (1-based)
    valid: jax.Array  # bool[B,cap]
    count: jax.Array  # int32[B]
    overflow: jax.Array  # bool[B]
    # unbounded-?P pair ops (OP_S_ANY_ANY / OP_ANY_ANY_O); width-0 when the
    # serve step was built without unbounded support
    u_preds: jax.Array  # int32[B,L] candidate predicate ids (1-based; 0 dead)
    u_ids: jax.Array  # int32[B,L,cap] per-candidate results (1-based)
    u_valid: jax.Array  # bool[B,L,cap]
    u_count: jax.Array  # int32[B,L]


def _u_candidates(
    q: ServeBatch, f: K2Forest, u_width: int,
    index: PredIndex | None, pmeta: PredIndexMeta | None,
    backend: str | None,
):
    """Candidate predicate lists for the unbounded lanes of a batch.

    Returns ``(is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid,
    ctrunc)``: 0-based candidates in ``cpreds[B, u_width]`` — from the SP/OP
    index when given (S?PO gathers SP; an optimizer may pre-swap s/o-keyed
    lanes), else the all-preds fallback sweep (requires u_width >= P).
    """
    is_u_pair = (q.op == OP_S_ANY_ANY) | (q.op == OP_ANY_ANY_O)
    is_u_check = q.op == OP_S_ANY_O
    is_u = is_u_pair | is_u_check
    u_axis = jnp.where(q.op == OP_ANY_ANY_O, 1, 0).astype(jnp.int32)
    u_key = jnp.maximum(jnp.where(u_axis == 1, q.o, q.s) - 1, 0)
    u_key = jnp.where(is_u, u_key, 0)
    b = q.op.shape[0]
    if index is not None:
        rows = jnp.where(u_axis == 1, pmeta.n_subjects + u_key, u_key)
        g = predindex.gather_batch(
            pmeta, index, jnp.where(is_u, rows, 0), u_width, backend
        )
        cpreds, cvalid, ctrunc = g.ids, g.valid, g.overflow
    else:
        if u_width < f.n_preds:
            raise ValueError(
                f"all-preds fallback needs u_width >= n_preds "
                f"({u_width} < {f.n_preds}); pass an index to prune"
            )
        lane = jnp.arange(u_width, dtype=jnp.int32)
        cpreds = jnp.broadcast_to(lane, (b, u_width))
        cvalid = jnp.broadcast_to(lane < f.n_preds, (b, u_width))
        ctrunc = jnp.zeros((b,), jnp.bool_)
    cvalid = cvalid & is_u[:, None]
    return is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid, ctrunc


def _serve_local(
    meta: K2Meta, f: K2Forest, q: ServeBatch, cap: int,
    backend: str | None = None, *,
    index: PredIndex | None = None, pmeta: PredIndexMeta | None = None,
    u_width: int = 0,
) -> ServeResult:
    """Resolve a batch against a (possibly local-shard) forest.

    ``backend`` selects the scan substrate ("pallas" kernel / "jnp"
    traversal; None = the ``REPRO_SCAN_BACKEND`` flag in kernels/ops.py).
    ``u_width`` > 0 enables the unbounded-?P lanes (candidate slots per
    query); 0 compiles them out entirely.
    """
    b = q.op.shape[0]
    is_check = q.op == OP_CHECK
    hit = k2forest.check(
        meta, f, jnp.maximum(q.p - 1, 0), q.s - 1, q.o - 1
    ) & is_check
    axes = jnp.where(q.op == OP_COL, 1, 0).astype(jnp.int32)
    key = jnp.maximum(jnp.where(q.op == OP_COL, q.o, q.s) - 1, 0)
    r = k2forest.scan_batch_mixed(
        meta, f, jnp.maximum(q.p - 1, 0), key, axes, cap, backend
    )
    scan_lane = (q.op == OP_ROW) | (q.op == OP_COL)
    valid = r.valid & scan_lane[:, None]
    ids = jnp.where(valid, r.ids + 1, 0)
    count = jnp.where(scan_lane, r.count, 0)
    overflow = r.overflow & scan_lane

    if u_width <= 0:
        return ServeResult(
            hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
            u_preds=jnp.zeros((b, 0), jnp.int32),
            u_ids=jnp.zeros((b, 0, cap), jnp.int32),
            u_valid=jnp.zeros((b, 0, cap), jnp.bool_),
            u_count=jnp.zeros((b, 0), jnp.int32),
        )

    is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid, ctrunc = (
        _u_candidates(q, f, u_width, index, pmeta, backend)
    )
    preds_f = jnp.where(cvalid, cpreds, 0).reshape(b * u_width)
    keys_f = jnp.repeat(u_key, u_width)

    # pair lanes: one pruned mixed scan replaces the P-way broadcast sweep
    ru = k2forest.scan_batch_mixed(
        meta, f, preds_f, keys_f, jnp.repeat(u_axis, u_width), cap, backend
    )
    pair_valid = cvalid & is_u_pair[:, None]
    u_valid = ru.valid.reshape(b, u_width, cap) & pair_valid[:, :, None]
    u_ids = jnp.where(u_valid, ru.ids.reshape(b, u_width, cap) + 1, 0)
    u_count = jnp.where(pair_valid, ru.count.reshape(b, u_width), 0)
    u_preds = jnp.where(pair_valid, cpreds + 1, 0)
    overflow = overflow | (
        is_u_pair
        & ((ru.overflow.reshape(b, u_width) & pair_valid).any(axis=1) | ctrunc)
    )

    # S?PO lanes: check candidates, compact matching predicate ids into ids.
    # NOTE this intentionally diverges from predindex.check_pruned_batch
    # (which compacts into u_width slots and so can never truncate): the
    # serve IR must fit the shared (B, cap) ids buffer, so matches beyond
    # cap truncate WITH the overflow bit set — callers (Engine.pattern)
    # must honor it.  Keep the three gather→check/scan→mask copies (here,
    # the sharded _local, predindex.*_pruned_batch) in sync when touching
    # the contract.
    hitm = k2forest.check(
        meta, f, preds_f,
        jnp.repeat(jnp.maximum(q.s - 1, 0), u_width),
        jnp.repeat(jnp.maximum(q.o - 1, 0), u_width),
    ).reshape(b, u_width) & cvalid & is_u_check[:, None]
    valid5, count5, ovf5, (ids5,) = jax.vmap(
        lambda v, a: _compact(v, cap, a)
    )(hitm, jnp.where(hitm, cpreds + 1, 0))
    ids = jnp.where(is_u_check[:, None], ids5, ids)
    valid = jnp.where(is_u_check[:, None], valid5, valid)
    count = jnp.where(is_u_check, count5, count)
    overflow = overflow | (is_u_check & (ovf5 | ctrunc))

    return ServeResult(
        hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
        u_preds=u_preds, u_ids=u_ids, u_valid=u_valid, u_count=u_count,
    )


def make_serve_step(
    meta: K2Meta, cap: int, *, backend: str | None = None,
    pmeta: PredIndexMeta | None = None, u_width: int | None = None,
):
    """Single-device jit'd serve program.

    ``u_width`` candidate slots per unbounded lane (default:
    ``pmeta.max_degree`` when an index meta is given, else 0 = unbounded
    ops compiled out).  Call as ``serve_step(forest, batch[, index])`` —
    passing ``index=None`` with ``u_width >= n_preds`` runs the all-preds
    fallback sweep.
    """
    if u_width is None:
        u_width = pmeta.max_degree if pmeta is not None else 0

    @jax.jit
    def serve_step(f: K2Forest, q: ServeBatch, index=None) -> ServeResult:
        return _serve_local(
            meta, f, q, cap, backend, index=index, pmeta=pmeta, u_width=u_width
        )

    return serve_step


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------


def shard_forest(f: K2Forest, mesh: Mesh, axis: str = "model") -> K2Forest:
    """Place the arena with the predicate dimension sharded over ``axis``."""
    sh = NamedSharding(mesh, P(axis))
    return K2Forest(*(jax.device_put(a, sh) for a in f))


def forest_pspecs(axis: str = "model") -> K2Forest:
    return K2Forest(
        t_words=P(axis), t_rank=P(axis), l_words=P(axis),
        ones_before=P(axis), level_start=P(axis), nnz=P(axis),
    )


def pad_preds(f: K2Forest, multiple: int) -> K2Forest:
    """Pad the predicate axis so it divides the model-axis size.

    Padded trees are all-zeros (valid empty k²-trees): queries routed to them
    return no results, so padding is semantically inert.
    """
    Pn = f.n_preds
    pad = (-Pn) % multiple
    if pad == 0:
        return f
    out = []
    for a in f:
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, cfg))
    return K2Forest(*out)


def make_sharded_serve_step(
    meta: K2Meta, mesh: Mesh, cap: int, *, data_axes=("data",),
    model_axis="model", backend: str | None = None,
    pmeta: PredIndexMeta | None = None, u_width: int | None = None,
):
    """shard_map'd serve program: forest by predicate, queries by batch.

    Every model shard holds P/mp trees with LOCAL indices; a query with
    global predicate g is owned by shard g // P_loc and resolved there with
    local id g % P_loc; other shards compute a masked (empty) traversal and
    the ``psum`` over the model axis merges.

    With ``pmeta`` (and a replicated ``PredIndex`` third argument) the
    unbounded IR ops are served too: candidates are gathered identically on
    every shard, each shard scans only the candidates it owns, and the psum
    assembles the ``[B, u_width, cap]`` block — the index-pruned counterpart
    of ``make_sharded_unbounded_scan``'s ``[B, P, cap]`` all-gather.
    Signature: ``fn(forest, batch)`` without an index, ``fn(forest, batch,
    index)`` with one.
    """
    if u_width is None:
        u_width = pmeta.max_degree if pmeta is not None else 0
    if u_width > 0 and pmeta is None:
        raise ValueError("sharded unbounded serve requires a pred index (pmeta)")
    mp = int(np.prod([mesh.shape[a] for a in (model_axis,)]))

    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    qspec = ServeBatch(op=P(dax), s=P(dax), p=P(dax), o=P(dax))
    fspec = forest_pspecs(model_axis)
    out_spec = ServeResult(
        hit=P(dax), ids=P(dax), valid=P(dax),
        count=P(dax), overflow=P(dax),
        u_preds=P(dax), u_ids=P(dax), u_valid=P(dax), u_count=P(dax),
    )

    def _local(f_loc: K2Forest, q: ServeBatch, index=None) -> ServeResult:
        p_loc = f_loc.t_words.shape[0]  # local predicate count
        b = q.op.shape[0]
        shard = jax.lax.axis_index(model_axis)
        g = q.p - 1  # 0-based global predicate
        owner = g // p_loc
        mine = owner == shard
        lp = jnp.where(mine, g % p_loc, 0).astype(jnp.int32)
        q_loc = ServeBatch(
            op=jnp.where(mine, q.op, -1), s=q.s, p=lp + 1, o=q.o
        )
        r = _serve_local(meta, f_loc, q_loc, cap, backend)
        # MINIMAL psum payload: only the id matrix and two bit-vectors go on
        # the wire; `valid` (== ids != 0) and `count` are re-derived locally
        # after the reduce.  This halves the all-reduce bytes vs reducing the
        # full ServeResult (§Perf hillclimb on the paper's own program).
        ids = jax.lax.psum(jnp.where(mine[:, None], r.ids, 0), model_axis)
        flags = jax.lax.psum(
            jnp.where(
                mine,
                r.hit.astype(jnp.int32) + 2 * r.overflow.astype(jnp.int32),
                0,
            ),
            model_axis,
        )
        valid = ids != 0
        hit = (flags & 1).astype(jnp.bool_)
        overflow = ((flags >> 1) & 1).astype(jnp.bool_)
        count = valid.sum(axis=-1).astype(jnp.int32)

        if u_width <= 0:
            return ServeResult(
                hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
                u_preds=jnp.zeros((b, 0), jnp.int32),
                u_ids=jnp.zeros((b, 0, cap), jnp.int32),
                u_valid=jnp.zeros((b, 0, cap), jnp.bool_),
                u_count=jnp.zeros((b, 0), jnp.int32),
            )

        # unbounded lanes: candidates gathered replicated (index is
        # replicated), each shard scans/checks only the candidates it owns
        is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid, ctrunc = (
            _u_candidates(q, f_loc, u_width, index, pmeta, backend)
        )
        owner_u = cpreds // p_loc
        mine_u = cvalid & (owner_u == shard)
        preds_f = jnp.where(mine_u, cpreds % p_loc, 0).reshape(b * u_width)
        keys_f = jnp.repeat(u_key, u_width)

        ru = k2forest.scan_batch_mixed(
            meta, f_loc, preds_f, keys_f, jnp.repeat(u_axis, u_width), cap,
            backend,
        )
        pair_mine = mine_u & is_u_pair[:, None]
        uv_loc = ru.valid.reshape(b, u_width, cap) & pair_mine[:, :, None]
        u_ids = jax.lax.psum(
            jnp.where(uv_loc, ru.ids.reshape(b, u_width, cap) + 1, 0),
            model_axis,
        )
        hitm_loc = k2forest.check(
            meta, f_loc, preds_f,
            jnp.repeat(jnp.maximum(q.s - 1, 0), u_width),
            jnp.repeat(jnp.maximum(q.o - 1, 0), u_width),
        ).reshape(b, u_width) & mine_u & is_u_check[:, None]
        # one packed [B, u_width] reduce: check hits (bit 0), per-candidate
        # counts (needed because a count can legitimately be 0 with no ids)
        packed = jax.lax.psum(
            hitm_loc.astype(jnp.int32)
            + 2 * jnp.where(pair_mine, ru.count.reshape(b, u_width), 0),
            model_axis,
        )
        hitm = (packed & 1) == 1
        u_count = packed >> 1
        pair_ovf = jax.lax.psum(
            (ru.overflow.reshape(b, u_width) & pair_mine)
            .any(axis=1).astype(jnp.int32),
            model_axis,
        ) > 0
        # replicated post-reduce compute (identical on every shard)
        u_valid = u_ids != 0
        u_preds = jnp.where(cvalid & is_u_pair[:, None], cpreds + 1, 0)
        valid5, count5, ovf5, (ids5,) = jax.vmap(
            lambda v, a: _compact(v, cap, a)
        )(hitm, jnp.where(hitm, cpreds + 1, 0))
        ids = jnp.where(is_u_check[:, None], ids5, ids)
        valid = jnp.where(is_u_check[:, None], valid5, valid)
        count = jnp.where(is_u_check, count5, count)
        overflow = (
            overflow
            | (is_u_pair & (pair_ovf | ctrunc))
            | (is_u_check & (ovf5 | ctrunc))
        )
        return ServeResult(
            hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
            u_preds=u_preds, u_ids=u_ids, u_valid=u_valid, u_count=u_count,
        )

    if u_width > 0:
        ispec = PredIndex(offsets=P(), words=P())  # replicated
        fn = shard_map(
            _local, mesh=mesh, in_specs=(fspec, qspec, ispec),
            out_specs=out_spec,
            check_vma=False,  # pallas_call has no replication rule
        )
    else:
        fn = shard_map(
            lambda f_loc, q: _local(f_loc, q), mesh=mesh,
            in_specs=(fspec, qspec), out_specs=out_spec,
            check_vma=False,  # pallas_call has no replication rule (scan kernel)
        )
    return jax.jit(fn)


def make_sharded_unbounded_scan(
    meta: K2Meta, mesh: Mesh, cap: int, *, data_axes=("data",), model_axis="model",
    backend: str | None = None,
):
    """(S,?P,?O) / (?S,?P,O) batch: every shard scans its LOCAL predicates,
    results all-gathered over the model axis -> [B, P_padded, cap].

    This is the paper's vertical-partitioning worst case turned into an
    embarrassingly parallel sweep — kept as the index-free fallback and the
    differential reference for the index-pruned unbounded lanes of
    ``make_sharded_serve_step``.  The local sweep is one flat
    (b · P_loc)-query ``scan_batch_mixed`` launch, so it follows the
    ``REPRO_SCAN_BACKEND`` flag (Pallas kernel / jnp reference) like the
    bounded-predicate serve path.
    """
    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    qP = P(dax)
    fspec = forest_pspecs(model_axis)

    def _local(f_loc: K2Forest, keys: jax.Array, axes: jax.Array):
        p_loc = f_loc.t_words.shape[0]
        b = keys.shape[0]
        # the all-preds sweep as one batched mixed scan with broadcast keys
        preds_f = jnp.tile(jnp.arange(p_loc, dtype=jnp.int32), b)
        keys_f = jnp.repeat(keys - 1, p_loc)
        axes_f = jnp.repeat(axes, p_loc)
        r = k2forest.scan_batch_mixed(
            meta, f_loc, preds_f, keys_f, axes_f, cap, backend
        )
        ids = jnp.where(r.valid, r.ids + 1, 0).reshape(b, p_loc, cap)
        valid = r.valid.reshape(b, p_loc, cap)
        count = r.count.reshape(b, p_loc)
        ids = jax.lax.all_gather(ids, model_axis, axis=1, tiled=True)
        valid = jax.lax.all_gather(valid, model_axis, axis=1, tiled=True)
        count = jax.lax.all_gather(count, model_axis, axis=1, tiled=True)
        return ids, valid, count

    fn = shard_map(
        _local, mesh=mesh, in_specs=(fspec, qP, qP), out_specs=(qP, qP, qP),
        check_vma=False,  # all_gather(tiled) replication defeats VMA inference
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# host-side convenience engine (the unified plan→serve pipeline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Engine:
    """Paper-facing interface: patterns with None variables + joins A–F.

    ``pattern`` encodes every keyed pattern into the serve IR and runs it
    through ONE cached compiled ``serve_step`` — check, row/col scan, and
    the three unbounded-?P ops all share a program.  Unbounded lanes are
    index-pruned when the store carries a ``pred_index`` (the default);
    ``use_pred_index=False`` forces the all-preds fallback sweep.
    """

    store: K2TriplesStore
    cap: int = 4096
    backend: str | None = None
    use_pred_index: bool = True
    _serve_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    @property
    def meta(self) -> K2Meta:
        return self.store.meta

    @property
    def forest(self) -> K2Forest:
        return self.store.forest

    def _pidx(self):
        return self.store.pred_index if self.use_pred_index else None

    def _serve(self, unbounded: bool):
        # cache keyed on the live config so mutating cap/backend/
        # use_pred_index after a query builds a fresh program; bounded ops
        # get their own u_width=0 program so a plain check/scan never pays
        # for the (masked) unbounded block
        key = (self.cap, self.backend, self.use_pred_index, unbounded)
        cache = self._serve_cache
        if key not in cache:
            bi = self._pidx()
            if not unbounded:
                cache[key] = make_serve_step(
                    self.meta, self.cap, backend=self.backend
                )
            elif bi is not None:
                cache[key] = make_serve_step(
                    self.meta, self.cap, backend=self.backend, pmeta=bi.meta,
                    u_width=max(bi.meta.max_degree, 1),
                )
            else:
                cache[key] = make_serve_step(
                    self.meta, self.cap, backend=self.backend,
                    u_width=self.store.n_preds,
                )
        return cache[key]

    def pattern(self, s: int | None, p: int | None, o: int | None):
        """Resolve one triple pattern; returns numpy (see the op table)."""
        m, f, cap = self.meta, self.forest, self.cap
        if p and not s and not o:  # (?S, P, ?O): pair enumeration
            r = patterns.any_p_any(m, f, p, cap, self.backend)
            v = np.asarray(r.valid)
            return np.stack([np.asarray(r.rows)[v], np.asarray(r.cols)[v]], axis=1)
        if not s and not p and not o:  # (?S, ?P, ?O): dump
            r = patterns.dump(m, f, cap, self.backend)
            out = {}
            for pi in range(self.store.n_preds):
                v = np.asarray(r.valid[pi])
                if v.any():
                    out[pi + 1] = np.stack(
                        [np.asarray(r.rows[pi])[v], np.asarray(r.cols[pi])[v]],
                        axis=1,
                    )
            return out

        if s and p and o:
            op = OP_CHECK
        elif s and p:
            op = OP_ROW
        elif p and o:
            op = OP_COL
        elif s and o:
            op = OP_S_ANY_O
        elif s:
            op = OP_S_ANY_ANY
        else:
            op = OP_ANY_ANY_O
        q = ServeBatch(
            op=jnp.asarray([op], jnp.int32),
            s=jnp.asarray([s or 0], jnp.int32),
            p=jnp.asarray([p or 0], jnp.int32),
            o=jnp.asarray([o or 0], jnp.int32),
        )
        unbounded = op in (OP_S_ANY_O, OP_S_ANY_ANY, OP_ANY_ANY_O)
        bi = self._pidx()
        r = self._serve(unbounded)(
            f, q, bi.device if (unbounded and bi is not None) else None
        )
        if op == OP_CHECK:
            return bool(np.asarray(r.hit)[0])
        if op in (OP_ROW, OP_COL, OP_S_ANY_O):
            if op == OP_S_ANY_O and bool(np.asarray(r.overflow)[0]):
                # the legacy bool[P] path was exact at any cap; never
                # silently hand back a truncated predicate list
                raise RuntimeError(
                    "(S,?P,O) matches exceed cap; raise Engine.cap"
                )
            return np.asarray(r.ids)[0][np.asarray(r.valid)[0]]
        u_preds = np.asarray(r.u_preds)[0]
        u_ids = np.asarray(r.u_ids)[0]
        u_valid = np.asarray(r.u_valid)[0]
        return {
            int(u_preds[l]): u_ids[l][u_valid[l]]
            for l in range(u_preds.shape[0])
            if u_preds[l] and u_valid[l].any()
        }

    # joins ------------------------------------------------------------
    def join(self, category: str, **kw):
        m, f = self.meta, self.forest
        cap = kw.pop("cap", self.cap)
        cap_y = kw.pop("cap_y", 256)
        if category == "A":
            r = joins.join_a(m, f, cap=cap, **kw)
            return np.asarray(r.ids)[np.asarray(r.valid)]
        if category == "B":
            r = joins.join_b(m, f, cap=cap, **kw)
            ids, valid = np.asarray(r.ids), np.asarray(r.valid)
            return {pi + 1: ids[pi][valid[pi]] for pi in range(ids.shape[0]) if valid[pi].any()}
        if category == "C":
            r = joins.join_c(m, f, cap=cap, **kw)
            return np.asarray(r.ids)[np.asarray(r.valid)]
        if category == "D":
            r = joins.join_d(m, f, cap_x=cap, cap_y=cap_y, **kw)
            return _pairs_to_dict(r)
        if category == "E":
            r = joins.join_e(m, f, cap_x=cap, cap_y=cap_y, **kw)
            return _pairs_to_dict_pred(r)
        if category == "F":
            r = joins.join_f(m, f, cap_x=cap, cap_y=cap_y, **kw)
            return _pairs_to_dict_pred(r)
        raise ValueError(f"unknown join category {category!r}")


def _pairs_to_dict(r: joins.JoinPairs) -> dict[int, np.ndarray]:
    xs, xv = np.asarray(r.x_ids), np.asarray(r.x_valid)
    ys, yv = np.asarray(r.y_ids), np.asarray(r.y_valid)
    out = {}
    for i in range(xs.shape[0]):
        if xv[i] and yv[i].any():
            out[int(xs[i])] = ys[i][yv[i]]
    return out


def _pairs_to_dict_pred(r: joins.JoinPairs) -> dict[int, dict[int, np.ndarray]]:
    out: dict[int, dict[int, np.ndarray]] = {}
    xs, xv = np.asarray(r.x_ids), np.asarray(r.x_valid)
    ys, yv = np.asarray(r.y_ids), np.asarray(r.y_valid)
    for p in range(xs.shape[0]):
        d = {}
        for i in range(xs.shape[1]):
            if xv[p, i] and yv[p, i].any():
                d[int(xs[p, i])] = ys[p, i][yv[p, i]]
        if d:
            out[p + 1] = d
    return out
