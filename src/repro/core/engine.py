"""Query engine: ``Engine.compile(query, config) -> Plan`` over one jit'd
batched ``serve_step`` (single & sharded).

Two layers:

  * ``Engine`` — the ONE host-side entry point: lowers any ``core.query``
    description (``TriplePatternQ`` / ``JoinQ`` / ``BgpQ`` / ``ServeQ``)
    under a frozen ``ExecConfig`` into a cached compiled ``Plan``.  Every
    keyed pattern, join side-list, and BGP step rides the pooled serve-IR
    programs below; cap overflow recovers by CapPolicy doubling.  The
    pre-redesign ``Engine.pattern`` / ``Engine.join`` survive as
    deprecation shims over ``compile``.

  * ``make_serve_step`` / ``make_sharded_serve_step`` — the compiled
    substrate: one program serving a BATCH of queries spanning all keyed
    patterns — checks, mixed row/col scans, AND the unbounded-predicate
    lanes (the serve IR ops below).  ``backend`` accepts an ``ExecConfig``
    (explicit backend + interpret, zero env reads at trace time) or the
    legacy string/None forms.

Serve IR: a ``ServeBatch`` lane is ``(op, s, p, o)`` with

    OP_CHECK      (S, P, O)     -> hit flag
    OP_ROW        (S, P, ?O)    -> object list            (ids/valid/count)
    OP_COL        (?S, P, O)    -> subject list           (ids/valid/count)
    OP_S_ANY_O    (S, ?P, O)    -> matching predicates    (ids/valid/count)
    OP_S_ANY_ANY  (S, ?P, ?O)   -> per-pred object lists  (u_* block)
    OP_ANY_ANY_O  (?S, ?P, O)   -> per-pred subject lists (u_* block)

The two full-enumeration patterns ((?S,P,?O) pairs and the (?S,?P,?O) dump)
return pair sets and stay on ``k2forest.range_scan[_all_preds]``.

Unbounded-``?P`` lanes are the paper's conceded worst case.  With a
``predindex.PredIndex`` (k²-triples+, arXiv:1310.4954) they gather their
candidate predicate list from the SP/OP index and launch a PRUNED
``scan_batch_mixed`` of ``u_width`` lanes per query; without one
(``index=None``) they fall back to the all-preds broadcast sweep
(``u_width`` must then cover ``n_preds``) — the differential reference.

Distribution (the paper's vertical partitioning lifted to the mesh):
the forest arena is sharded by predicate over the ``model`` axis; the query
batch is sharded over ``data`` (× ``pod``); the (tiny) predicate index is
replicated.  Inside ``shard_map`` each model shard resolves the queries —
and the candidate predicates — it owns (others masked out) and a ``psum``
over the model axis combines.  The pruned unbounded path reduces
``[B, u_width, cap]`` instead of all-gathering ``[B, P, cap]``: predicate
pruning shrinks the wire bytes by the same factor as the compute.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.compat import shard_map
from repro.core import delta as dyn
from repro.core import joins, k2forest, patterns, predindex, query as qapi
from repro.obs import cost as obs_cost
from repro.core.k2forest import K2Forest
from repro.core.k2tree import _compact
from repro.core.k2triples import K2TriplesStore
from repro.core.k2tree import K2Meta
from repro.core.predindex import PredIndex, PredIndexMeta
from repro.core import algebra
from repro.core.query import (
    BgpQ, CapOverflow, ExecConfig, JoinQ, Plan, SelectQ, ServeQ,
    TriplePatternQ,
)
from repro.core.sortedset import SENTINEL, IdSet
from repro.core import sortedset

# serve IR ops
OP_CHECK = 0  # (S, P, O)    -> hit flag
OP_ROW = 1  # (S, P, ?O)   -> object list
OP_COL = 2  # (?S, P, O)   -> subject list
OP_S_ANY_ANY = 3  # (S, ?P, ?O)  -> per-candidate-predicate object lists
OP_ANY_ANY_O = 4  # (?S, ?P, O)  -> per-candidate-predicate subject lists
OP_S_ANY_O = 5  # (S, ?P, O)   -> matching predicate list


class ServeBatch(NamedTuple):
    """Encoded queries (1-based ids; 0 for positions an op leaves free)."""

    op: jax.Array  # int32[B] in the serve IR ops above
    s: jax.Array  # int32[B] subject id (or 0)
    p: jax.Array  # int32[B] predicate id (0 for unbounded-?P ops)
    o: jax.Array  # int32[B] object id (or 0)


class ServeResult(NamedTuple):
    hit: jax.Array  # bool[B]      — checks
    ids: jax.Array  # int32[B,cap] — scans + S?PO predicate lists (1-based)
    valid: jax.Array  # bool[B,cap]
    count: jax.Array  # int32[B]
    overflow: jax.Array  # bool[B]
    # unbounded-?P pair ops (OP_S_ANY_ANY / OP_ANY_ANY_O); width-0 when the
    # serve step was built without unbounded support
    u_preds: jax.Array  # int32[B,L] candidate predicate ids (1-based; 0 dead)
    u_ids: jax.Array  # int32[B,L,cap] per-candidate results (1-based)
    u_valid: jax.Array  # bool[B,L,cap]
    u_count: jax.Array  # int32[B,L]


def take_lanes(q: ServeBatch, idx) -> ServeBatch:
    """Split a batch: the sub-``ServeBatch`` of lanes ``idx`` (any numpy
    fancy index).  The streamed-serving splitting hook — brokers carve
    per-tenant retry batches out of a coalesced one without re-encoding."""
    idx = np.asarray(idx)
    return ServeBatch(*(np.asarray(a)[idx] for a in q))


def host_result(r: ServeResult, *, unbounded: bool = True) -> ServeResult:
    """ONE blocking device->host fetch of a ``ServeResult`` (numpy fields).

    This is where a streamed decode pays its sync; calling it on batch N
    after submitting batch N+1 (``Plan.submit``) is the double-buffering
    pattern.  ``unbounded=False`` skips the ``u_*`` block — by far the
    largest transfer (``[B, L, cap]``) — for batches the caller knows
    carry no unbounded-``?P`` lanes.
    """
    t = obs.STATE.tracer
    if t is None:
        return _host_result(r, unbounded)
    with t.span("engine.fetch", cat="engine",
                b=int(r.ids.shape[0]), unbounded=unbounded):
        return _host_result(r, unbounded)


def _host_result(r: ServeResult, unbounded: bool) -> ServeResult:
    jax.block_until_ready(r.ids)
    b = r.ids.shape[0]
    if unbounded:
        return ServeResult(*(np.asarray(a) for a in r))
    return ServeResult(
        hit=np.asarray(r.hit), ids=np.asarray(r.ids),
        valid=np.asarray(r.valid), count=np.asarray(r.count),
        overflow=np.asarray(r.overflow),
        u_preds=np.zeros((b, 0), np.int32),
        u_ids=np.zeros((b, 0, r.ids.shape[1]), np.int32),
        u_valid=np.zeros((b, 0, r.ids.shape[1]), np.bool_),
        u_count=np.zeros((b, 0), np.int32),
    )


def decode_lane(op: int, r: ServeResult, i: int):
    """Decode ONE lane of a host-side ``ServeResult`` into its python-level
    answer (the per-op shapes ``_PatternExec._decode`` returns):

      OP_CHECK -> bool;  OP_ROW / OP_COL -> sorted id array;
      OP_S_ANY_O -> matching predicate id array;
      OP_S_ANY_ANY / OP_ANY_ANY_O -> {pred id: id array}.

    Lane-at-a-time is the streaming decode unit: a broker resolves each
    tenant's queries as their lanes decode instead of materializing a
    batch-level result object.
    """
    t = obs.STATE.tracer
    if t is None:
        return _decode_lane(op, r, i)
    with t.span("plan.decode_lane", cat="plan", op=int(op)):
        return _decode_lane(op, r, i)


def _decode_lane(op: int, r: ServeResult, i: int):
    if op == OP_CHECK:
        return bool(r.hit[i])
    if op in (OP_ROW, OP_COL, OP_S_ANY_O):
        return r.ids[i][r.valid[i]]
    if op in (OP_S_ANY_ANY, OP_ANY_ANY_O):
        return {
            int(r.u_preds[i, l]): r.u_ids[i, l][r.u_valid[i, l]]
            for l in range(r.u_preds.shape[1])
            if r.u_preds[i, l] and r.u_valid[i, l].any()
        }
    raise ValueError(f"not a decodable serve op: {op}")


def _u_candidates(
    q: ServeBatch, f: K2Forest, u_width: int,
    index: PredIndex | None, pmeta: PredIndexMeta | None,
    backend: str | None,
):
    """Candidate predicate lists for the unbounded lanes of a batch.

    Returns ``(is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid,
    ctrunc)``: 0-based candidates in ``cpreds[B, u_width]`` — from the SP/OP
    index when given (S?PO gathers SP; an optimizer may pre-swap s/o-keyed
    lanes), else the all-preds fallback sweep (requires u_width >= P).
    """
    is_u_pair = (q.op == OP_S_ANY_ANY) | (q.op == OP_ANY_ANY_O)
    is_u_check = q.op == OP_S_ANY_O
    is_u = is_u_pair | is_u_check
    u_axis = jnp.where(q.op == OP_ANY_ANY_O, 1, 0).astype(jnp.int32)
    u_key = jnp.maximum(jnp.where(u_axis == 1, q.o, q.s) - 1, 0)
    u_key = jnp.where(is_u, u_key, 0)
    b = q.op.shape[0]
    if index is not None:
        rows = jnp.where(u_axis == 1, pmeta.n_subjects + u_key, u_key)
        g = predindex.gather_batch(
            pmeta, index, jnp.where(is_u, rows, 0), u_width, backend
        )
        cpreds, cvalid, ctrunc = g.ids, g.valid, g.overflow
    else:
        if u_width < f.n_preds:
            raise ValueError(
                f"all-preds fallback needs u_width >= n_preds "
                f"({u_width} < {f.n_preds}); pass an index to prune"
            )
        lane = jnp.arange(u_width, dtype=jnp.int32)
        cpreds = jnp.broadcast_to(lane, (b, u_width))
        cvalid = jnp.broadcast_to(lane < f.n_preds, (b, u_width))
        ctrunc = jnp.zeros((b,), jnp.bool_)
    cvalid = cvalid & is_u[:, None]
    return is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid, ctrunc


def _serve_local(
    meta: K2Meta, f: K2Forest, q: ServeBatch, cap: int,
    backend: str | None = None, *,
    index: PredIndex | None = None, pmeta: PredIndexMeta | None = None,
    u_width: int = 0,
) -> ServeResult:
    """Resolve a batch against a (possibly local-shard) forest.

    ``backend`` selects the scan substrate ("pallas" kernel / "jnp"
    traversal; None = the ``REPRO_SCAN_BACKEND`` flag in kernels/ops.py).
    ``u_width`` > 0 enables the unbounded-?P lanes (candidate slots per
    query); 0 compiles them out entirely.
    """
    b = q.op.shape[0]
    is_check = q.op == OP_CHECK
    hit = k2forest.check(
        meta, f, jnp.maximum(q.p - 1, 0), q.s - 1, q.o - 1
    ) & is_check
    axes = jnp.where(q.op == OP_COL, 1, 0).astype(jnp.int32)
    key = jnp.maximum(jnp.where(q.op == OP_COL, q.o, q.s) - 1, 0)
    r = k2forest.scan_batch_mixed(
        meta, f, jnp.maximum(q.p - 1, 0), key, axes, cap, backend
    )
    scan_lane = (q.op == OP_ROW) | (q.op == OP_COL)
    valid = r.valid & scan_lane[:, None]
    ids = jnp.where(valid, r.ids + 1, 0)
    count = jnp.where(scan_lane, r.count, 0)
    overflow = r.overflow & scan_lane

    if u_width <= 0:
        return ServeResult(
            hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
            u_preds=jnp.zeros((b, 0), jnp.int32),
            u_ids=jnp.zeros((b, 0, cap), jnp.int32),
            u_valid=jnp.zeros((b, 0, cap), jnp.bool_),
            u_count=jnp.zeros((b, 0), jnp.int32),
        )

    is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid, ctrunc = (
        _u_candidates(q, f, u_width, index, pmeta, backend)
    )
    preds_f = jnp.where(cvalid, cpreds, 0).reshape(b * u_width)
    keys_f = jnp.repeat(u_key, u_width)

    # pair lanes: one pruned mixed scan replaces the P-way broadcast sweep
    ru = k2forest.scan_batch_mixed(
        meta, f, preds_f, keys_f, jnp.repeat(u_axis, u_width), cap, backend
    )
    pair_valid = cvalid & is_u_pair[:, None]
    u_valid = ru.valid.reshape(b, u_width, cap) & pair_valid[:, :, None]
    u_ids = jnp.where(u_valid, ru.ids.reshape(b, u_width, cap) + 1, 0)
    u_count = jnp.where(pair_valid, ru.count.reshape(b, u_width), 0)
    u_preds = jnp.where(pair_valid, cpreds + 1, 0)
    overflow = overflow | (
        is_u_pair
        & ((ru.overflow.reshape(b, u_width) & pair_valid).any(axis=1) | ctrunc)
    )

    # S?PO lanes: check candidates, compact matching predicate ids into ids.
    # NOTE this intentionally diverges from predindex.check_pruned_batch
    # (which compacts into u_width slots and so can never truncate): the
    # serve IR must fit the shared (B, cap) ids buffer, so matches beyond
    # cap truncate WITH the overflow bit set — callers (Engine.pattern)
    # must honor it.  Keep the three gather→check/scan→mask copies (here,
    # the sharded _local, predindex.*_pruned_batch) in sync when touching
    # the contract.
    hitm = k2forest.check(
        meta, f, preds_f,
        jnp.repeat(jnp.maximum(q.s - 1, 0), u_width),
        jnp.repeat(jnp.maximum(q.o - 1, 0), u_width),
    ).reshape(b, u_width) & cvalid & is_u_check[:, None]
    valid5, count5, ovf5, (ids5,) = jax.vmap(
        lambda v, a: _compact(v, cap, a)
    )(hitm, jnp.where(hitm, cpreds + 1, 0))
    ids = jnp.where(is_u_check[:, None], ids5, ids)
    valid = jnp.where(is_u_check[:, None], valid5, valid)
    count = jnp.where(is_u_check, count5, count)
    overflow = overflow | (is_u_check & (ovf5 | ctrunc))

    return ServeResult(
        hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
        u_preds=u_preds, u_ids=u_ids, u_valid=u_valid, u_count=u_count,
    )


def make_serve_step(
    meta: K2Meta, cap: int, *, backend: str | None = None,
    pmeta: PredIndexMeta | None = None, u_width: int | None = None,
    donate: bool = False,
):
    """Single-device jit'd serve program.

    ``backend``: an ``ExecConfig`` (explicit backend + interpret — the
    compiled-plan path, no env reads at trace time), a "pallas"/"jnp"
    string, or ``None`` (legacy env resolution at trace time).
    ``u_width`` candidate slots per unbounded lane (default:
    ``pmeta.max_degree`` when an index meta is given, else 0 = unbounded
    ops compiled out).  Call as ``serve_step(forest, batch[, index])`` —
    passing ``index=None`` with ``u_width >= n_preds`` runs the all-preds
    fallback sweep.

    ``donate=True`` donates the per-batch ``ServeBatch`` buffers (argument
    1) to XLA: the program may alias their device memory for outputs, so a
    donated device batch is consumed by the call (``x.is_deleted()``
    afterwards).  Numpy batches are unaffected (they are copied in under
    jit anyway); callers that re-use a device batch must copy first — the
    engine's ``_ServeExec`` does this defensively.
    """
    if u_width is None:
        u_width = pmeta.max_degree if pmeta is not None else 0

    def serve_step(f: K2Forest, q: ServeBatch, index=None) -> ServeResult:
        return _serve_local(
            meta, f, q, cap, backend, index=index, pmeta=pmeta, u_width=u_width
        )

    return jax.jit(serve_step, donate_argnums=(1,) if donate else ())


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------


def shard_forest(f: K2Forest, mesh: Mesh, axis: str = "model") -> K2Forest:
    """Place the arena with the predicate dimension sharded over ``axis``."""
    sh = NamedSharding(mesh, P(axis))
    return K2Forest(*(jax.device_put(a, sh) for a in f))


def forest_pspecs(axis: str = "model") -> K2Forest:
    return K2Forest(
        t_words=P(axis), t_rank=P(axis), l_words=P(axis),
        ones_before=P(axis), level_start=P(axis), nnz=P(axis),
    )


def pad_preds(f: K2Forest, multiple: int) -> K2Forest:
    """Pad the predicate axis so it divides the model-axis size.

    Padded trees are all-zeros (valid empty k²-trees): queries routed to them
    return no results, so padding is semantically inert.
    """
    Pn = f.n_preds
    pad = (-Pn) % multiple
    if pad == 0:
        return f
    out = []
    for a in f:
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, cfg))
    return K2Forest(*out)


def make_sharded_serve_step(
    meta: K2Meta, mesh: Mesh, cap: int, *, data_axes=("data",),
    model_axis="model", backend: str | None = None,
    pmeta: PredIndexMeta | None = None, u_width: int | None = None,
):
    """shard_map'd serve program: forest by predicate, queries by batch.

    Every model shard holds P/mp trees with LOCAL indices; a query with
    global predicate g is owned by shard g // P_loc and resolved there with
    local id g % P_loc; other shards compute a masked (empty) traversal and
    the ``psum`` over the model axis merges.

    With ``pmeta`` (and a replicated ``PredIndex`` third argument) the
    unbounded IR ops are served too: candidates are gathered identically on
    every shard, each shard scans only the candidates it owns, and the psum
    assembles the ``[B, u_width, cap]`` block — the index-pruned counterpart
    of ``make_sharded_unbounded_scan``'s ``[B, P, cap]`` all-gather.
    Signature: ``fn(forest, batch)`` without an index, ``fn(forest, batch,
    index)`` with one.
    """
    if u_width is None:
        u_width = pmeta.max_degree if pmeta is not None else 0
    if u_width > 0 and pmeta is None:
        raise ValueError("sharded unbounded serve requires a pred index (pmeta)")
    mp = int(np.prod([mesh.shape[a] for a in (model_axis,)]))

    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    qspec = ServeBatch(op=P(dax), s=P(dax), p=P(dax), o=P(dax))
    fspec = forest_pspecs(model_axis)
    out_spec = ServeResult(
        hit=P(dax), ids=P(dax), valid=P(dax),
        count=P(dax), overflow=P(dax),
        u_preds=P(dax), u_ids=P(dax), u_valid=P(dax), u_count=P(dax),
    )

    def _local(f_loc: K2Forest, q: ServeBatch, index=None) -> ServeResult:
        p_loc = f_loc.t_words.shape[0]  # local predicate count
        b = q.op.shape[0]
        shard = jax.lax.axis_index(model_axis)
        g = q.p - 1  # 0-based global predicate
        owner = g // p_loc
        mine = owner == shard
        lp = jnp.where(mine, g % p_loc, 0).astype(jnp.int32)
        q_loc = ServeBatch(
            op=jnp.where(mine, q.op, -1), s=q.s, p=lp + 1, o=q.o
        )
        r = _serve_local(meta, f_loc, q_loc, cap, backend)
        # MINIMAL psum payload: only the id matrix and two bit-vectors go on
        # the wire; `valid` (== ids != 0) and `count` are re-derived locally
        # after the reduce.  This halves the all-reduce bytes vs reducing the
        # full ServeResult (§Perf hillclimb on the paper's own program).
        ids = jax.lax.psum(jnp.where(mine[:, None], r.ids, 0), model_axis)
        flags = jax.lax.psum(
            jnp.where(
                mine,
                r.hit.astype(jnp.int32) + 2 * r.overflow.astype(jnp.int32),
                0,
            ),
            model_axis,
        )
        valid = ids != 0
        hit = (flags & 1).astype(jnp.bool_)
        overflow = ((flags >> 1) & 1).astype(jnp.bool_)
        count = valid.sum(axis=-1).astype(jnp.int32)

        if u_width <= 0:
            return ServeResult(
                hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
                u_preds=jnp.zeros((b, 0), jnp.int32),
                u_ids=jnp.zeros((b, 0, cap), jnp.int32),
                u_valid=jnp.zeros((b, 0, cap), jnp.bool_),
                u_count=jnp.zeros((b, 0), jnp.int32),
            )

        # unbounded lanes: candidates gathered replicated (index is
        # replicated), each shard scans/checks only the candidates it owns
        is_u_pair, is_u_check, u_key, u_axis, cpreds, cvalid, ctrunc = (
            _u_candidates(q, f_loc, u_width, index, pmeta, backend)
        )
        owner_u = cpreds // p_loc
        mine_u = cvalid & (owner_u == shard)
        preds_f = jnp.where(mine_u, cpreds % p_loc, 0).reshape(b * u_width)
        keys_f = jnp.repeat(u_key, u_width)

        ru = k2forest.scan_batch_mixed(
            meta, f_loc, preds_f, keys_f, jnp.repeat(u_axis, u_width), cap,
            backend,
        )
        pair_mine = mine_u & is_u_pair[:, None]
        uv_loc = ru.valid.reshape(b, u_width, cap) & pair_mine[:, :, None]
        u_ids = jax.lax.psum(
            jnp.where(uv_loc, ru.ids.reshape(b, u_width, cap) + 1, 0),
            model_axis,
        )
        hitm_loc = k2forest.check(
            meta, f_loc, preds_f,
            jnp.repeat(jnp.maximum(q.s - 1, 0), u_width),
            jnp.repeat(jnp.maximum(q.o - 1, 0), u_width),
        ).reshape(b, u_width) & mine_u & is_u_check[:, None]
        # one packed [B, u_width] reduce: check hits (bit 0), per-candidate
        # counts (needed because a count can legitimately be 0 with no ids)
        packed = jax.lax.psum(
            hitm_loc.astype(jnp.int32)
            + 2 * jnp.where(pair_mine, ru.count.reshape(b, u_width), 0),
            model_axis,
        )
        hitm = (packed & 1) == 1
        u_count = packed >> 1
        pair_ovf = jax.lax.psum(
            (ru.overflow.reshape(b, u_width) & pair_mine)
            .any(axis=1).astype(jnp.int32),
            model_axis,
        ) > 0
        # replicated post-reduce compute (identical on every shard)
        u_valid = u_ids != 0
        u_preds = jnp.where(cvalid & is_u_pair[:, None], cpreds + 1, 0)
        valid5, count5, ovf5, (ids5,) = jax.vmap(
            lambda v, a: _compact(v, cap, a)
        )(hitm, jnp.where(hitm, cpreds + 1, 0))
        ids = jnp.where(is_u_check[:, None], ids5, ids)
        valid = jnp.where(is_u_check[:, None], valid5, valid)
        count = jnp.where(is_u_check, count5, count)
        overflow = (
            overflow
            | (is_u_pair & (pair_ovf | ctrunc))
            | (is_u_check & (ovf5 | ctrunc))
        )
        return ServeResult(
            hit=hit, ids=ids, valid=valid, count=count, overflow=overflow,
            u_preds=u_preds, u_ids=u_ids, u_valid=u_valid, u_count=u_count,
        )

    if u_width > 0:
        ispec = PredIndex(*(P() for _ in PredIndex._fields))  # replicated
        fn = shard_map(
            _local, mesh=mesh, in_specs=(fspec, qspec, ispec),
            out_specs=out_spec,
            check_vma=False,  # pallas_call has no replication rule
        )
    else:
        fn = shard_map(
            lambda f_loc, q: _local(f_loc, q), mesh=mesh,
            in_specs=(fspec, qspec), out_specs=out_spec,
            check_vma=False,  # pallas_call has no replication rule (scan kernel)
        )
    return jax.jit(fn)


def make_sharded_unbounded_scan(
    meta: K2Meta, mesh: Mesh, cap: int, *, data_axes=("data",), model_axis="model",
    backend: str | None = None,
):
    """(S,?P,?O) / (?S,?P,O) batch: every shard scans its LOCAL predicates,
    results all-gathered over the model axis -> [B, P_padded, cap].

    This is the paper's vertical-partitioning worst case turned into an
    embarrassingly parallel sweep — kept as the index-free fallback and the
    differential reference for the index-pruned unbounded lanes of
    ``make_sharded_serve_step``.  The local sweep is one flat
    (b · P_loc)-query ``scan_batch_mixed`` launch, so it follows the
    ``REPRO_SCAN_BACKEND`` flag (Pallas kernel / jnp reference) like the
    bounded-predicate serve path.
    """
    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    qP = P(dax)
    fspec = forest_pspecs(model_axis)

    def _local(f_loc: K2Forest, keys: jax.Array, axes: jax.Array):
        p_loc = f_loc.t_words.shape[0]
        b = keys.shape[0]
        # the all-preds sweep as one batched mixed scan with broadcast keys
        preds_f = jnp.tile(jnp.arange(p_loc, dtype=jnp.int32), b)
        keys_f = jnp.repeat(keys - 1, p_loc)
        axes_f = jnp.repeat(axes, p_loc)
        r = k2forest.scan_batch_mixed(
            meta, f_loc, preds_f, keys_f, axes_f, cap, backend
        )
        ids = jnp.where(r.valid, r.ids + 1, 0).reshape(b, p_loc, cap)
        valid = r.valid.reshape(b, p_loc, cap)
        count = r.count.reshape(b, p_loc)
        ids = jax.lax.all_gather(ids, model_axis, axis=1, tiled=True)
        valid = jax.lax.all_gather(valid, model_axis, axis=1, tiled=True)
        count = jax.lax.all_gather(count, model_axis, axis=1, tiled=True)
        return ids, valid, count

    fn = shard_map(
        _local, mesh=mesh, in_specs=(fspec, qP, qP), out_specs=(qP, qP, qP),
        check_vma=False,  # all_gather(tiled) replication defeats VMA inference
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# the compiled-plan pipeline: Query -> Engine.compile(ExecConfig) -> Plan
# ---------------------------------------------------------------------------


_OP_FOR_SHAPE = {
    (True, True, True): OP_CHECK,
    (True, True, False): OP_ROW,
    (False, True, True): OP_COL,
    (True, False, True): OP_S_ANY_O,
    (True, False, False): OP_S_ANY_ANY,
    (False, False, True): OP_ANY_ANY_O,
}
_UNBOUNDED_OPS = (OP_S_ANY_O, OP_S_ANY_ANY, OP_ANY_ANY_O)


class _ExecBase:
    """Shared executor state: one per ``(shape_key, config)`` cache slot.

    Holds the effective caps — grown in place by the :class:`CapPolicy`
    doubling loop, so every plan sharing this executor benefits from a
    growth paid once.
    """

    def __init__(self, engine: "Engine", cfg: ExecConfig):
        self.engine = engine
        self.cfg = cfg
        self.cap = cfg.cap
        self.cap_y = cfg.cap_y
        # the store epoch this executor was compiled against — a dynamic
        # store bumps it on compaction swap, and running a stale executor
        # would silently serve dropped triples from the old forest
        self.epoch = engine.store_epoch

    def _grow(self, fn):
        self.engine._check_epoch(self.epoch)
        t, m = obs.STATE.tracer, obs.STATE.metrics
        if t is not None or m is not None:
            inner = fn

            def fn(cap, cap_y):
                try:
                    return inner(cap, cap_y)
                except CapOverflow:
                    # the policy loop will recompile at doubled caps —
                    # that retry is the event worth counting
                    if m is not None:
                        m.counter("plan.cap_overflow").inc()
                    if t is not None:
                        t.instant("plan.cap_overflow", cap=cap, cap_y=cap_y)
                    raise

        out, self.cap, self.cap_y = qapi.run_with_policy(
            self.cfg.cap_policy, self.cap, self.cap_y, fn
        )
        return out

    def submit(self, q, batch):
        raise NotImplementedError(
            f"{type(self).__name__} has no raw device surface; "
            "Plan.submit is a ServeQ-only streaming hook"
        )

    def compiled_text(self, q, batch):
        raise NotImplementedError(f"{type(self).__name__} has no HLO view")

    def cost_profile(self, q, batch):
        raise NotImplementedError(
            f"{type(self).__name__} has no compiled-program cost surface"
        )

    @staticmethod
    def _overflow_guard(r):
        if bool(np.asarray(r.overflow).any()):
            raise CapOverflow(
                "result lane truncated at cap; CapPolicy(grow=True) doubles"
            )


class _PatternExec(_ExecBase):
    """Any of the eight triple-pattern shapes, single query or batched."""

    def run(self, q: TriplePatternQ, batch):
        s, p, o, b, single = self._consts(q, batch)
        bound = q.bound
        if bound == (False, True, False):  # (?S, P, ?O) pair enumeration
            out = self._grow(lambda cap, _: self._run_pairs(p, b, cap))
        elif bound == (False, False, False):  # (?S, ?P, ?O) dump
            if batch is not None:
                raise ValueError("the dump pattern takes no batch")
            out = self._grow(lambda cap, _: self._run_dump(cap))
        else:
            op = _OP_FOR_SHAPE[bound]
            out = self._grow(
                lambda cap, _: self._run_serve(op, s, p, o, b, cap)
            )
        return out[0] if single else out

    def _consts(self, q: TriplePatternQ, batch):
        vals = {"s": q.s, "p": q.p, "o": q.o}
        bound = dict(zip("spo", q.bound))
        if batch is None:
            b, single = 1, True
            batch = {}
        else:
            if not batch:
                raise ValueError(
                    "batch must be a non-empty dict of bound-position id "
                    "arrays (or None to use the query's own constants)"
                )
            bad = set(batch) - {k for k in "spo" if bound[k]}
            if bad:
                raise ValueError(
                    f"batch keys {sorted(bad)} are not bound positions of {q!r}"
                )
            b, single = len(np.asarray(next(iter(batch.values())))), False
        arrs = []
        for k in "spo":
            if k in batch:
                a = np.asarray(batch[k], np.int64).reshape(-1)
                if a.shape[0] != b:
                    raise ValueError("batch arrays must share one length")
            else:
                a = np.full(b, vals[k] if bound[k] else 0, np.int64)
            arrs.append(a)
        return (*arrs, b, single)

    def _run_serve(self, op, s, p, o, b, cap):
        eng, cfg = self.engine, self.cfg
        ops_a = np.full(b, op, np.int32)
        if op not in _UNBOUNDED_OPS:
            r = eng._run_lanes(cfg, cap, ops_a, s, p, o)
            self._overflow_guard(r)
            return self._decode(op, r, range(b))

        bi = eng.store.pred_index if cfg.use_pred_index else None
        if bi is None:
            if cfg.mesh is not None:
                raise ValueError(
                    "sharded unbounded-?P serving needs the SP/OP index; "
                    "build the store with_pred_index=True or drop mesh"
                )
            r = eng._run_lanes(
                cfg, cap, ops_a, s, p, o,
                u_width=max(eng.store.n_preds, 1), with_index=False,
            )
            self._overflow_guard(r)
            return self._decode(op, r, range(b))

        u_width = eng._u_width(cfg)
        # quantile-sized lanes: pre-route outlier entities (candidate list
        # longer than the lane — the device gather's `truncated` bit,
        # mirrored on the host CSR) to the all-preds sweep fallback
        rows = (
            bi.meta.n_subjects + o - 1 if op == OP_ANY_ANY_O else s - 1
        )
        outlier = predindex.host_degrees(bi, rows) > u_width
        out = [None] * b
        in_idx = np.nonzero(~outlier)[0]
        out_idx = np.nonzero(outlier)[0]
        if in_idx.size:
            r = eng._run_lanes(
                cfg, cap, ops_a[in_idx], s[in_idx], p[in_idx], o[in_idx],
                u_width=u_width, with_index=True,
            )
            self._overflow_guard(r)
            for j, res in zip(in_idx, self._decode(op, r, range(in_idx.size))):
                out[j] = res
        if out_idx.size:
            # outliers are the degree-distribution tail: served by the
            # single-device all-preds sweep program, exact at any quantile
            r = eng._run_lanes(
                cfg.replace(mesh=None), cap,
                ops_a[out_idx], s[out_idx], p[out_idx], o[out_idx],
                u_width=max(eng.store.n_preds, 1), with_index=False,
            )
            self._overflow_guard(r)
            for j, res in zip(out_idx, self._decode(op, r, range(out_idx.size))):
                out[j] = res
        return out

    @staticmethod
    def _decode(op, r, idxs):
        h = jax.tree.map(np.asarray, r)
        return [decode_lane(op, h, i) for i in idxs]

    def _run_pairs(self, p, b, cap):
        eng = self.engine
        view = eng.dynamic_view()
        if view is None:
            r = k2forest.range_scan_batch(
                eng.meta, eng.forest, jnp.asarray(p - 1, jnp.int32), cap,
                self.cfg,
            )
            self._overflow_guard(r)
            rows, cols, valid = (
                np.asarray(a) for a in (r.rows, r.cols, r.valid)
            )
            return [
                np.stack(
                    [rows[i][valid[i]] + 1, cols[i][valid[i]] + 1], axis=1
                )
                for i in range(b)
            ]
        # dynamic: delta-only preds (beyond the static forest) are clamped
        # to a safe tree for dispatch and answered purely from the snapshot
        p = np.asarray(p, np.int64).reshape(-1)
        safe = p <= view.preds_static
        empty = np.empty(0, np.int64)
        if safe.any():
            p_run = np.where(safe, p, 1)
            r = k2forest.range_scan_batch(
                eng.meta, eng.forest, jnp.asarray(p_run - 1, jnp.int32),
                cap, self.cfg,
            )
            if bool((np.asarray(r.overflow) & safe).any()):
                raise CapOverflow(
                    "result lane truncated at cap; CapPolicy(grow=True) "
                    "doubles"
                )
            rows, cols, valid = (
                np.asarray(a) for a in (r.rows, r.cols, r.valid)
            )
        out = []
        for i in range(b):
            if safe[i]:
                ss = rows[i][valid[i]].astype(np.int64) + 1
                oo = cols[i][valid[i]].astype(np.int64) + 1
            else:
                ss, oo = empty, empty
            ss, oo = view.snap.merge_pairs(int(p[i]), ss, oo)
            out.append(np.stack([ss, oo], axis=1).reshape(-1, 2))
        return out

    def _run_dump(self, cap):
        eng = self.engine
        view = eng.dynamic_view()
        r = patterns.dump(eng.meta, eng.forest, cap, self.cfg)
        self._overflow_guard(r)
        rows, cols, valid = (np.asarray(a) for a in (r.rows, r.cols, r.valid))
        out = {}
        for pi in range(eng.store.n_preds):
            if valid[pi].any():
                out[pi + 1] = np.stack(
                    [rows[pi][valid[pi]], cols[pi][valid[pi]]], axis=1
                )
        if view is not None:
            merged = {}
            empty = np.empty(0, np.int64)
            for p in range(1, view.total_preds + 1):
                pairs = out.get(p)
                ss = pairs[:, 0].astype(np.int64) if pairs is not None else empty
                oo = pairs[:, 1].astype(np.int64) if pairs is not None else empty
                ss, oo = view.snap.merge_pairs(p, ss, oo)
                if len(ss):
                    merged[p] = np.stack(
                        [np.asarray(ss), np.asarray(oo)], axis=1
                    )
            out = merged
        return [out]


class _JoinExec(_ExecBase):
    """Join categories A–F.  A–C are pure serve-IR side-list lanes through
    the shared compiled serve step (+ ``sortedset`` algebra); D–F run the
    fused scan→rebind kernel path of ``core.joins``."""

    def run(self, q: JoinQ, batch):
        if batch is not None:
            raise ValueError("join plans take no batch")
        if q.category in "ABC":
            return self._grow(lambda cap, _: self._run_abc(q, cap))
        return self._grow(
            lambda cap, cap_y: self._run_def(q, cap, cap_y)
        )

    @staticmethod
    def _lane(vpos, p, c):
        # ?X in subject position -> reverse neighbors (?S,P,O) = OP_COL;
        # ?X in object position -> direct neighbors (S,P,?O) = OP_ROW
        return (OP_COL, 0, p, c) if vpos == "s" else (OP_ROW, c, p, 0)

    def _idset(self, r, i):
        ids = jnp.where(r.valid[i], r.ids[i], SENTINEL)
        return IdSet(ids, r.valid[i], r.count[i], jnp.asarray(False))

    def _run_abc(self, q, cap):
        eng, cfg = self.engine, self.cfg
        # the B/C per-pred side-list enumerations must cover delta-only
        # appended predicates too; those lanes are sanitized to dead on the
        # device and answered from the snapshot in the merge
        Pn = dyn.total_preds(eng.store)
        if q.category == "A":
            lanes = [
                self._lane(q.vpos1, q.p1, q.c1),
                self._lane(q.vpos2, q.p2, q.c2),
            ]
        elif q.category == "B":
            lanes = [self._lane(q.vpos1, q.p1, q.c1)] + [
                self._lane(q.vpos2, pp, q.c2) for pp in range(1, Pn + 1)
            ]
        else:  # C
            lanes = [
                self._lane(q.vpos1, pp, q.c1) for pp in range(1, Pn + 1)
            ] + [self._lane(q.vpos2, pp, q.c2) for pp in range(1, Pn + 1)]
        arr = np.asarray(lanes, np.int64)
        r = eng._run_lanes(cfg, cap, arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3])
        self._overflow_guard(r)

        if q.category == "A":
            rr = sortedset.intersect(self._idset(r, 0), self._idset(r, 1))
            return np.asarray(rr.ids)[np.asarray(rr.valid)]
        if q.category == "B":
            a = self._idset(r, 0)
            ids2 = jnp.where(r.valid[1:], r.ids[1:], SENTINEL)

            def one(idp, vp):
                b = IdSet(idp, vp, vp.sum().astype(jnp.int32), jnp.asarray(False))
                rr = sortedset.intersect(a, b)
                return rr.ids, rr.valid

            ids, valid = jax.vmap(one)(ids2, r.valid[1:])
            ids, valid = np.asarray(ids), np.asarray(valid)
            return {
                pi + 1: ids[pi][valid[pi]]
                for pi in range(Pn)
                if valid[pi].any()
            }
        ids = jnp.where(r.valid, r.ids, SENTINEL)
        u1 = sortedset.union_rows(ids[:Pn], r.valid[:Pn], cap, False)
        u2 = sortedset.union_rows(ids[Pn:], r.valid[Pn:], cap, False)
        if bool(np.asarray(u1.overflow | u2.overflow)):
            raise CapOverflow("side-list union truncated at cap")
        rr = sortedset.intersect(u1, u2)
        return np.asarray(rr.ids)[np.asarray(rr.valid)]

    def _run_def(self, q, cap, cap_y):
        eng, cfg = self.engine, self.cfg
        view = eng.dynamic_view()
        if view is not None:
            # the fused scan->rebind kernels read only the static forest;
            # with a live delta the join decomposes into two serve-lane
            # stages (X side list, then per-x rebind) so every stage rides
            # the sanitize+merge path
            return self._run_def_dynamic(q, cap, cap_y)
        m, f = eng.meta, eng.forest
        if q.category == "D":
            r = joins.join_d(
                m, f, q.p1, q.c1, q.vpos1, q.p2, q.vpos2,
                cap_x=cap, cap_y=cap_y, backend=cfg,
            )
            self._overflow_guard(r)
            return _pairs_to_dict(r)
        if q.category == "E":
            r = joins.join_e(
                m, f, q.p1, q.c1, q.vpos1, q.vpos2,
                cap_x=cap, cap_y=cap_y, backend=cfg,
            )
        else:  # F
            r = joins.join_f(
                m, f, q.c1, q.vpos1, q.vpos2,
                cap_x=cap, cap_y=cap_y, backend=cfg,
            )
        self._overflow_guard(r)
        return _pairs_to_dict_pred(r)

    def _run_def_dynamic(self, q, cap, cap_y):
        eng, cfg = self.engine, self.cfg
        pe = _PatternExec(eng, cfg)
        # stage 1: the shared-variable side list X
        if q.category in ("D", "E"):
            lane = np.asarray([self._lane(q.vpos1, q.p1, q.c1)], np.int64)
            r = eng._run_lanes(
                cfg, cap, lane[:, 0], lane[:, 1], lane[:, 2], lane[:, 3]
            )
            self._overflow_guard(r)
            xs = np.asarray(r.ids[0])[np.asarray(r.valid[0])].astype(np.int64)
        else:  # F: ?X linked to c1 by ANY predicate — unbounded lane, union
            op1 = OP_ANY_ANY_O if q.vpos1 == "s" else OP_S_ANY_ANY
            key = np.asarray([q.c1], np.int64)
            zero = np.zeros(1, np.int64)
            s1, o1 = (zero, key) if q.vpos1 == "s" else (key, zero)
            per = pe._run_serve(op1, s1, zero, o1, 1, cap)[0]
            xs = (
                np.unique(np.concatenate([np.asarray(v) for v in per.values()]))
                .astype(np.int64)
                if per else np.empty(0, np.int64)
            )
        if not xs.size:
            return {}
        # stage 2: rebind each x
        if q.category == "D":
            if q.vpos2 == "s":
                ops2 = np.full(xs.size, OP_ROW, np.int32)
                s2, o2 = xs, np.zeros(xs.size, np.int64)
            else:
                ops2 = np.full(xs.size, OP_COL, np.int32)
                s2, o2 = np.zeros(xs.size, np.int64), xs
            p2 = np.full(xs.size, q.p2, np.int64)
            r2 = eng._run_lanes(cfg, cap_y, ops2, s2, p2, o2)
            self._overflow_guard(r2)
            ids, valid = np.asarray(r2.ids), np.asarray(r2.valid)
            return {
                int(x): ids[i][valid[i]]
                for i, x in enumerate(xs)
                if valid[i].any()
            }
        op2 = OP_S_ANY_ANY if q.vpos2 == "s" else OP_ANY_ANY_O
        zero = np.zeros(xs.size, np.int64)
        s2, o2 = (xs, zero) if q.vpos2 == "s" else (zero, xs)
        per_x = pe._run_serve(op2, s2, zero, o2, xs.size, cap_y)
        out: dict[int, dict[int, np.ndarray]] = {}
        for i, x in enumerate(xs):
            for pl, ys in per_x[i].items():
                if len(ys):
                    out.setdefault(int(pl), {})[int(x)] = np.asarray(ys)
        return {p: d for p, d in sorted(out.items())}


_ANON = algebra.ANON  # internal prefix for None (anonymous) BGP positions


class _BgpExec(_ExecBase):
    """Basic graph patterns: the optimizer plans per call (its join order
    is data-dependent), but every check / bounded-scan step resolves
    through the engine's pooled serve-step programs.

    ``None`` positions are EXISTENTIAL: they join like variables inside
    the optimizer but are projected away from the result — only named
    variables come back, with distinct rows over those columns.
    """

    def run(self, q: BgpQ, batch):
        if batch is not None:
            raise ValueError("BGP plans take no batch")
        from repro.core import optimizer  # deferred: optimizer imports engine

        pats = algebra.name_anon(q.patterns)

        def fn(cap, _):
            return optimizer.run_bgp(
                self.engine.store, pats, cap=cap, exec_=self.cfg,
                serve=self.engine._lanes_runner(self.cfg, cap),
            )

        # project the anonymous columns away and dedup the named rows —
        # the shared algebra helper (run_bgp dedups over ALL columns, so
        # dropping some can leave duplicate rows in the named ones)
        return algebra.project_named(self._grow(fn))


class _SelectExec(_ExecBase):
    """SPARQL-shaped SELECT: the query lowers to a ``core.algebra``
    operator tree and ``core.planner`` executes it — cost-ordered (DP)
    conjunctive blocks with sideways information passing, every check /
    bounded-scan step through the engine's pooled serve-step programs.

    Returns columnar named bindings like ``_BgpExec``; with ``order_by``
    the row order is the query's (deterministic total order), otherwise
    rows come back in dedup order (set semantics either way).
    """

    def run(self, q: SelectQ, batch):
        if batch is not None:
            raise ValueError("SELECT plans take no batch")
        from repro.core import planner  # deferred: planner imports engine

        tree = algebra.from_select(q)

        def fn(cap, _):
            return planner.execute(
                self.engine.store, tree, cap=cap, exec_=self.cfg,
                serve=self.engine._lanes_runner(self.cfg, cap),
            )

        # the tree ends in Project (+ Slice): columns are already the
        # named selection, rows already distinct (and ordered if asked)
        return dict(self._grow(fn).cols)


class _ServeExec(_ExecBase):
    """Raw serve-IR passthrough: ``plan(ServeBatch) -> ServeResult``."""

    @staticmethod
    def _coerce(batch):
        if batch is None:
            raise ValueError("ServeQ plans take a ServeBatch")
        if not isinstance(batch, ServeBatch):
            batch = ServeBatch(*(jnp.asarray(a, jnp.int32) for a in batch))
        return batch

    def _donates(self) -> bool:
        """Whether the dispatched program donates its batch argument
        (mirrors ``Engine._program``'s donate condition)."""
        return self.cfg.donate_batch and self.cfg.mesh is None

    def _donation_copy(self, qb: ServeBatch) -> ServeBatch:
        """Fresh batch buffers for one donating dispatch.

        The donating program consumes (aliases) its batch argument, so a
        caller-held DEVICE batch is copied per call — this also makes cap
        growth safe (the retry dispatch gets its own copy).  Numpy inputs
        are copied in by jit anyway and skip the defensive copy.
        """
        if not self._donates():
            return qb
        return ServeBatch(*(
            jnp.array(a, jnp.int32, copy=True)
            if isinstance(a, jax.Array) else jnp.asarray(a, jnp.int32)
            for a in qb
        ))

    def run(self, q: ServeQ, batch):
        batch = self._coerce(batch)

        def one(cap):
            view = self.engine.dynamic_view()
            qb = batch if view is None else view.sanitize_batch(batch)
            r = self._call(qb, cap, q.unbounded)
            if view is not None:
                # the delta merge needs host arrays anyway; fetch, fold the
                # snapshot in (host-side widening means the delta itself can
                # never trip the guard), keep static overflow bits
                r = view.merge_lanes(
                    batch.op, batch.s, batch.p, batch.o,
                    host_result(r, unbounded=q.unbounded),
                )
            return r

        def fn(cap, _):
            t = obs.STATE.tracer
            if t is None:
                r = one(cap)
                self._overflow_guard(r)
                return r
            with t.span("plan.call", cat="plan",
                        b=int(batch.op.shape[0]), cap=cap,
                        unbounded=q.unbounded):
                with t.span("plan.dispatch", cat="plan"):
                    r = one(cap)
                with t.span("plan.sync", cat="plan"):
                    self._overflow_guard(r)
            return r

        return self._grow(fn)

    def submit(self, q: ServeQ, batch) -> ServeResult:
        """Streamed-serving dispatch: device ``ServeResult`` with NO host
        sync — the overflow guard and any cap growth are the caller's job
        (``launch.broker`` handles both per tenant).  The executor's cap
        never grows through this path, so a shared base plan stays at its
        configured geometry no matter what overflows ride through it.

        Dynamic stores: this is the STATIC lane only — the caller grabs
        ``Engine.dynamic_view()`` at dispatch, sanitizes the batch, and
        merges the snapshot into the fetched result itself (the broker
        does all three)."""
        self.engine._check_epoch(self.epoch)
        t = obs.STATE.tracer
        if t is None:
            return self._call(self._coerce(batch), self.cap, q.unbounded)
        batch = self._coerce(batch)
        with t.span("plan.submit", cat="plan", b=int(batch.op.shape[0]),
                    cap=self.cap, unbounded=q.unbounded):
            return self._call(batch, self.cap, q.unbounded)

    def _args(self, qb, cap, unbounded):
        eng, cfg = self.engine, self.cfg
        f = eng._forest_for(cfg)
        if not unbounded:
            return eng._program(cfg, cap, 0, False), (f, qb)
        bi = eng.store.pred_index if cfg.use_pred_index else None
        if bi is None:
            if cfg.mesh is not None:
                raise ValueError(
                    "sharded unbounded-?P serving needs the SP/OP index"
                )
            fn = eng._program(cfg, cap, max(eng.store.n_preds, 1), False)
            return fn, (f, qb, None)
        fn = eng._program(cfg, cap, eng._u_width(cfg), True)
        return fn, (f, qb, bi.select(cfg.pred_index_layout)[0])

    def _call(self, qb, cap, unbounded):
        fn, args = self._args(self._donation_copy(qb), cap, unbounded)
        return fn(*args)

    def compiled_text(self, q, batch):
        """Compiled-module text of the current program for this batch —
        lets callers assert communication properties (e.g. the
        sharded-smoke 'no all-gather on the wire' check)."""
        fn, args = self._args(batch, self.cap, q.unbounded)
        return fn.lower(*args).compile().as_text()

    def _u_width_of(self, unbounded: bool) -> int:
        """The unbounded-lane width the current program geometry carries
        (mirrors :meth:`_args` without building the program)."""
        if not unbounded:
            return 0
        eng, cfg = self.engine, self.cfg
        if eng.store.pred_index is None or not cfg.use_pred_index:
            return max(eng.store.n_preds, 1)
        return eng._u_width(cfg)

    def cost_profile(self, q: ServeQ, batch=None) -> dict:
        """Static XLA cost profile of the serve program this plan would
        dispatch for ``batch`` (8 pow2-padded lanes when ``None``) —
        cached per program geometry in the engine's program cache."""
        eng, cfg = self.engine, self.cfg
        if batch is None:
            b = eng._pad_b(1, cfg)
            z = np.zeros(b, np.int32)
            batch = ServeBatch(op=z, s=z, p=z, o=z)
        batch = self._coerce(batch)
        b = int(batch.op.shape[0])
        u_width = self._u_width_of(q.unbounded)
        key = (
            "cost_profile", cfg.backend, cfg.interpret, cfg.mesh,
            cfg.data_axes, cfg.model_axis, self.cap, u_width, b,
            q.unbounded, cfg.pred_index_layout, cfg.donate_batch,
        )
        prof = eng._programs.get(key)
        if prof is None:
            fn, args = self._args(batch, self.cap, q.unbounded)
            geometry = {
                "lanes": b,
                "cap": self.cap,
                "u_width": u_width,
                "unbounded": q.unbounded,
                "backend": cfg.backend,
                "sharded": cfg.mesh is not None,
            }
            prof = obs_cost.profile_jit(fn, args, geometry)
            eng._programs[key] = prof
        return dict(prof)


# ---------------------------------------------------------------------------
# host-side engine: compile queries against one store
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Engine:
    """The one query entry point: ``Engine.compile(query, config) -> Plan``.

    Queries are ``core.query`` descriptions (``TriplePatternQ`` / ``JoinQ``
    / ``BgpQ`` / ``ServeQ``); execution knobs travel ONLY inside a frozen
    :class:`ExecConfig`.  Compiled plans are cached on
    ``(shape_key(query), config)`` — two queries of the same shape share
    programs, caps, and growth state — and every keyed + unbounded pattern,
    join side-list, and BGP step rides the same cached ``serve_step``
    programs underneath.

    ``cap`` / ``backend`` / ``use_pred_index`` are legacy construction
    knobs (pre-ExecConfig); they seed :attr:`default_config` and feed the
    deprecation shims :meth:`pattern` and :meth:`join`.
    """

    store: K2TriplesStore
    cap: int = 4096
    backend: str | None = None
    use_pred_index: bool = True
    config: ExecConfig | None = None
    _plan_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _programs: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _sharded: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _stats: dict = dataclasses.field(
        default_factory=lambda: {"hits": 0, "misses": 0, "denied": 0},
        repr=False, compare=False,
    )
    _env_cfg: ExecConfig | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # store epoch the caches were built against; a DynamicStore bumps its
    # epoch on compaction swap and the caches (plans, programs, sharded
    # forests) all close over the old forest/meta, so they are dropped
    # wholesale at the next compile
    _built_epoch: int = dataclasses.field(default=-1, repr=False, compare=False)

    @property
    def meta(self) -> K2Meta:
        return self.store.meta

    @property
    def store_epoch(self) -> int:
        """Compaction epoch of a dynamic store (0 for a static one)."""
        return getattr(self.store, "epoch", 0)

    def _check_epoch(self, epoch: int) -> None:
        cur = self.store_epoch
        if epoch != cur:
            raise qapi.StaleEpoch(
                f"plan compiled at store epoch {epoch}, store is now at "
                f"{cur} (compacted); recompile"
            )

    def dynamic_view(self):
        """The delta read view for this dispatch, or ``None`` when the
        store is static (or the delta is empty) — the static fast path."""
        return dyn.view_of(self.store)

    @property
    def forest(self) -> K2Forest:
        return self.store.forest

    @property
    def default_config(self) -> ExecConfig:
        """Engine-level default: the explicit ``config`` if given, else the
        one-time ``ExecConfig.from_env()`` snapshot overlaid with the
        legacy ``cap``/``backend``/``use_pred_index`` fields."""
        if self.config is not None:
            return self.config.resolved()
        if self._env_cfg is None:
            self._env_cfg = ExecConfig.from_env()
        cfg = self._env_cfg.replace(
            cap=self.cap, use_pred_index=self.use_pred_index
        )
        if self.backend is not None:
            cfg = cfg.replace(backend=self.backend)
        return cfg.resolved()

    @property
    def plan_cache_stats(self) -> dict:
        return dict(self._stats, size=len(self._plan_cache))

    # -- compile -------------------------------------------------------

    def compile(self, q, config: ExecConfig | None = None, *, admit=None) -> Plan:
        """Lower ``q`` under ``config`` (default :attr:`default_config`).

        Plans are cached on ``(shape_key, config)``: the constants inside
        ``q`` are runtime inputs, so compiling a second query of the same
        shape is a cache hit.

        ``admit`` is the plan-cache admission hook: a callable invoked with
        the cache key ONLY on a miss; returning falsy raises
        :class:`~repro.core.query.AdmissionError` instead of compiling.
        Hits bypass it entirely — admission charges the expensive event
        (a new compiled executor), never the reuse of a shared one.  The
        multi-tenant broker uses this to budget per-tenant recompiles.
        """
        cfg = (config or self.default_config).resolved()
        cur = self.store_epoch
        if self._built_epoch != cur:
            # post-compaction: every cached executor/program closes over the
            # old epoch's forest+meta — invalidate them all before compiling
            self._plan_cache.clear()
            self._programs.clear()
            self._sharded.clear()
            self._built_epoch = cur
        self._validate(q, cfg)
        key = (qapi.shape_key(q), cfg)
        t, m = obs.STATE.tracer, obs.STATE.metrics
        ex = self._plan_cache.get(key)
        if ex is None:
            if admit is not None and not admit(key):
                self._stats["denied"] += 1
                if m is not None:
                    m.counter("engine.plan_cache.denied").inc()
                if t is not None:
                    t.instant("engine.admission_denied", shape=str(key[0]))
                raise qapi.AdmissionError(
                    f"plan-cache admission denied for {key[0]!r}"
                )
            self._stats["misses"] += 1
            if m is not None:
                m.counter("engine.plan_cache.misses").inc()
            if t is not None:
                with t.span("engine.compile", cat="engine",
                            shape=str(key[0]), backend=cfg.backend,
                            cap=cfg.cap, hit=False):
                    ex = self._build_executor(q, cfg)
            else:
                ex = self._build_executor(q, cfg)
            self._plan_cache[key] = ex
        else:
            self._stats["hits"] += 1
            if m is not None:
                m.counter("engine.plan_cache.hits").inc()
        return Plan(q, cfg, ex)

    def _validate(self, q, cfg: ExecConfig):
        if isinstance(q, TriplePatternQ):
            named = [t for t in (q.s, q.p, q.o) if isinstance(t, str)]
            if len(named) != len(set(named)):
                raise ValueError(
                    "a variable repeated inside one pattern needs join "
                    f"semantics; wrap it in BgpQ: {q!r}"
                )
        # a mesh request must never be silently dropped: only the serve-IR
        # shapes are sharded today.  Pair enumeration / dump (range kernel),
        # join rebinds D-F, and the BGP host loop's enumeration steps run
        # on the unsharded forest, so reject the combination loudly.
        if cfg.mesh is not None:
            if isinstance(q, TriplePatternQ) and q.bound in (
                (False, True, False), (False, False, False)
            ):
                raise ValueError(
                    "pair-enumeration/dump plans are not sharded; drop "
                    "ExecConfig.mesh for this shape"
                )
            if isinstance(q, JoinQ) and q.category in "DEF":
                raise ValueError(
                    f"join category {q.category} (fused scan->rebind) is "
                    "not sharded; drop ExecConfig.mesh"
                )
            if isinstance(q, (BgpQ, SelectQ)):
                raise ValueError(
                    "BGP/SELECT plans are not sharded (enumeration steps "
                    "run single-device); drop ExecConfig.mesh"
                )
        if isinstance(q, BgpQ):
            names = {v for tp in q.patterns for v in tp.variables}
            if any(v.startswith(_ANON) for v in names):
                raise ValueError(
                    f"variable names starting with {_ANON!r} are reserved "
                    "for anonymous (None) positions"
                )
            if not names and any(
                qapi.is_var(t)
                for tp in q.patterns for t in (tp.s, tp.p, tp.o)
            ):
                raise ValueError(
                    "a BGP whose variables are all anonymous has no "
                    "projectable columns; name at least one variable "
                    "(or use a TriplePatternQ check shape)"
                )
        if isinstance(q, SelectQ):
            blocks = (q.where,) + q.optional + q.union
            names = {v for blk in blocks for tp in blk for v in tp.variables}
            reserved = [v for v in names if v.startswith(algebra.INTERNAL)]
            if q.select:
                reserved += [
                    v for v in q.select if v.startswith(algebra.INTERNAL)
                ]
            if reserved:
                raise ValueError(
                    f"variable names starting with {algebra.INTERNAL!r} "
                    f"are reserved for internal columns: {reserved!r}"
                )
            if not names:
                raise ValueError(
                    "a SELECT whose variables are all anonymous has no "
                    "projectable columns; name at least one variable"
                )
            for ex in q.filter:  # raises TypeError on non-expressions
                algebra.expr_vars(ex)
        if (
            isinstance(q, ServeQ)
            and q.unbounded
            and cfg.u_width_quantile < 1.0
            and cfg.use_pred_index
            and self.store.pred_index is not None
        ):
            raise ValueError(
                "quantile-sized unbounded lanes need the decode-level sweep "
                "fallback; raw ServeQ plans require u_width_quantile=1.0 "
                "(use TriplePatternQ plans for quantile sizing)"
            )

    def _build_executor(self, q, cfg: ExecConfig):
        if isinstance(q, TriplePatternQ):
            return _PatternExec(self, cfg)
        if isinstance(q, JoinQ):
            return _JoinExec(self, cfg)
        if isinstance(q, BgpQ):
            return _BgpExec(self, cfg)
        if isinstance(q, SelectQ):
            return _SelectExec(self, cfg)
        if isinstance(q, ServeQ):
            return _ServeExec(self, cfg)
        raise TypeError(f"not a Query: {q!r}")

    # -- shared compiled-program machinery ------------------------------

    def _u_width(self, cfg: ExecConfig) -> int:
        bi = self.store.pred_index
        if cfg.u_width_quantile >= 1.0:
            return max(bi.meta.max_degree, 1)
        # the quantile pass walks the whole host CSR — memoize per quantile
        # so unbounded serve calls don't pay it repeatedly
        key = ("u_width", cfg.u_width_quantile)
        w = self._programs.get(key)
        if w is None:
            w = max(predindex.quantile_u_width(bi, cfg.u_width_quantile), 1)
            self._programs[key] = w
        return w

    def _forest_for(self, cfg: ExecConfig) -> K2Forest:
        if cfg.mesh is None:
            return self.forest
        key = (cfg.mesh, cfg.model_axis)
        f = self._sharded.get(key)
        if f is None:
            mp = int(cfg.mesh.shape[cfg.model_axis])
            f = shard_forest(
                pad_preds(self.forest, mp), cfg.mesh, cfg.model_axis
            )
            self._sharded[key] = f
        return f

    def _program(self, cfg: ExecConfig, cap: int, u_width: int, with_index: bool):
        """One cached compiled serve program per distinct geometry; shared
        by every executor of this engine."""
        donate = cfg.donate_batch and cfg.mesh is None
        key = (
            cfg.backend, cfg.interpret, cfg.mesh, cfg.data_axes,
            cfg.model_axis, cap, u_width, with_index,
            cfg.pred_index_layout, donate,
        )
        fn = self._programs.get(key)
        if fn is None:
            m = obs.STATE.metrics
            if m is not None:
                m.counter("engine.programs_built").inc()
            with obs.span("engine.program_build", cat="engine",
                          cap=cap, u_width=u_width, with_index=with_index,
                          sharded=cfg.mesh is not None):
                pmeta = (
                    self.store.pred_index.select(cfg.pred_index_layout)[1]
                    if with_index else None
                )
                if cfg.mesh is None:
                    fn = make_serve_step(
                        self.meta, cap, backend=cfg, pmeta=pmeta,
                        u_width=u_width, donate=donate,
                    )
                else:
                    fn = make_sharded_serve_step(
                        self.meta, cfg.mesh, cap, data_axes=cfg.data_axes,
                        model_axis=cfg.model_axis, backend=cfg, pmeta=pmeta,
                        u_width=u_width,
                    )
            self._programs[key] = fn
        return fn

    def _pad_b(self, b: int, cfg: ExecConfig) -> int:
        """Pad host batches to pow2 buckets (bounds retraces to log2 sizes);
        sharded programs additionally need data-axis divisibility."""
        n = 8
        while n < b:
            n <<= 1
        if cfg.mesh is not None:
            d = int(np.prod([cfg.mesh.shape[a] for a in cfg.data_axes]))
            n = max(n, d)
            n = ((n + d - 1) // d) * d
        return n

    def _run_lanes(
        self, cfg: ExecConfig, cap: int, ops_a, s, p, o,
        *, u_width: int = 0, with_index: bool = False,
    ) -> ServeResult:
        """Run serve-IR lanes through the cached program for this geometry.

        Lanes are padded to a pow2 bucket with dead (op=-1) entries —
        masked to zero output by ``_serve_local`` — and sliced back.  This
        is the ONE dispatch every pattern plan, join side-list, and BGP
        step shares.
        """
        b = int(np.shape(ops_a)[0])
        n = self._pad_b(b, cfg)
        t = obs.STATE.tracer
        if t is not None:
            with t.span("plan.lanes", cat="plan", b=b, padded=n, cap=cap,
                        u_width=u_width, sharded=cfg.mesh is not None):
                return self._run_lanes_inner(
                    cfg, cap, ops_a, s, p, o, b=b, n=n,
                    u_width=u_width, with_index=with_index,
                )
        return self._run_lanes_inner(
            cfg, cap, ops_a, s, p, o, b=b, n=n,
            u_width=u_width, with_index=with_index,
        )

    def _run_lanes_inner(
        self, cfg: ExecConfig, cap: int, ops_a, s, p, o,
        *, b: int, n: int, u_width: int, with_index: bool,
    ) -> ServeResult:
        view = self.dynamic_view()
        ops_run = (
            view.sanitize_ops(ops_a, s, p, o) if view is not None else ops_a
        )

        def pad(a, fill):
            out = np.full(n, fill, np.int32)
            out[:b] = np.asarray(a, np.int64)
            return out

        qb = ServeBatch(
            op=jnp.asarray(pad(ops_run, -1)),
            s=jnp.asarray(pad(s, 0)),
            p=jnp.asarray(pad(p, 0)),
            o=jnp.asarray(pad(o, 0)),
        )
        f = self._forest_for(cfg)
        fn = self._program(cfg, cap, u_width, with_index)
        if with_index:
            dev = self.store.pred_index.select(cfg.pred_index_layout)[0]
            r = fn(f, qb, dev)
        elif u_width > 0 and cfg.mesh is None:
            r = fn(f, qb, None)
        else:
            r = fn(f, qb)
        r = jax.tree.map(lambda a: a[:b], r)
        if view is not None:
            # the delta lane: fold the snapshot into the static results on
            # the host — subtract tombstones, union inserts, widen caps so
            # the delta can never cause a false overflow
            r = view.merge_lanes(ops_a, s, p, o, jax.tree.map(np.asarray, r))
        return r

    def _lanes_runner(self, cfg: ExecConfig, cap: int):
        """Bound-pred serve-lane callable handed to the BGP optimizer."""
        return lambda ops_a, s, p, o: self._run_lanes(cfg, cap, ops_a, s, p, o)

    # -- deprecation shims ----------------------------------------------

    def pattern(self, s: int | None, p: int | None, o: int | None):
        """DEPRECATED: build a ``TriplePatternQ`` and ``compile`` it.

        Kept as a thin shim over the plan pipeline — identical results,
        plus the CapPolicy growth the old path lacked.
        """
        warnings.warn(
            "Engine.pattern is deprecated; use "
            "Engine.compile(TriplePatternQ(s, p, o), ExecConfig(...))()",
            DeprecationWarning, stacklevel=2,
        )
        q = TriplePatternQ(s or None, p or None, o or None)
        return self.compile(q)()

    def join(self, category: str, **kw):
        """DEPRECATED: build a ``JoinQ`` and ``compile`` it."""
        warnings.warn(
            "Engine.join is deprecated; use "
            "Engine.compile(JoinQ(category, ...), ExecConfig(...))()",
            DeprecationWarning, stacklevel=2,
        )
        cap = kw.pop("cap", self.cap)
        cap_y = kw.pop("cap_y", 256)
        backend = kw.pop("backend", None)  # legacy per-call override
        q = JoinQ(category=category, **kw)
        cfg = self.default_config.replace(cap=cap, cap_y=cap_y)
        if backend is not None:
            cfg = cfg.replace(backend=backend)
        return self.compile(q, cfg)()


def _pairs_to_dict(r: joins.JoinPairs) -> dict[int, np.ndarray]:
    xs, xv = np.asarray(r.x_ids), np.asarray(r.x_valid)
    ys, yv = np.asarray(r.y_ids), np.asarray(r.y_valid)
    out = {}
    for i in range(xs.shape[0]):
        if xv[i] and yv[i].any():
            out[int(xs[i])] = ys[i][yv[i]]
    return out


def _pairs_to_dict_pred(r: joins.JoinPairs) -> dict[int, dict[int, np.ndarray]]:
    out: dict[int, dict[int, np.ndarray]] = {}
    xs, xv = np.asarray(r.x_ids), np.asarray(r.x_valid)
    ys, yv = np.asarray(r.y_ids), np.asarray(r.y_valid)
    for p in range(xs.shape[0]):
        d = {}
        for i in range(xs.shape[1]):
            if xv[p, i] and yv[p, i].any():
                d[int(xs[p, i])] = ys[p, i][yv[p, i]]
        if d:
            out[p + 1] = d
    return out
