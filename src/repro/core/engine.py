"""Query engine: host dispatch + jit'd batched ``serve_step`` (single & sharded).

Two layers:

  * ``Engine`` — host-side convenience: takes a triple pattern with ``None``
    for variables, dispatches to the right primitive, returns numpy results.
    This is the paper's per-query interface (Tables 3/4 are measured on it).

  * ``make_serve_step`` / ``make_sharded_serve_step`` — the production path:
    one compiled program serving a BATCH of bounded-predicate queries
    (checks + mixed row/col scans) plus optional unbounded-predicate scans.

Distribution (the paper's vertical partitioning lifted to the mesh):
the forest arena is sharded by predicate over the ``model`` axis; the query
batch is sharded over ``data`` (× ``pod``).  Inside ``shard_map`` each model
shard resolves the queries whose predicate it owns (others masked out) and a
``psum`` over the model axis combines — invalid lanes carry zeros, exactly
one shard owns each predicate.  Unbounded-``?P`` scans become the
embarrassingly-parallel local scan + ``all_gather`` the paper's analysis
begs for: the model axis attacks vertical partitioning's worst case.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import joins, k2forest, patterns
from repro.core.k2forest import K2Forest
from repro.core.k2triples import K2TriplesStore
from repro.core.k2tree import K2Meta

# serve ops
OP_CHECK = 0  # (S, P, O)    -> hit flag
OP_ROW = 1  # (S, P, ?O)   -> object list
OP_COL = 2  # (?S, P, O)   -> subject list


class ServeBatch(NamedTuple):
    """Encoded bounded-predicate queries (1-based ids)."""

    op: jax.Array  # int32[B] in {OP_CHECK, OP_ROW, OP_COL}
    s: jax.Array  # int32[B] subject id (or 0)
    p: jax.Array  # int32[B] predicate id
    o: jax.Array  # int32[B] object id (or 0)


class ServeResult(NamedTuple):
    hit: jax.Array  # bool[B]      — checks
    ids: jax.Array  # int32[B,cap] — scans (1-based; 0 where invalid)
    valid: jax.Array  # bool[B,cap]
    count: jax.Array  # int32[B]
    overflow: jax.Array  # bool[B]


def _serve_local(
    meta: K2Meta, f: K2Forest, q: ServeBatch, cap: int,
    backend: str | None = None,
) -> ServeResult:
    """Resolve a batch against a (possibly local-shard) forest.

    ``backend`` selects the scan substrate ("pallas" kernel / "jnp"
    traversal; None = the ``REPRO_SCAN_BACKEND`` flag in kernels/ops.py).
    """
    hit = k2forest.check(meta, f, q.p - 1, q.s - 1, q.o - 1) & (q.op == OP_CHECK)
    axes = jnp.where(q.op == OP_COL, 1, 0).astype(jnp.int32)
    key = jnp.where(q.op == OP_COL, q.o, q.s)
    r = k2forest.scan_batch_mixed(meta, f, q.p - 1, key - 1, axes, cap, backend)
    scan_lane = q.op != OP_CHECK
    valid = r.valid & scan_lane[:, None]
    ids = jnp.where(valid, r.ids + 1, 0)
    return ServeResult(
        hit=hit,
        ids=ids,
        valid=valid,
        count=jnp.where(scan_lane, r.count, 0),
        overflow=r.overflow & scan_lane,
    )


def make_serve_step(meta: K2Meta, cap: int, *, backend: str | None = None):
    """Single-device jit'd serve program."""

    @jax.jit
    def serve_step(f: K2Forest, q: ServeBatch) -> ServeResult:
        return _serve_local(meta, f, q, cap, backend)

    return serve_step


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------


def shard_forest(f: K2Forest, mesh: Mesh, axis: str = "model") -> K2Forest:
    """Place the arena with the predicate dimension sharded over ``axis``."""
    sh = NamedSharding(mesh, P(axis))
    return K2Forest(*(jax.device_put(a, sh) for a in f))


def forest_pspecs(axis: str = "model") -> K2Forest:
    return K2Forest(
        t_words=P(axis), t_rank=P(axis), l_words=P(axis),
        ones_before=P(axis), level_start=P(axis), nnz=P(axis),
    )


def pad_preds(f: K2Forest, multiple: int) -> K2Forest:
    """Pad the predicate axis so it divides the model-axis size.

    Padded trees are all-zeros (valid empty k²-trees): queries routed to them
    return no results, so padding is semantically inert.
    """
    Pn = f.n_preds
    pad = (-Pn) % multiple
    if pad == 0:
        return f
    out = []
    for a in f:
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, cfg))
    return K2Forest(*out)


def make_sharded_serve_step(
    meta: K2Meta, mesh: Mesh, cap: int, *, data_axes=("data",), model_axis="model"
):
    """shard_map'd serve program: forest by predicate, queries by batch.

    Every model shard holds P/mp trees with LOCAL indices; a query with
    global predicate g is owned by shard g // P_loc and resolved there with
    local id g % P_loc; other shards compute a masked (empty) traversal and
    the ``psum`` over the model axis merges.
    """
    mp = int(np.prod([mesh.shape[a] for a in (model_axis,)]))

    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    qspec = ServeBatch(op=P(dax), s=P(dax), p=P(dax), o=P(dax))
    fspec = forest_pspecs(model_axis)
    out_spec = ServeResult(
        hit=P(dax), ids=P(dax), valid=P(dax),
        count=P(dax), overflow=P(dax),
    )

    def _local(f_loc: K2Forest, q: ServeBatch) -> ServeResult:
        p_loc = f_loc.t_words.shape[0]  # local predicate count
        shard = jax.lax.axis_index(model_axis)
        g = q.p - 1  # 0-based global predicate
        owner = g // p_loc
        mine = owner == shard
        lp = jnp.where(mine, g % p_loc, 0).astype(jnp.int32)
        q_loc = ServeBatch(
            op=jnp.where(mine, q.op, -1), s=q.s, p=lp + 1, o=q.o
        )
        r = _serve_local(meta, f_loc, q_loc, cap)
        # MINIMAL psum payload: only the id matrix and two bit-vectors go on
        # the wire; `valid` (== ids != 0) and `count` are re-derived locally
        # after the reduce.  This halves the all-reduce bytes vs reducing the
        # full ServeResult (§Perf hillclimb on the paper's own program).
        ids = jax.lax.psum(jnp.where(mine[:, None], r.ids, 0), model_axis)
        flags = jax.lax.psum(
            jnp.where(
                mine,
                r.hit.astype(jnp.int32) + 2 * r.overflow.astype(jnp.int32),
                0,
            ),
            model_axis,
        )
        valid = ids != 0
        return ServeResult(
            hit=(flags & 1).astype(jnp.bool_),
            ids=ids,
            valid=valid,
            count=valid.sum(axis=-1).astype(jnp.int32),
            overflow=((flags >> 1) & 1).astype(jnp.bool_),
        )

    fn = shard_map(
        _local, mesh=mesh, in_specs=(fspec, qspec), out_specs=out_spec,
        check_vma=False,  # pallas_call has no replication rule (scan kernel)
    )
    return jax.jit(fn)


def make_sharded_unbounded_scan(
    meta: K2Meta, mesh: Mesh, cap: int, *, data_axes=("data",), model_axis="model",
    backend: str | None = None,
):
    """(S,?P,?O) / (?S,?P,O) batch: every shard scans its LOCAL predicates,
    results all-gathered over the model axis -> [B, P_padded, cap].

    This is the paper's vertical-partitioning worst case turned into an
    embarrassingly parallel sweep.  The local sweep is one flat
    (b · P_loc)-query ``scan_batch_mixed`` launch, so it follows the
    ``REPRO_SCAN_BACKEND`` flag (Pallas kernel / jnp reference) like the
    bounded-predicate serve path.
    """
    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    qP = P(dax)
    fspec = forest_pspecs(model_axis)

    def _local(f_loc: K2Forest, keys: jax.Array, axes: jax.Array):
        p_loc = f_loc.t_words.shape[0]
        b = keys.shape[0]
        # the all-preds sweep as one batched mixed scan with broadcast keys
        preds_f = jnp.tile(jnp.arange(p_loc, dtype=jnp.int32), b)
        keys_f = jnp.repeat(keys - 1, p_loc)
        axes_f = jnp.repeat(axes, p_loc)
        r = k2forest.scan_batch_mixed(
            meta, f_loc, preds_f, keys_f, axes_f, cap, backend
        )
        ids = jnp.where(r.valid, r.ids + 1, 0).reshape(b, p_loc, cap)
        valid = r.valid.reshape(b, p_loc, cap)
        count = r.count.reshape(b, p_loc)
        ids = jax.lax.all_gather(ids, model_axis, axis=1, tiled=True)
        valid = jax.lax.all_gather(valid, model_axis, axis=1, tiled=True)
        count = jax.lax.all_gather(count, model_axis, axis=1, tiled=True)
        return ids, valid, count

    fn = shard_map(
        _local, mesh=mesh, in_specs=(fspec, qP, qP), out_specs=(qP, qP, qP),
        check_vma=False,  # all_gather(tiled) replication defeats VMA inference
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# host-side convenience engine (per-query; used by benchmarks/examples)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Engine:
    """Paper-facing interface: patterns with None variables + joins A–F."""

    store: K2TriplesStore
    cap: int = 4096

    @property
    def meta(self) -> K2Meta:
        return self.store.meta

    @property
    def forest(self) -> K2Forest:
        return self.store.forest

    def pattern(self, s: int | None, p: int | None, o: int | None):
        """Resolve one triple pattern; returns numpy (see patterns.py)."""
        m, f, cap = self.meta, self.forest, self.cap
        if s and p and o:
            return bool(patterns.spo(m, f, s, p, o))
        if s and o:  # (S, ?P, O)
            return np.nonzero(np.asarray(patterns.s_any_o(m, f, s, o)))[0] + 1
        if s and p:
            r = patterns.sp_any(m, f, s, p, cap)
            return np.asarray(r.ids)[np.asarray(r.valid)]
        if p and o:
            r = patterns.any_po(m, f, p, o, cap)
            return np.asarray(r.ids)[np.asarray(r.valid)]
        if s:
            r = patterns.s_any_any(m, f, s, cap)
            ids, valid = np.asarray(r.ids), np.asarray(r.valid)
            return {pi + 1: ids[pi][valid[pi]] for pi in range(ids.shape[0]) if valid[pi].any()}
        if o:
            r = patterns.any_any_o(m, f, o, cap)
            ids, valid = np.asarray(r.ids), np.asarray(r.valid)
            return {pi + 1: ids[pi][valid[pi]] for pi in range(ids.shape[0]) if valid[pi].any()}
        if p:
            r = patterns.any_p_any(m, f, p, cap)
            v = np.asarray(r.valid)
            return np.stack([np.asarray(r.rows)[v], np.asarray(r.cols)[v]], axis=1)
        r = patterns.dump(m, f, cap)
        out = {}
        for pi in range(self.store.n_preds):
            v = np.asarray(r.valid[pi])
            if v.any():
                out[pi + 1] = np.stack(
                    [np.asarray(r.rows[pi])[v], np.asarray(r.cols[pi])[v]], axis=1
                )
        return out

    # joins ------------------------------------------------------------
    def join(self, category: str, **kw):
        m, f = self.meta, self.forest
        cap = kw.pop("cap", self.cap)
        cap_y = kw.pop("cap_y", 256)
        if category == "A":
            r = joins.join_a(m, f, cap=cap, **kw)
            return np.asarray(r.ids)[np.asarray(r.valid)]
        if category == "B":
            r = joins.join_b(m, f, cap=cap, **kw)
            ids, valid = np.asarray(r.ids), np.asarray(r.valid)
            return {pi + 1: ids[pi][valid[pi]] for pi in range(ids.shape[0]) if valid[pi].any()}
        if category == "C":
            r = joins.join_c(m, f, cap=cap, **kw)
            return np.asarray(r.ids)[np.asarray(r.valid)]
        if category == "D":
            r = joins.join_d(m, f, cap_x=cap, cap_y=cap_y, **kw)
            return _pairs_to_dict(r)
        if category == "E":
            r = joins.join_e(m, f, cap_x=cap, cap_y=cap_y, **kw)
            return _pairs_to_dict_pred(r)
        if category == "F":
            r = joins.join_f(m, f, cap_x=cap, cap_y=cap_y, **kw)
            return _pairs_to_dict_pred(r)
        raise ValueError(f"unknown join category {category!r}")


def _pairs_to_dict(r: joins.JoinPairs) -> dict[int, np.ndarray]:
    xs, xv = np.asarray(r.x_ids), np.asarray(r.x_valid)
    ys, yv = np.asarray(r.y_ids), np.asarray(r.y_valid)
    out = {}
    for i in range(xs.shape[0]):
        if xv[i] and yv[i].any():
            out[int(xs[i])] = ys[i][yv[i]]
    return out


def _pairs_to_dict_pred(r: joins.JoinPairs) -> dict[int, dict[int, np.ndarray]]:
    out: dict[int, dict[int, np.ndarray]] = {}
    xs, xv = np.asarray(r.x_ids), np.asarray(r.x_valid)
    ys, yv = np.asarray(r.y_ids), np.asarray(r.y_valid)
    for p in range(xs.shape[0]):
        d = {}
        for i in range(xs.shape[1]):
            if xv[p, i] and yv[p, i].any():
                d[int(xs[p, i])] = ys[p, i][yv[p, i]]
        if d:
            out[p + 1] = d
    return out
