"""Relational algebra over solution tables — the SPARQL-shaped layer.

The paper stops at conjunctive (BGP) joins; a production endpoint needs the
rest of the SPARQL surface.  This module is the layer between the
declarative ``core.query`` descriptions and the serve IR: a small operator
tree

    ``Scan``      one triple pattern (a BGP leaf)
    ``Join``      natural inner join (conjunction)
    ``LeftJoin``  OPTIONAL — left rows survive unmatched, right-only
                  variables come back :data:`UNBOUND`
    ``Union``     branch union (columns aligned, missing vars UNBOUND)
    ``Filter``    3-valued-logic expression filter (SPARQL errors drop rows)
    ``Project``   keep named columns (+ dedup)
    ``Slice``     ORDER BY + LIMIT/OFFSET over a deterministic total order

evaluated over **solution tables** — columnar ``{var: int64[n]}`` maps in
which ``UNBOUND == 0`` marks an OPTIONAL-introduced hole (dictionary ids
are 1-based, so 0 is free).  ``core.planner`` walks the tree: conjunctive
regions (``Join``-of-``Scan``) are flattened back into BGPs, cost-ordered,
and executed through the pooled serve-IR programs with sideways
information passing; everything here is the host-side table algebra those
blocks compose under.

Results are **set semantics** (DISTINCT implied, like the BGP layer);
``Slice`` makes LIMIT deterministic by sorting over the ORDER BY keys
*followed by every remaining column in sorted-name order* — a total order,
so a truncated result is reproducible and differential-testable.

This module is dependency-light on purpose (numpy + dataclasses only):
the oracle side of ``tests/test_algebra_differential.py`` re-implements
its semantics independently against dense triple sets.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

Term = Any  # int (bound 1-based id) | str '?var'

UNBOUND = np.int64(0)  # ids are 1-based; 0 marks an OPTIONAL-unbound slot
ANON = "?__anon"  # internal prefix for anonymous (None) positions
INTERNAL = "?__"  # every internal helper column lives under this prefix
_ROWID = "?__ljrow"  # LeftJoin's transient left-row tag


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    """One BGP triple pattern: ints bind, ``"?name"`` strings are variables."""

    s: Term
    p: Term
    o: Term

    @property
    def variables(self) -> set[str]:
        return {t for t in (self.s, self.p, self.o) if isinstance(t, str)}


def is_var(t: Term) -> bool:
    return isinstance(t, str)


# ---------------------------------------------------------------------------
# filter expressions (SPARQL-style 3-valued logic)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Cmp:
    """``lhs <op> rhs`` over dictionary ids; an UNBOUND operand is a SPARQL
    type error (the row is dropped unless a surrounding Or/Not saves it)."""

    op: str  # one of == != < <= > >=
    lhs: Term
    rhs: Term

    def __post_init__(self):
        if self.op not in _CMP_FNS:
            raise ValueError(f"unknown comparison {self.op!r}")


@dataclasses.dataclass(frozen=True)
class Bound:
    """SPARQL ``BOUND(?var)`` — true iff the column holds a real id."""

    var: str


@dataclasses.dataclass(frozen=True)
class And:
    a: Any
    b: Any


@dataclasses.dataclass(frozen=True)
class Or:
    a: Any
    b: Any


@dataclasses.dataclass(frozen=True)
class Not:
    e: Any


_CMP_FNS = {
    "==": np.equal, "!=": np.not_equal,
    "<": np.less, "<=": np.less_equal,
    ">": np.greater, ">=": np.greater_equal,
}


def expr_vars(expr) -> set[str]:
    if isinstance(expr, Cmp):
        return {t for t in (expr.lhs, expr.rhs) if isinstance(t, str)}
    if isinstance(expr, Bound):
        return {expr.var}
    if isinstance(expr, (And, Or)):
        return expr_vars(expr.a) | expr_vars(expr.b)
    if isinstance(expr, Not):
        return expr_vars(expr.e)
    raise TypeError(f"not a filter expression: {expr!r}")


def eval_expr(expr, t: "Table", scope: set[str]):
    """Evaluate to SPARQL 3-valued logic: ``(value, error)`` bool arrays.

    ``scope`` is the set of variables the expression may see (the
    *syntactic* variables of the filtered subtree) — a variable outside it
    is unbound regardless of what columns ride along in ``t``, so results
    never depend on whether a sideways-information-passing seed happened
    to add extra columns.  Error propagation follows SPARQL:
    ``false && error = false``, ``true || error = true``, errors filter.
    """
    n = t.n

    def operand(x):
        if isinstance(x, str):
            if x in scope and x in t.cols:
                c = t.cols[x]
                return c, c == UNBOUND
            return np.zeros(n, np.int64), np.ones(n, np.bool_)
        return np.full(n, int(x), np.int64), np.zeros(n, np.bool_)

    if isinstance(expr, Cmp):
        lv, lu = operand(expr.lhs)
        rv, ru = operand(expr.rhs)
        err = lu | ru
        return _CMP_FNS[expr.op](lv, rv) & ~err, err
    if isinstance(expr, Bound):
        if expr.var in scope and expr.var in t.cols:
            return t.cols[expr.var] != UNBOUND, np.zeros(n, np.bool_)
        return np.zeros(n, np.bool_), np.zeros(n, np.bool_)
    if isinstance(expr, And):
        av, ae = eval_expr(expr.a, t, scope)
        bv, be = eval_expr(expr.b, t, scope)
        a_false = ~av & ~ae
        b_false = ~bv & ~be
        err = (ae | be) & ~a_false & ~b_false
        return av & bv, err
    if isinstance(expr, Or):
        av, ae = eval_expr(expr.a, t, scope)
        bv, be = eval_expr(expr.b, t, scope)
        err = (ae | be) & ~av & ~bv
        return (av | bv) & ~err, err
    if isinstance(expr, Not):
        v, e = eval_expr(expr.e, t, scope)
        return ~v & ~e, e
    raise TypeError(f"not a filter expression: {expr!r}")


# ---------------------------------------------------------------------------
# operator tree
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scan:
    pattern: TriplePattern


@dataclasses.dataclass(frozen=True)
class Join:
    left: Any
    right: Any


@dataclasses.dataclass(frozen=True)
class LeftJoin:
    """OPTIONAL: every left row survives; unmatched rows carry UNBOUND in
    the right side's own variables."""

    left: Any
    right: Any


@dataclasses.dataclass(frozen=True)
class Union:
    left: Any
    right: Any


@dataclasses.dataclass(frozen=True)
class Filter:
    expr: Any
    child: Any


@dataclasses.dataclass(frozen=True)
class Project:
    child: Any
    vars: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Slice:
    """ORDER BY + LIMIT/OFFSET.  ``order_by`` entries are ``"?v"``
    (ascending) or ``"-?v"`` (descending); remaining columns in
    sorted-name order break ties, so the cut is deterministic."""

    child: Any
    order_by: tuple[str, ...] = ()
    limit: int | None = None
    offset: int = 0


Node = Any  # Scan | Join | LeftJoin | Union | Filter | Project | Slice


def bgp(patterns) -> Node:
    """A conjunction as a left-deep ``Join`` tree of ``Scan`` leaves."""
    pats = [
        p if isinstance(p, TriplePattern) else TriplePattern(p.s, p.p, p.o)
        for p in patterns
    ]
    if not pats:
        raise ValueError("a BGP needs at least one pattern")
    node: Node = Scan(pats[0])
    for p in pats[1:]:
        node = Join(node, Scan(p))
    return node


def flatten_bgp(node) -> list[TriplePattern] | None:
    """The conjunctive region under ``node`` as a pattern list, or ``None``
    when the subtree contains non-conjunctive operators.  This is what the
    planner cost-orders as ONE BGP block."""
    if isinstance(node, Scan):
        return [node.pattern]
    if isinstance(node, Join):
        left = flatten_bgp(node.left)
        right = flatten_bgp(node.right)
        if left is not None and right is not None:
            return left + right
    return None


def node_vars(node) -> set[str]:
    """The syntactic variables a subtree can bind (its visible columns)."""
    if isinstance(node, Scan):
        return set(node.pattern.variables)
    if isinstance(node, (Join, LeftJoin, Union)):
        return node_vars(node.left) | node_vars(node.right)
    if isinstance(node, Filter):
        return node_vars(node.child)  # a filter binds nothing
    if isinstance(node, Project):
        return set(node.vars)
    if isinstance(node, Slice):
        return node_vars(node.child)
    raise TypeError(f"not an algebra node: {node!r}")


# ---------------------------------------------------------------------------
# solution tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Table:
    """Columnar solution multiset: ``cols[var]`` is ``int64[n]``; the row
    count is explicit so zero-column tables (pure existence results) can
    still distinguish one row from none."""

    cols: dict[str, np.ndarray]
    n: int

    def __post_init__(self):
        self.cols = {
            k: np.asarray(v, np.int64).reshape(-1) for k, v in self.cols.items()
        }
        for k, v in self.cols.items():
            if v.shape[0] != self.n:
                raise ValueError(f"column {k} has {v.shape[0]} rows, not {self.n}")

    @classmethod
    def unit(cls) -> "Table":
        """The join identity: one row, no columns."""
        return cls({}, 1)

    @classmethod
    def empty(cls, vars=()) -> "Table":
        return cls({v: np.zeros(0, np.int64) for v in vars}, 0)

    @classmethod
    def from_bindings(cls, bindings: dict[str, np.ndarray]) -> "Table":
        n = len(next(iter(bindings.values()))) if bindings else 0
        return cls(dict(bindings), n)

    def take(self, idx) -> "Table":
        idx = np.asarray(idx)
        return Table({v: c[idx] for v, c in self.cols.items()}, int(idx.shape[0]))


# pairwise-match block size: caps the boolean compatibility matrix a
# generic (non-SIP) join materializes at any one time
_JOIN_BLOCK = 1 << 22


def join_tables(a: Table, b: Table) -> Table:
    """SPARQL-compatible natural join: two rows merge when every shared
    variable agrees *or is UNBOUND on either side* (the merged value is the
    bound one).  O(n·m) pair test, blocked to bound memory — the generic
    fallback; conjunctive regions never come here (the planner feeds them
    through the serve IR with sideways information passing instead)."""
    shared = [v for v in a.cols if v in b.cols]
    out_vars = list(a.cols) + [v for v in b.cols if v not in a.cols]
    if a.n == 0 or b.n == 0:
        return Table.empty(out_vars)
    ai_parts, bi_parts = [], []
    step = max(1, _JOIN_BLOCK // max(b.n, 1))
    for lo in range(0, a.n, step):
        hi = min(lo + step, a.n)
        ok = np.ones((hi - lo, b.n), np.bool_)
        for v in shared:
            av = a.cols[v][lo:hi, None]
            bv = b.cols[v][None, :]
            ok &= (av == bv) | (av == UNBOUND) | (bv == UNBOUND)
        ia, ib = np.nonzero(ok)
        ai_parts.append(ia + lo)
        bi_parts.append(ib)
    ai = np.concatenate(ai_parts)
    bi = np.concatenate(bi_parts)
    cols = {}
    for v in a.cols:
        av = a.cols[v][ai]
        if v in b.cols:
            cols[v] = np.where(av != UNBOUND, av, b.cols[v][bi])
        else:
            cols[v] = av
    for v in b.cols:
        if v not in a.cols:
            cols[v] = b.cols[v][bi]
    return Table(cols, int(ai.shape[0]))


def left_join_tables(a: Table, b: Table) -> Table:
    """OPTIONAL: inner-join rows plus every unmatched left row padded with
    UNBOUND in the right-only variables."""
    aa = Table({**a.cols, _ROWID: np.arange(a.n, dtype=np.int64)}, a.n)
    j = join_tables(aa, b)
    matched = np.zeros(a.n, np.bool_)
    if j.n:
        matched[j.cols[_ROWID]] = True
    miss = np.nonzero(~matched)[0]
    cols = {}
    for v in j.cols:
        if v == _ROWID:
            continue
        pad = (
            a.cols[v][miss]
            if v in a.cols
            else np.full(miss.shape[0], UNBOUND, np.int64)
        )
        cols[v] = np.concatenate([j.cols[v], pad])
    return Table(cols, j.n + int(miss.shape[0]))


def union_tables(a: Table, b: Table) -> Table:
    """Branch union: columns aligned over the union of variables, a branch
    missing a variable contributes UNBOUND there."""
    out_vars = list(a.cols) + [v for v in b.cols if v not in a.cols]

    def col(t, v):
        return t.cols.get(v, np.full(t.n, UNBOUND, np.int64))

    return Table(
        {v: np.concatenate([col(a, v), col(b, v)]) for v in out_vars},
        a.n + b.n,
    )


def distinct(t: Table) -> Table:
    """Set semantics: unique rows (column order normalized by name)."""
    if not t.cols:
        return Table({}, min(t.n, 1))
    keys = sorted(t.cols)
    stacked = np.stack([t.cols[k] for k in keys], axis=1)
    uniq = np.unique(stacked, axis=0)
    return Table({k: uniq[:, i] for i, k in enumerate(keys)}, uniq.shape[0])


def sort_slice(
    t: Table, order_by: tuple[str, ...], limit: int | None, offset: int = 0
) -> Table:
    """Deduplicate, totally order, and cut.

    Sort keys are the ORDER BY entries (``"-?v"`` descends) followed by
    every remaining column in sorted-name order — a total order over
    distinct rows, so LIMIT is deterministic (differential-testable).
    UNBOUND (0) sorts before every real id, matching SPARQL's
    unbound-first convention.
    """
    t = distinct(t)
    keys = []
    named = set()
    for spec in order_by:
        desc = spec.startswith("-")
        v = spec[1:] if desc else spec
        named.add(v)
        c = t.cols.get(v, np.full(t.n, UNBOUND, np.int64))
        keys.append(-c if desc else c)
    for v in sorted(t.cols):
        if v not in named:
            keys.append(t.cols[v])
    if keys:
        idx = np.lexsort(tuple(reversed(keys)))
    else:
        idx = np.arange(t.n)
    stop = t.n if limit is None else min(t.n, offset + limit)
    return t.take(idx[offset:stop])


# ---------------------------------------------------------------------------
# shared variable-binding helpers (the one home for anon/projection logic)
# ---------------------------------------------------------------------------


def name_anon(patterns, start: int = 0) -> list[TriplePattern]:
    """Materialize anonymous (``None``) positions as reserved internal
    variables so the planner can join through them; ``project_named``
    drops them again.  ``start`` offsets the numbering so several blocks
    of one query never collide on an anon name.  The ONE implementation —
    the BgpQ and SelectQ lowerings and the optimizer shims all route
    here."""
    return [
        TriplePattern(
            *(
                f"{ANON}{start + i}{k}" if t is None else t
                for k, t in zip("spo", (tp.s, tp.p, tp.o))
            )
        )
        for i, tp in enumerate(patterns)
    ]


def from_select(q) -> Node:
    """Lower a ``SelectQ``-shaped query description to an algebra tree.

    ``q`` is duck-typed (``where``/``union``/``optional``/``filter``/
    ``select``/``order_by``/``limit``/``offset``) so this module stays
    import-free of :mod:`repro.core.query`.  Composition follows the
    SPARQL group-graph-pattern order: WHERE joined with the UNION group,
    then each OPTIONAL block left-joined, then FILTERs, then projection
    and the ORDER/LIMIT slice.
    """
    idx = 0

    def named(block):
        nonlocal idx
        out = name_anon(block, start=idx)
        idx += len(block)
        return out

    node: Node | None = None
    if q.where:
        node = bgp(named(q.where))
    if q.union:
        ub: Node | None = None
        for branch in q.union:
            bn = bgp(named(branch))
            ub = bn if ub is None else Union(ub, bn)
        node = ub if node is None else Join(node, ub)
    if node is None:
        raise ValueError("SelectQ needs a WHERE or UNION block")
    for opt in q.optional:
        node = LeftJoin(node, bgp(named(opt)))
    for ex in q.filter:
        node = Filter(ex, node)
    # always project: SELECT * means "every NAMED variable" — anonymous
    # (?__anon) join columns must never leak, and projecting BEFORE the
    # Slice keeps the ORDER BY total order over visible columns only
    sel = (
        tuple(q.select)
        if q.select is not None
        else tuple(
            sorted(v for v in node_vars(node) if not v.startswith(INTERNAL))
        )
    )
    node = Project(node, sel)
    if q.order_by or q.limit is not None or q.offset:
        node = Slice(node, tuple(q.order_by), q.limit, q.offset)
    return node


def project_named(
    bindings: dict[str, np.ndarray], keep=None
) -> dict[str, np.ndarray]:
    """Project a columnar binding dict to ``keep`` (default: every
    non-internal column) and deduplicate the surviving rows — the shared
    tail of BGP/Select execution, previously duplicated between the
    optimizer and the BgpQ lowering."""
    if keep is None:
        keep = sorted(k for k in bindings if not k.startswith(INTERNAL))
    else:
        keep = sorted(keep)
    if not keep:
        return {}
    stacked = np.stack(
        [np.asarray(bindings[k], np.int64) for k in keep], axis=1
    )
    uniq = np.unique(stacked, axis=0)
    return {k: uniq[:, i] for i, k in enumerate(keep)}
