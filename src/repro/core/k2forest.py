"""K2Forest — the vertical-partitioning arena: one k²-tree per predicate.

The paper keeps |P| independent k²-trees.  For a device-resident engine we
pack them into padded 2-D word arenas ``(P, W)`` so that

  * the predicate axis is shardable (``model`` axis of the production mesh —
    vertical partitioning *is* the sharding scheme, lifted to the pod level);
  * a batch of queries with per-query predicate ids lowers to gathers
    ``words[pred, pos >> 5]`` — no per-query row materialization.

All trees share one ``K2Meta`` (same matrix side = dictionary extent, padded
to the hybrid-k power — exactly the paper's square-matrix construction).
"""

from __future__ import annotations

import types
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitvec, k2tree
from repro.core.k2tree import K2Meta, PairResult, QueryResult, _compact


class K2Forest(NamedTuple):
    t_words: jax.Array  # uint32[P, Wt]
    t_rank: jax.Array  # int32[P, Wt]
    l_words: jax.Array  # uint32[P, Wl]
    ones_before: jax.Array  # int32[P, max(H-1,1)]
    level_start: jax.Array  # int32[P, H]
    nnz: jax.Array  # int32[P]

    @property
    def n_preds(self) -> int:
        return self.t_words.shape[0]


class ForestStats(NamedTuple):
    """Honest compression accounting (padding is a layout, not a size)."""

    total_bits: int  # sum over predicates of (|T| + |L|)
    padded_bits: int  # device-arena footprint
    per_pred_bits: np.ndarray
    per_pred_nnz: np.ndarray


def build_forest(
    coords: Sequence[tuple[np.ndarray, np.ndarray]], meta: K2Meta
) -> tuple[K2Forest, ForestStats]:
    """Build one tree per predicate from (rows, cols) coordinate lists."""
    hosts = [k2tree.build_host(r, c, meta) for (r, c) in coords]
    P = len(hosts)
    H = meta.n_levels
    wt = max(1, max((h.t_bits.shape[0] + 31) // 32 for h in hosts))
    wl = max(1, max((h.l_bits.shape[0] + 31) // 32 for h in hosts))

    t_words = np.zeros((P, wt), np.uint32)
    t_rank = np.zeros((P, wt), np.int32)
    l_words = np.zeros((P, wl), np.uint32)
    ones_before = np.zeros((P, max(H - 1, 1)), np.int32)
    level_start = np.zeros((P, H), np.int32)
    nnz = np.zeros((P,), np.int32)
    bits = np.zeros((P,), np.int64)
    for i, h in enumerate(hosts):
        tw = bitvec.pack_bits_np(h.t_bits)
        t_words[i, : tw.shape[0]] = tw
        t_rank[i, : tw.shape[0]] = bitvec.rank_blocks_np(tw)
        # padding words rank-extend so rank1 beyond the tree stays monotone
        if tw.shape[0] < wt:
            total = int(h.t_bits.sum())
            t_rank[i, tw.shape[0]:] = total
        lw = bitvec.pack_bits_np(h.l_bits)
        l_words[i, : lw.shape[0]] = lw
        ones_before[i, : h.ones_before.shape[0]] = h.ones_before
        level_start[i] = h.level_start
        nnz[i] = h.nnz
        bits[i] = h.t_bits.shape[0] + h.l_bits.shape[0]

    forest = K2Forest(
        t_words=jnp.asarray(t_words),
        t_rank=jnp.asarray(t_rank),
        l_words=jnp.asarray(l_words),
        ones_before=jnp.asarray(ones_before),
        level_start=jnp.asarray(level_start),
        nnz=jnp.asarray(nnz),
    )
    stats = ForestStats(
        total_bits=int(bits.sum()),
        padded_bits=int(P * (wt + wl) * 32 + t_rank.size * 32),
        per_pred_bits=bits,
        per_pred_nnz=nnz.copy(),
    )
    return forest, stats


# ---------------------------------------------------------------------------
# batched queries — 2-D indexed (pred travels with every lane)
# ---------------------------------------------------------------------------


def check(
    meta: K2Meta, f: K2Forest, pred: jax.Array, rows: jax.Array, cols: jax.Array
) -> jax.Array:
    """Batched (S, P, O) over per-lane predicates -> bool[Q]."""
    H = meta.n_levels
    pred = pred.astype(jnp.int32)
    rd = k2tree._row_digits(meta, rows.astype(jnp.int32))
    cd = k2tree._row_digits(meta, cols.astype(jnp.int32))
    alive = jnp.ones(rows.shape, dtype=jnp.bool_)
    pos = (rd[0] * meta.ks[0] + cd[0]).astype(jnp.int32)
    for lvl in range(H):
        last = lvl == H - 1
        words = f.l_words if last else f.t_words
        bit = bitvec.get_bit_2d(words, pred, pos)
        alive = alive & (bit == 1)
        if not last:
            j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
            nxt = rd[lvl + 1] * meta.ks[lvl + 1] + cd[lvl + 1]
            pos = f.level_start[pred, lvl + 1] + j * meta.radices[lvl + 1] + nxt
            pos = jnp.where(alive, pos, 0).astype(jnp.int32)
    return alive


def check_all_preds(meta: K2Meta, f: K2Forest, row: jax.Array, col: jax.Array) -> jax.Array:
    """(S, ?P, O): bool[P] — the paper's 'check the cell in every tree'."""
    P = f.n_preds
    preds = jnp.arange(P, dtype=jnp.int32)
    return check(meta, f, preds, jnp.broadcast_to(row, (P,)), jnp.broadcast_to(col, (P,)))


def row_scan(meta: K2Meta, f: K2Forest, pred, row, cap: int,
             backend: str | None = None) -> QueryResult:
    """(S, P, ?O) — direct neighbors, ascending object id."""
    r = scan_batch_mixed(
        meta, f, jnp.reshape(jnp.asarray(pred, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(row, jnp.int32), (1,)),
        jnp.zeros((1,), jnp.int32), cap, backend,
    )
    return jax.tree.map(lambda x: x[0], r)


def col_scan(meta: K2Meta, f: K2Forest, pred, col, cap: int,
             backend: str | None = None) -> QueryResult:
    """(?S, P, O) — reverse neighbors, ascending subject id."""
    r = scan_batch_mixed(
        meta, f, jnp.reshape(jnp.asarray(pred, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(col, jnp.int32), (1,)),
        jnp.ones((1,), jnp.int32), cap, backend,
    )
    return jax.tree.map(lambda x: x[0], r)


def row_scan_batch(meta: K2Meta, f: K2Forest, preds, rows, cap: int,
                   backend: str | None = None) -> QueryResult:
    preds = jnp.asarray(preds, jnp.int32)
    return scan_batch_mixed(
        meta, f, preds, jnp.asarray(rows, jnp.int32),
        jnp.zeros(preds.shape, jnp.int32), cap, backend,
    )


def col_scan_batch(meta: K2Meta, f: K2Forest, preds, cols, cap: int,
                   backend: str | None = None) -> QueryResult:
    preds = jnp.asarray(preds, jnp.int32)
    return scan_batch_mixed(
        meta, f, preds, jnp.asarray(cols, jnp.int32),
        jnp.ones(preds.shape, jnp.int32), cap, backend,
    )


def row_scan_all_preds(meta: K2Meta, f: K2Forest, row, cap: int,
                       backend: str | None = None) -> QueryResult:
    """(S, ?P, ?O): per-predicate object lists, result axis 0 = predicate.

    The all-preds sweep is the batched mixed scan with a broadcast key —
    one kernel launch covers every predicate's tree.
    """
    preds = jnp.arange(f.n_preds, dtype=jnp.int32)
    rows = jnp.broadcast_to(jnp.asarray(row, jnp.int32), (f.n_preds,))
    return row_scan_batch(meta, f, preds, rows, cap, backend)


def col_scan_all_preds(meta: K2Meta, f: K2Forest, col, cap: int,
                       backend: str | None = None) -> QueryResult:
    """(?S, ?P, O): per-predicate subject lists."""
    preds = jnp.arange(f.n_preds, dtype=jnp.int32)
    cols = jnp.broadcast_to(jnp.asarray(col, jnp.int32), (f.n_preds,))
    return col_scan_batch(meta, f, preds, cols, cap, backend)


def _axis_scan_traced(
    meta: K2Meta, f: K2Forest, pred: jax.Array, fixed: jax.Array, axis: jax.Array, cap: int
) -> QueryResult:
    """Like ``_axis_scan`` but the row/col axis is a *traced* per-query flag.

    This lets one compiled program serve a mixed batch of direct-neighbor
    (S,P,?O) and reverse-neighbor (?S,P,O) scans — the serving hot path.
    """
    H = meta.n_levels
    pred = pred.astype(jnp.int32)
    is_row = (jnp.asarray(axis, jnp.int32) == 0)
    fdig = k2tree._row_digits(meta, fixed.astype(jnp.int32))

    k0 = meta.ks[0]
    sub0 = meta.subsides[0]
    init_n = min(k0, cap)
    j0 = jnp.arange(init_n, dtype=jnp.int32)
    p0 = jnp.where(is_row, fdig[0] * k0 + j0, j0 * k0 + fdig[0])
    pos = jnp.zeros((cap,), jnp.int32).at[:init_n].set(p0)
    base = jnp.zeros((cap,), jnp.int32).at[:init_n].set(j0 * sub0)
    valid = jnp.zeros((cap,), jnp.bool_).at[:init_n].set(True)
    overflow = jnp.asarray(k0 > cap)

    words0 = f.l_words if H == 1 else f.t_words
    valid = valid & (bitvec.get_bit_2d(words0, pred, pos) == 1)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
        child_base0 = f.level_start[pred, lvl + 1] + j * r
        ch = jnp.arange(k, dtype=jnp.int32)
        cpos = child_base0[:, None] + jnp.where(
            is_row, fdig[lvl + 1] * k + ch[None, :], ch[None, :] * k + fdig[lvl + 1]
        )
        cbase = base[:, None] + ch[None, :] * sub
        wordsc = f.l_words if last_child else f.t_words
        cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, base) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), cbase.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (ids,) = _compact(valid, cap, base)
    return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow | ovf)


def scan_batch_mixed(
    meta: K2Meta, f: K2Forest, preds, keys, axes, cap: int,
    backend: str | None = None,
) -> QueryResult:
    """Batched mixed row/col scans: axes[i]==0 -> row (S,P,?O), 1 -> col.

    ``backend`` selects the compute substrate: an ``ExecConfig``
    (``core.query``) carries explicit backend + interpret values (the
    compiled-plan path — zero env reads); a bare "pallas"/"jnp" string or
    ``None`` falls back to the legacy ``REPRO_SCAN_BACKEND`` env
    resolution.  "pallas" routes to the batched ``kernels.k2_scan`` TPU
    kernel, "jnp" to the vmapped level-synchronous traversal below.  Both
    produce bit-identical QueryResults (tests/test_k2_scan.py).
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    be, interp = ops.resolve_exec(backend)
    if be == "pallas":
        ids, valid, count, overflow = ops.k2_scan_forest(
            meta, f, preds, keys, axes, cap=cap, interpret=interp
        )
        return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow)
    return jax.vmap(lambda p, x, a: _axis_scan_traced(meta, f, p, x, a, cap))(
        jnp.asarray(preds), jnp.asarray(keys), jnp.asarray(axes)
    )


def _range_scan_traced(meta: K2Meta, f: K2Forest, pred: jax.Array, cap: int) -> PairResult:
    """Single-predicate (?S, P, ?O) traversal (vmap for batches) — the jnp
    reference behind ``range_scan_batch``.

    Level 0 bit-tests every root child and only then compacts the frontier:
    overflow latches only when more than ``cap`` root children are actually
    occupied.  (The previous code truncated the ``r0`` root radix to ``cap``
    *before* the bit test, falsely reporting overflow — and silently
    dropping candidates — for any sparse matrix under a large root radix.)
    """
    H = meta.n_levels
    pred = jnp.asarray(pred, dtype=jnp.int32)
    k0, r0, sub0 = meta.ks[0], meta.radices[0], meta.subsides[0]

    d0 = jnp.arange(r0, dtype=jnp.int32)
    words0 = f.l_words if H == 1 else f.t_words
    bit0 = bitvec.get_bit_2d(words0, pred, d0)
    valid, _, ovf, (pos, rbase, cbase) = _compact(
        bit0 == 1, cap, d0, (d0 // k0) * sub0, (d0 % k0) * sub0
    )
    overflow = ovf
    pos = jnp.where(valid, pos, 0)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
        child_base0 = f.level_start[pred, lvl + 1] + j * r
        d = jnp.arange(r, dtype=jnp.int32)
        cpos = child_base0[:, None] + d[None, :]
        crb = rbase[:, None] + (d[None, :] // k) * sub
        ccb = cbase[:, None] + (d[None, :] % k) * sub
        wordsc = f.l_words if last_child else f.t_words
        cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, rbase, cbase) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), crb.reshape(-1), ccb.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (rows, cols) = _compact(valid, cap, rbase, cbase)
    return PairResult(rows, cols, valid, count, overflow | ovf)


def range_scan_batch(
    meta: K2Meta, f: K2Forest, preds, cap: int, backend: str | None = None
) -> PairResult:
    """Batched (?S, P, ?O) pair enumeration, one lane per predicate.

    ``backend`` resolves exactly like ``scan_batch_mixed`` (ExecConfig /
    string / None): "pallas" routes to the batched ``kernels.k2_range``
    TPU kernel, "jnp" to the vmapped traversal above.  Bit-identical
    outputs (tests/test_k2_range.py).
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    preds = jnp.asarray(preds, jnp.int32)
    be, interp = ops.resolve_exec(backend)
    if be == "pallas":
        rows, cols, valid, count, overflow = ops.k2_range_forest(
            meta, f, preds, cap=cap, interpret=interp
        )
        return PairResult(rows, cols, valid, count, overflow)
    return jax.vmap(lambda p: _range_scan_traced(meta, f, p, cap))(preds)


def range_scan(meta: K2Meta, f: K2Forest, pred, cap: int,
               backend: str | None = None) -> PairResult:
    """(?S, P, ?O): all pairs of one predicate's matrix (Morton order)."""
    r = range_scan_batch(
        meta, f, jnp.reshape(jnp.asarray(pred, jnp.int32), (1,)), cap, backend
    )
    return jax.tree.map(lambda x: x[0], r)


def range_scan_all_preds(meta: K2Meta, f: K2Forest, cap: int,
                         backend: str | None = None) -> PairResult:
    """(?S, ?P, ?O): dataset dump, axis 0 = predicate."""
    preds = jnp.arange(f.n_preds, dtype=jnp.int32)
    return range_scan_batch(meta, f, preds, cap, backend)


def scan_rebind_batch(
    meta: K2Meta, f: K2Forest, preds1, keys1, axes1, preds2, axes2,
    cap_x: int, cap_y: int, backend: str | None = None,
):
    """Fused X-resolution + re-bind (join categories D–F).

    Per query lane: scan (preds1, keys1, axes1) into a ``cap_x`` side-list
    of ?X ids, then re-bind each X into pattern 2 as (preds2, X, axes2)
    scans of ``cap_y``.  Invalid X lanes scan key 0; callers mask their
    ``y_valid`` rows with ``x_valid``.

    Returns ``(x_ids, x_valid, x_count, x_overflow, y_ids, y_valid,
    y_count, y_overflow)`` shaped ``(Q,cap_x) ×2, (Q,) ×2,
    (Q,cap_x,cap_y) ×2, (Q,cap_x) ×2`` — 0-based coordinates throughout.
    "pallas" runs the fused ``kernels.k2_scan.k2_scan_rebind`` kernel (no
    host round-trip between the two traversals); "jnp" composes the two
    vmapped traversals.  Bit-identical outputs (tests/test_joins_kernel.py).
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    preds1 = jnp.asarray(preds1, jnp.int32)
    keys1 = jnp.asarray(keys1, jnp.int32)
    axes1 = jnp.asarray(axes1, jnp.int32)
    preds2 = jnp.asarray(preds2, jnp.int32)
    axes2 = jnp.asarray(axes2, jnp.int32)
    be, interp = ops.resolve_exec(backend)
    if be == "pallas":
        return ops.k2_scan_rebind_forest(
            meta, f, preds1, keys1, axes1, preds2, axes2,
            cap_x=cap_x, cap_y=cap_y, interpret=interp,
        )
    (q,) = preds1.shape
    # pin the resolved pair for the two sub-scans: re-passing a bare "jnp"
    # string would re-resolve interpret from the environment — an env read
    # inside compiled plan paths (tests/test_backend_flag.py)
    pinned = types.SimpleNamespace(backend="jnp", interpret=interp)
    rx = scan_batch_mixed(meta, f, preds1, keys1, axes1, cap_x, pinned)
    keys2 = jnp.where(rx.valid, rx.ids, 0).reshape(q * cap_x)
    p2 = jnp.broadcast_to(preds2[:, None], (q, cap_x)).reshape(q * cap_x)
    a2 = jnp.broadcast_to(axes2[:, None], (q, cap_x)).reshape(q * cap_x)
    ry = scan_batch_mixed(meta, f, p2, keys2, a2, cap_y, pinned)
    return (
        rx.ids, rx.valid, rx.count, rx.overflow,
        ry.ids.reshape(q, cap_x, cap_y), ry.valid.reshape(q, cap_x, cap_y),
        ry.count.reshape(q, cap_x), ry.overflow.reshape(q, cap_x),
    )
