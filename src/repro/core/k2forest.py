"""K2Forest — the vertical-partitioning arena: one k²-tree per predicate.

The paper keeps |P| independent k²-trees.  For a device-resident engine we
pack them into padded 2-D word arenas ``(P, W)`` so that

  * the predicate axis is shardable (``model`` axis of the production mesh —
    vertical partitioning *is* the sharding scheme, lifted to the pod level);
  * a batch of queries with per-query predicate ids lowers to gathers
    ``words[pred, pos >> 5]`` — no per-query row materialization.

All trees share one ``K2Meta`` (same matrix side = dictionary extent, padded
to the hybrid-k power — exactly the paper's square-matrix construction).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitvec, k2tree
from repro.core.k2tree import K2Meta, PairResult, QueryResult, _compact


class K2Forest(NamedTuple):
    t_words: jax.Array  # uint32[P, Wt]
    t_rank: jax.Array  # int32[P, Wt]
    l_words: jax.Array  # uint32[P, Wl]
    ones_before: jax.Array  # int32[P, max(H-1,1)]
    level_start: jax.Array  # int32[P, H]
    nnz: jax.Array  # int32[P]

    @property
    def n_preds(self) -> int:
        return self.t_words.shape[0]


class ForestStats(NamedTuple):
    """Honest compression accounting (padding is a layout, not a size)."""

    total_bits: int  # sum over predicates of (|T| + |L|)
    padded_bits: int  # device-arena footprint
    per_pred_bits: np.ndarray
    per_pred_nnz: np.ndarray


def build_forest(
    coords: Sequence[tuple[np.ndarray, np.ndarray]], meta: K2Meta
) -> tuple[K2Forest, ForestStats]:
    """Build one tree per predicate from (rows, cols) coordinate lists."""
    hosts = [k2tree.build_host(r, c, meta) for (r, c) in coords]
    P = len(hosts)
    H = meta.n_levels
    wt = max(1, max((h.t_bits.shape[0] + 31) // 32 for h in hosts))
    wl = max(1, max((h.l_bits.shape[0] + 31) // 32 for h in hosts))

    t_words = np.zeros((P, wt), np.uint32)
    t_rank = np.zeros((P, wt), np.int32)
    l_words = np.zeros((P, wl), np.uint32)
    ones_before = np.zeros((P, max(H - 1, 1)), np.int32)
    level_start = np.zeros((P, H), np.int32)
    nnz = np.zeros((P,), np.int32)
    bits = np.zeros((P,), np.int64)
    for i, h in enumerate(hosts):
        tw = bitvec.pack_bits_np(h.t_bits)
        t_words[i, : tw.shape[0]] = tw
        t_rank[i, : tw.shape[0]] = bitvec.rank_blocks_np(tw)
        # padding words rank-extend so rank1 beyond the tree stays monotone
        if tw.shape[0] < wt:
            total = int(h.t_bits.sum())
            t_rank[i, tw.shape[0]:] = total
        lw = bitvec.pack_bits_np(h.l_bits)
        l_words[i, : lw.shape[0]] = lw
        ones_before[i, : h.ones_before.shape[0]] = h.ones_before
        level_start[i] = h.level_start
        nnz[i] = h.nnz
        bits[i] = h.t_bits.shape[0] + h.l_bits.shape[0]

    forest = K2Forest(
        t_words=jnp.asarray(t_words),
        t_rank=jnp.asarray(t_rank),
        l_words=jnp.asarray(l_words),
        ones_before=jnp.asarray(ones_before),
        level_start=jnp.asarray(level_start),
        nnz=jnp.asarray(nnz),
    )
    stats = ForestStats(
        total_bits=int(bits.sum()),
        padded_bits=int(P * (wt + wl) * 32 + t_rank.size * 32),
        per_pred_bits=bits,
        per_pred_nnz=nnz.copy(),
    )
    return forest, stats


# ---------------------------------------------------------------------------
# batched queries — 2-D indexed (pred travels with every lane)
# ---------------------------------------------------------------------------


def check(
    meta: K2Meta, f: K2Forest, pred: jax.Array, rows: jax.Array, cols: jax.Array
) -> jax.Array:
    """Batched (S, P, O) over per-lane predicates -> bool[Q]."""
    H = meta.n_levels
    pred = pred.astype(jnp.int32)
    rd = k2tree._row_digits(meta, rows.astype(jnp.int32))
    cd = k2tree._row_digits(meta, cols.astype(jnp.int32))
    alive = jnp.ones(rows.shape, dtype=jnp.bool_)
    pos = (rd[0] * meta.ks[0] + cd[0]).astype(jnp.int32)
    for lvl in range(H):
        last = lvl == H - 1
        words = f.l_words if last else f.t_words
        bit = bitvec.get_bit_2d(words, pred, pos)
        alive = alive & (bit == 1)
        if not last:
            j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
            nxt = rd[lvl + 1] * meta.ks[lvl + 1] + cd[lvl + 1]
            pos = f.level_start[pred, lvl + 1] + j * meta.radices[lvl + 1] + nxt
            pos = jnp.where(alive, pos, 0).astype(jnp.int32)
    return alive


def check_all_preds(meta: K2Meta, f: K2Forest, row: jax.Array, col: jax.Array) -> jax.Array:
    """(S, ?P, O): bool[P] — the paper's 'check the cell in every tree'."""
    P = f.n_preds
    preds = jnp.arange(P, dtype=jnp.int32)
    return check(meta, f, preds, jnp.broadcast_to(row, (P,)), jnp.broadcast_to(col, (P,)))


def _axis_scan(
    meta: K2Meta, f: K2Forest, pred: jax.Array, fixed: jax.Array, cap: int, axis: int
) -> QueryResult:
    """Single-query row/col scan on predicate ``pred`` (vmap for batches)."""
    H = meta.n_levels
    pred = pred.astype(jnp.int32)
    fdig = k2tree._row_digits(meta, fixed.astype(jnp.int32))

    k0 = meta.ks[0]
    sub0 = meta.subsides[0]
    init_n = min(k0, cap)
    j0 = jnp.arange(init_n, dtype=jnp.int32)
    p0 = fdig[0] * k0 + j0 if axis == 0 else j0 * k0 + fdig[0]
    pos = jnp.zeros((cap,), jnp.int32).at[:init_n].set(p0)
    base = jnp.zeros((cap,), jnp.int32).at[:init_n].set(j0 * sub0)
    valid = jnp.zeros((cap,), jnp.bool_).at[:init_n].set(True)
    overflow = jnp.asarray(k0 > cap)

    words0 = f.l_words if H == 1 else f.t_words
    valid = valid & (bitvec.get_bit_2d(words0, pred, pos) == 1)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
        child_base0 = f.level_start[pred, lvl + 1] + j * r
        ch = jnp.arange(k, dtype=jnp.int32)
        if axis == 0:
            cpos = child_base0[:, None] + fdig[lvl + 1] * k + ch[None, :]
        else:
            cpos = child_base0[:, None] + ch[None, :] * k + fdig[lvl + 1]
        cbase = base[:, None] + ch[None, :] * sub
        wordsc = f.l_words if last_child else f.t_words
        cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, base) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), cbase.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (ids,) = _compact(valid, cap, base)
    return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow | ovf)


def row_scan(meta: K2Meta, f: K2Forest, pred, row, cap: int) -> QueryResult:
    """(S, P, ?O) — direct neighbors, ascending object id."""
    return _axis_scan(meta, f, jnp.asarray(pred), jnp.asarray(row), cap, axis=0)


def col_scan(meta: K2Meta, f: K2Forest, pred, col, cap: int) -> QueryResult:
    """(?S, P, O) — reverse neighbors, ascending subject id."""
    return _axis_scan(meta, f, jnp.asarray(pred), jnp.asarray(col), cap, axis=1)


def row_scan_batch(meta: K2Meta, f: K2Forest, preds, rows, cap: int) -> QueryResult:
    return jax.vmap(lambda p, r: _axis_scan(meta, f, p, r, cap, 0))(
        jnp.asarray(preds), jnp.asarray(rows)
    )


def col_scan_batch(meta: K2Meta, f: K2Forest, preds, cols, cap: int) -> QueryResult:
    return jax.vmap(lambda p, c: _axis_scan(meta, f, p, c, cap, 1))(
        jnp.asarray(preds), jnp.asarray(cols)
    )


def row_scan_all_preds(meta: K2Meta, f: K2Forest, row, cap: int) -> QueryResult:
    """(S, ?P, ?O): per-predicate object lists, result axis 0 = predicate."""
    preds = jnp.arange(f.n_preds, dtype=jnp.int32)
    return row_scan_batch(meta, f, preds, jnp.broadcast_to(jnp.asarray(row), (f.n_preds,)), cap)


def col_scan_all_preds(meta: K2Meta, f: K2Forest, col, cap: int) -> QueryResult:
    """(?S, ?P, O): per-predicate subject lists."""
    preds = jnp.arange(f.n_preds, dtype=jnp.int32)
    return col_scan_batch(meta, f, preds, jnp.broadcast_to(jnp.asarray(col), (f.n_preds,)), cap)


def _axis_scan_traced(
    meta: K2Meta, f: K2Forest, pred: jax.Array, fixed: jax.Array, axis: jax.Array, cap: int
) -> QueryResult:
    """Like ``_axis_scan`` but the row/col axis is a *traced* per-query flag.

    This lets one compiled program serve a mixed batch of direct-neighbor
    (S,P,?O) and reverse-neighbor (?S,P,O) scans — the serving hot path.
    """
    H = meta.n_levels
    pred = pred.astype(jnp.int32)
    is_row = (jnp.asarray(axis, jnp.int32) == 0)
    fdig = k2tree._row_digits(meta, fixed.astype(jnp.int32))

    k0 = meta.ks[0]
    sub0 = meta.subsides[0]
    init_n = min(k0, cap)
    j0 = jnp.arange(init_n, dtype=jnp.int32)
    p0 = jnp.where(is_row, fdig[0] * k0 + j0, j0 * k0 + fdig[0])
    pos = jnp.zeros((cap,), jnp.int32).at[:init_n].set(p0)
    base = jnp.zeros((cap,), jnp.int32).at[:init_n].set(j0 * sub0)
    valid = jnp.zeros((cap,), jnp.bool_).at[:init_n].set(True)
    overflow = jnp.asarray(k0 > cap)

    words0 = f.l_words if H == 1 else f.t_words
    valid = valid & (bitvec.get_bit_2d(words0, pred, pos) == 1)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
        child_base0 = f.level_start[pred, lvl + 1] + j * r
        ch = jnp.arange(k, dtype=jnp.int32)
        cpos = child_base0[:, None] + jnp.where(
            is_row, fdig[lvl + 1] * k + ch[None, :], ch[None, :] * k + fdig[lvl + 1]
        )
        cbase = base[:, None] + ch[None, :] * sub
        wordsc = f.l_words if last_child else f.t_words
        cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, base) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), cbase.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (ids,) = _compact(valid, cap, base)
    return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow | ovf)


def scan_batch_mixed(
    meta: K2Meta, f: K2Forest, preds, keys, axes, cap: int,
    backend: str | None = None,
) -> QueryResult:
    """Batched mixed row/col scans: axes[i]==0 -> row (S,P,?O), 1 -> col.

    ``backend`` selects the compute substrate: "pallas" routes to the batched
    ``kernels.k2_scan`` TPU kernel, "jnp" to the vmapped level-synchronous
    traversal below; None defers to ``kernels.ops.scan_backend()`` (the
    ``REPRO_SCAN_BACKEND`` env flag, default "pallas").  Both produce
    bit-identical QueryResults (tests/test_k2_scan.py).
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    if ops.scan_backend(backend) == "pallas":
        ids, valid, count, overflow = ops.k2_scan_forest(
            meta, f, preds, keys, axes, cap=cap
        )
        return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow)
    return jax.vmap(lambda p, x, a: _axis_scan_traced(meta, f, p, x, a, cap))(
        jnp.asarray(preds), jnp.asarray(keys), jnp.asarray(axes)
    )


def range_scan(meta: K2Meta, f: K2Forest, pred, cap: int) -> PairResult:
    """(?S, P, ?O): all pairs of one predicate's matrix."""
    H = meta.n_levels
    pred = jnp.asarray(pred, dtype=jnp.int32)
    k0, r0, sub0 = meta.ks[0], meta.radices[0], meta.subsides[0]

    init_n = min(r0, cap)
    d0 = jnp.arange(init_n, dtype=jnp.int32)
    pos = jnp.zeros((cap,), jnp.int32).at[:init_n].set(d0)
    rbase = jnp.zeros((cap,), jnp.int32).at[:init_n].set((d0 // k0) * sub0)
    cbase = jnp.zeros((cap,), jnp.int32).at[:init_n].set((d0 % k0) * sub0)
    valid = jnp.zeros((cap,), jnp.bool_).at[:init_n].set(True)
    overflow = jnp.asarray(r0 > cap)

    words0 = f.l_words if H == 1 else f.t_words
    valid = valid & (bitvec.get_bit_2d(words0, pred, pos) == 1)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1_2d(f.t_words, f.t_rank, pred, pos) - f.ones_before[pred, lvl]
        child_base0 = f.level_start[pred, lvl + 1] + j * r
        d = jnp.arange(r, dtype=jnp.int32)
        cpos = child_base0[:, None] + d[None, :]
        crb = rbase[:, None] + (d[None, :] // k) * sub
        ccb = cbase[:, None] + (d[None, :] % k) * sub
        wordsc = f.l_words if last_child else f.t_words
        cbit = bitvec.get_bit_2d(wordsc, pred, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, rbase, cbase) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), crb.reshape(-1), ccb.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (rows, cols) = _compact(valid, cap, rbase, cbase)
    return PairResult(rows, cols, valid, count, overflow | ovf)


def range_scan_all_preds(meta: K2Meta, f: K2Forest, cap: int) -> PairResult:
    """(?S, ?P, ?O): dataset dump, axis 0 = predicate."""
    preds = jnp.arange(f.n_preds, dtype=jnp.int32)
    return jax.vmap(lambda p: range_scan(meta, f, p, cap))(preds)
