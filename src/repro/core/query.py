"""The compiled-plan query API: ``Query`` → ``Engine.compile(ExecConfig)`` → ``Plan``.

This module is the ONE place execution knobs enter the system.  A query is
described declaratively (variables as ``"?name"`` strings or ``None`` for
anonymous, constants as 1-based dictionary ids), paired with a frozen,
hashable :class:`ExecConfig`, and lowered by ``Engine.compile`` into a
:class:`Plan` — a compile-once / run-many handle over the serve IR
(``core.engine.make_serve_step`` / ``make_sharded_serve_step``).

Query kinds
-----------

``TriplePatternQ(s, p, o)``
    Any of the paper's eight triple patterns.  Bound positions are ints,
    free positions are variables.  The *shape* (which positions are bound)
    selects the compiled program; the ids themselves are runtime inputs,
    so ``compile`` is amortized across every query of the same shape.

``JoinQ(category, vpos1, vpos2, p1, c1, p2, c2)``
    The paper's join categories A–F (``core.joins``).

``BgpQ(patterns)``
    A basic graph pattern — conjunction of triple patterns with shared
    variables — planned and executed by ``core.optimizer`` through the
    same serve-step machinery.

``ServeQ(unbounded)``
    The raw serve-IR passthrough: ``Plan(batch)`` takes a ``ServeBatch``
    spanning every keyed + unbounded op and returns the ``ServeResult``
    — the multi-tenant production surface.

Execution config
----------------

:class:`ExecConfig` is a frozen dataclass — hashable, so it keys plan and
program caches directly.  ``ExecConfig.from_env()`` is the ONLY sanctioned
consumer of the legacy ``REPRO_SCAN_BACKEND`` / ``REPRO_PALLAS_INTERPRET``
environment flags: it reads them once into an explicit config; nothing on
a compiled ``Plan.__call__`` path consults ``os.environ``
(tests/test_backend_flag.py enforces this).

Cap policy
----------

Fixed result capacities are what make the whole pipeline jit-able; the
PR-4 contract is that truncation is never silent.  :class:`CapPolicy`
upgrades "never silent" to "self-healing": on overflow the plan recompiles
at doubled cap (up to ``max_doublings``) and re-runs, so callers get the
complete answer without hand-tuning ``cap``.  ``grow=False`` restores the
raise-on-overflow behavior (:class:`CapOverflow`).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

Term = Any  # int (bound 1-based id) | str "?name" | None (anonymous variable)

SCAN_BACKENDS = ("pallas", "jnp")
PRED_INDEX_LAYOUTS = ("dac", "fixed")


class CapOverflow(RuntimeError):
    """A fixed-capacity result buffer truncated and the policy forbids (or
    exhausted) growth.  Subclasses ``RuntimeError`` so pre-redesign callers
    catching the old truncation errors keep working."""


class AdmissionError(RuntimeError):
    """Plan-cache admission denied: compiling this plan would exceed the
    caller's budget.  Raised by ``Engine.compile(..., admit=fn)`` when the
    ``admit`` callback vetoes a cache MISS — cache hits are never charged,
    so shared already-compiled programs stay free.  The multi-tenant serve
    broker translates per-tenant plan quotas into this."""


def default_interpret() -> bool:
    """The ONE definition of the auto interpret default: Pallas interpret
    mode everywhere except a real TPU backend.  Deterministic — consulted
    by ``ExecConfig.resolved()`` and ``kernels.ops.resolve_exec`` alike,
    never by reading the environment."""
    import jax

    return jax.default_backend() != "tpu"


def is_var(t: Term) -> bool:
    """Variables are ``None`` (anonymous) or ``"?name"`` strings."""
    return t is None or isinstance(t, str)


@dataclasses.dataclass(frozen=True)
class CapPolicy:
    """What a plan does when a result buffer overflows its cap.

    ``grow=True``: recompile at doubled cap and re-run, at most
    ``max_doublings`` times (the doubled programs land in the same program
    cache, so a grown plan stays warm).  ``grow=False``: raise
    :class:`CapOverflow` immediately.
    """

    grow: bool = True
    max_doublings: int = 6


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Frozen, hashable execution config — the only way knobs reach a plan.

    ``backend``
        Traversal substrate: "pallas" (batched TPU kernels) or "jnp"
        (vmapped reference traversal).
    ``interpret``
        Pallas interpret mode.  ``None`` = auto (interpret everywhere but
        a real TPU backend) — resolved once at compile time, never from
        the environment.
    ``cap`` / ``cap_y``
        Result capacities: ``cap`` for scan/side-list/X lanes, ``cap_y``
        for the re-bind (Y) lanes of join categories D–F.
    ``cap_policy``
        Overflow handling; see :class:`CapPolicy`.
    ``use_pred_index``
        Serve unbounded-``?P`` lanes through the SP/OP predicate index
        (k²-triples+) when the store carries one; ``False`` forces the
        all-preds sweep fallback.
    ``u_width_quantile``
        Sizes the unbounded candidate lane at this quantile of the
        per-entity predicate-degree distribution (per axis: the width is
        ``max(quantile(SP degrees), quantile(OP degrees))``) instead of
        ``max_degree``.  Outlier entities whose candidate list exceeds the
        lane (the index's ``truncated`` bit) are routed to the all-preds
        sweep fallback, so answers stay exact.  ``1.0`` = exact sizing
        from ``max_degree`` (no outliers).
    ``pred_index_layout``
        On-device layout of the SP/OP predicate index: "dac" (default —
        multi-level DAC(b=8) chunks + flag bitmaps, decoded inside the
        gather kernel) or "fixed" (byte-packed fallback).  Part of the
        program cache key, so plans over different layouts coexist;
        results are bit-identical across layouts (the differential suite
        enforces this).
    ``donate_batch``
        Donate the per-batch query-key buffers (the ``ServeBatch`` /
        lane arrays) to the compiled serve-step program
        (``jax.jit(donate_argnums=...)``), letting XLA alias their device
        memory for outputs on the hot dispatch path.  The engine copies
        caller-held device arrays defensively before a donating call, so
        semantics don't change; host (numpy) inputs are unaffected.
        Ignored (off) for sharded programs.
    ``mesh`` / ``data_axes`` / ``model_axis``
        When ``mesh`` is set, plans compile the shard_map'd serve step:
        forest sharded by predicate over ``model_axis``, query batches
        over ``data_axes``.
    """

    backend: str = "pallas"
    interpret: bool | None = None
    cap: int = 4096
    cap_y: int = 256
    cap_policy: CapPolicy = CapPolicy()
    use_pred_index: bool = True
    u_width_quantile: float = 1.0
    pred_index_layout: str = "dac"
    donate_batch: bool = True
    mesh: Any = None  # jax.sharding.Mesh | None (Mesh is hashable)
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"

    def __post_init__(self):
        if self.backend not in SCAN_BACKENDS:
            raise ValueError(
                f"unknown scan backend {self.backend!r} (want one of {SCAN_BACKENDS})"
            )
        if not (0.0 < self.u_width_quantile <= 1.0):
            raise ValueError(
                f"u_width_quantile must be in (0, 1], got {self.u_width_quantile}"
            )
        if self.cap < 1 or self.cap_y < 1:
            raise ValueError("cap and cap_y must be >= 1")
        if self.pred_index_layout not in PRED_INDEX_LAYOUTS:
            raise ValueError(
                f"unknown pred_index_layout {self.pred_index_layout!r} "
                f"(want one of {PRED_INDEX_LAYOUTS})"
            )

    @classmethod
    def from_env(cls, **overrides) -> "ExecConfig":
        """The one-time environment read.

        Folds the legacy ``REPRO_SCAN_BACKEND`` / ``REPRO_PALLAS_INTERPRET``
        flags into an explicit config ONCE, at call time; the returned
        config carries concrete values, so nothing downstream re-reads the
        environment.  ``overrides`` are applied on top.
        """
        if "backend" not in overrides:
            overrides["backend"] = os.environ.get("REPRO_SCAN_BACKEND", "pallas")
        if "interpret" not in overrides:
            # tri-state: unset -> auto (default_interpret), "0" -> force
            # compiled, anything else -> force interpret.  The pre-fix
            # expression (`env != "0" and default_interpret()`) collapsed
            # an explicit "1" into the auto default, silently ignoring it
            # on TPU backends where the default is False.
            raw = os.environ.get("REPRO_PALLAS_INTERPRET")
            overrides["interpret"] = (
                default_interpret() if raw is None else raw != "0"
            )
        if "pred_index_layout" not in overrides:
            overrides["pred_index_layout"] = os.environ.get(
                "REPRO_PRED_INDEX_LAYOUT", "dac"
            )
        return cls(**overrides)

    def resolved(self) -> "ExecConfig":
        """Fill ``interpret=None`` with :func:`default_interpret`.

        Deterministic — depends on the jax backend, never the environment.
        """
        if self.interpret is not None:
            return self
        return dataclasses.replace(self, interpret=default_interpret())

    def replace(self, **kw) -> "ExecConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Frozen observability config — the knobs behind ``repro.obs.enable``.

    ``trace``
        Record host-side spans into a ring-buffered tracer (exported as
        Chrome ``trace_event`` JSON, loadable in Perfetto).
    ``metrics``
        Record timing histograms / gauges into the global
        ``repro.obs`` metrics registry.  (The broker's own bookkeeping
        registry backing ``ServeBroker.stats()`` is always on; this knob
        governs only the obs-layer extras.)
    ``trace_capacity``
        Ring size in spans; when full, the OLDEST spans are dropped and
        counted — a long run degrades to a suffix window, never to
        back-pressure.
    ``device_annotations``
        Bridge live spans into ``jax.profiler.TraceAnnotation`` so a
        device profile captured around the same run carries the same
        span names.
    """

    trace: bool = True
    metrics: bool = True
    trace_capacity: int = 1 << 16
    device_annotations: bool = False

    def __post_init__(self):
        if self.trace_capacity < 1:
            raise ValueError("trace_capacity must be >= 1")

    def replace(self, **kw) -> "ObsConfig":
        return dataclasses.replace(self, **kw)


def run_with_policy(policy: CapPolicy, cap: int, cap_y: int, fn):
    """Run ``fn(cap, cap_y)`` under the cap policy.

    On :class:`CapOverflow` both caps double (the rebind ``cap_y`` lanes
    overflow under the same conditions as the X lanes) and ``fn`` re-runs,
    at most ``policy.max_doublings`` times.  Returns
    ``(result, cap, cap_y)`` so callers can persist the grown caps.
    """
    doublings = 0
    while True:
        try:
            return fn(cap, cap_y), cap, cap_y
        except CapOverflow:
            if not policy.grow or doublings >= policy.max_doublings:
                raise
            doublings += 1
            cap *= 2
            cap_y *= 2


# ---------------------------------------------------------------------------
# query descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriplePatternQ:
    """One triple pattern: ints bind a position, ``"?x"``/``None`` free it."""

    s: Term = None
    p: Term = None
    o: Term = None

    @property
    def bound(self) -> tuple[bool, bool, bool]:
        return (not is_var(self.s), not is_var(self.p), not is_var(self.o))

    @property
    def variables(self) -> tuple[str, ...]:
        # a named variable may legitimately repeat inside a BgpQ pattern
        # (join-on-self semantics, handled by the optimizer); a standalone
        # TriplePatternQ plan rejects that at compile time
        return tuple(
            t for t in (self.s, self.p, self.o) if isinstance(t, str)
        )


JOIN_CATEGORIES = "ABCDEF"
# which of (p1, c1, p2, c2) each category requires (vpos1/vpos2 always)
_JOIN_FIELDS = {
    "A": ("p1", "c1", "p2", "c2"),
    "B": ("p1", "c1", "c2"),
    "C": ("c1", "c2"),
    "D": ("p1", "c1", "p2"),
    "E": ("p1", "c1"),
    "F": ("c1",),
}


@dataclasses.dataclass(frozen=True)
class JoinQ:
    """A paper join category A–F (two patterns sharing variable ?X).

    ``vpos1``/``vpos2`` name the position ("s"/"o") of ?X in each pattern;
    ``p*``/``c*`` are the bound predicate / non-join constant of each side
    (which ones are required depends on the category — see
    ``core.joins``).
    """

    category: str
    vpos1: str
    vpos2: str
    p1: int | None = None
    c1: int | None = None
    p2: int | None = None
    c2: int | None = None

    def __post_init__(self):
        if self.category not in JOIN_CATEGORIES:
            raise ValueError(f"unknown join category {self.category!r}")
        if self.vpos1 not in ("s", "o") or self.vpos2 not in ("s", "o"):
            raise ValueError("vpos1/vpos2 must be 's' or 'o'")
        for fld in _JOIN_FIELDS[self.category]:
            if getattr(self, fld) is None:
                raise ValueError(
                    f"join category {self.category} requires {fld}="
                )


@dataclasses.dataclass(frozen=True)
class BgpQ:
    """Basic graph pattern: a conjunction of ≥1 triple patterns."""

    patterns: tuple[TriplePatternQ, ...]

    def __post_init__(self):
        pats = tuple(
            p if isinstance(p, TriplePatternQ) else TriplePatternQ(*p)
            for p in self.patterns
        )
        object.__setattr__(self, "patterns", pats)


def _coerce_block(ps):
    return tuple(
        p if isinstance(p, TriplePatternQ) else TriplePatternQ(*p) for p in ps
    )


@dataclasses.dataclass(frozen=True)
class SelectQ:
    """SPARQL-shaped SELECT over one group graph pattern.

    ``where`` is the base conjunction; each entry of ``union`` is an
    alternative branch (the branches' union is joined with ``where``);
    each entry of ``optional`` is an OPTIONAL block left-joined in
    declaration order; ``filter`` holds ``core.algebra`` expressions
    (``Cmp``/``Bound``/``And``/``Or``/``Not``, SPARQL 3-valued logic);
    ``select`` projects (``None`` = every named variable), ``order_by``
    entries are ``"?v"`` ascending / ``"-?v"`` descending, and
    ``limit``/``offset`` slice the ordered result.  Results are DISTINCT
    (set semantics, like ``BgpQ``); the ORDER BY ties break over the
    remaining columns in sorted-name order, so a LIMIT cut is
    deterministic.

    Lowered by ``core.algebra.from_select`` to an operator tree and
    executed by ``core.planner`` — cost-ordered conjunctive blocks with
    sideways information passing over the engine's pooled serve
    programs.
    """

    where: tuple[TriplePatternQ, ...] = ()
    optional: tuple[tuple[TriplePatternQ, ...], ...] = ()
    union: tuple[tuple[TriplePatternQ, ...], ...] = ()
    filter: tuple[Any, ...] = ()
    select: tuple[str, ...] | None = None
    order_by: tuple[str, ...] = ()
    limit: int | None = None
    offset: int = 0

    def __post_init__(self):
        object.__setattr__(self, "where", _coerce_block(self.where))
        object.__setattr__(
            self, "optional", tuple(_coerce_block(b) for b in self.optional)
        )
        object.__setattr__(
            self, "union", tuple(_coerce_block(b) for b in self.union)
        )
        object.__setattr__(self, "filter", tuple(self.filter))
        if self.select is not None:
            object.__setattr__(self, "select", tuple(self.select))
        object.__setattr__(self, "order_by", tuple(self.order_by))
        if not self.where and not self.union:
            raise ValueError("SelectQ needs a WHERE or UNION block")
        for spec in self.order_by:
            v = spec[1:] if spec.startswith("-") else spec
            if not v.startswith("?"):
                raise ValueError(
                    f"order_by entries are '?v' or '-?v', got {spec!r}"
                )
        if self.limit is not None and self.limit < 0:
            raise ValueError("limit must be >= 0")
        if self.offset < 0:
            raise ValueError("offset must be >= 0")


@dataclasses.dataclass(frozen=True)
class ServeQ:
    """Raw serve-IR passthrough: ``Plan(batch)`` takes a ``ServeBatch``.

    ``unbounded=False`` compiles the unbounded-``?P`` lanes out entirely —
    a batch of only CHECK/ROW/COL ops never pays for the ``u_*`` block.
    """

    unbounded: bool = True


Query = Any  # TriplePatternQ | JoinQ | BgpQ | SelectQ | ServeQ


def shape_key(query: Query):
    """The plan-cache key component: everything that selects a compiled
    program, nothing that is a runtime input (the constant ids)."""
    if isinstance(query, TriplePatternQ):
        return ("pattern", query.bound)
    if isinstance(query, JoinQ):
        return ("join", query.category, query.vpos1, query.vpos2)
    if isinstance(query, BgpQ):
        # BGP planning is data-dependent (cardinality estimates), so the
        # host plan re-runs per call; the compiled programs underneath are
        # shared via the engine's serve-lane pool for ANY BgpQ.
        return ("bgp",)
    if isinstance(query, SelectQ):
        # like BgpQ: planning is data-dependent and re-runs per call; the
        # serve-lane pool underneath is shared across ALL select plans
        return ("select",)
    if isinstance(query, ServeQ):
        return ("serve", query.unbounded)
    raise TypeError(f"not a Query: {query!r}")


# ---------------------------------------------------------------------------
# plan handle
# ---------------------------------------------------------------------------


class StaleEpoch(RuntimeError):
    """A compiled plan outlived a compaction swap of its dynamic store.

    Executors pin the store epoch they were compiled against; running one
    after ``DynamicStore.swap`` would silently serve dropped triples from
    the old forest, so the engine raises this instead.  ``Plan.__call__``
    recompiles transparently; ``Plan.submit`` (the raw device path) lets it
    propagate so the broker can refresh its base plan."""


class Plan:
    """Compile-once / run-many handle returned by ``Engine.compile``.

    ``plan()`` executes the query with its own constants; ``plan(batch)``
    re-executes the same compiled shape over a batch of constants (a dict
    of position → id array for ``TriplePatternQ``, a ``ServeBatch`` for
    ``ServeQ``).  Overflow is handled by the config's :class:`CapPolicy`.

    Plans with the same ``(shape_key(query), config)`` share one executor
    — and therefore one set of compiled programs and one effective
    (possibly grown) cap.
    """

    __slots__ = ("query", "config", "_executor")

    def __init__(self, query: Query, config: ExecConfig, executor):
        self.query = query
        self.config = config
        self._executor = executor

    def __call__(self, batch=None):
        try:
            return self._executor.run(self.query, batch)
        except StaleEpoch:
            # the store was compacted under us — recompile against the new
            # epoch (ids are stable across swaps, so the query still means
            # the same thing) and retry once
            eng = self._executor.engine
            self._executor = eng.compile(self.query, self.config)._executor
            return self._executor.run(self.query, batch)

    def submit(self, batch=None):
        """Asynchronous dispatch: launch the compiled program and return its
        DEVICE results immediately — no host sync, no overflow guard, no
        CapPolicy growth.  The streamed-serving hook: a caller (the
        ``launch.broker`` front-end) can overlap host-side decode of batch N
        with device execution of batch N+1, inspecting ``overflow`` itself.
        Only executors with a raw device surface support it (``ServeQ``)."""
        return self._executor.submit(self.query, batch)

    @property
    def effective_cap(self) -> int:
        """Current cap — ``config.cap`` until growth doubled it."""
        return self._executor.cap

    def compiled_text(self, batch=None) -> str:
        """Compiled-module text of the underlying program (where the
        executor exposes one, e.g. ``ServeQ``) — for asserting
        communication properties like 'no all-gather on the wire'."""
        return self._executor.compiled_text(self.query, batch)

    def cost_profile(self, batch=None) -> dict:
        """Static compile-time cost profile of the underlying program
        (where the executor exposes one, e.g. ``ServeQ``): XLA
        ``cost_analysis`` FLOPs/bytes, memory stats, and the lanes × cap
        geometry — see ``repro.obs.cost``.  Cached per program geometry."""
        return self._executor.cost_profile(self.query, batch)

    def __repr__(self):
        return (
            f"Plan({self.query!r}, backend={self.config.backend!r}, "
            f"cap={self.effective_cap})"
        )
