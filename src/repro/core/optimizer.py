"""BGP query optimizer — the paper's §Future-Work item, implemented.

"a query optimizer might allow more complex conjunctive queries to be
efficiently resolved" (paper, Discussion).  This module plans and executes
basic graph patterns (conjunctions of ≥2 triple patterns with shared
variables) on top of the pattern/join primitives:

  * **cardinality estimation** from k²-triples statistics — nnz per
    predicate tree and the dictionary extents — sharpened by the SP/OP
    predicate index (``core/predindex.py``): a bound subject/object with an
    unbounded ``?p`` is estimated over its CANDIDATE predicates only
    (per-entity predicate degree), not the whole-dataset ``nnz.sum()``;
  * **greedy join ordering**: start from the most selective pattern, then
    repeatedly pick the connected pattern with the lowest estimated result;
  * **binding propagation**: intermediate solutions are ID sets; each next
    pattern is resolved per-binding through the BATCHED engine primitives
    (``scan_batch_mixed``), so an n-pattern query costs one compiled program
    launch per plan step, not per binding.  Unbounded-``?p`` steps gather
    per-row candidate predicates from the index and launch ONE flat
    (row, candidate) batch — no host loop over all |P| predicates (the
    index-free fallback loops, as the differential reference).

Variables are strings starting with '?'.  Returns bindings as numpy arrays.

Entry points: the compiled-plan pipeline lowers a ``core.query.BgpQ``
through :func:`run_bgp` — execution knobs arrive as an ``ExecConfig`` and
check / bounded-scan steps resolve through the engine's pooled compiled
``serve_step`` programs (the ``serve`` callable).  The legacy
:func:`execute_bgp` survives as a deprecation shim that builds the Query
and runs the same core under the cap-growth policy.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import k2forest
from repro.core.k2triples import K2TriplesStore
from repro.core.query import BgpQ, CapOverflow, ExecConfig, TriplePatternQ
from repro.core import query as qapi

Term = Any  # int (bound id) | str '?var'


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: Term
    p: Term
    o: Term

    @property
    def variables(self) -> set[str]:
        return {t for t in (self.s, self.p, self.o) if isinstance(t, str)}


def _is_var(t: Term) -> bool:
    return isinstance(t, str)


def _candidate_preds(store: K2TriplesStore, s: Term, o: Term) -> np.ndarray | None:
    """0-based candidate predicates for an unbounded-?p pattern, or None
    when neither position is a bound in-range id (no pruning possible)."""
    bi = store.pred_index
    if bi is None:
        return None
    cand = None
    if not _is_var(s):
        cand = (
            bi.host_list(s - 1)
            if 1 <= s <= store.n_subjects
            else np.zeros(0, np.int32)
        )
    if not _is_var(o):
        op_list = (
            bi.host_list(store.n_subjects + o - 1)
            if 1 <= o <= store.n_objects
            else np.zeros(0, np.int32)
        )
        cand = op_list if cand is None else np.intersect1d(cand, op_list)
    return cand


def estimate_cardinality(store: K2TriplesStore, pat: TriplePattern) -> float:
    """Expected result size from per-predicate nnz + dictionary extents,
    predicate-pruned through the SP/OP index when ?p rides a bound s/o."""
    nnz = np.asarray(store.forest.nnz, np.float64)
    n_s = max(store.n_subjects, 1)
    n_o = max(store.n_objects, 1)
    if _is_var(pat.p):
        cand = _candidate_preds(store, pat.s, pat.o)
        total = float(nnz.sum()) if cand is None else float(nnz[cand].sum())
    else:
        total = float(nnz[pat.p - 1]) if 1 <= pat.p <= store.n_preds else 0.0
    sel = 1.0
    if not _is_var(pat.s):
        sel /= n_s
    if not _is_var(pat.o):
        sel /= n_o
    return max(total * sel, 1e-3)


def plan(store: K2TriplesStore, patterns: list[TriplePattern]) -> list[int]:
    """Greedy selectivity-ordered, connectivity-respecting plan."""
    n = len(patterns)
    cards = [estimate_cardinality(store, p) for p in patterns]
    order = [int(np.argmin(cards))]
    bound_vars = set(patterns[order[0]].variables)
    while len(order) < n:
        best, best_card = None, float("inf")
        for i in range(n):
            if i in order:
                continue
            connected = bool(patterns[i].variables & bound_vars)
            # already-bound variables shrink the estimate sharply
            card = cards[i] / (10.0 if connected else 1.0)
            if not connected:
                card *= 1e6  # cartesian products last
            if card < best_card:
                best, best_card = i, card
        order.append(best)
        bound_vars |= patterns[best].variables
    return order


def _ragged_take(starts: np.ndarray, deg: np.ndarray):
    """Expand ragged rows: flat element indices ``starts[i] + j`` for
    ``j < deg[i]``, plus the owning row of each element."""
    row_idx = np.repeat(np.arange(deg.shape[0]), deg)
    within = np.arange(int(deg.sum())) - np.repeat(np.cumsum(deg) - deg, deg)
    return row_idx, np.repeat(starts, deg) + within


def _ragged_candidates(store: K2TriplesStore, keys: np.ndarray, axis: int):
    """Per-row candidate predicates from the SP (axis 0) / OP (axis 1) index.

    keys: int64[n] 1-based subject/object ids.  Returns ``(row_idx, cand)``
    — the flat (row, candidate) launch layout: candidate ``cand[j]``
    (0-based) belongs to binding row ``row_idx[j]``.
    """
    bi = store.pred_index
    if bi is None:  # index-free fallback: every predicate for every row
        n_rows = keys.shape[0]
        P = store.n_preds
        return (
            np.repeat(np.arange(n_rows), P),
            np.tile(np.arange(P, dtype=np.int64), n_rows),
        )
    offs = bi.host_offsets
    n_ent = store.n_subjects if axis == 0 else store.n_objects
    base = 0 if axis == 0 else store.n_subjects
    rows = base + np.clip(keys - 1, 0, max(n_ent - 1, 0))
    in_range = (keys >= 1) & (keys <= n_ent)
    start = np.where(in_range, offs[rows], 0)
    deg = np.where(in_range, offs[rows + 1] - offs[rows], 0)
    row_idx, elem = _ragged_take(start, deg)
    return row_idx, bi.host_preds[elem].astype(np.int64)


def _resolve_with_bindings(
    store, pat, bindings: dict[str, np.ndarray], cap: int,
    backend=None, serve=None,
):
    """Resolve one pattern given current bindings -> columnar solution arrays.

    Chooses the cheapest realization: check / row scan / col scan /
    pair enumeration, batched over existing binding rows; an unbounded ?p
    with a bound s/o position resolves over index-pruned candidates in ONE
    flat launch.

    ``backend`` threads to the traversals (ExecConfig / string / None —
    see ``k2forest.scan_batch_mixed``).  ``serve`` is an optional serve-IR
    lane runner ``(ops, s, p, o) -> ServeResult`` (the engine's pooled
    compiled ``serve_step``); when given, check and bounded-scan steps run
    through it instead of raw ``k2forest`` launches, so an n-pattern BGP
    shares the programs (and their jit cache) with every other plan.
    """
    meta, f = store.meta, store.forest
    n_rows = len(next(iter(bindings.values()))) if bindings else 1
    pvar = _is_var(pat.p)

    def col(term, default):
        if _is_var(term) and term in bindings:
            return bindings[term].astype(np.int64), True
        if not _is_var(term):
            return np.full(n_rows, term, np.int64), True
        return np.full(n_rows, default, np.int64), False

    p_free = pvar and pat.p not in bindings
    s_arr, s_bound = col(pat.s, 1)
    o_arr, o_bound = col(pat.o, 1)
    p_arr, _ = col(pat.p, 1)
    out_cols: dict[str, list] = {v: [] for v in set(bindings) | pat.variables}

    def emit(rows, cols_list):
        """Keep binding rows ``rows`` and append the new columns.

        ``cols_list`` is positional ``(term, values)`` pairs; a variable
        repeated across positions of ONE pattern (e.g. ``(S, ?b, ?b)``)
        contributes several columns and only rows where they agree survive.
        """
        new: dict[str, np.ndarray] = {}
        keep = np.ones(rows.shape[0], np.bool_)
        for term, vals in cols_list:
            if not _is_var(term) or term in bindings:
                continue
            vals = np.asarray(vals, np.int64)
            if term in new:
                keep &= new[term] == vals
            else:
                new[term] = vals
        rows = rows[keep]
        for v in bindings:
            out_cols[v].append(bindings[v][rows])
        for var, vals in new.items():
            out_cols[var].append(vals[keep])

    def finish():
        return {
            v: (np.concatenate(cs) if cs else np.zeros(0, np.int64))
            for v, cs in out_cols.items()
        }

    if s_bound and o_bound:  # existence check (maybe per candidate pred)
        if p_free:
            # SP(s) candidates (either index half prunes; SP keys the check)
            row_idx, cand = _ragged_candidates(store, s_arr, 0)
        else:
            row_idx, cand = np.arange(n_rows), p_arr - 1
        # a binding value re-used in predicate position may be out of range
        ok = (cand >= 0) & (cand < store.n_preds)
        if serve is not None:
            from repro.core import engine as _eng

            r = serve(
                np.where(ok, _eng.OP_CHECK, -1),
                s_arr[row_idx], np.where(ok, cand + 1, 0), o_arr[row_idx],
            )
            hit = np.asarray(r.hit) & ok
        else:
            hit = np.asarray(
                k2forest.check(
                    meta, f, jnp.asarray(np.where(ok, cand, 0)),
                    jnp.asarray(s_arr[row_idx] - 1),
                    jnp.asarray(o_arr[row_idx] - 1),
                )
            ) & ok
        keep = np.nonzero(hit)[0]
        emit(row_idx[keep], [(pat.p, cand[keep] + 1)])
        return finish()

    if s_bound or o_bound:  # one free s/o position -> batched scan
        axis = 0 if s_bound else 1
        key_arr = s_arr if s_bound else o_arr
        if p_free:
            row_idx, cand = _ragged_candidates(store, key_arr, axis)
        else:
            row_idx, cand = np.arange(n_rows), p_arr - 1
        if row_idx.size == 0:  # no candidates anywhere: empty result
            emit(row_idx, [])
            return finish()
        ok = (cand >= 0) & (cand < store.n_preds)
        if serve is not None:
            from repro.core import engine as _eng

            op = _eng.OP_ROW if axis == 0 else _eng.OP_COL
            keys = key_arr[row_idx]
            r = serve(
                np.where(ok, op, -1),
                keys if axis == 0 else np.zeros_like(keys),
                np.where(ok, cand + 1, 0),
                keys if axis == 1 else np.zeros_like(keys),
            )
            if bool((np.asarray(r.overflow) & ok).any()):
                raise CapOverflow("BGP scan truncated at cap")
            ids = np.asarray(r.ids)  # serve ids are already 1-based
        else:
            r = k2forest.scan_batch_mixed(
                meta, f, jnp.asarray(np.where(ok, cand, 0)),
                jnp.asarray(key_arr[row_idx] - 1),
                jnp.full(row_idx.shape, axis, jnp.int32), cap, backend,
            )
            if bool((np.asarray(r.overflow) & ok).any()):
                raise CapOverflow("BGP scan truncated at cap")
            ids = np.asarray(r.ids) + 1
        lanes, slots = np.nonzero(np.asarray(r.valid) & ok[:, None])
        rows = row_idx[lanes]
        emit(rows, [
            (pat.p, cand[lanes] + 1),
            (pat.o if s_bound else pat.s, ids[lanes, slots]),
        ])
        return finish()

    # neither s nor o realized: enumerate candidate triples by range scan
    # and cross-product with the binding rows (cartesian steps land here)
    upreds = (
        np.arange(1, store.n_preds + 1, dtype=np.int64)
        if p_free
        else np.unique(np.clip(p_arr, 1, store.n_preds))
    )
    pr = k2forest.range_scan_batch(meta, f, jnp.asarray(upreds - 1), cap, backend)
    if bool(np.asarray(pr.overflow).any()):
        raise CapOverflow("BGP pair enumeration truncated at cap")
    pv = np.asarray(pr.valid)
    prow, pcol = np.asarray(pr.rows) + 1, np.asarray(pr.cols) + 1
    counts = pv.sum(axis=1)
    pair_p = np.repeat(upreds, counts)
    lanes, slots = np.nonzero(pv)
    pair_s, pair_o = prow[lanes, slots], pcol[lanes, slots]
    if p_free:
        n_pairs = pair_p.shape[0]
        rows = np.repeat(np.arange(n_rows), n_pairs)
        sel = np.tile(np.arange(n_pairs), n_rows)
    else:  # row i may only use pairs of ITS predicate value
        starts = np.searchsorted(pair_p, p_arr)
        deg = np.searchsorted(pair_p, p_arr, side="right") - starts
        rows, sel = _ragged_take(starts, deg)
    emit(rows, [
        (pat.p, pair_p[sel]), (pat.s, pair_s[sel]), (pat.o, pair_o[sel]),
    ])
    return finish()


def _pattern_holds(store: K2TriplesStore, pat: TriplePattern) -> bool:
    """Ground (variable-free) pattern: does the triple exist?"""
    if not (1 <= pat.p <= store.n_preds):
        return False
    return bool(
        np.asarray(
            k2forest.check(
                store.meta, store.forest, jnp.asarray([pat.p - 1]),
                jnp.asarray([pat.s - 1]), jnp.asarray([pat.o - 1]),
            )
        )[0]
    )


def run_bgp(
    store: K2TriplesStore, patterns: list[TriplePattern], *, cap: int = 2048,
    exec_: ExecConfig | str | None = None, serve=None,
) -> dict[str, np.ndarray]:
    """Plan + execute; returns columnar variable bindings (deduplicated).

    The compiled-plan core behind ``Engine.compile(BgpQ(...))``: knobs come
    from ``exec_`` (an ``ExecConfig``; strings/None are the legacy env
    path), ``serve`` optionally routes check / bounded-scan steps through
    the engine's pooled ``serve_step`` programs, and truncation raises
    :class:`CapOverflow` for the plan's growth policy to handle.

    At least one pattern must carry a variable — for a fully ground (ASK-
    style) query the columnar return type cannot distinguish "holds" from
    "fails"; use a check-shaped ``TriplePatternQ`` / ``k2forest.check``
    instead.
    """
    # ground patterns are pure existence filters: bindings cannot represent
    # an "alive but zero-column" state, so evaluate them up front
    ground = [p for p in patterns if not p.variables]
    patterns = [p for p in patterns if p.variables]
    if not patterns:
        raise ValueError(
            "a BGP needs at least one pattern with a variable; use "
            "k2forest.check / a check-shaped TriplePatternQ for fully "
            "ground queries"
        )
    if any(not _pattern_holds(store, g) for g in ground):
        return {v: np.zeros(0, np.int64) for p in patterns for v in p.variables}
    order = plan(store, patterns)
    first = patterns[order[0]]
    # seed: resolve the first pattern stand-alone
    bindings = _resolve_with_bindings(store, first, {}, cap, exec_, serve)
    bindings = {v: a for v, a in bindings.items() if v in first.variables}
    for idx in order[1:]:
        if not bindings or len(next(iter(bindings.values()))) == 0:
            return {v: np.zeros(0, np.int64) for p in patterns for v in p.variables}
        bindings = _resolve_with_bindings(
            store, patterns[idx], bindings, cap, exec_, serve
        )
    if bindings:
        # dedup solution rows
        keys = sorted(bindings)
        stacked = np.stack([bindings[k] for k in keys], axis=1)
        uniq = np.unique(stacked, axis=0)
        bindings = {k: uniq[:, i] for i, k in enumerate(keys)}
    return bindings


def execute_bgp(
    store: K2TriplesStore, patterns: list[TriplePattern], *, cap: int = 2048,
    backend: str | None = None,
) -> dict[str, np.ndarray]:
    """DEPRECATED shim: build a ``BgpQ`` + ``ExecConfig`` and run the
    compiled-plan core under the default cap-growth policy.

    Use ``Engine.compile(BgpQ(...), ExecConfig(...))()`` — identical
    results, plus plan/program caching across calls.
    """
    warnings.warn(
        "execute_bgp is deprecated; use "
        "Engine.compile(BgpQ(patterns), ExecConfig(...))()",
        DeprecationWarning, stacklevel=2,
    )
    # the round-trip through BgpQ is the point of the shim: the patterns get
    # the Query layer's coercion/validation before execution
    q = BgpQ(tuple(TriplePatternQ(p.s, p.p, p.o) for p in patterns))
    overrides = {"cap": cap}
    if backend is not None:
        overrides["backend"] = backend
    cfg = ExecConfig.from_env(**overrides)
    pats = [TriplePattern(t.s, t.p, t.o) for t in q.patterns]
    out, _, _ = qapi.run_with_policy(
        cfg.cap_policy, cfg.cap, cfg.cap_y,
        lambda c, _: run_bgp(store, pats, cap=c, exec_=cfg),
    )
    return out
