"""BGP query optimizer — now a thin façade over the algebra/planner layer.

"a query optimizer might allow more complex conjunctive queries to be
efficiently resolved" (paper, Discussion).  This module keeps the
original conjunctive-query entry points alive; since the SPARQL-shaped
refactor the actual machinery lives one layer down:

  * ``core.algebra``   — operator tree + solution-table algebra (and the
    shared anon-variable / projection helpers);
  * ``core.planner``   — cardinality estimation, greedy + DP cost-based
    join ordering, and sideways-information-passing execution of
    conjunctive blocks over the engine's pooled serve-IR programs.

:func:`run_bgp` lowers its pattern list to a ``Join``-of-``Scan`` tree
and executes it through :func:`repro.core.planner.execute`; the
historical names (``TriplePattern``, ``estimate_cardinality``, ``plan``,
``_resolve_with_bindings``, …) re-export from their new homes so existing
imports and tests keep working unchanged.

Variables are strings starting with '?'.  Returns bindings as numpy arrays.

Entry points: the compiled-plan pipeline lowers a ``core.query.BgpQ``
through :func:`run_bgp` — execution knobs arrive as an ``ExecConfig`` and
check / bounded-scan steps resolve through the engine's pooled compiled
``serve_step`` programs (the ``serve`` callable).  The legacy
:func:`execute_bgp` survives as a deprecation shim that builds the Query
and runs the same core under the cap-growth policy.
"""

from __future__ import annotations

import warnings
from typing import Any

import numpy as np

from repro.core import algebra, planner
from repro.core.algebra import TriplePattern  # noqa: F401  (re-export)
from repro.core.k2triples import K2TriplesStore
from repro.core.planner import (  # noqa: F401  (re-exports: historical home)
    _candidate_preds,
    _pattern_holds,
    _ragged_candidates,
    _ragged_take,
    _resolve_with_bindings,
    estimate_cardinality,
)
from repro.core.query import BgpQ, ExecConfig, TriplePatternQ
from repro.core import query as qapi

Term = Any  # int (bound id) | str '?var'


def _is_var(t: Term) -> bool:
    return isinstance(t, str)


def plan(store: K2TriplesStore, patterns: list[TriplePattern]) -> list[int]:
    """Greedy selectivity-ordered plan (see ``planner.greedy_order``);
    estimate ties break by lowest pattern index, so the order is stable
    across runs.  The cost-based search is ``planner.cost_order``."""
    return planner.greedy_order(store, patterns)


def run_bgp(
    store: K2TriplesStore, patterns: list[TriplePattern], *, cap: int = 2048,
    exec_: ExecConfig | str | None = None, serve=None,
) -> dict[str, np.ndarray]:
    """Plan + execute; returns columnar variable bindings (deduplicated).

    The compiled-plan core behind ``Engine.compile(BgpQ(...))``: knobs come
    from ``exec_`` (an ``ExecConfig``; strings/None are the legacy env
    path), ``serve`` optionally routes check / bounded-scan steps through
    the engine's pooled ``serve_step`` programs, and truncation raises
    :class:`CapOverflow` for the plan's growth policy to handle.

    Since the algebra refactor this is sugar for building a
    ``Join``-of-``Scan`` tree and running ``planner.execute`` on it; the
    cost-based (DP) join order replaces the original greedy one.

    At least one pattern must carry a variable — for a fully ground (ASK-
    style) query the columnar return type cannot distinguish "holds" from
    "fails"; use a check-shaped ``TriplePatternQ`` / ``k2forest.check``
    instead.
    """
    if not any(p.variables for p in patterns):
        raise ValueError(
            "a BGP needs at least one pattern with a variable; use "
            "k2forest.check / a check-shaped TriplePatternQ for fully "
            "ground queries"
        )
    table = planner.execute(
        store, algebra.bgp(patterns), cap=cap, exec_=exec_, serve=serve
    )
    return algebra.project_named(table.cols, keep=table.cols)


def execute_bgp(
    store: K2TriplesStore, patterns: list[TriplePattern], *, cap: int = 2048,
    backend: str | None = None,
) -> dict[str, np.ndarray]:
    """DEPRECATED shim: build a ``BgpQ`` + ``ExecConfig`` and run the
    compiled-plan core under the default cap-growth policy.

    Use ``Engine.compile(BgpQ(...), ExecConfig(...))()`` — identical
    results, plus plan/program caching across calls.
    """
    warnings.warn(
        "execute_bgp is deprecated; use "
        "Engine.compile(BgpQ(patterns), ExecConfig(...))()",
        DeprecationWarning, stacklevel=2,
    )
    # the round-trip through BgpQ is the point of the shim: the patterns get
    # the Query layer's coercion/validation before execution
    q = BgpQ(tuple(TriplePatternQ(p.s, p.p, p.o) for p in patterns))
    overrides = {"cap": cap}
    if backend is not None:
        overrides["backend"] = backend
    cfg = ExecConfig.from_env(**overrides)
    pats = [TriplePattern(t.s, t.p, t.o) for t in q.patterns]
    out, _, _ = qapi.run_with_policy(
        cfg.cap_policy, cfg.cap, cfg.cap_y,
        lambda c, _: run_bgp(store, pats, cap=c, exec_=cfg),
    )
    return out
