"""BGP query optimizer — the paper's §Future-Work item, implemented.

"a query optimizer might allow more complex conjunctive queries to be
efficiently resolved" (paper, Discussion).  This module plans and executes
basic graph patterns (conjunctions of ≥2 triple patterns with shared
variables) on top of the pattern/join primitives:

  * **cardinality estimation** straight from k²-triples statistics — nnz per
    predicate tree and the dictionary extents (no extra index needed; the
    vertical partitioning IS the statistics);
  * **greedy join ordering**: start from the most selective pattern, then
    repeatedly pick the connected pattern with the lowest estimated result;
  * **binding propagation**: intermediate solutions are ID sets; each next
    pattern is resolved per-binding through the BATCHED engine primitives
    (``scan_batch_mixed``), so an n-pattern query costs one compiled program
    launch per plan step, not per binding.

Variables are strings starting with '?'.  Returns bindings as numpy arrays.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import k2forest
from repro.core.k2triples import K2TriplesStore

Term = Any  # int (bound id) | str '?var'


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: Term
    p: Term
    o: Term

    @property
    def variables(self) -> set[str]:
        return {t for t in (self.s, self.p, self.o) if isinstance(t, str)}


def _is_var(t: Term) -> bool:
    return isinstance(t, str)


def estimate_cardinality(store: K2TriplesStore, pat: TriplePattern) -> float:
    """Expected result size from per-predicate nnz + dictionary extents."""
    nnz = np.asarray(store.forest.nnz, np.float64)
    n_s = max(store.n_subjects, 1)
    n_o = max(store.n_objects, 1)
    if _is_var(pat.p):
        total = float(nnz.sum())
    else:
        total = float(nnz[pat.p - 1]) if 1 <= pat.p <= store.n_preds else 0.0
    sel = 1.0
    if not _is_var(pat.s):
        sel /= n_s
    if not _is_var(pat.o):
        sel /= n_o
    return max(total * sel, 1e-3)


def plan(store: K2TriplesStore, patterns: list[TriplePattern]) -> list[int]:
    """Greedy selectivity-ordered, connectivity-respecting plan."""
    n = len(patterns)
    cards = [estimate_cardinality(store, p) for p in patterns]
    order = [int(np.argmin(cards))]
    bound_vars = set(patterns[order[0]].variables)
    while len(order) < n:
        best, best_card = None, float("inf")
        for i in range(n):
            if i in order:
                continue
            connected = bool(patterns[i].variables & bound_vars)
            # already-bound variables shrink the estimate sharply
            card = cards[i] / (10.0 if connected else 1.0)
            if not connected:
                card *= 1e6  # cartesian products last
            if card < best_card:
                best, best_card = i, card
        order.append(best)
        bound_vars |= patterns[best].variables
    return order


def _resolve_with_bindings(store, pat, bindings: dict[str, np.ndarray], cap: int):
    """Resolve one pattern given current bindings -> list of solution dicts
    realized as columnar arrays.  Chooses the cheapest realization:
    check / row scan / col scan batched over existing binding rows."""
    meta, f = store.meta, store.forest
    n_rows = len(next(iter(bindings.values()))) if bindings else 1
    svar, pvar, ovar = _is_var(pat.s), _is_var(pat.p), _is_var(pat.o)

    def col(term, default):
        if _is_var(term) and term in bindings:
            return bindings[term].astype(np.int64), True
        if not _is_var(term):
            return np.full(n_rows, term, np.int64), True
        return np.full(n_rows, default, np.int64), False

    preds = (
        range(1, store.n_preds + 1)
        if (pvar and pat.p not in bindings)
        else [None]
    )
    out_cols: dict[str, list] = {v: [] for v in set(bindings) | pat.variables}
    for pid in preds:
        if pid is None:
            p_arr, _ = col(pat.p, 1)
        else:
            p_arr = np.full(n_rows, pid, np.int64)
        s_arr, s_bound = col(pat.s, 1)
        o_arr, o_bound = col(pat.o, 1)

        if s_bound and o_bound:  # existence check per row
            hit = np.asarray(
                k2forest.check(
                    meta, f, jnp.asarray(p_arr - 1), jnp.asarray(s_arr - 1),
                    jnp.asarray(o_arr - 1),
                )
            )
            keep = np.nonzero(hit)[0]
            for v in bindings:
                out_cols[v].append(bindings[v][keep])
            if pvar and pat.p not in bindings:
                out_cols[pat.p].append(np.full(len(keep), pid, np.int64))
            for var, arr in ((pat.s, s_arr), (pat.o, o_arr)):
                if _is_var(var) and var not in bindings:
                    out_cols[var].append(arr[keep])
        else:  # one free position -> batched scan
            axis = 0 if s_bound else 1
            key = s_arr if s_bound else o_arr
            r = k2forest.scan_batch_mixed(
                meta, f, jnp.asarray(np.repeat(p_arr - 1, 1)),
                jnp.asarray(key - 1), jnp.full(n_rows, axis, jnp.int32), cap,
            )
            ids = np.asarray(r.ids) + 1
            valid = np.asarray(r.valid)
            rows, cols_ = np.nonzero(valid)
            vals = ids[rows, cols_]
            for v in bindings:
                out_cols[v].append(bindings[v][rows])
            if pvar and pat.p not in bindings:
                out_cols[pat.p].append(np.full(len(rows), pid, np.int64))
            free_var = pat.o if s_bound else pat.s
            if _is_var(free_var):
                out_cols[free_var].append(vals)
            bound_var = pat.s if s_bound else pat.o
            if _is_var(bound_var) and bound_var not in bindings:
                out_cols[bound_var].append((s_arr if s_bound else o_arr)[rows])

    return {
        v: (np.concatenate(cs) if cs else np.zeros(0, np.int64))
        for v, cs in out_cols.items()
    }


def execute_bgp(
    store: K2TriplesStore, patterns: list[TriplePattern], *, cap: int = 2048
) -> dict[str, np.ndarray]:
    """Plan + execute; returns columnar variable bindings (deduplicated)."""
    order = plan(store, patterns)
    first = patterns[order[0]]
    # seed: resolve the first pattern stand-alone
    bindings = _resolve_with_bindings(store, first, {}, cap)
    bindings = {v: a for v, a in bindings.items() if v in first.variables}
    for idx in order[1:]:
        if not bindings or len(next(iter(bindings.values()))) == 0:
            return {v: np.zeros(0, np.int64) for p in patterns for v in p.variables}
        bindings = _resolve_with_bindings(store, patterns[idx], bindings, cap)
    if bindings:
        # dedup solution rows
        keys = sorted(bindings)
        stacked = np.stack([bindings[k] for k in keys], axis=1)
        uniq = np.unique(stacked, axis=0)
        bindings = {k: uniq[:, i] for i, k in enumerate(keys)}
    return bindings
