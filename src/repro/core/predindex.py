"""Compressed SP/OP predicate indexes — the k²-triples+ subsystem.

The paper concedes that vertical partitioning's worst case is the
unbounded-predicate pattern: resolving ``(S,?P,?O)`` / ``(?S,?P,O)`` /
``(S,?P,O)`` means touching **all** |P| trees.  The follow-up work
*Compressed Vertical Partitioning for Full-In-Memory RDF Management*
(arXiv:1310.4954) fixes this with two compact indexes:

  * **SP** — for every subject s, the sorted list of predicates p such that
    some triple (s, p, ·) exists;
  * **OP** — for every object o, the sorted list of predicates p such that
    some triple (·, p, o) exists.

An unbounded-``?P`` query then scans only the candidate predicates named by
the index instead of sweeping the whole forest — predicate pruning, which
arXiv:2002.11622 confirms as the decisive optimization for this layout.

Layout (device, jit-able): both indexes share ONE arena so a mixed batch of
subject- and object-keyed queries needs a single gather program (row r of
subject s is ``s-1``, row of object o is ``|S| + o - 1``, 1-based ids).
Two on-device layouts exist, selected by ``PredIndexMeta.layout``:

  * ``layout="dac"`` (default) — the real multi-level **DAC(b=8)** of the
    paper: each list is gap-encoded (first entry +1, then deltas, all >= 1)
    and split into 8-bit chunks; level k holds the k-th chunk of every gap
    still alive at that level, in stable order, as one byte stream.  A
    rank-enabled flag bitmap per non-final level says "this element
    continues", and the in-level rank of a set flag is the element's
    position in the next level's stream.  The row-pointer side is also
    compressed: one int32 anchor per ``rows_per_block`` rows plus
    ``deg_width``-bit packed per-row degrees, so ``offsets[r]`` is an
    anchor plus a short masked SWAR sum.  The gather kernel decodes chunks,
    ranks flags, and prefix-sums the gaps back to predicate ids on device.
  * ``layout="fixed"`` — the byte-packed fallback: ``words`` holds the
    concatenated lists at ``bytes_per_pred`` ∈ {1, 2, 4} bytes per entry
    (the fixed-width special case of byte-aligned DACs — direct access is
    shift+mask) under plain int32 CSR ``offsets``.  Kept for differential
    testing and as an escape hatch (``ExecConfig.pred_index_layout``).

Size accounting is honest on two axes (``PredIndexStats``): the bits each
device arena *actually* costs (payload + row pointers, measured from the
materialized arrays), and the analytic multi-level DAC(b=8) size of the
gap-encoded lists — the number a 1310.4954-style host implementation would
report.  Since the DAC layout is real, measured ``payload_bits`` +
``offsets_bits`` now lands within word-padding distance of ``dac_bits``
(CI gates the ratio at 1.25×; ``benchmarks/check_compression.py``).

The batched query ops at the bottom (``gather_batch``, ``scan_pruned_batch``,
``check_pruned_batch``) are the substrate of the engine's unbounded serve
lanes and the optimizer's bound-``?P`` resolves.  ``gather_batch`` routes
through the ``kernels/pred_gather`` Pallas kernels or their jnp mirrors
exactly like ``k2forest.scan_batch_mixed`` routes (``REPRO_SCAN_BACKEND`` /
per-call ``backend=``); the decode layout follows ``pmeta.layout``, which
the engine selects per ``ExecConfig.pred_index_layout``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import k2forest
from repro.core.bitvec import popcount_np
from repro.core.k2forest import K2Forest
from repro.core.k2tree import K2Meta, QueryResult, _compact

DAC_CHUNK_BITS = 8


class PredIndex(NamedTuple):
    """Device arrays (a pytree; shards replicated next to the forest).

    Union of both layouts — unused fields are size-(1) placeholders so the
    pytree structure (and the shard_map in_specs built from it) is layout
    independent.

      * fixed: ``offsets`` int32[R+1] CSR row pointers, ``words`` the
        byte-packed predicate ids; ``degs``/``flags``/``frank`` unused.
      * dac:   ``offsets`` int32[n_blocks] block anchors, ``degs``
        uint32[n_blocks*4] packed per-row degrees, ``words`` the
        concatenated per-level chunk byte streams, ``flags`` the per-level
        continuation bitmaps (word aligned per level), ``frank``
        int32 exclusive in-level popcount per flag word.
    """

    offsets: jax.Array  # int32 — CSR row pointers (fixed) | block anchors (dac)
    words: jax.Array  # uint32 — packed predicate ids (fixed) | DAC chunk bytes
    degs: jax.Array  # uint32 — deg_width-bit packed per-row degrees (dac)
    flags: jax.Array  # uint32 — continuation bitmaps, levels 0..L-2 (dac)
    frank: jax.Array  # int32 — exclusive in-level rank per flag word (dac)


@dataclasses.dataclass(frozen=True)
class PredIndexMeta:
    """Static (hashable) geometry — travels like ``K2Meta``."""

    n_subjects: int
    n_objects: int
    n_preds: int
    bytes_per_pred: int  # 1, 2 or 4 (word-aligned: an entry never straddles)
    max_degree: int  # max list length over all subjects and objects
    # per-axis maxima: a hub object (e.g. a class object touching ~all P
    # predicates) inflates max_degree and with it any u_width sized from
    # it; callers serving subject-keyed batches can size from the SP side
    # alone (and rely on the `truncated` overflow bit otherwise)
    max_sp_degree: int = 0
    max_op_degree: int = 0
    # --- DAC layout geometry (static; meaningful when layout == "dac") ---
    layout: str = "fixed"  # "fixed" | "dac"
    levels: int = 1  # number of DAC chunk levels L
    level_byte_start: tuple = (0,)  # len L: start byte of each level stream
    flag_word_start: tuple = ()  # len L-1: word start of each level's bitmap
    deg_width: int = 32  # bits per packed degree (4 | 8 | 16 | 32)
    rows_per_block: int = 1  # rows sharing one anchor (4 words of degrees)


class PredIndexStats(NamedTuple):
    """Honest size accounting (the 1310.4954 Table analogue).

    ``payload_bits``/``offsets_bits`` are MEASURED from the default (DAC)
    device arrays — what the serving index actually costs resident —
    while ``dac_bits`` stays the analytic chunks+flags figure for the
    gap streams alone (no row pointers), so the measured-vs-analytic gap
    is visible.  The fixed-width fallback's cost is reported alongside.
    """

    sp_entries: int  # Σ_s |SP(s)|  (== #distinct (s,p) pairs)
    op_entries: int  # Σ_o |OP(o)|
    payload_bits: int  # measured: chunk streams + flag bitmaps + flag ranks
    offsets_bits: int  # measured: block anchors + packed per-row degrees
    dac_bits: int  # analytic DAC(b=8) of the gap-encoded lists
    bits_per_triple: float  # (payload + offsets) / n_triples, DAC layout
    fixed_payload_bits: int = 0  # byte-packed payload of the fixed fallback
    fixed_offsets_bits: int = 0  # its int32 CSR row pointers
    fixed_bits_per_triple: float = 0.0


@dataclasses.dataclass(frozen=True)
class BuiltPredIndex:
    """Everything ``K2TriplesStore`` carries: device + static + host views.

    ``device``/``meta`` are the default DAC layout; the fixed-width
    fallback rides along as ``device_fixed``/``meta_fixed`` (differential
    tests, ``ExecConfig.pred_index_layout="fixed"``).  ``select`` picks a
    layout pair by name.
    """

    device: PredIndex
    meta: PredIndexMeta
    stats: PredIndexStats
    host_offsets: np.ndarray  # int64[R + 1]
    host_preds: np.ndarray  # int32[total] 0-based, sorted within each row
    device_fixed: PredIndex | None = None
    meta_fixed: PredIndexMeta | None = None

    def select(self, layout: str | None = None):
        """(device, meta) for ``layout`` ("dac" | "fixed" | None=default)."""
        if (
            layout is not None
            and layout != self.meta.layout
            and self.device_fixed is not None
            and layout == self.meta_fixed.layout
        ):
            return self.device_fixed, self.meta_fixed
        return self.device, self.meta

    def host_list(self, row: int) -> np.ndarray:
        """0-based predicate list of one entity row (subjects then objects)."""
        return self.host_preds[self.host_offsets[row] : self.host_offsets[row + 1]]


def subject_row(s):
    """Entity row of 1-based subject id ``s`` (plain arithmetic, jit-safe)."""
    return s - 1


def object_row(pmeta: PredIndexMeta, o):
    """Entity row of 1-based object id ``o``."""
    return pmeta.n_subjects + o - 1


# ---------------------------------------------------------------------------
# construction (numpy, host)
# ---------------------------------------------------------------------------


def _dac_bits(values: np.ndarray, chunk: int = 8) -> int:
    """Analytic multi-level DAC size: ``chunk``-bit chunks + 1 flag bit each."""
    if values.size == 0:
        return 0
    v = values.astype(np.int64)
    nbits = np.maximum(1, np.floor(np.log2(np.maximum(v, 1))) + 1)
    nchunks = np.ceil(nbits / chunk)
    return int(nchunks.sum() * (chunk + 1))


def _encode_dac(gaps: np.ndarray):
    """Encode positive gaps into the multi-level DAC(b=8) arrays.

    Returns ``(words, levels, level_byte_start, flag_word_start, flags,
    frank)``: ``words`` uint32 holds the concatenated per-level byte
    streams (level boundaries are the static ``level_byte_start`` tuple);
    ``flags``/``frank`` hold the per-level continuation bitmaps and their
    exclusive in-level word ranks (word starts in ``flag_word_start``).
    """
    g = np.asarray(gaps, np.int64)
    if g.size == 0:
        return (
            np.zeros(1, np.uint32), 1, (0,), (),
            np.zeros(1, np.uint32), np.zeros(1, np.int32),
        )
    nbits = np.maximum(1, np.floor(np.log2(np.maximum(g, 1))).astype(np.int64) + 1)
    nchunks = (nbits + DAC_CHUNK_BITS - 1) // DAC_CHUNK_BITS
    levels = int(nchunks.max())

    streams, flag_words, frank_words, level_byte_start, flag_word_start = (
        [], [], [], [], []
    )
    byte_pos = 0
    flag_pos = 0
    cur, cur_nchunks = g, nchunks
    for lvl in range(levels):
        level_byte_start.append(byte_pos)
        stream = (cur & 0xFF).astype(np.uint8)
        streams.append(stream)
        byte_pos += int(stream.size)
        cont = cur_nchunks > (lvl + 1)
        if lvl < levels - 1:
            n_words = max((int(stream.size) + 31) // 32, 1)
            fw = np.zeros(n_words, np.int64)
            idx = np.nonzero(cont)[0]
            np.bitwise_or.at(fw, idx >> 5, np.int64(1) << (idx & 31))
            fw = fw.astype(np.uint32)
            fr = np.zeros(n_words, np.int64)
            np.cumsum(popcount_np(fw)[:-1], out=fr[1:])
            flag_word_start.append(flag_pos)
            flag_pos += n_words
            flag_words.append(fw)
            frank_words.append(fr.astype(np.int32))
        cur = cur[cont] >> DAC_CHUNK_BITS
        cur_nchunks = cur_nchunks[cont]

    chunk_bytes = np.concatenate(streams)
    padded = np.zeros((chunk_bytes.size + 3) // 4 * 4, np.uint8)
    padded[: chunk_bytes.size] = chunk_bytes
    words = padded.view("<u4").copy()
    if flag_words:
        flags = np.concatenate(flag_words)
        frank = np.concatenate(frank_words)
    else:
        flags = np.zeros(1, np.uint32)
        frank = np.zeros(1, np.int32)
    return (
        words, levels, tuple(level_byte_start), tuple(flag_word_start),
        flags, frank,
    )


def _pack_degrees(counts: np.ndarray, offsets: np.ndarray, max_degree: int):
    """Pack per-row degrees at the narrowest SWAR width + block anchors.

    Returns ``(anchors, degs, deg_width, rows_per_block)``.  A block is
    sized so its packed degrees span exactly 4 uint32 words, which bounds
    the kernel's offset-reconstruction unroll.
    """
    deg_width = next(w for w in (4, 8, 16, 32) if max_degree < (1 << w))
    per_word = 32 // deg_width
    rows_per_block = 4 * per_word
    n_rows = int(counts.size)
    n_blocks = max((n_rows + rows_per_block - 1) // rows_per_block, 1)
    padded = np.zeros(n_blocks * rows_per_block, np.uint64)
    padded[:n_rows] = counts.astype(np.uint64)
    lanes = padded.reshape(n_blocks * 4, per_word)
    shifts = np.arange(per_word, dtype=np.uint64) * deg_width
    degs = np.bitwise_or.reduce(lanes << shifts[None, :], axis=1).astype(np.uint32)
    anchors = offsets[: n_blocks * rows_per_block : rows_per_block].astype(np.int32)
    if anchors.size < n_blocks:  # counts.size == 0 degenerate
        anchors = np.zeros(n_blocks, np.int32)
    return anchors, degs, deg_width, rows_per_block


def build(
    ids: np.ndarray, *, n_subjects: int, n_objects: int, n_preds: int,
    n_triples: int | None = None,
) -> BuiltPredIndex:
    """Build SP+OP from int64[N,3] 1-based (s, p, o) ID triples."""
    ids = np.asarray(ids, dtype=np.int64).reshape(-1, 3)
    n_triples = int(ids.shape[0]) if n_triples is None else n_triples
    sp = np.unique(ids[:, [0, 1]], axis=0)  # sorted (s, p): lists come sorted
    op = np.unique(ids[:, [2, 1]], axis=0)

    R = n_subjects + n_objects
    counts = np.zeros(R, np.int64)
    np.add.at(counts, sp[:, 0] - 1, 1)
    np.add.at(counts, n_subjects + op[:, 0] - 1, 1)
    offsets = np.zeros(R + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])

    preds = np.zeros(max(int(offsets[-1]), 1), np.int32)
    # np.unique's lexsort already groups rows by entity with ascending preds,
    # so the payload is one concatenation per index half
    preds[: sp.shape[0]] = sp[:, 1] - 1
    op_base = int(offsets[n_subjects])
    preds[op_base : op_base + op.shape[0]] = op[:, 1] - 1

    bpp = 1 if n_preds <= 0xFF else (2 if n_preds <= 0xFFFF else 4)
    per_word = 4 // bpp
    n_entries = int(offsets[-1])
    padded = np.zeros(((max(n_entries, 1) + per_word - 1) // per_word) * per_word,
                      np.uint32)
    padded[:n_entries] = preds[:n_entries].astype(np.uint32)
    lanes = padded.reshape(-1, per_word)
    shifts = (np.arange(per_word, dtype=np.uint64) * 8 * bpp)
    words_fixed = np.bitwise_or.reduce(
        (lanes.astype(np.uint64) << shifts[None, :]), axis=1
    ).astype(np.uint32)

    max_degree = int(counts.max()) if R else 0
    max_sp = int(counts[:n_subjects].max()) if n_subjects else 0
    max_op = int(counts[n_subjects:].max()) if n_objects else 0
    # gap-encode each list: first entry +1, then deltas (all gaps >= 1)
    gaps = preds[:n_entries].astype(np.int64) + 1
    if n_entries:
        starts = offsets[:-1][counts > 0]
        inner = np.ones(n_entries, np.bool_)
        inner[starts] = False
        gaps[inner] = np.diff(preds[:n_entries].astype(np.int64))[inner[1:]]

    dac_words, levels, lbs, fws, flags, frank = _encode_dac(gaps)
    anchors, degs, deg_width, rows_per_block = _pack_degrees(
        counts, offsets, max_degree
    )

    payload_bits = int((dac_words.size + flags.size) * 32 + frank.size * 32)
    offsets_bits = int((anchors.size + degs.size) * 32)
    fixed_payload = int(words_fixed.size * 32)
    fixed_offsets = int((R + 1) * 32)
    stats = PredIndexStats(
        sp_entries=int(sp.shape[0]),
        op_entries=int(op.shape[0]),
        payload_bits=payload_bits,
        offsets_bits=offsets_bits,
        dac_bits=_dac_bits(gaps),
        bits_per_triple=float(payload_bits + offsets_bits) / max(n_triples, 1),
        fixed_payload_bits=fixed_payload,
        fixed_offsets_bits=fixed_offsets,
        fixed_bits_per_triple=float(fixed_payload + fixed_offsets)
        / max(n_triples, 1),
    )
    placeholder_u = jnp.zeros(1, jnp.uint32)
    placeholder_i = jnp.zeros(1, jnp.int32)
    common = dict(
        n_subjects=n_subjects, n_objects=n_objects, n_preds=n_preds,
        bytes_per_pred=bpp, max_degree=max_degree,
        max_sp_degree=max_sp, max_op_degree=max_op,
    )
    return BuiltPredIndex(
        device=PredIndex(
            offsets=jnp.asarray(anchors, jnp.int32),
            words=jnp.asarray(dac_words),
            degs=jnp.asarray(degs),
            flags=jnp.asarray(flags),
            frank=jnp.asarray(frank),
        ),
        meta=PredIndexMeta(
            layout="dac", levels=levels, level_byte_start=lbs,
            flag_word_start=fws, deg_width=deg_width,
            rows_per_block=rows_per_block, **common,
        ),
        stats=stats,
        host_offsets=offsets,
        host_preds=preds[:n_entries],
        device_fixed=PredIndex(
            offsets=jnp.asarray(offsets, jnp.int32),
            words=jnp.asarray(words_fixed),
            degs=placeholder_u,
            flags=placeholder_u,
            frank=placeholder_i,
        ),
        meta_fixed=PredIndexMeta(layout="fixed", **common),
    )


def quantile_u_width(bi: BuiltPredIndex, quantile: float) -> int:
    """Candidate-lane width at a degree quantile, sized PER AXIS.

    ``max_degree`` is dominated by hub entities (a class object touching
    ~all |P| predicates widens every unbounded lane back toward the sweep);
    sizing at a quantile of the nonzero per-entity degree distribution —
    separately for the SP (subject) and OP (object) halves, then unified
    with ``max`` so either axis of a mixed batch is covered at its own
    quantile — keeps the lane narrow.  Entities whose list exceeds the
    returned width trip the gather's ``truncated`` bit and must be routed
    to the all-preds sweep fallback (``degree_rows``/``host_degrees`` give
    the host-side pre-route; the plan layer does this automatically).

    ``quantile=1.0`` reproduces ``max(max_sp_degree, max_op_degree, 1)``
    exactly.
    """
    if not (0.0 < quantile <= 1.0):
        raise ValueError(f"quantile must be in (0, 1], got {quantile}")
    offs = bi.host_offsets
    ns = bi.meta.n_subjects
    widths = []
    for deg in (np.diff(offs[: ns + 1]), np.diff(offs[ns:])):
        deg = deg[deg > 0]
        if deg.size:
            widths.append(int(np.ceil(np.quantile(deg, quantile))))
    return max(widths, default=1) if widths else 1


def host_degrees(bi: BuiltPredIndex, rows: np.ndarray) -> np.ndarray:
    """Per-entity candidate-list lengths from the host CSR (O(1) per row).

    ``rows`` are 0-based entity rows (subjects then objects, the shared
    arena layout); out-of-range rows report degree 0.  This is the exact
    host-side mirror of the device gather's ``truncated`` criterion
    (``degree > u_width``), used to pre-route outliers to the sweep.
    """
    offs = bi.host_offsets
    rows = np.asarray(rows, np.int64)
    ok = (rows >= 0) & (rows < offs.shape[0] - 1)
    r = np.where(ok, rows, 0)
    return np.where(ok, offs[r + 1] - offs[r], 0)


# ---------------------------------------------------------------------------
# device queries
# ---------------------------------------------------------------------------


def payload_at(words: jax.Array, elem: jax.Array, bytes_per_pred: int) -> jax.Array:
    """Direct access: the ``elem``-th packed entry -> 0-based predicate id."""
    bidx = elem * bytes_per_pred
    word = words[jnp.clip(bidx >> 2, 0, words.shape[0] - 1)]
    shift = ((bidx & 3) * 8).astype(jnp.uint32)
    mask = jnp.uint32((1 << (8 * bytes_per_pred)) - 1 if bytes_per_pred < 4
                      else 0xFFFFFFFF)
    return ((word >> shift) & mask).astype(jnp.int32)


def _gather_traced(
    pmeta: PredIndexMeta, index: PredIndex, rows: jax.Array, cap: int
) -> QueryResult:
    """jnp reference gather: rows int32[B] (0-based entity rows) -> the
    ``QueryResult`` contract over 0-based predicate ids (prefix-valid,
    dead lanes zeroed, overflow = list longer than ``cap``).

    The math is ``ref.pred_gather_ref`` / ``ref.pred_gather_dac_ref``
    (per ``pmeta.layout``) — one jnp source of truth; the Pallas kernels
    are the independent implementations checked against it.
    """
    from repro.kernels import ref  # deferred: core must import without pallas

    rows = jnp.clip(jnp.asarray(rows, jnp.int32), 0,
                    max(pmeta.n_subjects + pmeta.n_objects - 1, 0))
    if pmeta.layout == "dac":
        ids, valid, count, overflow = ref.pred_gather_dac_ref(
            rows, index.offsets, index.words, index.degs, index.flags,
            index.frank, levels=pmeta.levels,
            level_byte_start=pmeta.level_byte_start,
            flag_word_start=pmeta.flag_word_start,
            deg_width=pmeta.deg_width, rows_per_block=pmeta.rows_per_block,
            cap=cap,
        )
    else:
        ids, valid, count, overflow = ref.pred_gather_ref(
            rows, index.offsets, index.words,
            bytes_per_pred=pmeta.bytes_per_pred, cap=cap,
        )
    return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow)


def gather_batch(
    pmeta: PredIndexMeta, index: PredIndex, rows, cap: int,
    backend: str | None = None,
) -> QueryResult:
    """Batched candidate-predicate gather (the ragged-gather launch layout).

    ``backend`` resolves exactly like ``k2forest.scan_batch_mixed``
    (ExecConfig / string / None): "pallas" runs the ``kernels.pred_gather``
    kernel, "jnp" the reference above; the decode follows ``pmeta.layout``.
    Bit-identical outputs across backends AND layouts
    (tests/test_pred_gather.py, tests/test_predindex.py).
    """
    from repro.kernels import ops  # deferred: core must import without pallas

    rows = jnp.asarray(rows, jnp.int32)
    be, interp = ops.resolve_exec(backend)
    if be == "pallas":
        ids, valid, count, overflow = ops.pred_gather_index(
            pmeta, index, rows, cap=cap, interpret=interp
        )
        return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow)
    return _gather_traced(pmeta, index, rows, cap)


class PredScanResult(NamedTuple):
    """Pruned unbounded scan: per-candidate-predicate result lists.

    All ids 0-based (the ``k2forest`` convention; the patterns layer shifts).
    ``u_width`` candidate slots per query; ``pvalid`` marks live candidates.
    """

    preds: jax.Array  # int32[..., L] candidate predicate ids (0 where dead)
    pvalid: jax.Array  # bool[..., L]
    ids: jax.Array  # int32[..., L, cap]
    valid: jax.Array  # bool[..., L, cap]
    count: jax.Array  # int32[..., L]
    overflow: jax.Array  # bool[..., L] per-candidate scan overflow
    truncated: jax.Array  # bool[...] candidate list exceeded L (never when
    #   L >= pmeta.max_degree)


def scan_pruned_batch(
    meta: K2Meta, f: K2Forest, pmeta: PredIndexMeta, index: PredIndex,
    keys, axes, cap: int, u_width: int, backend: str | None = None,
) -> PredScanResult:
    """(S,?P,?O) / (?S,?P,O) batch via the index: scan candidates only.

    ``keys`` int32[B] 0-based subject (axes==0) or object (axes==1) ids;
    one flat ``scan_batch_mixed`` launch of B·u_width lanes replaces the
    B·P broadcast sweep.
    """
    keys = jnp.asarray(keys, jnp.int32)
    axes = jnp.asarray(axes, jnp.int32)
    b = keys.shape[0]
    rows = jnp.where(axes == 1, pmeta.n_subjects + keys, keys)
    g = gather_batch(pmeta, index, rows, u_width, backend)
    preds_f = jnp.where(g.valid, g.ids, 0).reshape(b * u_width)
    keys_f = jnp.repeat(keys, u_width)
    axes_f = jnp.repeat(axes, u_width)
    r = k2forest.scan_batch_mixed(meta, f, preds_f, keys_f, axes_f, cap, backend)
    valid = r.valid.reshape(b, u_width, cap) & g.valid[:, :, None]
    return PredScanResult(
        preds=jnp.where(g.valid, g.ids, 0),
        pvalid=g.valid,
        ids=jnp.where(valid, r.ids.reshape(b, u_width, cap), 0),
        valid=valid,
        count=jnp.where(g.valid, r.count.reshape(b, u_width), 0),
        overflow=r.overflow.reshape(b, u_width) & g.valid,
        truncated=g.overflow,
    )


def check_pruned_batch(
    meta: K2Meta, f: K2Forest, pmeta: PredIndexMeta, index: PredIndex,
    rows, cols, u_width: int, backend: str | None = None,
) -> QueryResult:
    """(S,?P,O) batch via the SP index: check candidates only.

    ``rows``/``cols`` int32[B] 0-based subject/object ids.  Returns the
    matching predicate ids (0-based, ascending, compacted to the front of
    ``u_width`` slots); ``overflow`` latches only if the candidate list
    itself was truncated.
    """
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    b = rows.shape[0]
    g = gather_batch(pmeta, index, rows, u_width, backend)
    preds_f = jnp.where(g.valid, g.ids, 0).reshape(b * u_width)
    hit = k2forest.check(
        meta, f, preds_f, jnp.repeat(rows, u_width), jnp.repeat(cols, u_width)
    ).reshape(b, u_width) & g.valid
    valid, count, _, (ids,) = jax.vmap(
        lambda v, a: _compact(v, u_width, a)
    )(hit, jnp.where(hit, g.ids, 0))
    return QueryResult(ids=ids, valid=valid, count=count, overflow=g.overflow)


class PredBitmap:
    """Tiny host-side entity -> predicate-set bitmap for the delta lane.

    The SP/OP candidate-predicate index above is static (built once with the
    forest) and is consulted only for the STATIC side of a dynamic store.
    Recent inserts are covered by this structure instead: one arbitrary-width
    Python-int bitmask per touched entity (1-based predicate p sets bit p-1),
    so the delta lane's unbounded-?P merges cost a dict lookup plus a
    popcount-sized decode — no device rebuild per write.
    """

    __slots__ = ("_bits",)

    def __init__(self) -> None:
        self._bits: dict[int, int] = {}

    def add(self, entity: int, pred: int) -> None:
        self._bits[entity] = self._bits.get(entity, 0) | (1 << (pred - 1))

    def preds_of(self, entity: int) -> np.ndarray:
        """Sorted 1-based predicate ids recorded for ``entity``."""
        w = self._bits.get(entity, 0)
        if not w:
            return np.empty(0, dtype=np.int64)
        out = []
        p = 1
        while w:
            if w & 1:
                out.append(p)
            w >>= 1
            p += 1
        return np.asarray(out, dtype=np.int64)

    def __contains__(self, entity: int) -> bool:
        return entity in self._bits

    def __len__(self) -> int:
        return len(self._bits)

    def entities(self):
        return self._bits.keys()
