"""Join categories A–F over triple patterns (paper §k²-triples, Fig. 4).

A join query = two triple patterns sharing one variable ?X which sits in the
subject or object position of each pattern (SS / OO / SO joins).  The six
categories follow the paper:

  A — both predicates bounded, non-join positions bound     -> list ∩ list
  B — one unbounded predicate                               -> list ∩ each of P lists
  C — both predicates unbounded                             -> union ∩ union
  D — bounded predicates, one non-join position unbounded   -> resolve + re-bind
  E — D with one unbounded predicate                        -> D per predicate
  F — D with two unbounded predicates                       -> E per predicate

Every function is jit-able: inputs are scalar IDs (1-based), outputs are
fixed-capacity IdSet / JoinPairs with validity masks.  ``vpos`` ∈ {"s","o"}
names which position of a pattern holds the join variable; the SS/OO/SO kind
is implied by (vpos1, vpos2).  Cross (SO) joins rely on the dictionary's
shared [1,|SO|] range — IDs are directly comparable.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import k2forest, sortedset
from repro.core.k2forest import K2Forest
from repro.core.k2tree import K2Meta
from repro.core.sortedset import IdSet, SENTINEL


class JoinPairs(NamedTuple):
    """(X, Y) bindings: Y lists hang off each X lane (and optionally preds)."""

    x_ids: jax.Array  # int32[..., capx]
    x_valid: jax.Array  # bool[..., capx]
    y_ids: jax.Array  # int32[..., capx, capy]
    y_valid: jax.Array  # bool[..., capx, capy]
    overflow: jax.Array  # bool[]


# ---------------------------------------------------------------------------
# pattern side-lists (the paper's direct / reverse neighbors)
# ---------------------------------------------------------------------------


def _side_list(meta, f, p, const, vpos: str, cap: int) -> IdSet:
    """Sorted candidate values of the join variable for one pattern.

    (?X, P, O): reverse neighbors (column scan).  (S, P, ?X): direct (row).
    IDs returned 1-based.
    """
    p = jnp.asarray(p, jnp.int32) - 1
    c = jnp.asarray(const, jnp.int32) - 1
    if vpos == "s":
        r = k2forest.col_scan(meta, f, p, c, cap)
    else:
        r = k2forest.row_scan(meta, f, p, c, cap)
    return sortedset.from_result(
        jnp.where(r.valid, r.ids + 1, SENTINEL), r.valid, r.count, r.overflow
    )


def _side_list_all_preds(meta, f, const, vpos: str, cap: int):
    """-> (ids[P,cap], valid[P,cap], overflow) sorted within each predicate."""
    c = jnp.asarray(const, jnp.int32) - 1
    if vpos == "s":
        r = k2forest.col_scan_all_preds(meta, f, c, cap)
    else:
        r = k2forest.row_scan_all_preds(meta, f, c, cap)
    ids = jnp.where(r.valid, r.ids + 1, SENTINEL)
    return ids, r.valid, r.overflow.any()


# ---------------------------------------------------------------------------
# categories A–C: both non-join positions bound
# ---------------------------------------------------------------------------


def join_a(meta, f, p1, c1, vpos1: str, p2, c2, vpos2: str, cap: int) -> IdSet:
    """(?X,P1,O1)(?X,P2,O2)-style: two bounded patterns, intersect."""
    a = _side_list(meta, f, p1, c1, vpos1, cap)
    b = _side_list(meta, f, p2, c2, vpos2, cap)
    return sortedset.intersect(a, b)


class PerPredSets(NamedTuple):
    ids: jax.Array  # int32[P, cap]
    valid: jax.Array  # bool[P, cap]
    preds: jax.Array  # int32[P] 1-based predicate ids
    overflow: jax.Array


def join_b(meta, f, p1, c1, vpos1: str, c2, vpos2: str, cap: int) -> PerPredSets:
    """Pattern 2 has unbounded predicate: bounded side first, then ∩ per pred."""
    a = _side_list(meta, f, p1, c1, vpos1, cap)
    ids2, valid2, ovf2 = _side_list_all_preds(meta, f, c2, vpos2, cap)

    def one(ids_p, valid_p):
        b = IdSet(ids_p, valid_p, valid_p.sum().astype(jnp.int32), jnp.asarray(False))
        r = sortedset.intersect(a, b)
        return r.ids, r.valid

    ids, valid = jax.vmap(one)(ids2, valid2)
    P = f.n_preds
    return PerPredSets(
        ids, valid, jnp.arange(1, P + 1, dtype=jnp.int32), a.overflow | ovf2
    )


def join_c(meta, f, c1, vpos1: str, c2, vpos2: str, cap: int) -> IdSet:
    """Both predicates unbounded: union per side, intersect the unions."""
    ids1, valid1, ovf1 = _side_list_all_preds(meta, f, c1, vpos1, cap)
    ids2, valid2, ovf2 = _side_list_all_preds(meta, f, c2, vpos2, cap)
    u1 = sortedset.union_rows(ids1, valid1, cap, ovf1)
    u2 = sortedset.union_rows(ids2, valid2, cap, ovf2)
    return sortedset.intersect(u1, u2)


# ---------------------------------------------------------------------------
# categories D–F: pattern 2 carries an extra unbounded variable ?Y
# ---------------------------------------------------------------------------


def _rebind_batch(meta, f, preds, xs, vpos2: str, cap_y: int):
    """Resolve pattern-2 for every (pred, X) pair; X bound into vpos2."""
    if vpos2 == "s":  # (X, P2, ?Y): row scans
        r = k2forest.row_scan_batch(meta, f, preds - 1, xs - 1, cap_y)
    else:  # (?Y, P2, X): column scans
        r = k2forest.col_scan_batch(meta, f, preds - 1, xs - 1, cap_y)
    return jnp.where(r.valid, r.ids + 1, SENTINEL), r.valid, r.overflow.any()


def join_d(
    meta, f, p1, c1, vpos1: str, p2, vpos2: str, cap_x: int, cap_y: int
) -> JoinPairs:
    """(?X,P1,O1)(?Y,P2,?X)-style: resolve X list, re-bind into pattern 2.

    vpos2 names the position of **?X** in pattern 2; ?Y takes the other one.
    """
    a = _side_list(meta, f, p1, c1, vpos1, cap_x)
    xs = jnp.where(a.valid, a.ids, 1)  # clamp invalid lanes to a safe id
    preds = jnp.full((cap_x,), jnp.asarray(p2, jnp.int32))
    y_ids, y_valid, ovf = _rebind_batch(meta, f, preds, xs, vpos2, cap_y)
    y_valid = y_valid & a.valid[:, None]
    return JoinPairs(a.ids, a.valid, y_ids, y_valid, a.overflow | ovf)


def join_e(
    meta, f, p1, c1, vpos1: str, vpos2: str, cap_x: int, cap_y: int
) -> JoinPairs:
    """D with pattern-2 predicate unbounded: repeat for every predicate."""
    a = _side_list(meta, f, p1, c1, vpos1, cap_x)
    xs = jnp.where(a.valid, a.ids, 1)
    P = f.n_preds

    def per_pred(p):
        preds = jnp.full((cap_x,), p, jnp.int32)
        y_ids, y_valid, ovf = _rebind_batch(meta, f, preds, xs, vpos2, cap_y)
        return y_ids, y_valid & a.valid[:, None], ovf

    y_ids, y_valid, ovf = jax.vmap(per_pred)(jnp.arange(1, P + 1, dtype=jnp.int32))
    x_ids = jnp.broadcast_to(a.ids, (P, cap_x))
    x_valid = jnp.broadcast_to(a.valid, (P, cap_x))
    return JoinPairs(x_ids, x_valid, y_ids, y_valid, a.overflow | ovf.any())


def join_f(meta, f, c1, vpos1: str, vpos2: str, cap_x: int, cap_y: int) -> JoinPairs:
    """Both predicates unbounded: union X over predicates, then E's re-bind."""
    ids1, valid1, ovf1 = _side_list_all_preds(meta, f, c1, vpos1, cap_x)
    u = sortedset.union_rows(ids1, valid1, cap_x, ovf1)
    xs = jnp.where(u.valid, u.ids, 1)
    P = f.n_preds

    def per_pred(p):
        preds = jnp.full((cap_x,), p, jnp.int32)
        y_ids, y_valid, ovf = _rebind_batch(meta, f, preds, xs, vpos2, cap_y)
        return y_ids, y_valid & u.valid[:, None], ovf

    y_ids, y_valid, ovf = jax.vmap(per_pred)(jnp.arange(1, P + 1, dtype=jnp.int32))
    x_ids = jnp.broadcast_to(u.ids, (P, cap_x))
    x_valid = jnp.broadcast_to(u.valid, (P, cap_x))
    return JoinPairs(x_ids, x_valid, y_ids, y_valid, u.overflow | ovf.any())
