"""Join categories A–F over triple patterns (paper §k²-triples, Fig. 4).

A join query = two triple patterns sharing one variable ?X which sits in the
subject or object position of each pattern (SS / OO / SO joins).  The six
categories follow the paper:

  A — both predicates bounded, non-join positions bound     -> list ∩ list
  B — one unbounded predicate                               -> list ∩ each of P lists
  C — both predicates unbounded                             -> union ∩ union
  D — bounded predicates, one non-join position unbounded   -> resolve + re-bind
  E — D with one unbounded predicate                        -> D per predicate
  F — D with two unbounded predicates                       -> E per predicate

Every function is jit-able: inputs are scalar IDs (1-based), outputs are
fixed-capacity IdSet / IdSetsPerPred / JoinPairs with validity masks.
``vpos`` ∈ {"s","o"} names which position of a pattern holds the join
variable; the SS/OO/SO kind is implied by (vpos1, vpos2).  Cross (SO) joins
rely on the dictionary's shared [1,|SO|] range — IDs are directly comparable.

Every traversal routes through the ``core.k2forest`` batch entry points;
the ``backend=`` parameter accepts an ``ExecConfig`` (the compiled-plan
path — ``Engine.compile(JoinQ(...))`` threads one through, categories A–C
additionally resolving their side-lists via the shared serve-step
programs) or a legacy "pallas"/"jnp" string / ``None`` (per-call
``REPRO_SCAN_BACKEND`` resolution): "pallas" runs the batched ``k2_scan``
/ fused ``k2_scan_rebind`` kernels, "jnp" the vmapped reference traversal
— bit-identical outputs either way (tests/test_joins_kernel.py).

Overflow is tracked per predicate wherever a predicate axis exists
(``PerPredSets.overflow[P]``, ``JoinPairs.overflow[P]`` for E/F): a caller
can tell WHICH predicate's lane was truncated instead of losing that to a
single collapsed scalar.  Rebind overflow is masked by the X lane's
validity first — a clamped dead lane's scan cannot latch a phantom
overflow.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import k2forest, sortedset
from repro.core.k2forest import K2Forest
from repro.core.k2tree import K2Meta
from repro.core.sortedset import IdSet, SENTINEL


class JoinPairs(NamedTuple):
    """(X, Y) bindings: Y lists hang off each X lane (and optionally preds)."""

    x_ids: jax.Array  # int32[..., capx]
    x_valid: jax.Array  # bool[..., capx]
    y_ids: jax.Array  # int32[..., capx, capy]
    y_valid: jax.Array  # bool[..., capx, capy]
    overflow: jax.Array  # bool[] (D) or bool[P] (E/F: per-predicate)


# ---------------------------------------------------------------------------
# pattern side-lists (the paper's direct / reverse neighbors)
# ---------------------------------------------------------------------------


def _side_list(meta, f, p, const, vpos: str, cap: int,
               backend: str | None = None) -> IdSet:
    """Sorted candidate values of the join variable for one pattern.

    (?X, P, O): reverse neighbors (column scan).  (S, P, ?X): direct (row).
    IDs returned 1-based.
    """
    p = jnp.asarray(p, jnp.int32) - 1
    c = jnp.asarray(const, jnp.int32) - 1
    if vpos == "s":
        r = k2forest.col_scan(meta, f, p, c, cap, backend)
    else:
        r = k2forest.row_scan(meta, f, p, c, cap, backend)
    return sortedset.from_result(
        jnp.where(r.valid, r.ids + 1, SENTINEL), r.valid, r.count, r.overflow
    )


def _side_list_all_preds(meta, f, const, vpos: str, cap: int,
                         backend: str | None = None):
    """-> (ids[P,cap], valid[P,cap], overflow[P]) sorted within each pred."""
    c = jnp.asarray(const, jnp.int32) - 1
    if vpos == "s":
        r = k2forest.col_scan_all_preds(meta, f, c, cap, backend)
    else:
        r = k2forest.row_scan_all_preds(meta, f, c, cap, backend)
    ids = jnp.where(r.valid, r.ids + 1, SENTINEL)
    return ids, r.valid, r.overflow


# ---------------------------------------------------------------------------
# categories A–C: both non-join positions bound
# ---------------------------------------------------------------------------


def join_a(meta, f, p1, c1, vpos1: str, p2, c2, vpos2: str, cap: int,
           backend: str | None = None) -> IdSet:
    """(?X,P1,O1)(?X,P2,O2)-style: two bounded patterns, intersect."""
    a = _side_list(meta, f, p1, c1, vpos1, cap, backend)
    b = _side_list(meta, f, p2, c2, vpos2, cap, backend)
    return sortedset.intersect(a, b)


class PerPredSets(NamedTuple):
    ids: jax.Array  # int32[P, cap]
    valid: jax.Array  # bool[P, cap]
    preds: jax.Array  # int32[P] 1-based predicate ids
    counts: jax.Array  # int32[P] per-predicate result counts
    overflow: jax.Array  # bool[P] per-predicate truncation flags


def join_b(meta, f, p1, c1, vpos1: str, c2, vpos2: str, cap: int,
           backend: str | None = None) -> PerPredSets:
    """Pattern 2 has unbounded predicate: bounded side first, then ∩ per pred."""
    a = _side_list(meta, f, p1, c1, vpos1, cap, backend)
    ids2, valid2, ovf2 = _side_list_all_preds(meta, f, c2, vpos2, cap, backend)

    def one(ids_p, valid_p):
        b = IdSet(ids_p, valid_p, valid_p.sum().astype(jnp.int32), jnp.asarray(False))
        r = sortedset.intersect(a, b)
        return r.ids, r.valid

    ids, valid = jax.vmap(one)(ids2, valid2)
    P = f.n_preds
    return PerPredSets(
        ids, valid, jnp.arange(1, P + 1, dtype=jnp.int32),
        valid.sum(axis=-1).astype(jnp.int32), a.overflow | ovf2,
    )


def join_c(meta, f, c1, vpos1: str, c2, vpos2: str, cap: int,
           backend: str | None = None) -> IdSet:
    """Both predicates unbounded: union per side, intersect the unions."""
    ids1, valid1, ovf1 = _side_list_all_preds(meta, f, c1, vpos1, cap, backend)
    ids2, valid2, ovf2 = _side_list_all_preds(meta, f, c2, vpos2, cap, backend)
    u1 = sortedset.union_rows(ids1, valid1, cap, ovf1.any())
    u2 = sortedset.union_rows(ids2, valid2, cap, ovf2.any())
    return sortedset.intersect(u1, u2)


# ---------------------------------------------------------------------------
# categories D–F: pattern 2 carries an extra unbounded variable ?Y
# ---------------------------------------------------------------------------


def _wrap_rebind(x_valid, y_ids, y_valid, y_ovf):
    """Shift rebind output to 1-based ids, mask by X validity."""
    ids = jnp.where(y_valid, y_ids + 1, SENTINEL)
    valid = y_valid & x_valid[..., None]
    ovf = (y_ovf & x_valid).any(axis=-1)
    return ids, valid, ovf


def join_d(meta, f, p1, c1, vpos1: str, p2, vpos2: str,
           cap_x: int, cap_y: int, backend: str | None = None) -> JoinPairs:
    """(?X,P1,O1)(?Y,P2,?X)-style: resolve X list, re-bind into pattern 2.

    vpos2 names the position of **?X** in pattern 2; ?Y takes the other one.
    One fused scan→rebind launch: the X side-list never leaves the device.
    """
    ax1 = jnp.asarray([1 if vpos1 == "s" else 0], jnp.int32)
    ax2 = jnp.asarray([0 if vpos2 == "s" else 1], jnp.int32)
    p1v = jnp.reshape(jnp.asarray(p1, jnp.int32) - 1, (1,))
    c1v = jnp.reshape(jnp.asarray(c1, jnp.int32) - 1, (1,))
    p2v = jnp.reshape(jnp.asarray(p2, jnp.int32) - 1, (1,))
    (x_ids, x_valid, _, x_ovf, y_ids, y_valid, _, y_ovf) = (
        jax.tree.map(lambda a: a[0], k2forest.scan_rebind_batch(
            meta, f, p1v, c1v, ax1, p2v, ax2, cap_x, cap_y, backend
        ))
    )
    xi = jnp.where(x_valid, x_ids + 1, SENTINEL)
    yi, yv, yo = _wrap_rebind(x_valid, y_ids, y_valid, y_ovf)
    return JoinPairs(xi, x_valid, yi, yv, x_ovf | yo)


def join_e(meta, f, p1, c1, vpos1: str, vpos2: str,
           cap_x: int, cap_y: int, backend: str | None = None) -> JoinPairs:
    """D with pattern-2 predicate unbounded: repeat for every predicate.

    One fused launch with P query lanes — lane p re-resolves the (cheap) X
    side-list and re-binds it into predicate p's tree.
    """
    P = f.n_preds
    ax1 = jnp.full((P,), 1 if vpos1 == "s" else 0, jnp.int32)
    ax2 = jnp.full((P,), 0 if vpos2 == "s" else 1, jnp.int32)
    p1v = jnp.full((P,), jnp.asarray(p1, jnp.int32) - 1)
    c1v = jnp.full((P,), jnp.asarray(c1, jnp.int32) - 1)
    p2v = jnp.arange(P, dtype=jnp.int32)
    (x_ids, x_valid, _, x_ovf, y_ids, y_valid, _, y_ovf) = (
        k2forest.scan_rebind_batch(
            meta, f, p1v, c1v, ax1, p2v, ax2, cap_x, cap_y, backend
        )
    )
    xi = jnp.where(x_valid, x_ids + 1, SENTINEL)
    yi, yv, yo = _wrap_rebind(x_valid, y_ids, y_valid, y_ovf)
    return JoinPairs(xi, x_valid, yi, yv, x_ovf | yo)


def join_f(meta, f, c1, vpos1: str, vpos2: str,
           cap_x: int, cap_y: int, backend: str | None = None) -> JoinPairs:
    """Both predicates unbounded: union X over predicates, then E's re-bind.

    The unioned X list is data-dependent, so the re-bind runs as one flat
    (P·cap_x)-query batched scan instead of the fused kernel.
    """
    ids1, valid1, ovf1 = _side_list_all_preds(meta, f, c1, vpos1, cap_x, backend)
    u = sortedset.union_rows(ids1, valid1, cap_x, ovf1.any())
    xs = jnp.where(u.valid, u.ids, 1)  # clamp invalid lanes to a safe id
    P = f.n_preds
    preds = jnp.repeat(jnp.arange(P, dtype=jnp.int32), cap_x)
    keys = jnp.tile(xs - 1, P)
    axes = jnp.full((P * cap_x,), 0 if vpos2 == "s" else 1, jnp.int32)
    r = k2forest.scan_batch_mixed(meta, f, preds, keys, axes, cap_y, backend)
    y_ids = r.ids.reshape(P, cap_x, cap_y)
    y_valid = r.valid.reshape(P, cap_x, cap_y)
    y_ovf = r.overflow.reshape(P, cap_x)
    yi, yv, yo = _wrap_rebind(u.valid[None, :], y_ids, y_valid, y_ovf)
    x_ids = jnp.broadcast_to(u.ids, (P, cap_x))
    x_valid = jnp.broadcast_to(u.valid, (P, cap_x))
    return JoinPairs(x_ids, x_valid, yi, yv, u.overflow | yo)
