"""The paper's 4-range Dictionary mapping (Fig. 2, following BitMat).

Terms are split into four lexicographically-sorted categories:

  * SO — terms playing BOTH subject and object roles -> IDs [1, |SO|]
  * S  — subject-only terms                          -> IDs [|SO|+1, |SO|+|S|]
  * O  — object-only terms                           -> IDs [|SO|+1, |SO|+|O|]
  * P  — predicates                                  -> IDs [1, |P|]

so that subject/object cross-joins land in the shared [1,|SO|]² submatrix.
IDs are 1-based as in the paper; matrix coordinates are (id - 1).

The paper scopes dictionary *compression* out, but the system's thesis —
full-in-memory serving — needs it at dbpedia scale, and *Compressed
Indexes for Fast Search of Semantic Data* (arXiv:1904.07619) shows the
standard recipe is query-competitive: bucketed **plain front coding** for
the sorted term strings (each bucket stores its head verbatim, the rest as
(shared-prefix-len, suffix) varint records) with an **Elias–Fano** monotone
sequence over the bucket byte offsets, supporting both dictionary
operations — ``locate`` (term -> dense 1-based id, binary search over
bucket heads + in-bucket walk) and ``extract`` (id -> term, EF access +
bounded decode).  :class:`FrontCodedStrings` implements the pool,
:class:`CompressedTripleDictionary` the paper's 4-range mapping on top of
it (same API as :class:`TripleDictionary`), and ``size_bits`` /
``analytic_bits`` keep the accounting honest (measured arrays vs the
textbook n·(2 + log(u/n)) EF bound + raw front-coded bytes) for
``benchmarks/bench_compression``'s end-to-end bits/triple.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.bitvec import popcount_np


@dataclasses.dataclass(frozen=True)
class TripleDictionary:
    """Immutable term <-> ID mapping with the paper's four ranges."""

    so_terms: tuple[str, ...]  # sorted; IDs 1..|SO|
    s_terms: tuple[str, ...]  # sorted; IDs |SO|+1 ..
    o_terms: tuple[str, ...]  # sorted; IDs |SO|+1 ..
    p_terms: tuple[str, ...]  # sorted; IDs 1..|P|

    # ---- sizes -----------------------------------------------------------
    @property
    def n_so(self) -> int:
        return len(self.so_terms)

    @property
    def n_subjects(self) -> int:  # total distinct subjects
        return self.n_so + len(self.s_terms)

    @property
    def n_objects(self) -> int:  # total distinct objects
        return self.n_so + len(self.o_terms)

    @property
    def n_preds(self) -> int:
        return len(self.p_terms)

    @property
    def matrix_extent(self) -> int:
        """Rows/cols the square adjacency matrices must cover."""
        return max(self.n_subjects, self.n_objects, 1)

    # ---- encode ----------------------------------------------------------
    def encode_subject(self, term: str) -> int:
        i = bisect.bisect_left(self.so_terms, term)
        if i < len(self.so_terms) and self.so_terms[i] == term:
            return i + 1
        j = bisect.bisect_left(self.s_terms, term)
        if j < len(self.s_terms) and self.s_terms[j] == term:
            return self.n_so + j + 1
        raise KeyError(f"unknown subject: {term!r}")

    def encode_object(self, term: str) -> int:
        i = bisect.bisect_left(self.so_terms, term)
        if i < len(self.so_terms) and self.so_terms[i] == term:
            return i + 1
        j = bisect.bisect_left(self.o_terms, term)
        if j < len(self.o_terms) and self.o_terms[j] == term:
            return self.n_so + j + 1
        raise KeyError(f"unknown object: {term!r}")

    def encode_predicate(self, term: str) -> int:
        j = bisect.bisect_left(self.p_terms, term)
        if j < len(self.p_terms) and self.p_terms[j] == term:
            return j + 1
        raise KeyError(f"unknown predicate: {term!r}")

    # ---- decode ----------------------------------------------------------
    def decode_subject(self, sid: int) -> str:
        if 1 <= sid <= self.n_so:
            return self.so_terms[sid - 1]
        return self.s_terms[sid - self.n_so - 1]

    def decode_object(self, oid: int) -> str:
        if 1 <= oid <= self.n_so:
            return self.so_terms[oid - 1]
        return self.o_terms[oid - self.n_so - 1]

    def decode_predicate(self, pid: int) -> str:
        return self.p_terms[pid - 1]

    def encode_triples(
        self, triples: Iterable[tuple[str, str, str]]
    ) -> np.ndarray:
        """-> int64[N, 3] of 1-based (s, p, o) IDs."""
        out = [
            (self.encode_subject(s), self.encode_predicate(p), self.encode_object(o))
            for (s, p, o) in triples
        ]
        return np.asarray(out, dtype=np.int64).reshape(-1, 3)


def build_dictionary(triples: Sequence[tuple[str, str, str]]) -> TripleDictionary:
    """Classify every term into SO / S / O / P and sort each class."""
    subjects = {t[0] for t in triples}
    objects = {t[2] for t in triples}
    preds = {t[1] for t in triples}
    so = subjects & objects
    return TripleDictionary(
        so_terms=tuple(sorted(so)),
        s_terms=tuple(sorted(subjects - so)),
        o_terms=tuple(sorted(objects - so)),
        p_terms=tuple(sorted(preds)),
    )


# ---------------------------------------------------------------------------
# Elias–Fano monotone sequence (host-side; the bucket-offset index)
# ---------------------------------------------------------------------------


def _varint(n: int) -> bytes:
    """LEB128 unsigned varint."""
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _read_varint(buf: bytes, pos: int) -> tuple[int, int]:
    v = shift = 0
    while True:
        b = buf[pos]
        pos += 1
        v |= (b & 0x7F) << shift
        if not (b & 0x80):
            return v, pos
        shift += 7


class EliasFano:
    """Elias–Fano encoding of a non-decreasing int sequence with O(1)-ish
    ``access``: low ``l = floor(log2(u/n))`` bits packed densely, high bits
    as a unary bitvector ``H`` where element i sets bit ``(v_i >> l) + i``,
    plus per-word inclusive popcount blocks so ``access(i)`` is a
    ``searchsorted`` (select1) + in-word bit walk.  Measured size counts all
    three arrays; ``analytic_bits`` is the textbook ``n * (2 + l)`` bound.
    """

    def __init__(self, values: Sequence[int]):
        v = np.asarray(values, np.int64).reshape(-1)
        self.n = int(v.size)
        if self.n == 0:
            self._l = 0
            self._low = np.zeros(0, np.uint32)
            self._high = np.zeros(1, np.uint32)
            self._cum = np.zeros(1, np.int64)
            self.universe = 0
            return
        if np.any(v[1:] < v[:-1]) or v[0] < 0:
            raise ValueError("EliasFano needs a non-decreasing, non-negative sequence")
        u = int(v[-1]) + 1
        self.universe = u
        l = max(0, (u // self.n).bit_length() - 1)
        self._l = l
        # low halves, l bits each, packed LSB-first into uint32 words
        if l:
            lw = np.zeros((self.n * l + 31) // 32, np.int64)
            for k in range(l):
                bitpos = np.arange(self.n, dtype=np.int64) * l + k
                bitpos = bitpos[((v >> k) & 1) == 1]
                np.bitwise_or.at(lw, bitpos >> 5, np.int64(1) << (bitpos & 31))
            self._low = lw.astype(np.uint32)
        else:
            self._low = np.zeros(0, np.uint32)
        # high halves: unary bitvector, bit (v_i >> l) + i set for element i
        hb = (v >> l) + np.arange(self.n, dtype=np.int64)
        hw = np.zeros((int(hb[-1]) >> 5) + 1, np.int64)
        np.bitwise_or.at(hw, hb >> 5, np.int64(1) << (hb & 31))
        self._high = hw.astype(np.uint32)
        self._cum = np.cumsum(popcount_np(self._high)).astype(np.int64)

    def __len__(self) -> int:
        return self.n

    def _low_at(self, i: int) -> int:
        val = 0
        for k in range(self._l):
            bp = i * self._l + k
            val |= ((int(self._low[bp >> 5]) >> (bp & 31)) & 1) << k
        return val

    def __getitem__(self, i: int) -> int:
        if not 0 <= i < self.n:
            raise IndexError(i)
        # select1(i): word via searchsorted on inclusive ranks, then bit walk
        w = int(np.searchsorted(self._cum, i, side="right"))
        r = i - (int(self._cum[w - 1]) if w else 0)
        word = int(self._high[w])
        for b in range(32):
            if (word >> b) & 1:
                if r == 0:
                    return ((w * 32 + b - i) << self._l) | self._low_at(i)
                r -= 1
        raise AssertionError("rank blocks inconsistent with bitvector")

    def size_bits(self) -> int:
        return 32 * (self._low.size + self._high.size + 2 * self._cum.size)

    def analytic_bits(self) -> int:
        return self.n * (2 + self._l)


# ---------------------------------------------------------------------------
# bucketed plain-front-coded string pool with EF offsets (locate + extract)
# ---------------------------------------------------------------------------


class FrontCodedStrings:
    """Sorted string list, plain-front-coded in buckets of ``bucket`` terms.

    Each bucket stores its head verbatim (``varint(len) + bytes``) and the
    remaining terms as ``varint(lcp) + varint(suffix_len) + suffix`` records;
    bucket byte offsets live in an :class:`EliasFano` index.  ``extract``
    (``__getitem__``) decodes at most ``bucket`` records; ``locate`` binary
    searches the bucket heads then walks one bucket.  LCPs are in characters
    (suffixes stored as UTF-8), so non-ASCII terms round-trip.
    """

    def __init__(self, terms: Sequence[str], bucket: int = 8):
        self.bucket = int(bucket)
        blob = bytearray()
        offsets: list[int] = []
        prev = ""
        for i, t in enumerate(terms):
            if i % self.bucket == 0:
                offsets.append(len(blob))
                enc = t.encode()
                blob += _varint(len(enc)) + enc
            else:
                lcp = 0
                m = min(len(prev), len(t))
                while lcp < m and prev[lcp] == t[lcp]:
                    lcp += 1
                enc = t[lcp:].encode()
                blob += _varint(lcp) + _varint(len(enc)) + enc
            prev = t
        self.n = len(terms)
        self._blob = bytes(blob)
        self._ef = EliasFano(offsets)

    def __len__(self) -> int:
        return self.n

    def _head(self, b: int) -> str:
        pos = self._ef[b]
        ln, pos = _read_varint(self._blob, pos)
        return self._blob[pos : pos + ln].decode()

    def _bucket_iter(self, b: int):
        """Yield (index, term) for every term in bucket b, in order."""
        pos = self._ef[b]
        ln, pos = _read_varint(self._blob, pos)
        cur = self._blob[pos : pos + ln].decode()
        pos += ln
        i = b * self.bucket
        yield i, cur
        end = min(self.n, i + self.bucket)
        for i in range(i + 1, end):
            lcp, pos = _read_varint(self._blob, pos)
            ln, pos = _read_varint(self._blob, pos)
            cur = cur[:lcp] + self._blob[pos : pos + ln].decode()
            pos += ln
            yield i, cur

    def __getitem__(self, idx: int) -> str:
        if not 0 <= idx < self.n:
            raise IndexError(idx)
        b = idx // self.bucket
        for i, t in self._bucket_iter(b):
            if i == idx:
                return t
        raise AssertionError("bucket walk missed its own index")

    def locate(self, term: str) -> int:
        """0-based index of ``term``, or -1 if absent (terms must be sorted)."""
        if self.n == 0 or term < self._head(0):
            return -1
        lo, hi = 0, len(self._ef) - 1
        while lo < hi:  # rightmost bucket whose head <= term
            mid = (lo + hi + 1) // 2
            if self._head(mid) <= term:
                lo = mid
            else:
                hi = mid - 1
        for i, t in self._bucket_iter(lo):
            if t == term:
                return i
            if t > term:
                return -1
        return -1

    def size_bits(self) -> int:
        """Measured: blob bytes + the EF offset index (incl. rank blocks)."""
        return 8 * len(self._blob) + self._ef.size_bits()

    def analytic_bits(self) -> int:
        """Front-coded bytes + the EF bound (no word padding, no rank)."""
        return 8 * len(self._blob) + self._ef.analytic_bits()

    def size_bytes(self) -> int:
        return (self.size_bits() + 7) // 8


# ---------------------------------------------------------------------------
# the 4-range Dictionary over front-coded pools (same API as TripleDictionary)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class CompressedTripleDictionary:
    """The paper's 4-range mapping with every term class stored as a
    :class:`FrontCodedStrings` pool — duck-compatible with
    :class:`TripleDictionary` (same encode/decode/size API) but holding
    compressed bytes instead of Python string tuples, so the end-to-end
    bits/triple quoted by ``bench_compression`` includes a *real* dictionary.
    """

    so: FrontCodedStrings
    s: FrontCodedStrings
    o: FrontCodedStrings
    p: FrontCodedStrings

    # ---- sizes -----------------------------------------------------------
    @property
    def n_so(self) -> int:
        return len(self.so)

    @property
    def n_subjects(self) -> int:
        return self.n_so + len(self.s)

    @property
    def n_objects(self) -> int:
        return self.n_so + len(self.o)

    @property
    def n_preds(self) -> int:
        return len(self.p)

    @property
    def matrix_extent(self) -> int:
        return max(self.n_subjects, self.n_objects, 1)

    # TripleDictionary compatibility: materialized term tuples (tests only —
    # hot paths go through locate/extract and never expand these)
    @property
    def so_terms(self) -> tuple[str, ...]:
        return tuple(self.so[i] for i in range(len(self.so)))

    @property
    def s_terms(self) -> tuple[str, ...]:
        return tuple(self.s[i] for i in range(len(self.s)))

    @property
    def o_terms(self) -> tuple[str, ...]:
        return tuple(self.o[i] for i in range(len(self.o)))

    @property
    def p_terms(self) -> tuple[str, ...]:
        return tuple(self.p[i] for i in range(len(self.p)))

    # ---- encode (locate) -------------------------------------------------
    def encode_subject(self, term: str) -> int:
        i = self.so.locate(term)
        if i >= 0:
            return i + 1
        j = self.s.locate(term)
        if j >= 0:
            return self.n_so + j + 1
        raise KeyError(f"unknown subject: {term!r}")

    def encode_object(self, term: str) -> int:
        i = self.so.locate(term)
        if i >= 0:
            return i + 1
        j = self.o.locate(term)
        if j >= 0:
            return self.n_so + j + 1
        raise KeyError(f"unknown object: {term!r}")

    def encode_predicate(self, term: str) -> int:
        j = self.p.locate(term)
        if j >= 0:
            return j + 1
        raise KeyError(f"unknown predicate: {term!r}")

    # ---- decode (extract) ------------------------------------------------
    def decode_subject(self, sid: int) -> str:
        if 1 <= sid <= self.n_so:
            return self.so[sid - 1]
        return self.s[sid - self.n_so - 1]

    def decode_object(self, oid: int) -> str:
        if 1 <= oid <= self.n_so:
            return self.so[oid - 1]
        return self.o[oid - self.n_so - 1]

    def decode_predicate(self, pid: int) -> str:
        return self.p[pid - 1]

    def encode_triples(
        self, triples: Iterable[tuple[str, str, str]]
    ) -> np.ndarray:
        out = [
            (self.encode_subject(s), self.encode_predicate(p), self.encode_object(o))
            for (s, p, o) in triples
        ]
        return np.asarray(out, dtype=np.int64).reshape(-1, 3)

    # ---- accounting ------------------------------------------------------
    def size_bits(self) -> int:
        return sum(
            pool.size_bits() for pool in (self.so, self.s, self.o, self.p)
        )

    def analytic_bits(self) -> int:
        return sum(
            pool.analytic_bits() for pool in (self.so, self.s, self.o, self.p)
        )

    def raw_bits(self) -> int:
        """Uncompressed UTF-8 bytes of every term (the baseline)."""
        total = 0
        for pool in (self.so, self.s, self.o, self.p):
            for i in range(len(pool)):
                total += len(pool[i].encode())
        return 8 * total


def build_compressed_dictionary(
    triples: Sequence[tuple[str, str, str]], *, bucket: int = 8
) -> CompressedTripleDictionary:
    """Classify terms into SO / S / O / P and front-code each sorted class."""
    subjects = {t[0] for t in triples}
    objects = {t[2] for t in triples}
    preds = {t[1] for t in triples}
    so = subjects & objects
    return CompressedTripleDictionary(
        so=FrontCodedStrings(sorted(so), bucket),
        s=FrontCodedStrings(sorted(subjects - so), bucket),
        o=FrontCodedStrings(sorted(objects - so), bucket),
        p=FrontCodedStrings(sorted(preds), bucket),
    )


class ExtendedDictionary:
    """Incremental id-range extension on top of a frozen dictionary.

    The delta layer (``core/delta.py``) must mint ids for terms the static
    dictionary has never seen without perturbing any existing id — the
    static k²-forest and DAC index are addressed by those ids.  Extension
    terms therefore get a single SHARED subject/object id appended above
    ``base.matrix_extent`` (id ``ext_base + k``, 1-based ``k``), and
    extension predicates are appended above ``base.n_preds``.  Compaction
    folds the extension into the rebuilt store by passing the same
    ``ExtendedDictionary`` through — appended ranges keep ids stable across
    epochs, so plans and cached results never need re-translation.

    Duck-compatible with :class:`TripleDictionary` /
    :class:`CompressedTripleDictionary` (``encode_*`` raise ``KeyError`` on
    unknown terms; ``decode_*`` cover both base and extension ranges).
    """

    def __init__(self, base: TripleDictionary | CompressedTripleDictionary):
        self.base = base
        self.ext_base = base.matrix_extent
        self.pred_base = base.n_preds
        self._terms: list[str] = []  # shared S/O extension pool
        self._ids: dict[str, int] = {}
        self._preds: list[str] = []
        self._pred_ids: dict[str, int] = {}

    # --- extents (appended ranges inflate both roles: harmless empty rows)

    @property
    def n_so(self) -> int:
        return self.base.n_so

    @property
    def n_subjects(self) -> int:
        return self.ext_base + len(self._terms) if self._terms else self.base.n_subjects

    @property
    def n_objects(self) -> int:
        return self.ext_base + len(self._terms) if self._terms else self.base.n_objects

    @property
    def n_preds(self) -> int:
        return self.pred_base + len(self._preds)

    @property
    def matrix_extent(self) -> int:
        return max(self.ext_base + len(self._terms), 1)

    @property
    def n_ext_terms(self) -> int:
        return len(self._terms)

    # --- encode (base first, then the extension pool)

    def _encode_ext(self, term: str) -> int:
        i = self._ids.get(term)
        if i is None:
            raise KeyError(term)
        return i

    def encode_subject(self, term: str) -> int:
        try:
            return self.base.encode_subject(term)
        except KeyError:
            return self._encode_ext(term)

    def encode_object(self, term: str) -> int:
        try:
            return self.base.encode_object(term)
        except KeyError:
            return self._encode_ext(term)

    def encode_predicate(self, term: str) -> int:
        try:
            return self.base.encode_predicate(term)
        except KeyError:
            i = self._pred_ids.get(term)
            if i is None:
                raise KeyError(term)
            return i

    # --- extend (idempotent: re-adding returns the existing id)

    def add_term(self, term: str) -> int:
        """Register ``term`` in the shared S/O extension pool -> its id."""
        for enc in (self.base.encode_subject, self.base.encode_object):
            try:
                return enc(term)
            except KeyError:
                pass
        i = self._ids.get(term)
        if i is None:
            i = self.ext_base + len(self._terms) + 1
            self._terms.append(term)
            self._ids[term] = i
        return i

    def add_predicate(self, term: str) -> int:
        try:
            return self.base.encode_predicate(term)
        except KeyError:
            i = self._pred_ids.get(term)
            if i is None:
                i = self.pred_base + len(self._preds) + 1
                self._preds.append(term)
                self._pred_ids[term] = i
            return i

    # --- decode

    def _decode_ext(self, xid: int) -> str:
        return self._terms[xid - self.ext_base - 1]

    def decode_subject(self, sid: int) -> str:
        if sid > self.ext_base:
            return self._decode_ext(sid)
        return self.base.decode_subject(sid)

    def decode_object(self, oid: int) -> str:
        if oid > self.ext_base:
            return self._decode_ext(oid)
        return self.base.decode_object(oid)

    def decode_predicate(self, pid: int) -> str:
        if pid > self.pred_base:
            return self._preds[pid - self.pred_base - 1]
        return self.base.decode_predicate(pid)

    def encode_triples(
        self, triples: Iterable[tuple[str, str, str]]
    ) -> np.ndarray:
        out = [
            (self.encode_subject(s), self.encode_predicate(p), self.encode_object(o))
            for (s, p, o) in triples
        ]
        return np.asarray(out, dtype=np.int64).reshape(-1, 3)
