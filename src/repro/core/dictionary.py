"""The paper's 4-range Dictionary mapping (Fig. 2, following BitMat).

Terms are split into four lexicographically-sorted categories:

  * SO — terms playing BOTH subject and object roles -> IDs [1, |SO|]
  * S  — subject-only terms                          -> IDs [|SO|+1, |SO|+|S|]
  * O  — object-only terms                           -> IDs [|SO|+1, |SO|+|O|]
  * P  — predicates                                  -> IDs [1, |P|]

so that subject/object cross-joins land in the shared [1,|SO|]² submatrix.
IDs are 1-based as in the paper; matrix coordinates are (id - 1).

The paper scopes dictionary *compression* out; we keep the mapping exact and
additionally ship a front-coded string pool (``FrontCodedStrings``) used by the
end-to-end examples, so the system is runnable on raw N3-ish input.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TripleDictionary:
    """Immutable term <-> ID mapping with the paper's four ranges."""

    so_terms: tuple[str, ...]  # sorted; IDs 1..|SO|
    s_terms: tuple[str, ...]  # sorted; IDs |SO|+1 ..
    o_terms: tuple[str, ...]  # sorted; IDs |SO|+1 ..
    p_terms: tuple[str, ...]  # sorted; IDs 1..|P|

    # ---- sizes -----------------------------------------------------------
    @property
    def n_so(self) -> int:
        return len(self.so_terms)

    @property
    def n_subjects(self) -> int:  # total distinct subjects
        return self.n_so + len(self.s_terms)

    @property
    def n_objects(self) -> int:  # total distinct objects
        return self.n_so + len(self.o_terms)

    @property
    def n_preds(self) -> int:
        return len(self.p_terms)

    @property
    def matrix_extent(self) -> int:
        """Rows/cols the square adjacency matrices must cover."""
        return max(self.n_subjects, self.n_objects, 1)

    # ---- encode ----------------------------------------------------------
    def encode_subject(self, term: str) -> int:
        i = bisect.bisect_left(self.so_terms, term)
        if i < len(self.so_terms) and self.so_terms[i] == term:
            return i + 1
        j = bisect.bisect_left(self.s_terms, term)
        if j < len(self.s_terms) and self.s_terms[j] == term:
            return self.n_so + j + 1
        raise KeyError(f"unknown subject: {term!r}")

    def encode_object(self, term: str) -> int:
        i = bisect.bisect_left(self.so_terms, term)
        if i < len(self.so_terms) and self.so_terms[i] == term:
            return i + 1
        j = bisect.bisect_left(self.o_terms, term)
        if j < len(self.o_terms) and self.o_terms[j] == term:
            return self.n_so + j + 1
        raise KeyError(f"unknown object: {term!r}")

    def encode_predicate(self, term: str) -> int:
        j = bisect.bisect_left(self.p_terms, term)
        if j < len(self.p_terms) and self.p_terms[j] == term:
            return j + 1
        raise KeyError(f"unknown predicate: {term!r}")

    # ---- decode ----------------------------------------------------------
    def decode_subject(self, sid: int) -> str:
        if 1 <= sid <= self.n_so:
            return self.so_terms[sid - 1]
        return self.s_terms[sid - self.n_so - 1]

    def decode_object(self, oid: int) -> str:
        if 1 <= oid <= self.n_so:
            return self.so_terms[oid - 1]
        return self.o_terms[oid - self.n_so - 1]

    def decode_predicate(self, pid: int) -> str:
        return self.p_terms[pid - 1]

    def encode_triples(
        self, triples: Iterable[tuple[str, str, str]]
    ) -> np.ndarray:
        """-> int64[N, 3] of 1-based (s, p, o) IDs."""
        out = [
            (self.encode_subject(s), self.encode_predicate(p), self.encode_object(o))
            for (s, p, o) in triples
        ]
        return np.asarray(out, dtype=np.int64).reshape(-1, 3)


def build_dictionary(triples: Sequence[tuple[str, str, str]]) -> TripleDictionary:
    """Classify every term into SO / S / O / P and sort each class."""
    subjects = {t[0] for t in triples}
    objects = {t[2] for t in triples}
    preds = {t[1] for t in triples}
    so = subjects & objects
    return TripleDictionary(
        so_terms=tuple(sorted(so)),
        s_terms=tuple(sorted(subjects - so)),
        o_terms=tuple(sorted(objects - so)),
        p_terms=tuple(sorted(preds)),
    )


# ---------------------------------------------------------------------------
# front-coded string pool (examples-only; compression of the Dictionary is
# explicitly out of the paper's scope)
# ---------------------------------------------------------------------------


class FrontCodedStrings:
    """Sorted string list, front-coded in buckets: (shared-prefix-len, suffix)."""

    def __init__(self, terms: Sequence[str], bucket: int = 8):
        self.bucket = bucket
        self._heads: list[str] = []
        self._blob = bytearray()
        self._offsets: list[int] = []
        prev = ""
        for i, t in enumerate(terms):
            if i % bucket == 0:
                self._heads.append(t)
                self._offsets.append(len(self._blob))
                prev = t
            else:
                lcp = 0
                m = min(len(prev), len(t))
                while lcp < m and prev[lcp] == t[lcp]:
                    lcp += 1
                enc = t[lcp:].encode()
                self._blob += lcp.to_bytes(2, "little") + len(enc).to_bytes(2, "little") + enc
                prev = t
        self.n = len(terms)

    def __len__(self) -> int:
        return self.n

    def __getitem__(self, idx: int) -> str:
        b, r = divmod(idx, self.bucket)
        cur = self._heads[b]
        pos = self._offsets[b]
        for _ in range(r):
            lcp = int.from_bytes(self._blob[pos : pos + 2], "little")
            ln = int.from_bytes(self._blob[pos + 2 : pos + 4], "little")
            suf = self._blob[pos + 4 : pos + 4 + ln].decode()
            cur = cur[:lcp] + suf
            pos += 4 + ln
        return cur

    def size_bytes(self) -> int:
        return sum(len(h.encode()) for h in self._heads) + len(self._blob)
