"""Background compaction: fold the delta into a rebuilt static store.

The LSM contract's second half: when the delta grows past
:class:`CompactionPolicy` thresholds, dump the static store's ID triples off
the device (``patterns.dump`` — no retained source triples, the forest IS
the store), apply tombstones, union the inserts, and rebuild forest + DAC
predicate index + dictionary extents with ``k2triples.from_id_triples``.
The rebuild runs off the serve path (the broker does it in a worker
thread); ``DynamicStore.swap`` then installs the new epoch atomically while
in-flight plans keep serving the old one — mutations that raced in after
the pinned snapshot survive in the rebased delta.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import patterns
from repro.core.delta import DeltaSnapshot, DynamicStore
from repro.core.k2triples import K2TriplesStore, from_id_triples


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When to fold the delta down.

    ``max_delta``: compact once inserts + tombstones exceed this many
    entries.  ``max_tombstone_frac``: compact once tombstones exceed this
    fraction of the static triple count (but only after
    ``min_tombstones`` — tiny stores shouldn't churn).
    """

    max_delta: int = 4096
    max_tombstone_frac: float = 0.2
    min_tombstones: int = 64

    def __post_init__(self):
        if self.max_delta < 1:
            raise ValueError("max_delta must be >= 1")
        if not (0.0 < self.max_tombstone_frac <= 1.0):
            raise ValueError("max_tombstone_frac must be in (0, 1]")


def needs_compaction(store: DynamicStore, policy: CompactionPolicy) -> bool:
    d = store.delta
    n_ins, n_tomb = d.n_inserts, d.n_tombstones
    if n_ins + n_tomb >= policy.max_delta:
        return True
    n_static = max(store.static.n_triples, 1)
    return (
        n_tomb >= policy.min_tombstones
        and n_tomb / n_static >= policy.max_tombstone_frac
    )


def dump_static_ids(static: K2TriplesStore, backend=None) -> np.ndarray:
    """Recover int64[N, 3] 1-based (s, p, o) triples from the forest."""
    if static.n_triples == 0:
        return np.empty((0, 3), dtype=np.int64)
    cap = max(int(np.asarray(static.forest.nnz).max()), 1)
    r = patterns.dump(static.meta, static.forest, cap, backend)
    rows = np.asarray(r.rows)
    cols = np.asarray(r.cols)
    valid = np.asarray(r.valid)
    if bool(np.asarray(r.overflow).any()):  # cap == max nnz: cannot happen
        raise RuntimeError("static dump overflowed its own nnz cap")
    out = []
    for pi in range(static.n_preds):
        v = valid[pi]
        if not v.any():
            continue
        ss, oo = rows[pi][v], cols[pi][v]
        out.append(
            np.stack([ss, np.full(ss.shape, pi + 1, dtype=np.int64), oo], axis=1)
        )
    if not out:
        return np.empty((0, 3), dtype=np.int64)
    return np.concatenate(out, axis=0).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class CompactionReport:
    epoch: int
    n_triples: int
    delta_merged: int
    tombstones_applied: int
    duration_s: float


def compact(store: DynamicStore, *, backend=None) -> CompactionReport:
    """Fold the current delta snapshot into a new static epoch.

    Pins a :class:`DeltaSnapshot`, rebuilds off-path, then ``swap``s —
    writes landing during the rebuild survive in the rebased delta.  The
    dictionary (including any appended-range extension) is carried through
    unchanged: ids never move across epochs.
    """
    t0 = time.perf_counter()
    static = store.static
    snap: DeltaSnapshot = store.delta.snapshot()

    ids = dump_static_ids(static, backend)
    applied = 0
    if snap.n_tombstones and ids.shape[0]:
        keep = np.ones(ids.shape[0], dtype=bool)
        for p, pairs in snap.tomb.items():
            sel = np.nonzero(ids[:, 1] == p)[0]
            for j in sel:
                if (int(ids[j, 0]), int(ids[j, 2])) in pairs:
                    keep[j] = False
                    applied += 1
        ids = ids[keep]
    if snap.n_inserts:
        extra = [
            (s, p, o)
            for p, pairs in sorted(snap.ins.items())
            for (s, o) in sorted(pairs)
        ]
        ids = np.concatenate(
            [ids, np.asarray(extra, dtype=np.int64).reshape(-1, 3)], axis=0
        )
    if ids.shape[0]:
        ids = np.unique(ids, axis=0)

    d = store.dictionary
    if d is not None:
        n_subjects, n_objects, n_preds = d.n_subjects, d.n_objects, d.n_preds
    else:
        n_subjects = max(static.n_subjects, snap.n_subjects)
        n_objects = max(static.n_objects, snap.n_objects)
        n_preds = max(static.n_preds, snap.n_preds)

    new_static = from_id_triples(
        ids,
        n_so=static.n_so,
        n_subjects=n_subjects,
        n_objects=n_objects,
        n_preds=n_preds,
        dictionary=static.dictionary,
        with_pred_index=static.pred_index is not None,
    )
    epoch = store.swap(new_static, snap)
    return CompactionReport(
        epoch=epoch,
        n_triples=int(ids.shape[0]),
        delta_merged=snap.n_inserts,
        tombstones_applied=applied,
        duration_s=time.perf_counter() - t0,
    )
