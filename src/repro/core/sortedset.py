"""Fixed-shape sorted-ID set algebra (intersection / union / unique).

The paper's join machinery lives on ID-sorted result lists: "this
intersection is performed in a very faster way by taking advantage of the
ID-ordered of both lists".  Here the lists are fixed-capacity lanes with
validity masks, so every op is jit-able:

  * invalid lanes are driven to the ``SENTINEL`` (int32 max) so sorted order
    puts them at the tail;
  * intersection = vectorized binary search (``jnp.searchsorted``) of A's
    lanes in B — O(cap·log cap) with no data-dependent shapes;
  * union = concatenate + sort + neighbor-dedup + compact.

``repro.kernels.sorted_intersect`` provides the Pallas-tiled version of the
intersection; this module is its oracle and the default CPU path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

SENTINEL = jnp.int32(2**31 - 1)


class IdSet(NamedTuple):
    """Ascending ids in valid lanes; SENTINEL elsewhere."""

    ids: jax.Array  # int32[cap]
    valid: jax.Array  # bool[cap]
    count: jax.Array  # int32[]
    overflow: jax.Array  # bool[]


def from_result(ids: jax.Array, valid: jax.Array, count, overflow) -> IdSet:
    """Normalize a QueryResult-like tuple: sentinel-fill invalid lanes."""
    ids = jnp.where(valid, ids, SENTINEL)
    return IdSet(ids, valid, jnp.asarray(count, jnp.int32), jnp.asarray(overflow))


def intersect(a: IdSet, b: IdSet) -> IdSet:
    """A ∩ B, ascending; capacity = a.cap (A's hits are a subset of A)."""
    pos = jnp.searchsorted(b.ids, a.ids)
    hit = jnp.take(b.ids, jnp.clip(pos, 0, b.ids.shape[0] - 1)) == a.ids
    valid = a.valid & hit
    ids = jnp.where(valid, a.ids, SENTINEL)
    # valid lanes of A stay sorted; compact via sort (sentinels sink to tail)
    order = jnp.argsort(ids)
    ids = ids[order]
    valid = ids != SENTINEL
    return IdSet(ids, valid, valid.sum().astype(jnp.int32), a.overflow | b.overflow)


def union_rows(ids2d: jax.Array, valid2d: jax.Array, cap: int, overflow) -> IdSet:
    """Union of P sorted rows -> one sorted deduped set of capacity ``cap``."""
    flat = jnp.where(valid2d, ids2d, SENTINEL).reshape(-1)
    flat = jnp.sort(flat)
    keep = (flat != SENTINEL) & jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), flat[1:] != flat[:-1]]
    )
    n_unique = keep.sum()
    # stable-compact the kept lanes to the front, then truncate/pad to cap
    idx = jnp.cumsum(keep.astype(jnp.int32)) - 1
    tgt = jnp.where(keep, idx, flat.shape[0])
    out = jnp.full((flat.shape[0] + 1,), SENTINEL, jnp.int32).at[tgt].set(
        flat, mode="drop"
    )[:-1]
    out = out[:cap] if flat.shape[0] >= cap else jnp.pad(
        out, (0, cap - flat.shape[0]), constant_values=SENTINEL
    )
    valid = out != SENTINEL
    ovf = jnp.asarray(overflow) | (n_unique > cap)
    return IdSet(out, valid, jnp.minimum(n_unique, cap).astype(jnp.int32), ovf)


def to_dense_mask(s: IdSet, extent: int) -> jax.Array:
    """bool[extent+1] membership table (ids are 1-based; index 0 unused)."""
    return (
        jnp.zeros((extent + 2,), jnp.bool_)
        .at[jnp.where(s.valid, s.ids, extent + 1)]
        .set(True, mode="drop")[: extent + 1]
    )
