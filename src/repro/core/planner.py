"""Cost-based query planner + algebra-tree executor over the serve IR.

This is the execution half of the SPARQL-shaped layer (``core.algebra``
holds the operator tree and the host-side table algebra).  Two jobs:

**Join ordering.**  :func:`estimate_cardinality` prices one triple pattern
from k²-triples statistics (per-predicate nnz, dictionary extents,
SP/OP-index predicate pruning — the PR-4 degree estimates);
:func:`step_estimate` refines it for a pattern entering a pipeline whose
variables are partially bound.  :func:`cost_order` runs a Selinger-style
dynamic program over pattern subsets (≤ 8 patterns; bitmask DP minimizing
the pipeline's total lane-work — the rows flowing into each step, see
:func:`order_cost`) and falls back to
:func:`greedy_order` — the original greedy selectivity order — beyond
that.  Both break estimate ties by **lowest pattern index** (strict
``<``), so plan order, and therefore plan-cache behaviour, is stable
across runs.

**Tree execution.**  :func:`execute` evaluates an algebra tree to a
:class:`~repro.core.algebra.Table`.  Conjunctive regions
(``Join``-of-``Scan``) are flattened back into BGP blocks and run as ONE
sideways-information-passing pipeline: the block is cost-ordered, the
first pattern seeds the bindings, and every later pattern resolves
through :func:`_resolve_with_bindings` — existing bindings become the
next step's key batch through the engine's pooled flat-launch programs
(the ``serve`` runner), one compiled launch per plan step.  A ``Join`` or
``LeftJoin`` whose right side flattens is *seeded* with the left result
(bindings ride through the same pipeline), so OPTIONAL blocks also cost
one launch per pattern; only genuinely non-conjunctive shapes (Union
arms, unseedable sides) fall back to the host-side table joins.

Planner decisions are observable: when tracing is on, each block emits a
``planner.order`` span carrying the chosen order plus estimated-vs-actual
per-step cardinalities, and a ``planner.sip_pruned_lanes`` counter tallies
the lanes the SP/OP index pruned out of unbounded-``?p`` steps.  All of
it sits behind the usual ``obs.STATE`` ``None`` guards (tripwire-tested).
"""

from __future__ import annotations

import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import algebra, k2forest
from repro.core import delta as dyn
from repro.core.algebra import Table, TriplePattern
from repro.core.k2triples import K2TriplesStore
from repro.core.query import CapOverflow, ExecConfig

Term = Any  # int (bound id) | str '?var'

# DP join-order search is exhaustive up to this many patterns per block;
# larger blocks use the greedy order (search is O(2^n · n²))
DP_LIMIT = 8


def _is_var(t: Term) -> bool:
    return isinstance(t, str)


# ---------------------------------------------------------------------------
# cardinality model
# ---------------------------------------------------------------------------


def _candidate_preds(store: K2TriplesStore, s: Term, o: Term) -> np.ndarray | None:
    """0-based candidate predicates for an unbounded-?p pattern, or None
    when neither position is a bound in-range id (no pruning possible)."""
    bi = store.pred_index
    if bi is None:
        return None
    cand = None
    if not _is_var(s):
        cand = (
            bi.host_list(s - 1)
            if 1 <= s <= store.n_subjects
            else np.zeros(0, np.int32)
        )
    if not _is_var(o):
        op_list = (
            bi.host_list(store.n_subjects + o - 1)
            if 1 <= o <= store.n_objects
            else np.zeros(0, np.int32)
        )
        cand = op_list if cand is None else np.intersect1d(cand, op_list)
    return cand


def estimate_cardinality(store: K2TriplesStore, pat: TriplePattern) -> float:
    """Expected result size from per-predicate nnz + dictionary extents,
    predicate-pruned through the SP/OP index when ?p rides a bound s/o."""
    nnz = np.asarray(store.forest.nnz, np.float64)
    n_s = max(store.n_subjects, 1)
    n_o = max(store.n_objects, 1)
    if _is_var(pat.p):
        cand = _candidate_preds(store, pat.s, pat.o)
        total = float(nnz.sum()) if cand is None else float(nnz[cand].sum())
    else:
        total = float(nnz[pat.p - 1]) if 1 <= pat.p <= store.n_preds else 0.0
    sel = 1.0
    if not _is_var(pat.s):
        sel /= n_s
    if not _is_var(pat.o):
        sel /= n_o
    return max(total * sel, 1e-3)


def step_estimate(
    store: K2TriplesStore, pat: TriplePattern, bound_vars
) -> float:
    """Estimated per-row fanout of resolving ``pat`` when the variables in
    ``bound_vars`` already carry values: each bound position divides the
    stand-alone estimate by its dictionary extent (uniformity assumption —
    the same independence model :func:`estimate_cardinality` uses for
    constants)."""
    card = estimate_cardinality(store, pat)
    for term, extent in (
        (pat.s, store.n_subjects),
        (pat.p, store.n_preds),
        (pat.o, store.n_objects),
    ):
        if _is_var(term) and term in bound_vars:
            card /= max(extent, 1)
    return max(card, 1e-6)


# ---------------------------------------------------------------------------
# join-order search
# ---------------------------------------------------------------------------

# Per-op lane pricing: a pipeline step whose subject AND object are both
# realized at launch time (constants or already-bound variables) runs as
# OP_CHECK lanes — one fixed-depth traversal per lane, no frontier
# expansion, no cap-wide decode — while any step with a free s/o position
# runs OP_ROW/OP_COL scan lanes that expand a frontier and rake a cap-wide
# result window.  Microbenches put a check lane at roughly a quarter of a
# scan lane on both backends, and the exact ratio matters less than the
# *ordering* signal: a cheap check over many rows can beat a selective
# scan (see ``tests/test_planner.py::test_lane_pricing_flips_order``).
LANE_PRICE_CHECK = 0.25
LANE_PRICE_SCAN = 1.0


def step_lane_price(pat: TriplePattern, bound_vars) -> float:
    """Lane price of resolving ``pat`` against rows where ``bound_vars``
    carry values: check-shaped steps (s and o both realized — the
    ``_resolve_with_bindings`` existence-check branch, whether or not ?p
    is free) are cheap; anything with a free s/o position scans."""

    def realized(t: Term) -> bool:
        return (not _is_var(t)) or t in bound_vars

    if realized(pat.s) and realized(pat.o):
        return LANE_PRICE_CHECK
    return LANE_PRICE_SCAN


def greedy_order(
    store: K2TriplesStore, patterns: list[TriplePattern], bound0=frozenset()
) -> list[int]:
    """Greedy selectivity-ordered, connectivity-respecting plan.

    Ties on the estimated cost break by LOWEST PATTERN INDEX (the strict
    ``<`` keeps the first candidate): equal estimates are common on
    symmetric patterns, and a stable order keeps plan-cache keys and
    differential runs reproducible.
    """
    n = len(patterns)
    cards = [estimate_cardinality(store, p) for p in patterns]
    order: list[int] = []
    bound_vars = set(bound0)
    if not bound0:
        # seed: np.argmin returns the lowest index on ties
        order.append(int(np.argmin(cards)))
        bound_vars |= patterns[order[0]].variables
    while len(order) < n:
        best, best_card = None, float("inf")
        for i in range(n):
            if i in order:
                continue
            connected = bool(patterns[i].variables & bound_vars)
            # already-bound variables shrink the estimate sharply
            card = cards[i] / (10.0 if connected else 1.0)
            if not connected:
                card *= 1e6  # cartesian products last
            if card < best_card:
                best, best_card = i, card
        order.append(best)
        bound_vars |= patterns[best].variables
    return order


def order_cost(
    store: K2TriplesStore,
    patterns: list[TriplePattern],
    order,
    bound0=frozenset(),
    *,
    lane_pricing: bool = True,
) -> float:
    """Modelled cost of executing ``patterns`` in ``order``: the sum of
    estimated rows flowing INTO each step, each weighted by the step's
    per-op lane price (:func:`step_lane_price` — check lanes cost a
    fraction of scan lanes; ``lane_pricing=False`` restores the uniform
    rows-only model for comparison).  The first unseeded step has no
    input rows; its cost is its own enumeration (estimated output,
    unpriced).  The final result cardinality is deliberately NOT counted:
    it is order-invariant in reality, but its *estimate* is
    order-sensitive, and letting it into the objective biases the search
    toward orders that merely under-estimate it."""
    bound = set(bound0)
    rows = 1.0
    cost = 0.0
    for k, i in enumerate(order):
        rows_in = rows
        price = step_lane_price(patterns[i], bound) if lane_pricing else 1.0
        rows *= step_estimate(store, patterns[i], bound)
        cost += rows if (k == 0 and not bound0) else rows_in * price
        bound |= patterns[i].variables
    return cost


def cost_order(
    store: K2TriplesStore, patterns: list[TriplePattern], bound0=frozenset(),
    *, lane_pricing: bool = True,
) -> list[int]:
    """Cost-based join order: exhaustive bitmask DP for blocks of ≤
    :data:`DP_LIMIT` patterns minimizing :func:`order_cost` (including
    its per-op lane pricing — the DP transition and :func:`order_cost`
    MUST price identically or the search optimizes the wrong objective);
    greedy beyond.  Cost ties break lexicographically by order tuple,
    i.e. by pattern index — same determinism contract as
    :func:`greedy_order`."""
    n = len(patterns)
    if n > DP_LIMIT:
        return greedy_order(store, patterns, bound0)
    # best[mask] = (cost, rows, order): cheapest way to have joined `mask`
    best: dict[int, tuple[float, float, tuple[int, ...]]] = {}
    for i in range(n):
        rows = step_estimate(store, patterns[i], bound0) if bound0 else (
            estimate_cardinality(store, patterns[i])
        )
        price = step_lane_price(patterns[i], bound0) if lane_pricing else 1.0
        # first-step cost mirrors order_cost: its enumeration when
        # unseeded, one (constant, priced) seeded launch otherwise
        best[1 << i] = (rows if not bound0 else price, rows, (i,))
    full = (1 << n) - 1
    for mask in range(1, full + 1):
        cur = best.get(mask)
        if cur is None:
            continue
        cost, rows, order = cur
        bound = set(bound0)
        for i in order:
            bound |= patterns[i].variables
        for j in range(n):
            bit = 1 << j
            if mask & bit:
                continue
            price = step_lane_price(patterns[j], bound) if lane_pricing else 1.0
            nrows = rows * step_estimate(store, patterns[j], bound)
            # lane-work model: the step costs its INPUT rows (launch
            # lanes) times its lane price, not its estimated output —
            # see order_cost
            cand = (cost + rows * price, nrows, order + (j,))
            prev = best.get(mask | bit)
            if prev is None or (cand[0], cand[2]) < (prev[0], prev[2]):
                best[mask | bit] = cand
    return list(best[full][2])


# ---------------------------------------------------------------------------
# one-pattern resolution (shared with the optimizer shims)
# ---------------------------------------------------------------------------


def _ragged_take(starts: np.ndarray, deg: np.ndarray):
    """Expand ragged rows: flat element indices ``starts[i] + j`` for
    ``j < deg[i]``, plus the owning row of each element."""
    row_idx = np.repeat(np.arange(deg.shape[0]), deg)
    within = np.arange(int(deg.sum())) - np.repeat(np.cumsum(deg) - deg, deg)
    return row_idx, np.repeat(starts, deg) + within


def _ragged_candidates(store: K2TriplesStore, keys: np.ndarray, axis: int):
    """Per-row candidate predicates from the SP (axis 0) / OP (axis 1) index.

    keys: int64[n] 1-based subject/object ids.  Returns ``(row_idx, cand)``
    — the flat (row, candidate) launch layout: candidate ``cand[j]``
    (0-based) belongs to binding row ``row_idx[j]``.
    """
    bi = store.pred_index
    if bi is None:  # index-free fallback: every predicate for every row
        n_rows = keys.shape[0]
        P = dyn.total_preds(store)
        return (
            np.repeat(np.arange(n_rows), P),
            np.tile(np.arange(P, dtype=np.int64), n_rows),
        )
    offs = bi.host_offsets
    n_ent = store.n_subjects if axis == 0 else store.n_objects
    base = 0 if axis == 0 else store.n_subjects
    rows = base + np.clip(keys - 1, 0, max(n_ent - 1, 0))
    in_range = (keys >= 1) & (keys <= n_ent)
    start = np.where(in_range, offs[rows], 0)
    deg = np.where(in_range, offs[rows + 1] - offs[rows], 0)
    row_idx, elem = _ragged_take(start, deg)
    cand = bi.host_preds[elem].astype(np.int64)
    snap = dyn.snapshot_of(store)
    if snap is not None:
        # the static SP/OP index knows nothing about recent inserts: union
        # each row's delta predicates from the snapshot's per-entity bitmap
        pm = snap.s_preds if axis == 0 else snap.o_preds
        extra_r: list[int] = []
        extra_c: list[np.ndarray] = []
        for i, k in enumerate(np.asarray(keys).tolist()):
            ps = pm.preds_of(int(k))
            if ps.size:
                extra_r.extend([i] * ps.size)
                extra_c.append(ps - 1)  # candidates are 0-based
        if extra_r:
            row_idx = np.concatenate([row_idx, np.asarray(extra_r)])
            cand = np.concatenate([cand, np.concatenate(extra_c)])
            big = np.int64(dyn.total_preds(store) + 1)
            uk = np.unique(row_idx * big + cand)  # dedup, (row, cand) order
            row_idx, cand = uk // big, uk % big
    return row_idx, cand


def _resolve_with_bindings(
    store, pat, bindings: dict[str, np.ndarray], cap: int,
    backend=None, serve=None, stats: dict | None = None,
):
    """Resolve one pattern given current bindings -> columnar solution arrays.

    Chooses the cheapest realization: check / row scan / col scan /
    pair enumeration, batched over existing binding rows; an unbounded ?p
    with a bound s/o position resolves over index-pruned candidates in ONE
    flat launch.

    ``backend`` threads to the traversals (ExecConfig / string / None —
    see ``k2forest.scan_batch_mixed``).  ``serve`` is an optional serve-IR
    lane runner ``(ops, s, p, o) -> ServeResult`` (the engine's pooled
    compiled ``serve_step``); when given, check and bounded-scan steps run
    through it instead of raw ``k2forest`` launches, so an n-pattern BGP
    shares the programs (and their jit cache) with every other plan.

    ``stats`` (optional dict) accumulates planner observability counts —
    currently ``sip_pruned_lanes``: how many (row, predicate) lanes the
    SP/OP index pruned out of unbounded-``?p`` steps versus the
    every-predicate fallback.
    """
    meta, f = store.meta, store.forest
    view = dyn.view_of(store)
    if view is not None and serve is None:
        # no pooled engine runner handed in: synthesize a raw-launch runner
        # so the delta sanitize+merge still wraps every check/scan lane
        serve = _dyn_raw_runner(store, view, cap, backend)
    P_tot = dyn.total_preds(store)
    n_rows = len(next(iter(bindings.values()))) if bindings else 1
    pvar = _is_var(pat.p)

    def col(term, default):
        if _is_var(term) and term in bindings:
            return bindings[term].astype(np.int64), True
        if not _is_var(term):
            return np.full(n_rows, term, np.int64), True
        return np.full(n_rows, default, np.int64), False

    p_free = pvar and pat.p not in bindings
    s_arr, s_bound = col(pat.s, 1)
    o_arr, o_bound = col(pat.o, 1)
    p_arr, _ = col(pat.p, 1)
    out_cols: dict[str, list] = {v: [] for v in set(bindings) | pat.variables}

    def note_pruned(row_idx):
        if stats is not None and store.pred_index is not None:
            stats["sip_pruned_lanes"] = stats.get("sip_pruned_lanes", 0) + (
                n_rows * store.n_preds - int(row_idx.shape[0])
            )

    def emit(rows, cols_list):
        """Keep binding rows ``rows`` and append the new columns.

        ``cols_list`` is positional ``(term, values)`` pairs; a variable
        repeated across positions of ONE pattern (e.g. ``(S, ?b, ?b)``)
        contributes several columns and only rows where they agree survive.
        """
        new: dict[str, np.ndarray] = {}
        keep = np.ones(rows.shape[0], np.bool_)
        for term, vals in cols_list:
            if not _is_var(term) or term in bindings:
                continue
            vals = np.asarray(vals, np.int64)
            if term in new:
                keep &= new[term] == vals
            else:
                new[term] = vals
        rows = rows[keep]
        for v in bindings:
            out_cols[v].append(bindings[v][rows])
        for var, vals in new.items():
            out_cols[var].append(vals[keep])

    def finish():
        return {
            v: (np.concatenate(cs) if cs else np.zeros(0, np.int64))
            for v, cs in out_cols.items()
        }

    if s_bound and o_bound:  # existence check (maybe per candidate pred)
        if p_free:
            # SP(s) candidates (either index half prunes; SP keys the check)
            row_idx, cand = _ragged_candidates(store, s_arr, 0)
            note_pruned(row_idx)
        else:
            row_idx, cand = np.arange(n_rows), p_arr - 1
        # a binding value re-used in predicate position may be out of range
        ok = (cand >= 0) & (cand < P_tot)
        if serve is not None:
            from repro.core import engine as _eng

            r = serve(
                np.where(ok, _eng.OP_CHECK, -1),
                s_arr[row_idx], np.where(ok, cand + 1, 0), o_arr[row_idx],
            )
            hit = np.asarray(r.hit) & ok
        else:
            hit = np.asarray(
                k2forest.check(
                    meta, f, jnp.asarray(np.where(ok, cand, 0)),
                    jnp.asarray(s_arr[row_idx] - 1),
                    jnp.asarray(o_arr[row_idx] - 1),
                )
            ) & ok
        keep = np.nonzero(hit)[0]
        emit(row_idx[keep], [(pat.p, cand[keep] + 1)])
        return finish()

    if s_bound or o_bound:  # one free s/o position -> batched scan
        axis = 0 if s_bound else 1
        key_arr = s_arr if s_bound else o_arr
        if p_free:
            row_idx, cand = _ragged_candidates(store, key_arr, axis)
            note_pruned(row_idx)
        else:
            row_idx, cand = np.arange(n_rows), p_arr - 1
        if row_idx.size == 0:  # no candidates anywhere: empty result
            emit(row_idx, [])
            return finish()
        ok = (cand >= 0) & (cand < P_tot)
        if serve is not None:
            from repro.core import engine as _eng

            op = _eng.OP_ROW if axis == 0 else _eng.OP_COL
            keys = key_arr[row_idx]
            r = serve(
                np.where(ok, op, -1),
                keys if axis == 0 else np.zeros_like(keys),
                np.where(ok, cand + 1, 0),
                keys if axis == 1 else np.zeros_like(keys),
            )
            if bool((np.asarray(r.overflow) & ok).any()):
                raise CapOverflow("BGP scan truncated at cap")
            ids = np.asarray(r.ids)  # serve ids are already 1-based
        else:
            r = k2forest.scan_batch_mixed(
                meta, f, jnp.asarray(np.where(ok, cand, 0)),
                jnp.asarray(key_arr[row_idx] - 1),
                jnp.full(row_idx.shape, axis, jnp.int32), cap, backend,
            )
            if bool((np.asarray(r.overflow) & ok).any()):
                raise CapOverflow("BGP scan truncated at cap")
            ids = np.asarray(r.ids) + 1
        lanes, slots = np.nonzero(np.asarray(r.valid) & ok[:, None])
        rows = row_idx[lanes]
        emit(rows, [
            (pat.p, cand[lanes] + 1),
            (pat.o if s_bound else pat.s, ids[lanes, slots]),
        ])
        return finish()

    # neither s nor o realized: enumerate candidate triples by range scan
    # and cross-product with the binding rows (cartesian steps land here)
    upreds = (
        np.arange(1, P_tot + 1, dtype=np.int64)
        if p_free
        else np.unique(np.clip(p_arr, 1, P_tot))
    )
    if view is None:
        pr = k2forest.range_scan_batch(
            meta, f, jnp.asarray(upreds - 1), cap, backend
        )
        if bool(np.asarray(pr.overflow).any()):
            raise CapOverflow("BGP pair enumeration truncated at cap")
        pv = np.asarray(pr.valid)
        prow, pcol = np.asarray(pr.rows) + 1, np.asarray(pr.cols) + 1
        counts = pv.sum(axis=1)
        pair_p = np.repeat(upreds, counts)
        lanes, slots = np.nonzero(pv)
        pair_s, pair_o = prow[lanes, slots], pcol[lanes, slots]
    else:
        # dynamic: scan only the static trees, then merge each predicate's
        # pair list through the snapshot — keeping pair_p grouped in
        # ascending predicate order for the searchsorted below
        sta = upreds[upreds <= view.preds_static]
        per: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        if sta.size:
            pr = k2forest.range_scan_batch(
                meta, f, jnp.asarray(sta - 1), cap, backend
            )
            if bool(np.asarray(pr.overflow).any()):
                raise CapOverflow("BGP pair enumeration truncated at cap")
            pv = np.asarray(pr.valid)
            prow, pcol = np.asarray(pr.rows) + 1, np.asarray(pr.cols) + 1
            for i, p in enumerate(sta.tolist()):
                per[p] = (
                    prow[i][pv[i]].astype(np.int64),
                    pcol[i][pv[i]].astype(np.int64),
                )
        empty = np.empty(0, np.int64)
        pp, ps, po = [], [], []
        for p in upreds.tolist():
            ss, oo = per.get(p, (empty, empty))
            ss, oo = view.snap.merge_pairs(int(p), ss, oo)
            if len(ss):
                pp.append(np.full(len(ss), p, np.int64))
                ps.append(np.asarray(ss, np.int64))
                po.append(np.asarray(oo, np.int64))
        pair_p = np.concatenate(pp) if pp else empty
        pair_s = np.concatenate(ps) if ps else empty
        pair_o = np.concatenate(po) if po else empty
    if p_free:
        n_pairs = pair_p.shape[0]
        rows = np.repeat(np.arange(n_rows), n_pairs)
        sel = np.tile(np.arange(n_pairs), n_rows)
    else:  # row i may only use pairs of ITS predicate value
        starts = np.searchsorted(pair_p, p_arr)
        deg = np.searchsorted(pair_p, p_arr, side="right") - starts
        rows, sel = _ragged_take(starts, deg)
    emit(rows, [
        (pat.p, pair_p[sel]), (pat.s, pair_s[sel]), (pat.o, pair_o[sel]),
    ])
    return finish()


def _pattern_holds(store: K2TriplesStore, pat: TriplePattern) -> bool:
    """Ground (variable-free) pattern: does the triple exist?"""
    snap = dyn.snapshot_of(store)
    if snap is not None:
        if snap.contains(pat.s, pat.p, pat.o):
            return True
        if snap.tomb_contains(pat.s, pat.p, pat.o):
            return False
        if pat.s > store.n_subjects or pat.o > store.n_objects:
            return False  # appended-range id the static forest cannot hold
    if not (1 <= pat.p <= store.n_preds):
        return False
    return bool(
        np.asarray(
            k2forest.check(
                store.meta, store.forest, jnp.asarray([pat.p - 1]),
                jnp.asarray([pat.s - 1]), jnp.asarray([pat.o - 1]),
            )
        )[0]
    )


def _dyn_raw_runner(store, view, cap: int, backend):
    """Serve-shaped CHECK/ROW/COL lane runner over raw ``k2forest``
    launches, wrapped in the delta sanitize+merge — the fallback used when
    :func:`_resolve_with_bindings` is called on a dynamic store without a
    pooled engine runner."""
    from repro.core import engine as _eng

    meta, f = store.meta, store.forest

    def run(ops, s, p, o):
        ops0 = np.asarray(ops, np.int32).reshape(-1)
        s = np.asarray(s, np.int64).reshape(-1)
        p = np.asarray(p, np.int64).reshape(-1)
        o = np.asarray(o, np.int64).reshape(-1)
        ops_r = view.sanitize_ops(ops0, s, p, o)
        b = ops_r.shape[0]
        hit = np.zeros(b, np.bool_)
        ids = np.zeros((b, cap), np.int32)
        valid = np.zeros((b, cap), np.bool_)
        count = np.zeros(b, np.int32)
        ovf = np.zeros(b, np.bool_)
        is_chk = ops_r == _eng.OP_CHECK
        if is_chk.any():
            hh = np.asarray(
                k2forest.check(
                    meta, f,
                    jnp.asarray(np.where(is_chk, p - 1, 0)),
                    jnp.asarray(np.where(is_chk, s - 1, 0)),
                    jnp.asarray(np.where(is_chk, o - 1, 0)),
                )
            )
            hit = hh & is_chk
        is_scan = (ops_r == _eng.OP_ROW) | (ops_r == _eng.OP_COL)
        if is_scan.any():
            axis = (ops_r == _eng.OP_COL).astype(np.int32)
            key = np.where(axis == 1, o, s)
            r = k2forest.scan_batch_mixed(
                meta, f,
                jnp.asarray(np.where(is_scan, p - 1, 0)),
                jnp.asarray(np.where(is_scan, key - 1, 0)),
                jnp.asarray(axis), cap, backend,
            )
            rv = np.asarray(r.valid) & is_scan[:, None]
            ids = np.where(rv, np.asarray(r.ids) + 1, 0).astype(np.int32)
            valid = rv
            count = rv.sum(axis=1).astype(np.int32)
            ovf = np.asarray(r.overflow) & is_scan
        res = _eng.ServeResult(
            hit=hit, ids=ids, valid=valid, count=count, overflow=ovf,
            u_preds=np.zeros((b, 0), np.int32),
            u_ids=np.zeros((b, 0, cap), np.int32),
            u_valid=np.zeros((b, 0, cap), np.bool_),
            u_count=np.zeros((b, 0), np.int32),
        )
        return view.merge_lanes(ops0, s, p, o, res)

    return run


# ---------------------------------------------------------------------------
# block + tree execution
# ---------------------------------------------------------------------------


def _n_rows(bindings: dict[str, np.ndarray]) -> int:
    return len(next(iter(bindings.values()))) if bindings else 0


def _run_block(
    store, patterns, seed: Table | None, *, cap, exec_, serve,
    order_override=None,
):
    """Execute one conjunctive block as a SIP pipeline -> Table (multiset).

    ``seed`` carries bindings from an already-evaluated left side: its
    columns become the initial binding table and every pattern resolves
    against them (sideways information passing).  Without a seed the
    cheapest pattern is resolved stand-alone first.  Ground patterns are
    pure existence prefilters.  ``order_override`` (indices into the
    variable-carrying patterns) bypasses the cost search — the benchmark
    hook for comparing strategies on identical machinery.
    """
    ground = [p for p in patterns if not p.variables]
    live = [p for p in patterns if p.variables]
    out_vars = sorted(
        set().union(set(seed.cols) if seed is not None else set(),
                    *(p.variables for p in live))
    )
    if any(not _pattern_holds(store, g) for g in ground):
        return Table.empty(out_vars)
    if not live:
        return Table(dict(seed.cols), seed.n) if seed is not None else Table.unit()

    bound0 = frozenset(seed.cols) if seed is not None else frozenset()
    if order_override is not None:
        order = list(order_override)
    else:
        order = cost_order(store, live, bound0)

    tracer = obs.STATE.tracer
    metrics = obs.STATE.metrics
    stats: dict | None = (
        {} if (metrics is not None or tracer is not None) else None
    )
    t0 = time.perf_counter_ns() if tracer is not None else 0
    estimated: list[float] = []
    if tracer is not None:
        rows_est = 1.0
        bound = set(bound0)
        for i in order:
            rows_est *= step_estimate(store, live[i], bound)
            estimated.append(round(rows_est, 3))
            bound |= live[i].variables

    actual: list[int] = []
    bindings = {v: c for v, c in seed.cols.items()} if seed is not None else {}
    empty = False
    for k, idx in enumerate(order):
        if k == 0 and seed is None:
            bindings = _resolve_with_bindings(
                store, live[idx], {}, cap, exec_, serve, stats=stats
            )
            bindings = {
                v: a for v, a in bindings.items() if v in live[idx].variables
            }
        else:
            if _n_rows(bindings) == 0:
                empty = True
                break
            bindings = _resolve_with_bindings(
                store, live[idx], bindings, cap, exec_, serve, stats=stats
            )
        actual.append(_n_rows(bindings))

    if tracer is not None:
        tracer.add(
            "planner.order", t0, time.perf_counter_ns(), cat="planner",
            order=list(order), estimated=estimated, actual=actual,
            seeded=seed is not None, patterns=len(live),
        )
    if metrics is not None and stats and stats.get("sip_pruned_lanes"):
        metrics.counter("planner.sip_pruned_lanes").inc(
            stats["sip_pruned_lanes"]
        )

    if empty:
        return Table.empty(out_vars)
    return Table.from_bindings(bindings)


def _conjuncts(expr) -> list:
    """Flatten a top-level ``And`` chain into its conjunct list."""
    if isinstance(expr, algebra.And):
        return _conjuncts(expr.a) + _conjuncts(expr.b)
    return [expr]


def _conjoin(conjuncts: list):
    out = conjuncts[0]
    for c in conjuncts[1:]:
        out = algebra.And(out, c)
    return out


def push_filters(node):
    """Rewrite an algebra tree, pushing safe conjunctive FILTERs down.

    Two rules, applied bottom-up until fixpoint:

      * ``Filter(c, LeftJoin(a, b))`` -> ``LeftJoin(Filter(c, a), b)`` for
        each conjunct ``c`` whose variables are all bound by ``a`` (the
        required side).  Safe because an OPTIONAL match never changes the
        left side's own columns — filtering before the join drops exactly
        the rows the outer filter would have dropped, matched or not.
      * ``Filter(c, Union(a, b))`` -> ``Union(Filter(c, a), Filter(c, b))``
        for each conjunct scoped inside BOTH arms (conservative: a conjunct
        mentioning a variable only one arm binds stays above the union).

    Conjuncts that don't qualify stay in a residual filter above the node.
    Pure rewrite — the differential tests check result equivalence.
    """
    if isinstance(node, algebra.Filter):
        child = push_filters(node.child)
        conjuncts = _conjuncts(node.expr)
        if isinstance(child, algebra.LeftJoin):
            lvars = algebra.node_vars(child.left)
            down = [c for c in conjuncts if algebra.expr_vars(c) <= lvars]
            stay = [c for c in conjuncts if not algebra.expr_vars(c) <= lvars]
            if down:
                child = algebra.LeftJoin(
                    push_filters(algebra.Filter(_conjoin(down), child.left)),
                    child.right,
                )
            return algebra.Filter(_conjoin(stay), child) if stay else child
        if isinstance(child, algebra.Union):
            avars = algebra.node_vars(child.left)
            bvars = algebra.node_vars(child.right)
            down = [
                c for c in conjuncts
                if algebra.expr_vars(c) <= avars
                and algebra.expr_vars(c) <= bvars
            ]
            stay = [c for c in conjuncts if c not in down]
            if down:
                e = _conjoin(down)
                child = algebra.Union(
                    push_filters(algebra.Filter(e, child.left)),
                    push_filters(algebra.Filter(e, child.right)),
                )
            return algebra.Filter(_conjoin(stay), child) if stay else child
        return algebra.Filter(node.expr, child)
    if isinstance(node, algebra.Join):
        return algebra.Join(push_filters(node.left), push_filters(node.right))
    if isinstance(node, algebra.LeftJoin):
        return algebra.LeftJoin(
            push_filters(node.left), push_filters(node.right)
        )
    if isinstance(node, algebra.Union):
        return algebra.Union(push_filters(node.left), push_filters(node.right))
    if isinstance(node, algebra.Project):
        return algebra.Project(push_filters(node.child), node.vars)
    if isinstance(node, algebra.Slice):
        return algebra.Slice(
            push_filters(node.child), node.order_by, node.limit, node.offset
        )
    return node


def _seedable(left: Table, patterns) -> bool:
    """A block can consume ``left`` as SIP seed when every shared variable
    column is fully bound — an UNBOUND (0) value is a compat-join
    wildcard, which the keyed serve lanes cannot express."""
    pat_vars = set().union(*(p.variables for p in patterns)) if patterns else set()
    return all(
        bool((c != algebra.UNBOUND).all())
        for v, c in left.cols.items()
        if v in pat_vars
    )


def execute(
    store: K2TriplesStore, node, *, cap: int = 2048,
    exec_: ExecConfig | str | None = None, serve=None, order_override=None,
) -> Table:
    """Evaluate an algebra tree to a solution :class:`Table` (multiset —
    final semantics, DISTINCT included, are applied by ``Project`` /
    ``Slice`` nodes or by the caller via ``algebra.project_named``).

    Conjunctive regions run as cost-ordered SIP pipelines over the serve
    IR (see :func:`_run_block`); ``LeftJoin``/``Join`` sides that flatten
    to a BGP are seeded with the left result so they reuse the same
    pooled launches; everything else evaluates on host tables.
    ``order_override`` threads to root-level block execution only (the
    benchmark hook).
    """
    kw = dict(cap=cap, exec_=exec_, serve=serve)
    node = push_filters(node)

    def ev(n, override=None):
        if isinstance(n, (algebra.Scan, algebra.Join)):
            flat = algebra.flatten_bgp(n)
            if flat is not None:
                return _run_block(store, flat, None, order_override=override, **kw)
        if isinstance(n, algebra.Join):
            left = ev(n.left)
            rflat = algebra.flatten_bgp(n.right)
            if rflat is not None:
                if left.n == 0:
                    return Table.empty(
                        sorted(set(left.cols) | algebra.node_vars(n.right))
                    )
                if _seedable(left, rflat):
                    return _run_block(store, rflat, left, **kw)
            right = ev(n.right)
            return algebra.join_tables(left, right)
        if isinstance(n, algebra.LeftJoin):
            left = ev(n.left)
            rvars = algebra.node_vars(n.right)
            if left.n == 0:
                return Table.empty(sorted(set(left.cols) | rvars))
            rflat = algebra.flatten_bgp(n.right)
            if rflat is not None and _seedable(left, rflat):
                rowid = "?__ljrow"
                seed = Table(
                    {**left.cols, rowid: np.arange(left.n, dtype=np.int64)},
                    left.n,
                )
                j = _run_block(store, rflat, seed, **kw)
                matched = np.zeros(left.n, np.bool_)
                if j.n:
                    matched[j.cols[rowid]] = True
                miss = np.nonzero(~matched)[0]
                cols = {}
                for v in j.cols:
                    if v == rowid:
                        continue
                    pad = (
                        left.cols[v][miss]
                        if v in left.cols
                        else np.full(miss.shape[0], algebra.UNBOUND, np.int64)
                    )
                    cols[v] = np.concatenate([j.cols[v], pad])
                return Table(cols, j.n + int(miss.shape[0]))
            right = ev(n.right)
            return algebra.left_join_tables(left, right)
        if isinstance(n, algebra.Union):
            return algebra.union_tables(ev(n.left), ev(n.right))
        if isinstance(n, algebra.Filter):
            t = ev(n.child)
            scope = algebra.node_vars(n.child)
            val, err = algebra.eval_expr(n.expr, t, scope)
            return t.take(np.nonzero(val & ~err)[0])
        if isinstance(n, algebra.Project):
            t = ev(n.child)
            cols = {
                v: t.cols.get(v, np.full(t.n, algebra.UNBOUND, np.int64))
                for v in n.vars
            }
            return algebra.distinct(Table(cols, t.n))
        if isinstance(n, algebra.Slice):
            return algebra.sort_slice(
                ev(n.child), n.order_by, n.limit, n.offset
            )
        raise TypeError(f"not an algebra node: {n!r}")

    return ev(node, order_override)
