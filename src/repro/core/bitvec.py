"""Packed bit vectors with O(1) rank — the k²-tree storage primitive.

The paper stores the tree as plain bit arrays T and L navigated with
``rank1``.  On TPU we pack bits LSB-first into ``uint32`` words and keep a
per-word exclusive cumulative popcount (``rank_blocks``) so that

    rank1(p) = rank_blocks[p >> 5] + popcount(word[p >> 5] & ((1 << (p & 31)) - 1))

is a gather + integer ALU op — fully vectorizable on the VPU.

Host-side construction is numpy; query-side helpers are jnp and are used by
both the pure-JAX reference paths and as oracles for the Pallas kernels.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


class BitVec(NamedTuple):
    """A packed bit vector plus rank acceleration structure.

    Attributes:
      words:       uint32[n_words]  bits, LSB-first within each word.
      rank_blocks: int32[n_words]   exclusive cumulative popcount per word.
      n_bits:      int              logical length (python int, static).
    """

    words: jax.Array
    rank_blocks: jax.Array
    n_bits: int


# ---------------------------------------------------------------------------
# host-side (numpy) construction
# ---------------------------------------------------------------------------


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """Pack a {0,1} uint8 array into uint32 words, LSB-first."""
    bits = np.asarray(bits, dtype=np.uint8)
    n = bits.shape[0]
    n_words = max(1, (n + WORD_BITS - 1) // WORD_BITS)
    padded = np.zeros(n_words * WORD_BITS, dtype=np.uint64)
    padded[:n] = bits
    lanes = padded.reshape(n_words, WORD_BITS)
    weights = (np.uint64(1) << np.arange(WORD_BITS, dtype=np.uint64))
    return (lanes * weights).sum(axis=1).astype(np.uint32)


def rank_blocks_np(words: np.ndarray) -> np.ndarray:
    """Exclusive cumulative popcount per word (int32)."""
    pops = popcount_np(words)
    out = np.zeros_like(pops, dtype=np.int64)
    np.cumsum(pops[:-1], out=out[1:])
    return out.astype(np.int32)


def popcount_np(words: np.ndarray) -> np.ndarray:
    w = words.astype(np.uint32)
    w = w - ((w >> np.uint32(1)) & np.uint32(0x55555555))
    w = (w & np.uint32(0x33333333)) + ((w >> np.uint32(2)) & np.uint32(0x33333333))
    w = (w + (w >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((w * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.int32)


def bitvec_from_bits(bits: np.ndarray) -> BitVec:
    words = pack_bits_np(bits)
    return BitVec(
        words=jnp.asarray(words),
        rank_blocks=jnp.asarray(rank_blocks_np(words)),
        n_bits=int(bits.shape[0]),
    )


# ---------------------------------------------------------------------------
# device-side (jnp) queries — vectorized over arbitrary index shapes
# ---------------------------------------------------------------------------


def get_bit(words: jax.Array, pos: jax.Array) -> jax.Array:
    """bit value at position(s) ``pos`` (int32) -> int32 {0,1}.

    Out-of-range positions are clamped by jnp.take's default mode; callers
    must mask invalid lanes themselves.
    """
    word = jnp.take(words, pos >> 5, mode="clip")
    return ((word >> (pos & 31).astype(jnp.uint32)) & 1).astype(jnp.int32)


def rank1(words: jax.Array, rank_blocks: jax.Array, pos: jax.Array) -> jax.Array:
    """Number of set bits strictly before ``pos`` (vectorized)."""
    widx = pos >> 5
    base = jnp.take(rank_blocks, widx, mode="clip")
    word = jnp.take(words, widx, mode="clip")
    mask = (jnp.uint32(1) << (pos & 31).astype(jnp.uint32)) - jnp.uint32(1)
    return base + jax.lax.population_count(word & mask).astype(jnp.int32)


def get_bit_2d(words2d: jax.Array, row: jax.Array, pos: jax.Array) -> jax.Array:
    """get_bit over a (P, W) padded word arena: row selects the tree."""
    word = words2d[row, jnp.clip(pos >> 5, 0, words2d.shape[-1] - 1)]
    return ((word >> (pos & 31).astype(jnp.uint32)) & 1).astype(jnp.int32)


def rank1_2d(
    words2d: jax.Array, rank2d: jax.Array, row: jax.Array, pos: jax.Array
) -> jax.Array:
    widx = jnp.clip(pos >> 5, 0, words2d.shape[-1] - 1)
    base = rank2d[row, widx]
    word = words2d[row, widx]
    mask = (jnp.uint32(1) << (pos & 31).astype(jnp.uint32)) - jnp.uint32(1)
    return base + jax.lax.population_count(word & mask).astype(jnp.int32)
