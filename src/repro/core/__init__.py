# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public query surface: `repro.core.query` (Query descriptions +
# ExecConfig + Plan) and `repro.core.engine.Engine.compile` — see
# EXPERIMENTS.md §"The query API". Re-exported lazily to keep
# `import repro.core` free of jax initialization.


def __getattr__(name):
    if name in (
        "ExecConfig", "ObsConfig", "CapPolicy", "CapOverflow", "Plan",
        "TriplePatternQ", "JoinQ", "BgpQ", "ServeQ",
    ):
        from repro.core import query

        return getattr(query, name)
    if name == "Engine":
        from repro.core.engine import Engine

        return Engine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
