"""The paper's eight SPARQL triple patterns on k²-tree primitives.

Every function takes 1-based IDs (the paper's dictionary space) and returns
1-based IDs inside fixed-shape ``QueryResult`` / ``PairResult`` contracts
(ids, valid-mask, count, overflow) so the whole pattern layer is jit-able.

Pattern -> primitive map (paper §k²-triples):

  (S, P, O)     cell check on the P-th tree            -> ``spo``
  (S, ?P, O)    cell check on every tree               -> ``s_any_o``
  (S, P, ?O)    row scan (direct neighbors), sorted    -> ``sp_any``
  (S, ?P, ?O)   row scan on every tree                 -> ``s_any_any``
  (?S, P, O)    column scan (reverse neighbors)        -> ``any_po``
  (?S, ?P, O)   column scan on every tree              -> ``any_any_o``
  (?S, P, ?O)   full range scan of one tree            -> ``any_p_any``
  (?S, ?P, ?O)  range scan on every tree (dump)        -> ``dump``

The three unbounded-``?P`` entries (``s_any_o`` / ``s_any_any`` /
``any_any_o``) additionally accept a k²-triples+ SP/OP predicate index
(``index=`` + ``pmeta=``, see ``core/predindex.py``): candidates are then
gathered from the index and only those trees are touched — the pruned
layout (``s_any_any`` / ``any_any_o`` return a ``PredScanResult`` whose
axis 0 is the CANDIDATE slot, with ``preds`` naming each slot's predicate;
``s_any_o`` returns the matching predicates as a ``QueryResult`` list).
Without an index the all-preds sweep runs (the differential reference):
per-predicate layouts with axis 0 = predicate, exactly the paper's shapes.

Execution knobs: every routed function's ``backend`` parameter accepts an
``ExecConfig`` (``core.query``) — the compiled-plan path threads one
through, so no environment flag is consulted — or a legacy "pallas"/"jnp"
string / ``None`` (per-call env resolution).  The serving hot path no
longer lives here: ``Engine.compile`` lowers patterns straight to the
serve IR; these functions remain the per-primitive reference surface
(and back the (?S,P,?O) / dump plan shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import k2forest, predindex
from repro.core.k2forest import K2Forest
from repro.core.k2tree import K2Meta, PairResult, QueryResult
from repro.core.predindex import PredScanResult


def _ids(res: QueryResult) -> QueryResult:
    """Shift 0-based matrix coordinates back to 1-based dictionary IDs."""
    return res._replace(ids=jnp.where(res.valid, res.ids + 1, 0))


def _pairs(res: PairResult) -> PairResult:
    return res._replace(
        rows=jnp.where(res.valid, res.rows + 1, 0),
        cols=jnp.where(res.valid, res.cols + 1, 0),
    )


def spo(meta: K2Meta, f: K2Forest, s, p, o) -> jax.Array:
    """(S, P, O) -> bool[...] (batched over leading dims of s/p/o)."""
    s, p, o = (jnp.asarray(x, jnp.int32) for x in (s, p, o))
    return k2forest.check(meta, f, p - 1, s - 1, o - 1)


def s_any_o(meta: K2Meta, f: K2Forest, s, o, backend: str | None = None,
            *, index=None, pmeta=None, u_width: int | None = None):
    """(S, ?P, O) -> bool[P]; index i <-> predicate i+1.

    With ``index``: only the subject's SP candidates are checked and the
    MATCHING predicate ids (1-based, ascending) come back as a
    ``QueryResult`` — same information, pruned layout.
    """
    s, o = jnp.asarray(s, jnp.int32), jnp.asarray(o, jnp.int32)
    if index is None:
        return k2forest.check_all_preds(meta, f, s - 1, o - 1)
    r = predindex.check_pruned_batch(
        meta, f, pmeta, index, jnp.reshape(s - 1, (1,)),
        jnp.reshape(o - 1, (1,)), u_width or max(pmeta.max_degree, 1), backend,
    )
    r = jax.tree.map(lambda x: x[0], r)
    return _ids(r)


def sp_any(meta: K2Meta, f: K2Forest, s, p, cap: int,
           backend: str | None = None) -> QueryResult:
    """(S, P, ?O) -> object IDs, ascending (merge-join ready)."""
    s, p = jnp.asarray(s, jnp.int32), jnp.asarray(p, jnp.int32)
    return _ids(k2forest.row_scan(meta, f, p - 1, s - 1, cap, backend))


def _pruned_one(meta, f, pmeta, index, key, axis: int, cap: int,
                u_width: int | None, backend) -> PredScanResult:
    """Single-query pruned unbounded scan, shifted to 1-based ids."""
    r = predindex.scan_pruned_batch(
        meta, f, pmeta, index, jnp.reshape(key, (1,)),
        jnp.full((1,), axis, jnp.int32), cap,
        u_width or max(pmeta.max_degree, 1), backend,
    )
    r = jax.tree.map(lambda x: x[0], r)
    return r._replace(
        preds=jnp.where(r.pvalid, r.preds + 1, 0),
        ids=jnp.where(r.valid, r.ids + 1, 0),
    )


def s_any_any(meta: K2Meta, f: K2Forest, s, cap: int,
              backend: str | None = None, *, index=None, pmeta=None,
              u_width: int | None = None):
    """(S, ?P, ?O) -> per-predicate object lists (axis 0 = predicate).

    With ``index``: axis 0 becomes the CANDIDATE slot of a
    ``PredScanResult`` (``preds[l]`` names slot l's predicate) — only the
    subject's SP candidates are scanned.
    """
    s = jnp.asarray(s, jnp.int32)
    if index is None:
        return _ids(k2forest.row_scan_all_preds(meta, f, s - 1, cap, backend))
    return _pruned_one(meta, f, pmeta, index, s - 1, 0, cap, u_width, backend)


def any_po(meta: K2Meta, f: K2Forest, p, o, cap: int,
           backend: str | None = None) -> QueryResult:
    """(?S, P, O) -> subject IDs, ascending."""
    p, o = jnp.asarray(p, jnp.int32), jnp.asarray(o, jnp.int32)
    return _ids(k2forest.col_scan(meta, f, p - 1, o - 1, cap, backend))


def any_any_o(meta: K2Meta, f: K2Forest, o, cap: int,
              backend: str | None = None, *, index=None, pmeta=None,
              u_width: int | None = None):
    """(?S, ?P, O) -> per-predicate subject lists.

    With ``index``: pruned to the object's OP candidates (see
    ``s_any_any``).
    """
    o = jnp.asarray(o, jnp.int32)
    if index is None:
        return _ids(k2forest.col_scan_all_preds(meta, f, o - 1, cap, backend))
    return _pruned_one(meta, f, pmeta, index, o - 1, 1, cap, u_width, backend)


def any_p_any(meta: K2Meta, f: K2Forest, p, cap: int,
              backend: str | None = None) -> PairResult:
    """(?S, P, ?O) -> all (subject, object) pairs of predicate P."""
    p = jnp.asarray(p, jnp.int32)
    return _pairs(k2forest.range_scan(meta, f, p - 1, cap, backend))


def dump(meta: K2Meta, f: K2Forest, cap: int,
         backend: str | None = None) -> PairResult:
    """(?S, ?P, ?O) -> every triple (axis 0 = predicate)."""
    return _pairs(k2forest.range_scan_all_preds(meta, f, cap, backend))


# batched forms used by the serving path -----------------------------------


def spo_batch(meta, f, s, p, o):
    return spo(meta, f, s, p, o)


def sp_any_batch(meta, f, s, p, cap: int, backend: str | None = None) -> QueryResult:
    s, p = jnp.asarray(s, jnp.int32), jnp.asarray(p, jnp.int32)
    return _ids(k2forest.row_scan_batch(meta, f, p - 1, s - 1, cap, backend))


def any_po_batch(meta, f, p, o, cap: int, backend: str | None = None) -> QueryResult:
    p, o = jnp.asarray(p, jnp.int32), jnp.asarray(o, jnp.int32)
    return _ids(k2forest.col_scan_batch(meta, f, p - 1, o - 1, cap, backend))
