"""k²-tree: compressed quadtree over a sparse binary matrix (the paper's core).

Construction (host, numpy): sort-based, level-order emission of the T / L bit
arrays with the paper's hybrid arity (k=4 for the first 5 levels, then k=2).

Queries (device, JAX): the paper's DFS pointer-chasing is re-formulated as
**level-synchronous batched traversal** — a static Python loop over the (small,
static) tree height where every level processes a whole frontier of candidate
nodes as dense vectors: gather word, popcount-rank, compute child positions.
All result shapes are static (``max_results`` cap + valid count + overflow
flag) so every query lowers to one XLA program.

Navigation invariant (hybrid-k generalization of Brisaboa et al. 2009):
  * levels ``0 .. H-2`` live in T, level ``H-1`` (the matrix cells) lives in L;
  * the j-th 1-bit (level order) of level ``l`` owns the bit slab
    ``[j * k²_{l+1}, (j+1) * k²_{l+1})`` of level ``l+1``;
  * ``j = rank1(T, pos) - ones_before_level[l]``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitvec
from repro.core.bitvec import BitVec

# Paper §k²-trees: "hybrid policy which uses values k=4, up to the level 5 of
# the tree, and then k=2, for the rest ones".
HYBRID_K4_LEVELS = 5


def hybrid_ks(side_needed: int, k4_levels: int = HYBRID_K4_LEVELS) -> tuple[int, ...]:
    """Per-level arities covering at least ``side_needed`` (paper's hybrid)."""
    ks: list[int] = []
    side = 1
    while side < side_needed:
        ks.append(4 if len(ks) < k4_levels else 2)
        side *= ks[-1]
    return tuple(ks) if ks else (2,)


@dataclasses.dataclass(frozen=True)
class K2Meta:
    """Static (hashable) tree geometry, shared by every tree of a forest."""

    ks: tuple[int, ...]  # per-level arity, len == n_levels

    @property
    def n_levels(self) -> int:
        return len(self.ks)

    @property
    def side(self) -> int:
        return int(np.prod(self.ks))

    @property
    def radices(self) -> tuple[int, ...]:
        return tuple(k * k for k in self.ks)

    @property
    def subsides(self) -> tuple[int, ...]:
        """Submatrix side of a node at each level (after that level's split)."""
        out, s = [], self.side
        for k in self.ks:
            s //= k
            out.append(s)
        return tuple(out)  # subsides[-1] == 1 (cells)


class K2Tree(NamedTuple):
    """One compressed matrix: device arrays (meta travels separately)."""

    t: BitVec
    l: BitVec
    ones_before: jax.Array  # int32[n_levels-1]: #1s in T before each level
    level_start: jax.Array  # int32[n_levels]: bit offset of each level
    #   (levels 0..H-2 offsets are into T; level_start[H-1] == 0, into L)
    nnz: int


# ---------------------------------------------------------------------------
# construction (numpy, host)
# ---------------------------------------------------------------------------


class K2HostArrays(NamedTuple):
    """Raw numpy arrays (pre-device) — also used by the forest packer."""

    t_bits: np.ndarray  # uint8[t_len]
    l_bits: np.ndarray  # uint8[l_len]
    ones_before: np.ndarray  # int32[H-1]
    level_start: np.ndarray  # int32[H]
    nnz: int


def build_host(rows: np.ndarray, cols: np.ndarray, meta: K2Meta) -> K2HostArrays:
    """Sort-based level-order construction. O(nnz · H)."""
    H = meta.n_levels
    radices = meta.radices
    side = meta.side
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    if rows.size and (rows.max() >= side or cols.max() >= side):
        raise ValueError("coordinates exceed matrix side")

    # mixed-radix Morton-style code, most-significant level first
    code = np.zeros(rows.shape[0], dtype=np.int64)
    r, c, s = rows.copy(), cols.copy(), side
    for k in meta.ks:
        s //= k
        code = code * (k * k) + ((r // s) * k + (c // s))
        r %= s
        c %= s
    code = np.unique(code)
    nnz = int(code.shape[0])

    # per-level sorted prefixes (the 1-nodes of each level)
    prefixes: list[np.ndarray] = [None] * H  # type: ignore[list-item]
    prefixes[H - 1] = code
    for lvl in range(H - 2, -1, -1):
        prefixes[lvl] = np.unique(prefixes[lvl + 1] // radices[lvl + 1])

    level_bits: list[np.ndarray] = []
    for lvl in range(H):
        if lvl == 0:
            bits = np.zeros(radices[0], dtype=np.uint8)
            bits[prefixes[0]] = 1
        else:
            parent_idx = np.searchsorted(prefixes[lvl - 1], prefixes[lvl] // radices[lvl])
            pos = parent_idx * radices[lvl] + prefixes[lvl] % radices[lvl]
            bits = np.zeros(prefixes[lvl - 1].shape[0] * radices[lvl], dtype=np.uint8)
            bits[pos] = 1
        level_bits.append(bits)

    t_bits = (
        np.concatenate(level_bits[:-1]) if H > 1 else np.zeros(0, dtype=np.uint8)
    )
    l_bits = level_bits[-1]

    lvl_lens = np.array([b.shape[0] for b in level_bits[:-1]], dtype=np.int64)
    level_start = np.zeros(H, dtype=np.int32)
    if H > 1:
        level_start[1:-1] = np.cumsum(lvl_lens)[:-1].astype(np.int32)
    level_start[H - 1] = 0  # last level indexes into L

    ones = np.array([int(b.sum()) for b in level_bits[:-1]], dtype=np.int64)
    ones_before = np.zeros(max(H - 1, 1), dtype=np.int32)
    if H > 1:
        ones_before[1:] = np.cumsum(ones)[:-1].astype(np.int32)
        ones_before = ones_before[: H - 1]

    return K2HostArrays(t_bits, l_bits, ones_before, level_start, nnz)


def build(rows: np.ndarray, cols: np.ndarray, meta: K2Meta) -> K2Tree:
    h = build_host(rows, cols, meta)
    return K2Tree(
        t=bitvec.bitvec_from_bits(h.t_bits),
        l=bitvec.bitvec_from_bits(h.l_bits),
        ones_before=jnp.asarray(h.ones_before),
        level_start=jnp.asarray(h.level_start),
        nnz=h.nnz,
    )


def size_bits(tree: K2HostArrays | K2Tree) -> int:
    """Structure size in bits (T + L), the paper's compression metric."""
    if isinstance(tree, K2HostArrays):
        return int(tree.t_bits.shape[0] + tree.l_bits.shape[0])
    return tree.t.n_bits + tree.l.n_bits


# ---------------------------------------------------------------------------
# queries (JAX, batched / level-synchronous)
# ---------------------------------------------------------------------------


def _row_digits(meta: K2Meta, v: jax.Array) -> list[jax.Array]:
    """Per-level digit of a coordinate v along one axis (static unroll)."""
    digs = []
    rem = v
    for sub in meta.subsides:
        digs.append(rem // sub)
        rem = rem % sub
    return digs


def check(meta: K2Meta, tree: K2Tree, rows: jax.Array, cols: jax.Array) -> jax.Array:
    """Batched cell query: does (row, col) contain a 1?  -> bool[Q].

    Paper pattern (S, P, O).
    """
    H = meta.n_levels
    rd = _row_digits(meta, rows.astype(jnp.int32))
    cd = _row_digits(meta, cols.astype(jnp.int32))
    alive = jnp.ones(rows.shape, dtype=jnp.bool_)
    pos = (rd[0] * meta.ks[0] + cd[0]).astype(jnp.int32)
    for lvl in range(H):
        last = lvl == H - 1
        bv = tree.l if last else tree.t
        bit = bitvec.get_bit(bv.words, pos)
        alive = alive & (bit == 1)
        if not last:
            j = bitvec.rank1(tree.t.words, tree.t.rank_blocks, pos) - tree.ones_before[lvl]
            nxt_digit = rd[lvl + 1] * meta.ks[lvl + 1] + cd[lvl + 1]
            pos = tree.level_start[lvl + 1] + j * meta.radices[lvl + 1] + nxt_digit
            pos = jnp.where(alive, pos, 0).astype(jnp.int32)
    return alive


class QueryResult(NamedTuple):
    """Fixed-shape query result: ID-sorted ids, validity, count, overflow."""

    ids: jax.Array  # int32[cap]   (row or column ids; garbage where ~valid)
    valid: jax.Array  # bool[cap]
    count: jax.Array  # int32[]    number of valid results (pre-truncation min cap)
    overflow: jax.Array  # bool[]  True if the frontier/capacity was exceeded


class PairResult(NamedTuple):
    rows: jax.Array  # int32[cap]
    cols: jax.Array  # int32[cap]
    valid: jax.Array
    count: jax.Array
    overflow: jax.Array


def _compact(valid: jax.Array, cap: int, *arrays: jax.Array):
    """Stable-compact valid lanes to the front; returns (valid', arrays')."""
    idx = jnp.cumsum(valid.astype(jnp.int32)) - 1
    tgt = jnp.where(valid, idx, cap)  # invalid -> dropped (mode="drop")
    n = jnp.minimum(valid.sum(), cap)
    new_valid = jnp.arange(cap, dtype=jnp.int32) < n
    outs = []
    for a in arrays:
        out = jnp.zeros((cap,), a.dtype).at[tgt].set(a, mode="drop")
        outs.append(out)
    overflow = valid.sum() > cap
    return new_valid, n.astype(jnp.int32), overflow, outs


def _axis_scan(
    meta: K2Meta,
    tree: K2Tree,
    fixed: jax.Array,  # scalar int32 — the bound coordinate
    cap: int,
    axis: int,  # 0: row fixed (direct neighbors); 1: col fixed (reverse)
) -> QueryResult:
    """Row/column scan, level-synchronous frontier BFS with static cap.

    axis=0 resolves (S, P, ?O) — all 1s in a row, ascending column order.
    axis=1 resolves (?S, P, O) — all 1s in a column, ascending row order.
    """
    H = meta.n_levels
    fixed = fixed.astype(jnp.int32)
    fdig = _row_digits(meta, fixed)

    pos = jnp.zeros((cap,), jnp.int32)
    base = jnp.zeros((cap,), jnp.int32)  # free-axis offset of each node
    valid = jnp.zeros((cap,), jnp.bool_)

    k0 = meta.ks[0]
    sub0 = meta.subsides[0]
    init_n = min(k0, cap)
    j0 = jnp.arange(init_n, dtype=jnp.int32)
    if axis == 0:
        p0 = fdig[0] * k0 + j0
    else:
        p0 = j0 * k0 + fdig[0]
    pos = pos.at[:init_n].set(p0)
    base = base.at[:init_n].set(j0 * sub0)
    valid = valid.at[:init_n].set(True)
    overflow = jnp.asarray(k0 > cap)

    # test level-0 candidates immediately: frontier only ever holds 1-nodes,
    # so capacity requirements track the matrix's true occupancy
    bv0 = tree.l if H == 1 else tree.t
    valid = valid & (bitvec.get_bit(bv0.words, pos) == 1)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1(tree.t.words, tree.t.rank_blocks, pos) - tree.ones_before[lvl]
        child_base0 = tree.level_start[lvl + 1] + j * r
        # expand: (cap,) -> (cap, k) child candidates, entry-major keeps the
        # free axis ascending => results stay ID-sorted (merge-join property)
        ch = jnp.arange(k, dtype=jnp.int32)
        if axis == 0:
            cpos = child_base0[:, None] + fdig[lvl + 1] * k + ch[None, :]
        else:
            cpos = child_base0[:, None] + ch[None, :] * k + fdig[lvl + 1]
        cbase = base[:, None] + ch[None, :] * sub
        bvc = tree.l if last_child else tree.t
        cbit = bitvec.get_bit(bvc.words, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, base) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), cbase.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (ids,) = _compact(valid, cap, base)
    return QueryResult(ids=ids, valid=valid, count=count, overflow=overflow | ovf)


def row_scan(meta: K2Meta, tree: K2Tree, row: jax.Array, cap: int) -> QueryResult:
    """(S, P, ?O): objects related to ``row``, ascending object id."""
    return _axis_scan(meta, tree, row, cap, axis=0)


def col_scan(meta: K2Meta, tree: K2Tree, col: jax.Array, cap: int) -> QueryResult:
    """(?S, P, O): subjects related to ``col``, ascending subject id."""
    return _axis_scan(meta, tree, col, cap, axis=1)


def range_scan(meta: K2Meta, tree: K2Tree, cap: int) -> PairResult:
    """(?S, P, ?O): every 1-cell of the matrix (Morton order), capped.

    Level 0 bit-tests every root child and only then compacts into the
    ``cap`` frontier — overflow latches only when more than ``cap`` root
    children are occupied (not whenever the root radix exceeds ``cap``).
    """
    H = meta.n_levels
    k0 = meta.ks[0]
    r0 = meta.radices[0]
    sub0 = meta.subsides[0]

    d0 = jnp.arange(r0, dtype=jnp.int32)
    bv0 = tree.l if H == 1 else tree.t
    bit0 = bitvec.get_bit(bv0.words, d0)
    valid, _, ovf, (pos, rbase, cbase) = _compact(
        bit0 == 1, cap, d0, (d0 // k0) * sub0, (d0 % k0) * sub0
    )
    overflow = ovf
    pos = jnp.where(valid, pos, 0)

    for lvl in range(H - 1):
        last_child = lvl + 1 == H - 1
        k = meta.ks[lvl + 1]
        r = meta.radices[lvl + 1]
        sub = meta.subsides[lvl + 1]
        j = bitvec.rank1(tree.t.words, tree.t.rank_blocks, pos) - tree.ones_before[lvl]
        child_base0 = tree.level_start[lvl + 1] + j * r
        d = jnp.arange(r, dtype=jnp.int32)
        cpos = child_base0[:, None] + d[None, :]
        crb = rbase[:, None] + (d[None, :] // k) * sub
        ccb = cbase[:, None] + (d[None, :] % k) * sub
        bvc = tree.l if last_child else tree.t
        cbit = bitvec.get_bit(bvc.words, jnp.where(valid[:, None], cpos, 0))
        cvalid = valid[:, None] & (cbit == 1)
        valid, _, ovf, (pos, rbase, cbase) = _compact(
            cvalid.reshape(-1), cap, cpos.reshape(-1), crb.reshape(-1), ccb.reshape(-1)
        )
        overflow = overflow | ovf
        pos = jnp.where(valid, pos, 0)

    valid, count, ovf, (rows, cols) = _compact(valid, cap, rbase, cbase)
    return PairResult(rows, cols, valid, count, overflow | ovf)
