"""LSM-style delta layer: live inserts/deletes over a frozen k²-triples store.

The paper's structure is build-once — ultra-compressed but immutable.  This
module takes the LSM route to mutability: a small write-optimized
:class:`DeltaStore` absorbs inserts (per-predicate sorted (s, o) arrays) and
deletes (a tombstone set), while the static forest + DAC index + front-coded
dictionary keep serving reads at full speed.  A :class:`DynamicStore` facade
wraps static + delta and is accepted everywhere a store is today (attribute
proxying); the engine grabs an immutable :class:`DynView` per dispatch and
merges the delta lane into the pooled ``_run_lanes`` results on the host:

    merged = (static − tombstones) ∪ inserts          (per lane, per pred)

Unseen terms get ids from an appended range (``dictionary.ExtendedDictionary``)
— static ids never move — and lanes whose constants fall outside the static
extents are masked to dead (op = -1) before device dispatch, so the static
program never gathers out-of-range rows; the merge then supplies the
delta-only answer.  Background compaction (``core/compaction.py``) folds the
delta into a rebuilt static store and atomically swaps it in under
``DynamicStore.swap``; the epoch counter lets plans detect staleness
(``query.StaleEpoch``).
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.dictionary import ExtendedDictionary
from repro.core.k2triples import K2TriplesStore
from repro.core.predindex import PredBitmap

# serve IR opcodes — mirrored from core.engine (kept in sync by
# tests/test_dynamic.py::test_opcodes_in_sync); importing engine here would
# be circular (engine imports this module).
OP_CHECK = 0
OP_ROW = 1
OP_COL = 2
OP_S_ANY_ANY = 3
OP_ANY_ANY_O = 4
OP_S_ANY_O = 5

_NEED_S = (OP_CHECK, OP_ROW, OP_S_ANY_O, OP_S_ANY_ANY)
_NEED_O = (OP_CHECK, OP_COL, OP_S_ANY_O, OP_ANY_ANY_O)
_NEED_P = (OP_CHECK, OP_ROW, OP_COL)

_EMPTY = np.empty(0, dtype=np.int64)


class DeltaSnapshot:
    """Immutable point-in-time view of a :class:`DeltaStore`.

    All lookups the merge path needs are precomputed on the host: per-pred
    (s, o) pair sets, per-(s, o) predicate lists, and per-entity predicate
    bitmaps (:class:`~repro.core.predindex.PredBitmap`) standing in for the
    SP/OP index on the delta side.
    """

    def __init__(
        self,
        ins: dict[int, frozenset],
        tomb: dict[int, frozenset],
        *,
        n_subjects: int,
        n_objects: int,
        n_preds: int,
        version: int,
    ):
        self.ins = ins
        self.tomb = tomb
        self.n_subjects = n_subjects
        self.n_objects = n_objects
        self.n_preds = n_preds
        self.version = version
        self.n_inserts = sum(len(v) for v in ins.values())
        self.n_tombstones = sum(len(v) for v in tomb.values())
        self.empty = not self.n_inserts and not self.n_tombstones

        # per-(s,o) predicate lists for (S, ?P, O)
        self.so_preds: dict[tuple[int, int], list[int]] = {}
        self.tomb_so_preds: dict[tuple[int, int], list[int]] = {}
        # per-entity predicate bitmaps for (S, ?P, ?O) / (?S, ?P, O)
        self.s_preds = PredBitmap()
        self.o_preds = PredBitmap()
        self.tomb_s_preds = PredBitmap()
        self.tomb_o_preds = PredBitmap()
        for src, so_map, sb, ob in (
            (ins, self.so_preds, self.s_preds, self.o_preds),
            (tomb, self.tomb_so_preds, self.tomb_s_preds, self.tomb_o_preds),
        ):
            for p in sorted(src):
                for (s, o) in src[p]:
                    so_map.setdefault((s, o), []).append(p)
                    sb.add(s, p)
                    ob.add(o, p)

        self.dirty_preds = frozenset(ins) | frozenset(tomb)
        # lazily materialized per-pred sorted arrays
        self._sp: dict[tuple[int, int, int], np.ndarray] = {}

    # --- point lookups -----------------------------------------------------

    def contains(self, s: int, p: int, o: int) -> bool:
        v = self.ins.get(p)
        return v is not None and (s, o) in v

    def tomb_contains(self, s: int, p: int, o: int) -> bool:
        v = self.tomb.get(p)
        return v is not None and (s, o) in v

    # --- per-pred scans ----------------------------------------------------

    def _scan(self, src: int, p: int, axis: int, key: int) -> np.ndarray:
        """Sorted ids on ``axis`` (0: objects of subject ``key``; 1: subjects
        of object ``key``) for pred ``p`` in pool ``src`` (0=ins, 1=tomb)."""
        ck = (src, p, axis)
        idx = self._sp.get(ck)
        if idx is None:
            pairs = (self.ins if src == 0 else self.tomb).get(p)
            if not pairs:
                idx = (_EMPTY, _EMPTY)
            else:
                a = np.asarray(sorted(pairs), dtype=np.int64)
                if axis == 0:  # keyed by s, yields o
                    idx = (a[:, 0], a[:, 1])
                else:  # keyed by o, yields s
                    order = np.lexsort((a[:, 0], a[:, 1]))
                    idx = (a[order, 1], a[order, 0])
            self._sp[ck] = idx
        keys, vals = idx
        lo = np.searchsorted(keys, key, side="left")
        hi = np.searchsorted(keys, key, side="right")
        out = vals[lo:hi]
        out = np.sort(out) if out.size else out
        return out

    def objects_of(self, s: int, p: int) -> np.ndarray:
        return self._scan(0, p, 0, s)

    def subjects_of(self, o: int, p: int) -> np.ndarray:
        return self._scan(0, p, 1, o)

    def tomb_objects_of(self, s: int, p: int) -> np.ndarray:
        return self._scan(1, p, 0, s)

    def tomb_subjects_of(self, o: int, p: int) -> np.ndarray:
        return self._scan(1, p, 1, o)

    def preds_linking(self, s: int, o: int) -> list[int]:
        return self.so_preds.get((s, o), [])

    def tomb_preds_linking(self, s: int, o: int) -> list[int]:
        return self.tomb_so_preds.get((s, o), [])

    def pairs_of(self, p: int) -> frozenset:
        return self.ins.get(p) or frozenset()

    def tomb_pairs_of(self, p: int) -> frozenset:
        return self.tomb.get(p) or frozenset()

    # --- pair-list merge (the (?S, P, ?O) / dump shapes) -------------------

    def merge_pairs(self, p: int, s_arr, o_arr):
        """Merge one static (s, o) pair list for pred ``p``.

        Untouched preds come back unchanged (Morton order preserved);
        touched preds come back lex-sorted by (s, o).
        """
        rm = self.tomb.get(p)
        add = self.ins.get(p)
        if not rm and not add:
            return s_arr, o_arr
        pairs = set(zip(np.asarray(s_arr).tolist(), np.asarray(o_arr).tolist()))
        if rm:
            pairs -= rm
        if add:
            pairs |= add
        if not pairs:
            return _EMPTY, _EMPTY
        a = np.asarray(sorted(pairs), dtype=np.int64)
        return a[:, 0], a[:, 1]


class DeltaStore:
    """Write-optimized mutable side of a :class:`DynamicStore`.

    Semantics (the LSM contract):

      * ``insert`` clears any tombstone for the triple and records it in the
        insert pool (delete-then-reinsert round-trips).
      * ``delete`` removes a delta-resident insert and records a tombstone
        unconditionally — a tombstone for a triple the static side never had
        is semantically inert (the merge subtracts nothing) and is swept at
        the next compaction.
      * answers = (static − tombstones) ∪ inserts.

    Thread-safe; ``snapshot()`` is version-cached so the read path only
    rebuilds host lookup tables after an actual mutation.
    """

    def __init__(self, static: K2TriplesStore, dictionary=None):
        self._lock = threading.Lock()
        self._ins: dict[int, set] = {}
        self._tomb: dict[int, set] = {}
        self._dict = dictionary
        self._version = 0
        self._snap: DeltaSnapshot | None = None
        self.n_subjects = static.n_subjects
        self.n_objects = static.n_objects
        self.n_preds = static.n_preds

    @property
    def n_inserts(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._ins.values())

    @property
    def n_tombstones(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._tomb.values())

    @property
    def empty(self) -> bool:
        with self._lock:
            return not self._ins and not self._tomb

    def _check_ids(self, s: int, p: int, o: int) -> None:
        if s < 1 or p < 1 or o < 1:
            raise ValueError(f"ids are 1-based, got ({s}, {p}, {o})")
        if self._dict is not None:
            ext = self._dict.matrix_extent
            if s > ext or o > ext or p > self._dict.n_preds:
                raise ValueError(
                    f"id ({s}, {p}, {o}) beyond dictionary extents "
                    f"({ext}, {self._dict.n_preds}) — add terms via "
                    "insert_strings / ExtendedDictionary.add_term first"
                )

    def insert(self, s: int, p: int, o: int) -> None:
        s, p, o = int(s), int(p), int(o)
        self._check_ids(s, p, o)
        with self._lock:
            t = self._tomb.get(p)
            if t is not None:
                t.discard((s, o))
                if not t:
                    del self._tomb[p]
            self._ins.setdefault(p, set()).add((s, o))
            self.n_subjects = max(self.n_subjects, s)
            self.n_objects = max(self.n_objects, o)
            self.n_preds = max(self.n_preds, p)
            self._version += 1

    def delete(self, s: int, p: int, o: int) -> None:
        s, p, o = int(s), int(p), int(o)
        with self._lock:
            v = self._ins.get(p)
            if v is not None:
                v.discard((s, o))
                if not v:
                    del self._ins[p]
            self._tomb.setdefault(p, set()).add((s, o))
            self.n_preds = max(self.n_preds, p)
            self._version += 1

    def snapshot(self) -> DeltaSnapshot:
        with self._lock:
            if self._snap is None or self._snap.version != self._version:
                self._snap = DeltaSnapshot(
                    {p: frozenset(v) for p, v in self._ins.items()},
                    {p: frozenset(v) for p, v in self._tomb.items()},
                    n_subjects=self.n_subjects,
                    n_objects=self.n_objects,
                    n_preds=self.n_preds,
                    version=self._version,
                )
            return self._snap

    def rebase(self, new_static: K2TriplesStore, absorbed: DeltaSnapshot) -> "DeltaStore":
        """Post-compaction delta: drop everything ``absorbed`` folded into
        ``new_static``, keep mutations that raced in after the snapshot."""
        out = DeltaStore(new_static, self._dict)
        with self._lock:
            for p, v in self._ins.items():
                rem = v - absorbed.ins.get(p, frozenset())
                if rem:
                    out._ins[p] = set(rem)
            for p, v in self._tomb.items():
                rem = v - absorbed.tomb.get(p, frozenset())
                if rem:
                    out._tomb[p] = set(rem)
            out.n_subjects = max(out.n_subjects, self.n_subjects)
            out.n_objects = max(out.n_objects, self.n_objects)
            out.n_preds = max(out.n_preds, self.n_preds)
            out._version = 1 if (out._ins or out._tomb) else 0
        return out


class DynamicStore:
    """Mutable facade: static :class:`K2TriplesStore` + :class:`DeltaStore`.

    Duck-compatible with the static store — every attribute the engine and
    planner read (``meta``/``forest``/``stats``/``n_*``/``pred_index``)
    proxies to the current static epoch; ``dictionary`` upgrades to an
    :class:`~repro.core.dictionary.ExtendedDictionary` so unseen terms get
    appended ids.  ``swap`` installs a compacted static store and bumps
    ``epoch`` atomically; in-flight reads keep the old epoch's objects alive
    via the :class:`DynView` they grabbed at dispatch.
    """

    def __init__(self, static: K2TriplesStore, *, dictionary=None):
        if dictionary is None and static.dictionary is not None:
            dictionary = ExtendedDictionary(static.dictionary)
        self._lock = threading.Lock()
        self._static = static
        self._dictionary = dictionary
        self._delta = DeltaStore(static, dictionary)
        self._epoch = 0

    # --- identity ----------------------------------------------------------

    @property
    def static(self) -> K2TriplesStore:
        return self._static

    @property
    def delta(self) -> DeltaStore:
        return self._delta

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def dictionary(self):
        return self._dictionary if self._dictionary is not None else self._static.dictionary

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._static, name)

    # --- writes ------------------------------------------------------------

    def insert(self, s: int, p: int, o: int) -> None:
        # under the store lock: ``swap`` rebases and REPLACES self._delta
        # while holding it, so a write loaded against the pre-rebase delta
        # outside the lock could land on the orphaned store after the
        # rebase copied it — silently dropped.  Lock order (store lock,
        # then delta lock inside DeltaStore.insert) matches swap/rebase.
        with self._lock:
            self._delta.insert(s, p, o)

    def delete(self, s: int, p: int, o: int) -> None:
        with self._lock:
            self._delta.delete(s, p, o)

    def insert_strings(self, triples) -> int:
        """Insert string triples, minting appended ids for unseen terms."""
        d = self._dictionary
        if d is None:
            raise ValueError("store has no dictionary; use insert(s, p, o)")
        n = 0
        for (s, p, o) in triples:
            self.insert(d.add_term(s), d.add_predicate(p), d.add_term(o))
            n += 1
        return n

    def delete_strings(self, triples) -> int:
        d = self.dictionary
        if d is None:
            raise ValueError("store has no dictionary; use delete(s, p, o)")
        n = 0
        for (s, p, o) in triples:
            try:
                ids = (d.encode_subject(s), d.encode_predicate(p), d.encode_object(o))
            except KeyError:
                continue  # unknown term -> triple cannot exist
            self.delete(*ids)
            n += 1
        return n

    # --- reads -------------------------------------------------------------

    def view(self) -> "DynView":
        with self._lock:
            d = self._dictionary
            return DynView(
                self._static, self._delta.snapshot(), self._epoch,
                ext_minted=d.matrix_extent if d is not None else 0,
                preds_minted=d.n_preds if d is not None else 0,
            )

    # --- compaction hand-off ----------------------------------------------

    def swap(self, new_static: K2TriplesStore, absorbed: DeltaSnapshot) -> int:
        """Install a compacted static store; returns the new epoch."""
        with self._lock:
            self._delta = self._delta.rebase(new_static, absorbed)
            self._static = new_static
            self._epoch += 1
            return self._epoch


# ---------------------------------------------------------------------------
# per-dispatch read view: sanitize + host-side merge
# ---------------------------------------------------------------------------


def view_of(store) -> "DynView | None":
    """The delta lane for ``store``, or None when reads are purely static.

    A view is returned not only when the delta snapshot holds mutations
    but also whenever ids beyond the static extents exist at all (the
    dictionary minted appended terms with no resident insert yet, e.g.
    ``add_term`` before the first write or between epochs) — those lanes
    still need sanitizing, or a clamped device gather would read the
    wrong row instead of answering empty.
    """
    if not isinstance(store, DynamicStore):
        return None
    v = store.view()
    if v.snap.empty and not v.needs_sanitize:
        return None
    return v


def snapshot_of(store) -> DeltaSnapshot | None:
    v = view_of(store)
    return v.snap if v is not None else None


def total_preds(store) -> int:
    """Predicate count including delta-only appended predicates."""
    if isinstance(store, DynamicStore):
        return max(store.static.n_preds, store.delta.n_preds)
    return store.n_preds


class DynView:
    """Immutable (static epoch, delta snapshot) pair used for one dispatch.

    ``sanitize_*`` masks lanes whose constants exceed the static extents to
    dead (op = -1) so the device program never gathers out of range;
    ``merge_*`` then folds the snapshot into the host-fetched results:
    subtract tombstones, union inserts, widen caps host-side so the delta
    can never cause a false overflow.
    """

    def __init__(
        self,
        static: K2TriplesStore,
        snap: DeltaSnapshot,
        epoch: int,
        *,
        ext_minted: int = 0,
        preds_minted: int = 0,
    ):
        self.static = static
        self.snap = snap
        self.epoch = epoch
        self.ext_static = max(static.n_subjects, static.n_objects)
        self.preds_static = static.n_preds
        # largest ids in existence anywhere — delta-resident OR merely
        # minted by the dictionary's appended range with no insert yet
        self.ext_minted = max(
            self.ext_static, snap.n_subjects, snap.n_objects, ext_minted
        )
        self.preds_minted = max(self.preds_static, snap.n_preds, preds_minted)

    @property
    def needs_sanitize(self) -> bool:
        """Ids beyond the static extents exist: lanes must be masked even
        when the delta snapshot itself is empty."""
        return (
            self.ext_minted > self.ext_static
            or self.preds_minted > self.preds_static
        )

    @property
    def total_preds(self) -> int:
        return max(self.preds_static, self.snap.n_preds)

    # --- sanitize ----------------------------------------------------------

    def sanitize_ops(self, ops, s, p, o) -> np.ndarray:
        ops = np.array(ops, dtype=np.int32, copy=True).reshape(-1)
        s = np.asarray(s, dtype=np.int64).reshape(-1)
        p = np.asarray(p, dtype=np.int64).reshape(-1)
        o = np.asarray(o, dtype=np.int64).reshape(-1)
        bad = np.isin(ops, _NEED_S) & (s > self.ext_static)
        bad |= np.isin(ops, _NEED_O) & (o > self.ext_static)
        bad |= np.isin(ops, _NEED_P) & (p > self.preds_static)
        ops[bad] = -1
        return ops

    def sanitize_batch(self, qb):
        """ServeBatch -> ServeBatch with out-of-static-range lanes masked."""
        ops = self.sanitize_ops(qb.op, qb.s, qb.p, qb.o)
        if (ops == np.asarray(qb.op)).all():
            return qb
        return qb._replace(op=ops)

    # --- merge -------------------------------------------------------------

    def _merge_check(self, hit: bool, s: int, p: int, o: int) -> bool:
        if self.snap.contains(s, p, o):
            return True
        if hit and self.snap.tomb_contains(s, p, o):
            return False
        return bool(hit)

    def check(self, s: int, p: int, o: int, static_hit: bool) -> bool:
        """(S, P, O) with the delta folded in (planner point lookups)."""
        return self._merge_check(static_hit, s, p, o)

    def _merge_sorted(self, base: np.ndarray, rm: np.ndarray, add) -> np.ndarray:
        out = base.astype(np.int64, copy=False)
        if len(rm):
            out = np.setdiff1d(out, rm, assume_unique=False)
        if len(add):
            out = np.union1d(out, np.asarray(add, dtype=np.int64))
        return out

    def merge_lanes(self, ops, s, p, o, r):
        """Fold the delta into one host-fetched ``ServeResult``.

        ``ops``/``s``/``p``/``o`` are the ORIGINAL (pre-sanitize) lane
        arrays; ``r`` is the numpy ``ServeResult`` of the sanitized batch.
        Returns ``r`` itself when no lane touches a dirty key; otherwise a
        rebuilt result whose ids/u blocks are widened host-side as needed.
        """
        snap = self.snap
        ops = np.asarray(ops).reshape(-1)
        s = np.asarray(s, dtype=np.int64).reshape(-1)
        p = np.asarray(p, dtype=np.int64).reshape(-1)
        o = np.asarray(o, dtype=np.int64).reshape(-1)
        b = ops.shape[0]

        new_hit: dict[int, bool] = {}
        new_ids: dict[int, np.ndarray] = {}
        new_u: dict[int, dict[int, np.ndarray]] = {}

        for i in range(b):
            op = int(ops[i])
            if op == OP_CHECK:
                si, pi, oi = int(s[i]), int(p[i]), int(o[i])
                h = self._merge_check(bool(r.hit[i]), si, pi, oi)
                if h != bool(r.hit[i]):
                    new_hit[i] = h
            elif op in (OP_ROW, OP_COL):
                pi = int(p[i])
                if pi not in snap.dirty_preds:
                    continue
                if op == OP_ROW:
                    key = int(s[i])
                    rm = snap.tomb_objects_of(key, pi)
                    add = snap.objects_of(key, pi)
                else:
                    key = int(o[i])
                    rm = snap.tomb_subjects_of(key, pi)
                    add = snap.subjects_of(key, pi)
                if not rm.size and not add.size:
                    continue
                base = np.asarray(r.ids[i])[np.asarray(r.valid[i])]
                new_ids[i] = self._merge_sorted(base, rm, add)
            elif op == OP_S_ANY_O:
                si, oi = int(s[i]), int(o[i])
                rm = snap.tomb_preds_linking(si, oi)
                add = snap.preds_linking(si, oi)
                if not rm and not add:
                    continue
                base = np.asarray(r.ids[i])[np.asarray(r.valid[i])]
                new_ids[i] = self._merge_sorted(
                    base, np.asarray(rm, dtype=np.int64), add
                )
            elif op in (OP_S_ANY_ANY, OP_ANY_ANY_O):
                if op == OP_S_ANY_ANY:
                    key = int(s[i])
                    dp = snap.s_preds.preds_of(key)
                    tp = snap.tomb_s_preds.preds_of(key)
                else:
                    key = int(o[i])
                    dp = snap.o_preds.preds_of(key)
                    tp = snap.tomb_o_preds.preds_of(key)
                if not dp.size and not tp.size:
                    continue
                per: dict[int, np.ndarray] = {}
                up = np.asarray(r.u_preds[i])
                for l in range(up.shape[0]):
                    pl = int(up[l])
                    if pl <= 0:
                        continue
                    v = np.asarray(r.u_valid[i, l])
                    per[pl] = np.asarray(r.u_ids[i, l])[v].astype(np.int64)
                for pl in tp.tolist():
                    if pl not in per:
                        continue
                    rm = (
                        snap.tomb_objects_of(key, pl)
                        if op == OP_S_ANY_ANY
                        else snap.tomb_subjects_of(key, pl)
                    )
                    if rm.size:
                        per[pl] = np.setdiff1d(per[pl], rm, assume_unique=False)
                for pl in dp.tolist():
                    add = (
                        snap.objects_of(key, pl)
                        if op == OP_S_ANY_ANY
                        else snap.subjects_of(key, pl)
                    )
                    if add.size:
                        cur = per.get(pl, _EMPTY)
                        per[pl] = np.union1d(cur, add)
                per = {pl: v for pl, v in sorted(per.items()) if v.size}
                new_u[i] = per

        if not new_hit and not new_ids and not new_u:
            return r

        hit = np.array(r.hit, dtype=np.bool_, copy=True)
        for i, h in new_hit.items():
            hit[i] = h

        ids, valid, count = r.ids, r.valid, r.count
        if new_ids:
            cap = ids.shape[1]
            cap2 = max(cap, max(len(v) for v in new_ids.values()))
            ids = np.zeros((b, cap2), dtype=np.int32)
            valid = np.zeros((b, cap2), dtype=np.bool_)
            ids[:, :cap] = r.ids
            valid[:, :cap] = r.valid
            count = np.array(r.count, copy=True)
            for i, m in new_ids.items():
                ids[i] = 0
                valid[i] = False
                ids[i, : len(m)] = m
                valid[i, : len(m)] = True
                count[i] = len(m)

        u_preds, u_ids, u_valid, u_count = r.u_preds, r.u_ids, r.u_valid, r.u_count
        if new_u:
            L, ucap = r.u_preds.shape[1], r.u_ids.shape[2]
            L2 = max(L, max(len(d) for d in new_u.values()), 1)
            ucap2 = max(
                ucap,
                max(
                    (max((len(a) for a in d.values()), default=0) for d in new_u.values()),
                    default=0,
                ),
                1,
            )
            u_preds = np.zeros((b, L2), dtype=np.int32)
            u_ids = np.zeros((b, L2, ucap2), dtype=np.int32)
            u_valid = np.zeros((b, L2, ucap2), dtype=np.bool_)
            u_count = np.zeros((b, L2), dtype=np.int32)
            u_preds[:, :L] = r.u_preds
            u_ids[:, :L, :ucap] = r.u_ids
            u_valid[:, :L, :ucap] = r.u_valid
            u_count[:, :L] = r.u_count
            for i, d in new_u.items():
                u_preds[i] = 0
                u_ids[i] = 0
                u_valid[i] = False
                u_count[i] = 0
                for l, (pl, arr) in enumerate(d.items()):
                    u_preds[i, l] = pl
                    u_ids[i, l, : len(arr)] = arr
                    u_valid[i, l, : len(arr)] = True
                    u_count[i, l] = len(arr)

        return r._replace(
            hit=hit,
            ids=ids,
            valid=valid,
            count=count,
            u_preds=u_preds,
            u_ids=u_ids,
            u_valid=u_valid,
            u_count=u_count,
        )
