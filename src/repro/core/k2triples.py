"""K2TriplesStore — the paper's engine state: dictionary + per-predicate forest.

Builds the vertical-partitioned k²-tree arena from ID triples, keeps the
|SO| boundary needed for cross-joins, and exposes honest size accounting
(the paper's Table 2 metric) including the analytic comparisons used by
``benchmarks/bench_compression.py``:

  * raw ID triples            — 3 × 32 bits/triple (lower bound for a table)
  * MonetDB-style vertical    — 2 × 32 bits/triple (per-predicate [S,O] table)
  * RDF-3X-style sextuple     — 6 orderings, byte-level gap compression
  * k²-triples                — |T| + |L| bits summed over predicates

Device placement / sharding of the forest over the ``model`` mesh axis lives
in ``repro.dist.sharding`` + ``repro.core.engine``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import k2forest, k2tree, predindex
from repro.core.dictionary import (
    CompressedTripleDictionary,
    TripleDictionary,
    build_compressed_dictionary,
    build_dictionary,
)
from repro.core.k2forest import ForestStats, K2Forest
from repro.core.k2tree import K2Meta
from repro.core.predindex import BuiltPredIndex


@dataclasses.dataclass(frozen=True)
class K2TriplesStore:
    meta: K2Meta
    forest: K2Forest
    stats: ForestStats
    n_so: int  # |SO| — cross-joins live in [0, n_so)²
    n_subjects: int
    n_objects: int
    n_preds: int
    n_triples: int
    dictionary: TripleDictionary | CompressedTripleDictionary | None = None
    # k²-triples+ (arXiv:1310.4954): SP/OP candidate-predicate indexes that
    # turn the unbounded-?P sweep into a pruned scan.  None = sweep fallback.
    pred_index: BuiltPredIndex | None = None


def from_id_triples(
    ids: np.ndarray,
    *,
    n_so: int,
    n_subjects: int,
    n_objects: int,
    n_preds: int,
    dictionary: TripleDictionary | CompressedTripleDictionary | None = None,
    k4_levels: int = k2tree.HYBRID_K4_LEVELS,
    with_pred_index: bool = True,
) -> K2TriplesStore:
    """Build the store from int64[N,3] 1-based (s, p, o) ID triples."""
    ids = np.asarray(ids, dtype=np.int64).reshape(-1, 3)
    extent = max(n_subjects, n_objects, 1)
    meta = K2Meta(k2tree.hybrid_ks(extent, k4_levels))

    order = np.lexsort((ids[:, 2], ids[:, 0], ids[:, 1]))
    ids = ids[order]
    coords: list[tuple[np.ndarray, np.ndarray]] = []
    bounds = np.searchsorted(ids[:, 1], np.arange(1, n_preds + 2))
    for p in range(n_preds):
        sl = ids[bounds[p] : bounds[p + 1]]
        coords.append((sl[:, 0] - 1, sl[:, 2] - 1))

    forest, stats = k2forest.build_forest(coords, meta)
    pidx = (
        predindex.build(
            ids, n_subjects=n_subjects, n_objects=n_objects, n_preds=n_preds
        )
        if with_pred_index
        else None
    )
    return K2TriplesStore(
        meta=meta,
        forest=forest,
        stats=stats,
        n_so=n_so,
        n_subjects=n_subjects,
        n_objects=n_objects,
        n_preds=n_preds,
        n_triples=int(ids.shape[0]),
        dictionary=dictionary,
        pred_index=pidx,
    )


def from_string_triples(triples, *, compressed: bool = True) -> K2TriplesStore:
    """String triples -> store.  ``compressed=True`` (default) keeps the
    dictionary as front-coded byte pools (:class:`CompressedTripleDictionary`,
    same API); ``compressed=False`` keeps plain Python string tuples."""
    d = (
        build_compressed_dictionary(triples)
        if compressed
        else build_dictionary(triples)
    )
    ids = d.encode_triples(triples)
    ids = np.unique(ids, axis=0)  # the paper cleans duplicate triples
    return from_id_triples(
        ids,
        n_so=d.n_so,
        n_subjects=d.n_subjects,
        n_objects=d.n_objects,
        n_preds=d.n_preds,
        dictionary=d,
    )


# ---------------------------------------------------------------------------
# analytic size baselines (Table 2 comparisons, ID-space as in the paper)
# ---------------------------------------------------------------------------


def size_k2triples_bits(store: K2TriplesStore, *, with_rank: bool = False) -> int:
    """|T|+|L| summed over predicates; with_rank adds the o(n) rank overhead
    (we charge the full int32-per-word directory we actually materialize)."""
    bits = store.stats.total_bits
    if with_rank:
        bits += store.stats.total_bits  # int32 rank word per uint32 data word
    return bits


def size_pred_index_bits(store: K2TriplesStore) -> int:
    """SP+OP index overhead (payload + CSR offsets), 0 when not built.

    Reported next to the k² column by ``benchmarks/bench_compression.py`` so
    the compression claims stay honest after the index lands — this is the
    price of predicate pruning, the 1310.4954 Table analogue.
    """
    if store.pred_index is None:
        return 0
    st = store.pred_index.stats
    return st.payload_bits + st.offsets_bits


def size_dictionary_bits(store: K2TriplesStore) -> int:
    """Measured dictionary bits: front-coded pools + EF offset indexes when
    the store carries a :class:`CompressedTripleDictionary`; raw UTF-8 bytes
    for a plain :class:`TripleDictionary`; 0 for ID-only stores."""
    d = store.dictionary
    if d is None:
        return 0
    if isinstance(d, CompressedTripleDictionary):
        return d.size_bits()
    return 8 * sum(
        len(t.encode())
        for terms in (d.so_terms, d.s_terms, d.o_terms, d.p_terms)
        for t in terms
    )


def size_raw_triples_bits(n_triples: int) -> int:
    return 3 * 32 * n_triples


def size_vertical_tables_bits(n_triples: int) -> int:
    """MonetDB-style: per-predicate [S,O] 2-column tables."""
    return 2 * 32 * n_triples


def size_sextuple_gap_bits(ids: np.ndarray) -> int:
    """RDF-3X-style: 6 sort orders, leading-column delta + varint bytes."""
    ids = np.asarray(ids, dtype=np.int64)
    total_bytes = 0
    for perm in ((0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0), (2, 0, 1), (2, 1, 0)):
        arr = ids[:, perm]
        order = np.lexsort((arr[:, 2], arr[:, 1], arr[:, 0]))
        arr = arr[order]
        delta = arr.copy()
        delta[1:, 0] = arr[1:, 0] - arr[:-1, 0]
        same0 = delta[1:, 0] == 0
        delta[1:, 1] = np.where(same0, arr[1:, 1] - arr[:-1, 1], arr[1:, 1])
        same01 = same0 & (delta[1:, 1] == 0)
        delta[1:, 2] = np.where(same01, arr[1:, 2] - arr[:-1, 2], arr[1:, 2])
        v = np.abs(delta)
        nbytes = np.maximum(1, np.ceil(np.log2(v + 2) / 7)).sum()
        total_bytes += int(nbytes)
    return total_bytes * 8
