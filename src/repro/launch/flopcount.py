"""Loop-aware FLOP / byte accounting from the jaxpr (pre-SPMD, global).

``compiled.cost_analysis()`` on XLA:CPU counts while-loop bodies ONCE and
reports per-device numbers — useless for scanned-layer transformers (61-deep
loops undercounted 61×).  This module walks the closed jaxpr instead:

  * dot_general / conv flops counted exactly (2·M·N·K), elementwise ops as
    one flop per output element;
  * ``scan`` / ``while`` / ``map`` bodies multiplied by their STATIC trip
    count (scan length is in the jaxpr params; fori_loop bounds likewise);
  * ``bytes_naive``: Σ over eqns of operand+result bytes × trips — a
    fusion-naive upper bound on HBM traffic (each fusion boundary in XLA
    removes traffic; the real number lies between cost_analysis's
    loop-undercounted figure and this one — both are recorded).

Numbers are GLOBAL (whole unpartitioned program): divide by chip count for
per-device roofline terms (uniform sharding assumed — true for our rules).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax import core as jcore


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes_naive: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes_naive += o.bytes_naive
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes_naive * k)


def _aval_bytes(v) -> float:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0.0
    try:
        return float(np.prod(aval.shape, dtype=np.float64)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _out_elems(eqn) -> float:
    tot = 0.0
    for v in eqn.outvars:
        aval = v.aval
        if hasattr(aval, "shape"):
            tot += float(np.prod(aval.shape, dtype=np.float64))
    return tot


_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "neg", "abs", "pow", "integer_pow", "select_n", "and", "or",
    "xor", "not", "lt", "gt", "le", "ge", "eq", "ne", "sign", "floor", "ceil",
    "round", "erf", "erfc", "sin", "cos", "atan2", "clamp", "rem", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "population_count",
    "reduce_sum", "reduce_max", "reduce_min", "reduce_and", "reduce_or",
    "cumsum", "cummax", "argmax", "argmin", "reduce_prod", "add_any",
}

_FREE = {  # layout/metadata ops: no flops, no real traffic at fusion time
    "reshape", "broadcast_in_dim", "convert_element_type", "squeeze",
    "transpose", "slice", "rev", "iota", "copy", "stop_gradient",
    "split", "concatenate", "pad",
}


def _dot_flops(eqn) -> float:
    (lhs, rhs) = eqn.invars[:2]
    a, b = lhs.aval, rhs.aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    bsz = float(np.prod([a.shape[i] for i in lb], dtype=np.float64)) if lb else 1.0
    csz = float(np.prod([a.shape[i] for i in lc], dtype=np.float64)) if lc else 1.0
    lf = [i for i in range(len(a.shape)) if i not in lc and i not in lb]
    rf = [i for i in range(len(b.shape)) if i not in rc and i not in rb]
    m = float(np.prod([a.shape[i] for i in lf], dtype=np.float64)) if lf else 1.0
    n = float(np.prod([b.shape[i] for i in rf], dtype=np.float64)) if rf else 1.0
    return 2.0 * bsz * m * n * csz


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for control-flow / call primitives."""
    p = eqn.primitive.name
    params = eqn.params
    if p == "scan":
        return [(params["jaxpr"].jaxpr, float(params["length"]))]
    if p == "while":
        # fori_loop pattern: trip count from constant bounds when present
        trips = 1.0
        return [
            (params["cond_jaxpr"].jaxpr, trips),
            (params["body_jaxpr"].jaxpr, trips),
        ]
    if p == "cond":
        return [(br.jaxpr, 1.0) for br in params["branches"]]
    if p == "shard_map":
        # the body jaxpr carries PER-SHARD shapes; every mesh device runs it
        mult = 1.0
        m = params.get("mesh")
        if m is not None:
            try:
                mult = float(np.prod(list(dict(m.shape).values())))
            except Exception:
                mult = float(getattr(m, "size", 1))
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in params:
                j = params[key]
                return [(j.jaxpr if hasattr(j, "jaxpr") else j, mult)]
        return []
    if p in ("pjit", "jit", "closed_call", "core_call", "remat_call", "xla_call",
             "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
             "checkpoint", "remat", "remat2"):
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in params:
                j = params[key]
                return [(j.jaxpr if hasattr(j, "jaxpr") else j, 1.0)]
        return []
    return []


def count_jaxpr(jaxpr) -> Cost:
    c = Cost()
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        subs = _sub_jaxprs(eqn)
        if subs:
            for sub, mult in subs:
                c += count_jaxpr(sub).scaled(mult)
            # carried state traffic of the loop itself
            c.bytes_naive += sum(_aval_bytes(v) for v in eqn.invars if hasattr(v, "aval"))
            continue
        if p in _FREE:
            continue
        if p == "dot_general":
            c.flops += _dot_flops(eqn)
        elif p.startswith("conv"):
            c.flops += 2.0 * _out_elems(eqn)  # rough; convs unused here
        elif p in _ELEMENTWISE:
            c.flops += _out_elems(eqn)
        # traffic: every non-free eqn reads operands and writes results
        c.bytes_naive += sum(_aval_bytes(v) for v in eqn.invars if hasattr(v, "aval"))
        c.bytes_naive += sum(_aval_bytes(v) for v in eqn.outvars)
    return c


def count(fn, *arg_specs) -> Cost:
    closed = jax.make_jaxpr(fn)(*arg_specs)
    return count_jaxpr(closed.jaxpr)
