"""Multi-tenant serving benchmark: drive the streaming broker with a
skewed tenant trace and report sustained queries/sec + per-QUERY tail
latency, single-device and predicate-sharded.

    python -m repro.launch.serve --triples 100000 --tenants 8 --queries 4096
    python -m repro.launch.serve --fast --sharded --json serve_rows.json

The harness builds a store, compiles ONE base ``ServeQ`` plan through
:class:`repro.launch.broker.ServeBroker`, replays a Zipf-skewed
multi-tenant trace of mixed serve-IR ops through per-tenant async
streams, and reports the broker's structured stats.  Latency is measured
per query (submit -> decoded result), never per batch, and tail
percentiles follow ``tail_percentile``'s sample-count guard — a p99 is
only printed when 100+ samples support it.

All execution knobs ride an explicit ``ExecConfig`` (env flags folded in
once via ``ExecConfig.from_env``); ``--sharded`` factors the serve mesh
from the ACTUAL device count (``mesh.serve_mesh_shape`` — every device
used or a loud failure) and refuses to run when only one device is
visible rather than silently degrading to single-device numbers.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np

from repro import obs
from repro.launch.broker import (
    CoalescePolicy, ServeBroker, TenantPolicy, tail_percentile,
)

# mixed-op trace composition: production traffic is mostly point lookups
# and bounded scans, with a thin unbounded-?P tail (the paper's worst case)
_OP_WEIGHTS = {
    0: 0.30,  # OP_CHECK
    1: 0.25,  # OP_ROW
    2: 0.25,  # OP_COL
    3: 0.08,  # OP_S_ANY_ANY
    4: 0.07,  # OP_ANY_ANY_O
    5: 0.05,  # OP_S_ANY_O
}


def zipf_weights(n_tenants: int, a: float) -> np.ndarray:
    """Normalized Zipf(a) tenant weights: tenant 0 is the heaviest."""
    w = 1.0 / np.arange(1, n_tenants + 1, dtype=np.float64) ** a
    return w / w.sum()


def make_trace(
    ds, n_queries: int, n_tenants: int, *, zipf_a: float = 1.1,
    unbounded: bool = True, select_frac: float = 0.0, seed: int = 0,
) -> list[tuple]:
    """A skewed multi-tenant trace: ``(tenant, op, s, p, o)`` lane rows,
    plus ``(tenant, SelectQ)`` rows for a ``select_frac`` fraction of the
    trace (SPARQL-shaped queries anchored on real subjects: a bounded
    WHERE scan with an OPTIONAL second predicate, ordered and limited).

    Tenants are Zipf(a)-weighted; ops follow ``_OP_WEIGHTS`` (bounded-only
    when ``unbounded=False``); ids come from real triples so every query
    has a non-empty answer shape to decode.
    """
    from repro.core.query import SelectQ, TriplePatternQ

    rng = np.random.default_rng(seed)
    ops_pool = [op for op in _OP_WEIGHTS if unbounded or op < 3]
    p_ops = np.array([_OP_WEIGHTS[op] for op in ops_pool])
    p_ops = p_ops / p_ops.sum()
    ops = rng.choice(ops_pool, size=n_queries, p=p_ops)
    tenants = rng.choice(n_tenants, size=n_queries, p=zipf_weights(n_tenants, zipf_a))
    rows = ds.ids[rng.integers(0, ds.n_triples, n_queries)]
    is_select = rng.random(n_queries) < select_frac
    trace: list[tuple] = []
    for i in range(n_queries):
        s, p, o = map(int, rows[i])
        tenant = f"tenant-{tenants[i]}"
        if is_select[i]:
            p2 = int(rng.integers(1, ds.n_preds + 1))
            trace.append((tenant, SelectQ(
                where=(TriplePatternQ(s, p, "?o"),),
                optional=((TriplePatternQ(s, p2, "?x"),),),
                order_by=("?o",),
                limit=16,
            )))
            continue
        if ops[i] >= 3:
            p = 0  # unbounded-?P ops leave the predicate free
        trace.append((tenant, int(ops[i]), s, p, o))
    return trace


async def _replay(broker: ServeBroker, trace) -> int:
    """Replay the trace as one async stream per tenant (per-tenant FIFO),
    counting decoded results.  Rows are ``(tenant, op, s, p, o)`` lanes
    or ``(tenant, SelectQ)`` full-shape queries — ``broker.stream``
    accepts both item shapes."""
    per_tenant: dict[str, list] = {}
    for tenant, *rest in trace:
        per_tenant.setdefault(tenant, []).append(
            rest[0] if len(rest) == 1 else tuple(rest)
        )

    async def one(tenant, queries):
        n = 0
        async for _ in broker.stream(tenant, queries):
            n += 1
        return n

    counts = await asyncio.gather(
        *(one(t, qs) for t, qs in per_tenant.items())
    )
    return sum(counts)


def run_bench(
    *,
    n_triples: int = 100_000,
    n_preds: int = 64,
    n_tenants: int = 8,
    n_queries: int = 4096,
    zipf_a: float = 1.1,
    cap: int = 1024,
    max_batch: int = 256,
    deadline_ms: float = 2.0,
    backend: str | None = None,
    donate: bool | None = None,
    sharded: bool = False,
    unbounded: bool = True,
    select_frac: float = 0.0,
    warmup: int = 64,
    seed: int = 0,
    quiet: bool = False,
    trace_path: str | None = None,
    metrics_path: str | None = None,
    obs_on: bool = False,
) -> dict:
    """Build a store, serve a skewed multi-tenant trace through the
    broker, and return one machine-readable serving row.

    ``trace_path`` / ``metrics_path`` / ``obs_on`` switch the
    observability layer on for the measured window (trace and metrics
    are cleared at the warmup boundary, together with the broker's own
    stats, so exports describe exactly the run the row reports):
    ``trace_path`` gets the Chrome ``trace_event`` JSON, ``metrics_path``
    the metrics snapshot + per-plan cost profiles + Prometheus text."""
    import jax

    from repro.core import engine as eng, k2triples
    from repro.core.query import ExecConfig
    from repro.data import rdf
    from repro.launch import mesh as meshlib

    ds = rdf.generate(
        n_triples,
        n_subjects=max(64, n_triples // 12),
        n_preds=n_preds,
        n_objects=max(64, n_triples // 8),
        preds_per_subject=min(6, n_preds),
        seed=seed,
    )
    t0 = time.time()
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    if not quiet:
        print(
            f"store: {store.n_triples} triples, {store.n_preds} preds, "
            f"side {store.meta.side}, "
            f"{store.stats.total_bits/8/1024:.1f} KiB structure "
            f"({store.stats.total_bits/max(store.n_triples,1):.2f} bits/triple), "
            f"built in {time.time()-t0:.1f}s"
        )

    n_dev = len(jax.devices())
    overrides: dict = {"cap": cap}
    if backend is not None:
        overrides["backend"] = backend
    if donate is not None:
        overrides["donate_batch"] = donate
    mesh_shape = None
    if sharded:
        if n_dev < 2:
            raise ValueError(
                "--sharded requested but only one device is visible; "
                "refusing to silently serve unsharded (run on a multi-"
                "device backend, or fake hosts with XLA_FLAGS="
                "--xla_force_host_platform_device_count=N)"
            )
        mesh_shape = meshlib.serve_mesh_shape(n_dev)
        overrides["mesh"] = jax.make_mesh(mesh_shape, ("data", "model"))
        if not quiet:
            print(f"sharded over mesh {{'data': {mesh_shape[0]}, 'model': {mesh_shape[1]}}}")
    cfg = ExecConfig.from_env(**overrides)

    engine = eng.Engine(store)
    trace = make_trace(
        ds, n_queries, n_tenants, zipf_a=zipf_a, unbounded=unbounded,
        select_frac=select_frac, seed=seed + 1,
    )
    # bound per-tenant windows so ~two coalesced batches stay outstanding:
    # the pipeline keeps both buffers fed while latency still means
    # "time through the broker", not "time parked in an unbounded queue"
    depth = max(16, (2 * max_batch) // max(n_tenants, 1))

    obs_enabled = obs_on or trace_path is not None or metrics_path is not None
    tracer = metrics = None
    if obs_enabled:
        from repro.core.query import ObsConfig

        tracer, metrics = obs.enable(ObsConfig(trace=True, metrics=True))

    async def main():
        broker = ServeBroker(
            engine, cfg, unbounded=unbounded,
            coalesce=CoalescePolicy(
                max_batch=max_batch, max_delay_s=deadline_ms * 1e-3
            ),
            tenant_policy=TenantPolicy(queue_depth=depth),
        )
        async with broker:
            # warmup: compile the serve program + prime every op type
            await _replay(broker, trace[: min(warmup, len(trace))])
            broker.reset_stats()
            if tracer is not None:
                tracer.clear()
            if metrics is not None:
                metrics.reset()
            t0 = time.perf_counter()
            n_done = await _replay(broker, trace)
            wall = time.perf_counter() - t0
        return broker, broker.stats(), n_done, wall

    try:
        broker, stats, n_done, wall = asyncio.run(main())
        if obs_enabled:
            _export_obs(
                broker, engine, tracer, metrics,
                trace_path=trace_path, metrics_path=metrics_path,
                quiet=quiet,
            )
    finally:
        if obs_enabled:
            obs.disable()
    assert n_done == n_queries, (n_done, n_queries)
    row = {
        "mode": "sharded" if sharded else "single",
        "mesh": list(mesh_shape) if mesh_shape else None,
        "devices": n_dev,
        "backend": cfg.backend,
        "triples": store.n_triples,
        "preds": store.n_preds,
        "tenants": n_tenants,
        "zipf_a": zipf_a,
        "unbounded": unbounded,
        "queries": n_queries,
        "select_frac": select_frac,
        "selects": stats["selects"],
        "cap": cap,
        "max_batch": max_batch,
        "donate": cfg.donate_batch and cfg.mesh is None,
        "pred_index_layout": cfg.pred_index_layout,
        "deadline_ms": deadline_ms,
        "wall_s": wall,
        "qps": n_queries / wall,
        "p50_ms": stats["p50_ms"],
        "p99_ms": stats["p99_ms"],
        "coalesce_factor": stats["coalesce_factor"],
        "batches": stats["batches"],
        "shed": stats["shed"],
        "cap_growth_events": stats["cap_growth_events"],
        "queue_peak": stats["queue_peak"],
        "obs": obs_enabled,
        "per_tenant": stats["tenants"],
    }
    if not quiet:
        print(format_row(row))
    return row


def _export_obs(broker, engine, tracer, metrics, *, trace_path, metrics_path,
                quiet):
    """Write the run's observability exports: Chrome trace JSON and a
    metrics document (broker + obs registries, plan-cache stats, per-plan
    cost profiles, Prometheus text exposition)."""
    if trace_path is not None and tracer is not None:
        with open(trace_path, "w") as fh:
            json.dump(tracer.to_chrome(metadata=obs.provenance()), fh)
        if not quiet:
            print(f"# wrote {trace_path} ({tracer.dropped} spans dropped)")
    if metrics_path is not None:
        doc = {
            "provenance": obs.provenance(),
            "broker": broker.metrics.snapshot(),
            "obs": metrics.snapshot() if metrics is not None else {},
            "plan_cache": engine.plan_cache_stats,
            "cost_profiles": broker.cost_profiles(),
            "prometheus": (
                broker.metrics.to_prometheus()
                + (metrics.to_prometheus() if metrics is not None else "")
            ),
        }
        with open(metrics_path, "w") as fh:
            json.dump(doc, fh, indent=2, default=float)
        if not quiet:
            print(f"# wrote {metrics_path}")


def format_row(row: dict) -> str:
    def pct(v):
        return f"{v:.2f} ms" if v is not None else "n/a (insufficient samples)"

    return (
        f"{row['mode']} x {row['backend']}: {row['queries']} queries, "
        f"{row['tenants']} tenants (zipf {row['zipf_a']}): "
        f"{row['qps']:,.0f} queries/s sustained, per-query p50 {pct(row['p50_ms'])}, "
        f"p99 {pct(row['p99_ms'])}, coalesce x{row['coalesce_factor']:.1f} "
        f"({row['batches']} batches), {row['cap_growth_events']} cap growths, "
        f"{row['shed']} shed"
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--triples", type=int, default=100_000)
    ap.add_argument("--preds", type=int, default=64)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--zipf", type=float, default=1.1, help="tenant skew exponent")
    ap.add_argument("--queries", type=int, default=4096, help="trace length")
    ap.add_argument("--batch", type=int, default=256, help="coalesce max_batch")
    ap.add_argument(
        "--deadline-ms", type=float, default=2.0,
        help="coalesce deadline for the oldest pending query",
    )
    ap.add_argument("--cap", type=int, default=1024)
    ap.add_argument(
        "--backend", default=None, choices=("pallas", "jnp"),
        help="scan backend override (default: ExecConfig.from_env)",
    )
    ap.add_argument("--sharded", action="store_true", help="shard over local devices")
    ap.add_argument(
        "--no-donate", action="store_true",
        help="disable per-batch buffer donation (the before/after knob)",
    )
    ap.add_argument(
        "--bounded-only", action="store_true",
        help="trace without unbounded-?P ops (compiles the u_* block out)",
    )
    ap.add_argument(
        "--select-frac", type=float, default=0.0,
        help="fraction of the trace served as SPARQL-shaped SelectQ "
             "queries (OPTIONAL + ORDER/LIMIT) instead of raw lanes",
    )
    ap.add_argument("--fast", action="store_true", help="tiny smoke-test trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write the serving rows as JSON ({'serving': [...]})",
    )
    ap.add_argument(
        "--trace", nargs="?", const="serve_trace.json", default=None,
        metavar="PATH",
        help="enable tracing; write Chrome trace_event JSON "
             "(default PATH: serve_trace.json — load it in Perfetto)",
    )
    ap.add_argument(
        "--metrics", nargs="?", const="serve_metrics.json", default=None,
        metavar="PATH",
        help="enable metrics; write snapshot + cost profiles + Prometheus "
             "text (default PATH: serve_metrics.json)",
    )
    ap.add_argument(
        "--obs-overhead", action="store_true",
        help="run the bench twice (observability off, then on) and report "
             "the p50/qps overhead of tracing",
    )
    args = ap.parse_args(argv)

    kw = dict(
        n_triples=args.triples, n_preds=args.preds, n_tenants=args.tenants,
        n_queries=args.queries, zipf_a=args.zipf, cap=args.cap,
        max_batch=args.batch, deadline_ms=args.deadline_ms,
        backend=args.backend, sharded=args.sharded,
        donate=(False if args.no_donate else None),
        unbounded=not args.bounded_only, select_frac=args.select_frac,
        seed=args.seed,
    )
    if args.fast:
        kw.update(
            n_triples=20_000, n_preds=16, n_queries=256, max_batch=64,
            cap=256, warmup=32,
        )
    try:
        if args.obs_overhead:
            rows = [run_bench(**kw)]
            rows.append(run_bench(
                **kw, obs_on=True,
                trace_path=args.trace, metrics_path=args.metrics,
            ))
            off, on = rows
            print(format_overhead(off, on))
        else:
            rows = [run_bench(
                **kw, trace_path=args.trace, metrics_path=args.metrics,
            )]
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"serving": rows}, fh, indent=2, default=float)
        print(f"# wrote {args.json}")


def format_overhead(off: dict, on: dict) -> str:
    """One-line tracing-overhead report from an off/on run pair."""
    parts = [f"obs overhead: qps {off['qps']:,.0f} -> {on['qps']:,.0f} "
             f"({(off['qps'] - on['qps']) / off['qps'] * 100:+.1f}%)"]
    if off["p50_ms"] is not None and on["p50_ms"] is not None:
        parts.append(
            f"p50 {off['p50_ms']:.3f} -> {on['p50_ms']:.3f} ms "
            f"({(on['p50_ms'] - off['p50_ms']) / off['p50_ms'] * 100:+.1f}%)"
        )
    return ", ".join(parts)


if __name__ == "__main__":
    main()
