"""Serving driver for the k²-triples engine: build a store, serve query
batches through the compiled (optionally sharded) serve step.

    python -m repro.launch.serve --triples 100000 --batch 1024 --queries 10
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--triples", type=int, default=100_000)
    ap.add_argument("--preds", type=int, default=64)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--queries", type=int, default=10, help="batches to serve")
    ap.add_argument("--cap", type=int, default=1024)
    ap.add_argument("--sharded", action="store_true", help="shard over local devices")
    args = ap.parse_args()

    from repro.core import engine as eng, k2triples
    from repro.data import rdf

    ds = rdf.generate(
        args.triples,
        n_subjects=max(64, args.triples // 12),
        n_preds=args.preds,
        n_objects=max(64, args.triples // 8),
        seed=0,
    )
    t0 = time.time()
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    print(
        f"store: {store.n_triples} triples, {store.n_preds} preds, "
        f"side {store.meta.side}, {store.stats.total_bits/8/1024:.1f} KiB structure "
        f"({store.stats.total_bits/max(store.n_triples,1):.2f} bits/triple), "
        f"built in {time.time()-t0:.1f}s"
    )

    rng = np.random.default_rng(1)
    serve = None
    forest = store.forest
    if args.sharded and len(jax.devices()) > 1:
        n = len(jax.devices())
        mp = min(4, n)
        mesh = jax.make_mesh((n // mp, mp), ("data", "model"))
        forest = eng.pad_preds(store.forest, mp)
        forest = eng.shard_forest(forest, mesh, "model")
        serve = eng.make_sharded_serve_step(store.meta, mesh, args.cap)
        print(f"sharded over mesh {dict(mesh.shape)}")
    else:
        serve = eng.make_serve_step(store.meta, args.cap)

    lat = []
    hits = results = 0
    for i in range(args.queries):
        ids = ds.ids[rng.integers(0, ds.n_triples, args.batch)]
        q = eng.ServeBatch(
            op=jnp.asarray(rng.integers(0, 3, args.batch), jnp.int32),
            s=jnp.asarray(ids[:, 0], jnp.int32),
            p=jnp.asarray(ids[:, 1], jnp.int32),
            o=jnp.asarray(ids[:, 2], jnp.int32),
        )
        t0 = time.time()
        r = serve(forest, q)
        jax.block_until_ready(r.ids)
        lat.append(time.time() - t0)
        hits += int(np.asarray(r.hit).sum())
        results += int(np.asarray(r.count).sum())
    lat = np.array(lat[1:]) if len(lat) > 1 else np.array(lat)  # drop compile
    print(
        f"{args.queries} batches × {args.batch} queries: "
        f"p50 {np.percentile(lat,50)*1e3:.2f} ms, p99 {np.percentile(lat,99)*1e3:.2f} ms, "
        f"{args.batch/np.median(lat):,.0f} queries/s, "
        f"{hits} check-hits, {results} scan results"
    )


if __name__ == "__main__":
    main()
