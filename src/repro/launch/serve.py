"""Serving driver for the k²-triples engine: build a store, compile ONE
serve plan (optionally sharded), stream query batches through it.

    python -m repro.launch.serve --triples 100000 --batch 1024 --queries 10

All execution knobs ride an explicit ``ExecConfig`` — the env flags are
folded in once via ``ExecConfig.from_env()``; the hot loop is
``plan(batch)`` with zero per-call configuration.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--triples", type=int, default=100_000)
    ap.add_argument("--preds", type=int, default=64)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--queries", type=int, default=10, help="batches to serve")
    ap.add_argument("--cap", type=int, default=1024)
    ap.add_argument(
        "--backend", default=None, choices=("pallas", "jnp"),
        help="scan backend override (default: ExecConfig.from_env)",
    )
    ap.add_argument("--sharded", action="store_true", help="shard over local devices")
    args = ap.parse_args()

    from repro.core import engine as eng, k2triples
    from repro.core.query import ExecConfig, ServeQ
    from repro.data import rdf

    ds = rdf.generate(
        args.triples,
        n_subjects=max(64, args.triples // 12),
        n_preds=args.preds,
        n_objects=max(64, args.triples // 8),
        seed=0,
    )
    t0 = time.time()
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    print(
        f"store: {store.n_triples} triples, {store.n_preds} preds, "
        f"side {store.meta.side}, {store.stats.total_bits/8/1024:.1f} KiB structure "
        f"({store.stats.total_bits/max(store.n_triples,1):.2f} bits/triple), "
        f"built in {time.time()-t0:.1f}s"
    )

    overrides: dict = {"cap": args.cap}
    if args.backend is not None:
        overrides["backend"] = args.backend
    if args.sharded and len(jax.devices()) > 1:
        n = len(jax.devices())
        mp = min(4, n)
        overrides["mesh"] = jax.make_mesh((n // mp, mp), ("data", "model"))
        print(f"sharded over mesh {dict(overrides['mesh'].shape)}")
    cfg = ExecConfig.from_env(**overrides)

    engine = eng.Engine(store)
    plan = engine.compile(ServeQ(unbounded=False), cfg)

    rng = np.random.default_rng(1)
    lat = []
    hits = results = 0
    for i in range(args.queries):
        ids = ds.ids[rng.integers(0, ds.n_triples, args.batch)]
        q = eng.ServeBatch(
            op=jnp.asarray(rng.integers(0, 3, args.batch), jnp.int32),
            s=jnp.asarray(ids[:, 0], jnp.int32),
            p=jnp.asarray(ids[:, 1], jnp.int32),
            o=jnp.asarray(ids[:, 2], jnp.int32),
        )
        t0 = time.time()
        r = plan(q)
        jax.block_until_ready(r.ids)
        lat.append(time.time() - t0)
        hits += int(np.asarray(r.hit).sum())
        results += int(np.asarray(r.count).sum())
    lat = np.array(lat[1:]) if len(lat) > 1 else np.array(lat)  # drop compile
    print(
        f"{args.queries} batches × {args.batch} queries: "
        f"p50 {np.percentile(lat,50)*1e3:.2f} ms, p99 {np.percentile(lat,99)*1e3:.2f} ms, "
        f"{args.batch/np.median(lat):,.0f} queries/s, "
        f"{hits} check-hits, {results} scan results"
    )


if __name__ == "__main__":
    main()
