"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

  single-pod: (16, 16)    axes (data, model)  = 256 chips (one v5e pod)
  multi-pod : (2, 16, 16) axes (pod, data, model) = 512 chips

'model' carries TP / EP / the k²-triples predicate arena; 'data' carries DP
+ FSDP weight shards; 'pod' is pure DP across the (slow) cross-pod links —
gradient all-reduce over 'pod' is the int8-compression target.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Every mesh axis that is not 'model' (DP/FSDP axes)."""
    return tuple(a for a in mesh.axis_names if a != "model")


# TPU v5e hardware constants (per chip) — the roofline denominators
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
