"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

  single-pod: (16, 16)    axes (data, model)  = 256 chips (one v5e pod)
  multi-pod : (2, 16, 16) axes (pod, data, model) = 512 chips

'model' carries TP / EP / the k²-triples predicate arena; 'data' carries DP
+ FSDP weight shards; 'pod' is pure DP across the (slow) cross-pod links —
gradient all-reduce over 'pod' is the int8-compression target.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Every mesh axis that is not 'model' (DP/FSDP axes)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def serve_mesh_shape(n_devices: int, *, model_max: int = 4) -> tuple[int, int]:
    """Factor ``n_devices`` into a (data, model) serve-mesh shape that uses
    EVERY device: the model axis is the largest divisor of ``n_devices``
    not exceeding ``model_max``.

    This replaces the old ``mp = min(4, n)`` factorization, whose
    ``(n // mp, mp)`` mesh silently dropped devices whenever ``n % mp``
    was nonzero (6 devices became a 1x4 mesh serving on 4).  Here
    6 -> (2, 3), 8 -> (2, 4), 5 -> (5, 1); the product is always
    ``n_devices`` or the call fails loudly.
    """
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    mp = max(
        d for d in range(1, min(model_max, n_devices) + 1)
        if n_devices % d == 0
    )
    shape = (n_devices // mp, mp)
    assert shape[0] * shape[1] == n_devices
    return shape


# TPU v5e hardware constants (per chip) — the roofline denominators
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
