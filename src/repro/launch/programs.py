"""Program builders: one (arch × shape × mesh) cell -> a lowerable program.

``build(arch_id, shape_id, mesh)`` returns a ``Program`` whose ``fn`` +
``in_specs`` (ShapeDtypeStructs) + ``in_shardings`` feed straight into

    jax.jit(fn, in_shardings=...).lower(*in_specs).compile()

Nothing is allocated — params, optimizer state, caches and batches are all
abstract.  The same builders back the real train/serve drivers (which
``init`` + ``device_put`` concrete arrays instead).

Cell kinds per family:
  lm:      train (grad+optimizer), prefill, decode (32k & 500k KV)
  gnn:     train on the 4 graph shapes (sampled blocks for minibatch_lg)
  recsys:  train / forward / bulk / retrieval
  engine:  sharded SPARQL serve batches (the paper's program)
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cb
from repro.dist import sharding as shd
from repro.launch import mesh as meshlib
from repro.models import transformer as tfm
from repro.models.gnn import common as gnn_common
from repro.models.gnn import egnn, equiformer_v2, graphcast, mace
from repro.models.recsys import xdeepfm
from repro.train import optim
from repro.train.trainer import make_train_step


class Program(NamedTuple):
    name: str
    fn: Callable
    in_specs: tuple  # ShapeDtypeStructs (pytrees)
    in_shardings: tuple
    donate: tuple[int, ...] = ()
    # analytic model flops for §Roofline's MODEL_FLOPS/HLO_FLOPS ratio
    model_flops: float = 0.0


def _opt(arch: cb.ArchSpec):
    return optim.adafactor(1e-3) if arch.optimizer == "adafactor" else optim.adamw(3e-4)


def _dtype(arch: cb.ArchSpec):
    return jnp.bfloat16 if arch.param_dtype == "bfloat16" else jnp.float32


def _tree_shardings_none_ok(mesh, specs, axes, rules=None):
    def one(s, names):
        if names is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, shd.spec_for(mesh, tuple(names), s.shape, rules))

    return jax.tree.map(
        one, specs, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )


# ---------------------------------------------------------------------------
# LM programs
# ---------------------------------------------------------------------------


def lm_train_flops(cfg: tfm.TransformerCfg, tokens: int) -> float:
    """6·N_active·D (+ attention quadratic term) — the §Roofline numerator."""
    base = 6.0 * cfg.n_active_params * tokens
    # causal attention: 2·(2·S·S/2·H·dh)·B fwd ≈ 6·S·H·dh per token bwd-incl
    return base


def build_lm(arch: cb.ArchSpec, shape: cb.ShapeSpec, mesh: Mesh, *, smoke=False) -> Program:
    cfg: tfm.TransformerCfg = arch.smoke_cfg if smoke else arch.cfg
    dims = shape.dims
    B, S = dims["global_batch"], dims["seq_len"]
    if smoke:
        B, S = 2, 64
    dp = meshlib.dp_axes(mesh)
    rules = dict(shape.rules_override)
    dt = _dtype(arch)

    pspecs = tfm.param_specs(cfg, dt)
    paxes = tfm.logical_axes(cfg)
    psh = _tree_shardings_none_ok(mesh, pspecs, paxes, rules)
    # sequence-parallel residual stream: [B, S, D] -> (dp, 'model', None)
    constrain = shd.constrain_fn(mesh, ("batch", "seq_sp", None), rules)
    # expert-parallel MoE: per-shard routing under shard_map (no global sort)
    moe_ctx = {"mesh": mesh, "dp_axes": dp} if cfg.moe else None

    if shape.kind == "train":
        opt = _opt(arch)
        ospecs = jax.eval_shape(opt.init, pspecs)
        oaxes = opt.state_logical_axes(paxes)
        osh = _tree_shardings_none_ok(mesh, ospecs, oaxes, rules)
        bspec = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        bsh = {
            "tokens": NamedSharding(mesh, P(dp, None)),
            "labels": NamedSharding(mesh, P(dp, None)),
        }
        constrain_logits = shd.constrain_fn(mesh, ("batch", None, "vocab"), rules)
        loss = lambda p, b: tfm.loss_fn(
            cfg, p, b, constrain=constrain, constrain_logits=constrain_logits,
            moe_ctx=moe_ctx,
        )
        step = make_train_step(loss, opt)
        return Program(
            name=f"{arch.arch_id}:{shape.shape_id}",
            fn=step,
            in_specs=(pspecs, ospecs, bspec),
            in_shardings=(psh, osh, bsh),
            donate=(0, 1),
            model_flops=lm_train_flops(cfg, B * S),
        )

    if shape.kind == "prefill":
        bspec = jax.ShapeDtypeStruct((B, S), jnp.int32)
        bsh = NamedSharding(mesh, P(dp, None))
        fn = lambda p, t: tfm.prefill(cfg, p, t, constrain=constrain, moe_ctx=moe_ctx)
        return Program(
            name=f"{arch.arch_id}:{shape.shape_id}",
            fn=fn,
            in_specs=(pspecs, bspec),
            in_shardings=(psh, bsh),
            model_flops=2.0 * cfg.n_active_params * B * S,
        )

    # decode: one token against a KV cache of S
    cache_spec = tfm.KVCache.specs(cfg, B, S)
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    cache_sh = {
        k: NamedSharding(
            mesh, shd.spec_for(mesh, kv_axes, v.shape, {**shd.DEFAULT_RULES, **rules})
        )
        for k, v in cache_spec.items()
    }
    tok_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    len_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    bsh = NamedSharding(mesh, shd.spec_for(mesh, ("batch",), (B,), rules))
    fn = lambda p, c, t, ln: tfm.decode_step(cfg, p, c, t, ln)
    return Program(
        name=f"{arch.arch_id}:{shape.shape_id}",
        fn=fn,
        in_specs=(pspecs, cache_spec, tok_spec, len_spec),
        in_shardings=(psh, cache_sh, bsh, bsh),
        donate=(1,),
        model_flops=2.0 * cfg.n_active_params * B
        + 4.0 * B * S * cfg.n_layers * cfg.n_kv_heads * cfg.d_head,
    )


# ---------------------------------------------------------------------------
# GNN programs
# ---------------------------------------------------------------------------

GNN_MODULES = {
    "mace": mace,
    "graphcast": graphcast,
    "egnn": egnn,
    "equiformer-v2": equiformer_v2,
}

GNN_RULES = {
    # node/edge arrays data-parallel; channel dims TP over 'model'
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
}


def _gnn_sizes(shape: cb.ShapeSpec, smoke: bool):
    d = shape.dims
    if shape.shape_id == "minibatch_lg":
        seeds = 16 if smoke else d["batch_nodes"]
        f = d["fanouts"]
        n = seeds * int(np.prod([x + 1 for x in f]))
        e, m = 0, seeds
        for x in f:
            m *= x
            e += m
        return n, e, d["d_feat"], d["n_classes"], 1
    if shape.shape_id == "molecule":
        b = 8 if smoke else d["batch"]
        return b * d["n_nodes"], b * d["n_edges"], 8, 0, b
    n, e = (256, 1024) if smoke else (d["n_nodes"], d["n_edges"])
    return n, e, d["d_feat"], d["n_classes"], 1


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def build_gnn(arch: cb.ArchSpec, shape: cb.ShapeSpec, mesh: Mesh, *, smoke=False) -> Program:
    mod = GNN_MODULES[arch.arch_id]
    n, e, d_feat, n_classes, n_graphs = _gnn_sizes(shape, smoke)
    dp_size = int(np.prod([mesh.shape[a] for a in meshlib.dp_axes(mesh)]))
    n = _pad_to(n, dp_size)
    e = _pad_to(e, dp_size * mesh.shape["model"])

    cfg = arch.smoke_cfg if smoke else arch.cfg
    out_dim = n_classes if n_classes else 1
    cfg = dataclasses.replace(cfg, out_dim=out_dim, **(
        {"in_dim": d_feat} if hasattr(cfg, "in_dim") else {}
    ))
    # edge-chunked message passing for the huge-edge shapes (bounds the
    # per-layer [E_loc, C, dim] working set; see equiformer_v2.forward)
    if hasattr(cfg, "edge_chunks") and not smoke and e >= 10_000_000:
        cfg = dataclasses.replace(cfg, edge_chunks=128)
    # full-batch giant graphs: remat RE-GATHERS the halo in the backward
    # (5x collective, no memory win — measured); turn it off there
    if shape.shape_id == "ogb_products" and not smoke:
        cfg = dataclasses.replace(cfg, remat=False)

    pspecs = mod.param_specs(cfg)
    # GNN params are small relative to activations: replicate
    psh = jax.tree.map(
        lambda s: NamedSharding(mesh, P()), pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    gb = gnn_common.GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n, d_feat), jnp.float32),
        positions=jax.ShapeDtypeStruct((n, 3), jnp.float32),
        species=jax.ShapeDtypeStruct((n,), jnp.int32),
        edge_src=jax.ShapeDtypeStruct((e,), jnp.int32),
        edge_dst=jax.ShapeDtypeStruct((e,), jnp.int32),
        edge_feat=jax.ShapeDtypeStruct((e, 4), jnp.float32),
        node_mask=jax.ShapeDtypeStruct((n,), jnp.bool_),
        edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
        labels=jax.ShapeDtypeStruct((n,), jnp.int32),
        graph_ids=jax.ShapeDtypeStruct((n,), jnp.int32),
        graph_y=jax.ShapeDtypeStruct((n_graphs,), jnp.float32),
    )
    dp = meshlib.dp_axes(mesh)
    nsh = NamedSharding(mesh, P(dp))
    esh = NamedSharding(mesh, P(dp))
    gsh = gnn_common.GraphBatch(
        node_feat=nsh, positions=nsh, species=nsh,
        edge_src=esh, edge_dst=esh, edge_feat=esh,
        node_mask=nsh, edge_mask=esh, labels=nsh,
        graph_ids=nsh, graph_y=NamedSharding(mesh, P()),
    )

    opt = _opt(arch)
    ospecs = jax.eval_shape(opt.init, pspecs)
    osh = jax.tree.map(lambda s: NamedSharding(mesh, P()), ospecs,
                       is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    loss = lambda p, b: mod.loss_fn(cfg, p, b)
    step = make_train_step(loss, opt)
    # model flops: classify weights by whether they apply per-edge or
    # per-node, then 2·size·count fwd, ×3 for fwd+bwd
    EDGE_KEYS = ("edge_mlp", "phi_e", "phi_x", "w0", "w1_r", "w1_i", "w2_r",
                 "w2_i", "attn", "radial")
    per_edge = per_node = 0
    for kp, w in jax.tree_util.tree_flatten_with_path(pspecs)[0]:
        if len(w.shape) < 2:
            continue
        path = jax.tree_util.keystr(kp)
        sz = w.shape[-2] * w.shape[-1]
        if any(k in path for k in EDGE_KEYS):
            per_edge += sz
        else:
            per_node += sz
    return Program(
        name=f"{arch.arch_id}:{shape.shape_id}",
        fn=step,
        in_specs=(pspecs, ospecs, gb),
        in_shardings=(psh, osh, gsh),
        donate=(0, 1),
        model_flops=3.0 * 2.0 * (e * per_edge + n * per_node),
    )


# ---------------------------------------------------------------------------
# recsys programs
# ---------------------------------------------------------------------------


def build_recsys(arch: cb.ArchSpec, shape: cb.ShapeSpec, mesh: Mesh, *, smoke=False) -> Program:
    cfg: xdeepfm.XDeepFMCfg = arch.smoke_cfg if smoke else arch.cfg
    dp = meshlib.dp_axes(mesh)
    pspecs = xdeepfm.param_specs(cfg)
    paxes = {
        "tables": ("fields", "rows", None),
        "linear": ("fields", "rows"),
        "cin": [(None, None, None) for _ in cfg.cin_layers],
        "cin_out": (None, None),
        "dnn": {
            "w": [(None, None) for _ in range(len(cfg.mlp_dims) + 1)],
            "b": [(None,) for _ in range(len(cfg.mlp_dims) + 1)],
        },
        "bias": (),
    }
    psh = _tree_shardings_none_ok(mesh, pspecs, paxes)
    B = 64 if smoke else shape.dims["batch"]

    if shape.kind == "retrieval":
        nc = 4096 if smoke else shape.dims["n_candidates"]
        uspec = jax.ShapeDtypeStruct((cfg.n_fields,), jnp.int32)
        cspec = jax.ShapeDtypeStruct((nc,), jnp.int32)
        fn = lambda p, u, c: xdeepfm.retrieval_score(cfg, p, u, c)
        return Program(
            name=f"{arch.arch_id}:{shape.shape_id}", fn=fn,
            in_specs=(pspecs, uspec, cspec),
            in_shardings=(psh, NamedSharding(mesh, P()), NamedSharding(mesh, P(dp))),
            model_flops=2.0 * nc * cfg.embed_dim,
        )

    ids_spec = jax.ShapeDtypeStruct((B, cfg.n_fields), jnp.int32)
    ids_sh = NamedSharding(mesh, P(dp, None))
    flops_fwd = 2.0 * B * (
        cfg.n_fields * cfg.embed_dim  # lookups
        + sum(
            h * hp * cfg.n_fields * cfg.embed_dim
            for h, hp in zip(cfg.cin_layers, (cfg.n_fields, *cfg.cin_layers[:-1]))
        )
        + cfg.n_fields * cfg.embed_dim * cfg.mlp_dims[0]
        + sum(a * b for a, b in zip(cfg.mlp_dims, (*cfg.mlp_dims[1:], 1)))
    )

    if shape.kind == "forward":
        fn = lambda p, ids: xdeepfm.forward(cfg, p, ids)
        return Program(
            name=f"{arch.arch_id}:{shape.shape_id}", fn=fn,
            in_specs=(pspecs, ids_spec), in_shardings=(psh, ids_sh),
            model_flops=flops_fwd,
        )

    lbl_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    opt = _opt(arch)
    ospecs = jax.eval_shape(opt.init, pspecs)
    oaxes = opt.state_logical_axes(paxes)
    osh = _tree_shardings_none_ok(mesh, ospecs, oaxes)
    loss = lambda p, b: xdeepfm.loss_fn(cfg, p, b)
    step = make_train_step(loss, opt)
    return Program(
        name=f"{arch.arch_id}:{shape.shape_id}", fn=step,
        in_specs=(pspecs, ospecs, {"ids": ids_spec, "labels": lbl_spec}),
        in_shardings=(psh, osh, {"ids": ids_sh, "labels": NamedSharding(mesh, P(dp))}),
        donate=(0, 1),
        model_flops=3.0 * flops_fwd,
    )


# ---------------------------------------------------------------------------
# engine (k²-triples) programs — the paper's serving path
# ---------------------------------------------------------------------------


def _engine_forest_specs(cfg, mesh: Mesh):
    """Static arena shapes for the dry run (no store build, no allocation).

    Arena widths follow the paper's measured ~5 bits/triple at dbpedia
    sparsity (Table 2: 0.864 GB / 232 M triples ≈ 32 bits/triple incl.
    dictionary; structure-only ≈ 5) with a 4× safety factor, padded to the
    mesh.  The REAL store builder produces exact shapes; serving programs
    are re-lowered per store shape bucket in production.
    """
    from repro.core.k2tree import K2Meta, hybrid_ks

    P_pad = _pad_to(cfg.n_preds, mesh.shape["model"])
    extent = max(cfg.n_subjects, cfg.n_objects)
    meta = K2Meta(hybrid_ks(extent))
    H = meta.n_levels
    bits_per_tree = max(4096, 20 * cfg.n_triples // cfg.n_preds)
    wt = (bits_per_tree * 3 // 4 + 31) // 32
    wl = (bits_per_tree // 4 + 31) // 32
    from repro.core.k2forest import K2Forest

    return meta, K2Forest(
        t_words=jax.ShapeDtypeStruct((P_pad, wt), jnp.uint32),
        t_rank=jax.ShapeDtypeStruct((P_pad, wt), jnp.int32),
        l_words=jax.ShapeDtypeStruct((P_pad, wl), jnp.uint32),
        ones_before=jax.ShapeDtypeStruct((P_pad, max(H - 1, 1)), jnp.int32),
        level_start=jax.ShapeDtypeStruct((P_pad, H), jnp.int32),
        nnz=jax.ShapeDtypeStruct((P_pad,), jnp.int32),
    )


def build_engine(arch: cb.ArchSpec, shape: cb.ShapeSpec, mesh: Mesh, *, smoke=False) -> Program:
    from repro.core import engine as eng

    cfg = arch.smoke_cfg if smoke else arch.cfg
    meta, fspecs = _engine_forest_specs(cfg, mesh)
    dp = meshlib.dp_axes(mesh)
    B = 256 if smoke else shape.dims["batch"]
    fsh = jax.tree.map(
        lambda s: NamedSharding(mesh, P("model")), fspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    qsh = NamedSharding(mesh, P(dp))

    if shape.dims.get("unbounded"):
        fn = eng.make_sharded_unbounded_scan(meta, mesh, cfg.cap, data_axes=dp)
        specs = (
            fspecs,
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        )
        return Program(
            name=f"{arch.arch_id}:{shape.shape_id}", fn=fn,
            in_specs=specs, in_shardings=(fsh, qsh, qsh),
            model_flops=2.0 * B * cfg.n_preds * cfg.cap * 4,
        )

    fn = eng.make_sharded_serve_step(meta, mesh, cfg.cap, data_axes=dp)
    q = eng.ServeBatch(
        op=jax.ShapeDtypeStruct((B,), jnp.int32),
        s=jax.ShapeDtypeStruct((B,), jnp.int32),
        p=jax.ShapeDtypeStruct((B,), jnp.int32),
        o=jax.ShapeDtypeStruct((B,), jnp.int32),
    )
    qsh_t = eng.ServeBatch(op=qsh, s=qsh, p=qsh, o=qsh)
    return Program(
        name=f"{arch.arch_id}:{shape.shape_id}", fn=fn,
        in_specs=(fspecs, q), in_shardings=(fsh, qsh_t),
        model_flops=2.0 * B * cfg.cap * meta.n_levels * 2,
    )


# ---------------------------------------------------------------------------


def build(arch_id: str, shape_id: str, mesh: Mesh, *, smoke: bool = False) -> Program:
    arch = cb.get(arch_id)
    shape = arch.shape(shape_id)
    if shape.skip:
        raise ValueError(f"{arch_id}:{shape_id} skipped: {shape.skip}")
    builder = {
        "lm": build_lm,
        "gnn": build_gnn,
        "recsys": build_recsys,
        "engine": build_engine,
    }[arch.family]
    return builder(arch, shape, mesh, smoke=smoke)


def all_cells(include_engine: bool = True):
    for arch_id, arch in cb.ARCHS.items():
        if arch.family == "engine" and not include_engine:
            continue
        for s in arch.shapes:
            if not s.skip:
                yield arch_id, s.shape_id
