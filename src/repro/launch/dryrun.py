import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first backend init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioner accepts it),
  * the program fits (memory_analysis),
  * and extracts the §Roofline terms (cost_analysis + HLO collective parse).

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
    python -m repro.launch.dryrun --all --out results/dryrun

Results are appended as JSON (one file per cell) so a crashed sweep resumes
where it left off (--force recompiles).
"""

import argparse
import json
import time
import traceback


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, out_dir: str,
             force: bool = False, verbose: bool = True) -> dict:
    import jax

    from repro.configs import get
    from repro.launch import flopcount, programs, roofline
    from repro.launch.mesh import make_production_mesh

    mesh_name = "2x16x16" if multi_pod else "16x16"
    key = f"{arch_id}__{shape_id}__{mesh_name}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_name, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        prog = programs.build(arch_id, shape_id, mesh)
        with mesh:
            jcost = flopcount.count(prog.fn, *prog.in_specs)
            jitted = jax.jit(
                prog.fn, in_shardings=prog.in_shardings, donate_argnums=prog.donate
            )
            lowered = jitted.lower(*prog.in_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            r = roofline.analyze(
                prog.name, mesh_name, mesh.devices.size, compiled,
                prog.model_flops, jcost.flops,
            )
        rec.update(r.to_dict())
        # XLA:CPU lowers bf16 dots via f32 converts of whole buffers (hoisted
        # out of loops).  On TPU the MXU consumes bf16 natively, so these f32
        # copies don't exist — quantify them so HBM fit is judged fairly.
        import re as _re

        artifact = 0
        for mm in _re.finditer(
            r"f32\[([0-9,]+)\][^=]* convert\(.*bf16\[", compiled.as_text()
        ):
            n = 1
            for d in mm.group(1).split(","):
                n *= int(d)
            if n * 4 >= 1 << 26:  # only count >=64MB buffers
                artifact += n * 4
        rec.update(
            ok=True,
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            memory_analysis=str(ma),
            arg_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
            temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
            out_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
            cpu_bf16_upcast_artifact_bytes=int(artifact),
        )
        if verbose:
            print(roofline.fmt_row(r), f"[lower {t_lower:.0f}s compile {t_compile:.0f}s]", flush=True)
            print("  mem:", str(ma), flush=True)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"FAIL {key}: {rec['error']}", flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch import programs

    meshes = [False, True]
    if args.multipod_only:
        meshes = [True]
    if args.singlepod_only:
        meshes = [False]

    cells = (
        list(programs.all_cells())
        if args.all
        else [(args.arch, args.shape)]
    )
    n_ok = n_fail = 0
    for arch_id, shape_id in cells:
        for mp in meshes:
            rec = run_cell(arch_id, shape_id, mp, args.out, force=args.force)
            n_ok += bool(rec.get("ok"))
            n_fail += not rec.get("ok")
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
