"""Streaming multi-tenant serve broker over compiled ``ServeQ`` plans.

The production front-end the ROADMAP north star asks for: many tenants
submit single queries as async streams; the broker coalesces them into
mixed-op ``ServeBatch``es under a deadline/size policy, double-buffers
host-side decode against device serve, and streams each tenant's results
back the moment its lanes decode — no batch-level result object is ever
materialized for callers.

    broker = ServeBroker(engine, ExecConfig(cap=512))
    async with broker:
        objs = await broker.submit("tenant-a", eng.OP_ROW, s=12, p=3)

Pipeline (one background task)::

    submit() ──▶ global FIFO ──▶ coalesce (deadline/size) ──▶ Plan.submit
                                                              (device, async)
         futures ◀── per-lane streamed decode ◀── host_result ◀─┘
                     (batch N decodes while batch N+1 runs on device)

Isolation properties
--------------------

* **The shared base plan never grows.**  Dispatch rides ``Plan.submit`` —
  the raw device path with no CapPolicy growth — so one tenant's
  overflowing queries cannot recompile (or widen) the program every other
  tenant is served by.
* **Cap growth is per tenant and budgeted.**  Lanes whose ``overflow`` bit
  is set are retried on doubled-cap plans compiled under that tenant's
  :class:`TenantPolicy` budget (``max_cap_doublings``); a tenant that
  exhausts its budget gets :class:`~repro.core.query.CapOverflow` on that
  query while everyone else proceeds at base cap.
* **Plan-cache admission is quota'd.**  Every retry cap level is a plan
  the engine must compile; ``Engine.compile(admit=...)`` charges the
  tenant's ``max_plans`` quota on cache MISSES only — plans another tenant
  already compiled are shared free of charge — and denial surfaces as
  :class:`~repro.core.query.AdmissionError` on the offending query.

Back-pressure (the shed policy)
-------------------------------

Per-tenant queues are bounded at ``TenantPolicy.queue_depth`` *accepted
but unresolved* requests.  The policy is **shed-newest, fail-fast**: a
submit over the bound raises :class:`QueueFull` immediately (counted in
``stats()``) and nothing already accepted is ever dropped — so a flooding
tenant sees its own rejections synchronously while other tenants' queues
and latency are untouched.

Ordering
--------

Per-tenant FIFO: results resolve in submission order.  Batches decode in
dispatch order, lanes decode in lane order, and a tenant with a retried
(overflowed) lane has its later lanes in that batch held until the retry
lands — so growth never reorders a stream.

Stats
-----

``stats()`` returns a structured dict: global and per-tenant query
latency percentiles (``p50_ms``/``p99_ms`` via :func:`tail_percentile`,
which refuses sample counts that cannot support a tail quantile), queue
depth + peak, coalesce factor, flush-reason counts, shed counts, and
cap-growth / admission-denial events.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import math
import time

import numpy as np

from repro.core import engine as eng
from repro.core.query import (
    AdmissionError, CapOverflow, CapPolicy, ExecConfig, ServeQ,
)

__all__ = [
    "CoalescePolicy", "TenantPolicy", "QueueFull", "ServeBroker",
    "tail_percentile",
]


class QueueFull(RuntimeError):
    """Shed signal: the tenant's bounded queue is at ``queue_depth``.

    Raised synchronously by ``submit``/``submit_nowait`` (shed-newest,
    fail-fast — see the module docstring); the request was NOT enqueued.
    """


@dataclasses.dataclass(frozen=True)
class CoalescePolicy:
    """When pending requests flush into a device batch.

    A batch dispatches when ``max_batch`` requests are pending OR the
    oldest pending request has waited ``max_delay_s`` — whichever comes
    first.  Batches are padded to ``max_batch`` with dead (op = -1) lanes
    so every dispatch hits ONE compiled program geometry (no retraces).
    ``max_inflight`` bounds device batches awaiting decode; 2 is the
    double-buffer: batch N decodes on host while N+1 runs on device.
    """

    max_batch: int = 256
    max_delay_s: float = 2e-3
    max_inflight: int = 2

    def __post_init__(self):
        if self.max_batch < 1 or self.max_inflight < 1:
            raise ValueError("max_batch and max_inflight must be >= 1")
        if self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant admission + back-pressure budgets (one policy, applied
    to every tenant; tenants are created on first submit).

    ``queue_depth``
        Accepted-but-unresolved request bound; beyond it submissions shed
        (:class:`QueueFull`).
    ``max_cap_doublings``
        Cap-growth budget: how many times this tenant's overflowing
        queries may double the retry cap above the broker's base cap.
    ``max_plans``
        Plan-cache quota: how many plan-cache MISSES (new compiled
        programs — one per distinct retry cap level) the tenant may
        charge.  Shared cache hits are free.
    """

    queue_depth: int = 1024
    max_cap_doublings: int = 4
    max_plans: int = 4

    def __post_init__(self):
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if self.max_cap_doublings < 0 or self.max_plans < 0:
            raise ValueError("budgets must be >= 0")


def tail_percentile(samples, q: float) -> float | None:
    """``np.percentile`` guarded by sample count: ``None`` unless there are
    at least ``ceil(100 / (100 - q))`` samples — the minimum for the q-th
    percentile to be interpolated between order statistics rather than
    being a relabeled maximum (p99 needs 100 samples, p50 needs 2)."""
    n = len(samples)
    if not 0 <= q < 100:
        raise ValueError(f"q must be in [0, 100), got {q}")
    need = max(1, math.ceil(100.0 / (100.0 - q)))
    if n < need:
        return None
    return float(np.percentile(np.asarray(samples), q))


@dataclasses.dataclass
class _Req:
    tenant: str
    op: int
    s: int
    p: int
    o: int
    t_submit: float
    future: asyncio.Future


@dataclasses.dataclass
class _TenantState:
    name: str
    pending: int = 0  # accepted, not yet resolved
    shed: int = 0
    completed: int = 0
    failed: int = 0
    cap_level: int = 0  # highest doubling level this tenant reached
    plans_charged: int = 0  # plan-cache misses charged against max_plans
    cap_growth_events: int = 0
    admission_denials: int = 0
    lat_s: list = dataclasses.field(default_factory=list)


class ServeBroker:
    """Async multi-tenant request broker over one ``Engine``.

    Use as an async context manager (or ``start()`` / ``aclose()``)::

        async with ServeBroker(engine, cfg) as broker:
            hit = await broker.submit("t0", eng.OP_CHECK, s, p, o)

    ``unbounded=False`` compiles the ``u_*`` block out of the base plan —
    a broker serving only CHECK/ROW/COL traffic never pays for it (and
    the decode fetch skips the ``[B, L, cap]`` transfer either way when a
    batch carries no unbounded lanes).
    """

    def __init__(
        self,
        engine: eng.Engine,
        config: ExecConfig | None = None,
        *,
        unbounded: bool = True,
        coalesce: CoalescePolicy = CoalescePolicy(),
        tenant_policy: TenantPolicy = TenantPolicy(),
    ):
        self.engine = engine
        cfg = (config or engine.default_config).resolved()
        # growth is broker-managed (per tenant); the base plan must never
        # self-heal behind the broker's back
        self.config = cfg.replace(cap_policy=CapPolicy(grow=False))
        self.coalesce = coalesce
        self.tenant_policy = tenant_policy
        self.unbounded = unbounded
        self._query = ServeQ(unbounded=unbounded)
        self.base_plan = engine.compile(self._query, self.config)
        # data-axis divisibility for sharded dispatch geometries
        self._pad_to = self._padded_batch(coalesce.max_batch)

        self._queue: collections.deque[_Req] = collections.deque()
        self._inflight: collections.deque = collections.deque()
        self._tenants: dict[str, _TenantState] = {}
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self._draining = False
        self._running = False
        self._stats = collections.Counter()
        self._queue_peak = 0

    # -- lifecycle ------------------------------------------------------

    async def __aenter__(self) -> "ServeBroker":
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    async def start(self) -> None:
        if self._running:
            raise RuntimeError("broker already started")
        self._wake = asyncio.Event()
        self._draining = False
        self._running = True
        self._task = asyncio.get_running_loop().create_task(self._run())

    async def aclose(self) -> None:
        """Drain: serve everything accepted, then stop the loop."""
        if not self._running:
            return
        self._draining = True
        self._wake.set()
        await self._task
        self._running = False

    # -- submission -----------------------------------------------------

    def submit_nowait(self, tenant: str, op: int, s: int = 0, p: int = 0,
                      o: int = 0) -> asyncio.Future:
        """Enqueue one query; the future resolves to its decoded answer
        (see ``engine.decode_lane`` for per-op shapes).  Raises
        :class:`QueueFull` when the tenant's queue is at ``queue_depth``
        (the shed policy) and ``RuntimeError`` when the broker is not
        accepting."""
        if not self._running or self._draining:
            raise RuntimeError("broker is not accepting requests")
        st = self._tenant(tenant)
        if st.pending >= self.tenant_policy.queue_depth:
            st.shed += 1
            self._stats["shed"] += 1
            raise QueueFull(
                f"tenant {tenant!r} at queue_depth="
                f"{self.tenant_policy.queue_depth}; shed-newest"
            )
        st.pending += 1
        fut = asyncio.get_running_loop().create_future()
        self._queue.append(
            _Req(tenant, int(op), int(s), int(p), int(o),
                 time.perf_counter(), fut)
        )
        self._queue_peak = max(self._queue_peak, len(self._queue))
        self._wake.set()
        return fut

    async def submit(self, tenant: str, op: int, s: int = 0, p: int = 0,
                     o: int = 0):
        return await self.submit_nowait(tenant, op, s, p, o)

    async def stream(self, tenant: str, queries):
        """Submit a tenant's query stream, yielding results in submission
        order.  ``queries`` is an iterable of ``(op, s, p, o)``.  The
        whole stream is admitted through the same bounded queue — a
        :class:`QueueFull` shed propagates to the caller mid-stream."""
        window: collections.deque[asyncio.Future] = collections.deque()
        for (op, s, p, o) in queries:
            while window and window[0].done():
                yield await window.popleft()
            # stay inside the tenant's queue bound: wait for the oldest
            # outstanding result instead of shedding our own stream
            while (
                window
                and self._tenant(tenant).pending >= self.tenant_policy.queue_depth
            ):
                yield await window.popleft()
            window.append(self.submit_nowait(tenant, op, s, p, o))
        while window:
            yield await window.popleft()

    # -- the serve loop -------------------------------------------------

    async def _run(self):
        while True:
            if len(self._inflight) >= self.coalesce.max_inflight:
                await self._deliver(*self._inflight.popleft())
                continue
            reqs = await self._collect(block=not self._inflight)
            if reqs:
                self._dispatch(reqs)
            elif self._inflight:
                await self._deliver(*self._inflight.popleft())
            elif self._draining and not self._queue:
                return

    async def _collect(self, *, block: bool) -> list[_Req]:
        pol = self.coalesce
        while not self._queue:
            if not block or self._draining:
                return []
            self._wake.clear()
            await self._wake.wait()
        # deadline of the OLDEST pending request governs the flush
        deadline = self._queue[0].t_submit + pol.max_delay_s
        while len(self._queue) < pol.max_batch and not self._draining:
            now = time.perf_counter()
            if now >= deadline:
                break
            self._wake.clear()
            try:
                await asyncio.wait_for(self._wake.wait(), deadline - now)
            except asyncio.TimeoutError:
                break
        if len(self._queue) >= pol.max_batch:
            self._stats["flush_size"] += 1
        elif self._draining:
            self._stats["flush_drain"] += 1
        else:
            self._stats["flush_deadline"] += 1
        n = min(len(self._queue), pol.max_batch)
        return [self._queue.popleft() for _ in range(n)]

    def _dispatch(self, reqs: list[_Req]):
        qb = self._encode(reqs, self._pad_to)
        raw = self.base_plan.submit(qb)  # async device dispatch, no sync
        self._inflight.append((raw, reqs))
        self._stats["batches"] += 1
        self._stats["lanes"] += len(reqs)

    def _encode(self, reqs: list[_Req], pad_to: int) -> eng.ServeBatch:
        n = max(pad_to, self._padded_batch(len(reqs)))
        op = np.full(n, -1, np.int32)  # dead lanes: masked to zero output
        s = np.zeros(n, np.int32)
        p = np.zeros(n, np.int32)
        o = np.zeros(n, np.int32)
        for i, r in enumerate(reqs):
            op[i], s[i], p[i], o[i] = r.op, r.s, r.p, r.o
        return eng.ServeBatch(op=op, s=s, p=p, o=o)

    def _padded_batch(self, b: int) -> int:
        """pow2 bucket (>= 8), then data-axis divisibility when sharded."""
        n = 8
        while n < b:
            n <<= 1
        cfg = self.config
        if cfg.mesh is not None:
            d = int(np.prod([cfg.mesh.shape[a] for a in cfg.data_axes]))
            n = ((max(n, d) + d - 1) // d) * d
        return n

    # -- streamed decode + per-tenant growth ----------------------------

    async def _deliver(self, raw, reqs: list[_Req]):
        has_u = any(r.op in eng._UNBOUNDED_OPS for r in reqs)
        # the blocking device->host fetch runs off-loop so submitters keep
        # filling the next batch while this one decodes
        host = await asyncio.to_thread(
            eng.host_result, raw, unbounded=has_u and self.unbounded
        )
        retry_tenants = {
            reqs[i].tenant
            for i in np.nonzero(host.overflow[: len(reqs)])[0]
        }
        for i, r in enumerate(reqs):
            # streamed delivery: every lane of an unaffected tenant
            # resolves here, before any retry work happens
            if r.tenant not in retry_tenants:
                self._resolve(r, eng.decode_lane(r.op, host, i))
        for tenant in sorted(retry_tenants):
            # per-tenant FIFO: the whole segment of a tenant with a
            # retried lane is held and re-released in submission order
            segment = [(i, r) for i, r in enumerate(reqs) if r.tenant == tenant]
            await self._retry_tenant(tenant, segment, host)

    def _resolve(self, r: _Req, value):
        st = self._tenants[r.tenant]
        st.pending -= 1
        st.completed += 1
        st.lat_s.append(time.perf_counter() - r.t_submit)
        if not r.future.cancelled():
            r.future.set_result(value)

    def _fail(self, r: _Req, exc: BaseException):
        st = self._tenants[r.tenant]
        st.pending -= 1
        st.failed += 1
        if not r.future.cancelled():
            r.future.set_exception(exc)

    async def _retry_tenant(self, tenant, segment, host):
        """Re-run a tenant's overflowed lanes on doubled-cap plans, then
        release its held segment in submission order."""
        grow = [(i, r) for (i, r) in segment if bool(host.overflow[i])]
        try:
            done = await asyncio.to_thread(
                self._grow_and_run, tenant, [r for (_, r) in grow]
            )
            regrown, err = dict(zip((i for i, _ in grow), done)), None
        except (CapOverflow, AdmissionError) as e:
            regrown, err = {}, e
        for i, r in segment:
            if i in regrown:
                self._resolve(r, regrown[i])
            elif err is not None and bool(host.overflow[i]):
                self._fail(r, err)
            else:
                self._resolve(r, eng.decode_lane(r.op, host, i))

    def _grow_and_run(self, tenant: str, rs: list[_Req]):
        """Blocking (off-loop) escalation: double the cap from the tenant's
        remembered level until the lanes fit or the budget runs out."""
        st = self._tenants[tenant]
        pol = self.tenant_policy
        level = max(st.cap_level, 1)
        while True:
            if level > pol.max_cap_doublings:
                raise CapOverflow(
                    f"tenant {tenant!r} exhausted its cap budget "
                    f"(max_cap_doublings={pol.max_cap_doublings})"
                )
            cap = self.config.cap << level
            cfg = self.config.replace(cap=cap, cap_y=self.config.cap_y << level)
            try:
                plan = self.engine.compile(
                    self._query, cfg, admit=self._admit(st)
                )
            except AdmissionError:
                st.admission_denials += 1
                self._stats["admission_denials"] += 1
                raise
            st.cap_growth_events += 1
            self._stats["cap_growth_events"] += 1
            st.cap_level = max(st.cap_level, level)
            qb = self._encode(rs, 0)
            host = eng.host_result(
                plan.submit(qb),
                unbounded=any(r.op in eng._UNBOUNDED_OPS for r in rs),
            )
            if not host.overflow[: len(rs)].any():
                return [
                    eng.decode_lane(r.op, host, i) for i, r in enumerate(rs)
                ]
            level += 1

    def _admit(self, st: _TenantState):
        """The per-tenant plan-cache admission closure: charge MISSES
        against ``max_plans`` (the engine never calls this on a hit)."""

        def admit(_key):
            if st.plans_charged >= self.tenant_policy.max_plans:
                return False
            st.plans_charged += 1
            return True

        return admit

    def _tenant(self, name: str) -> _TenantState:
        st = self._tenants.get(name)
        if st is None:
            st = self._tenants[name] = _TenantState(name)
        return st

    # -- stats ----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero counters and latency samples — the benchmark warmup
        boundary.  Admission state (cap levels, plan charges) is retained:
        it is real broker state, not measurement."""
        self._stats.clear()
        self._queue_peak = 0
        for st in self._tenants.values():
            st.lat_s.clear()
            st.completed = st.failed = st.shed = 0

    def stats(self) -> dict:
        """Structured serving stats (JSON-ready)."""
        all_lat = [t for st in self._tenants.values() for t in st.lat_s]
        batches = int(self._stats["batches"])
        return {
            "batches": batches,
            "lanes": int(self._stats["lanes"]),
            "coalesce_factor": (
                self._stats["lanes"] / batches if batches else 0.0
            ),
            "flush_size": int(self._stats["flush_size"]),
            "flush_deadline": int(self._stats["flush_deadline"]),
            "flush_drain": int(self._stats["flush_drain"]),
            "queue_depth": len(self._queue),
            "queue_peak": self._queue_peak,
            "shed": int(self._stats["shed"]),
            "cap_growth_events": int(self._stats["cap_growth_events"]),
            "admission_denials": int(self._stats["admission_denials"]),
            "queries": len(all_lat),
            "p50_ms": _ms(tail_percentile(all_lat, 50)),
            "p99_ms": _ms(tail_percentile(all_lat, 99)),
            "tenants": {
                name: {
                    "queries": st.completed,
                    "failed": st.failed,
                    "shed": st.shed,
                    "pending": st.pending,
                    "cap_level": st.cap_level,
                    "plans_charged": st.plans_charged,
                    "cap_growth_events": st.cap_growth_events,
                    "p50_ms": _ms(tail_percentile(st.lat_s, 50)),
                    "p99_ms": _ms(tail_percentile(st.lat_s, 99)),
                }
                for name, st in sorted(self._tenants.items())
            },
        }


def _ms(v: float | None) -> float | None:
    return None if v is None else v * 1e3
