"""Streaming multi-tenant serve broker over compiled ``ServeQ`` plans.

The production front-end the ROADMAP north star asks for: many tenants
submit single queries as async streams; the broker coalesces them into
mixed-op ``ServeBatch``es under a deadline/size policy, double-buffers
host-side decode against device serve, and streams each tenant's results
back the moment its lanes decode — no batch-level result object is ever
materialized for callers.

    broker = ServeBroker(engine, ExecConfig(cap=512))
    async with broker:
        objs = await broker.submit("tenant-a", eng.OP_ROW, s=12, p=3)

Pipeline (one background task)::

    submit() ──▶ global FIFO ──▶ coalesce (deadline/size) ──▶ Plan.submit
                                                              (device, async)
         futures ◀── per-lane streamed decode ◀── host_result ◀─┘
                     (batch N decodes while batch N+1 runs on device)

Isolation properties
--------------------

* **The shared base plan never grows.**  Dispatch rides ``Plan.submit`` —
  the raw device path with no CapPolicy growth — so one tenant's
  overflowing queries cannot recompile (or widen) the program every other
  tenant is served by.
* **Cap growth is per tenant and budgeted.**  Lanes whose ``overflow`` bit
  is set are retried on doubled-cap plans compiled under that tenant's
  :class:`TenantPolicy` budget (``max_cap_doublings``); a tenant that
  exhausts its budget gets :class:`~repro.core.query.CapOverflow` on that
  query while everyone else proceeds at base cap.
* **Plan-cache admission is quota'd.**  Every retry cap level is a plan
  the engine must compile; ``Engine.compile(admit=...)`` charges the
  tenant's ``max_plans`` quota on cache MISSES only — plans another tenant
  already compiled are shared free of charge — and denial surfaces as
  :class:`~repro.core.query.AdmissionError` on the offending query.

Back-pressure (the shed policy)
-------------------------------

Per-tenant queues are bounded at ``TenantPolicy.queue_depth`` *accepted
but unresolved* requests.  The policy is **shed-newest, fail-fast**: a
submit over the bound raises :class:`QueueFull` immediately (counted in
``stats()``) and nothing already accepted is ever dropped — so a flooding
tenant sees its own rejections synchronously while other tenants' queues
and latency are untouched.

Ordering
--------

Per-tenant FIFO: results resolve in submission order.  Batches decode in
dispatch order, lanes decode in lane order, and a tenant with a retried
(overflowed) lane has its later lanes in that batch held until the retry
lands — so growth never reorders a stream.

Writes (dynamic stores)
-----------------------

When the engine serves a :class:`~repro.core.delta.DynamicStore`,
``submit_insert`` / ``submit_delete`` apply live mutations to its delta —
synchronously (an in-memory set op), budgeted per tenant by
``TenantPolicy.max_writes`` (:class:`WriteBudgetExhausted` past the
bound; the budget refills at compaction).  Reads stay on the raw static
lane: dispatch pins the delta view, sanitizes lanes whose constants
exceed the static extents, and decode merges the delta host-side —
(static − tombstones) ∪ inserts per lane — off the event loop.  With a
:class:`~repro.core.compaction.CompactionPolicy`, a write that trips the
threshold schedules a background compaction; the epoch swap is atomic,
in-flight batches finish against the old epoch, and the base plan is
rebuilt eagerly so the serve loop never pays a ``StaleEpoch`` round-trip.

Stats
-----

``stats()`` returns a structured dict: global and per-tenant query
latency percentiles (``p50_ms``/``p99_ms`` via :func:`tail_percentile`,
which refuses sample counts that cannot support a tail quantile), queue
depth + peak, coalesce factor, flush-reason counts, shed counts, and
cap-growth / admission-denial events.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import math
import time
import warnings

import numpy as np

from repro import obs
from repro.core import delta as dyn
from repro.core import engine as eng
from repro.core.compaction import CompactionPolicy, compact, needs_compaction
from repro.core.query import (
    AdmissionError, CapOverflow, CapPolicy, ExecConfig, SelectQ, ServeQ,
    StaleEpoch,
)
from repro.obs import LATENCY_MS_BUCKETS, MetricsRegistry

__all__ = [
    "CoalescePolicy", "TenantPolicy", "QueueFull", "ServeBroker",
    "WriteBudgetExhausted", "tail_percentile",
]


class QueueFull(RuntimeError):
    """Shed signal: the tenant's bounded queue is at ``queue_depth``.

    Raised synchronously by ``submit``/``submit_nowait`` (shed-newest,
    fail-fast — see the module docstring); the request was NOT enqueued.
    """


class WriteBudgetExhausted(RuntimeError):
    """The tenant spent its ``TenantPolicy.max_writes`` budget.

    Raised synchronously by ``submit_insert``/``submit_delete``; the write
    was NOT applied.  The budget is resident-delta-based: it refills when
    a compaction folds the delta into a new static epoch, so a sustained
    writer is paced by the compactor rather than cut off forever.
    """


@dataclasses.dataclass(frozen=True)
class CoalescePolicy:
    """When pending requests flush into a device batch.

    A batch dispatches when ``max_batch`` requests are pending OR the
    oldest pending request has waited ``max_delay_s`` — whichever comes
    first.  Batches are padded to ``max_batch`` with dead (op = -1) lanes
    so every dispatch hits ONE compiled program geometry (no retraces).
    ``max_inflight`` bounds device batches awaiting decode; 2 is the
    double-buffer: batch N decodes on host while N+1 runs on device.
    """

    max_batch: int = 256
    max_delay_s: float = 2e-3
    max_inflight: int = 2

    def __post_init__(self):
        if self.max_batch < 1 or self.max_inflight < 1:
            raise ValueError("max_batch and max_inflight must be >= 1")
        if self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant admission + back-pressure budgets (one policy, applied
    to every tenant; tenants are created on first submit).

    ``queue_depth``
        Accepted-but-unresolved request bound; beyond it submissions shed
        (:class:`QueueFull`).
    ``max_cap_doublings``
        Cap-growth budget: how many times this tenant's overflowing
        queries may double the retry cap above the broker's base cap.
    ``max_plans``
        Plan-cache quota: how many plan-cache MISSES (new compiled
        programs — one per distinct retry cap level) the tenant may
        charge.  Shared cache hits are free.
    ``max_writes``
        Write budget: how many inserts + deletes the tenant may have
        resident in the delta at once; refilled when compaction folds
        the delta down (:class:`WriteBudgetExhausted` past the bound).
    """

    queue_depth: int = 1024
    max_cap_doublings: int = 4
    max_plans: int = 4
    max_writes: int = 4096

    def __post_init__(self):
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if self.max_cap_doublings < 0 or self.max_plans < 0:
            raise ValueError("budgets must be >= 0")
        if self.max_writes < 1:
            raise ValueError("max_writes must be >= 1")


def tail_percentile(samples, q: float) -> float | None:
    """``np.percentile`` guarded by sample count: ``None`` unless there are
    at least ``ceil(100 / (100 - q))`` samples — the minimum for the q-th
    percentile to be interpolated between order statistics rather than
    being a relabeled maximum (p99 needs 100 samples, p50 needs 2)."""
    n = len(samples)
    if not 0 <= q < 100:
        raise ValueError(f"q must be in [0, 100), got {q}")
    need = max(1, math.ceil(100.0 / (100.0 - q)))
    if n < need:
        return None
    return float(np.percentile(np.asarray(samples), q))


# _Req.op marker for SELECT queries (real serve-IR ops are >= 0; dead
# lanes are -1): selects never ride the coalesced ServeBatch
OP_SELECT = -2


@dataclasses.dataclass
class _Req:
    tenant: str
    op: int
    s: int
    p: int
    o: int
    t_submit: float
    future: asyncio.Future
    seq: int = 0  # global submission sequence — the per-query trace id
    t_deliver: float = 0.0  # stamped at resolve/fail time


@dataclasses.dataclass
class _BatchMeta:
    """Timeline of one dispatched batch (``time.perf_counter`` seconds):
    coalesce ``[tc0, tc1]`` → encode+dispatch ``[td0, td1]`` → inflight →
    fetch ``[tf0, tf1]`` → decode/deliver.  Feeds the retroactive trace
    spans emitted once the batch fully delivers."""

    bid: int
    n_padded: int
    tc0: float = 0.0
    tc1: float = 0.0
    td0: float = 0.0
    td1: float = 0.0
    tf0: float = 0.0
    tf1: float = 0.0


@dataclasses.dataclass
class _TenantState:
    name: str
    pending: int = 0  # accepted, not yet resolved
    shed: int = 0
    completed: int = 0
    failed: int = 0
    cap_level: int = 0  # highest doubling level this tenant reached
    plans_charged: int = 0  # plan-cache misses charged against max_plans
    cap_growth_events: int = 0
    admission_denials: int = 0
    inserts: int = 0
    deletes: int = 0
    writes_resident: int = 0  # writes in the live delta (budget state)
    lat_s: list = dataclasses.field(default_factory=list)


class ServeBroker:
    """Async multi-tenant request broker over one ``Engine``.

    Use as an async context manager (or ``start()`` / ``aclose()``)::

        async with ServeBroker(engine, cfg) as broker:
            hit = await broker.submit("t0", eng.OP_CHECK, s, p, o)

    ``unbounded=False`` compiles the ``u_*`` block out of the base plan —
    a broker serving only CHECK/ROW/COL traffic never pays for it (and
    the decode fetch skips the ``[B, L, cap]`` transfer either way when a
    batch carries no unbounded lanes).
    """

    def __init__(
        self,
        engine: eng.Engine,
        config: ExecConfig | None = None,
        *,
        unbounded: bool = True,
        coalesce: CoalescePolicy = CoalescePolicy(),
        tenant_policy: TenantPolicy = TenantPolicy(),
        compaction: CompactionPolicy | None = None,
    ):
        self.engine = engine
        self.compaction = compaction
        cfg = (config or engine.default_config).resolved()
        # growth is broker-managed (per tenant); the base plan must never
        # self-heal behind the broker's back
        self.config = cfg.replace(cap_policy=CapPolicy(grow=False))
        self.coalesce = coalesce
        self.tenant_policy = tenant_policy
        self.unbounded = unbounded
        self._query = ServeQ(unbounded=unbounded)
        self.base_plan = engine.compile(self._query, self.config)
        # data-axis divisibility for sharded dispatch geometries
        self._pad_to = self._padded_batch(coalesce.max_batch)

        self._queue: collections.deque[_Req] = collections.deque()
        self._inflight: collections.deque = collections.deque()
        self._tenants: dict[str, _TenantState] = {}
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self._draining = False
        self._running = False
        # ALWAYS-ON bookkeeping registry backing ``stats()`` — the typed
        # replacement for the old ad-hoc ``collections.Counter``.  The
        # obs-layer extras (timing histograms, spans) live in the global
        # ``repro.obs`` state and only run while observability is enabled.
        self.metrics = MetricsRegistry()
        self._c = {
            name: self.metrics.counter(f"broker.{name}")
            for name in (
                "batches", "lanes", "flush_size", "flush_deadline",
                "flush_drain", "shed", "cap_growth_events",
                "admission_denials", "selects", "inserts", "deletes",
                "compactions", "compaction_ms", "compaction_errors",
            )
        }
        self._compaction_task: asyncio.Task | None = None
        # SELECT queries run off-loop (each is a host-planned multi-launch
        # pipeline, not a lane); the semaphore bounds their thread fanout
        self._select_sem = asyncio.Semaphore(max(2, coalesce.max_inflight))
        self._select_tasks: set[asyncio.Task] = set()
        self._queue_peak = 0
        self._seq = 0  # per-query trace ids
        self._bid = 0  # batch ids
        self._retry_cfgs: set[ExecConfig] = set()  # cap levels ever compiled

    # -- lifecycle ------------------------------------------------------

    async def __aenter__(self) -> "ServeBroker":
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    async def start(self) -> None:
        if self._running:
            raise RuntimeError("broker already started")
        self._wake = asyncio.Event()
        self._draining = False
        self._running = True
        self._task = asyncio.get_running_loop().create_task(self._run())

    async def aclose(self) -> None:
        """Drain: serve everything accepted, then stop the loop."""
        if not self._running:
            return
        self._draining = True
        self._wake.set()
        await self._task
        if self._select_tasks:  # selects accepted before the drain finish
            await asyncio.gather(*self._select_tasks, return_exceptions=True)
        if self._compaction_task is not None and not self._compaction_task.done():
            await self._compaction_task
        self._running = False

    # -- submission -----------------------------------------------------

    def submit_nowait(self, tenant: str, op: int, s: int = 0, p: int = 0,
                      o: int = 0) -> asyncio.Future:
        """Enqueue one query; the future resolves to its decoded answer
        (see ``engine.decode_lane`` for per-op shapes).  Raises
        :class:`QueueFull` when the tenant's queue is at ``queue_depth``
        (the shed policy) and ``RuntimeError`` when the broker is not
        accepting."""
        if not self._running or self._draining:
            raise RuntimeError("broker is not accepting requests")
        st = self._tenant(tenant)
        if st.pending >= self.tenant_policy.queue_depth:
            st.shed += 1
            self._c["shed"].inc()
            raise QueueFull(
                f"tenant {tenant!r} at queue_depth="
                f"{self.tenant_policy.queue_depth}; shed-newest"
            )
        st.pending += 1
        fut = asyncio.get_running_loop().create_future()
        self._queue.append(
            _Req(tenant, int(op), int(s), int(p), int(o),
                 time.perf_counter(), fut, seq=self._seq)
        )
        self._seq += 1
        self._queue_peak = max(self._queue_peak, len(self._queue))
        self._wake.set()
        return fut

    async def submit(self, tenant: str, op: int, s: int = 0, p: int = 0,
                     o: int = 0):
        return await self.submit_nowait(tenant, op, s, p, o)

    # -- the write path -------------------------------------------------

    def submit_insert_nowait(self, tenant: str, s: int, p: int, o: int) -> None:
        """Insert one id triple into the dynamic store's delta.

        Writes apply synchronously (a delta insert is an in-memory set op
        — there is nothing to coalesce or await) and become visible to
        every batch dispatched after this call.  Requires the engine to
        serve a :class:`~repro.core.delta.DynamicStore`; raises
        :class:`WriteBudgetExhausted` when the tenant's resident-write
        budget (``TenantPolicy.max_writes``) is spent — it refills at the
        next compaction.  May schedule a background compaction when a
        :class:`~repro.core.compaction.CompactionPolicy` was configured.
        """
        self._write(tenant, s, p, o, insert=True)

    async def submit_insert(self, tenant: str, s: int, p: int, o: int) -> None:
        self.submit_insert_nowait(tenant, s, p, o)

    def submit_delete_nowait(self, tenant: str, s: int, p: int, o: int) -> None:
        """Delete one id triple (tombstone it in the delta).

        Same contract as :meth:`submit_insert_nowait`: synchronous,
        budgeted by ``max_writes``, compaction-triggering.
        """
        self._write(tenant, s, p, o, insert=False)

    async def submit_delete(self, tenant: str, s: int, p: int, o: int) -> None:
        self.submit_delete_nowait(tenant, s, p, o)

    def _write(self, tenant: str, s: int, p: int, o: int, *, insert: bool):
        if not self._running or self._draining:
            raise RuntimeError("broker is not accepting requests")
        store = self.engine.store
        if not isinstance(store, dyn.DynamicStore):
            raise TypeError(
                "writes need a DynamicStore; wrap the static store in "
                "repro.core.delta.DynamicStore"
            )
        st = self._tenant(tenant)
        if st.writes_resident >= self.tenant_policy.max_writes:
            raise WriteBudgetExhausted(
                f"tenant {tenant!r} has {st.writes_resident} writes resident "
                f"(max_writes={self.tenant_policy.max_writes}); budget "
                "refills at the next compaction"
            )
        if insert:
            store.insert(s, p, o)
            st.inserts += 1
            self._c["inserts"].inc()
        else:
            store.delete(s, p, o)
            st.deletes += 1
            self._c["deletes"].inc()
        st.writes_resident += 1
        self._maybe_compact()

    def _maybe_compact(self):
        """Kick a background compaction when the policy says the delta is
        due and none is already running.  The rebuild runs off-loop; the
        epoch swap is atomic and reads keep serving the old epoch until
        the swapped store lands (dispatch then sees ``StaleEpoch`` once
        and refreshes the base plan)."""
        if self.compaction is None or not needs_compaction(
            self.engine.store, self.compaction
        ):
            return
        if self._compaction_task is not None and not self._compaction_task.done():
            return
        task = asyncio.get_running_loop().create_task(self._run_compaction())
        task.add_done_callback(self._observe_compaction)
        self._compaction_task = task

    def _observe_compaction(self, task: asyncio.Task) -> None:
        """Surface a background-compaction failure when the task completes
        (not first at ``drain``): count it and warn.  The broker keeps
        serving the old epoch — the delta simply grows until the next
        write re-triggers the policy."""
        if task.cancelled():
            return
        exc = task.exception()
        if exc is not None:
            self._c["compaction_errors"].inc()
            warnings.warn(
                f"background compaction failed: {exc!r}", RuntimeWarning,
                stacklevel=2,
            )

    async def _run_compaction(self):
        # writes resident at this point are exactly the entries the pinned
        # snapshot will absorb (writes racing in during the rebuild stay
        # resident in the rebased delta and must keep paying budget) —
        # capture per tenant so the refill below decrements rather than
        # zeroing away still-resident raced writes.  A write landing
        # between this capture and the snapshot pin is absorbed but not
        # decremented: it stays counted, erring on the strict side.
        absorbed = {
            name: st.writes_resident for name, st in self._tenants.items()
        }
        with obs.span("broker.compaction", cat="broker"):
            rep = await asyncio.to_thread(
                compact, self.engine.store,
                backend=self.config.backend,
            )
        # the swap bumped the store epoch: every cached plan (base + retry
        # levels) is stale — rebuild the base plan eagerly so the serve
        # loop never pays the StaleEpoch round-trip.  Off the event loop:
        # Engine.compile is a full JAX trace+JIT and must not stall
        # intake/dispatch; a dispatch racing the refresh self-heals via
        # its own StaleEpoch recompile.
        await asyncio.to_thread(self._refresh_base_plan)
        for name, st in self._tenants.items():
            st.writes_resident = max(
                0, st.writes_resident - absorbed.get(name, 0)
            )
        self._c["compactions"].inc()
        self._c["compaction_ms"].inc(rep.duration_s * 1e3)
        m = obs.STATE.metrics
        if m is not None:
            m.gauge("broker.epoch").set(rep.epoch)
        return rep

    def _refresh_base_plan(self):
        self.base_plan = self.engine.compile(self._query, self.config)
        self._retry_cfgs.clear()  # stale cap levels; recompiled on demand

    def submit_select_nowait(self, tenant: str, q: SelectQ) -> asyncio.Future:
        """Enqueue one SPARQL-shaped :class:`~repro.core.query.SelectQ`;
        the future resolves to its columnar named bindings.

        Selects share the tenant's bounded queue (``queue_depth``) and its
        latency/completion stats with the lane path, but never ride the
        coalesced ``ServeBatch``: each executes off the event loop through
        ``Engine.compile`` with cap growth budgeted by the tenant's
        ``max_cap_doublings`` and plan-cache admission charged through the
        same ``max_plans`` quota (the compiled ``("select",)`` executor is
        shared across tenants — misses are charged to whoever compiles a
        cap level first, hits are free, exactly like retry plans).
        """
        if not self._running or self._draining:
            raise RuntimeError("broker is not accepting requests")
        st = self._tenant(tenant)
        if st.pending >= self.tenant_policy.queue_depth:
            st.shed += 1
            self._c["shed"].inc()
            raise QueueFull(
                f"tenant {tenant!r} at queue_depth="
                f"{self.tenant_policy.queue_depth}; shed-newest"
            )
        st.pending += 1
        fut = asyncio.get_running_loop().create_future()
        r = _Req(tenant, OP_SELECT, 0, 0, 0, time.perf_counter(), fut,
                 seq=self._seq)
        self._seq += 1
        self._c["selects"].inc()
        task = asyncio.get_running_loop().create_task(self._run_select(r, q))
        self._select_tasks.add(task)
        task.add_done_callback(self._select_tasks.discard)
        return fut

    async def submit_select(self, tenant: str, q: SelectQ):
        return await self.submit_select_nowait(tenant, q)

    async def _run_select(self, r: _Req, q: SelectQ):
        async with self._select_sem:
            try:
                value = await asyncio.to_thread(self._select_call, r, q)
            except (CapOverflow, AdmissionError) as e:
                st = self._tenants[r.tenant]
                if isinstance(e, AdmissionError):
                    st.admission_denials += 1
                    self._c["admission_denials"].inc()
                self._fail(r, e)
            except Exception as e:  # lowering/validation errors -> caller
                self._fail(r, e)
            else:
                self._resolve(r, value)

    def _select_call(self, r: _Req, q: SelectQ):
        """Blocking (off-loop) SELECT execution under the tenant's growth
        budget; cap-doubling recompiles pass the tenant's admission
        closure like any retry plan."""
        st = self._tenants[r.tenant]
        # mesh=None: SELECT planner blocks run single-device (the engine
        # rejects sharded BGP/SELECT loudly); the broker's base serve plan
        # stays sharded regardless
        cfg = self.config.replace(
            mesh=None,
            cap_policy=CapPolicy(
                grow=True,
                max_doublings=self.tenant_policy.max_cap_doublings,
            ),
        )
        with obs.span("broker.select", cat="broker", tenant=r.tenant,
                      seq=r.seq):
            plan = self.engine.compile(q, cfg, admit=self._admit(st))
            return plan()

    async def stream(self, tenant: str, queries):
        """Submit a tenant's query stream, yielding results in submission
        order.  ``queries`` is an iterable of ``(op, s, p, o)`` lane
        tuples and/or :class:`~repro.core.query.SelectQ` queries (mixed
        freely — the serve driver's full-shape traffic).  The whole
        stream is admitted through the same bounded queue — a
        :class:`QueueFull` shed propagates to the caller mid-stream."""
        window: collections.deque[asyncio.Future] = collections.deque()
        for item in queries:
            while window and window[0].done():
                yield await window.popleft()
            # stay inside the tenant's queue bound: wait for the oldest
            # outstanding result instead of shedding our own stream
            while (
                window
                and self._tenant(tenant).pending >= self.tenant_policy.queue_depth
            ):
                yield await window.popleft()
            if isinstance(item, SelectQ):
                window.append(self.submit_select_nowait(tenant, item))
            else:
                window.append(self.submit_nowait(tenant, *item))
        while window:
            yield await window.popleft()

    # -- the serve loop -------------------------------------------------

    async def _run(self):
        while True:
            if len(self._inflight) >= self.coalesce.max_inflight:
                await self._deliver(*self._inflight.popleft())
                continue
            reqs, tc0, tc1 = await self._collect(block=not self._inflight)
            if reqs:
                self._dispatch(reqs, tc0, tc1)
            elif self._inflight:
                await self._deliver(*self._inflight.popleft())
            elif self._draining and not self._queue:
                return

    async def _collect(self, *, block: bool):
        """Coalesce: returns ``(reqs, tc0, tc1)`` — the collected batch
        plus the perf-counter window the coalesce wait spanned."""
        pol = self.coalesce
        while not self._queue:
            if not block or self._draining:
                return [], 0.0, 0.0
            self._wake.clear()
            await self._wake.wait()
        tc0 = time.perf_counter()
        # deadline of the OLDEST pending request governs the flush
        deadline = self._queue[0].t_submit + pol.max_delay_s
        while len(self._queue) < pol.max_batch and not self._draining:
            now = time.perf_counter()
            if now >= deadline:
                break
            self._wake.clear()
            try:
                await asyncio.wait_for(self._wake.wait(), deadline - now)
            except asyncio.TimeoutError:
                break
        if len(self._queue) >= pol.max_batch:
            self._c["flush_size"].inc()
        elif self._draining:
            self._c["flush_drain"].inc()
        else:
            self._c["flush_deadline"].inc()
        n = min(len(self._queue), pol.max_batch)
        return [self._queue.popleft() for _ in range(n)], tc0, time.perf_counter()

    def _dispatch(self, reqs: list[_Req], tc0: float = 0.0, tc1: float = 0.0):
        td0 = time.perf_counter()
        qb = self._encode(reqs, self._pad_to)
        # pin the dynamic view AT dispatch: the static lane answers this
        # batch against lanes sanitized to the static extents, and decode
        # merges the SAME delta snapshot — writes landing mid-flight wait
        # for the next batch (per-batch snapshot isolation)
        try:
            raw, view = self._submit_dyn(self.base_plan, qb)
        except StaleEpoch:  # a compaction swapped under the base plan
            self._refresh_base_plan()
            raw, view = self._submit_dyn(self.base_plan, qb)
        meta = _BatchMeta(
            bid=self._bid, n_padded=int(qb.op.shape[0]),
            tc0=tc0 or td0, tc1=tc1 or td0, td0=td0,
            td1=time.perf_counter(),
        )
        self._bid += 1
        self._inflight.append((raw, reqs, meta, qb, view))
        self._c["batches"].inc()
        self._c["lanes"].inc(len(reqs))
        m = obs.STATE.metrics
        if m is not None:
            m.histogram("broker.batch_occupancy").observe(
                len(reqs) / meta.n_padded
            )
            m.gauge("broker.queue_depth").set(len(self._queue))
            h = m.histogram("broker.queue_wait_ms", LATENCY_MS_BUCKETS)
            for r in reqs:
                h.observe((td0 - r.t_submit) * 1e3)

    def _encode(self, reqs: list[_Req], pad_to: int) -> eng.ServeBatch:
        n = max(pad_to, self._padded_batch(len(reqs)))
        op = np.full(n, -1, np.int32)  # dead lanes: masked to zero output
        s = np.zeros(n, np.int32)
        p = np.zeros(n, np.int32)
        o = np.zeros(n, np.int32)
        for i, r in enumerate(reqs):
            op[i], s[i], p[i], o[i] = r.op, r.s, r.p, r.o
        return eng.ServeBatch(op=op, s=s, p=p, o=o)

    def _submit_dyn(self, plan, qb: eng.ServeBatch):
        """Static-lane dispatch for a possibly-dynamic store: sanitize
        lanes whose constants exceed the static extents (delta-only ids
        must not reach the device), submit raw, and return the pinned
        ``(raw, view)`` pair — the caller merges decode-time with the SAME
        view.  ``view`` is None for static stores / empty deltas."""
        view = self.engine.dynamic_view()
        qb_run = qb if view is None else view.sanitize_batch(qb)
        return plan.submit(qb_run), view

    def _padded_batch(self, b: int) -> int:
        """pow2 bucket (>= 8), then data-axis divisibility when sharded."""
        n = 8
        while n < b:
            n <<= 1
        cfg = self.config
        if cfg.mesh is not None:
            d = int(np.prod([cfg.mesh.shape[a] for a in cfg.data_axes]))
            n = ((max(n, d) + d - 1) // d) * d
        return n

    # -- streamed decode + per-tenant growth ----------------------------

    async def _deliver(self, raw, reqs: list[_Req], meta: _BatchMeta,
                       qb: eng.ServeBatch, view):
        has_u = any(r.op in eng._UNBOUNDED_OPS for r in reqs)
        meta.tf0 = time.perf_counter()

        # the blocking device->host fetch (and the host-side delta merge,
        # when the store is dynamic) runs off-loop so submitters keep
        # filling the next batch while this one decodes
        def fetch():
            host = eng.host_result(raw, unbounded=has_u and self.unbounded)
            if view is not None:
                # merge against the ORIGINAL (unsanitized) lane constants:
                # lanes masked off the device get delta-only answers
                host = view.merge_lanes(qb.op, qb.s, qb.p, qb.o, host)
            return host

        host = await asyncio.to_thread(fetch)
        meta.tf1 = time.perf_counter()
        retry_tenants = {
            reqs[i].tenant
            for i in np.nonzero(host.overflow[: len(reqs)])[0]
        }
        for i, r in enumerate(reqs):
            # streamed delivery: every lane of an unaffected tenant
            # resolves here, before any retry work happens
            if r.tenant not in retry_tenants:
                self._resolve(r, eng.decode_lane(r.op, host, i))
        for tenant in sorted(retry_tenants):
            # per-tenant FIFO: the whole segment of a tenant with a
            # retried lane is held and re-released in submission order
            segment = [(i, r) for i, r in enumerate(reqs) if r.tenant == tenant]
            await self._retry_tenant(tenant, segment, host)
        if obs.STATE.tracer is not None:
            self._trace_batch(reqs, meta)

    def _trace_batch(self, reqs: list[_Req], meta: _BatchMeta):
        """Emit the batch's retroactive spans now that every timestamp of
        its lifetime is known.

        Batch stages land as complete spans on a bounded pool of
        ``batch-slot-*`` tracks (slot = ``bid`` mod ``2 * max_inflight``
        — the inflight bound guarantees a slot's previous occupant fully
        delivered before reuse, so same-track spans never overlap).  Each
        query's lifetime lands as Chrome *async* events keyed by its
        ``seq``, phases nested by time under one ``query`` umbrella:
        queue → dispatch → inflight → fetch → decode.
        """
        t = obs.STATE.tracer
        ns = lambda sec: int(sec * 1e9)  # noqa: E731 — perf_counter s -> ns
        t_end = time.perf_counter()
        slot = f"batch-slot-{meta.bid % (2 * self.coalesce.max_inflight)}"
        occupancy = len(reqs) / meta.n_padded
        t.add("broker.batch", ns(meta.tc0), ns(t_end), tid=slot, cat="broker",
              bid=meta.bid, lanes=len(reqs), padded=meta.n_padded,
              occupancy=round(occupancy, 4))
        for name, a, b in (
            ("broker.coalesce", meta.tc0, meta.tc1),
            ("broker.dispatch", meta.td0, meta.td1),
            ("broker.inflight", meta.td1, meta.tf0),
            ("broker.fetch", meta.tf0, meta.tf1),
            ("broker.decode_deliver", meta.tf1, t_end),
        ):
            t.add(name, ns(a), ns(b), tid=slot, cat="broker", bid=meta.bid)
        for i, r in enumerate(reqs):
            td = r.t_deliver or t_end
            t.add_async("query", r.seq, ns(r.t_submit), ns(td),
                        tenant=r.tenant, op=r.op, lane=i, bid=meta.bid)
            for name, a, b in (
                ("queue", r.t_submit, meta.td0),
                ("dispatch", meta.td0, meta.td1),
                ("inflight", meta.td1, meta.tf0),
                ("fetch", meta.tf0, meta.tf1),
                ("decode", meta.tf1, td),
            ):
                t.add_async(name, r.seq, ns(a), ns(min(b, td)))

    def _resolve(self, r: _Req, value):
        st = self._tenants[r.tenant]
        st.pending -= 1
        st.completed += 1
        r.t_deliver = time.perf_counter()
        lat = r.t_deliver - r.t_submit
        st.lat_s.append(lat)
        m = obs.STATE.metrics
        if m is not None:
            m.histogram(
                "broker.query_latency_ms", LATENCY_MS_BUCKETS
            ).observe(lat * 1e3)
        if not r.future.cancelled():
            r.future.set_result(value)

    def _fail(self, r: _Req, exc: BaseException):
        st = self._tenants[r.tenant]
        st.pending -= 1
        st.failed += 1
        r.t_deliver = time.perf_counter()
        if not r.future.cancelled():
            r.future.set_exception(exc)

    async def _retry_tenant(self, tenant, segment, host):
        """Re-run a tenant's overflowed lanes on doubled-cap plans, then
        release its held segment in submission order."""
        grow = [(i, r) for (i, r) in segment if bool(host.overflow[i])]
        try:
            done = await asyncio.to_thread(
                self._grow_and_run, tenant, [r for (_, r) in grow]
            )
            regrown, err = dict(zip((i for i, _ in grow), done)), None
        except (CapOverflow, AdmissionError) as e:
            regrown, err = {}, e
        for i, r in segment:
            if i in regrown:
                self._resolve(r, regrown[i])
            elif err is not None and bool(host.overflow[i]):
                self._fail(r, err)
            else:
                self._resolve(r, eng.decode_lane(r.op, host, i))

    def _grow_and_run(self, tenant: str, rs: list[_Req]):
        """Blocking (off-loop) escalation: double the cap from the tenant's
        remembered level until the lanes fit or the budget runs out."""
        st = self._tenants[tenant]
        pol = self.tenant_policy
        level = max(st.cap_level, 1)
        while True:
            if level > pol.max_cap_doublings:
                raise CapOverflow(
                    f"tenant {tenant!r} exhausted its cap budget "
                    f"(max_cap_doublings={pol.max_cap_doublings})"
                )
            cap = self.config.cap << level
            cfg = self.config.replace(cap=cap, cap_y=self.config.cap_y << level)
            try:
                plan = self.engine.compile(
                    self._query, cfg, admit=self._admit(st)
                )
            except AdmissionError:
                st.admission_denials += 1
                self._c["admission_denials"].inc()
                raise
            st.cap_growth_events += 1
            self._c["cap_growth_events"].inc()
            st.cap_level = max(st.cap_level, level)
            self._retry_cfgs.add(cfg)
            with obs.span("broker.retry", cat="broker", tenant=tenant,
                          level=level, cap=cap, lanes=len(rs)):
                qb = self._encode(rs, 0)
                try:
                    raw, view = self._submit_dyn(plan, qb)
                except StaleEpoch:  # compaction swapped mid-retry
                    plan = self.engine.compile(
                        self._query, cfg, admit=self._admit(st)
                    )
                    raw, view = self._submit_dyn(plan, qb)
                host = eng.host_result(
                    raw, unbounded=any(r.op in eng._UNBOUNDED_OPS for r in rs),
                )
                if view is not None:
                    host = view.merge_lanes(qb.op, qb.s, qb.p, qb.o, host)
            if not host.overflow[: len(rs)].any():
                return [
                    eng.decode_lane(r.op, host, i) for i, r in enumerate(rs)
                ]
            level += 1

    def _admit(self, st: _TenantState):
        """The per-tenant plan-cache admission closure: charge MISSES
        against ``max_plans`` (the engine never calls this on a hit)."""

        def admit(_key):
            if st.plans_charged >= self.tenant_policy.max_plans:
                return False
            st.plans_charged += 1
            return True

        return admit

    def _tenant(self, name: str) -> _TenantState:
        st = self._tenants.get(name)
        if st is None:
            st = self._tenants[name] = _TenantState(name)
        return st

    # -- stats ----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero EVERY counter ``stats()`` reports, global and per-tenant
        (flush reasons, shed / cap-growth / admission-denial counts, queue
        peak, latency samples, insert / delete / compaction counts) — the
        benchmark warmup boundary.  Admission and write-budget STATE
        (``cap_level``, ``plans_charged``, ``writes_resident``) is
        retained: those are live budgets governing future admissions, not
        measurements — and ``delta_triples`` / ``tombstones`` in
        ``stats()`` are live gauges of the store, unaffected by reset."""
        self.metrics.reset()
        self._queue_peak = 0
        for st in self._tenants.values():
            st.lat_s.clear()
            st.completed = st.failed = st.shed = 0
            st.cap_growth_events = st.admission_denials = 0
            st.inserts = st.deletes = 0

    def stats(self) -> dict:
        """Structured serving stats (JSON-ready).  ``delta_triples`` and
        ``tombstones`` are LIVE store gauges (0 for static stores);
        everything else is counted since the last ``reset_stats``."""
        all_lat = [t for st in self._tenants.values() for t in st.lat_s]
        batches = self._c["batches"].value
        store = self.engine.store
        d = store.delta if isinstance(store, dyn.DynamicStore) else None
        return {
            "batches": batches,
            "lanes": self._c["lanes"].value,
            "coalesce_factor": (
                self._c["lanes"].value / batches if batches else 0.0
            ),
            "flush_size": self._c["flush_size"].value,
            "flush_deadline": self._c["flush_deadline"].value,
            "flush_drain": self._c["flush_drain"].value,
            "queue_depth": len(self._queue),
            "queue_peak": self._queue_peak,
            "selects": self._c["selects"].value,
            "shed": self._c["shed"].value,
            "cap_growth_events": self._c["cap_growth_events"].value,
            "admission_denials": self._c["admission_denials"].value,
            "inserts": self._c["inserts"].value,
            "deletes": self._c["deletes"].value,
            "compactions": self._c["compactions"].value,
            "compaction_ms": self._c["compaction_ms"].value,
            "compaction_errors": self._c["compaction_errors"].value,
            "delta_triples": d.n_inserts if d is not None else 0,
            "tombstones": d.n_tombstones if d is not None else 0,
            "queries": len(all_lat),
            "p50_ms": _ms(tail_percentile(all_lat, 50)),
            "p99_ms": _ms(tail_percentile(all_lat, 99)),
            "tenants": {
                name: {
                    "queries": st.completed,
                    "failed": st.failed,
                    "shed": st.shed,
                    "pending": st.pending,
                    "cap_level": st.cap_level,
                    "plans_charged": st.plans_charged,
                    "cap_growth_events": st.cap_growth_events,
                    "inserts": st.inserts,
                    "deletes": st.deletes,
                    "writes_resident": st.writes_resident,
                    "p50_ms": _ms(tail_percentile(st.lat_s, 50)),
                    "p99_ms": _ms(tail_percentile(st.lat_s, 99)),
                }
                for name, st in sorted(self._tenants.items())
            },
        }

    def cost_profiles(self) -> dict:
        """Static XLA cost profiles of every program this broker has
        served through: the shared base plan at its dispatch geometry,
        plus each doubled-cap retry level any tenant ever compiled
        (cache hits — profiling never charges admission quotas)."""
        out = {"base": self.base_plan.cost_profile(
            self._encode([], self._pad_to)
        )}
        for cfg in sorted(self._retry_cfgs, key=lambda c: c.cap):
            plan = self.engine.compile(self._query, cfg)
            out[f"retry_cap_{cfg.cap}"] = plan.cost_profile(
                self._encode([], 0)
            )
        return out


def _ms(v: float | None) -> float | None:
    return None if v is None else v * 1e3
