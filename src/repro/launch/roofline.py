"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Three terms per (arch × shape × mesh), all in SECONDS per step:

    compute    = FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HBM_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW

Sources — and the two XLA:CPU gotchas they work around:

  * ``cost_analysis()`` counts while-loop bodies ONCE.  A 61-layer scanned
    transformer would be undercounted 61×.  FLOPs therefore come from the
    jaxpr (``flopcount`` — exact dot_general accounting with scan lengths),
    divided by chips.
  * Memory + collective traffic comes from the OPTIMIZED HLO text, sectioned
    per computation; each while body's traffic is multiplied by its trip
    count (recovered from the loop-condition constant).  Per-op traffic =
    Σ operand/result buffer bytes (post-fusion: those are real HBM buffers).
    Collectives get ring-algorithm wire factors over their replica-group
    size g: all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all
    (g-1)/g, collective-permute 1.

The dominant term is the bottleneck; §Perf hillclimbs whatever dominates.
"""

from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"
)
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_SKIP_OPS = (
    "parameter(", "constant(", "tuple(", "get-tuple-element(", "bitcast(",
    "after-all(", "partition-id(", "replica-id(",
)


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CompStats:
    mem_bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: {k: 0.0 for k in _COLL_KINDS})
    whiles: list = dataclasses.field(default_factory=list)  # (cond, body)
    calls: list = dataclasses.field(default_factory=list)  # fusion/call refs
    max_const: int = 1


def parse_hlo(text: str) -> dict[str, CompStats]:
    comps: dict[str, CompStats] = {}
    cur: CompStats | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if raw and not raw[0].isspace():
            m = _COMP_HDR_RE.match(raw.strip())
            if m and "{" in raw:
                name = m.group(1)
                cur = comps.setdefault(name, CompStats())
                if raw.startswith("ENTRY"):
                    entry = name
                continue
        if cur is None or "=" not in line:
            continue
        mc = _CONST_RE.search(line)
        if mc:
            cur.max_const = max(cur.max_const, int(mc.group(1)))
        if any(s in line for s in _SKIP_OPS):
            continue
        mw = _WHILE_RE.search(line)
        if mw:
            cur.whiles.append((mw.group(1), mw.group(2)))
            continue
        # called computations (fusions etc.) — traffic counted at call site
        for kind in _COLL_KINDS:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                # result type(s) sit between '=' and the op keyword; tuple
                # outputs (multi-operand all-reduce) contain parens, so cut
                # at the op token rather than the first '('.
                rest = line.split("=", 1)[1]
                idx = rest.find(f" {kind}")
                nbytes = _shape_bytes(rest[:idx] if idx > 0 else rest)
                g = 1
                gm = _GROUPS_IOTA_RE.search(line)
                if gm:
                    g = int(gm.group(2))
                else:
                    gm = _GROUPS_RE.search(line)
                    if gm and gm.group(1).strip():
                        g = len(gm.group(1).split(","))
                if g > 1:
                    f = (
                        2.0 * (g - 1) / g if kind == "all-reduce"
                        else 1.0 if kind == "collective-permute"
                        else (g - 1) / g
                    )
                    cur.coll[kind] += nbytes * f
                break
        # memory traffic: all buffer shapes on the op line (result + operands)
        cur.mem_bytes += _shape_bytes(line)
    comps["__entry__"] = comps.get(entry, CompStats()) if entry else CompStats()
    if entry:
        comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _aggregate(comps: dict, name: str, mult: float, out: dict, seen: tuple) -> None:
    if name not in comps or name in seen:
        return
    st = comps[name]
    out["mem"] += st.mem_bytes * mult
    for k in _COLL_KINDS:
        out[k] += st.coll[k] * mult
    for cond, body in st.whiles:
        trips = max(comps.get(cond, CompStats()).max_const, 1)
        _aggregate(comps, cond, mult * trips, out, seen + (name,))
        _aggregate(comps, body, mult * trips, out, seen + (name,))


def hlo_traffic(text: str) -> dict:
    """Loop-aware per-device traffic: {'mem': bytes, <coll-kind>: wire bytes}."""
    comps = parse_hlo(text)
    entry = comps.get("__entry_name__")
    out = {"mem": 0.0, **{k: 0.0 for k in _COLL_KINDS}}
    if isinstance(entry, str):
        _aggregate(comps, entry, 1.0, out, ())
    else:  # fallback: flat sum, no loop multipliers
        for name, st in comps.items():
            if isinstance(st, CompStats):
                out["mem"] += st.mem_bytes
                for k in _COLL_KINDS:
                    out[k] += st.coll[k]
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    mesh: str
    chips: int
    flops_per_dev: float  # jaxpr-exact, / chips
    bytes_per_dev: float  # HLO loop-aware traffic
    wire_bytes_per_dev: float
    model_flops: float
    coll_detail: dict
    peak_mem_bytes: float = 0.0
    cost_analysis_flops: float = 0.0  # raw XLA numbers, for reference
    cost_analysis_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (global program FLOPs): remat/redundancy waste."""
        total = self.flops_per_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Useful model FLOP/s at the bound step time vs chip peak."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips / t) / PEAK_FLOPS_BF16

    def to_dict(self) -> dict:
        return {
            "name": self.name, "mesh": self.mesh, "chips": self.chips,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "wire_bytes_per_dev": self.wire_bytes_per_dev,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "peak_mem_bytes": self.peak_mem_bytes,
            "coll_detail": self.coll_detail,
            "cost_analysis_flops": self.cost_analysis_flops,
            "cost_analysis_bytes": self.cost_analysis_bytes,
        }


def analyze(name, mesh_name, chips, compiled, model_flops, jaxpr_flops) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    txt = compiled.as_text()
    traffic = hlo_traffic(txt)
    wire = sum(v for k, v in traffic.items() if k != "mem")
    peak = 0.0
    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
        )
    except Exception:
        pass
    return Roofline(
        name=name, mesh=mesh_name, chips=chips,
        flops_per_dev=jaxpr_flops / chips,
        bytes_per_dev=traffic["mem"],
        wire_bytes_per_dev=wire, model_flops=model_flops,
        coll_detail=traffic, peak_mem_bytes=peak,
        cost_analysis_flops=float(cost.get("flops", 0.0)),
        cost_analysis_bytes=float(cost.get("bytes accessed", 0.0)),
    )


def fmt_row(r: Roofline) -> str:
    return (
        f"{r.name:42s} {r.mesh:9s} "
        f"c={r.t_compute*1e3:9.3f}ms m={r.t_memory*1e3:9.3f}ms "
        f"x={r.t_collective*1e3:9.3f}ms -> {r.bottleneck:10s} "
        f"useful={r.useful_flops_frac*100:5.1f}% roof={r.roofline_frac*100:5.1f}% "
        f"hbm={r.peak_mem_bytes/2**30:6.2f}G"
    )
