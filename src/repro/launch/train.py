"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Runs REAL training (allocating params, streaming data) on whatever devices
exist — the production path on a TPU pod, the smoke path on this CPU
container (use --smoke and small --steps).  Includes the full
fault-tolerance loop: auto-resume, periodic atomic checkpoints, straggler
watchdog.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="defaults to the arch's train shape")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro.configs import get
    from repro.data.tokens import TokenStream
    from repro.data import graphs as G, recsys as R
    from repro.models import transformer as tfm
    from repro.models.gnn import common as gc
    from repro.models.recsys import xdeepfm
    from repro.launch.programs import GNN_MODULES
    from repro.train import optim
    from repro.train.trainer import Trainer, TrainerConfig

    arch = get(args.arch)
    cfg = arch.smoke_cfg if args.smoke else arch.cfg
    key = jax.random.PRNGKey(0)
    opt = optim.adafactor(args.lr) if arch.optimizer == "adafactor" else optim.adamw(args.lr)

    if arch.family == "lm":
        params = tfm.init(cfg, key)
        ts = TokenStream(cfg.vocab, args.seq, seed=0)

        def batches():
            while True:
                b = ts.batch(args.batch)
                yield {k: jnp.asarray(v) for k, v in b.items()}

        loss_fn = lambda p, b: tfm.loss_fn(cfg, p, b)
    elif arch.family == "gnn":
        mod = GNN_MODULES[args.arch]
        import dataclasses as dc

        n_classes = 7
        cfg = dc.replace(cfg, out_dim=n_classes, **(
            {"in_dim": 32} if hasattr(cfg, "in_dim") else {}))
        params = mod.init(cfg, key)
        g = G.random_graph(512, 4096, 32, n_classes=n_classes, seed=0)

        def batches():
            gb = G.to_batch(g, n_classes)
            gb = jax.tree.map(lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x, gb)
            while True:
                yield gb

        loss_fn = lambda p, b: mod.loss_fn(cfg, p, b)
    elif arch.family == "recsys":
        params = xdeepfm.init(cfg, key)
        i = [0]

        def batches():
            while True:
                b = R.ctr_batch(args.batch, cfg.n_fields, cfg.rows_per_field, seed=i[0])
                i[0] += 1
                yield {k: jnp.asarray(v) for k, v in b.items()}

        loss_fn = lambda p, b: xdeepfm.loss_fn(cfg, p, b)
    else:
        raise SystemExit(f"train driver does not apply to family {arch.family!r}")

    tc = TrainerConfig(
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, log_every=args.log_every
    )
    tr = Trainer(tc, loss_fn, opt, params)
    if tr.try_resume():
        print(f"resumed from step {tr.step_num}")
    hist = tr.run(batches(), args.steps)
    print(
        f"done: {len(hist)} steps, loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
        f"stragglers flagged: {len(tr.watchdog.flagged)}"
    )


if __name__ == "__main__":
    main()
