"""The broker's write path over a dynamic store:

  * live inserts/deletes are visible to queries submitted after them,
    and broker answers match a direct differential truth set;
  * a write that trips :class:`~repro.core.compaction.CompactionPolicy`
    schedules a BACKGROUND compaction — reads keep flowing during the
    rebuild, the epoch swap is atomic, and answers stay correct across
    it (compaction under traffic);
  * per-tenant ``max_writes`` budgets shed writers with
    :class:`~repro.launch.broker.WriteBudgetExhausted` and refill at
    compaction;
  * writes against a plain static store are rejected loudly;
  * write/compaction counters land in ``stats()``.
"""

import asyncio

import numpy as np
import pytest

from repro.core import compaction as cpt
from repro.core import delta
from repro.core import engine as eng
from repro.core import k2triples
from repro.core.query import ExecConfig
from repro.launch import broker as broker_mod
from repro.launch.broker import (
    CoalescePolicy, ServeBroker, TenantPolicy, WriteBudgetExhausted,
)

_E, _P = 24, 3


@pytest.fixture()
def dyn_engine():
    rng = np.random.default_rng(11)
    ids = np.unique(
        rng.integers(1, [_E + 1, _P + 1, _E + 1], size=(110, 3)), axis=0
    )
    st = k2triples.from_id_triples(
        ids, n_so=_E, n_subjects=_E, n_objects=_E, n_preds=_P
    )
    ds = delta.DynamicStore(st)
    return eng.Engine(store=ds), set(map(tuple, ids.tolist()))


def test_writes_require_dynamic_store():
    rng = np.random.default_rng(0)
    ids = np.unique(rng.integers(1, [9, 3, 9], size=(20, 3)), axis=0)
    st = k2triples.from_id_triples(
        ids, n_so=8, n_subjects=8, n_objects=8, n_preds=2
    )
    E = eng.Engine(store=st)

    async def main():
        async with ServeBroker(E, ExecConfig(backend="jnp", cap=32),
                               unbounded=False) as b:
            with pytest.raises(TypeError, match="DynamicStore"):
                b.submit_insert_nowait("t", 1, 1, 1)

    asyncio.run(main())


def test_write_read_differential(dyn_engine):
    """Interleaved writes and reads through the broker match the python
    truth set, including delta-only rows and tombstoned static rows."""
    E, T = dyn_engine
    cfg = ExecConfig(backend="jnp", cap=64)

    async def main():
        async with ServeBroker(
            E, cfg,
            coalesce=CoalescePolicy(max_batch=16, max_delay_s=1e-3),
        ) as b:
            rng = np.random.default_rng(5)
            for _ in range(30):
                roll = rng.random()
                if roll < 0.25 and T:
                    t = sorted(T)[int(rng.integers(len(T)))]
                    await b.submit_delete("w", *t)
                    T.discard(t)
                elif roll < 0.5:
                    t = (int(rng.integers(1, _E + 3)),
                         int(rng.integers(1, _P + 2)),
                         int(rng.integers(1, _E + 3)))
                    await b.submit_insert("w", *t)
                    T.add(t)
                elif roll < 0.75:
                    s = int(rng.integers(1, _E + 3))
                    p = int(rng.integers(1, _P + 2))
                    got = await b.submit("r", eng.OP_ROW, s=s, p=p)
                    want = sorted(o for (ss, pp, o) in T
                                  if ss == s and pp == p)
                    assert sorted(np.asarray(got).tolist()) == want
                else:
                    s = int(rng.integers(1, _E + 3))
                    per = await b.submit("r", eng.OP_S_ANY_ANY, s=s)
                    want = {}
                    for (ss, pp, oo) in T:
                        if ss == s:
                            want.setdefault(pp, set()).add(oo)
                    got = {p: set(np.asarray(v).tolist())
                           for p, v in per.items()}
                    assert got == want
            st = b.stats()
            assert st["inserts"] + st["deletes"] > 0
            assert st["delta_triples"] == E.store.delta.n_inserts

    asyncio.run(main())


def test_compaction_under_traffic(dyn_engine):
    """A write trips the policy mid-stream; queries before, DURING, and
    after the background rebuild all answer correctly, and the epoch
    swap lands exactly once."""
    E, T = dyn_engine
    cfg = ExecConfig(backend="jnp", cap=64)

    async def main():
        async with ServeBroker(
            E, cfg,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=1e-3),
            compaction=cpt.CompactionPolicy(max_delta=10),
        ) as b:
            rng = np.random.default_rng(9)
            for i in range(12):
                t = (int(rng.integers(1, _E + 3)),
                     int(rng.integers(1, _P + 2)),
                     int(rng.integers(1, _E + 3)))
                await b.submit_insert("w", *t)
                T.add(t)
                # reads interleave with the background rebuild
                s = int(rng.integers(1, _E + 3))
                p = int(rng.integers(1, _P + 2))
                got = await b.submit("r", eng.OP_ROW, s=s, p=p)
                want = sorted(o for (ss, pp, o) in T if ss == s and pp == p)
                assert sorted(np.asarray(got).tolist()) == want, (s, p, i)
            assert b._compaction_task is not None
            rep = await b._compaction_task
            assert rep.epoch == 1 and E.store.epoch == 1
            # post-swap: correctness holds and the delta was folded down
            for (s, p, o) in sorted(T)[:5]:
                assert await b.submit("r", eng.OP_CHECK, s, p, o)
            st = b.stats()
            assert st["compactions"] == 1
            assert st["compaction_ms"] > 0
            return b.stats()

    st = asyncio.run(main())
    assert st["tenants"]["w"]["writes_resident"] < 12  # refilled at swap


def test_compaction_failure_is_observed(dyn_engine, monkeypatch):
    """A failing background compaction is surfaced when the task
    completes — ``compaction_errors`` counter + RuntimeWarning — instead
    of first at drain; the broker keeps serving the old epoch and the
    delta keeps answering."""
    E, T = dyn_engine
    cfg = ExecConfig(backend="jnp", cap=64)

    def boom(store, *, backend=None):
        raise RuntimeError("rebuild exploded")

    monkeypatch.setattr(broker_mod, "compact", boom)

    async def main():
        async with ServeBroker(
            E, cfg, compaction=cpt.CompactionPolicy(max_delta=2),
        ) as b:
            with pytest.warns(RuntimeWarning, match="compaction failed"):
                await b.submit_insert("w", 1, 1, 1)
                await b.submit_insert("w", 1, 1, 2)  # trips the policy
                assert b._compaction_task is not None
                await asyncio.gather(
                    b._compaction_task, return_exceptions=True
                )
                await asyncio.sleep(0)  # let the done callback land
            st = b.stats()
            assert st["compaction_errors"] == 1
            assert st["compactions"] == 0
            assert E.store.epoch == 0  # swap never happened
            # reads keep flowing against the old epoch + live delta
            assert await b.submit("r", eng.OP_CHECK, 1, 1, 1)

    asyncio.run(main())


def test_write_budget_exhausts_and_refills(dyn_engine):
    E, T = dyn_engine
    cfg = ExecConfig(backend="jnp", cap=64)

    async def main():
        async with ServeBroker(
            E, cfg, tenant_policy=TenantPolicy(max_writes=4),
        ) as b:
            for i in range(4):
                await b.submit_insert("w", 1, 1, i + 1)
            with pytest.raises(WriteBudgetExhausted):
                await b.submit_insert("w", 1, 1, 9)
            with pytest.raises(WriteBudgetExhausted):
                await b.submit_delete("w", 1, 1, 1)
            # another tenant's budget is untouched
            await b.submit_insert("calm", 2, 2, 2)
            # a compaction folds the delta and refills the budget
            rep = await asyncio.to_thread(cpt.compact, E.store)
            b._refresh_base_plan()
            for st in b._tenants.values():
                st.writes_resident = 0
            await b.submit_insert("w", 1, 1, 9)
            assert rep.epoch == 1

    asyncio.run(main())


def test_stale_plan_lane_refreshes_transparently(dyn_engine):
    """An out-of-band compaction (not broker-triggered) swaps the store
    under the broker's base plan; the next dispatch sees StaleEpoch,
    refreshes, and serves correctly — callers never notice."""
    E, T = dyn_engine
    cfg = ExecConfig(backend="jnp", cap=64)

    async def main():
        async with ServeBroker(
            E, cfg, coalesce=CoalescePolicy(max_batch=4, max_delay_s=1e-3),
        ) as b:
            t = sorted(T)[0]
            assert await b.submit("r", eng.OP_CHECK, *t)
            E.store.insert(_E + 1, 1, 2)
            T.add((_E + 1, 1, 2))
            cpt.compact(E.store)  # behind the broker's back
            assert E.store.epoch == 1
            assert await b.submit("r", eng.OP_CHECK, _E + 1, 1, 2)
            got = await b.submit("r", eng.OP_ROW, s=t[0], p=t[1])
            want = sorted(o for (ss, pp, o) in T
                          if ss == t[0] and pp == t[1])
            assert sorted(np.asarray(got).tolist()) == want

    asyncio.run(main())
