"""Differential suite for the dynamic (LSM-delta) store.

Randomized insert/delete/query interleavings on a
:class:`~repro.core.delta.DynamicStore` are checked against a pure-python
truth set maintained alongside — every pattern shape, join categories
A–F, and SELECT chains — on both scan backends and both predicate-index
layouts, before AND after a mid-trace compaction.  The required edge
cases ride the same traces: delete-then-reinsert (of static triples),
inserts of ids the static store has never seen, and (for dictionary
stores) inserts of entirely unseen *terms* through the appended-id-range
dictionary extension.

Epoch semantics get their own tests: a compaction swap must raise
:class:`~repro.core.query.StaleEpoch` on the raw ``Plan.submit`` lane and
recompile transparently on ``Plan.__call__``.
"""

import threading

import numpy as np
import pytest

from repro.core import compaction, delta, k2triples
from repro.core import engine as eng
from repro.core.dictionary import ExtendedDictionary, build_dictionary
from repro.core.predindex import PredBitmap
from repro.core.query import (
    ExecConfig, JoinQ, SelectQ, ServeQ, StaleEpoch, TriplePatternQ,
)


def test_opcodes_in_sync():
    """delta.py mirrors the serve-IR op constants instead of importing
    engine (circular import); this is the tripwire if they ever drift."""
    assert (
        delta.OP_CHECK, delta.OP_ROW, delta.OP_COL,
        delta.OP_S_ANY_ANY, delta.OP_ANY_ANY_O, delta.OP_S_ANY_O,
    ) == (
        eng.OP_CHECK, eng.OP_ROW, eng.OP_COL,
        eng.OP_S_ANY_ANY, eng.OP_ANY_ANY_O, eng.OP_S_ANY_O,
    )
    assert set(delta._NEED_P) == {eng.OP_CHECK, eng.OP_ROW, eng.OP_COL}
    assert set(eng._UNBOUNDED_OPS) == {
        delta.OP_S_ANY_O, delta.OP_S_ANY_ANY, delta.OP_ANY_ANY_O
    }


# ---------------------------------------------------------------------------
# delta-layer unit semantics (pure python, no device)
# ---------------------------------------------------------------------------


def _mini_store(seed=0, n=80, E=20, P=3):
    rng = np.random.default_rng(seed)
    ids = np.unique(rng.integers(1, [E + 1, P + 1, E + 1], size=(n, 3)), axis=0)
    st = k2triples.from_id_triples(
        ids, n_so=E, n_subjects=E, n_objects=E, n_preds=P
    )
    return st, set(map(tuple, ids.tolist()))


def test_delta_store_semantics():
    st, T = _mini_store()
    d = delta.DeltaStore(st)
    t0 = next(iter(sorted(T)))

    d.delete(*t0)
    snap = d.snapshot()
    assert snap.tomb_contains(*t0) and not snap.contains(*t0)
    # reinsert clears the tombstone and does NOT leave a delta insert for
    # a triple the static store already holds? It does — the delta has no
    # static visibility; the merge unions it away and compaction dedups.
    d.insert(*t0)
    snap = d.snapshot()
    assert snap.contains(*t0) and not snap.tomb_contains(*t0)

    # snapshot is version-cached: no mutation -> same object
    assert d.snapshot() is snap
    d.insert(1, 1, 1)
    assert d.snapshot() is not snap

    # delete of a delta insert removes it AND tombstones (the same id
    # triple may also exist statically)
    d.delete(1, 1, 1)
    snap = d.snapshot()
    assert not snap.contains(1, 1, 1) and snap.tomb_contains(1, 1, 1)


def test_delta_rebase_keeps_post_snapshot_writes():
    st, _ = _mini_store()
    d = delta.DeltaStore(st)
    d.insert(2, 1, 3)
    absorbed = d.snapshot()
    d.insert(4, 2, 5)          # lands AFTER the compaction pin
    d.delete(6, 1, 7)
    d2 = d.rebase(st, absorbed)
    snap = d2.snapshot()
    assert not snap.contains(2, 1, 3)       # folded into the new static
    assert snap.contains(4, 2, 5)           # survived the swap
    assert snap.tomb_contains(6, 1, 7)


def test_racing_writes_survive_compaction_swap():
    """Writes issued concurrently with background compactions must never
    land on the orphaned pre-rebase delta (the lost-write race): the
    store lock serializes insert/delete against ``swap``, so every write
    is visible after the dust settles."""
    st, T = _mini_store(seed=5)
    ds = delta.DynamicStore(st)
    errs: list[Exception] = []
    written = set()

    def writer():
        try:
            for i in range(300):
                t = (21 + i % 5, 1 + i % 3, 1 + i % 20)
                ds.insert(*t)
                written.add(t)
        except Exception as e:  # pragma: no cover - diagnostic only
            errs.append(e)

    def compactor():
        try:
            for _ in range(6):
                compaction.compact(ds, backend="jnp")
        except Exception as e:  # pragma: no cover - diagnostic only
            errs.append(e)

    w = threading.Thread(target=writer)
    c = threading.Thread(target=compactor)
    w.start()
    c.start()
    w.join()
    c.join()
    assert not errs
    # final quiescent fold-down: the static side must now hold every write
    compaction.compact(ds, backend="jnp")
    assert ds.delta.empty
    got = set(map(tuple, compaction.dump_static_ids(ds.static).tolist()))
    assert got == T | written


def test_view_of_sanitizes_minted_ids_with_empty_delta():
    """``add_term``/``add_predicate`` with no resident insert yet: the
    minted ids exceed the static extents, so dispatch still needs a
    sanitizing view even though the delta snapshot is empty — otherwise a
    clamped device gather reads the wrong row instead of answering empty."""
    strs = [("s:a", "p:x", "s:b"), ("s:b", "p:x", "o:c"), ("s:a", "p:y", "o:c")]
    st = k2triples.from_string_triples(strs)
    ds = delta.DynamicStore(st)
    assert delta.view_of(ds) is None  # fresh store: pure static fast path

    d = ds.dictionary
    nid = d.add_term("zz:new")  # minted, nothing inserted
    qid = d.add_predicate("zz:q")
    assert ds.delta.empty
    v = delta.view_of(ds)
    assert v is not None and v.snap.empty and v.needs_sanitize

    E = eng.Engine(store=ds)
    cfg = ExecConfig(backend="jnp", cap=32)
    px = d.encode_predicate("p:x")
    sa = d.encode_subject("s:a")
    # every shape carrying a minted constant answers empty/false
    assert not bool(E.compile(TriplePatternQ(nid, px, sa), cfg)())
    assert E.compile(TriplePatternQ(nid, px, None), cfg)().tolist() == []
    assert E.compile(TriplePatternQ(None, px, nid), cfg)().tolist() == []
    assert E.compile(TriplePatternQ(sa, qid, None), cfg)().tolist() == []
    assert E.compile(TriplePatternQ(nid, None, None), cfg)() == {}
    # static answers are untouched by the sanitizing view
    sb = d.encode_object("s:b")
    assert bool(E.compile(TriplePatternQ(sa, px, sb), cfg)())

    # once the term actually lands, the same constants answer for real
    ds.insert(nid, px, sa)
    assert bool(E.compile(TriplePatternQ(nid, px, sa), cfg)())
    assert E.compile(TriplePatternQ(nid, px, None), cfg)().tolist() == [sa]


def test_dynamic_store_proxies_and_validates():
    st, _ = _mini_store()
    ds = delta.DynamicStore(st)
    assert ds.n_so == st.n_so and ds.n_preds == st.n_preds
    assert ds.epoch == 0
    with pytest.raises(ValueError):
        ds.insert(0, 1, 1)  # ids are 1-based


def test_pred_bitmap():
    b = PredBitmap()
    b.add(5, 3)
    b.add(5, 1)
    b.add(9, 64)  # beyond one machine word: python-int bitmask
    assert b.preds_of(5).tolist() == [1, 3]
    assert b.preds_of(9).tolist() == [64]
    assert b.preds_of(7).tolist() == []
    assert 5 in b and 7 not in b
    assert sorted(b.entities()) == [5, 9] and len(b) == 2


def test_extended_dictionary_appended_range():
    base = build_dictionary(
        [("a", "p", "b"), ("b", "p", "c"), ("a", "q", "c")]
    )
    d = ExtendedDictionary(base)
    n_s0, n_o0, n_p0 = d.n_subjects, d.n_objects, d.n_preds

    sid = d.add_term("zz-new")
    assert sid == d.ext_base + 1  # appended range: static ids never move
    assert d.add_term("zz-new") == sid  # idempotent
    assert d.add_term("a") == base.encode_subject("a")  # base hit, no mint
    assert d.decode_subject(sid) == "zz-new"
    assert d.decode_object(sid) == "zz-new"  # shared S/O extension pool
    assert d.encode_subject("zz-new") == sid
    assert d.n_subjects == max(n_s0, d.ext_base + 1)
    assert d.n_objects == max(n_o0, d.ext_base + 1)

    pid = d.add_predicate("r-new")
    assert pid == n_p0 + 1 and d.decode_predicate(pid) == "r-new"
    assert d.encode_predicate("p") == base.encode_predicate("p")


# ---------------------------------------------------------------------------
# the randomized churn differential
# ---------------------------------------------------------------------------

_E, _P = 24, 4


def _probe_patterns(E, T, run, rng):
    """Every pattern shape vs the python truth set."""
    # (S, P, ?O) / (?S, P, O) / (S, P, O)
    for _ in range(6):
        s = int(rng.integers(1, E + 3))
        p = int(rng.integers(1, _P + 2))
        o = int(rng.integers(1, E + 3))
        assert run(TriplePatternQ(s, p, None)).tolist() == sorted(
            oo for (ss, pp, oo) in T if ss == s and pp == p
        )
        assert run(TriplePatternQ(None, p, o)).tolist() == sorted(
            ss for (ss, pp, oo) in T if oo == o and pp == p
        )
        assert bool(run(TriplePatternQ(s, p, o))) == ((s, p, o) in T)
    for t in list(sorted(T))[:4]:  # present checks
        assert bool(run(TriplePatternQ(*t)))
    # (S, ?P, O) / (S, ?P, ?O) / (?S, ?P, O)
    for _ in range(3):
        s = int(rng.integers(1, E + 3))
        o = int(rng.integers(1, E + 3))
        assert run(TriplePatternQ(s, None, o)).tolist() == sorted(
            pp for (ss, pp, oo) in T if ss == s and oo == o
        )
        want = {}
        for (ss, pp, oo) in T:
            if ss == s:
                want.setdefault(pp, []).append(oo)
        got = {k: sorted(v.tolist()) for k, v in run(
            TriplePatternQ(s, None, None)).items()}
        assert got == {k: sorted(v) for k, v in want.items()}
        want = {}
        for (ss, pp, oo) in T:
            if oo == o:
                want.setdefault(pp, []).append(ss)
        got = {k: sorted(v.tolist()) for k, v in run(
            TriplePatternQ(None, None, o)).items()}
        assert got == {k: sorted(v) for k, v in want.items()}
    # (?S, P, ?O) pairs + full dump
    p = int(rng.integers(1, _P + 2))
    assert sorted(map(tuple, run(TriplePatternQ(None, p, None)).tolist())) \
        == sorted((ss, oo) for (ss, pp, oo) in T if pp == p)
    got = {k: sorted(map(tuple, v.tolist())) for k, v in run(
        TriplePatternQ(None, None, None)).items()}
    want = {}
    for (ss, pp, oo) in T:
        want.setdefault(pp, []).append((ss, oo))
    assert got == {k: sorted(v) for k, v in want.items()}


def _side(T, vpos, p, c):
    """ids X with (X p c) when the variable sits at s, else (c p X)."""
    if vpos == "s":
        return {ss for (ss, pp, oo) in T if pp == p and oo == c}
    return {oo for (ss, pp, oo) in T if pp == p and ss == c}


def _stage2(T, v2, p, x):
    if v2 == "s":
        return sorted(oo for (ss, pp, oo) in T if pp == p and ss == x)
    return sorted(ss for (ss, pp, oo) in T if pp == p and oo == x)


def _probe_joins(T, run, rng, Ptot):
    c1 = int(rng.integers(1, _E + 1))
    c2 = int(rng.integers(1, _E + 1))
    v1, v2 = "s", "o"
    got = run(JoinQ("A", v1, v2, p1=1, c1=c1, p2=2, c2=c2))
    assert got.tolist() == sorted(_side(T, v1, 1, c1) & _side(T, v2, 2, c2))
    got = run(JoinQ("B", v1, v2, p1=1, c1=c1, c2=c2))
    a = _side(T, v1, 1, c1)
    want = {p: sorted(a & _side(T, v2, p, c2)) for p in range(1, Ptot + 1)}
    assert {p: v.tolist() for p, v in got.items()} == {
        p: v for p, v in want.items() if v
    }
    got = run(JoinQ("C", v1, v2, c1=c1, c2=c2))
    u1 = set().union(*[_side(T, v1, p, c1) for p in range(1, Ptot + 1)])
    u2 = set().union(*[_side(T, v2, p, c2) for p in range(1, Ptot + 1)])
    assert got.tolist() == sorted(u1 & u2)
    got = run(JoinQ("D", v1, v2, p1=1, c1=c1, p2=2))
    want = {
        x: _stage2(T, v2, 2, x) for x in _side(T, v1, 1, c1)
        if _stage2(T, v2, 2, x)
    }
    assert {x: v.tolist() for x, v in got.items()} == want
    got = run(JoinQ("E", v1, v2, p1=1, c1=c1))
    want = {}
    for x in _side(T, v1, 1, c1):
        for p in range(1, Ptot + 1):
            ys = _stage2(T, v2, p, x)
            if ys:
                want.setdefault(p, {})[x] = ys
    assert {
        p: {x: v.tolist() for x, v in d.items()} for p, d in got.items()
    } == want
    got = run(JoinQ("F", v1, v2, c1=c1))
    xs = set().union(*[_side(T, v1, p, c1) for p in range(1, Ptot + 1)])
    want = {}
    for x in xs:
        for p in range(1, Ptot + 1):
            ys = _stage2(T, v2, p, x)
            if ys:
                want.setdefault(p, {})[x] = ys
    assert {
        p: {x: v.tolist() for x, v in d.items()} for p, d in got.items()
    } == want


def _probe_select(T, run):
    q = SelectQ(
        select=("?a", "?b", "?c"),
        where=(TriplePatternQ("?a", 1, "?b"), TriplePatternQ("?b", 2, "?c")),
    )
    got = run(q)
    rows = set(zip(
        got["?a"].tolist(), got["?b"].tolist(), got["?c"].tolist()
    ))
    want = {
        (s, o, o2)
        for (s, p, o) in T if p == 1
        for (s2, p2, o2) in T if p2 == 2 and s2 == o
    }
    assert rows == want


def _churn(ds, T, rng, n_ops):
    for _ in range(n_ops):
        if T and rng.random() < 0.4:
            t = list(sorted(T))[int(rng.integers(len(T)))]
            ds.delete(*t)
            T.discard(t)
        else:
            # inserts may carry ids the static store never saw (E+1, E+2
            # entities; P+1 predicate) — the appended range
            t = (
                int(rng.integers(1, _E + 3)),
                int(rng.integers(1, _P + 2)),
                int(rng.integers(1, _E + 3)),
            )
            ds.insert(*t)
            T.add(t)


@pytest.mark.parametrize("layout", ["dac", "fixed"])
@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_churn_differential(backend, layout):
    """insert/delete/query interleavings vs python truth, both backends ×
    both pred-index layouts, with a compaction in the middle of the
    trace and more churn after it."""
    seed = {"pallas": 0, "jnp": 1}[backend] * 2 + {"dac": 0, "fixed": 1}[layout]
    rng = np.random.default_rng(seed)
    ids = np.unique(
        rng.integers(1, [_E + 1, _P + 1, _E + 1], size=(130, 3)), axis=0
    )
    st = k2triples.from_id_triples(
        ids, n_so=_E, n_subjects=_E, n_objects=_E, n_preds=_P
    )
    ds = delta.DynamicStore(st)
    E = eng.Engine(store=ds)
    cfg = ExecConfig(backend=backend, pred_index_layout=layout, cap=128)
    run = lambda q: E.compile(q, cfg)()  # noqa: E731
    T = set(map(tuple, ids.tolist()))

    # explicit delete-then-reinsert of a STATIC triple
    t0 = next(iter(sorted(T)))
    ds.delete(*t0)
    T.discard(t0)
    assert not bool(run(TriplePatternQ(*t0)))
    ds.insert(*t0)
    T.add(t0)
    assert bool(run(TriplePatternQ(*t0)))

    _churn(ds, T, rng, 25)
    Ptot = delta.total_preds(ds)
    _probe_patterns(_E, T, run, rng)
    _probe_joins(T, run, rng, Ptot)
    _probe_select(T, run)

    rep = compaction.compact(ds, backend=backend)
    assert ds.epoch == 1 and ds.delta.empty
    assert rep.n_triples == len(T)

    # the SAME probes stay green post-swap (plans recompile at epoch 1)
    _probe_patterns(_E, T, run, rng)
    _probe_joins(T, run, rng, Ptot)

    # and after further churn on the compacted epoch
    _churn(ds, T, rng, 15)
    _probe_patterns(_E, T, run, rng)
    _probe_select(T, run)


# ---------------------------------------------------------------------------
# unseen terms through the string path
# ---------------------------------------------------------------------------


def test_unseen_term_inserts_and_id_stability():
    strs = [
        ("s:a", "p:x", "s:b"), ("s:b", "p:x", "o:c"),
        ("s:a", "p:y", "o:c"), ("s:d", "p:y", "s:a"),
    ]
    st = k2triples.from_string_triples(strs)
    ds = delta.DynamicStore(st)
    E = eng.Engine(store=ds)
    cfg = ExecConfig(backend="jnp", cap=32)
    d = ds.dictionary
    assert isinstance(d, ExtendedDictionary)

    ds.insert_strings([("new:e", "p:x", "s:a"), ("s:a", "new:q", "new:f")])
    e_id = d.encode_subject("new:e")
    f_id = d.encode_object("new:f")
    q_id = d.encode_predicate("new:q")
    assert e_id > d.ext_base and q_id > d.pred_base  # appended range

    px = d.encode_predicate("p:x")
    sa = d.encode_subject("s:a")
    assert bool(E.compile(TriplePatternQ(e_id, px, sa), cfg)())
    assert E.compile(TriplePatternQ(sa, q_id, None), cfg)().tolist() == [f_id]

    ds.delete_strings([("s:a", "p:x", "s:b")])
    sb = d.encode_object("s:b")
    assert not bool(E.compile(TriplePatternQ(sa, px, sb), cfg)())
    ds.delete_strings([("never", "seen", "terms")])  # no-op, no raise

    compaction.compact(ds, backend="jnp")
    # ids NEVER move across epochs: the same strings encode identically
    assert d.encode_subject("new:e") == e_id
    assert d.encode_predicate("new:q") == q_id
    assert d.decode_subject(e_id) == "new:e"
    assert bool(E.compile(TriplePatternQ(e_id, px, sa), cfg)())
    assert not bool(E.compile(TriplePatternQ(sa, px, sb), cfg)())


# ---------------------------------------------------------------------------
# epoch semantics
# ---------------------------------------------------------------------------


def test_stale_epoch_submit_and_transparent_call():
    st, T = _mini_store(seed=3)
    ds = delta.DynamicStore(st)
    E = eng.Engine(store=ds)
    cfg = ExecConfig(backend="jnp", cap=64)
    plan = E.compile(ServeQ(unbounded=False), cfg)
    qb = eng.ServeBatch(
        op=np.zeros(8, np.int32), s=np.ones(8, np.int32),
        p=np.ones(8, np.int32), o=np.ones(8, np.int32),
    )
    raw = plan.submit(qb)  # fine at epoch 0
    assert raw is not None

    ds.insert(1, 1, 1)
    compaction.compact(ds, backend="jnp")
    assert ds.epoch == 1

    # the raw lane refuses: its executor was pinned at epoch 0
    with pytest.raises(StaleEpoch):
        plan.submit(qb)
    # __call__ recompiles transparently and keeps answering
    r = plan(qb)
    assert bool(np.asarray(r.hit)[0])  # (1,1,1) was just inserted+compacted

    # pattern plans recompile transparently too
    p2 = E.compile(TriplePatternQ(1, 1, None), cfg)
    ds.insert(1, 1, 9)
    compaction.compact(ds, backend="jnp")
    assert 9 in p2().tolist()
