"""``hypothesis`` when installed, else a seeded-example fallback.

The tier-1 suite must collect and run in hermetic containers with no
network.  When the real library is importable we re-export it untouched
(full shrinking/fuzzing).  Otherwise a minimal shim drives each ``@given``
test from deterministic draws: ``max_examples`` examples per test, each
seeded from (test name, example index), so failures reproduce exactly.

Only the API surface this repo uses is emulated:

    @settings(max_examples=N, deadline=None)
    @given(st.integers(...), st.booleans(), st.lists(...), st.data())

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # ------------------------------------------------- shim
    import functools
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def example(self, rng):  # pragma: no cover - interface
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = min_value, max_value

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi, endpoint=True))

    class _Booleans(_Strategy):
        def example(self, rng):
            return bool(rng.integers(0, 2))

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=None):
            self.elem = elem
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 10

        def example(self, rng):
            n = int(rng.integers(self.min_size, self.max_size, endpoint=True))
            return [self.elem.example(rng) for _ in range(n)]

    class _DataStrategy(_Strategy):
        pass

    class _DataObject:
        """Mid-test draws: ``data.draw(st.integers(...))``."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Integers(min_value, max_value)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def lists(elem, min_size=0, max_size=None):
            return _Lists(elem, min_size=min_size, max_size=max_size)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _St()
    strategies = st

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            # strategies fill the RIGHTMOST params (hypothesis semantics);
            # bind them by NAME so pytest fixtures (passed as kwargs) and
            # drawn values can never collide
            strat_names = list(inspect.signature(fn).parameters)[-len(strats):]

            @functools.wraps(fn)
            def run(*fixture_args, **fixture_kw):
                n = getattr(run, "_max_examples", _DEFAULT_EXAMPLES)
                name_seed = zlib.crc32(fn.__qualname__.encode())
                for ex in range(n):
                    rng = np.random.default_rng((name_seed, ex))
                    drawn = {
                        nm: (_DataObject(rng) if isinstance(s, _DataStrategy)
                             else s.example(rng))
                        for nm, s in zip(strat_names, strats)
                    }
                    try:
                        fn(*fixture_args, **fixture_kw, **drawn)
                    except Exception as e:  # reproduce: same seed tuple
                        raise AssertionError(
                            f"{fn.__qualname__} failed on fallback example "
                            f"{ex} (seed=({name_seed}, {ex})): {e!r}"
                        ) from e

            # expose only the leftover (fixture) params to pytest
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[: -len(strats) or None]
            run.__signature__ = sig.replace(parameters=params)
            del run.__wrapped__  # keep pytest off the original signature
            return run

        return deco
