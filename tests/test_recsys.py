"""xDeepFM: loss/grad, EmbeddingBag semantics, CIN math, retrieval path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import recsys as R
from repro.models.recsys import xdeepfm as xd

CFG = xd.XDeepFMCfg(
    n_fields=8, embed_dim=6, rows_per_field=1000, cin_layers=(16, 16), mlp_dims=(32, 32)
)


@pytest.fixture(scope="module")
def params():
    return xd.init(CFG, jax.random.PRNGKey(0))


def test_loss_and_grads(params, rng):
    b = {k: jnp.asarray(v) for k, v in R.ctr_batch(64, 8, 1000, seed=1).items()}
    loss, g = jax.value_and_grad(lambda p: xd.loss_fn(CFG, p, b))(params)
    assert np.isfinite(float(loss))
    assert float(jnp.abs(g["tables"]).sum()) > 0  # embeddings learn


def test_embedding_bag_matches_manual(rng):
    ids, bag_ids, counts = R.multi_hot_bags(16, 1000, seed=2)
    tbl = jax.random.normal(jax.random.PRNGKey(1), (1000, 6))
    out = np.asarray(xd.embedding_bag(tbl, jnp.asarray(ids), jnp.asarray(bag_ids), 16))
    exp = np.zeros((16, 6), np.float32)
    for i, bid in zip(ids, bag_ids):
        exp[bid] += np.asarray(tbl)[i]
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)
    # mean mode
    out_m = np.asarray(
        xd.embedding_bag(tbl, jnp.asarray(ids), jnp.asarray(bag_ids), 16, mode="mean")
    )
    np.testing.assert_allclose(out_m, exp / counts[:, None], rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=40), st.integers(min_value=0, max_value=2**31 - 1))
def test_embedding_bag_property(n_bags, seed):
    """Σ over bags of bag-sums == Σ over all lookups (conservation)."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, 5, n_bags)
    bag_ids = np.repeat(np.arange(n_bags), counts).astype(np.int32)
    ids = rng.integers(0, 100, counts.sum()).astype(np.int32)
    tbl = jnp.asarray(rng.standard_normal((100, 4)), jnp.float32)
    out = xd.embedding_bag(tbl, jnp.asarray(ids), jnp.asarray(bag_ids), n_bags)
    np.testing.assert_allclose(
        np.asarray(out.sum(0)), np.asarray(jnp.take(tbl, jnp.asarray(ids), 0).sum(0)),
        rtol=1e-4, atol=1e-4,
    )


def test_cin_matches_explicit(rng, params):
    """CIN einsum == the paper's explicit definition x^{k+1}_h = Σ_ij W_hij x^k_i ∘ x^0_j."""
    B, F, D = 3, 8, 6
    x0 = jnp.asarray(rng.standard_normal((B, F, D)), jnp.float32)
    W = params["cin"][0]  # [H, F, F]
    z = jnp.einsum("bhd,bmd->bhmd", x0, x0)
    got = np.asarray(jnp.einsum("bhmd,nhm->bnd", z, W))
    H = W.shape[0]
    exp = np.zeros((B, H, D), np.float32)
    for b in range(B):
        for h in range(H):
            for i in range(F):
                for j in range(F):
                    exp[b, h] += np.asarray(W)[h, i, j] * np.asarray(x0)[b, i] * np.asarray(x0)[b, j]
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


def test_retrieval_scores_shape_and_rank(params, rng):
    user = jnp.asarray(rng.integers(0, 1000, 8), jnp.int32)
    cands = jnp.arange(500, dtype=jnp.int32)
    s = xd.retrieval_score(CFG, params, user, cands)
    assert s.shape == (500,)
    assert np.isfinite(np.asarray(s)).all()
    # identical candidate ids -> identical scores
    s2 = xd.retrieval_score(CFG, params, user, jnp.zeros(500, jnp.int32))
    assert np.allclose(np.asarray(s2), np.asarray(s2)[0])
