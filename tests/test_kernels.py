"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import k2tree
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ops, ref

SENTINEL = 2**31 - 1


@pytest.mark.parametrize("m,n", [(8, 128), (16, 256), (32, 512), (8, 1024)])
def test_popcount_shapes(rng, m, n):
    w = rng.integers(0, 2**32, (m, n), dtype=np.uint32)
    got = np.asarray(ops.popcount(jnp.asarray(w)))
    exp = np.asarray(ref.popcount_ref(jnp.asarray(w)))
    assert (got == exp).all()


@pytest.mark.parametrize("side,nnz,q", [(50, 100, 700), (500, 900, 1100), (3000, 500, 512)])
def test_k2_check_sweep(rng, side, nnz, q):
    meta = K2Meta(hybrid_ks(side))
    rows = rng.integers(0, side, nnz)
    cols = rng.integers(0, side, nnz)
    tree = k2tree.build(rows, cols, meta)
    qr = rng.integers(0, side, q).astype(np.int32)
    qc = rng.integers(0, side, q).astype(np.int32)
    got = np.asarray(ops.k2_check_tree(meta, tree, jnp.asarray(qr), jnp.asarray(qc), block_q=256))
    exp = np.asarray(
        ref.k2_check_ref(
            meta, jnp.asarray(qr), jnp.asarray(qc), tree.t.words, tree.t.rank_blocks,
            tree.l.words, tree.ones_before, tree.level_start,
        )
    )
    assert (got == exp).all()
    dense = np.zeros((meta.side, meta.side), np.uint8)
    dense[rows, cols] = 1
    assert (got == (dense[qr, qc] == 1)).all()


@pytest.mark.parametrize("ca,cb,na,nb", [(128, 128, 50, 100), (512, 1024, 300, 700), (2048, 256, 1000, 200)])
def test_sorted_intersect_sweep(rng, ca, cb, na, nb):
    a = np.sort(rng.choice(100_000, na, replace=False)).astype(np.int32)
    b = np.sort(rng.choice(100_000, nb, replace=False)).astype(np.int32)
    ap = np.full(ca, SENTINEL, np.int32); ap[:na] = a
    bp = np.full(cb, SENTINEL, np.int32); bp[:nb] = b
    got = np.asarray(ops.sorted_intersect_mask(jnp.asarray(ap), jnp.asarray(bp)))
    exp = np.asarray(ref.sorted_intersect_mask_ref(jnp.asarray(ap), jnp.asarray(bp)))
    assert (got == exp).all()
    assert (got[:na] == np.isin(a, b)).all()
    assert not got[na:].any()  # sentinels never match


@pytest.mark.parametrize("m,k,d,dtype", [
    (256, 256, 128, np.float32),
    (512, 384, 256, np.float32),
    (256, 256, 128, jnp.bfloat16),
])
def test_block_spmm_sweep(rng, m, k, d, dtype):
    bm = bk = 128
    mask = (rng.random((m // bm, k // bk)) < 0.5).astype(np.int32)
    a = (rng.random((m, k)) < 0.02).astype(np.float32)
    x = rng.standard_normal((k, d)).astype(np.float32)
    a_t = jnp.asarray(a, dtype)
    x_t = jnp.asarray(x, dtype)
    got = np.asarray(ops.block_spmm(jnp.asarray(mask), a_t, x_t))
    exp = np.asarray(ref.block_spmm_ref(jnp.asarray(mask), a_t, x_t))
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, exp, rtol=tol, atol=tol)


def test_block_spmm_mask_semantics(rng):
    """Masked-off tiles contribute exactly zero (never silently included)."""
    m = k = 256
    mask = np.zeros((2, 2), np.int32)
    mask[0, 0] = 1
    a = np.ones((m, k), np.float32)
    x = np.ones((k, 128), np.float32)
    got = np.asarray(ops.block_spmm(jnp.asarray(mask), jnp.asarray(a), jnp.asarray(x)))
    assert (got[:128] == 128.0).all()  # only the ON tile's 128 k-elems
    assert (got[128:] == 0.0).all()


def test_mask_from_k2_level():
    from repro.kernels.block_spmm import mask_from_k2_level

    lvl = jnp.asarray(np.array([[1, 0], [0, 1]], np.int32))
    m = np.asarray(mask_from_k2_level(lvl, side=512, block=128))
    assert m.shape == (4, 4)
    assert m[:2, :2].all() and m[2:, 2:].all()
    assert not m[:2, 2:].any() and not m[2:, :2].any()
