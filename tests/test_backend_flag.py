"""Regression: the kernel-layer env flags must be re-read, not latched —
and must never be read at all inside a compiled ``Plan`` path.

The original ``kernels/ops.py`` captured ``REPRO_SCAN_BACKEND`` once into a
module constant, so a test or notebook setting it after import was silently
ignored; ``scan_backend()`` now consults the environment on every call.
``REPRO_PALLAS_INTERPRET`` had the same bug class (an ``INTERPRET`` module
constant) — ``pallas_interpret()`` resolves it per call too.

The inverse bug class arrived with the plan redesign: ``scan_backend()`` /
``pallas_interpret()`` being consulted *inside* compiled paths whenever an
``override is None`` slipped through the threading.  An ``ExecConfig``
carries explicit values end to end, so a compiled ``Plan.__call__`` must
perform ZERO ``os.environ`` reads — enforced below with an environment
tripwire.
"""

import os

import numpy as np
import pytest

import jax

from repro.core import k2forest
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ops


def test_scan_backend_rereads_env(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend() == "jnp"
    # flipping AFTER the first resolve must take effect — the regression
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "pallas")
    assert ops.scan_backend() == "pallas"
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend() == "jnp"
    monkeypatch.delenv("REPRO_SCAN_BACKEND")
    assert ops.scan_backend() == ops.DEFAULT_SCAN_BACKEND == "pallas"


def test_scan_backend_override_and_validation(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend("pallas") == "pallas"  # per-call override wins
    with pytest.raises(ValueError):
        ops.scan_backend("bogus")
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ops.scan_backend()


def test_pallas_interpret_rereads_env(monkeypatch):
    """The INTERPRET-latch regression: flipping the var after import must be
    honored by the per-call resolver."""
    on_tpu = jax.default_backend() == "tpu"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.pallas_interpret() == (not on_tpu)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.pallas_interpret() is False  # the flip takes effect
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert ops.pallas_interpret() == (not on_tpu)  # default: interpret off-TPU
    # explicit override wins regardless of the environment
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.pallas_interpret(True) is True
    assert ops.pallas_interpret(False) is False


def test_no_module_level_latch():
    """The latched constant is gone: the module exposes only the resolver."""
    assert not hasattr(ops, "INTERPRET")


def test_resolve_exec_config_skips_env(monkeypatch):
    """An ExecConfig-shaped object resolves without touching the env."""
    from repro.core.query import ExecConfig

    monkeypatch.setenv("REPRO_SCAN_BACKEND", "bogus")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "bogus")
    assert ops.resolve_exec(ExecConfig(backend="jnp", interpret=False)) == (
        "jnp", False,
    )
    assert ops.resolve_exec(ExecConfig(backend="pallas", interpret=True)) == (
        "pallas", True,
    )
    # interpret=None resolves deterministically from the jax backend
    be, interp = ops.resolve_exec(ExecConfig(backend="pallas"))
    assert (be, interp) == ("pallas", jax.default_backend() != "tpu")
    # legacy strings still go through (and hit) the env validation
    with pytest.raises(ValueError):
        ops.resolve_exec(None)


class _EnvTripwire(dict):
    def get(self, k, d=None):
        if str(k).startswith("REPRO_"):
            raise AssertionError(
                f"os.environ read of {k!r} inside a compiled Plan path"
            )
        return super().get(k, d)


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_no_env_read_inside_plan_call(monkeypatch, backend):
    """The redesign bugfix regression: with an explicit ExecConfig, nothing
    on ``Plan.__call__`` — pattern serve, unbounded lanes, joins, BGP —
    consults the REPRO_* environment.  The env holds invalid values AND
    ``kernels.ops`` sees a tripwire mapping, so any read fails loudly."""
    from repro.core import engine as eng, k2triples
    from repro.core.query import BgpQ, ExecConfig, JoinQ, TriplePatternQ
    from repro.data import rdf

    ds = rdf.generate(500, n_subjects=30, n_preds=4, n_objects=40, seed=23)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    T = set(map(tuple, ds.ids.tolist()))
    E = eng.Engine(store)
    cfg = ExecConfig(backend=backend, interpret=jax.default_backend() != "tpu",
                     cap=128)
    s_, p_, o_ = map(int, ds.ids[3])

    monkeypatch.setenv("REPRO_SCAN_BACKEND", "bogus")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "bogus")
    monkeypatch.setattr(ops.os, "environ", _EnvTripwire(os.environ))

    assert E.compile(TriplePatternQ(s_, p_, o_), cfg)() is True
    assert E.compile(TriplePatternQ(s_, p_, "?o"), cfg)().tolist() == sorted(
        oo for (ss, pp, oo) in T if ss == s_ and pp == p_
    )
    E.compile(TriplePatternQ(s_, "?p", "?o"), cfg)()  # unbounded + gather
    E.compile(TriplePatternQ("?s", p_, "?o"), cfg)()  # pair enumeration
    E.compile(JoinQ("A", "s", "s", p1=p_, c1=o_, p2=p_, c2=o_), cfg)()
    E.compile(JoinQ("D", "s", "o", p1=p_, c1=o_, p2=p_), cfg)()  # rebind kernel
    E.compile(BgpQ((TriplePatternQ(s_, "?p", "?o"),)), cfg)()


def test_env_flip_switches_dispatch(monkeypatch):
    """Both env values drive scan_batch_mixed to identical results — the
    flag actually reaches the dispatch site after an in-session flip."""
    rng = np.random.default_rng(31)
    side = 60
    meta = K2Meta(hybrid_ks(side))
    f, _ = k2forest.build_forest(
        [(rng.integers(0, side, 120), rng.integers(0, side, 120))], meta
    )
    preds = np.zeros(4, np.int32)
    keys = rng.integers(0, side, 4)
    axes = np.array([0, 1, 0, 1], np.int32)
    out = {}
    for be in ("jnp", "pallas"):
        monkeypatch.setenv("REPRO_SCAN_BACKEND", be)
        out[be] = k2forest.scan_batch_mixed(meta, f, preds, keys, axes, 32)
    for a, b in zip(tuple(out["jnp"]), tuple(out["pallas"])):
        assert (np.asarray(a) == np.asarray(b)).all()
