"""Regression: ``REPRO_SCAN_BACKEND`` must be re-read, not latched at import.

The original ``kernels/ops.py`` captured the env var once into a module
constant, so a test or notebook setting it after import was silently
ignored.  ``scan_backend()`` now consults the environment on every call.
"""

import numpy as np
import pytest

from repro.core import k2forest
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ops


def test_scan_backend_rereads_env(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend() == "jnp"
    # flipping AFTER the first resolve must take effect — the regression
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "pallas")
    assert ops.scan_backend() == "pallas"
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend() == "jnp"
    monkeypatch.delenv("REPRO_SCAN_BACKEND")
    assert ops.scan_backend() == ops.DEFAULT_SCAN_BACKEND == "pallas"


def test_scan_backend_override_and_validation(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend("pallas") == "pallas"  # per-call override wins
    with pytest.raises(ValueError):
        ops.scan_backend("bogus")
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ops.scan_backend()


def test_env_flip_switches_dispatch(monkeypatch):
    """Both env values drive scan_batch_mixed to identical results — the
    flag actually reaches the dispatch site after an in-session flip."""
    rng = np.random.default_rng(31)
    side = 60
    meta = K2Meta(hybrid_ks(side))
    f, _ = k2forest.build_forest(
        [(rng.integers(0, side, 120), rng.integers(0, side, 120))], meta
    )
    preds = np.zeros(4, np.int32)
    keys = rng.integers(0, side, 4)
    axes = np.array([0, 1, 0, 1], np.int32)
    out = {}
    for be in ("jnp", "pallas"):
        monkeypatch.setenv("REPRO_SCAN_BACKEND", be)
        out[be] = k2forest.scan_batch_mixed(meta, f, preds, keys, axes, 32)
    for a, b in zip(tuple(out["jnp"]), tuple(out["pallas"])):
        assert (np.asarray(a) == np.asarray(b)).all()
