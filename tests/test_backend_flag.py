"""Regression: the kernel-layer env flags must be re-read, not latched.

The original ``kernels/ops.py`` captured ``REPRO_SCAN_BACKEND`` once into a
module constant, so a test or notebook setting it after import was silently
ignored; ``scan_backend()`` now consults the environment on every call.
``REPRO_PALLAS_INTERPRET`` had the same bug class (an ``INTERPRET`` module
constant) — ``pallas_interpret()`` resolves it per call too.
"""

import numpy as np
import pytest

import jax

from repro.core import k2forest
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ops


def test_scan_backend_rereads_env(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend() == "jnp"
    # flipping AFTER the first resolve must take effect — the regression
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "pallas")
    assert ops.scan_backend() == "pallas"
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend() == "jnp"
    monkeypatch.delenv("REPRO_SCAN_BACKEND")
    assert ops.scan_backend() == ops.DEFAULT_SCAN_BACKEND == "pallas"


def test_scan_backend_override_and_validation(monkeypatch):
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "jnp")
    assert ops.scan_backend("pallas") == "pallas"  # per-call override wins
    with pytest.raises(ValueError):
        ops.scan_backend("bogus")
    monkeypatch.setenv("REPRO_SCAN_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ops.scan_backend()


def test_pallas_interpret_rereads_env(monkeypatch):
    """The INTERPRET-latch regression: flipping the var after import must be
    honored by the per-call resolver."""
    on_tpu = jax.default_backend() == "tpu"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.pallas_interpret() == (not on_tpu)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.pallas_interpret() is False  # the flip takes effect
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert ops.pallas_interpret() == (not on_tpu)  # default: interpret off-TPU
    # explicit override wins regardless of the environment
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.pallas_interpret(True) is True
    assert ops.pallas_interpret(False) is False


def test_no_module_level_latch():
    """The latched constant is gone: the module exposes only the resolver."""
    assert not hasattr(ops, "INTERPRET")


def test_env_flip_switches_dispatch(monkeypatch):
    """Both env values drive scan_batch_mixed to identical results — the
    flag actually reaches the dispatch site after an in-session flip."""
    rng = np.random.default_rng(31)
    side = 60
    meta = K2Meta(hybrid_ks(side))
    f, _ = k2forest.build_forest(
        [(rng.integers(0, side, 120), rng.integers(0, side, 120))], meta
    )
    preds = np.zeros(4, np.int32)
    keys = rng.integers(0, side, 4)
    axes = np.array([0, 1, 0, 1], np.int32)
    out = {}
    for be in ("jnp", "pallas"):
        monkeypatch.setenv("REPRO_SCAN_BACKEND", be)
        out[be] = k2forest.scan_batch_mixed(meta, f, preds, keys, axes, 32)
    for a, b in zip(tuple(out["jnp"]), tuple(out["pallas"])):
        assert (np.asarray(a) == np.asarray(b)).all()
