"""Differential coverage for the kernel-backed join pipeline (categories A–F).

Every category runs on both scan backends and must agree bit-exactly —
"pallas" drives the batched ``k2_scan`` / fused ``k2_scan_rebind`` kernels,
"jnp" the vmapped reference traversal — and against a brute-force Python-set
oracle.  Includes the fused scan→rebind primitive itself (vs the jnp
composition and the scatter-compaction ref), per-predicate overflow
surfacing, cap-overflow truncation, and empty-predicate lanes.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import joins, k2forest, k2triples, sortedset
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ref

from oracle import assert_results_identical, dense_from_coords


@pytest.fixture(scope="module")
def store_and_oracle():
    """A store with skewed predicates: pred 3 empty, pred 1 dense."""
    rng = np.random.default_rng(21)
    n_s, n_p, n_o = 90, 5, 110
    trips = set()
    for _ in range(2500):
        p = int(rng.integers(1, n_p + 1))
        if p == 3:
            continue  # empty predicate lane
        trips.add((int(rng.integers(1, n_s + 1)), p, int(rng.integers(1, n_o + 1))))
    ids = np.array(sorted(trips), np.int64)
    store = k2triples.from_id_triples(
        ids, n_so=min(n_s, n_o), n_subjects=n_s, n_objects=n_o, n_preds=n_p,
    )
    return store, trips


def _both(fn):
    """Run a join closure on both backends, assert bit-exact, return pallas."""
    rp, rj = fn("pallas"), fn("jnp")
    assert_results_identical(tuple(rp), tuple(rj), type(rp).__name__)
    return rp


def _side(T, p, const, vpos):
    if vpos == "s":
        return sorted({s for (s, pp, o) in T if (p is None or pp == p) and o == const})
    return sorted({o for (s, pp, o) in T if (p is None or pp == p) and s == const})


def test_join_a_b_c_backends_and_oracle(store_and_oracle):
    store, T = store_and_oracle
    m, f = store.meta, store.forest
    cap = 256
    # constants chosen from real triples so sides are non-empty
    (s1, p1, o1), (s2, p2, o2) = sorted(T)[10], sorted(T)[500]

    ra = _both(lambda be: joins.join_a(m, f, p1, o1, "s", p2, o2, "s", cap, be))
    got = np.asarray(ra.ids)[np.asarray(ra.valid)].tolist()
    assert got == sorted(set(_side(T, p1, o1, "s")) & set(_side(T, p2, o2, "s")))

    rb = _both(lambda be: joins.join_b(m, f, p1, o1, "s", o2, "s", cap, be))
    l1 = set(_side(T, p1, o1, "s"))
    for pp in range(1, 6):
        exp = sorted(l1 & set(_side(T, pp, o2, "s")))
        assert np.asarray(rb.ids[pp - 1])[np.asarray(rb.valid[pp - 1])].tolist() == exp
        assert int(rb.counts[pp - 1]) == len(exp)
    # per-pred overflow vector, no truncation at this cap
    assert rb.overflow.shape == (5,)
    assert not np.asarray(rb.overflow).any()

    rc = _both(lambda be: joins.join_c(m, f, o1, "s", o2, "s", cap, be))
    got = np.asarray(rc.ids)[np.asarray(rc.valid)].tolist()
    assert got == sorted(set(_side(T, None, o1, "s")) & set(_side(T, None, o2, "s")))


def test_join_d_e_f_backends_and_oracle(store_and_oracle):
    store, T = store_and_oracle
    m, f = store.meta, store.forest
    cap_x, cap_y = 128, 64
    (s1, p1, o1) = sorted(T)[33]

    rd = _both(lambda be: joins.join_d(m, f, p1, o1, "s", 2, "o", cap_x, cap_y, be))
    xs = _side(T, p1, o1, "s")
    assert np.asarray(rd.x_ids)[np.asarray(rd.x_valid)].tolist() == xs
    for i, x in enumerate(xs):
        exp = sorted({ss for (ss, pp, oo) in T if pp == 2 and oo == x})
        got = np.asarray(rd.y_ids[i])[np.asarray(rd.y_valid[i])].tolist()
        assert got == exp
    assert rd.overflow.shape == ()

    re_ = _both(lambda be: joins.join_e(m, f, p1, o1, "s", "o", cap_x, cap_y, be))
    assert re_.overflow.shape == (5,)
    for pp in range(1, 6):
        for i, x in enumerate(xs):
            exp = sorted({ss for (ss, p3, oo) in T if p3 == pp and oo == x})
            got = np.asarray(re_.y_ids[pp - 1, i])[np.asarray(re_.y_valid[pp - 1, i])]
            assert got.tolist() == exp, (pp, x)
    # pred 3 is empty: its lane yields nothing and no overflow
    assert not np.asarray(re_.y_valid[2]).any()
    assert not bool(np.asarray(re_.overflow)[2])

    rf = _both(lambda be: joins.join_f(m, f, o1, "s", "o", cap_x, cap_y, be))
    assert rf.overflow.shape == (5,)
    xs_f = _side(T, None, o1, "s")
    assert np.asarray(rf.x_ids[0])[np.asarray(rf.x_valid[0])].tolist() == xs_f
    for pp in range(1, 6):
        for i, x in enumerate(xs_f):
            exp = sorted({ss for (ss, p3, oo) in T if p3 == pp and oo == x})
            got = np.asarray(rf.y_ids[pp - 1, i])[np.asarray(rf.y_valid[pp - 1, i])]
            assert got.tolist() == exp, (pp, x)


def test_join_empty_sides(store_and_oracle):
    """Queries against the empty predicate: empty results on every backend."""
    store, T = store_and_oracle
    m, f = store.meta, store.forest
    ra = _both(lambda be: joins.join_a(m, f, 3, 1, "s", 3, 2, "s", 64, be))
    assert not np.asarray(ra.valid).any()
    rd = _both(lambda be: joins.join_d(m, f, 3, 1, "s", 1, "o", 32, 16, be))
    assert not np.asarray(rd.x_valid).any()
    assert not np.asarray(rd.y_valid).any()
    assert not bool(rd.overflow)


def test_join_y_cap_overflow_per_pred(store_and_oracle):
    """Tiny cap_y truncates Y lists; overflow is per-pred and only where real.

    cap_y == k0 keeps the initial frontier un-truncated (cap below the root
    arity latches overflow unconditionally — the scan's documented
    conservative floor), so the empty predicate's lane must stay clean.
    """
    store, T = store_and_oracle
    m, f = store.meta, store.forest
    cap_y = m.ks[0]  # == 4
    (s1, p1, o1) = sorted(T)[33]
    r = _both(lambda be: joins.join_e(m, f, p1, o1, "s", "o", 128, cap_y, be))
    ovf = np.asarray(r.overflow)
    xs = _side(T, p1, o1, "s")
    for pp in range(1, 6):
        truncated = any(
            len({ss for (ss, p3, oo) in T if p3 == pp and oo == x}) > cap_y
            for x in xs
        )
        # overflow may be conservatively latched by intermediate frontiers,
        # but a pred with an actually-truncated Y list MUST flag, and the
        # empty pred (no frontiers at all) must NOT
        if truncated:
            assert ovf[pp - 1], pp
    assert not ovf[2]  # empty predicate
    # truncated Y lists still return the sorted prefix
    for pp in range(1, 6):
        for i, x in enumerate(xs):
            exp = sorted({ss for (ss, p3, oo) in T if p3 == pp and oo == x})
            got = np.asarray(r.y_ids[pp - 1, i])[np.asarray(r.y_valid[pp - 1, i])]
            assert got.tolist() == exp[: len(got)]


def test_scan_rebind_primitive_three_way():
    """The fused primitive: kernel vs jnp composition vs scatter-compaction
    ref, on randomized forests, bit-exact across all 8 outputs."""
    rng = np.random.default_rng(22)
    for side, n_preds, nnz in [(60, 3, 250), (200, 2, 600)]:
        meta = K2Meta(hybrid_ks(side))
        coords = [
            (rng.integers(0, side, nnz), rng.integers(0, side, nnz))
            for _ in range(n_preds)
        ]
        f, _ = k2forest.build_forest(coords, meta)
        q = 6
        preds1 = rng.integers(0, n_preds, q)
        keys1 = rng.integers(0, side, q)
        axes1 = rng.integers(0, 2, q)
        preds2 = rng.integers(0, n_preds, q)
        axes2 = rng.integers(0, 2, q)
        args = (preds1, keys1, axes1, preds2, axes2)
        for cap_x, cap_y in [(16, 8), (64, 4)]:
            o_pl = k2forest.scan_rebind_batch(meta, f, *args, cap_x, cap_y, "pallas")
            o_j = k2forest.scan_rebind_batch(meta, f, *args, cap_x, cap_y, "jnp")
            o_r = ref.k2_scan_rebind_ref(
                meta, *(jnp.asarray(a, jnp.int32) for a in args),
                t_words=f.t_words, t_rank=f.t_rank, l_words=f.l_words,
                ones_before=f.ones_before, level_start=f.level_start,
                cap_x=cap_x, cap_y=cap_y,
            )
            names = ("x_ids", "x_valid", "x_count", "x_ovf",
                     "y_ids", "y_valid", "y_count", "y_ovf")
            for nm, a, b in zip(names, o_pl, o_j):
                assert (np.asarray(a) == np.asarray(b)).all(), (side, nm, "p-vs-j")
            for nm, a, b in zip(names, o_pl, o_r):
                assert (np.asarray(a) == np.asarray(b)).all(), (side, nm, "p-vs-ref")
            # dense-oracle spot check: each valid X lane's Y list is the
            # true row/col line of the rebound key
            dense = dense_from_coords(coords, meta.side)
            x_ids, x_valid = np.asarray(o_pl[0]), np.asarray(o_pl[1])
            y_ids, y_valid = np.asarray(o_pl[4]), np.asarray(o_pl[5])
            y_ovf = np.asarray(o_pl[7])
            for qi in range(q):
                for xi in range(cap_x):
                    if not x_valid[qi, xi]:
                        continue
                    d = dense[preds2[qi]]
                    line = (d[x_ids[qi, xi]] if axes2[qi] == 0
                            else d[:, x_ids[qi, xi]])
                    exp = np.nonzero(line)[0]
                    got = y_ids[qi, xi][y_valid[qi, xi]]
                    if y_ovf[qi, xi]:
                        assert (got == exp[: len(got)]).all()
                    else:
                        assert (got == exp).all()


def test_rebind_ref_wrapper_signature():
    """k2_scan_rebind_ref accepts positional arena arrays too (kernels parity)."""
    rng = np.random.default_rng(23)
    side = 60
    meta = K2Meta(hybrid_ks(side))
    coords = [(rng.integers(0, side, 100), rng.integers(0, side, 100))]
    f, _ = k2forest.build_forest(coords, meta)
    out = ref.k2_scan_rebind_ref(
        meta, jnp.zeros(2, jnp.int32), jnp.asarray([0, 5], jnp.int32),
        jnp.zeros(2, jnp.int32), jnp.zeros(2, jnp.int32),
        jnp.ones(2, jnp.int32),
        f.t_words, f.t_rank, f.l_words, f.ones_before, f.level_start,
        cap_x=8, cap_y=8,
    )
    assert out[0].shape == (2, 8)
    assert out[4].shape == (2, 8, 8)
