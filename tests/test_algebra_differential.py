"""Randomized differential suite for the SPARQL-shaped algebra layer:
``planner.execute`` over random operator trees (OPTIONAL / UNION / FILTER
/ LIMIT+ORDER nests, depth ≤ 3) vs an independent brute-force oracle.

The oracle evaluates solution mappings as python dicts (a missing key IS
the unbound state) with its own compat-join / 3-valued-logic / total-
order-slice implementations — sharing only the *syntactic* helpers
(``node_vars``, the expression dataclasses) with the code under test.
Runs on both scan backends and with the SP/OP predicate index enabled and
disabled, plus explicit empty-side OPTIONAL and overlapping-UNION cases.
"""

import numpy as np
import pytest

from repro.core import algebra, k2triples, planner
from repro.core.algebra import (
    And, Bound, Cmp, Filter, Join, LeftJoin, Not, Or, Project, Scan, Slice,
    TriplePattern, Union,
)
from repro.data import rdf


@pytest.fixture(scope="module")
def small_store():
    ds = rdf.generate(220, n_subjects=16, n_preds=5, n_objects=18, seed=17)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, list(map(tuple, ds.ids.tolist())), ds


# ---------------------------------------------------------------------------
# oracle: list-of-dicts solution semantics
# ---------------------------------------------------------------------------

_ORACLE_ROW_LIMIT = 20_000


class _TooBig(Exception):
    """Oracle blow-up guard: regenerate the random tree instead."""


def _o_bgp(T, patterns):
    sols = [dict()]
    for pat in patterns:
        new = []
        for b in sols:
            for (s, p, o) in T:
                bb = dict(b)
                ok = True
                for term, val in ((pat.s, s), (pat.p, p), (pat.o, o)):
                    if isinstance(term, str):
                        if term in bb and bb[term] != val:
                            ok = False
                            break
                        bb[term] = val
                    elif term != val:
                        ok = False
                        break
                if ok:
                    new.append(bb)
        if len(new) > _ORACLE_ROW_LIMIT:
            raise _TooBig
        sols = new
    return sols


def _compat(a, b):
    m = dict(a)
    for k, v in b.items():
        if k in m and m[k] != v:
            return None
        m[k] = v
    return m


def _o_join(A, B):
    if len(A) * max(len(B), 1) > 50 * _ORACLE_ROW_LIMIT:
        raise _TooBig
    out = [m for a in A for b in B if (m := _compat(a, b)) is not None]
    if len(out) > _ORACLE_ROW_LIMIT:
        raise _TooBig
    return out


def _o_leftjoin(A, B):
    out = []
    for a in A:
        ms = [m for b in B if (m := _compat(a, b)) is not None]
        out.extend(ms if ms else [dict(a)])
    if len(out) > _ORACLE_ROW_LIMIT:
        raise _TooBig
    return out


def _o_expr(e, row, scope):
    """SPARQL 3VL: returns True / False / None (None = type error)."""

    def operand(x):
        if isinstance(x, str):
            return row.get(x) if x in scope else None
        return int(x)

    if isinstance(e, Cmp):
        l, r = operand(e.lhs), operand(e.rhs)
        if l is None or r is None:
            return None
        return {
            "==": l == r, "!=": l != r, "<": l < r, "<=": l <= r,
            ">": l > r, ">=": l >= r,
        }[e.op]
    if isinstance(e, Bound):
        return e.var in scope and row.get(e.var) is not None
    if isinstance(e, And):
        a, b = _o_expr(e.a, row, scope), _o_expr(e.b, row, scope)
        if a is False or b is False:
            return False
        if a is None or b is None:
            return None
        return True
    if isinstance(e, Or):
        a, b = _o_expr(e.a, row, scope), _o_expr(e.b, row, scope)
        if a is True or b is True:
            return True
        if a is None or b is None:
            return None
        return False
    if isinstance(e, Not):
        v = _o_expr(e.e, row, scope)
        return None if v is None else not v
    raise TypeError(e)


def _o_eval(node, T):
    if isinstance(node, (Scan, Join)):
        flat = algebra.flatten_bgp(node)
        if flat is not None:
            return _o_bgp(T, flat)
    if isinstance(node, Join):
        return _o_join(_o_eval(node.left, T), _o_eval(node.right, T))
    if isinstance(node, LeftJoin):
        return _o_leftjoin(_o_eval(node.left, T), _o_eval(node.right, T))
    if isinstance(node, Union):
        return _o_eval(node.left, T) + _o_eval(node.right, T)
    if isinstance(node, Filter):
        scope = algebra.node_vars(node.child)
        return [
            r for r in _o_eval(node.child, T)
            if _o_expr(node.expr, r, scope) is True
        ]
    if isinstance(node, Project):
        return [
            {v: r.get(v, 0) for v in node.vars}
            for r in _o_eval(node.child, T)
        ]
    if isinstance(node, Slice):
        rows = _o_eval(node.child, T)
        keys = sorted({k for r in rows for k in r})
        named = []
        sort_keys = []
        for spec in node.order_by:
            desc = spec.startswith("-")
            v = spec[1:] if desc else spec
            named.append(v)
            sort_keys.append((v, desc))
        sort_keys += [(v, False) for v in keys if v not in named]
        uniq = {tuple(r.get(k, 0) for k in keys) for r in rows}
        as_dict = [dict(zip(keys, t)) for t in uniq]
        as_dict.sort(key=lambda r: tuple(
            -(r.get(v) or 0) if d else (r.get(v) or 0) for v, d in sort_keys
        ))
        stop = (
            len(as_dict) if node.limit is None
            else min(len(as_dict), node.offset + node.limit)
        )
        return as_dict[node.offset:stop]
    raise TypeError(node)


def _rows(table):
    keys = sorted(table.cols)
    if not keys:
        return [], keys
    arr = np.stack([table.cols[k] for k in keys], axis=1)
    return list(map(tuple, arr.tolist())), keys


def _oracle_rows(sols, keys):
    return [tuple(r.get(k, 0) for k in keys) for r in sols]


def _check(store, T, node, *, backend, ordered=False, cap=4096):
    got = planner.execute(store, node, cap=cap, exec_=backend)
    got_rows, keys = _rows(got)
    exp_rows = _oracle_rows(_o_eval(node, T), keys)
    if ordered:
        assert got_rows == exp_rows, (node, got_rows, exp_rows)
    else:
        assert set(got_rows) == set(exp_rows), (node, got_rows, exp_rows)


# ---------------------------------------------------------------------------
# random tree generation
# ---------------------------------------------------------------------------

_POOL = ["?a", "?b", "?c", "?x"]


def _random_patterns(rng, ds, T, n_pats):
    while True:
        pats = []
        for _ in range(n_pats):
            s_, p_, o_ = T[rng.integers(0, len(T))]
            terms = []
            for const, extent in (
                (s_, ds.n_subjects), (p_, ds.n_preds), (o_, ds.n_objects),
            ):
                r = rng.random()
                if r < 0.45:
                    terms.append(_POOL[rng.integers(0, len(_POOL))])
                elif r < 0.85:
                    terms.append(int(const))
                else:
                    terms.append(int(rng.integers(1, extent + 1)))
            pats.append(TriplePattern(*terms))
        if any(p.variables for p in pats):
            return pats


def _random_expr(rng, vars_, ds):
    def leaf():
        v = vars_[rng.integers(0, len(vars_))]
        r = rng.random()
        if r < 0.2:
            return Bound(v)
        if r < 0.3:  # out-of-scope variable: the 3VL error path
            return Cmp(">", "?zz", int(rng.integers(1, 5)))
        op = ["==", "!=", "<", "<=", ">", ">="][rng.integers(0, 6)]
        rhs = (
            vars_[rng.integers(0, len(vars_))]
            if rng.random() < 0.3
            else int(rng.integers(1, max(ds.n_subjects, ds.n_objects) + 1))
        )
        return Cmp(op, v, rhs)

    e = leaf()
    if rng.random() < 0.5:
        comb = [And, Or][rng.integers(0, 2)]
        e = comb(e, leaf())
    if rng.random() < 0.2:
        e = Not(e)
    return e


def _random_tree(rng, ds, T, depth):
    if depth == 0 or rng.random() < 0.35:
        return algebra.bgp(_random_patterns(rng, ds, T, int(rng.integers(1, 3))))
    kind = ["join", "leftjoin", "union", "filter"][rng.integers(0, 4)]
    if kind == "filter":
        child = _random_tree(rng, ds, T, depth - 1)
        cvars = sorted(algebra.node_vars(child))
        return Filter(_random_expr(rng, cvars, ds), child)
    left = _random_tree(rng, ds, T, depth - 1)
    right = algebra.bgp(_random_patterns(rng, ds, T, int(rng.integers(1, 3))))
    node_cls = {"join": Join, "leftjoin": LeftJoin, "union": Union}[kind]
    return node_cls(left, right)


def _finish_tree(rng, tree):
    """Randomly wrap with Project and/or Slice; returns (tree, ordered)."""
    names = sorted(algebra.node_vars(tree))
    if rng.random() < 0.4 and names:
        k = int(rng.integers(1, len(names) + 1))
        sel = list(rng.choice(names, size=k, replace=False))
        tree = Project(tree, tuple(sorted(sel)))
        names = sorted(sel)
    if rng.random() < 0.5 and names:
        v = names[rng.integers(0, len(names))]
        spec = ("-" + v) if rng.random() < 0.5 else v
        tree = Slice(tree, (spec,), int(rng.integers(1, 12)),
                     int(rng.integers(0, 3)))
        return tree, True
    return tree, False


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
@pytest.mark.parametrize("with_index", [True, False])
def test_random_trees_match_oracle(small_store, backend, with_index):
    store, T, ds = small_store
    if not with_index:
        store = store.__class__(**{**store.__dict__, "pred_index": None})
    rng = np.random.default_rng(7 if with_index else 8)
    done = 0
    while done < 8:
        tree, ordered = _finish_tree(
            rng, _random_tree(rng, ds, T, int(rng.integers(1, 4)))
        )
        try:
            _check(store, T, tree, backend=backend, ordered=ordered)
        except _TooBig:
            continue  # cartesian blow-up: draw another tree
        done += 1


# ---------------------------------------------------------------------------
# targeted shapes
# ---------------------------------------------------------------------------


def _absent_pair(T, ds):
    """A (p, o) combination carried by no triple: the empty OPTIONAL side."""
    have = {(p, o) for _, p, o in T}
    for p in range(1, ds.n_preds + 1):
        for o in range(1, ds.n_objects + 1):
            if (p, o) not in have:
                return p, o
    raise AssertionError("dataset saturates every (p, o) pair")


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
@pytest.mark.parametrize("with_index", [True, False])
def test_optional_empty_side(small_store, backend, with_index):
    """OPTIONAL over an empty block: every left row survives, right
    variables all UNBOUND."""
    store, T, ds = small_store
    if not with_index:
        store = store.__class__(**{**store.__dict__, "pred_index": None})
    p_dead, o_dead = _absent_pair(T, ds)
    tree = LeftJoin(
        algebra.bgp([TriplePattern("?a", 1, "?b")]),
        algebra.bgp([TriplePattern("?a", p_dead, o_dead)]),
    )
    got = planner.execute(store, tree, cap=4096, exec_=backend)
    left = {(s, o) for s, p, o in T if p == 1}
    assert set(zip(got.cols["?a"].tolist(), got.cols["?b"].tolist())) == left
    _check(store, T, tree, backend=backend)


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_optional_unbound_fill_and_filter(small_store, backend):
    """Unmatched OPTIONAL rows carry UNBOUND; a comparison on the unbound
    column is a SPARQL type error and drops those rows, while Bound()
    can select them."""
    store, T, ds = small_store
    base = LeftJoin(
        algebra.bgp([TriplePattern("?a", 1, "?b")]),
        algebra.bgp([TriplePattern("?b", 2, "?c")]),
    )
    _check(store, T, base, backend=backend)
    _check(store, T, Filter(Cmp(">=", "?c", 1), base), backend=backend)
    _check(store, T, Filter(Not(Bound("?c")), base), backend=backend)


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
@pytest.mark.parametrize("with_index", [True, False])
def test_union_overlapping_branches(small_store, backend, with_index):
    """Overlapping UNION branches: identical rows from both branches
    collapse under the final set semantics; branch-only variables come
    back UNBOUND on the other branch's rows."""
    store, T, ds = small_store
    if not with_index:
        store = store.__class__(**{**store.__dict__, "pred_index": None})
    # branch overlap: p=1 rows appear in both arms
    tree = Project(
        Union(
            algebra.bgp([TriplePattern("?x", 1, "?y")]),
            algebra.bgp([TriplePattern("?x", "?p", "?y")]),
        ),
        ("?x", "?y"),
    )
    got = planner.execute(store, tree, cap=4096, exec_=backend)
    exp = {(s, o) for s, p, o in T}
    assert set(zip(got.cols["?x"].tolist(), got.cols["?y"].tolist())) == exp
    # asymmetric variables: ?z only on the right branch
    tree2 = Union(
        algebra.bgp([TriplePattern("?x", 1, "?y")]),
        algebra.bgp([TriplePattern("?x", 2, "?y"), TriplePattern("?y", 3, "?z")]),
    )
    _check(store, T, tree2, backend=backend)


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_order_limit_deterministic(small_store, backend):
    """The Slice total order (ORDER BY keys, then remaining columns by
    sorted name) makes LIMIT reproducible — byte-identical across runs."""
    store, T, ds = small_store
    tree = Slice(
        algebra.bgp([TriplePattern("?a", "?p", "?b")]),
        ("-?b",), 5, 1,
    )
    a = planner.execute(store, tree, cap=4096, exec_=backend)
    b = planner.execute(store, tree, cap=4096, exec_=backend)
    ra, _ = _rows(a)
    rb, _ = _rows(b)
    assert ra == rb and len(ra) <= 5
    _check(store, T, tree, backend=backend, ordered=True)


# ---------------------------------------------------------------------------
# FILTER pushdown (planner.push_filters): structure + result equivalence
# ---------------------------------------------------------------------------


def test_push_filters_structure():
    """The two rewrite rules, asserted on trees directly."""
    a = algebra.bgp([TriplePattern("?a", 1, "?b")])
    b = algebra.bgp([TriplePattern("?b", 2, "?c")])
    c_left = Cmp(">", "?a", 3)       # scoped by the required side only
    c_right = Cmp(">", "?c", 3)      # needs the OPTIONAL side

    # LeftJoin: left-scoped conjunct sinks below; right-scoped stays
    got = planner.push_filters(Filter(And(c_left, c_right), LeftJoin(a, b)))
    assert got == Filter(c_right, LeftJoin(Filter(c_left, a), b))
    # fully left-scoped: no residual filter remains
    got = planner.push_filters(Filter(c_left, LeftJoin(a, b)))
    assert got == LeftJoin(Filter(c_left, a), b)

    # Union: a conjunct scoped in BOTH arms replicates into each
    u = Union(a, algebra.bgp([TriplePattern("?a", 2, "?b")]))
    got = planner.push_filters(Filter(c_left, u))
    assert got == Union(Filter(c_left, u.left), Filter(c_left, u.right))
    # scoped in only one arm: stays above (conservative)
    u2 = Union(a, b)
    got = planner.push_filters(Filter(c_left, u2))
    assert got == Filter(c_left, u2)

    # recursion reaches nested nodes (a filter two levels down)
    nested = Project(Filter(c_left, LeftJoin(a, b)), ("?a",))
    got = planner.push_filters(nested)
    assert got == Project(LeftJoin(Filter(c_left, a), b), ("?a",))


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_push_filters_differential(small_store, backend):
    """Random Filter-over-LeftJoin/Union trees: ``planner.execute`` (which
    pushes) still matches the oracle evaluating the ORIGINAL tree — the
    rewrite is semantics-preserving — and the rewrite actually fires."""
    store, T, ds = small_store
    rng = np.random.default_rng(23)
    fired = 0
    done = 0
    while done < 10:
        shape = ["leftjoin", "union"][rng.integers(0, 2)]
        left = algebra.bgp(_random_patterns(rng, ds, T, int(rng.integers(1, 3))))
        right = algebra.bgp(_random_patterns(rng, ds, T, int(rng.integers(1, 3))))
        node = (LeftJoin if shape == "leftjoin" else Union)(left, right)
        fvars = sorted(
            algebra.node_vars(left)
            if shape == "leftjoin"
            else algebra.node_vars(node)
        )
        if not fvars:
            continue
        tree = Filter(_random_expr(rng, fvars, ds), node)
        if planner.push_filters(tree) != tree:
            fired += 1
        try:
            _check(store, T, tree, backend=backend)
        except _TooBig:
            continue
        done += 1
    assert fired >= 3  # the rewrite engaged on a real fraction of trees
