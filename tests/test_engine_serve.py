"""Serve-path semantics: ServeResult cap/overflow, pad_preds inertness,
check-lane masking in ``_serve_local`` — on both scan backends."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, k2triples
from repro.data import rdf


@pytest.fixture(scope="module")
def store():
    ds = rdf.generate(3000, n_subjects=120, n_preds=6, n_objects=150, seed=11)
    st = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return st, ds


def _truth(ds):
    return set(map(tuple, ds.ids.tolist()))


def _batch(ops, s, p, o):
    return eng.ServeBatch(
        op=jnp.asarray(ops, jnp.int32), s=jnp.asarray(s, jnp.int32),
        p=jnp.asarray(p, jnp.int32), o=jnp.asarray(o, jnp.int32),
    )


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_serve_local_matches_truth(store, backend):
    st, ds = store
    T = _truth(ds)
    ids = ds.ids[:48]
    ops = np.arange(48) % 3
    q = _batch(ops, ids[:, 0], ids[:, 1], ids[:, 2])
    r = eng._serve_local(st.meta, st.forest, q, cap=256, backend=backend)
    hit, rids, valid = np.asarray(r.hit), np.asarray(r.ids), np.asarray(r.valid)
    for i, (s_, p_, o_) in enumerate(map(tuple, ids.tolist())):
        if ops[i] == eng.OP_CHECK:
            assert hit[i]  # the triple exists by construction
        elif ops[i] == eng.OP_ROW:
            exp = sorted(oo for (ss, pp, oo) in T if ss == s_ and pp == p_)
            assert rids[i][valid[i]].tolist() == exp, i
        else:
            exp = sorted(ss for (ss, pp, oo) in T if pp == p_ and oo == o_)
            assert rids[i][valid[i]].tolist() == exp, i


def test_check_lanes_masked(store):
    """op==OP_CHECK lanes report NO scan output; scan lanes report no hit."""
    st, ds = store
    ids = ds.ids[:16]
    q = _batch(np.zeros(16), ids[:, 0], ids[:, 1], ids[:, 2])  # all checks
    r = eng._serve_local(st.meta, st.forest, q, cap=64)
    assert np.asarray(r.hit).all()
    assert not np.asarray(r.valid).any()
    assert (np.asarray(r.ids) == 0).all()
    assert (np.asarray(r.count) == 0).all()
    assert not np.asarray(r.overflow).any()

    q2 = _batch(np.ones(16), ids[:, 0], ids[:, 1], ids[:, 2])  # all row scans
    r2 = eng._serve_local(st.meta, st.forest, q2, cap=64)
    assert not np.asarray(r2.hit).any()  # hit is a check-lane-only signal
    assert (np.asarray(r2.count) >= 1).all()  # (s,p) came from real triples


def test_serve_overflow_and_cap(store):
    """cap smaller than a row's result count: overflow flag + prefix ids."""
    st, ds = store
    T = _truth(ds)
    # the subject/pred pair with the most objects
    from collections import Counter

    (s_, p_), n = Counter((s, p) for s, p, o in T).most_common(1)[0]
    assert n >= 3
    exp = sorted(oo for (ss, pp, oo) in T if ss == s_ and pp == p_)
    cap = n - 1
    serve = eng.make_serve_step(st.meta, cap=cap)
    r = serve(st.forest, _batch([eng.OP_ROW], [s_], [p_], [0]))
    assert bool(np.asarray(r.overflow)[0])
    got = np.asarray(r.ids)[0][np.asarray(r.valid)[0]]
    assert int(np.asarray(r.count)[0]) == len(got) <= cap
    assert got.tolist() == exp[: len(got)]  # truncation keeps the prefix

    # overflow is CONSERVATIVE: cap == n can still latch it (intermediate
    # frontiers hold 1-nodes with no hit in the scanned line), but a roomy
    # cap must clear the flag and return the complete sorted answer
    serve2 = eng.make_serve_step(st.meta, cap=256)
    r2 = serve2(st.forest, _batch([eng.OP_ROW], [s_], [p_], [0]))
    assert not bool(np.asarray(r2.overflow)[0])
    assert np.asarray(r2.ids)[0][np.asarray(r2.valid)[0]].tolist() == exp


def test_pad_preds_inert(store):
    """Padded predicates are valid empty trees: zero results, and real
    predicates answer identically before/after padding."""
    st, ds = store
    f_pad = eng.pad_preds(st.forest, 8)
    assert f_pad.n_preds == 8
    ids = ds.ids[:24]
    ops = np.arange(24) % 3
    q = _batch(ops, ids[:, 0], ids[:, 1], ids[:, 2])
    r0 = eng._serve_local(st.meta, st.forest, q, cap=64)
    r1 = eng._serve_local(st.meta, f_pad, q, cap=64)
    for a, b in zip(r0, r1):
        assert (np.asarray(a) == np.asarray(b)).all()

    # queries routed AT a padded predicate return nothing on any op
    pad_p = st.forest.n_preds + 1  # 1-based id of the first padded tree
    qp = _batch([0, 1, 2], [1, 1, 0], [pad_p] * 3, [1, 0, 1])
    rp = eng._serve_local(st.meta, f_pad, qp, cap=64)
    assert not np.asarray(rp.hit).any()
    assert not np.asarray(rp.valid).any()
    assert (np.asarray(rp.count) == 0).all()
    assert not np.asarray(rp.overflow).any()


def test_pad_preds_noop_when_aligned(store):
    st, _ = store
    assert eng.pad_preds(st.forest, 3) is st.forest  # 6 % 3 == 0
