"""The observability layer: tracer, metrics registry, cost profiles,
trace validation, and the two contracts that make it safe to ship:

  * **Disabled is free** — with observability off, plan calls and broker
    dispatch make ZERO tracer/obs-registry calls beyond the ``is None``
    branch at each site (spy-based tripwire, mirroring
    ``test_no_env_read_inside_plan_call``).  The broker's own always-on
    bookkeeping registry (plain ``Counter.inc`` behind ``stats()``) is
    the documented exemption: it replaced the old ad-hoc
    ``collections.Counter`` and is not part of the obs layer.
  * **Enabled is consistent** — a traced broker run still returns exact
    answers, its Chrome trace covers every query's
    queue→dispatch→inflight→fetch→decode lifetime, and the metrics
    snapshot agrees with ``stats()``.
"""

import asyncio
import json

import numpy as np
import pytest

import repro.obs as obs
from repro.core import engine as eng, k2triples
from repro.core.query import ExecConfig, ObsConfig, ServeQ
from repro.data import rdf
from repro.launch.broker import CoalescePolicy, ServeBroker, TenantPolicy
from repro.obs.metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, log_buckets,
)
from repro.obs.trace import NOOP_SPAN, Tracer
from repro.obs.validate import validate_chrome_trace


@pytest.fixture(autouse=True)
def _obs_off_after():
    """Observability is process-global state: never leak it across tests."""
    yield
    obs.disable()


@pytest.fixture(scope="module")
def store_and_truth():
    ds = rdf.generate(
        2500, n_subjects=50, n_preds=12, n_objects=70,
        preds_per_subject=3, seed=17,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, set(map(tuple, ds.ids.tolist())), ds


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_log_buckets_shape():
    b = log_buckets(1e-3, 1e3, per_decade=1)
    assert b == (1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0, 1000.0)
    b3 = log_buckets(1.0, 10.0, per_decade=3)
    assert b3[0] == 1.0 and b3[-1] == 10.0 and len(b3) == 4
    assert list(b3) == sorted(b3)
    with pytest.raises(ValueError):
        log_buckets(10.0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(1.0, 10.0, per_decade=0)


def test_counter_gauge_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("x.count")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("x.count") is c  # create-or-return
    g = reg.gauge("x.level")
    g.set(2.5)
    assert g.value == 2.5
    reg.reset()
    assert c.value == 0 and g.value == 0.0  # objects stay valid
    with pytest.raises(TypeError):
        reg.gauge("x.count")  # typed: a name never changes kind


def test_histogram_buckets_and_percentile():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 5.0, 50.0, 500.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(560.5)
    snap = h._snapshot()
    assert snap["buckets"] == {"1.0": 1, "10.0": 2, "100.0": 1, "+Inf": 1}
    assert snap["min"] == 0.5 and snap["max"] == 500.0
    p50 = h.percentile(50)
    assert 1.0 <= p50 <= 10.0  # the median lands in the (1, 10] bucket
    assert h.percentile(100) == 500.0
    assert Histogram("e", (1.0,), reg._lock).percentile(50) is None
    reg.reset()
    assert h.count == 0 and h._snapshot()["buckets"] == {}


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("broker.batches").inc(3)
    reg.gauge("queue.depth").set(7)
    h = reg.histogram("lat.ms", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(20.0)
    text = reg.to_prometheus()
    assert "# TYPE broker_batches counter\nbroker_batches 3" in text
    assert "queue_depth 7" in text
    assert 'lat_ms_bucket{le="1"} 1' in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text  # cumulative
    assert "lat_ms_count 2" in text


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_span_and_chrome_export():
    t = Tracer(capacity=64)
    with t.span("outer", cat="test", k=1):
        with t.span("inner"):
            pass
    t.instant("mark", note="hi")
    ev = t.events()
    assert [e["name"] for e in ev] == ["inner", "outer", "mark"]
    assert ev[1]["t0"] <= ev[0]["t0"] and ev[1]["t1"] >= ev[0]["t1"]

    ch = t.to_chrome(metadata={"run": "unit"})
    assert ch["otherData"]["run"] == "unit"
    assert validate_chrome_trace(ch) == []
    names = {e["name"] for e in ch["traceEvents"]}
    assert {"outer", "inner", "mark", "thread_name"} <= names


def test_tracer_error_annotation():
    t = Tracer(capacity=8)
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    (ev,) = t.events()
    assert ev["args"]["error"] == "RuntimeError"


def test_tracer_retroactive_and_async():
    t = Tracer(capacity=64)
    n0 = t.now()
    t.add("batch", n0, n0 + 1000, tid="batch-slot-0", cat="broker", bid=0)
    t.add_async("query", 7, n0, n0 + 500, tenant="a")
    t.add_async("queue", 7, n0, n0 + 100)
    ch = t.to_chrome()
    assert validate_chrome_trace(ch) == []
    b_events = [e for e in ch["traceEvents"] if e.get("ph") == "b"]
    e_events = [e for e in ch["traceEvents"] if e.get("ph") == "e"]
    assert len(b_events) == len(e_events) == 2
    assert all(e["id"] == "7" for e in b_events)
    # string track ids surface as thread_name metadata
    meta = [e for e in ch["traceEvents"] if e.get("ph") == "M"]
    assert any(e["args"]["name"] == "batch-slot-0" for e in meta)


def test_tracer_ring_drops_oldest():
    t = Tracer(capacity=4)
    for i in range(10):
        t.add(f"s{i}", i, i + 1)
    assert t.dropped == 6
    assert [e["name"] for e in t.events()] == ["s6", "s7", "s8", "s9"]
    assert t.to_chrome()["droppedSpans"] == 6
    t.clear()
    assert t.dropped == 0 and t.events() == []


def test_noop_span_is_shared_and_inert():
    assert obs.span("anything", k=1) is NOOP_SPAN
    with NOOP_SPAN as s:
        assert s is NOOP_SPAN


# ---------------------------------------------------------------------------
# trace validation
# ---------------------------------------------------------------------------


def test_validate_rejects_malformed():
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": []}) != []
    bad_dur = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": -1, "pid": 1, "tid": 1},
    ]}
    assert any("dur" in p for p in validate_chrome_trace(bad_dur))
    overlap = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 1},
    ]}
    assert any("nest" in p for p in validate_chrome_trace(overlap))
    unbalanced = {"traceEvents": [
        {"name": "q", "ph": "b", "ts": 0, "cat": "query", "id": "1",
         "pid": 1, "tid": 0},
    ]}
    assert any("unmatched" in p for p in validate_chrome_trace(unbalanced))
    ok = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 2, "dur": 3, "pid": 1, "tid": 1},
    ]}
    assert validate_chrome_trace(ok) == []
    # --require-queries needs at least one query-cat async span
    assert any("query" in p
               for p in validate_chrome_trace(ok, require_queries=True))


# ---------------------------------------------------------------------------
# cost profiles
# ---------------------------------------------------------------------------


def test_cost_profile_of_compiled_plan(store_and_truth):
    store, _, _ = store_and_truth
    E = eng.Engine(store)
    plan = E.compile(ServeQ(unbounded=False), ExecConfig(backend="jnp", cap=64))
    prof = plan.cost_profile()
    assert prof["geometry"]["cap"] == 64
    assert prof["geometry"]["lanes"] == 8  # pow2-padded minimum
    assert prof["geometry"]["u_width"] == 0  # bounded plan: no u_* block
    assert prof.get("flops", 0) > 0
    assert prof.get("bytes_accessed", 0) > 0
    assert "memory" in prof and prof["memory"]["output_bytes"] > 0
    # cached per geometry: identical dict again, not a recompile
    assert plan.cost_profile() == prof
    # a pattern plan has no raw compiled surface to profile
    from repro.core.query import TriplePatternQ

    pat = E.compile(TriplePatternQ(1, 1, "?o"), ExecConfig(backend="jnp"))
    with pytest.raises(NotImplementedError):
        pat.cost_profile()


# ---------------------------------------------------------------------------
# the disabled-path tripwire
# ---------------------------------------------------------------------------


def _arm_tripwire(monkeypatch):
    """Make every obs-layer recording surface raise.  The broker's
    bookkeeping ``Counter.inc`` (its always-on ``stats()`` registry) is
    deliberately NOT armed — it replaced the ad-hoc stats dict and runs
    regardless of observability, like the stats dict always did."""

    def boom(name):
        def _(*a, **k):
            raise AssertionError(
                f"obs call {name} on the DISABLED path — instrumentation "
                "must be behind an `is None` guard"
            )
        return _

    for m in ("__init__", "begin", "end", "span", "add", "add_async",
              "instant", "_record"):
        monkeypatch.setattr(Tracer, m, boom(f"Tracer.{m}"))
    monkeypatch.setattr(Histogram, "observe", boom("Histogram.observe"))
    monkeypatch.setattr(Gauge, "set", boom("Gauge.set"))


def test_disabled_path_makes_no_obs_calls(monkeypatch, store_and_truth):
    """With observability off, compiled plan calls are obs-free."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    plan = E.compile(ServeQ(unbounded=False), ExecConfig(backend="jnp", cap=256))
    qb = eng.ServeBatch(
        op=np.full(8, eng.OP_CHECK, np.int32), s=ds.ids[:8, 0].astype(np.int32),
        p=ds.ids[:8, 1].astype(np.int32), o=ds.ids[:8, 2].astype(np.int32),
    )
    plan(qb)  # prime compilation before arming the tripwire

    assert not obs.enabled()
    _arm_tripwire(monkeypatch)
    r = plan(qb)
    host = eng.host_result(plan.submit(qb), unbounded=False)
    assert eng.decode_lane(eng.OP_CHECK, host, 0) is True
    assert bool(np.asarray(r.hit)[0])
    E.compile(ServeQ(unbounded=False), ExecConfig(backend="jnp", cap=256))


def test_disabled_path_broker_dispatch(monkeypatch, store_and_truth):
    """With observability off, a full broker roundtrip — enqueue,
    coalesce, dispatch, deliver — is obs-free too (its bookkeeping
    counters excepted, see ``_arm_tripwire``)."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)

    async def main():
        async with ServeBroker(
            E, ExecConfig(backend="jnp", cap=256), unbounded=False,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002),
        ) as b:
            _arm_tripwire(monkeypatch)
            futs = [b.submit_nowait("t", eng.OP_CHECK, *map(int, ds.ids[i]))
                    for i in range(6)]
            return await asyncio.gather(*futs)

    assert not obs.enabled()
    got = asyncio.run(main())
    assert all(got)


# ---------------------------------------------------------------------------
# enabled end-to-end: broker run under tracing + metrics
# ---------------------------------------------------------------------------


def _direct_truth(T, queries):
    out = []
    for op, s, p, o in queries:
        if op == eng.OP_CHECK:
            out.append((s, p, o) in T)
        elif op == eng.OP_ROW:
            out.append(sorted(oo for (ss, pp, oo) in T if ss == s and pp == p))
        else:
            out.append(sorted(ss for (ss, pp, oo) in T if pp == p and oo == o))
    return out


def test_enabled_broker_trace_covers_every_query(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    tracer, metrics = obs.enable(ObsConfig())
    queries = []
    rng = np.random.default_rng(3)
    for i in rng.integers(0, len(ds.ids), 24):
        s, p, o = map(int, ds.ids[i])
        queries.append((int(rng.integers(0, 3)), s, p, o))

    async def main():
        async with ServeBroker(
            E, ExecConfig(backend="jnp", cap=256), unbounded=False,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.001),
        ) as b:
            futs = [b.submit_nowait(f"t{i % 3}", *q)
                    for i, q in enumerate(queries)]
            got = await asyncio.gather(*futs)
            return b, got, b.stats()

    b, got, st = asyncio.run(main())

    # answers stay exact under tracing
    for g, want in zip(got, _direct_truth(T, queries)):
        assert (g if isinstance(g, bool) else sorted(g)) == want

    # the trace is schema-valid and covers every query's lifetime
    ch = tracer.to_chrome()
    assert validate_chrome_trace(ch, require_queries=True) == []
    per_query: dict = {}
    for e in ch["traceEvents"]:
        if e.get("ph") == "b":
            per_query.setdefault(e["id"], set()).add(e["name"])
    assert len(per_query) == len(queries)
    for qid, names in per_query.items():
        assert {"query", "queue", "dispatch", "inflight", "fetch",
                "decode"} <= names, (qid, names)
    batch_spans = [e for e in ch["traceEvents"]
                   if e.get("ph") == "X" and e["name"] == "broker.batch"]
    assert len(batch_spans) == st["batches"]
    assert all(0 < e["args"]["occupancy"] <= 1 for e in batch_spans)

    # the obs metrics snapshot agrees with the broker's reported totals
    snap = metrics.snapshot()
    assert snap["broker.query_latency_ms"]["count"] == st["queries"]
    occ = snap["broker.batch_occupancy"]
    assert occ["count"] == st["batches"]
    book = b.metrics.snapshot()
    assert book["broker.batches"]["value"] == st["batches"]
    assert book["broker.lanes"]["value"] == st["lanes"]

    # per-plan compile-time cost profiles, base geometry included
    profiles = b.cost_profiles()
    assert profiles["base"]["geometry"]["cap"] == 256
    assert profiles["base"].get("flops", 0) > 0


def test_engine_compile_metrics_absorb_plan_cache_stats(store_and_truth):
    store, _, ds = store_and_truth
    E = eng.Engine(store)
    _, metrics = obs.enable(ObsConfig(trace=False, metrics=True))
    cfg = ExecConfig(backend="jnp", cap=128)
    q = ServeQ(unbounded=False)
    E.compile(q, cfg)
    E.compile(q, cfg)
    with pytest.raises(Exception):
        E.compile(q, cfg.replace(cap=64), admit=lambda k: False)
    snap = metrics.snapshot()
    assert snap["engine.plan_cache.misses"]["value"] == 1
    assert snap["engine.plan_cache.hits"]["value"] == 1
    assert snap["engine.plan_cache.denied"]["value"] == 1
    assert E.plan_cache_stats == {
        "hits": 1, "misses": 1, "denied": 1, "size": 1
    }
