"""k²-tree vs dense-matrix oracle, incl. hypothesis sweeps (paper core)."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import k2tree
from repro.core.k2tree import K2Meta, hybrid_ks


def _dense(rows, cols, side):
    d = np.zeros((side, side), np.uint8)
    d[rows, cols] = 1
    return d


def test_hybrid_ks_matches_paper():
    # k=4 for the first 5 levels, then k=2
    ks = hybrid_ks(100_000)
    assert ks[:5] == (4, 4, 4, 4, 4)
    assert all(k == 2 for k in ks[5:])
    assert np.prod(ks) >= 100_000


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=2, max_value=120),  # side_needed
    st.integers(min_value=0, max_value=150),  # nnz
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_check_matches_dense(side_needed, nnz, seed):
    rng = np.random.default_rng(seed)
    meta = K2Meta(hybrid_ks(side_needed))
    rows = rng.integers(0, side_needed, nnz)
    cols = rng.integers(0, side_needed, nnz)
    tree = k2tree.build(rows, cols, meta)
    dense = _dense(rows, cols, meta.side)
    q = 64
    qr = rng.integers(0, side_needed, q)
    qc = rng.integers(0, side_needed, q)
    got = np.asarray(k2tree.check(meta, tree, jnp.asarray(qr), jnp.asarray(qc)))
    assert (got == (dense[qr, qc] == 1)).all()


def test_scans_sorted_and_complete(rng):
    meta = K2Meta(hybrid_ks(200))
    rows = rng.integers(0, 200, 400)
    cols = rng.integers(0, 200, 400)
    tree = k2tree.build(rows, cols, meta)
    dense = _dense(rows, cols, meta.side)
    for r in rng.integers(0, 200, 10):
        res = k2tree.row_scan(meta, tree, jnp.asarray(int(r)), cap=256)
        ids = np.asarray(res.ids)[np.asarray(res.valid)]
        exp = np.nonzero(dense[r])[0]
        assert (ids == exp).all()  # equality => ID-sorted (merge-join ready)
    for c in rng.integers(0, 200, 10):
        res = k2tree.col_scan(meta, tree, jnp.asarray(int(c)), cap=256)
        ids = np.asarray(res.ids)[np.asarray(res.valid)]
        assert (ids == np.nonzero(dense[:, c])[0]).all()


def test_range_scan_full(rng):
    meta = K2Meta(hybrid_ks(64))
    rows = rng.integers(0, 64, 100)
    cols = rng.integers(0, 64, 100)
    tree = k2tree.build(rows, cols, meta)
    dense = _dense(rows, cols, meta.side)
    res = k2tree.range_scan(meta, tree, cap=512)
    v = np.asarray(res.valid)
    got = set(zip(np.asarray(res.rows)[v].tolist(), np.asarray(res.cols)[v].tolist()))
    assert got == set(zip(*np.nonzero(dense)))


def test_overflow_flag(rng):
    meta = K2Meta(hybrid_ks(64))
    rows = np.zeros(60, np.int64)  # dense row 0
    cols = np.arange(60)
    tree = k2tree.build(rows, cols, meta)
    res = k2tree.row_scan(meta, tree, jnp.asarray(0), cap=16)
    assert bool(res.overflow)
    assert int(res.count) <= 16


def test_size_bits_compresses(rng):
    """The paper's point: sparse matrices compress far below dense bits."""
    meta = K2Meta(hybrid_ks(4096))
    rows = rng.integers(0, 4096, 2000)
    cols = rng.integers(0, 4096, 2000)
    h = k2tree.build_host(rows, cols, meta)
    dense_bits = 4096 * 4096
    assert k2tree.size_bits(h) < dense_bits / 50
