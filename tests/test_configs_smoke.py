"""REQUIRED per-arch smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs.  One test per assigned architecture."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get
from repro.data import graphs as G, recsys as R
from repro.data.tokens import TokenStream
from repro.launch.programs import GNN_MODULES
from repro.models import transformer as tfm
from repro.models.recsys import xdeepfm

LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in ARCHS.items() if s.family == "gnn"]


def test_registry_complete():
    fams = {}
    for a, s in ARCHS.items():
        fams.setdefault(s.family, []).append(a)
    assert len(fams["lm"]) == 5
    assert len(fams["gnn"]) == 4
    assert len(fams["recsys"]) == 1
    assert "k2triples" in fams["engine"]
    # 40 assigned cells
    n_cells = sum(len(s.shapes) for s in ARCHS.values() if s.family != "engine")
    assert n_cells == 40


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    spec = get(arch_id)
    cfg = spec.smoke_cfg
    params = tfm.init(cfg, jax.random.PRNGKey(0))
    ts = TokenStream(cfg.vocab, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ts.batch(2).items()}
    loss, grads = jax.value_and_grad(lambda p: tfm.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss)), arch_id
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch_id
    # serve path: prefill emits logits of the right shape
    logits, cache = tfm.prefill(cfg, params, batch["tokens"])
    assert logits.shape == (2, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_smoke_train_step(arch_id):
    spec = get(arch_id)
    mod = GNN_MODULES[arch_id]
    mol = G.molecule_batch(4, 8, 16, seed=1)
    mol = jax.tree.map(lambda x: jnp.asarray(x) if isinstance(x, np.ndarray) else x, mol)
    cfg = spec.smoke_cfg
    if hasattr(cfg, "in_dim"):
        cfg = dataclasses.replace(cfg, in_dim=mol.node_feat.shape[1], out_dim=1)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, mol))(params)
    assert np.isfinite(float(loss)), arch_id
    out = mod.forward(cfg, params, mol)
    assert out.shape[0] == mol.node_feat.shape[0]
    assert np.isfinite(np.asarray(out, np.float32)).all(), arch_id


def test_recsys_smoke_train_step():
    spec = get("xdeepfm")
    cfg = spec.smoke_cfg
    params = xdeepfm.init(cfg, jax.random.PRNGKey(0))
    b = {k: jnp.asarray(v) for k, v in R.ctr_batch(32, cfg.n_fields, cfg.rows_per_field).items()}
    loss, grads = jax.value_and_grad(lambda p: xdeepfm.loss_fn(cfg, p, b))(params)
    assert np.isfinite(float(loss))
    logit = xdeepfm.forward(cfg, params, b["ids"])
    assert logit.shape == (32,)
    assert np.isfinite(np.asarray(logit)).all()


def test_engine_smoke_serve():
    from repro.core import engine as eng, k2triples
    from repro.data import rdf

    cfg = get("k2triples").smoke_cfg
    ds = rdf.generate(
        cfg.n_triples, n_subjects=cfg.n_subjects, n_preds=cfg.n_preds,
        n_objects=cfg.n_objects, seed=0,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    serve = eng.make_serve_step(store.meta, cap=cfg.cap)
    ids = ds.ids[:16]
    q = eng.ServeBatch(
        op=jnp.zeros(16, jnp.int32), s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(ids[:, 1], jnp.int32), o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    r = serve(store.forest, q)
    assert np.asarray(r.hit).all()  # every existing triple found


@pytest.mark.parametrize(
    "arch_id,shape_id",
    [("tinyllama-1.1b", "train_4k"), ("egnn", "molecule"),
     ("xdeepfm", "serve_p99"), ("k2triples", "serve_64k")],
)
def test_program_builders_smoke_lower(arch_id, shape_id):
    """Program builders produce lowerable cells on a 1x1 mesh (smoke shapes)."""
    from repro.launch import programs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    prog = programs.build(arch_id, shape_id, mesh, smoke=True)
    with mesh:
        lowered = jax.jit(prog.fn, in_shardings=prog.in_shardings).lower(*prog.in_specs)
        assert lowered is not None
