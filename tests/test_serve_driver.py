"""Regression tests for the serve-driver bugfixes:

  * ``serve_mesh_shape`` uses EVERY device (the old ``min(4, n)``
    factorization silently dropped devices when ``n % 4 != 0``);
  * ``--sharded`` on a single visible device errors loudly instead of
    silently serving unsharded;
  * ``ExecConfig.from_env`` distinguishes an unset REPRO_PALLAS_INTERPRET
    (auto) from an explicit ``"1"`` (the old expression AND'd the env
    value with ``default_interpret()``, so an explicit 1 was ignored on
    TPU);
  * trace generation sanity for the serving benchmark.
"""

import numpy as np
import pytest

from repro.core import query as qapi
from repro.launch import mesh as meshlib
from repro.launch import serve


# ---------------------------------------------------------------------------
# satellite 1: mesh factorization must use every device
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", list(range(1, 17)))
def test_serve_mesh_shape_uses_every_device(n):
    dp, mp = meshlib.serve_mesh_shape(n)
    assert dp * mp == n  # the old bug: 6 -> (1, 4) served on 4 of 6
    assert 1 <= mp <= 4


def test_serve_mesh_shape_known_factorizations():
    assert meshlib.serve_mesh_shape(6) == (2, 3)
    assert meshlib.serve_mesh_shape(8) == (2, 4)
    assert meshlib.serve_mesh_shape(5) == (5, 1)  # prime: model stays 1
    assert meshlib.serve_mesh_shape(12) == (3, 4)
    assert meshlib.serve_mesh_shape(1) == (1, 1)


def test_serve_mesh_shape_rejects_zero_devices():
    with pytest.raises(ValueError):
        meshlib.serve_mesh_shape(0)


def test_serve_mesh_shape_model_max():
    assert meshlib.serve_mesh_shape(16, model_max=8) == (2, 8)
    assert meshlib.serve_mesh_shape(16, model_max=3) == (8, 2)


# ---------------------------------------------------------------------------
# satellite 2: --sharded with one device must not silently degrade
# ---------------------------------------------------------------------------


def test_sharded_single_device_errors():
    import jax

    if len(jax.devices()) > 1:
        pytest.skip("needs a single-device backend to exercise the guard")
    with pytest.raises(ValueError, match="only one device"):
        serve.run_bench(
            n_triples=500, n_preds=4, n_queries=8, n_tenants=2,
            sharded=True, quiet=True,
        )


# ---------------------------------------------------------------------------
# satellite 3: from_env interpret tri-state
# ---------------------------------------------------------------------------


def test_from_env_interpret_unset_uses_auto(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.setattr(qapi, "default_interpret", lambda: False)
    assert qapi.ExecConfig.from_env().interpret is False
    monkeypatch.setattr(qapi, "default_interpret", lambda: True)
    assert qapi.ExecConfig.from_env().interpret is True


def test_from_env_interpret_explicit_1_wins(monkeypatch):
    """The regression: on a real-TPU host default_interpret() is False and
    the old ``env != "0" and default_interpret()`` silently discarded an
    explicit REPRO_PALLAS_INTERPRET=1."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    monkeypatch.setattr(qapi, "default_interpret", lambda: False)
    assert qapi.ExecConfig.from_env().interpret is True


def test_from_env_interpret_explicit_0_wins(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    monkeypatch.setattr(qapi, "default_interpret", lambda: True)
    assert qapi.ExecConfig.from_env().interpret is False


def test_from_env_interpret_override_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert qapi.ExecConfig.from_env(interpret=True).interpret is True


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------


def test_zipf_weights():
    w = serve.zipf_weights(8, 1.1)
    assert w.shape == (8,)
    assert w.sum() == pytest.approx(1.0)
    assert (np.diff(w) < 0).all()  # tenant 0 heaviest, strictly skewed


def test_make_trace_shape_and_ops():
    from repro.data import rdf

    ds = rdf.generate(
        2000, n_subjects=40, n_preds=8, n_objects=60,
        preds_per_subject=3, seed=1,
    )
    trace = serve.make_trace(ds, 500, 4, zipf_a=1.1, seed=2)
    assert len(trace) == 500
    tenants = {t for t, *_ in trace}
    assert tenants <= {f"tenant-{i}" for i in range(4)}
    for _, op, s, p, o in trace:
        assert 0 <= op <= 5
        assert s >= 1 and o >= 1
        # unbounded-?P ops must leave the predicate free
        assert (p == 0) if op >= 3 else (p >= 1)
    # skew: the heaviest tenant dominates
    counts = {t: sum(1 for row in trace if row[0] == t) for t in tenants}
    assert counts["tenant-0"] == max(counts.values())


def test_make_trace_bounded_only():
    from repro.data import rdf

    ds = rdf.generate(
        1000, n_subjects=30, n_preds=6, n_objects=50,
        preds_per_subject=2, seed=3,
    )
    trace = serve.make_trace(ds, 200, 2, unbounded=False, seed=4)
    assert all(op < 3 for _, op, *_ in trace)


# ---------------------------------------------------------------------------
# end-to-end harness smoke (tiny, jnp)
# ---------------------------------------------------------------------------


def test_run_bench_smoke_row():
    row = serve.run_bench(
        n_triples=2000, n_preds=8, n_tenants=3, n_queries=48,
        cap=128, max_batch=16, deadline_ms=1.0, backend="jnp",
        warmup=8, quiet=True,
    )
    assert row["mode"] == "single"
    assert row["queries"] == 48
    assert row["qps"] > 0
    assert row["p50_ms"] is not None and row["p50_ms"] > 0
    assert row["p99_ms"] is None  # 48 samples cannot support a p99
    assert row["shed"] == 0
    assert set(row["per_tenant"]) <= {f"tenant-{i}" for i in range(3)}
    assert "n/a" in serve.format_row(row)  # guard surfaces in the report
