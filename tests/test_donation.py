"""Buffer-donation tripwires for the pooled serve programs.

``ExecConfig.donate_batch`` makes the single-device serve program donate
its per-batch ``ServeBatch`` buffers to XLA (outputs may alias the batch
memory).  The hazards this suite pins down:

  * a donating program really consumes a device batch (``is_deleted()``)
    — if donation silently stops plumbing through, the perf win vanishes
    with no functional signal;
  * the engine's executors defensively COPY caller batches per dispatch,
    so a caller-held device batch survives ``plan(batch)`` and can be
    resubmitted — including across a cap-growth recompile, where the SAME
    logical batch is dispatched twice (the retry must not see a deleted
    buffer);
  * ``donate_batch=False`` restores non-consuming programs bit-exactly;
  * sharded configs never donate, whatever the flag says.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as eng, k2triples
from repro.core.query import ExecConfig, ServeQ
from repro.data import rdf

from oracle import assert_results_identical


@pytest.fixture(scope="module")
def store_and_ids():
    ds = rdf.generate(3000, n_subjects=64, n_preds=8, n_objects=80, seed=3)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, ds.ids


def _device_batch(store, b=8, seed=0):
    rng = np.random.default_rng(seed)
    ops = np.array([i % 3 for i in range(b)], np.int32)
    return eng.ServeBatch(
        op=jnp.asarray(ops),
        s=jnp.asarray(rng.integers(1, store.n_subjects + 1, b), jnp.int32),
        p=jnp.asarray(rng.integers(1, store.n_preds + 1, b), jnp.int32),
        o=jnp.asarray(rng.integers(1, store.n_objects + 1, b), jnp.int32),
    )


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donating_program_consumes_device_batch(store_and_ids):
    """make_serve_step(donate=True) really donates: at least one batch
    buffer is consumed by the call (XLA aliases what it can — buffers it
    cannot use stay alive, with a warning this test tolerates), and the
    result matches the non-donating program on an identical batch.  If
    donation silently stops plumbing through, NO buffer is deleted and
    this trips."""
    store, _ = store_and_ids
    step_d = eng.make_serve_step(store.meta, 32, backend="jnp", donate=True)
    step_n = eng.make_serve_step(store.meta, 32, backend="jnp", donate=False)
    qb = _device_batch(store)
    qb2 = eng.ServeBatch(*(jnp.array(a, copy=True) for a in qb))
    r_n = step_n(store.forest, qb2)
    r_d = step_d(store.forest, qb)
    assert_results_identical(tuple(r_d), tuple(r_n), "donate-vs-not")
    assert any(a.is_deleted() for a in qb), "donated batch must be consumed"
    assert not any(a.is_deleted() for a in qb2)


@pytest.mark.parametrize("donate", [True, False])
def test_plan_leaves_caller_batch_alive(store_and_ids, donate):
    """The pooled Plan path defensively copies: a caller-held device batch
    survives the call under donation and can be resubmitted, and the
    donate flag never changes answers."""
    store, _ = store_and_ids
    E = eng.Engine(store)
    plan = E.compile(
        ServeQ(unbounded=False),
        ExecConfig(backend="jnp", cap=64, donate_batch=donate),
    )
    qb = _device_batch(store, seed=1)
    r1 = plan(qb)
    assert not any(a.is_deleted() for a in qb), "caller batch was consumed"
    r2 = plan(qb)  # resubmitting the same buffers must be legal
    assert_results_identical(tuple(r1), tuple(r2), f"resubmit[{donate}]")


def test_donation_survives_cap_growth_recompile(store_and_ids):
    """The tripwire for the double-dispatch hazard: a batch that overflows
    the initial cap makes ``Plan`` recompile at doubled cap and re-run the
    SAME logical batch — each dispatch must get fresh buffers or the retry
    dies on a deleted donated array."""
    store, ids = store_and_ids
    sp, counts = np.unique(ids[:, :2], axis=0, return_counts=True)
    k = int(np.argmax(counts))
    deg_s, deg_p, deg = int(sp[k, 0]), int(sp[k, 1]), int(counts[k])
    assert deg >= 2, "need a row with degree >= 2 to overflow cap=1"
    E = eng.Engine(store_and_ids[0])
    plan = E.compile(
        ServeQ(unbounded=False),
        ExecConfig(backend="jnp", cap=1, donate_batch=True),
    )
    qb = eng.ServeBatch(
        op=jnp.asarray([eng.OP_ROW], jnp.int32),
        s=jnp.asarray([deg_s], jnp.int32),
        p=jnp.asarray([deg_p], jnp.int32),
        o=jnp.asarray([0], jnp.int32),
    )
    r = plan(qb)  # grows cap at least once, re-dispatching qb
    assert plan.effective_cap >= 2
    assert not any(a.is_deleted() for a in qb)
    got = np.asarray(r.ids[0])[np.asarray(r.valid[0])]
    assert got.shape[0] == deg


def test_submit_is_donation_safe_for_streaming(store_and_ids):
    """``Plan.submit`` (the broker's no-sync path) must also preserve the
    caller's buffers — the broker re-reads batch columns for decode."""
    store, _ = store_and_ids
    E = eng.Engine(store)
    plan = E.compile(
        ServeQ(unbounded=True),
        ExecConfig(backend="jnp", cap=64, donate_batch=True),
    )
    qb = _device_batch(store, seed=2)
    r = plan.submit(qb)
    assert not any(a.is_deleted() for a in qb)
    # the result is real device output, identical on a second submit
    r2 = plan.submit(qb)
    assert_results_identical(tuple(r), tuple(r2), "submit-twice")


def test_sharded_config_never_donates(store_and_ids):
    """Donation is single-device only: with a mesh, the executor's
    ``_donates()`` is False no matter the flag (donating sharded inputs
    would alias buffers across shards)."""
    store, _ = store_and_ids
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", donate_batch=True)
    assert cfg.mesh is None
    ex = E.compile(ServeQ(unbounded=False), cfg)._executor
    assert ex._donates() is True
    if len(jax.devices()) == 1:
        pytest.skip("needs >1 device to build a mesh config")
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    cfg_sh = ExecConfig(backend="jnp", donate_batch=True, mesh=mesh)
    ex_sh = E.compile(ServeQ(unbounded=False), cfg_sh)._executor
    assert ex_sh._donates() is False
