"""Packed bit vector + rank: numpy oracles and hypothesis properties."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import bitvec


def test_pack_roundtrip(rng):
    bits = (rng.random(1000) < 0.3).astype(np.uint8)
    words = bitvec.pack_bits_np(bits)
    unpacked = np.zeros(len(bits), np.uint8)
    for i in range(len(bits)):
        unpacked[i] = (words[i >> 5] >> np.uint32(i & 31)) & 1
    assert (unpacked == bits).all()


def test_popcount_np(rng):
    w = rng.integers(0, 2**32, 256, dtype=np.uint32)
    exp = np.array([bin(int(x)).count("1") for x in w])
    assert (bitvec.popcount_np(w) == exp).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=300), st.data())
def test_rank1_matches_cumsum(bits, data):
    bits = np.array(bits, np.uint8)
    bv = bitvec.bitvec_from_bits(bits)
    pos = data.draw(st.integers(min_value=0, max_value=len(bits) - 1))
    got = int(bitvec.rank1(bv.words, bv.rank_blocks, jnp.asarray(pos)))
    assert got == int(bits[:pos].sum())


def test_rank1_vectorized(rng):
    bits = (rng.random(2048) < 0.5).astype(np.uint8)
    bv = bitvec.bitvec_from_bits(bits)
    pos = rng.integers(0, 2048, 200)
    got = np.asarray(bitvec.rank1(bv.words, bv.rank_blocks, jnp.asarray(pos)))
    exp = np.cumsum(bits)[pos] - bits[pos]  # exclusive rank
    exp = np.concatenate([[0], np.cumsum(bits)])[pos]
    assert (got == exp).all()


def test_get_bit(rng):
    bits = (rng.random(500) < 0.2).astype(np.uint8)
    bv = bitvec.bitvec_from_bits(bits)
    pos = rng.integers(0, 500, 100)
    got = np.asarray(bitvec.get_bit(bv.words, jnp.asarray(pos)))
    assert (got == bits[pos]).all()
