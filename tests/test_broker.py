"""The streaming multi-tenant serve broker (`repro.launch.broker`):

  * coalescing respects the deadline/size policy;
  * per-tenant result ordering is preserved, including through retries;
  * back-pressure sheds per the documented shed-newest policy;
  * admission control isolates a cap-doubling tenant (budgets, quotas,
    and the shared base plan never growing);
  * broker results are bit-identical to direct ``plan(batch)`` calls on
    both scan backends, single-device and mesh-sharded.
"""

import asyncio
from collections import Counter

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import delta, engine as eng, k2triples
from repro.core.query import (
    AdmissionError, CapOverflow, ExecConfig, ServeQ,
)
from repro.data import rdf
from repro.launch.broker import (
    CoalescePolicy, QueueFull, ServeBroker, TenantPolicy, tail_percentile,
)


@pytest.fixture(scope="module")
def store_and_truth():
    ds = rdf.generate(
        2500, n_subjects=50, n_preds=12, n_objects=70,
        preds_per_subject=3, seed=17,
    )
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    return store, set(map(tuple, ds.ids.tolist())), ds


def _hot_row(T):
    """The (s, p) with the most objects — guaranteed to overflow tiny caps."""
    (s, p), n = Counter((s, p) for s, p, o in T).most_common(1)[0]
    return s, p, n


def _mixed_queries(ds, n, seed=0, ops_hi=6):
    rng = np.random.default_rng(seed)
    ops = rng.integers(0, ops_hi, n)
    rows = ds.ids[rng.integers(0, ds.n_triples, n)]
    out = []
    for i in range(n):
        s, p, o = map(int, rows[i])
        out.append((int(ops[i]), s, 0 if ops[i] >= 3 else p, o))
    return out


# ---------------------------------------------------------------------------
# coalescing policy
# ---------------------------------------------------------------------------


def test_coalesce_size_flush(store_and_truth):
    """max_batch pending requests flush as ONE batch (size-triggered)."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)

    async def main():
        pol = CoalescePolicy(max_batch=16, max_delay_s=10.0)  # deadline far off
        async with ServeBroker(E, cfg, unbounded=False, coalesce=pol) as b:
            futs = [
                b.submit_nowait("t0", eng.OP_CHECK, *map(int, ds.ids[i]))
                for i in range(16)
            ]
            await asyncio.gather(*futs)
            return b.stats()

    st = asyncio.run(main())
    assert st["batches"] == 1
    assert st["lanes"] == 16
    assert st["flush_size"] == 1
    assert st["coalesce_factor"] == 16.0


def test_coalesce_deadline_flush(store_and_truth):
    """Fewer than max_batch requests flush once the oldest hits the
    deadline — they are not parked until the batch fills."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)

    async def main():
        pol = CoalescePolicy(max_batch=64, max_delay_s=0.01)
        async with ServeBroker(E, cfg, unbounded=False, coalesce=pol) as b:
            futs = [
                b.submit_nowait("t0", eng.OP_CHECK, *map(int, ds.ids[i]))
                for i in range(3)
            ]
            await asyncio.gather(*futs)
            st = b.stats()
            assert st["batches"] == 1 and st["lanes"] == 3
            assert st["flush_deadline"] == 1
            return st

    asyncio.run(main())


# ---------------------------------------------------------------------------
# ordering
# ---------------------------------------------------------------------------


def test_per_tenant_ordering_preserved(store_and_truth):
    """Results resolve in submission order per tenant — including when a
    tenant's lane overflows and is retried at grown cap mid-stream."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    s_hot, p_hot, n_hot = _hot_row(T)
    cfg = ExecConfig(backend="jnp", cap=2)  # hot row overflows

    order: dict[str, list[int]] = {"A": [], "B": []}

    async def main():
        pol = CoalescePolicy(max_batch=32, max_delay_s=0.005)
        async with ServeBroker(E, cfg, unbounded=False, coalesce=pol) as b:
            futs = []
            for k in range(12):
                tenant = "A" if k % 2 == 0 else "B"
                if tenant == "A" and k in (4, 6):
                    f = b.submit_nowait(tenant, eng.OP_ROW, s_hot, p_hot, 0)
                else:
                    s, p, o = map(int, ds.ids[k])
                    f = b.submit_nowait(tenant, eng.OP_CHECK, s, p, o)
                f.add_done_callback(
                    lambda _, t=tenant, seq=k: order[t].append(seq)
                )
                futs.append(f)
            await asyncio.gather(*futs)

    asyncio.run(main())
    assert order["A"] == sorted(order["A"])
    assert order["B"] == sorted(order["B"])
    assert len(order["A"]) == 6 and len(order["B"]) == 6


# ---------------------------------------------------------------------------
# back-pressure
# ---------------------------------------------------------------------------


def test_backpressure_sheds_newest(store_and_truth):
    """The documented shed policy: a submit over queue_depth raises
    QueueFull synchronously, accepted requests all complete, and other
    tenants are unaffected."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)

    async def main():
        pol = CoalescePolicy(max_batch=8, max_delay_s=5.0)
        async with ServeBroker(
            E, cfg, unbounded=False, coalesce=pol,
            tenant_policy=TenantPolicy(queue_depth=4),
        ) as b:
            accepted = [
                b.submit_nowait("flood", eng.OP_CHECK, *map(int, ds.ids[i]))
                for i in range(4)
            ]
            with pytest.raises(QueueFull):
                b.submit_nowait("flood", eng.OP_CHECK, *map(int, ds.ids[4]))
            # a different tenant still gets in
            ok = b.submit_nowait("calm", eng.OP_CHECK, *map(int, ds.ids[5]))
            res = await asyncio.gather(*accepted, ok)
            st = b.stats()
            assert st["shed"] == 1
            assert st["tenants"]["flood"]["shed"] == 1
            assert st["tenants"]["calm"]["shed"] == 0
            assert st["tenants"]["flood"]["queries"] == 4  # nothing accepted dropped
            return res

    res = asyncio.run(main())
    assert all(isinstance(r, bool) for r in res)


# ---------------------------------------------------------------------------
# admission control / cap isolation
# ---------------------------------------------------------------------------


def test_cap_doubling_tenant_isolated(store_and_truth):
    """A tenant whose queries overflow grows ITS retry plans; the shared
    base plan keeps its cap, the calm tenant's stats stay clean, and the
    grown results are exact."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    s_hot, p_hot, n_hot = _hot_row(T)
    assert n_hot > 4
    cfg = ExecConfig(backend="jnp", cap=2)

    async def main():
        async with ServeBroker(
            E, cfg, unbounded=False,
            coalesce=CoalescePolicy(max_batch=16, max_delay_s=0.002),
            tenant_policy=TenantPolicy(max_cap_doublings=8, max_plans=8),
        ) as b:
            fa = [b.submit_nowait("hot", eng.OP_ROW, s_hot, p_hot, 0)
                  for _ in range(3)]
            fb = [b.submit_nowait("calm", eng.OP_CHECK, *map(int, ds.ids[i]))
                  for i in range(3)]
            ra = await asyncio.gather(*fa)
            rb = await asyncio.gather(*fb)
            return ra, rb, b.stats(), b.base_plan.effective_cap

    ra, rb, st, base_cap = asyncio.run(main())
    exp = sorted(oo for (ss, pp, oo) in T if ss == s_hot and pp == p_hot)
    for r in ra:
        assert list(r) == exp  # complete answers after growth
    assert all(rb)
    assert base_cap == 2  # the SHARED plan never grew
    assert st["tenants"]["hot"]["cap_level"] >= 1
    assert st["tenants"]["hot"]["plans_charged"] >= 1
    assert st["tenants"]["calm"]["cap_level"] == 0
    assert st["tenants"]["calm"]["plans_charged"] == 0
    assert st["cap_growth_events"] >= 1


def test_cap_budget_exhaustion_fails_only_offender(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    s_hot, p_hot, _ = _hot_row(T)
    cfg = ExecConfig(backend="jnp", cap=2)

    async def main():
        async with ServeBroker(
            E, cfg, unbounded=False,
            coalesce=CoalescePolicy(max_batch=16, max_delay_s=0.002),
            tenant_policy=TenantPolicy(max_cap_doublings=0),
        ) as b:
            f_bad = b.submit_nowait("greedy", eng.OP_ROW, s_hot, p_hot, 0)
            f_ok = b.submit_nowait("calm", eng.OP_CHECK, *map(int, ds.ids[0]))
            with pytest.raises(CapOverflow):
                await f_bad
            assert await f_ok is True
            st = b.stats()
            assert st["tenants"]["greedy"]["failed"] == 1
            assert st["tenants"]["calm"]["failed"] == 0

    asyncio.run(main())


def test_plan_quota_denial(store_and_truth):
    """max_plans=0 denies the first retry compile with AdmissionError; the
    engine's plan cache gains nothing for that tenant."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    s_hot, p_hot, _ = _hot_row(T)
    cfg = ExecConfig(backend="jnp", cap=2)

    async def main():
        async with ServeBroker(
            E, cfg, unbounded=False,
            tenant_policy=TenantPolicy(max_plans=0),
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002),
        ) as b:
            misses_before = E.plan_cache_stats["misses"]
            with pytest.raises(AdmissionError):
                await b.submit("greedy", eng.OP_ROW, s_hot, p_hot, 0)
            st = b.stats()
            assert st["admission_denials"] == 1
            assert E.plan_cache_stats["misses"] == misses_before

    asyncio.run(main())


def test_shared_retry_plans_are_free_for_second_tenant(store_and_truth):
    """Admission charges plan-cache MISSES only: after tenant A compiled
    the doubled-cap plan, tenant B's identical growth is a hit — zero
    plans charged to B."""
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    s_hot, p_hot, _ = _hot_row(T)
    cfg = ExecConfig(backend="jnp", cap=2)

    async def main():
        async with ServeBroker(
            E, cfg, unbounded=False,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002),
            tenant_policy=TenantPolicy(max_cap_doublings=8, max_plans=8),
        ) as b:
            await b.submit("A", eng.OP_ROW, s_hot, p_hot, 0)
            await b.submit("B", eng.OP_ROW, s_hot, p_hot, 0)
            st = b.stats()
            assert st["tenants"]["A"]["plans_charged"] >= 1
            assert st["tenants"]["B"]["plans_charged"] == 0  # cache hits
            assert st["tenants"]["B"]["cap_level"] >= 1  # but it did grow

    asyncio.run(main())


# ---------------------------------------------------------------------------
# differential: broker == direct plan(batch)
# ---------------------------------------------------------------------------


def _direct_decoded(E, cfg, queries, unbounded=True):
    """Reference: one direct Plan call per query through the blocking API,
    decoded with the same lane decoder."""
    plan = E.compile(ServeQ(unbounded=unbounded), cfg)
    out = []
    for (op, s, p, o) in queries:
        qb = eng.ServeBatch(
            op=jnp.asarray([op] + [-1] * 7, jnp.int32),
            s=jnp.asarray([s] + [0] * 7, jnp.int32),
            p=jnp.asarray([p] + [0] * 7, jnp.int32),
            o=jnp.asarray([o] + [0] * 7, jnp.int32),
        )
        r = plan(qb)
        out.append(eng.decode_lane(op, eng.host_result(r), 0))
    return out


def _assert_same(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            assert np.asarray(a[k]).tolist() == np.asarray(b[k]).tolist()
    elif isinstance(a, (bool, np.bool_)):
        assert bool(a) == bool(b)
    else:
        assert np.asarray(a).tolist() == np.asarray(b).tolist()


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_broker_matches_direct_plan(store_and_truth, backend):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend=backend, cap=256)
    queries = _mixed_queries(ds, 24, seed=3)

    async def main():
        async with ServeBroker(
            E, cfg, coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002)
        ) as b:
            futs = [b.submit_nowait("t0", *q) for q in queries]
            return await asyncio.gather(*futs)

    got = asyncio.run(main())
    ref = _direct_decoded(E, cfg, queries)
    for g, r in zip(got, ref):
        _assert_same(g, r)


def test_broker_matches_direct_plan_sharded(store_and_truth):
    """Mesh-sharded broker == single-device reference (1x1 mesh exercises
    the shard_map'd program + data-axis padding on any device count)."""
    import jax

    store, T, ds = store_and_truth
    E = eng.Engine(store)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ExecConfig(backend="jnp", cap=256, mesh=mesh)
    ref_cfg = ExecConfig(backend="jnp", cap=256)
    queries = _mixed_queries(ds, 12, seed=5)

    async def main():
        async with ServeBroker(
            E, cfg, coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002)
        ) as b:
            futs = [b.submit_nowait("t0", *q) for q in queries]
            return await asyncio.gather(*futs)

    got = asyncio.run(main())
    ref = _direct_decoded(E, ref_cfg, queries)
    for g, r in zip(got, ref):
        _assert_same(g, r)


def test_stream_yields_in_order(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)
    queries = _mixed_queries(ds, 16, seed=7, ops_hi=3)

    async def main():
        out = []
        async with ServeBroker(
            E, cfg, unbounded=False,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002),
            tenant_policy=TenantPolicy(queue_depth=4),  # forces windowing
        ) as b:
            async for res in b.stream("t0", queries):
                out.append(res)
        return out

    got = asyncio.run(main())
    ref = _direct_decoded(E, cfg, queries, unbounded=False)
    assert len(got) == len(ref)
    for g, r in zip(got, ref):
        _assert_same(g, r)


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------


def test_tail_percentile_guard():
    assert tail_percentile([], 50) is None
    assert tail_percentile([1.0], 50) is None  # p50 needs 2 samples
    assert tail_percentile([1.0, 2.0], 50) == pytest.approx(1.5)
    assert tail_percentile(list(range(99)), 99) is None  # p99 needs 100
    assert tail_percentile(list(range(100)), 99) is not None
    with pytest.raises(ValueError):
        tail_percentile([1.0], 100)


def test_stats_surface(store_and_truth):
    store, T, ds = store_and_truth
    E = eng.Engine(store)
    cfg = ExecConfig(backend="jnp", cap=256)

    async def main():
        async with ServeBroker(
            E, cfg, unbounded=False,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002),
        ) as b:
            futs = [
                b.submit_nowait(f"t{i % 2}", eng.OP_CHECK, *map(int, ds.ids[i]))
                for i in range(8)
            ]
            await asyncio.gather(*futs)
            st = b.stats()
            b.reset_stats()
            return st, b.stats()

    st, cleared = asyncio.run(main())
    assert st["queries"] == 8
    assert st["batches"] >= 1
    assert st["p50_ms"] is not None and st["p50_ms"] > 0
    assert st["p99_ms"] is None  # 8 samples cannot support a p99
    assert set(st["tenants"]) == {"t0", "t1"}
    assert st["queue_peak"] >= 1
    assert cleared["queries"] == 0 and cleared["batches"] == 0


def test_reset_stats_clears_every_counter(store_and_truth):
    """``reset_stats`` zeroes EVERY counter ``stats()`` reports — flush
    reasons, shed, cap-growth, admission denials, queue peak, write and
    compaction counts, per-tenant counts — while retaining admission and
    write-budget STATE (cap_level, plans_charged, writes_resident), which
    governs future admissions rather than measuring the past."""
    store, T, ds = store_and_truth
    E = eng.Engine(delta.DynamicStore(store))  # writes need a delta
    s_hot, p_hot, _ = _hot_row(T)
    cfg = ExecConfig(backend="jnp", cap=2)  # tiny cap: growth guaranteed

    async def main():
        async with ServeBroker(
            E, cfg, unbounded=False,
            coalesce=CoalescePolicy(max_batch=8, max_delay_s=0.002),
            tenant_policy=TenantPolicy(
                queue_depth=2, max_cap_doublings=8, max_plans=8
            ),
        ) as b:
            # drive every counter: growth (hot row at cap=2), a shed
            # (queue_depth=2), writes, and ordinary completions
            b.submit_insert_nowait("hot", 1, 1, 2)
            b.submit_delete_nowait("hot", 1, 1, 2)
            b.submit_insert_nowait("calm", 2, 1, 3)
            futs = [b.submit_nowait("hot", eng.OP_ROW, s_hot, p_hot, 0)
                    for _ in range(2)]
            with pytest.raises(QueueFull):
                b.submit_nowait("hot", eng.OP_ROW, s_hot, p_hot, 0)
            futs += [b.submit_nowait("calm", eng.OP_CHECK,
                                     *map(int, ds.ids[i])) for i in range(2)]
            await asyncio.gather(*futs)
            st = b.stats()
            b.reset_stats()
            return st, b.stats()

    st, cleared = asyncio.run(main())
    # the run really exercised what reset must clear
    assert st["cap_growth_events"] >= 1
    assert st["shed"] == 1
    assert st["tenants"]["hot"]["cap_growth_events"] >= 1
    assert st["inserts"] == 2 and st["deletes"] == 1
    assert st["tenants"]["hot"]["inserts"] == 1
    assert st["tenants"]["hot"]["deletes"] == 1

    zero_keys = (
        "batches", "lanes", "flush_size", "flush_deadline", "flush_drain",
        "queue_peak", "shed", "cap_growth_events", "admission_denials",
        "queries", "inserts", "deletes", "compactions", "compaction_ms",
    )
    for k in zero_keys:
        assert cleared[k] == 0, (k, cleared[k])
    assert cleared["coalesce_factor"] == 0.0
    assert cleared["p50_ms"] is None and cleared["p99_ms"] is None
    for name, ts in cleared["tenants"].items():
        for k in ("queries", "failed", "shed", "pending",
                  "cap_growth_events", "inserts", "deletes"):
            assert ts[k] == 0, (name, k, ts[k])
        assert ts["p50_ms"] is None and ts["p99_ms"] is None
    # admission + write-budget STATE survives: budgets keep governing
    assert cleared["tenants"]["hot"]["cap_level"] >= 1
    assert cleared["tenants"]["hot"]["plans_charged"] >= 1
    assert cleared["tenants"]["hot"]["writes_resident"] == 2
    # delta_triples / tombstones are LIVE store gauges, not measurements
    assert cleared["tombstones"] == 1


def test_submit_after_close_rejected(store_and_truth):
    store, _, ds = store_and_truth
    E = eng.Engine(store)

    async def main():
        b = ServeBroker(E, ExecConfig(backend="jnp", cap=64), unbounded=False)
        async with b:
            await b.submit("t", eng.OP_CHECK, *map(int, ds.ids[0]))
        with pytest.raises(RuntimeError):
            b.submit_nowait("t", eng.OP_CHECK, 1, 1, 1)

    asyncio.run(main())
