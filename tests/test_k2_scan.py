"""Differential harness for the batched k²-scan Pallas kernel.

Three-way agreement on every case:

    kernels.k2_scan (interpret)  ==  kernels.ref.k2_scan_ref (jnp, scatter
    compaction)  ==  core.k2forest.scan_batch_mixed(backend="jnp") (vmapped
    traced-axis traversal)         — bit-exact, all four output arrays;

and each against the numpy dense-matrix oracle (tests/oracle.py) for the
capped-result contract.  Forest configs cover randomized matrices at several
heights, empty trees, full rows, the minimal single-cell matrix, and caps
straddling the true result count (overflow boundary); the sweep runs well
over 200 distinct (matrix, axis, key, cap) cases.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import k2forest
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ref

from oracle import (
    assert_results_identical,
    assert_scan_result,
    dense_from_coords,
    scan_truth,
)


def _forest(coords, side):
    meta = K2Meta(hybrid_ks(side))
    f, _ = k2forest.build_forest(coords, meta)
    return meta, f


def _run_all_backends(meta, f, preds, keys, axes, cap):
    """(pallas, jnp, ref) results for one query batch; asserts 3-way equality."""
    preds = jnp.asarray(preds, jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    axes = jnp.asarray(axes, jnp.int32)
    r_pl = k2forest.scan_batch_mixed(meta, f, preds, keys, axes, cap,
                                     backend="pallas")
    r_jnp = k2forest.scan_batch_mixed(meta, f, preds, keys, axes, cap,
                                      backend="jnp")
    r_ref = ref.k2_scan_ref(
        meta, preds, keys, axes, f.t_words, f.t_rank, f.l_words,
        f.ones_before, f.level_start, cap=cap,
    )
    assert_results_identical(tuple(r_pl), tuple(r_jnp), "pallas-vs-jnp")
    assert_results_identical(tuple(r_pl), tuple(r_ref), "pallas-vs-ref")
    return r_pl


def _sweep(coords, side, caps, n_keys, seed, counter):
    """Run the 3-way differential + dense oracle over a (matrix, cap) grid."""
    rng = np.random.default_rng(seed)
    meta, f = _forest(coords, side)
    dense = dense_from_coords(coords, meta.side)
    P = len(coords)
    keys1 = np.unique(
        np.concatenate([[0, side - 1], rng.integers(0, side, n_keys)])
    ).astype(np.int32)
    # every key queried on both axes, predicates round-robin
    keys = np.repeat(keys1, 2)
    axes = np.tile(np.array([0, 1], np.int32), len(keys1))
    preds = (np.arange(len(keys)) % P).astype(np.int32)
    for cap in caps:
        r = _run_all_backends(meta, f, preds, keys, axes, cap)
        ids, valid = np.asarray(r.ids), np.asarray(r.valid)
        count, ovf = np.asarray(r.count), np.asarray(r.overflow)
        for i in range(len(keys)):
            truth = scan_truth(dense[preds[i]], int(keys[i]), int(axes[i]))
            assert_scan_result(
                ids[i], valid[i], count[i], ovf[i], truth, cap,
                label=f"side={side} cap={cap} pred={preds[i]} "
                      f"key={keys[i]} axis={axes[i]}",
            )
            counter[0] += 1


def test_k2_scan_randomized_sweep():
    """≥200 randomized (matrix, axis, key, cap) cases, 3-way + dense oracle."""
    counter = [0]
    rng = np.random.default_rng(7)
    # randomized forests at three tree heights / densities
    for side, n_preds, nnz_hi, caps, n_keys, seed in [
        (60, 4, 400, (8, 64), 40, 1),     # H=3, mixed densities
        (200, 3, 900, (16, 128), 30, 2),  # H=4
        (900, 2, 1500, (32,), 30, 3),     # H=5
    ]:
        coords = []
        for _ in range(n_preds):
            n = int(rng.integers(0, nnz_hi))
            coords.append((rng.integers(0, side, n), rng.integers(0, side, n)))
        _sweep(coords, side, caps, n_keys=n_keys, seed=seed, counter=counter)
    assert counter[0] >= 200, counter[0]


def test_k2_scan_empty_trees():
    """Empty forests: zero results, no overflow, on every backend."""
    side = 120
    empty = np.zeros(0, np.int64)
    counter = [0]
    _sweep([(empty, empty)] * 2, side, caps=(1, 16), n_keys=6, seed=4,
           counter=counter)
    meta, f = _forest([(empty, empty)], side)
    r = _run_all_backends(meta, f, [0, 0], [0, side - 1], [0, 1], 8)
    assert not np.asarray(r.valid).any()
    assert (np.asarray(r.count) == 0).all()
    assert not np.asarray(r.overflow).any()


def test_k2_scan_full_rows():
    """A fully-populated matrix: every scan returns a full line (or caps)."""
    side = 64
    rr = np.repeat(np.arange(side), side)
    cc = np.tile(np.arange(side), side)
    counter = [0]
    _sweep([(rr, cc)], side, caps=(16, 64, 100), n_keys=5, seed=5,
           counter=counter)
    meta, f = _forest([(rr, cc)], side)
    r = _run_all_backends(meta, f, [0], [3], [0], 64)
    assert int(r.count[0]) == side
    assert not bool(r.overflow[0])
    assert (np.asarray(r.ids[0]) == np.arange(side)).all()


def test_k2_scan_single_cell_matrix():
    """Minimal geometry: one 1-cell in the smallest (side-2) matrix."""
    side = 2
    counter = [0]
    _sweep([(np.array([1]), np.array([0]))], side, caps=(1, 2, 4), n_keys=2,
           seed=6, counter=counter)
    meta, f = _forest([(np.array([1]), np.array([0]))], side)
    assert meta.n_levels == 1  # the L-only tree exercises the H==1 path
    r = _run_all_backends(meta, f, [0, 0, 0, 0], [1, 0, 0, 1], [0, 0, 1, 1], 2)
    assert np.asarray(r.count).tolist() == [1, 0, 1, 0]


@pytest.mark.parametrize("cap_delta", [-1, 0, 1])
def test_k2_scan_cap_overflow_boundary(cap_delta):
    """cap straddling the exact result count: count/overflow semantics."""
    side = 64
    n = 40  # 1-cells in row 0
    rng = np.random.default_rng(8)
    cols = np.sort(rng.choice(side, n, replace=False))
    meta, f = _forest([(np.zeros(n, np.int64), cols)], side)
    cap = n + cap_delta
    r = _run_all_backends(meta, f, [0], [0], [0], cap)
    truth = cols.astype(np.int32)
    assert_scan_result(r.ids[0], r.valid[0], r.count[0], r.overflow[0],
                       truth, cap, label=f"cap_delta={cap_delta}")
    if cap_delta < 0:
        assert bool(r.overflow[0])
        assert int(r.count[0]) == cap
    else:
        assert not bool(r.overflow[0])
        assert int(r.count[0]) == n


def test_k2_scan_cap_below_root_arity():
    """cap < k0 truncates the INITIAL frontier and must latch overflow."""
    side = 64  # k0 == 4
    rr = np.repeat(np.arange(side), side)
    cc = np.tile(np.arange(side), side)
    meta, f = _forest([(rr, cc)], side)
    r = _run_all_backends(meta, f, [0], [5], [0], 2)
    assert bool(r.overflow[0])
    assert np.asarray(r.ids[0]).tolist() == [0, 1]  # lowest ids survive


def test_k2_scan_mixed_axes_one_batch():
    """Row and col scans of the same key in one batch agree with separate."""
    side = 100
    rng = np.random.default_rng(9)
    coords = [(rng.integers(0, side, 500), rng.integers(0, side, 500))]
    meta, f = _forest(coords, side)
    dense = dense_from_coords(coords, meta.side)[0]
    keys = np.array([17, 17, 42, 42], np.int32)
    axes = np.array([0, 1, 0, 1], np.int32)
    r = _run_all_backends(meta, f, np.zeros(4, np.int32), keys, axes, 64)
    for i in range(4):
        truth = scan_truth(dense, int(keys[i]), int(axes[i]))
        got = np.asarray(r.ids[i])[np.asarray(r.valid[i])]
        assert (got == truth).all()
