"""Transformer family: forward/loss/grad, prefill-decode consistency,
flash attention vs dense reference."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import layers as L, transformer as tf


@pytest.fixture(scope="module")
def tiny():
    cfg = tf.TransformerCfg(
        name="tiny", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=97, chunk_q=8, chunk_kv=16,
    )
    return cfg, tf.init(cfg, jax.random.PRNGKey(0))


def test_param_count_matches_formula(tiny):
    cfg, params = tiny
    assert sum(x.size for x in jax.tree.leaves(params)) == cfg.n_params


def test_forward_and_grad_finite(tiny):
    cfg, params = tiny
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss, g = jax.value_and_grad(lambda p: tf.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(g))
    assert np.isfinite(float(gn)) and float(gn) > 0


def test_prefill_decode_matches_forward(tiny):
    cfg, params = tiny
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (2, 24), 0, cfg.vocab)
    logits_pf, cache = tf.prefill(cfg, params, toks)
    h = tf.forward(cfg, params, toks)
    logits_fw = tf.unembed_logits(cfg, params, h[:, -1:, :])[:, 0]
    np.testing.assert_allclose(
        np.asarray(logits_pf), np.asarray(logits_fw), rtol=3e-2, atol=3e-2
    )
    S, MAX = 24, 32
    cache_p = {
        k: jnp.pad(v, ((0, 0), (0, 0), (0, MAX - S), (0, 0), (0, 0)))
        for k, v in cache.items()
    }
    newtok = jax.random.randint(jax.random.PRNGKey(3), (2,), 0, cfg.vocab)
    logits_dec, _ = tf.decode_step(cfg, params, cache_p, newtok, jnp.full((2,), S, jnp.int32))
    toks_ext = jnp.concatenate([toks, newtok[:, None]], axis=1)
    h2 = tf.forward(cfg, params, toks_ext)
    logits_ext = tf.unembed_logits(cfg, params, h2[:, -1:, :])[:, 0]
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_ext), rtol=5e-2, atol=5e-2
    )


@pytest.mark.parametrize("window,cap", [(None, None), (16, None), (None, 30.0), (16, 50.0)])
def test_flash_attention_vs_dense(rng, window, cap):
    B, S, H, Kv, dh = 2, 48, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)

    def dense(q, k, v):
        G = H // Kv
        qg = q.reshape(B, S, Kv, G, dh)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k) / np.sqrt(dh)
        if cap:
            s = cap * jnp.tanh(s / cap)
        qp, kp = jnp.arange(S), jnp.arange(S)
        m = kp[None, :] <= qp[:, None]
        if window:
            m = m & (kp[None, :] > qp[:, None] - window)
        s = jnp.where(m[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bqkgc,bckd->bqkgd", p, v).reshape(B, S, H, dh)

    flash = lambda q, k, v: L.chunked_attention(
        q, k, v, causal=True, window=window, attn_softcap=cap, chunk_q=16, chunk_kv=16
    ).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(flash(q, k, v)), np.asarray(dense(q, k, v)), rtol=2e-2, atol=2e-2
    )
    g1 = jax.grad(lambda *a: flash(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: dense(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=4e-2, atol=4e-2)


def test_moe_routing_capacity(rng):
    """Every kept slot routes a real (token, expert) pair with its gate weight."""
    cfg = tf.TransformerCfg(
        name="m", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_head=8,
        d_ff=32, vocab=17, moe=tf.MoECfg(n_experts=4, top_k=2, d_ff_expert=16),
    )
    T, D = 32, 16
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    gates = jax.nn.softmax(jnp.asarray(rng.standard_normal((T, 4))), axis=-1)
    C = tf.moe_capacity(cfg, T)
    idx, wgt, valid = tf._moe_dispatch_indices(gates, 4, 2, C)
    idx, wgt, valid = np.asarray(idx), np.asarray(wgt), np.asarray(valid)
    topv, topi = jax.lax.top_k(gates, 2)
    topv = topv / topv.sum(-1, keepdims=True)
    assigned = {(int(t), int(e)) for t in range(T) for e in np.asarray(topi[t])}
    for slot in np.nonzero(valid)[0]:
        e = slot // C
        t = idx[slot]
        assert (t, e) in assigned
        expect_w = float(topv[t][np.asarray(topi[t]) == e][0])
        assert abs(wgt[slot] - expect_w) < 1e-5
    # per-expert capacity respected
    for e in range(4):
        assert valid[e * C : (e + 1) * C].sum() <= C


def test_moe_loss_finite(rng):
    cfg = tf.TransformerCfg(
        name="moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_head=8,
        d_ff=64, vocab=53, chunk_q=8, chunk_kv=8,
        moe=tf.MoECfg(n_experts=8, top_k=2, d_ff_expert=32),
    )
    p = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 53)
    loss, g = jax.value_and_grad(lambda p: tf.loss_fn(cfg, p, {"tokens": toks, "labels": toks}))(p)
    assert np.isfinite(float(loss))
    # router must receive gradient (dispatch is differentiable through gates)
    rg = g["layers"]["router"]
    assert float(jnp.abs(rg).sum()) > 0
