"""Data pipelines: RDF generator/parser, token stream, sorted-set algebra."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import sortedset
from repro.data import rdf
from repro.data.tokens import TokenStream


def test_rdf_generate_shape_stats():
    ds = rdf.generate(5000, n_subjects=200, n_preds=10, n_objects=300, seed=0)
    assert ds.ids.shape[1] == 3
    assert ds.ids[:, 0].max() <= 200 and ds.ids[:, 0].min() >= 1
    assert ds.ids[:, 1].max() <= 10
    assert ds.ids[:, 2].max() <= 300
    # duplicates removed (paper cleans them)
    assert len(np.unique(ds.ids, axis=0)) == len(ds.ids)


def test_generate_like_paper_ratios():
    ds = rdf.generate_like("geonames", 10_000)
    assert ds.n_preds <= 20  # geonames has 20 predicates


def test_parse_n3_roundtrip():
    text = '<http://a> <http://p> "literal with spaces" .\n<http://b> <http://p> <http://a> .'
    ts = rdf.parse_n3(text)
    assert ts[0] == ("http://a", "http://p", '"literal with spaces"')
    assert ts[1] == ("http://b", "http://p", "http://a")


def test_front_coded_strings():
    from repro.core.dictionary import FrontCodedStrings

    terms = sorted(f"http://example.org/resource/{i:06d}" for i in range(100))
    fc = FrontCodedStrings(terms, bucket=8)
    for i in (0, 1, 7, 8, 55, 99):
        assert fc[i] == terms[i]
    raw = sum(len(t.encode()) for t in terms)
    assert fc.size_bytes() < raw / 2  # front-coding compresses shared prefixes


def test_token_stream_learnable_structure():
    ts = TokenStream(64, 32, seed=0)
    b = ts.batch(16)
    assert b["tokens"].shape == (16, 32)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    # deterministic structure: most transitions are (t + shift) % V
    diffs = (b["labels"] - b["tokens"]) % 64
    # per-row modal diff should dominate (75% bigram structure)
    row_match = [(d == np.bincount(d).argmax()).mean() for d in diffs]
    assert np.mean(row_match) > 0.5


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(min_value=1, max_value=1000), max_size=50),
    st.lists(st.integers(min_value=1, max_value=1000), max_size=50),
)
def test_sortedset_intersect_property(a, b):
    cap = 64
    a = sorted(set(a))[:cap]
    b = sorted(set(b))[:cap]
    S = sortedset.SENTINEL

    def mk(v):
        ids = np.full(cap, S, np.int32)
        ids[: len(v)] = v
        return sortedset.IdSet(
            jnp.asarray(ids), jnp.asarray(ids != S),
            jnp.asarray(len(v), jnp.int32), jnp.asarray(False),
        )

    r = sortedset.intersect(mk(a), mk(b))
    got = np.asarray(r.ids)[np.asarray(r.valid)].tolist()
    assert got == sorted(set(a) & set(b))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.lists(st.integers(min_value=1, max_value=500), max_size=20), min_size=1, max_size=5))
def test_sortedset_union_property(rows):
    cap = 64
    S = sortedset.SENTINEL
    P = len(rows)
    ids = np.full((P, cap), S, np.int32)
    valid = np.zeros((P, cap), bool)
    for i, r in enumerate(rows):
        r = sorted(set(r))[:cap]
        ids[i, : len(r)] = r
        valid[i, : len(r)] = True
    r = sortedset.union_rows(jnp.asarray(ids), jnp.asarray(valid), cap, False)
    got = np.asarray(r.ids)[np.asarray(r.valid)].tolist()
    exp = sorted(set().union(*[set(x) for x in rows]))[:cap]
    assert got == exp
