"""All 8 triple patterns + join categories A–F against a brute-force oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, k2triples
from repro.core.dictionary import build_dictionary
from repro.data import rdf


@pytest.fixture(scope="module")
def store_and_oracle():
    ds = rdf.generate(3000, n_subjects=120, n_preds=7, n_objects=150, seed=1)
    store = k2triples.from_id_triples(
        ds.ids, n_so=ds.n_so, n_subjects=ds.n_subjects,
        n_objects=ds.n_objects, n_preds=ds.n_preds,
    )
    T = set(map(tuple, ds.ids.tolist()))
    return store, T, ds


def test_patterns_all_eight(store_and_oracle):
    store, T, ds = store_and_oracle
    E = eng.Engine(store, cap=1024)
    rng = np.random.default_rng(2)
    samples = ds.ids[rng.integers(0, ds.n_triples, 10)]
    s, p, o = map(int, samples[3])

    assert E.pattern(s, p, o) is True
    assert E.pattern(s, p, int(ds.n_objects)) == ((s, p, ds.n_objects) in T)
    assert set(E.pattern(s, None, o).tolist()) == {
        pp for (ss, pp, oo) in T if ss == s and oo == o
    }
    assert E.pattern(s, p, None).tolist() == sorted(
        oo for (ss, pp, oo) in T if ss == s and pp == p
    )
    assert E.pattern(None, p, o).tolist() == sorted(
        ss for (ss, pp, oo) in T if pp == p and oo == o
    )
    exp = {}
    for (ss, pp, oo) in T:
        if ss == s:
            exp.setdefault(pp, []).append(oo)
    got = E.pattern(s, None, None)
    assert {k: sorted(v) for k, v in exp.items()} == {k: v.tolist() for k, v in got.items()}
    exp = {}
    for (ss, pp, oo) in T:
        if oo == o:
            exp.setdefault(pp, []).append(ss)
    got = E.pattern(None, None, o)
    assert {k: sorted(v) for k, v in exp.items()} == {k: v.tolist() for k, v in got.items()}
    got = E.pattern(None, p, None)
    assert sorted(map(tuple, got.tolist())) == sorted(
        (ss, oo) for (ss, pp, oo) in T if pp == p
    )
    # (?S,?P,?O): dump
    got = E.pattern(None, None, None)
    dumped = {(ss, pp, oo) for pp, pairs in got.items() for ss, oo in pairs.tolist()}
    assert dumped == T


def _side(T, p, const, vpos):
    if vpos == "s":
        return sorted({s for (s, pp, o) in T if (p is None or pp == p) and o == const})
    return sorted({o for (s, pp, o) in T if (p is None or pp == p) and s == const})


def test_joins_a_to_f(store_and_oracle):
    store, T, ds = store_and_oracle
    E = eng.Engine(store, cap=1024)
    rng = np.random.default_rng(3)
    samples = ds.ids[rng.integers(0, ds.n_triples, 4)]
    p1, o1 = int(samples[0][1]), int(samples[0][2])
    p2, o2 = int(samples[1][1]), int(samples[1][2])
    s1, s2 = int(samples[0][0]), int(samples[1][0])

    # A (SS / OO / SO)
    got = E.join("A", p1=p1, c1=o1, vpos1="s", p2=p2, c2=o2, vpos2="s")
    assert got.tolist() == sorted(set(_side(T, p1, o1, "s")) & set(_side(T, p2, o2, "s")))
    got = E.join("A", p1=p1, c1=s1, vpos1="o", p2=p2, c2=s2, vpos2="o")
    assert got.tolist() == sorted(set(_side(T, p1, s1, "o")) & set(_side(T, p2, s2, "o")))
    got = E.join("A", p1=p1, c1=o1, vpos1="s", p2=p2, c2=s2, vpos2="o")
    assert got.tolist() == sorted(set(_side(T, p1, o1, "s")) & set(_side(T, p2, s2, "o")))

    # B
    got = E.join("B", p1=p1, c1=o1, vpos1="s", c2=o2, vpos2="s")
    l1 = set(_side(T, p1, o1, "s"))
    exp = {}
    for pp in range(1, ds.n_preds + 1):
        inter = sorted(l1 & set(_side(T, pp, o2, "s")))
        if inter:
            exp[pp] = inter
    assert {k: v.tolist() for k, v in got.items()} == exp

    # C
    got = E.join("C", c1=o1, vpos1="s", c2=o2, vpos2="s")
    assert got.tolist() == sorted(set(_side(T, None, o1, "s")) & set(_side(T, None, o2, "s")))

    # D
    got = E.join("D", p1=p1, c1=o1, vpos1="s", p2=p2, vpos2="o")
    exp = {}
    for x in _side(T, p1, o1, "s"):
        ys = sorted({ss for (ss, pp, oo) in T if pp == p2 and oo == x})
        if ys:
            exp[x] = ys
    assert {k: v.tolist() for k, v in got.items()} == exp

    # E
    got = E.join("E", p1=p1, c1=o1, vpos1="s", vpos2="o")
    exp = {}
    for pp in range(1, ds.n_preds + 1):
        d = {}
        for x in _side(T, p1, o1, "s"):
            ys = sorted({ss for (ss, p3, oo) in T if p3 == pp and oo == x})
            if ys:
                d[x] = ys
        if d:
            exp[pp] = d
    assert {k: {kk: vv.tolist() for kk, vv in v.items()} for k, v in got.items()} == exp

    # F
    got = E.join("F", c1=o1, vpos1="s", vpos2="o")
    exp = {}
    for pp in range(1, ds.n_preds + 1):
        d = {}
        for x in _side(T, None, o1, "s"):
            ys = sorted({ss for (ss, p3, oo) in T if p3 == pp and oo == x})
            if ys:
                d[x] = ys
        if d:
            exp[pp] = d
    assert {k: {kk: vv.tolist() for kk, vv in v.items()} for k, v in got.items()} == exp


def test_serve_step_batched(store_and_oracle):
    store, T, ds = store_and_oracle
    rng = np.random.default_rng(4)
    B = 64
    ops = rng.integers(0, 3, B).astype(np.int32)
    ids = ds.ids[rng.integers(0, ds.n_triples, B)]
    q = eng.ServeBatch(
        op=jnp.asarray(ops), s=jnp.asarray(ids[:, 0], jnp.int32),
        p=jnp.asarray(ids[:, 1], jnp.int32), o=jnp.asarray(ids[:, 2], jnp.int32),
    )
    serve = eng.make_serve_step(store.meta, cap=512)
    r = serve(store.forest, q)
    hit, rids, valid = np.asarray(r.hit), np.asarray(r.ids), np.asarray(r.valid)
    for i in range(B):
        s_, p_, o_ = map(int, ids[i])
        if ops[i] == 0:
            assert hit[i] == ((s_, p_, o_) in T)
        elif ops[i] == 1:
            assert rids[i][valid[i]].tolist() == sorted(
                oo for (ss, pp, oo) in T if ss == s_ and pp == p_
            )
        else:
            assert rids[i][valid[i]].tolist() == sorted(
                ss for (ss, pp, oo) in T if pp == p_ and oo == o_
            )


def test_dictionary_roundtrip(store_and_oracle):
    _, _, ds = store_and_oracle
    strs = rdf.to_strings(ds)[:500]
    d = build_dictionary(strs)
    enc = d.encode_triples(strs)
    for (st_, pt, ot), (si, pi, oi) in zip(strs, enc):
        assert d.decode_subject(si) == st_
        assert d.decode_predicate(pi) == pt
        assert d.decode_object(oi) == ot
    # SO terms shared range (paper Fig. 2)
    assert d.n_so == len(set(t[0] for t in strs) & set(t[2] for t in strs))


def test_string_pipeline_end_to_end():
    text = """
<http://ex/a> <http://ex/p1> <http://ex/b> .
<http://ex/b> <http://ex/p1> <http://ex/c> .
<http://ex/a> <http://ex/p2> "lit" .
"""
    triples = rdf.parse_n3(text)
    store = k2triples.from_string_triples(triples)
    E = eng.Engine(store, cap=64)
    d = store.dictionary
    a = d.encode_subject("http://ex/a")
    p1 = d.encode_predicate("http://ex/p1")
    b = d.encode_object("http://ex/b")
    assert E.pattern(a, p1, b) is True
    # b plays both roles -> shared SO range id
    assert d.encode_subject("http://ex/b") == d.encode_object("http://ex/b")
