"""Differential harness for the batched k²-range Pallas kernel ((?S,P,?O)).

Three-way agreement on every case:

    kernels.k2_range (interpret)  ==  kernels.ref.k2_range_ref (jnp, scatter
    compaction)  ==  core.k2forest.range_scan_batch(backend="jnp") (vmapped
    traversal)                      — bit-exact, all five output arrays;

and each against the numpy Morton-order oracle (tests/oracle.py) for the
capped fixed-shape ``PairResult`` contract.  Includes the level-0 overflow
regression: the pre-fix traversal truncated the root radix to ``cap``
*before* the bit test, so a sparse matrix under a large root radix falsely
reported overflow and silently dropped candidates.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import k2forest
from repro.core.k2tree import K2Meta, hybrid_ks
from repro.kernels import ref

from oracle import (
    assert_pair_result,
    assert_results_identical,
    dense_from_coords,
    morton_pairs_truth,
)


def _forest(coords, side):
    meta = K2Meta(hybrid_ks(side))
    f, _ = k2forest.build_forest(coords, meta)
    return meta, f


def _run_all_backends(meta, f, preds, cap):
    """(pallas, jnp, ref) results for one pred batch; asserts 3-way equality."""
    preds = jnp.asarray(preds, jnp.int32)
    r_pl = k2forest.range_scan_batch(meta, f, preds, cap, backend="pallas")
    r_jnp = k2forest.range_scan_batch(meta, f, preds, cap, backend="jnp")
    r_ref = ref.k2_range_ref(
        meta, preds, f.t_words, f.t_rank, f.l_words,
        f.ones_before, f.level_start, cap=cap,
    )
    assert_results_identical(tuple(r_pl), tuple(r_jnp), "pallas-vs-jnp")
    assert_results_identical(tuple(r_pl), tuple(r_ref), "pallas-vs-ref")
    return r_pl


def _sweep(coords, side, caps, counter):
    meta, f = _forest(coords, side)
    dense = dense_from_coords(coords, meta.side)
    P = len(coords)
    truths = [morton_pairs_truth(d, meta.ks) for d in dense]
    for cap in caps:
        r = _run_all_backends(meta, f, np.arange(P, dtype=np.int32), cap)
        for p in range(P):
            tr, tc = truths[p]
            assert_pair_result(
                r.rows[p], r.cols[p], r.valid[p], r.count[p], r.overflow[p],
                tr, tc, cap, label=f"side={side} cap={cap} pred={p}",
            )
            counter[0] += 1


def test_k2_range_randomized_sweep():
    """Randomized (matrix, cap) grid at three heights, 3-way + Morton oracle."""
    counter = [0]
    rng = np.random.default_rng(11)
    for side, n_preds, nnz_hi, caps, seed in [
        (60, 4, 300, (8, 64, 512), 1),    # H=3
        (200, 3, 700, (16, 1024), 2),     # H=4
        (900, 2, 1200, (32, 2048), 3),    # H=5, r0=16
    ]:
        coords = []
        for _ in range(n_preds):
            n = int(rng.integers(0, nnz_hi))
            coords.append((rng.integers(0, side, n), rng.integers(0, side, n)))
        _sweep(coords, side, caps, counter)
    assert counter[0] >= 20, counter[0]


def test_k2_range_empty_and_full():
    side = 64
    empty = np.zeros(0, np.int64)
    rr = np.repeat(np.arange(side), side)
    cc = np.tile(np.arange(side), side)
    counter = [0]
    _sweep([(empty, empty), (rr, cc)], side, caps=(1, 16, side * side), counter=counter)
    meta, f = _forest([(empty, empty)], side)
    r = _run_all_backends(meta, f, [0], 8)
    assert not np.asarray(r.valid).any()
    assert int(r.count[0]) == 0
    assert not bool(r.overflow[0])


def test_k2_range_single_cell_h1():
    """Minimal geometry: the H==1 (L-only) tree."""
    side = 2
    meta, f = _forest([(np.array([1]), np.array([0]))], side)
    assert meta.n_levels == 1
    for cap in (1, 2, 4):
        r = _run_all_backends(meta, f, [0], cap)
        assert int(r.count[0]) == 1
        assert not bool(r.overflow[0])
        assert int(r.rows[0][0]) == 1 and int(r.cols[0][0]) == 0


def test_k2_range_level0_overflow_regression():
    """cap below the ROOT RADIX on a sparse matrix: the old traversal both
    falsely latched overflow (r0 > cap) and dropped any candidate whose root
    child index exceeded cap.  Fixed semantics: bit-test every root child,
    compact, overflow only on real frontier truncation."""
    side = 900  # H=5: root radix r0 = 16
    meta = K2Meta(hybrid_ks(side))
    assert meta.radices[0] == 16
    # two cells in root children 0 and 15 — the second died under truncation
    rows = np.array([3, 870])
    cols = np.array([5, 2])
    f, _ = k2forest.build_forest([(rows, cols)], meta)
    cap = 4  # < r0
    r = _run_all_backends(meta, f, [0], cap)
    assert int(r.count[0]) == 2
    assert not bool(r.overflow[0])  # 2 occupied root children <= cap
    dense = dense_from_coords([(rows, cols)], meta.side)[0]
    tr, tc = morton_pairs_truth(dense, meta.ks)
    assert_pair_result(r.rows[0], r.cols[0], r.valid[0], r.count[0],
                       r.overflow[0], tr, tc, cap, label="level0-regression")
    # the single-tree jnp reference is fixed the same way
    from repro.core import k2tree
    tree = k2tree.build(rows, cols, meta)
    rt = k2tree.range_scan(meta, tree, cap=cap)
    assert int(rt.count) == 2 and not bool(rt.overflow)


@pytest.mark.parametrize("cap_delta", [-1, 0, 1])
def test_k2_range_cap_overflow_boundary(cap_delta):
    """cap straddling the exact pair count: count/overflow semantics."""
    side = 64
    n = 30
    rng = np.random.default_rng(12)
    flat = rng.choice(side * side, n, replace=False)
    rows, cols = flat // side, flat % side
    meta, f = _forest([(rows, cols)], side)
    cap = n + cap_delta
    r = _run_all_backends(meta, f, [0], cap)
    dense = dense_from_coords([(rows, cols)], meta.side)[0]
    tr, tc = morton_pairs_truth(dense, meta.ks)
    assert_pair_result(r.rows[0], r.cols[0], r.valid[0], r.count[0],
                       r.overflow[0], tr, tc, cap, label=f"delta={cap_delta}")
    if cap_delta < 0:
        assert bool(r.overflow[0])
        assert int(r.count[0]) == cap
    else:
        assert not bool(r.overflow[0])
        assert int(r.count[0]) == n


def test_k2_range_all_preds_dump():
    """range_scan_all_preds == per-pred range_scan; follows the backend flag."""
    side = 100
    rng = np.random.default_rng(13)
    coords = [
        (rng.integers(0, side, 200), rng.integers(0, side, 200)),
        (np.zeros(0, np.int64), np.zeros(0, np.int64)),  # empty predicate
        (rng.integers(0, side, 50), rng.integers(0, side, 50)),
    ]
    meta, f = _forest(coords, side)
    r_all = {be: k2forest.range_scan_all_preds(meta, f, 256, backend=be)
             for be in ("pallas", "jnp")}
    assert_results_identical(
        tuple(r_all["pallas"]), tuple(r_all["jnp"]), "dump pallas-vs-jnp"
    )
    dense = dense_from_coords(coords, meta.side)
    for p in range(3):
        one = k2forest.range_scan(meta, f, p, 256, backend="pallas")
        for a, b in zip(tuple(one), tuple(r_all["pallas"])):
            assert (np.asarray(a) == np.asarray(b)[p]).all()
        tr, tc = morton_pairs_truth(dense[p], meta.ks)
        assert_pair_result(
            one.rows, one.cols, one.valid, one.count, one.overflow,
            tr, tc, 256, label=f"dump pred={p}",
        )
